"""Atomic, sharded, async checkpointing.

Layout: ``<dir>/step_<N>/`` containing one ``.npz`` per host-shard (this
single-host build writes ``shard_0.npz``) plus ``meta.json``. Writes go
to ``step_<N>.tmp/`` and are renamed only after fsync — a crashed save
never corrupts the latest checkpoint, and ``latest_step`` only believes
fully-renamed directories (restart-safe).

``AsyncCheckpointer`` double-buffers: the params are fetched to host
memory synchronously (cheap: device->host copy) and serialized on a
background thread so the train loop overlaps the disk write with the
next steps. At fleet scale each host writes only its own param shards;
here the shard list is what ``jax.tree_util.tree_flatten_with_path``
yields on one host.
"""
from __future__ import annotations

import json
import os
import re
import shutil
import threading
from typing import Any

import jax
import ml_dtypes
import numpy as np

PyTree = Any

# dtypes numpy's npz cannot round-trip -> stored as a same-width uint view
_VIEW_AS = {
    np.dtype(ml_dtypes.bfloat16): np.uint16,
    np.dtype(ml_dtypes.float8_e4m3fn): np.uint8,
    np.dtype(ml_dtypes.float8_e5m2): np.uint8,
}


def _flatten(tree: PyTree):
    flat, treedef = jax.tree_util.tree_flatten_with_path(tree)
    names, leaves, dtypes = [], [], []
    for path, leaf in flat:
        name = "/".join(
            str(p.key) if hasattr(p, "key") else str(p.idx) for p in path
        )
        arr = np.asarray(leaf)
        dtypes.append(str(arr.dtype))
        if arr.dtype in _VIEW_AS:
            arr = arr.view(_VIEW_AS[arr.dtype])
        names.append(name)
        leaves.append(arr)
    return names, leaves, dtypes, treedef


def _unview(arr: np.ndarray, dtype_str: str) -> np.ndarray:
    dt = np.dtype(dtype_str)
    if dt in _VIEW_AS and arr.dtype == _VIEW_AS[dt]:
        return arr.view(dt)
    return arr


def save_checkpoint(directory: str, step: int, tree: PyTree, extra_meta: dict | None = None):
    """Atomic synchronous save."""
    os.makedirs(directory, exist_ok=True)
    final = os.path.join(directory, f"step_{step}")
    tmp = final + ".tmp"
    if os.path.exists(tmp):
        shutil.rmtree(tmp)
    os.makedirs(tmp)
    names, leaves, dtypes, _ = _flatten(tree)
    payload = {f"arr_{i}": a for i, a in enumerate(leaves)}
    np.savez(os.path.join(tmp, "shard_0.npz"), **payload)
    meta = {
        "step": step,
        "names": names,
        "dtypes": dtypes,
        **(extra_meta or {}),
    }
    with open(os.path.join(tmp, "meta.json"), "w") as f:
        json.dump(meta, f)
        f.flush()
        os.fsync(f.fileno())
    if os.path.exists(final):
        shutil.rmtree(final)
    os.rename(tmp, final)
    return final


def latest_step(directory: str) -> int | None:
    if not os.path.isdir(directory):
        return None
    steps = []
    for d in os.listdir(directory):
        m = re.fullmatch(r"step_(\d+)", d)
        if m and os.path.exists(os.path.join(directory, d, "meta.json")):
            steps.append(int(m.group(1)))
    return max(steps) if steps else None


def restore_checkpoint(directory: str, step: int, like: PyTree) -> tuple[PyTree, dict]:
    """Restore into the structure of ``like`` (shapes must match)."""
    path = os.path.join(directory, f"step_{step}")
    with open(os.path.join(path, "meta.json")) as f:
        meta = json.load(f)
    data = np.load(os.path.join(path, "shard_0.npz"))
    leaves = [
        _unview(data[f"arr_{i}"], dt)
        for i, dt in enumerate(meta["dtypes"])
    ]
    ref_flat, treedef = jax.tree_util.tree_flatten(like)
    assert len(ref_flat) == len(leaves), (
        f"checkpoint has {len(leaves)} leaves, expected {len(ref_flat)}"
    )
    restored = [
        np.asarray(a).astype(r.dtype).reshape(r.shape)
        for a, r in zip(leaves, ref_flat)
    ]
    return treedef.unflatten(restored), meta


class AsyncCheckpointer:
    """Double-buffered background writer. ``wait()`` before exit."""

    def __init__(self, directory: str):
        self.directory = directory
        self._thread: threading.Thread | None = None
        self._error: BaseException | None = None

    def save(self, step: int, tree: PyTree, extra_meta: dict | None = None):
        self.wait()
        host_tree = jax.tree.map(np.asarray, tree)  # sync device->host

        def work():
            try:
                save_checkpoint(self.directory, step, host_tree, extra_meta)
            except BaseException as e:  # surfaced on next wait()
                self._error = e

        self._thread = threading.Thread(target=work, daemon=True)
        self._thread.start()

    def wait(self):
        if self._thread is not None:
            self._thread.join()
            self._thread = None
        if self._error is not None:
            err, self._error = self._error, None
            raise err
