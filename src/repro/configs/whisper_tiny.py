"""whisper-tiny — enc-dec audio [arXiv:2212.04356].

The conv frontend is a STUB per the assignment: ``input_specs()``
provides precomputed frame embeddings (B, 1500, d_model). 4 encoder + 4
decoder layers, LayerNorm + GELU, sinusoidal positions (no RoPE).
"""
from repro.configs.base import ModelConfig

CONFIG = ModelConfig(
    name="whisper-tiny",
    family="audio",
    num_layers=4,
    d_model=384,
    num_heads=6,
    num_kv_heads=6,
    d_ff=1536,
    vocab_size=51_865,
    activation="gelu",
    num_encoder_layers=4,
    encoder_seq=1500,
)
