"""grok-1-314b — 8-expert top-2 MoE [hf:xai-org/grok-1]."""
from repro.configs.base import ModelConfig

CONFIG = ModelConfig(
    name="grok-1-314b",
    family="moe",
    num_layers=64,
    d_model=6144,
    num_heads=48,
    num_kv_heads=8,
    d_ff=32_768,  # per-expert FFN width
    vocab_size=131_072,
    num_experts=8,
    top_k=2,
    head_dim=128,
)
