"""paligemma-3b — SigLIP + Gemma decoder [arXiv:2407.07726].

The SigLIP vision tower is a STUB per the assignment: ``input_specs()``
provides precomputed patch embeddings (B, 256, d_model); the decoder is
the Gemma-style transformer below (MQA: kv=1).
"""
from repro.configs.base import ModelConfig

CONFIG = ModelConfig(
    name="paligemma-3b",
    family="vlm",
    num_layers=18,
    d_model=2048,
    num_heads=8,
    num_kv_heads=1,
    d_ff=16_384,
    vocab_size=257_216,
    head_dim=256,
    activation="gelu",
    num_image_tokens=256,
)
