"""Architecture registry: ``--arch <id>`` -> ModelConfig."""
from __future__ import annotations

from repro.configs.base import (
    ALL_SHAPES,
    SHAPES_BY_NAME,
    ModelConfig,
    ShapeConfig,
    shapes_for,
)
from repro.configs.granite_3_2b import CONFIG as GRANITE_3_2B
from repro.configs.grok_1_314b import CONFIG as GROK_1_314B
from repro.configs.h2o_danube_3_4b import CONFIG as H2O_DANUBE_3_4B
from repro.configs.moonshot_v1_16b_a3b import CONFIG as MOONSHOT_V1_16B_A3B
from repro.configs.paligemma_3b import CONFIG as PALIGEMMA_3B
from repro.configs.qwen3_0p6b import CONFIG as QWEN3_0P6B
from repro.configs.whisper_tiny import CONFIG as WHISPER_TINY
from repro.configs.xlstm_125m import CONFIG as XLSTM_125M
from repro.configs.yi_9b import CONFIG as YI_9B
from repro.configs.zamba2_1p2b import CONFIG as ZAMBA2_1P2B

ARCHS: dict[str, ModelConfig] = {
    c.name: c
    for c in (
        ZAMBA2_1P2B,
        MOONSHOT_V1_16B_A3B,
        GROK_1_314B,
        XLSTM_125M,
        GRANITE_3_2B,
        QWEN3_0P6B,
        H2O_DANUBE_3_4B,
        YI_9B,
        PALIGEMMA_3B,
        WHISPER_TINY,
    )
}


def get_arch(name: str) -> ModelConfig:
    if name not in ARCHS:
        raise KeyError(f"unknown arch {name!r}; available: {sorted(ARCHS)}")
    return ARCHS[name]


def all_cells():
    """Every (arch, shape) dry-run cell — 40 total."""
    for cfg in ARCHS.values():
        for shape in shapes_for(cfg):
            yield cfg, shape


__all__ = [
    "ALL_SHAPES",
    "ARCHS",
    "ModelConfig",
    "SHAPES_BY_NAME",
    "ShapeConfig",
    "all_cells",
    "get_arch",
    "shapes_for",
]
