"""xlstm-125m — sLSTM + mLSTM blocks [arXiv:2405.04517].

d_ff = 0: xLSTM blocks carry their own up/down projections (proj_factor),
so there is no separate FFN sublayer. Every 6th layer is sLSTM (the
paper's sparse-sLSTM placements), the rest mLSTM.
"""
from repro.configs.base import ModelConfig

CONFIG = ModelConfig(
    name="xlstm-125m",
    family="ssm",
    num_layers=12,
    d_model=768,
    num_heads=4,
    num_kv_heads=4,
    d_ff=0,
    vocab_size=50_304,
    slstm_every=6,
    proj_factor=2.0,
)
