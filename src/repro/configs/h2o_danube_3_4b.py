"""h2o-danube-3-4b — llama+mistral mix with sliding-window attention [arXiv:2401.16818]."""
from repro.configs.base import ModelConfig

CONFIG = ModelConfig(
    name="h2o-danube-3-4b",
    family="dense",
    num_layers=24,
    d_model=3840,
    num_heads=32,
    num_kv_heads=8,
    d_ff=10_240,
    vocab_size=32_000,
    sliding_window=4096,  # rolling KV cache -> eligible for long_500k decode
)
