"""zamba2-1.2b — Mamba2 backbone + shared attention block [arXiv:2411.15242]."""
from repro.configs.base import ModelConfig

CONFIG = ModelConfig(
    name="zamba2-1.2b",
    family="hybrid",
    num_layers=38,
    d_model=2048,
    num_heads=32,
    num_kv_heads=32,
    d_ff=8192,
    vocab_size=32_000,
    ssm_state=64,
    mamba_expand=2,
    mamba_head_dim=64,
    attn_every=6,  # shared attn+MLP block invoked every 6 Mamba2 layers
)
