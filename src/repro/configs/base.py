"""Config system: ModelConfig (architecture) + ShapeConfig (workload)."""
from __future__ import annotations

import dataclasses

import jax.numpy as jnp

DTYPES = {"float32": jnp.float32, "bfloat16": jnp.bfloat16}


@dataclasses.dataclass(frozen=True)
class ModelConfig:
    name: str
    family: str  # dense | moe | ssm | hybrid | vlm | audio
    num_layers: int
    d_model: int
    num_heads: int
    num_kv_heads: int
    d_ff: int
    vocab_size: int
    head_dim: int | None = None
    qk_norm: bool = False
    sliding_window: int | None = None
    rope_theta: float = 10_000.0
    activation: str = "silu"
    # --- MoE ---
    num_experts: int = 0
    top_k: int = 0
    capacity_factor: float = 1.25
    # --- SSM / hybrid ---
    ssm_state: int = 0
    mamba_head_dim: int = 64
    mamba_expand: int = 2
    attn_every: int = 0  # hybrid: shared attn+mlp block every k mamba layers
    # --- xLSTM ---
    slstm_every: int = 0  # every k-th layer is sLSTM (others mLSTM)
    proj_factor: float = 2.0
    # --- enc-dec (audio) ---
    num_encoder_layers: int = 0
    encoder_seq: int = 0  # precomputed frame embeddings (conv frontend stub)
    # --- vlm ---
    num_image_tokens: int = 0  # precomputed patch embeddings (SigLIP stub)
    # --- numerics / execution ---
    param_dtype: str = "float32"
    compute_dtype: str = "bfloat16"
    tie_embeddings: bool = True
    remat: bool = True
    # scan over stacked layers (small HLO, fast compile). The dry-run
    # unrolls instead: XLA cost analysis counts a while-loop body ONCE,
    # so roofline terms from a scanned module undercount by ~num_layers.
    scan_layers: bool = True
    # --- §Perf hillclimb knobs (EXPERIMENTS.md) ---
    # logits dtype: "float32" (baseline) or "bfloat16" (halves the
    # dominant (B,S,V) memory term; CE reductions still accumulate f32)
    logits_dtype: str = "float32"
    # skip fully-masked causal attention blocks (lower-triangular kv
    # iteration instead of the full grid): ~2x attention-FLOP cut
    causal_block_skip: bool = False
    # int8 KV cache (per-token-per-head symmetric scales): halves the
    # cache-read term that dominates decode
    kv_quant: bool = False
    attn_q_block: int = 512
    attn_kv_block: int = 1024
    mamba_chunk: int = 256

    @property
    def resolved_head_dim(self) -> int:
        return self.head_dim or self.d_model // self.num_heads

    @property
    def pdtype(self):
        return DTYPES[self.param_dtype]

    @property
    def cdtype(self):
        return DTYPES[self.compute_dtype]

    @property
    def sub_quadratic(self) -> bool:
        """Eligible for the 500k-context decode shape (O(1)/O(window) state)."""
        return self.family in ("ssm", "hybrid") or self.sliding_window is not None

    @property
    def has_decode(self) -> bool:
        return True  # all assigned archs are decoder-bearing

    def reduced(self) -> "ModelConfig":
        """Tiny same-family variant for CPU smoke tests."""
        return dataclasses.replace(
            self,
            name=self.name + "-smoke",
            num_layers=min(self.num_layers, 4 if self.attn_every == 0 else self.attn_every + 1),
            d_model=128,
            num_heads=max(4, min(self.num_heads, 4)),
            num_kv_heads=min(self.num_kv_heads, 2) if self.num_kv_heads > 1 else 1,
            d_ff=256 if self.d_ff else 0,
            vocab_size=512,
            head_dim=32,
            num_experts=min(self.num_experts, 8),
            top_k=min(self.top_k, 2),
            ssm_state=min(self.ssm_state, 16) if self.ssm_state else 0,
            mamba_head_dim=32,
            sliding_window=64 if self.sliding_window else None,
            num_encoder_layers=min(self.num_encoder_layers, 2),
            encoder_seq=32 if self.encoder_seq else 0,
            num_image_tokens=16 if self.num_image_tokens else 0,
            param_dtype="float32",
            compute_dtype="float32",
            attn_q_block=32,
            attn_kv_block=32,
            mamba_chunk=16,
        )


@dataclasses.dataclass(frozen=True)
class ShapeConfig:
    name: str
    seq_len: int
    global_batch: int
    kind: str  # "train" | "prefill" | "decode"


TRAIN_4K = ShapeConfig("train_4k", 4_096, 256, "train")
PREFILL_32K = ShapeConfig("prefill_32k", 32_768, 32, "prefill")
DECODE_32K = ShapeConfig("decode_32k", 32_768, 128, "decode")
LONG_500K = ShapeConfig("long_500k", 524_288, 1, "decode")

ALL_SHAPES = (TRAIN_4K, PREFILL_32K, DECODE_32K, LONG_500K)
SHAPES_BY_NAME = {s.name: s for s in ALL_SHAPES}


def shapes_for(config: ModelConfig) -> tuple[ShapeConfig, ...]:
    """The shape cells defined for an architecture.

    ``long_500k`` needs sub-quadratic attention: run for SSM/hybrid/SWA
    archs, skip for pure full-attention archs (noted in DESIGN.md).
    """
    out = [TRAIN_4K, PREFILL_32K, DECODE_32K]
    if config.sub_quadratic:
        out.append(LONG_500K)
    return tuple(out)
