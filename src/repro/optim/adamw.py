"""AdamW + warmup-cosine schedule + global-norm clipping, pure JAX.

Optimizer moments dtype is configurable: f32 default; bf16 for the
largest archs (grok-314b) so params+moments+grads fit the pod (see
DESIGN.md §9 and EXPERIMENTS.md §Dry-run memory table).
"""
from __future__ import annotations

import dataclasses
from typing import Any

import jax
import jax.numpy as jnp

PyTree = Any


@dataclasses.dataclass(frozen=True)
class AdamWConfig:
    lr: float = 3e-4
    b1: float = 0.9
    b2: float = 0.95
    eps: float = 1e-8
    weight_decay: float = 0.1
    clip_norm: float = 1.0
    warmup_steps: int = 100
    total_steps: int = 10_000
    min_lr_ratio: float = 0.1
    moment_dtype: str = "float32"  # "bfloat16" for memory-bound archs


def cosine_schedule(cfg: AdamWConfig, step):
    step = jnp.asarray(step, jnp.float32)
    warm = step / jnp.maximum(cfg.warmup_steps, 1)
    prog = (step - cfg.warmup_steps) / jnp.maximum(
        cfg.total_steps - cfg.warmup_steps, 1
    )
    prog = jnp.clip(prog, 0.0, 1.0)
    cos = cfg.min_lr_ratio + (1 - cfg.min_lr_ratio) * 0.5 * (1 + jnp.cos(jnp.pi * prog))
    return cfg.lr * jnp.where(step < cfg.warmup_steps, warm, cos)


def adamw_init(cfg: AdamWConfig, params: PyTree) -> PyTree:
    dt = jnp.bfloat16 if cfg.moment_dtype == "bfloat16" else jnp.float32
    zeros = lambda p: jnp.zeros(p.shape, dt)
    return {
        "m": jax.tree.map(zeros, params),
        "v": jax.tree.map(zeros, params),
        "count": jnp.zeros((), jnp.int32),
    }


def global_norm(tree: PyTree):
    return jnp.sqrt(
        sum(jnp.sum(jnp.square(g.astype(jnp.float32))) for g in jax.tree.leaves(tree))
    )


def adamw_update(cfg: AdamWConfig, grads: PyTree, opt_state: PyTree, params: PyTree):
    """One AdamW step. Returns (new_params, new_opt_state, metrics)."""
    count = opt_state["count"] + 1
    gnorm = global_norm(grads)
    scale = jnp.minimum(1.0, cfg.clip_norm / jnp.maximum(gnorm, 1e-12))
    lr = cosine_schedule(cfg, count)
    b1, b2 = cfg.b1, cfg.b2
    bc1 = 1 - b1 ** count.astype(jnp.float32)
    bc2 = 1 - b2 ** count.astype(jnp.float32)

    def upd(p, g, m, v):
        g32 = g.astype(jnp.float32) * scale
        m32 = b1 * m.astype(jnp.float32) + (1 - b1) * g32
        v32 = b2 * v.astype(jnp.float32) + (1 - b2) * g32 * g32
        step = (m32 / bc1) / (jnp.sqrt(v32 / bc2) + cfg.eps)
        decay = cfg.weight_decay * p.astype(jnp.float32) if p.ndim >= 2 else 0.0
        new_p = p.astype(jnp.float32) - lr * (step + decay)
        return new_p.astype(p.dtype), m32.astype(m.dtype), v32.astype(v.dtype)

    flat_p, treedef = jax.tree.flatten(params)
    flat_g = treedef.flatten_up_to(grads)
    flat_m = treedef.flatten_up_to(opt_state["m"])
    flat_v = treedef.flatten_up_to(opt_state["v"])
    out = [upd(p, g, m, v) for p, g, m, v in zip(flat_p, flat_g, flat_m, flat_v)]
    new_params = treedef.unflatten([o[0] for o in out])
    new_m = treedef.unflatten([o[1] for o in out])
    new_v = treedef.unflatten([o[2] for o in out])
    return (
        new_params,
        {"m": new_m, "v": new_v, "count": count},
        {"grad_norm": gnorm, "lr": lr},
    )
