"""Gradient compression for cross-pod reduction.

bf16 compression with error feedback: the quantization residual is
carried to the next step so the compressed SGD direction is unbiased in
the long run (EF-SGD). Applied only to the cross-pod all-reduce — the
intra-pod reduce stays full precision (ICI is fast; DCN between pods is
the scarce resource at 1000+ node scale).
"""
from __future__ import annotations

from typing import Any

import jax
import jax.numpy as jnp

PyTree = Any


def init_error_feedback(params: PyTree) -> PyTree:
    return jax.tree.map(lambda p: jnp.zeros(p.shape, jnp.float32), params)


def compress_bf16_ef(grads: PyTree, ef: PyTree):
    """(grads, ef) -> (compressed bf16 grads, new ef residuals)."""

    def one(g, e):
        corrected = g.astype(jnp.float32) + e
        q = corrected.astype(jnp.bfloat16)
        return q, corrected - q.astype(jnp.float32)

    flat = jax.tree.map(one, grads, ef)
    comp = jax.tree.map(lambda t: t[0], flat, is_leaf=lambda t: isinstance(t, tuple))
    new_ef = jax.tree.map(lambda t: t[1], flat, is_leaf=lambda t: isinstance(t, tuple))
    return comp, new_ef


def decompress_bf16_ef(comp: PyTree) -> PyTree:
    return jax.tree.map(lambda g: g.astype(jnp.float32), comp)
