from repro.optim.adamw import AdamWConfig, adamw_init, adamw_update, cosine_schedule
from repro.optim.compression import (
    compress_bf16_ef,
    decompress_bf16_ef,
    init_error_feedback,
)

__all__ = [
    "AdamWConfig",
    "adamw_init",
    "adamw_update",
    "compress_bf16_ef",
    "cosine_schedule",
    "decompress_bf16_ef",
    "init_error_feedback",
]
