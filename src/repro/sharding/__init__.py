from repro.sharding.rules import (
    batch_specs,
    cache_spec,
    make_batch_sharding,
    make_cache_sharding,
    make_param_sharding,
    param_spec,
)

__all__ = [
    "batch_specs",
    "cache_spec",
    "make_batch_sharding",
    "make_cache_sharding",
    "make_param_sharding",
    "param_spec",
]
