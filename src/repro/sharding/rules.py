"""Sharding rules: param-path pattern -> PartitionSpec, with divisibility
fallbacks.

Strategy (baseline, see EXPERIMENTS.md §Perf for hillclimbed variants):

* 2D logical layout per weight matrix — FSDP shard along the ``data``
  axis and tensor-parallel shard along the ``model`` axis:
    in-projections  (D, X):     P("data", "model")
    out-projections (X, D):     P("model", "data")
    embedding       (V, D):     P("model", "data")   (vocab-parallel)
    experts         (E, D, F):  P("model", "data", None)  (expert-parallel)
* Stacked layer params carry a leading L dim -> specs shift right one.
* The ``pod`` axis replicates params (pure DP across pods); the batch is
  sharded over ("pod", "data").
* Any dim not divisible by its mesh-axis extent falls back to
  unsharded on that axis (GQA head counts, odd vocab, tiny models) —
  compilation must succeed for every assigned arch on the production
  mesh, so the rules degrade rather than fail.
"""
from __future__ import annotations

import re

import jax
import numpy as np
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P

# (path regex, spec WITHOUT the stacked-layer dim). Longest match wins.
_RULES: tuple[tuple[str, tuple], ...] = (
    # embeddings / lm head (tied)
    (r"embed/table$", ("model", "data")),
    # attention
    (r"(attn|self_attn|cross_attn)/wq$", ("data", "model")),
    (r"(attn|self_attn|cross_attn)/wk$", ("data", "model")),
    (r"(attn|self_attn|cross_attn)/wv$", ("data", "model")),
    (r"(attn|self_attn|cross_attn)/wo$", ("model", "data")),
    # dense mlp
    (r"mlp/w_gate$", ("data", "model")),
    (r"mlp/w_up$", ("data", "model")),
    (r"mlp/w_down$", ("model", "data")),
    # moe (expert-parallel on model axis)
    (r"moe/w_router$", ("data", None)),
    (r"moe/w_gate$", ("model", "data", None)),
    (r"moe/w_up$", ("model", "data", None)),
    (r"moe/w_down$", ("model", None, "data")),
    # mamba2
    (r"mamba/w_in$", ("data", "model")),
    (r"mamba/w_out$", ("model", "data")),
    (r"mamba/conv_w$", (None, "model")),
    # xlstm
    (r"cell/w_up$", ("data", "model")),
    (r"cell/w[qkv]$", ("data", "model")),
    (r"cell/w_if$", ("data", None)),
    (r"cell/w_down$", ("model", "data")),
    (r"cell/w_x$", ("data", "model")),
    (r"cell/w_h$", ("model", None, None)),
    (r"cell/w_out$", ("data", "model")),
)


def _path_str(path) -> str:
    parts = []
    for p in path:
        if hasattr(p, "key"):
            parts.append(str(p.key))
        elif hasattr(p, "idx"):
            parts.append(str(p.idx))
        else:
            parts.append(str(p))
    return "/".join(parts)


def _fit(spec: tuple, shape: tuple, mesh: Mesh) -> P:
    """Drop axes whose extent does not divide the corresponding dim."""
    out = []
    for dim, ax in zip(shape, spec):
        if ax is None:
            out.append(None)
            continue
        axes = (ax,) if isinstance(ax, str) else tuple(ax)
        size = int(np.prod([mesh.shape[a] for a in axes]))
        out.append(ax if dim % size == 0 else None)
    # pad to rank
    out += [None] * (len(shape) - len(out))
    return P(*out)


def param_spec(path, leaf_shape, mesh: Mesh, *, stacked_depth: int = 0) -> P:
    """PartitionSpec for one param leaf.

    stacked_depth: how many leading dims are layer-stacking dims (scanned
    stacks have 1). Detected automatically by the caller from path names.

    MoE expert weights whose expert count does not divide the `model`
    axis (e.g. grok's 8 experts on a 16-wide axis) fall back to sharding
    the FFN dim on `model` instead of replicating: a replicated expert
    tensor makes GSPMD compute every expert redundantly on all 16 model
    shards (measured 16x useful-FLOP blowup — EXPERIMENTS.md §Perf).
    """
    name = _path_str(path)
    moe = re.search(r"moe/w_(gate|up|down)$", name)
    if moe:
        experts = leaf_shape[stacked_depth]
        model = mesh.shape.get("model", 1)
        if experts % model != 0:
            if moe.group(1) == "down":  # (E, F, D)
                spec = (None, "model", "data")
            else:  # (E, D, F)
                spec = (None, "data", "model")
            full = (None,) * stacked_depth + spec
            return _fit(full, leaf_shape, mesh)
    for pat, spec in _RULES:
        if re.search(pat, name):
            full = (None,) * stacked_depth + tuple(spec)
            return _fit(full, leaf_shape, mesh)
    return _fit((None,) * len(leaf_shape), leaf_shape, mesh)  # replicated


_STACKED_CONTAINERS = ("blocks", "encoder")
_UNSTACKED = ("shared_attn",)  # hybrid shared block is NOT stacked


def _is_stacked(path) -> bool:
    name_parts = []
    for p in path:
        if hasattr(p, "key"):
            name_parts.append(str(p.key))
    if not name_parts:
        return False
    if name_parts[0] in _UNSTACKED:
        return False
    # python-list blocks (ssm family) index with SequenceKey -> not stacked
    for p in path:
        if hasattr(p, "idx"):
            return False
    return name_parts[0] in _STACKED_CONTAINERS


def make_param_sharding(mesh: Mesh, params_shape, *, strategy: str = "2d") -> object:
    """Tree of NamedSharding matching a params (or opt-state) shape tree.

    strategy:
      "2d"         — FSDP on `data` + TP on `model` (baseline).
      "replicated" — pure data parallelism: params replicated, batch
                     sharded over BOTH data axes. For small archs this
                     removes every per-layer weight all-gather (§Perf).
    """

    def one(path, leaf):
        if strategy == "replicated":
            return NamedSharding(mesh, P(*([None] * len(leaf.shape))))
        depth = 1 if _is_stacked(path) else 0
        return NamedSharding(mesh, param_spec(path, leaf.shape, mesh, stacked_depth=depth))

    return jax.tree_util.tree_map_with_path(one, params_shape)


def batch_specs(mesh: Mesh, global_batch: int, *, include_model: bool = False) -> P:
    """Token batches shard over every data-like axis that divides B."""
    names = ("pod", "data", "model") if include_model else ("pod", "data")
    axes = [a for a in names if a in mesh.shape]
    size = int(np.prod([mesh.shape[a] for a in axes]))
    while axes and global_batch % size != 0:
        axes.pop(0)
        size = int(np.prod([mesh.shape[a] for a in axes]))
    if not axes:
        return P(None, None)
    return P(tuple(axes), None)


def make_batch_sharding(mesh: Mesh, batch_shape_tree, *,
                        include_model: bool = False) -> object:
    """Sharding tree for {"tokens","labels",("extras")} ShapeDtypeStructs."""

    def one(path, leaf):
        b = leaf.shape[0]
        spec = batch_specs(mesh, b, include_model=include_model)
        full = tuple(spec) + (None,) * (len(leaf.shape) - len(tuple(spec)))
        return NamedSharding(mesh, _fit(full, leaf.shape, mesh))

    return jax.tree_util.tree_map_with_path(one, batch_shape_tree)


def cache_spec(path, leaf_shape, mesh: Mesh) -> P:
    """Decode caches: batch on data axes, heads/features on model.

    kv k/v: (L, B, S, KV, hd) -> (None, data, None, model, None)
    ssm state: (L, B, H, N, P) -> (None, data, model, None, None)
    everything else: batch-sharded on dim of size B where possible.
    """
    name = _path_str(path)
    if re.search(r"kv/(k|v)$", name):
        # Prefer KV-head sharding on "model"; GQA counts that don't divide
        # the axis fall back to sharding the cache SEQ dim instead (the
        # decode softmax then reduces over a sharded axis — GSPMD inserts
        # the all-reduce; still far cheaper than replicating the cache).
        kv_heads, seq = leaf_shape[3], leaf_shape[2]
        model = mesh.shape.get("model", 1)
        if kv_heads % model == 0:
            return _fit((None, "data", None, "model", None), leaf_shape, mesh)
        if seq % model == 0:
            return _fit((None, "data", "model", None, None), leaf_shape, mesh)
        return _fit((None, "data", None, None, None), leaf_shape, mesh)
    if re.search(r"kv/(k|v)_scale$", name):  # (L, B, S, KV)
        kv_heads, seq = leaf_shape[3], leaf_shape[2]
        model = mesh.shape.get("model", 1)
        if kv_heads % model == 0:
            return _fit((None, "data", None, "model"), leaf_shape, mesh)
        if seq % model == 0:
            return _fit((None, "data", "model", None), leaf_shape, mesh)
        return _fit((None, "data", None, None), leaf_shape, mesh)
    if re.search(r"kv/pos$", name):
        return P(*([None] * len(leaf_shape)))
    if re.search(r"^ssm$", name) or re.search(r"/ssm$", name):
        return _fit((None, "data", "model", None, None), leaf_shape, mesh)
    if re.search(r"conv$", name):
        return _fit((None, "data", None, None), leaf_shape, mesh)
    if re.search(r"enc_out$", name):
        return _fit(("data", None, None), leaf_shape, mesh)
    # xlstm states: (B, H, ...) batch on data
    return _fit(("data",) + (None,) * (len(leaf_shape) - 1), leaf_shape, mesh)


def make_cache_sharding(mesh: Mesh, cache_shape_tree) -> object:
    def one(path, leaf):
        return NamedSharding(mesh, cache_spec(path, leaf.shape, mesh))

    return jax.tree_util.tree_map_with_path(one, cache_shape_tree)
