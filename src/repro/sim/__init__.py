"""Cluster-dynamics scenario subsystem (DESIGN.md §7).

Generates the non-stationary conditions — straggler drift, worker churn,
bandwidth collapse, correlated rack incidents — that the closed-loop
adaptive controller (``repro.runtime.control``) must survive. Scenarios
are seeded and deterministic; the registry mirrors the allocation-scheme
registry.
"""
from repro.sim.events import (
    BadRack,
    BandwidthFade,
    Event,
    MuRandomWalk,
    MuStep,
    TraceState,
    WorkerChurn,
)
from repro.sim.scenario import (
    ClusterTrace,
    ScenarioSpec,
    make_scenario,
    register_scenario,
    scenario_kinds,
    scenario_names,
)

__all__ = [
    "BadRack",
    "BandwidthFade",
    "ClusterTrace",
    "Event",
    "MuRandomWalk",
    "MuStep",
    "ScenarioSpec",
    "TraceState",
    "WorkerChurn",
    "make_scenario",
    "register_scenario",
    "scenario_kinds",
    "scenario_names",
]
