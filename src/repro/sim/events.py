"""Composable cluster-dynamics event primitives (DESIGN.md §7).

The paper's allocation assumes the group parameters ``(a_j, mu_j)`` are
known and static; deployed clusters drift. Each primitive below perturbs
one aspect of a ``ClusterSpec`` over simulated rounds — straggler-rate
drift, worker churn, bandwidth degradation, a correlated rack failure —
and a ``ScenarioSpec`` (``repro.sim.scenario``) composes them into a
seeded, deterministic ``ClusterTrace``.

Mechanics: the trace generator walks a mutable ``TraceState`` (per-group
``num_workers/mu/alpha/bandwidth`` arrays) through the horizon, calling
``event.step(state, t, rng)`` for every event each round, then snapshots
a ``ClusterSpec``. Persistent events (random walks, step changes, churn)
mutate the state once; windowed events (bandwidth fade, bad rack) apply
a multiplicative factor on entry and undo it on exit, so they compose
with any drift that happened inside the window.
"""
from __future__ import annotations

import dataclasses

import numpy as np

from repro.core.runtime_model import ClusterSpec, GroupSpec

#: clamp band for perturbed mu — mirrors StragglerTracker's MLE clamp
#: (the shifted-exp model is only meaningful below ~750)
MU_MIN, MU_MAX = 1e-3, 750.0


@dataclasses.dataclass
class TraceState:
    """Mutable per-group state the event primitives evolve."""

    num_workers: np.ndarray  # (G,) int
    mu: np.ndarray  # (G,) float
    alpha: np.ndarray  # (G,) float
    bandwidth: np.ndarray  # (G,) float (inf = free links)

    @classmethod
    def from_cluster(cls, cluster: ClusterSpec) -> "TraceState":
        return cls(
            num_workers=np.asarray(
                [g.num_workers for g in cluster.groups], np.int64
            ),
            mu=np.asarray([g.mu for g in cluster.groups], float),
            alpha=np.asarray([g.alpha for g in cluster.groups], float),
            bandwidth=cluster.bandwidths.copy(),
        )

    def snapshot(self) -> ClusterSpec:
        """Current state as an immutable ClusterSpec (mu clamped sane)."""
        mu = np.clip(self.mu, MU_MIN, MU_MAX)
        return ClusterSpec(
            tuple(
                GroupSpec(int(n), float(m), float(a), float(b))
                for n, m, a, b in zip(
                    self.num_workers, mu, self.alpha, self.bandwidth
                )
            )
        )


@dataclasses.dataclass(frozen=True)
class Event:
    """Base event: ``step`` is called once per round, in composition order."""

    def step(self, state: TraceState, t: int, rng: np.random.Generator):
        raise NotImplementedError

    def _groups(self, state: TraceState, group: int | None) -> np.ndarray:
        if group is None:
            return np.arange(state.mu.shape[0])
        if not 0 <= group < state.mu.shape[0]:
            raise ValueError(
                f"{type(self).__name__}: group {group} out of range for a "
                f"{state.mu.shape[0]}-group cluster"
            )
        return np.asarray([group])


@dataclasses.dataclass(frozen=True)
class MuRandomWalk(Event):
    """Lognormal per-round random walk of a group's straggling rate.

    ``mu <- mu * exp(N(bias, sigma^2))`` each round: ``sigma`` is the
    per-round drift scale, ``bias`` an optional deterministic trend
    (negative = the group slowly degrades — the classic shared-cluster
    pattern where a worker pool gets progressively busier).
    """

    sigma: float = 0.05
    bias: float = 0.0
    group: int | None = None  # None = every group walks independently

    def __post_init__(self):
        if self.sigma < 0:
            raise ValueError(f"MuRandomWalk sigma must be >= 0, got {self.sigma}")

    def step(self, state, t, rng):
        idx = self._groups(state, self.group)
        steps = rng.normal(self.bias, self.sigma, size=idx.shape[0])
        state.mu[idx] = np.clip(state.mu[idx] * np.exp(steps), MU_MIN, MU_MAX)


@dataclasses.dataclass(frozen=True)
class MuStep(Event):
    """One-shot step change of a group's mu at round ``at`` (x ``factor``).

    ``factor < 1`` is the canonical straggler onset (the group suddenly
    slows down); ``factor > 1`` models recovery or an upgrade.
    """

    at: int
    group: int
    factor: float

    def __post_init__(self):
        if self.at < 0:
            raise ValueError(f"MuStep at must be >= 0, got {self.at}")
        if not self.factor > 0:
            raise ValueError(f"MuStep factor must be > 0, got {self.factor}")

    def step(self, state, t, rng):
        if t == self.at:
            idx = self._groups(state, self.group)
            state.mu[idx] = np.clip(state.mu[idx] * self.factor, MU_MIN, MU_MAX)


@dataclasses.dataclass(frozen=True)
class WorkerChurn(Event):
    """Join/leave burst: round ``at`` resizes a group by ``frac``.

    ``frac = -0.4`` removes 40% of the group's CURRENT workers (leave
    burst, never below one worker); ``frac = +0.5`` adds 50% (join
    burst / scale-up). Joins only become load-bearing once the
    controller replans them in — exactly the elasticity gap the
    adaptive loop closes.
    """

    at: int
    group: int
    frac: float

    def __post_init__(self):
        if self.at < 0:
            raise ValueError(f"WorkerChurn at must be >= 0, got {self.at}")
        if self.frac == 0 or not np.isfinite(self.frac):
            raise ValueError(
                f"WorkerChurn frac must be a nonzero fraction, got {self.frac}"
            )

    def step(self, state, t, rng):
        if t == self.at:
            idx = int(self._groups(state, self.group)[0])
            cur = int(state.num_workers[idx])
            delta = int(round(self.frac * cur))
            state.num_workers[idx] = max(1, cur + delta)


@dataclasses.dataclass(frozen=True)
class _WindowedEvent(Event):
    """Multiplicative perturbation active on rounds ``[start, end)``."""

    start: int = 0
    end: int = 0

    def __post_init__(self):
        if not 0 <= self.start < self.end:
            raise ValueError(
                f"{type(self).__name__} needs 0 <= start < end, got "
                f"[{self.start}, {self.end})"
            )

    def _apply(self, state: TraceState, invert: bool):
        raise NotImplementedError

    def step(self, state, t, rng):
        if t == self.start:
            self._apply(state, invert=False)
        elif t == self.end:
            self._apply(state, invert=True)


@dataclasses.dataclass(frozen=True)
class BandwidthFade(_WindowedEvent):
    """Link degradation: a group's bandwidth x ``factor`` during the window.

    Recovery is the window's end. Only schemes under the CommDelay model
    react (infinite-bandwidth groups are unaffected by construction —
    ``inf * factor == inf``).
    """

    group: int = 0
    factor: float = 0.1

    def __post_init__(self):
        super().__post_init__()
        if not 0 < self.factor:
            raise ValueError(
                f"BandwidthFade factor must be > 0, got {self.factor}"
            )

    def _apply(self, state, invert):
        idx = self._groups(state, self.group)
        f = 1.0 / self.factor if invert else self.factor
        state.bandwidth[idx] = state.bandwidth[idx] * f


@dataclasses.dataclass(frozen=True)
class BadRack(_WindowedEvent):
    """Correlated rack-level incident: one group's mu AND bandwidth collapse.

    Models a top-of-rack switch brownout or thermal event — compute slows
    (``mu_factor``) and the link degrades (``bw_factor``) together for
    the whole group, then both recover at the window's end.
    """

    group: int = 0
    mu_factor: float = 0.1
    bw_factor: float = 0.1

    def __post_init__(self):
        super().__post_init__()
        if not (self.mu_factor > 0 and self.bw_factor > 0):
            raise ValueError(
                f"BadRack factors must be > 0, got mu_factor={self.mu_factor}, "
                f"bw_factor={self.bw_factor}"
            )

    def _apply(self, state, invert):
        idx = self._groups(state, self.group)
        mf = 1.0 / self.mu_factor if invert else self.mu_factor
        bf = 1.0 / self.bw_factor if invert else self.bw_factor
        state.mu[idx] = np.clip(state.mu[idx] * mf, MU_MIN, MU_MAX)
        state.bandwidth[idx] = state.bandwidth[idx] * bf
