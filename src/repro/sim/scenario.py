"""ScenarioSpec / ClusterTrace: seeded cluster-dynamics scenarios (§7).

A ``ScenarioSpec`` is a frozen, registered description of how a cluster
misbehaves over time — a composition of the event primitives in
``repro.sim.events`` plus a horizon and a classification ``kind``
(``drift`` / ``churn`` / ``control``). ``spec.trace(base, seed)``
expands it against a concrete base ``ClusterSpec`` into a
``ClusterTrace``: a time-indexed tuple of perturbed ``ClusterSpec``s,
fully deterministic in ``(spec, base, seed)`` so scenario replays are
exact (the adaptive-controller tests and ``benchmarks/fig_adapt.py``
depend on this).

The registry mirrors the allocation-scheme registry
(``repro.core.schemes``): scenarios are registered by name with a
factory whose *named* keyword parameters are the accepted params;
``make_scenario`` rejects anything else, and ``scenario_names()`` feeds
CLI ``choices`` so ``--scenario`` is validated for free.

Built-in scenarios assume >= 2 groups (events target group indices 0/1)
with group 0 conventionally the fastest — the shape every benchmark
fleet in this repo has.
"""
from __future__ import annotations

import dataclasses
import inspect
from typing import Callable

import numpy as np

from repro.core.runtime_model import ClusterSpec
from repro.sim.events import (
    BadRack,
    BandwidthFade,
    Event,
    MuRandomWalk,
    MuStep,
    TraceState,
    WorkerChurn,
)

#: scenario classifications: ``control`` scenarios are stationary (the
#: adaptive controller should HOLD); ``drift``/``churn`` are the
#: non-stationary cases it must win on (fig_adapt's acceptance split).
KINDS = ("drift", "churn", "control")


@dataclasses.dataclass(frozen=True)
class ScenarioSpec:
    """One named cluster-dynamics scenario (frozen, registry citizen)."""

    name: str
    events: tuple[Event, ...]
    horizon: int = 120
    kind: str = "control"
    #: the registered allocation scheme whose adaptivity this scenario
    #: exercises (bandwidth scenarios need a CommDelay scheme to matter)
    scheme: str = "optimal"
    description: str = ""

    def __post_init__(self):
        if self.horizon <= 0:
            raise ValueError(f"scenario horizon must be > 0, got {self.horizon}")
        if self.kind not in KINDS:
            raise ValueError(
                f"scenario kind must be one of {KINDS}, got {self.kind!r}"
            )

    def trace(
        self,
        base: ClusterSpec,
        seed: int = 0,
        horizon: int | None = None,
    ) -> "ClusterTrace":
        """Expand against a base cluster into a deterministic trace.

        Events step BEFORE each round's snapshot, so an event ``at=0``
        already shapes the first round. ``horizon`` overrides the
        spec's (e.g. a trainer clamps the trace to its step budget).
        """
        h = self.horizon if horizon is None else int(horizon)
        if h <= 0:
            raise ValueError(f"trace horizon must be > 0, got {h}")
        rng = np.random.default_rng(seed)
        state = TraceState.from_cluster(base)
        clusters = []
        for t in range(h):
            for ev in self.events:
                ev.step(state, t, rng)
            clusters.append(state.snapshot())
        return ClusterTrace(scenario=self.name, clusters=tuple(clusters))


@dataclasses.dataclass(frozen=True)
class ClusterTrace:
    """Time-indexed sequence of perturbed ClusterSpecs (one per round)."""

    scenario: str
    clusters: tuple[ClusterSpec, ...]

    @property
    def horizon(self) -> int:
        return len(self.clusters)

    def at(self, t: int) -> ClusterSpec:
        """Cluster state at round t (clamped to the trace's ends)."""
        return self.clusters[min(max(int(t), 0), len(self.clusters) - 1)]

    def membership(self, t: int) -> tuple[int, ...]:
        """Per-group worker counts at round t (the registration truth)."""
        return tuple(g.num_workers for g in self.at(t).groups)

    def change_rounds(self) -> tuple[int, ...]:
        """Rounds whose cluster differs from the previous round's."""
        return tuple(
            t
            for t in range(1, len(self.clusters))
            if self.clusters[t] != self.clusters[t - 1]
        )


# --------------------------------------------------------------- registry
ScenarioFactory = Callable[..., ScenarioSpec]


@dataclasses.dataclass(frozen=True)
class _Registration:
    factory: ScenarioFactory
    params: frozenset


_REGISTRY: dict[str, _Registration] = {}


def _factory_params(factory: ScenarioFactory) -> frozenset:
    try:
        sig = inspect.signature(factory)
    except (TypeError, ValueError):
        return frozenset()
    return frozenset(
        p.name
        for p in sig.parameters.values()
        if p.kind in (p.POSITIONAL_OR_KEYWORD, p.KEYWORD_ONLY)
    )


def register_scenario(
    name: str, factory: ScenarioFactory, *, params=None
) -> None:
    """Register a scenario factory under a lookup name (scheme-registry
    semantics: the factory's named keyword params are the accepted
    params; ``make_scenario`` rejects anything outside them)."""
    if name in _REGISTRY:
        raise ValueError(f"scenario {name!r} already registered")
    accepted = _factory_params(factory) if params is None else frozenset(params)
    _REGISTRY[name] = _Registration(factory, accepted)


def scenario_names() -> tuple[str, ...]:
    """All registered scenario names (CLI choices, benchmark sweeps)."""
    return tuple(sorted(_REGISTRY))


def scenario_kinds() -> dict[str, str]:
    """name -> kind for every registered scenario (default params)."""
    return {name: make_scenario(name).kind for name in scenario_names()}


def make_scenario(name: str, **params) -> ScenarioSpec:
    """Resolve a registered scenario name + params to a ScenarioSpec.

    ``None`` values mean "not provided" and are dropped (so CLI callers
    can pass optional flags unconditionally); unknown parameters raise.
    """
    if name not in _REGISTRY:
        raise ValueError(
            f"unknown scenario {name!r}; registered: "
            f"{', '.join(scenario_names())}"
        )
    reg = _REGISTRY[name]
    provided = {key: v for key, v in params.items() if v is not None}
    unknown = sorted(set(provided) - reg.params)
    if unknown:
        accepted = ", ".join(sorted(reg.params)) or "(none)"
        raise ValueError(
            f"scenario {name!r} does not accept parameter(s) "
            f"{', '.join(unknown)}; accepted: {accepted}"
        )
    return reg.factory(**provided)


# ------------------------------------------------------ built-in scenarios
def _make_static(*, horizon=None):
    return ScenarioSpec(
        name="static",
        events=(),
        horizon=int(horizon or 120),
        kind="control",
        description="stationary cluster — the adaptive controller must "
                    "hold (any replan here is wasted recompilation)",
    )


def _make_noise(*, horizon=None, sigma=None):
    return ScenarioSpec(
        name="noise",
        events=(MuRandomWalk(sigma=float(sigma if sigma is not None else 0.01)),),
        horizon=int(horizon or 120),
        kind="control",
        description="estimation noise only: a tiny unbiased mu walk — "
                    "hysteresis must absorb it without replanning",
    )


def _make_mu_drift(*, horizon=None, sigma=None, bias=None):
    h = int(horizon or 120)
    # per-round defaults scale with the horizon so the TOTAL drift is
    # horizon-invariant (walk dispersion ~ sigma*sqrt(h), trend ~ bias*h):
    # a reduced-horizon replay stresses the controller identically
    sigma = float(sigma) if sigma is not None else 0.44 / np.sqrt(h)
    bias = float(bias) if bias is not None else -3.6 / h
    return ScenarioSpec(
        name="mu_drift",
        events=(
            MuRandomWalk(sigma=sigma),
            # the fast group slowly degrades (shared-cluster contention):
            # a deterministic trend the static plan cannot track
            MuRandomWalk(sigma=0.0, bias=bias, group=0),
        ),
        horizon=h,
        kind="drift",
        description="all groups random-walk; the fast group trends slower "
                    "round over round (total drift horizon-invariant)",
    )


def _make_mu_step(*, horizon=None, factor=None, at=None):
    h = int(horizon or 120)
    return ScenarioSpec(
        name="mu_step",
        events=(
            MuStep(
                at=int(at if at is not None else h // 3),
                group=0,
                factor=float(factor if factor is not None else 0.05),
            ),
        ),
        horizon=h,
        kind="drift",
        description="the fastest group's mu collapses 20x mid-trace — the "
                    "canonical straggler onset the controller must catch",
    )


def _make_churn(*, horizon=None, frac=None):
    h = int(horizon or 120)
    f = float(frac if frac is not None else 0.5)
    if not 0 < f < 1:
        raise ValueError(f"churn frac must be in (0, 1), got {f}")
    return ScenarioSpec(
        name="churn",
        events=(
            WorkerChurn(at=h // 4, group=1, frac=-f),
            # frac applies to the group's CURRENT (shrunken) size, so
            # restoring the original capacity needs f/(1-f), not f
            WorkerChurn(at=(2 * h) // 3, group=1, frac=f / (1.0 - f)),
        ),
        horizon=h,
        kind="churn",
        description="the biggest group loses half its workers, then a "
                    "join burst restores the original capacity "
                    "(load-bearing only after a replan)",
    )


def _make_bw_collapse(*, horizon=None, factor=None):
    h = int(horizon or 120)
    return ScenarioSpec(
        name="bw_collapse",
        events=(
            BandwidthFade(
                start=h // 3, end=(2 * h) // 3, group=0,
                factor=float(factor if factor is not None else 0.02),
            ),
        ),
        horizon=h,
        kind="drift",
        scheme="comm_aware",
        description="the fast group's link degrades 50x then recovers — "
                    "only a CommDelay scheme can route around it",
    )


def _make_bad_rack(*, horizon=None):
    h = int(horizon or 120)
    return ScenarioSpec(
        name="bad_rack",
        events=(
            BadRack(start=h // 3, end=(2 * h) // 3, group=0,
                    mu_factor=0.1, bw_factor=0.1),
        ),
        horizon=h,
        kind="drift",
        scheme="comm_aware",
        description="correlated rack incident: one group's compute AND "
                    "link collapse together, then recover",
    )


register_scenario("static", _make_static)
register_scenario("noise", _make_noise)
register_scenario("mu_drift", _make_mu_drift)
register_scenario("mu_step", _make_mu_step)
register_scenario("churn", _make_churn)
register_scenario("bw_collapse", _make_bw_collapse)
register_scenario("bad_rack", _make_bad_rack)
