"""Deterministic synthetic LM data pipeline.

Tokens are a counter-mode hash of (step, batch row, position) — fully
deterministic, seekable (restore = set the step counter), and cheap. A
Markov-ish structure (next token depends on a rolling mix of previous
ids) gives the loss a learnable signal so the end-to-end training example
can show loss actually decreasing rather than memorizing noise.

The pipeline is checkpointable: ``state()`` returns {"step": int}, and
``SyntheticLMData(..., start_step=...)`` resumes exactly.
"""
from __future__ import annotations

import dataclasses

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs.base import ModelConfig, ShapeConfig


def _hash2d(step: int, b: int, s: int, seed: int) -> np.ndarray:
    """uint32 counter hash (splitmix-style), vectorized over (b, s)."""
    bi = np.arange(b, dtype=np.uint64)[:, None]
    si = np.arange(s, dtype=np.uint64)[None, :]
    with np.errstate(over="ignore"):  # uint64 wraparound is the hash
        x = (np.uint64(step) * np.uint64(0x9E3779B97F4A7C15)
             + bi * np.uint64(0xBF58476D1CE4E5B9)
             + si * np.uint64(0x94D049BB133111EB)
             + np.uint64(seed))
        x ^= x >> np.uint64(30)
        x *= np.uint64(0xBF58476D1CE4E5B9)
        x ^= x >> np.uint64(27)
        x *= np.uint64(0x94D049BB133111EB)
        x ^= x >> np.uint64(31)
    return (x & np.uint64(0xFFFFFFFF)).astype(np.uint32)


@dataclasses.dataclass
class SyntheticLMData:
    """Iterator of {"tokens": (B, S) i32, "labels": (B, S) i32} batches."""

    config: ModelConfig
    shape: ShapeConfig
    seed: int = 0
    start_step: int = 0
    learnable: bool = True

    def __post_init__(self):
        self._step = self.start_step

    def state(self) -> dict:
        return {"step": self._step, "seed": self.seed}

    def _raw(self, step: int) -> np.ndarray:
        b, s = self.shape.global_batch, self.shape.seq_len
        h = _hash2d(step, b, s + 1, self.seed)
        v = self.config.vocab_size
        if not self.learnable:
            return (h % np.uint32(v)).astype(np.int32)
        # Markov structure: token_t mixes a small random step with
        # token_{t-1}, so the conditional entropy is well below log V.
        base = (h % np.uint32(17)).astype(np.int64)
        toks = np.cumsum(base, axis=1) % v
        return toks.astype(np.int32)

    def next_batch(self) -> dict:
        seq = self._raw(self._step)  # (B, S+1)
        self._step += 1
        batch = {
            "tokens": jnp.asarray(seq[:, :-1]),
            "labels": jnp.asarray(seq[:, 1:]),
        }
        extras = make_extras(self.config, self.shape.global_batch)
        if extras:
            batch["extras"] = extras
        return batch


def make_extras(config: ModelConfig, batch: int):
    """Modality-frontend STUBS: precomputed embeddings per the assignment."""
    if config.family == "vlm":
        return {
            "image_embeds": jnp.zeros(
                (batch, config.num_image_tokens, config.d_model), config.cdtype
            )
        }
    if config.family == "audio":
        return {
            "frames": jnp.zeros(
                (batch, config.encoder_seq, config.d_model), config.cdtype
            )
        }
    return None


def make_batch_specs(config: ModelConfig, shape: ShapeConfig):
    """ShapeDtypeStruct stand-ins for one training batch (dry-run input)."""
    b, s = shape.global_batch, shape.seq_len
    batch = {
        "tokens": jax.ShapeDtypeStruct((b, s), jnp.int32),
        "labels": jax.ShapeDtypeStruct((b, s), jnp.int32),
    }
    if config.family == "vlm":
        batch["extras"] = {
            "image_embeds": jax.ShapeDtypeStruct(
                (b, config.num_image_tokens, config.d_model), config.cdtype
            )
        }
    if config.family == "audio":
        batch["extras"] = {
            "frames": jax.ShapeDtypeStruct(
                (b, config.encoder_seq, config.d_model), config.cdtype
            )
        }
    return batch
