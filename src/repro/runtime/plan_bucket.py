"""Shape-bucketed deployment plans (DESIGN.md §11).

A replan changes the integerized per-group loads, which changes the
slot count ``n`` and every ``(n,)``/``(W,)`` array a compiled consumer
program was traced against — so, pre-bucketing, every accepted replan
recompiled the fused serve/train program, and ``AdaptiveController``
had to amortize that through ``replan_cost``. Bucketing removes the
recompile for most replans:

* **Quantization** — per-group integer loads are rounded UP to
  multiples of a small ``quantum``. Rounding up preserves coverage
  (workers compute at least as many coded rows as the real-valued
  optimum asks) at a bounded redundancy overshoot, and collapses nearby
  plans onto a small set of *bucket signatures*. Two plans in the same
  bucket have IDENTICAL deployed shapes and worker->slot scatter maps.
* **Stacked branch state** — ``PlanBucketSet`` holds up to ``capacity``
  admitted buckets as stacked host arrays padded to a fixed slot
  capacity ``n_cap``; ``device_state()`` exposes them as one pytree of
  ``(B, ...)`` arrays that consumers pass as RUNTIME ARGUMENTS to their
  compiled programs (never closed over — closures bake at trace time),
  and ``select_bucket`` picks the active branch *inside* the program
  with ``lax.switch`` on a runtime bucket index. An intra-bucket (or
  cross-bucket, within capacity) replan therefore changes only array
  VALUES, never shapes: zero retraces, zero host round-trips.

``CodedRoundExecutor`` owns admission/eviction and the structural
escape hatch (worker count changed, or ``n`` outgrew ``n_cap`` — the
only cases that still rebuild and retrace).
"""
from __future__ import annotations

import dataclasses
from collections import OrderedDict

import jax
import jax.numpy as jnp
import numpy as np
from jax import lax

from repro.core.planner import DeploymentPlan, integerize
from repro.core.runtime_model import ClusterSpec


@dataclasses.dataclass(frozen=True)
class BucketConfig:
    """Quantization / capacity knobs for plan bucketing.

    quantum: per-group integer loads round UP to multiples of this.
    capacity: max simultaneously-compiled bucket branches (LRU evict).
    n_headroom: slot capacity ``n_cap = ceil(n0 * n_headroom)`` over the
      initial plan's quantized slot count; replans needing more slots
      trigger a structural rebuild.
    """

    quantum: int = 4
    capacity: int = 8
    n_headroom: float = 1.5

    def __post_init__(self):
        if self.quantum < 1:
            raise ValueError(f"quantum must be >= 1, got {self.quantum}")
        if self.capacity < 1:
            raise ValueError(f"capacity must be >= 1, got {self.capacity}")
        if self.n_headroom < 1.0:
            raise ValueError(
                f"n_headroom must be >= 1.0, got {self.n_headroom}"
            )


def quantize_loads_int(loads_int, quantum: int) -> np.ndarray:
    """Round per-group integer loads UP to multiples of ``quantum``.

    Zero loads stay zero (a comm-excluded group must not be handed
    rows by quantization).
    """
    loads_int = np.asarray(loads_int, dtype=np.int64)
    q = int(quantum)
    return -(-loads_int // q) * q


def quantize_plan(plan: DeploymentPlan, quantum: int) -> DeploymentPlan:
    """Re-integerize a deployment plan onto quantized per-group loads.

    The underlying real-valued allocation rides along unchanged (the
    controller's coverage metric keeps using the true loads); only the
    deployed integer loads / row ranges / slot count are quantized.
    """
    alloc = plan.allocation
    if alloc is None:
        raise ValueError("plan bucketing needs the real-valued allocation")
    q_loads = quantize_loads_int(alloc.loads_int, quantum)
    n_w = np.asarray(
        [g.num_workers for g in plan.cluster.groups], dtype=np.int64
    )
    q_alloc = dataclasses.replace(
        alloc,
        loads_int=q_loads,
        n_int=int(np.sum(n_w * q_loads)),
    )
    return integerize(plan.cluster, q_alloc)


def bucket_signature(cluster: ClusterSpec, loads_int_q, k: int) -> tuple:
    """Hashable identity of a quantized deployment shape.

    Two plans with equal signatures deploy IDENTICAL shapes and
    worker->slot maps: same k, same per-group worker counts (order
    matters — the scatter map is positional), same quantized loads.
    """
    return (
        int(k),
        tuple(int(g.num_workers) for g in cluster.groups),
        tuple(int(v) for v in np.asarray(loads_int_q)),
    )


def select_bucket(state: dict, index):
    """Pick one bucket's branch state inside a compiled program.

    ``state`` is the ``(B, ...)``-stacked pytree from ``device_state``;
    ``index`` a traced int32. Selection is a ``lax.switch`` over the
    bucket slots (the in-program replanning of ISSUE 7: the branch is
    chosen at RUN time, so a host-side replan only has to update the
    index and array values it already passes as arguments).
    """
    b = int(next(iter(state.values())).shape[0])
    if b == 1:
        return {k: v[0] for k, v in state.items()}
    branches = [
        (lambda s: (lambda st: jax.tree.map(lambda a: a[s], st)))(slot)
        for slot in range(b)
    ]
    return lax.switch(index, branches, state)


class PlanBucketSet:
    """LRU set of admitted plan buckets as stacked, padded host arrays.

    Rows: per-bucket runtime state a round consumer needs — per-worker
    loads and shifted-exp parameters ``(W,)``, slot owner map and
    alive mask padded to ``(n_cap,)``, and the scalar deadline. Padding
    slots point at worker 0 but are never alive, so decode paths mask
    them out exactly like erasures (for the MDS generator, the first
    ``n`` rows of an ``(n_cap, k)`` systematic code are a valid
    ``(n, k)`` code — capacity rows simply never arrive).
    """

    def __init__(self, num_workers: int, n_cap: int, capacity: int):
        self.num_workers = int(num_workers)
        self.n_cap = int(n_cap)
        self.capacity = int(capacity)
        #: signature -> row slot, in LRU order (oldest first)
        self._slots: OrderedDict[tuple, int] = OrderedDict()
        b, w, n = self.capacity, self.num_workers, self.n_cap
        self._owner = np.zeros((b, n), np.int32)
        self._alive = np.zeros((b, n), bool)
        self._loads = np.zeros((b, w), np.float32)
        self._deadline = np.full((b,), np.inf, np.float32)
        self._mus = np.ones((b, w), np.float64)
        self._alphas = np.ones((b, w), np.float64)
        self._shifts = np.full((b, w), np.inf, np.float32)

    def __len__(self) -> int:
        return len(self._slots)

    def __contains__(self, sig: tuple) -> bool:
        return sig in self._slots

    def slot_of(self, sig: tuple) -> int:
        return self._slots[sig]

    @property
    def signatures(self) -> tuple:
        return tuple(self._slots)

    def _write_params(self, slot: int, deadline, mus, alphas, shifts):
        self._deadline[slot] = float(deadline)
        self._mus[slot] = np.asarray(mus, np.float64)
        self._alphas[slot] = np.asarray(alphas, np.float64)
        self._shifts[slot] = np.asarray(shifts, np.float32)

    def admit(
        self, sig: tuple, plan: DeploymentPlan, deadline, mus, alphas, shifts
    ) -> tuple[int, bool]:
        """Admit (or refresh) a bucket; returns ``(slot, hit)``.

        On a hit the shape rows (owner/alive/loads) are already correct
        by signature identity; only the runtime parameters (deadline and
        the possibly-drifted worker params) are rewritten. On a miss the
        LRU bucket is evicted when at capacity.
        """
        if plan.num_workers != self.num_workers or plan.n > self.n_cap:
            raise ValueError("structural change cannot be admitted")
        hit = sig in self._slots
        if hit:
            slot = self._slots[sig]
            self._slots.move_to_end(sig)
        else:
            if len(self._slots) >= self.capacity:
                _, slot = self._slots.popitem(last=False)  # LRU evict
            else:
                slot = len(self._slots)
            self._slots[sig] = slot
            owner = np.zeros((self.n_cap,), np.int32)
            alive = np.zeros((self.n_cap,), bool)
            for w_i, (s, e) in enumerate(plan.row_ranges):
                owner[s:e] = w_i
            alive[: plan.n] = True
            self._owner[slot] = owner
            self._alive[slot] = alive
            self._loads[slot] = np.asarray(
                plan.loads_per_worker, np.float32
            )
        self._write_params(slot, deadline, mus, alphas, shifts)
        return slot, hit

    def device_state(self) -> dict:
        """The stacked branch state as a pytree of device arrays.

        Passed to compiled programs as runtime arguments every dispatch;
        cheap (a few hundred KB at serving scale) and REQUIRED for
        correctness — closing over it would bake values at trace time.
        """
        return {
            "owner": jnp.asarray(self._owner),
            "alive": jnp.asarray(self._alive),
            "loads": jnp.asarray(self._loads),
            "deadline": jnp.asarray(self._deadline),
            "mus": jnp.asarray(self._mus),
            "alphas": jnp.asarray(self._alphas),
            "shifts": jnp.asarray(self._shifts),
        }
