from repro.runtime.executor import CodedRoundExecutor
from repro.runtime.fault_tolerance import ElasticController, StragglerTracker
from repro.runtime.serve_loop import CodedLMHead, ServeConfig, Server
from repro.runtime.telemetry import Telemetry
from repro.runtime.train_loop import (
    TrainConfig,
    Trainer,
    make_coded_train_step_fn,
    make_train_step,
)

__all__ = [
    "CodedLMHead",
    "CodedRoundExecutor",
    "ElasticController",
    "ServeConfig",
    "Server",
    "StragglerTracker",
    "Telemetry",
    "TrainConfig",
    "Trainer",
    "make_coded_train_step_fn",
    "make_train_step",
]
