from repro.runtime.train_loop import Trainer, TrainConfig, make_train_step
from repro.runtime.serve_loop import CodedLMHead, ServeConfig, Server
from repro.runtime.fault_tolerance import ElasticController, StragglerTracker

__all__ = [
    "CodedLMHead",
    "ElasticController",
    "ServeConfig",
    "Server",
    "StragglerTracker",
    "TrainConfig",
    "Trainer",
    "make_train_step",
]
