from repro.runtime.control import (
    AdaptConfig,
    AdaptiveController,
    Decision,
    coverage_latency,
    replan_decision,
)
from repro.runtime.executor import CodedRoundExecutor
from repro.runtime.fault_tolerance import ElasticController, StragglerTracker
from repro.runtime.serve_loop import CodedLMHead, ServeConfig, Server
from repro.runtime.telemetry import Telemetry
from repro.runtime.timing import RoundClock, RoundTiming
from repro.runtime.train_loop import (
    TrainConfig,
    Trainer,
    make_coded_train_step_fn,
    make_train_step,
)

__all__ = [
    "AdaptConfig",
    "AdaptiveController",
    "CodedLMHead",
    "CodedRoundExecutor",
    "Decision",
    "ElasticController",
    "RoundClock",
    "RoundTiming",
    "ServeConfig",
    "Server",
    "StragglerTracker",
    "Telemetry",
    "TrainConfig",
    "Trainer",
    "coverage_latency",
    "make_coded_train_step_fn",
    "make_train_step",
    "replan_decision",
]
