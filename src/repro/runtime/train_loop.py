"""Training loop: heterogeneity-aware gradient coding on the coded substrate.

Paper integration (DESIGN.md §5):

* **Gradient coding** (Wang et al. 2019, arXiv:1901.09339) — the global
  batch is split into ``k`` partitions; the ``grad_coding`` scheme
  (Theorem-2 load balancing, ``core/allocation.py``) assigns each worker
  a speed-proportional number of coded partition-gradients, and the
  master recovers the FULL-batch gradient from any ``k`` coded rows via
  the decode vectors of ``core/gradient_coding.py``. Erasure aggregation
  is device-resident: the jitted train step samples the straggler mask,
  solves for the decode vector, and folds sub-threshold rounds in with
  ``jnp.where`` — one compiled program per step, no host numpy.
* **Heterogeneity-aware batch split** (``heterogeneous_batch_split``) —
  the paper's Theorem-2 share ``N_j l*_j / n*`` applied to microbatches;
  the *uncoded* drop-straggler comparator of ``benchmarks/fig_grad.py``.
* **Drop-straggler aggregation** (``aggregate_with_erasures``) — the
  host-side baseline: gradients from workers that miss the deadline are
  dropped and the sum rescaled. When EVERY worker misses, the step is
  skipped (previous gradient reused when available) and the event is
  surfaced through telemetry instead of aborting training.

The per-round mechanics — deadline, erasure-mask sampling, worker->slot
scatter map, elastic replans — come from the ``CodedRoundExecutor``
shared with the serving loop (``runtime/executor.py``); ``Trainer`` adds
the gradient-specific encode/decode on top. ``TrainConfig(cluster=...)``
turns coded execution on; without a cluster the plain jitted step runs.
"""
from __future__ import annotations

import dataclasses
from typing import Any

import jax
import jax.numpy as jnp
import numpy as np

from repro.checkpoint import AsyncCheckpointer, latest_step, restore_checkpoint
from repro.core.allocation import optimal_allocation
from repro.core.gradient_coding import assignment_matrix, decode_vector_jit
from repro.core.runtime_model import ClusterSpec
from repro.core.schemes import AllocationScheme
from repro.models.model import Model
from repro.obs.metrics import REGISTRY
from repro.obs.trace import SpanTracer
from repro.optim import AdamWConfig, adamw_init, adamw_update
from repro.runtime.executor import CodedRoundExecutor
from repro.runtime.plan_bucket import BucketConfig
from repro.runtime.telemetry import Telemetry

PyTree = Any


def heterogeneous_batch_split(cluster: ClusterSpec, global_batch: int) -> np.ndarray:
    """Per-group microbatch sizes from the paper's optimal allocation.

    Group j's share is N_j l*_j / n* — the same equalized-finish-time
    split Theorem 2 yields for coded rows. Rounds to integers preserving
    the total (largest-remainder).
    """
    plan = optimal_allocation(cluster, k=global_batch)
    n_w = np.asarray([g.num_workers for g in cluster.groups], float)
    share = n_w * plan.loads / float(plan.n)
    raw = share * global_batch
    base = np.floor(raw).astype(int)
    rem = global_batch - base.sum()
    order = np.argsort(-(raw - base))
    base[order[:rem]] += 1
    return base


def aggregate_with_erasures(grads_list, token_counts, finished_mask, *,
                            prev_grads=None, telemetry: Telemetry | None = None):
    """Weighted-average gradients over the workers that met the deadline.

    grads_list: list of gradient pytrees (one per worker/group shard).
    token_counts: tokens contributing to each shard's gradient.
    finished_mask: bool per shard. Returns the rescaled mean gradient.

    When EVERY worker misses the deadline the step degrades instead of
    aborting: the previous gradient (``prev_grads``) is reused when the
    caller carries one, otherwise a zero gradient is returned, and the
    event is surfaced through ``telemetry`` so operators see the stall.
    """
    w = np.asarray(token_counts, np.float64) * np.asarray(finished_mask, np.float64)
    total = w.sum()
    if total <= 0:
        if telemetry is not None:
            telemetry.event(
                "all_workers_missed_deadline", workers=len(grads_list)
            )
        if prev_grads is not None:
            return prev_grads
        return jax.tree.map(
            lambda g: jnp.zeros_like(g, dtype=jnp.float32), grads_list[0]
        )
    scale = [float(x / total) for x in w]

    def combine(*leaves):
        acc = None
        for s, leaf in zip(scale, leaves):
            term = s * leaf.astype(jnp.float32)
            acc = term if acc is None else acc + term
        return acc

    return jax.tree.map(combine, *grads_list)


@dataclasses.dataclass
class TrainConfig:
    steps: int = 100
    checkpoint_dir: str | None = None
    checkpoint_every: int = 50
    log_every: int = 10
    telemetry_path: str | None = None
    #: bound the in-memory event window (ring buffer); the JSONL sink
    #: at ``telemetry_path`` stays complete regardless
    telemetry_max_events: int | None = None
    seed: int = 0
    # ---- coded execution (gradient coding on the shared substrate) ----
    #: straggler fleet to plan against; None = plain (uncoded) training
    cluster: ClusterSpec | None = None
    #: registry name or typed scheme for the partition-load allocation
    scheme: str | AllocationScheme = "grad_coding"
    scheme_params: dict | None = None
    #: gradient partitions k (must divide the global batch); None = one
    #: partition per batch row
    partitions: int | None = None
    deadline_safety: float = 3.0
    # ---- cluster dynamics + closed-loop adaptation (DESIGN.md §7) ----
    #: registered scenario name (or a ScenarioSpec) perturbing the TRUE
    #: cluster over the run; the plan only tracks it when adaptive
    scenario: object | None = None
    #: consume straggler estimates and maybe replan every this many
    #: steps; None = no adaptive control (caller-initiated replans only)
    adapt_every: int | None = None
    #: hysteresis: minimum relative estimated-latency improvement
    adapt_threshold: float = 0.05
    #: modeled cost of one replan (recompile), in round-latency units
    adapt_replan_cost: float = 0.0
    #: adapt from MEASURED wall-clock round times instead of simulated
    #: ground truth: each coded dispatch runs under a ``RoundClock``
    #: (perf_counter + block_until_ready, decomposed per worker, §12)
    #: and the controller ingests the timings via ``observe_timing``
    measure_times: bool = False
    # ---- plan bucketing (DESIGN.md §11) ----
    #: quantize integer loads to this multiple and replan via an
    #: in-program bucket switch; None = off (every replan recompiles)
    bucket_quantum: int | None = None
    bucket_capacity: int = 8
    bucket_headroom: float = 1.5

    def bucket_config(self) -> BucketConfig | None:
        if self.bucket_quantum is None:
            return None
        return BucketConfig(
            quantum=self.bucket_quantum,
            capacity=self.bucket_capacity,
            n_headroom=self.bucket_headroom,
        )


def make_train_step_fn(model: Model, opt_cfg: AdamWConfig):
    """Raw (params, opt_state, batch) -> (params, opt_state, metrics)."""

    def train_step(params, opt_state, batch):
        (loss, metrics), grads = jax.value_and_grad(model.loss_fn, has_aux=True)(
            params, batch
        )
        params, opt_state, opt_metrics = adamw_update(opt_cfg, grads, opt_state, params)
        return params, opt_state, {**metrics, **opt_metrics}

    return train_step


def make_train_step(model: Model, opt_cfg: AdamWConfig, *, donate: bool = True):
    """Jitted train step (see make_train_step_fn)."""
    kwargs = {"donate_argnums": (0, 1)} if donate else {}
    return jax.jit(make_train_step_fn(model, opt_cfg), **kwargs)


def make_coded_train_step_fn(
    model: Model,
    opt_cfg: AdamWConfig,
    executor: CodedRoundExecutor,
    b_matrix,
    partitions: int,
):
    """Raw coded step: (params, opt_state, batch, key, deadline) -> ...

    One traceable program per round (DESIGN.md §5):

    1. per-partition gradients — the (B, S) batch reshaped to
       ``(k, B/k, S)`` and ``value_and_grad`` vmapped over the partition
       axis;
    2. straggler mask — ``executor.finish_mask_jit`` samples per-worker
       times under the scheme's latency model from the ``fold_in``'d
       step key, gathered to the coded-row erasure mask through the
       worker->slot scatter map;
    3. decode — ``decode_vector_jit`` solves ``a^T B_S = 1`` on the
       survivors; the aggregated gradient is the partitions weighted by
       ``a^T B`` (exactly ones when decodable — the coding is linear, so
       this equals explicitly materializing the n coded gradients and
       combining them with ``a``);
    4. skip-step fallback — when fewer than k coded rows survive, params
       and optimizer state pass through unchanged via ``jnp.where`` on
       the decode-ok flag (no Python branch; ``metrics['skipped']``
       surfaces the event).

    The optional trailing ``true_params`` argument is a
    ``(mus_w, alphas_w, shift_w)`` triple of (W,) arrays: when given,
    the straggler mask samples from THEM instead of the plan's closure
    constants — the scenario layer's ground truth, injectable every
    round without retracing (DESIGN.md §7).

    ``bucket_args`` (the pair from ``executor.bucket_args()``) switches
    straggler sampling and the slot-erasure mask onto the bucket branch
    selected in-program (DESIGN.md §11); ``b_matrix`` must then be sized
    to the bucket slot capacity (capacity rows are never alive). The
    ``deadline`` argument is ignored on that path — it comes from the
    selected branch.
    """
    b_mat = jnp.asarray(b_matrix, jnp.float32)

    def coded_step(params, opt_state, batch, key, deadline,
                   true_params=None, bucket_args=None):
        if batch.get("extras") is not None:
            raise NotImplementedError(
                "coded training does not partition family extras yet"
            )
        toks, labels = batch["tokens"], batch["labels"]
        b = toks.shape[0]
        tp = toks.reshape(partitions, b // partitions, *toks.shape[1:])
        lp = labels.reshape(partitions, b // partitions, *labels.shape[1:])

        def part_grad(tb, lb):
            (_, metrics), g = jax.value_and_grad(model.loss_fn, has_aux=True)(
                params, {"tokens": tb, "labels": lb}
            )
            return g, metrics

        grads_k, metrics_k = jax.vmap(part_grad)(tp, lp)

        mus_w, alphas_w, shift_w = (
            true_params if true_params is not None else (None, None, None)
        )
        if bucket_args is not None:
            state, index = bucket_args
            wmask, sel = executor.finish_mask_bucket_jit(
                key, state, index, mus=mus_w, alphas=alphas_w, shifts=shift_w
            )
            row_alive = executor.slot_mask_bucket_jit(wmask, sel)  # (n_cap,)
        elif true_params is None:
            wmask = executor.finish_mask_jit(key, deadline)  # (W,) workers
            row_alive = executor.slot_mask_jit(wmask)  # (n,) coded rows
        else:
            wmask = executor.finish_mask_jit(
                key, deadline, mus=mus_w, alphas=alphas_w, shifts=shift_w
            )
            row_alive = executor.slot_mask_jit(wmask)  # (n,) coded rows
        a, ok = decode_vector_jit(b_mat, row_alive)
        w_part = a @ b_mat  # (k,) partition weights; == 1 when decodable
        agg = jax.tree.map(
            lambda g: jnp.tensordot(
                w_part / partitions, g.astype(jnp.float32), axes=1
            ),
            grads_k,
        )
        new_params, new_opt, opt_metrics = adamw_update(
            opt_cfg, agg, opt_state, params
        )
        # fewer than k surviving coded rows: skip the step (params and
        # optimizer state unchanged) — erasure degradation, never an abort
        new_params = jax.tree.map(
            lambda new, old: jnp.where(ok, new, old), new_params, params
        )
        new_opt = jax.tree.map(
            lambda new, old: jnp.where(ok, new, old), new_opt, opt_state
        )
        metrics = {name: jnp.mean(v) for name, v in metrics_k.items()}
        metrics.update(opt_metrics)
        metrics["survivors"] = jnp.sum(wmask).astype(jnp.float32)
        metrics["coded_rows_alive"] = jnp.sum(row_alive).astype(jnp.float32)
        metrics["skipped"] = 1.0 - ok.astype(jnp.float32)
        return new_params, new_opt, metrics

    return coded_step


class Trainer:
    """End-to-end single-host trainer with checkpoint/restart.

    With ``TrainConfig(cluster=...)`` the trainer runs coded: a
    ``CodedRoundExecutor`` plans partition loads under the configured
    scheme (``grad_coding`` by default) and every step runs as one
    compiled program — gradients, straggler sampling, decode and the
    skip-step fallback included. ``self.traces`` counts (re)traces so
    tests can assert the step never re-enters Python. ``replan``
    rebuilds the program on membership changes, scheme params preserved.

    Cluster dynamics close the loop (DESIGN.md §7):
    ``TrainConfig(scenario=...)`` drifts the TRUE cluster over the run
    (straggler masks sample from the drifted parameters, injected as
    per-round arrays — no retrace), and ``adapt_every=R`` attaches an
    ``AdaptiveController`` that observes every round's worker times,
    re-estimates (mu, alpha, bandwidth), and replans + recompiles when
    the hysteresis rule fires — the replans land in telemetry as
    ``adapt_decision`` events.
    """

    def __init__(self, model: Model, data, opt_cfg: AdamWConfig, cfg: TrainConfig):
        self.model = model
        self.data = data
        self.opt_cfg = opt_cfg
        self.cfg = cfg
        self.traces = 0
        self.executor: CodedRoundExecutor | None = None
        if cfg.cluster is not None:
            # validate the coded config BEFORE acquiring file handles
            # (telemetry/checkpointer), so a raising __init__ leaks nothing
            gb = (
                self.data.shape.global_batch
                if hasattr(self.data, "shape") else None
            )
            k = cfg.partitions if cfg.partitions is not None else gb
            if k is None:
                raise ValueError(
                    "coded training needs cfg.partitions when the data "
                    "pipeline has no .shape to infer the batch from"
                )
            if gb is not None and gb % k:
                raise ValueError(
                    f"partitions ({k}) must divide the global batch ({gb})"
                )
            self.partitions = int(k)
        if cfg.cluster is None and (
            cfg.scenario is not None or cfg.adapt_every is not None
            or cfg.measure_times
        ):
            raise ValueError(
                "scenario / adapt_every / measure_times require coded "
                "training (cfg.cluster)"
            )
        if cfg.adapt_every is not None and cfg.adapt_every <= 0:
            raise ValueError(
                f"adapt_every must be a positive cadence, got {cfg.adapt_every}"
            )
        self.telemetry = Telemetry(
            cfg.telemetry_path, max_events=cfg.telemetry_max_events
        )
        #: span tracer (§14): per-step dispatch spans, shared with the
        #: executor so replan/bucket-switch spans nest on the same stack
        self.tracer = SpanTracer(self.telemetry)
        self._ckpt = (
            AsyncCheckpointer(cfg.checkpoint_dir) if cfg.checkpoint_dir else None
        )
        self.controller = None
        self.trace = None
        self.clock = None
        if cfg.cluster is not None:
            self.executor = CodedRoundExecutor(
                cfg.cluster,
                self.partitions,
                cfg.scheme,
                scheme_params=cfg.scheme_params,
                deadline_safety=cfg.deadline_safety,
                bucket_config=cfg.bucket_config(),
                telemetry=self.telemetry,
                tracer=self.tracer,
            )
            self._build_coded_step()
            if cfg.scenario is not None:
                from repro.sim import ScenarioSpec, make_scenario

                # a registered name is built AT the step budget so the
                # factories anchor event times/drift rates to the run
                # length (a 120-round spec clamped to 8 steps would
                # never reach its events); an explicit ScenarioSpec
                # keeps its own horizon — the caller placed the events
                spec = (
                    cfg.scenario
                    if isinstance(cfg.scenario, ScenarioSpec)
                    else make_scenario(str(cfg.scenario), horizon=cfg.steps)
                )
                self.trace = spec.trace(
                    cfg.cluster, seed=cfg.seed, horizon=cfg.steps
                )
            if cfg.adapt_every is not None:
                from repro.runtime.control import AdaptConfig, AdaptiveController

                self.controller = AdaptiveController(
                    self.executor,
                    AdaptConfig(
                        every=cfg.adapt_every,
                        threshold=cfg.adapt_threshold,
                        replan_cost=cfg.adapt_replan_cost,
                    ),
                    telemetry=self.telemetry,
                    on_replan=self._on_replan,
                )
            if cfg.measure_times:
                from repro.runtime.timing import RoundClock

                self.clock = RoundClock(
                    self.executor, telemetry=self.telemetry
                )
        else:
            self.step_fn = make_train_step(model, opt_cfg)

    def _build_coded_step(self) -> None:
        """(Re)compile the coded step against the executor's current plan.

        Bucket mode sizes the assignment matrix at the bucket slot
        CAPACITY: the fixed-shape decode masks capacity rows dead, so
        one matrix (and one compiled step) serves every admitted bucket.
        """
        buckets = self.executor.buckets
        n_rows = buckets.n_cap if buckets is not None else self.executor.n
        self.b_matrix = np.asarray(
            assignment_matrix(
                n_rows,
                self.partitions,
                key=jax.random.PRNGKey(self.cfg.seed),
            )
        )
        raw = make_coded_train_step_fn(
            self.model, self.opt_cfg, self.executor, self.b_matrix,
            self.partitions,
        )

        def counted(params, opt_state, batch, key, deadline,
                    true_params=None, bucket_args=None):
            self.traces += 1  # python side effect: runs only while tracing
            return raw(params, opt_state, batch, key, deadline, true_params,
                       bucket_args)

        self.coded_step_fn = jax.jit(counted, donate_argnums=(0, 1))

    def _on_replan(self) -> None:
        """Replan hook: rebuild the compiled step only when shapes moved.

        A bucket-switch replan (``last_replan_structural`` False) keeps
        the compiled step valid — the new branch reaches it through
        ``bucket_args`` at the next step, costing zero retraces.
        """
        if (
            self.executor.buckets is not None
            and not self.executor.last_replan_structural
        ):
            return
        self._build_coded_step()

    def replan(self, new_cluster: ClusterSpec):
        """Elastic replan mid-training; scheme params preserved.

        Rebuilds the deadline, assignment matrix and the compiled step
        for the new membership (worker/slot shapes change — skipped on a
        non-structural bucket switch), and surfaces the replan through
        telemetry.
        """
        if self.executor is None:
            raise ValueError("replan requires coded training (cfg.cluster)")
        plan = self.executor.replan(new_cluster)
        self._on_replan()
        self.telemetry.event(
            "replan", workers=plan.num_workers, n=plan.n,
            deadline=self.executor.deadline,
        )
        return plan

    def init_or_restore(self):
        params = self.model.init_params(jax.random.PRNGKey(self.cfg.seed))
        opt_state = adamw_init(self.opt_cfg, params)
        start = 0
        if self.cfg.checkpoint_dir:
            last = latest_step(self.cfg.checkpoint_dir)
            if last is not None:
                state, meta = restore_checkpoint(
                    self.cfg.checkpoint_dir, last,
                    {"params": params, "opt": opt_state},
                )
                params, opt_state = state["params"], state["opt"]
                start = meta["step"]
                if hasattr(self.data, "_step"):
                    self.data._step = meta.get("data_step", start)
        return params, opt_state, start

    def run(self):
        params, opt_state, start = self.init_or_restore()
        tokens_per_step = (
            self.data.shape.global_batch * self.data.shape.seq_len
            if hasattr(self.data, "shape") else None
        )
        coded = self.executor is not None
        step_key = jax.random.PRNGKey(self.cfg.seed + 1)
        history = []
        for step in range(start, self.cfg.steps):
            batch = self.data.next_batch()
            if coded:
                skey = jax.random.fold_in(step_key, step)
                # scenario ground truth: this round's straggling samples
                # from the TRUE (drifted) cluster while loads/deadline
                # stay whatever the current plan believes
                true_params = (
                    self.executor.worker_param_arrays(self.trace.at(step))
                    if self.trace is not None else None
                )
                bucket_args = (
                    self.executor.bucket_args()
                    if self.executor.buckets is not None else None
                )
                if self.clock is not None:
                    # measured-reality path (§12): the dispatch runs
                    # under the clock (perf_counter + block_until_ready)
                    # and the controller ingests the DECOMPOSED
                    # wall-clock times — same key as the compiled step's
                    # finish mask, so the split matches the draw that
                    # actually gated the round
                    with self.tracer.span("dispatch", step=step):
                        timing = self.clock.measure(
                            lambda: self.coded_step_fn(
                                params, opt_state, batch, skey,
                                jnp.float32(self.executor.deadline),
                                true_params, bucket_args,
                            ),
                            key=skey,
                            true_cluster=(
                                self.trace.at(step)
                                if self.trace is not None else None
                            ),
                        )
                    params, opt_state, metrics = timing.result
                    if self.controller is not None:
                        d = self.controller.observe_timing(timing)
                        if (
                            d is not None and d.replanned
                            and self.executor.last_replan_structural
                        ):
                            # the next dispatch retraces the rebuilt
                            # step: compile time, not round latency
                            self.clock.discard_next()
                else:
                    with self.tracer.span("dispatch", step=step):
                        params, opt_state, metrics = self.coded_step_fn(
                            params, opt_state, batch, skey,
                            jnp.float32(self.executor.deadline),
                            true_params, bucket_args,
                        )
                    if self.controller is not None:
                        # the controller observes the SAME per-worker
                        # times the compiled step's finish mask was
                        # drawn from (same key, same sampler) — a true
                        # closed loop
                        self.controller.observe_truth(
                            skey,
                            self.trace.at(step)
                            if self.trace is not None else None,
                        )
            else:
                params, opt_state, metrics = self.step_fn(
                    params, opt_state, batch
                )
            self.telemetry.tick()
            if (step + 1) % self.cfg.log_every == 0 or step == start:
                rec = self.telemetry.log(step + 1, metrics, tokens_per_step)
                history.append(rec)
            if self._ckpt and (step + 1) % self.cfg.checkpoint_every == 0:
                self._ckpt.save(
                    step + 1,
                    {"params": params, "opt": opt_state},
                    {"data_step": self.data.state()["step"]
                     if hasattr(self.data, "state") else step + 1},
                )
        if self._ckpt:
            self._ckpt.wait()
        # final counters (process-global registry: alloc-cache tallies)
        # land in the JSONL so obsreport sees them without a serve run
        REGISTRY.emit(
            self.telemetry, phase="train", rounds=float(self.cfg.steps)
        )
        self.telemetry.close()
        return params, opt_state, history
