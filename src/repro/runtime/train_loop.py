"""Training loop: heterogeneity-aware DP + fault-tolerant aggregation.

Paper integration (beyond-paper, recorded in EXPERIMENTS.md):

* **Heterogeneity-aware batch split** — the paper's optimal load
  allocation (Theorem 2) applied to the global batch: worker group j
  processes a share proportional to ``N_j * l*_j / n*``. Uniform DP on a
  heterogeneous fleet makes every step as slow as the slowest group; the
  paper's allocation equalizes the per-group expected finish time (the
  same Lemma-1 balancing argument, applied to microbatches instead of
  coded rows).
* **Drop-straggler aggregation** — gradients from workers that miss the
  deadline (T* x safety) are dropped and the sum is rescaled by the
  surviving token count (erasure semantics, no code needed since
  gradients are an average, not an exact recovery).

The in-process loop below runs the standard jitted step; the
heterogeneous sharding math is exercised by tests/benchmarks via
``heterogeneous_batch_split`` and ``aggregate_with_erasures``.
"""
from __future__ import annotations

import dataclasses
import functools
from typing import Any

import jax
import jax.numpy as jnp
import numpy as np

from repro.checkpoint import AsyncCheckpointer, latest_step, restore_checkpoint
from repro.core.allocation import optimal_allocation
from repro.core.runtime_model import ClusterSpec
from repro.models.model import Model
from repro.optim import AdamWConfig, adamw_init, adamw_update
from repro.runtime.telemetry import Telemetry

PyTree = Any


def heterogeneous_batch_split(cluster: ClusterSpec, global_batch: int) -> np.ndarray:
    """Per-group microbatch sizes from the paper's optimal allocation.

    Group j's share is N_j l*_j / n* — the same equalized-finish-time
    split Theorem 2 yields for coded rows. Rounds to integers preserving
    the total (largest-remainder).
    """
    plan = optimal_allocation(cluster, k=global_batch)
    n_w = np.asarray([g.num_workers for g in cluster.groups], float)
    share = n_w * plan.loads / float(plan.n)
    raw = share * global_batch
    base = np.floor(raw).astype(int)
    rem = global_batch - base.sum()
    order = np.argsort(-(raw - base))
    base[order[:rem]] += 1
    return base


def aggregate_with_erasures(grads_list, token_counts, finished_mask):
    """Weighted-average gradients over the workers that met the deadline.

    grads_list: list of gradient pytrees (one per worker/group shard).
    token_counts: tokens contributing to each shard's gradient.
    finished_mask: bool per shard. Returns the rescaled mean gradient.
    """
    w = np.asarray(token_counts, np.float64) * np.asarray(finished_mask, np.float64)
    total = w.sum()
    assert total > 0, "every worker missed the deadline"
    scale = [float(x / total) for x in w]

    def combine(*leaves):
        acc = None
        for s, leaf in zip(scale, leaves):
            term = s * leaf.astype(jnp.float32)
            acc = term if acc is None else acc + term
        return acc

    return jax.tree.map(combine, *grads_list)


@dataclasses.dataclass
class TrainConfig:
    steps: int = 100
    checkpoint_dir: str | None = None
    checkpoint_every: int = 50
    log_every: int = 10
    telemetry_path: str | None = None
    seed: int = 0


def make_train_step_fn(model: Model, opt_cfg: AdamWConfig):
    """Raw (params, opt_state, batch) -> (params, opt_state, metrics)."""

    def train_step(params, opt_state, batch):
        (loss, metrics), grads = jax.value_and_grad(model.loss_fn, has_aux=True)(
            params, batch
        )
        params, opt_state, opt_metrics = adamw_update(opt_cfg, grads, opt_state, params)
        return params, opt_state, {**metrics, **opt_metrics}

    return train_step


def make_train_step(model: Model, opt_cfg: AdamWConfig, *, donate: bool = True):
    """Jitted train step (see make_train_step_fn)."""
    kwargs = {"donate_argnums": (0, 1)} if donate else {}
    return jax.jit(make_train_step_fn(model, opt_cfg), **kwargs)


class Trainer:
    """End-to-end single-host trainer with checkpoint/restart."""

    def __init__(self, model: Model, data, opt_cfg: AdamWConfig, cfg: TrainConfig):
        self.model = model
        self.data = data
        self.opt_cfg = opt_cfg
        self.cfg = cfg
        self.step_fn = make_train_step(model, opt_cfg)
        self.telemetry = Telemetry(cfg.telemetry_path)
        self._ckpt = (
            AsyncCheckpointer(cfg.checkpoint_dir) if cfg.checkpoint_dir else None
        )

    def init_or_restore(self):
        params = self.model.init_params(jax.random.PRNGKey(self.cfg.seed))
        opt_state = adamw_init(self.opt_cfg, params)
        start = 0
        if self.cfg.checkpoint_dir:
            last = latest_step(self.cfg.checkpoint_dir)
            if last is not None:
                state, meta = restore_checkpoint(
                    self.cfg.checkpoint_dir, last,
                    {"params": params, "opt": opt_state},
                )
                params, opt_state = state["params"], state["opt"]
                start = meta["step"]
                if hasattr(self.data, "_step"):
                    self.data._step = meta.get("data_step", start)
        return params, opt_state, start

    def run(self):
        params, opt_state, start = self.init_or_restore()
        tokens_per_step = (
            self.data.shape.global_batch * self.data.shape.seq_len
            if hasattr(self.data, "shape") else None
        )
        history = []
        for step in range(start, self.cfg.steps):
            batch = self.data.next_batch()
            params, opt_state, metrics = self.step_fn(params, opt_state, batch)
            self.telemetry.tick()
            if (step + 1) % self.cfg.log_every == 0 or step == start:
                rec = self.telemetry.log(step + 1, metrics, tokens_per_step)
                history.append(rec)
            if self._ckpt and (step + 1) % self.cfg.checkpoint_every == 0:
                self._ckpt.save(
                    step + 1,
                    {"params": params, "opt": opt_state},
                    {"data_step": self.data.state()["step"]
                     if hasattr(self.data, "state") else step + 1},
                )
        if self._ckpt:
            self._ckpt.wait()
        self.telemetry.close()
        return params, opt_state, history
