"""Fault tolerance & elasticity for the coded-computation runtime.

Straggler mitigation IS the paper's contribution (the coded redundancy
lets the master proceed with the fastest responders); this module adds
the fleet-control pieces around it:

* ``StragglerTracker`` — online (mu, alpha) estimation per group from
  observed round-trip times (shifted-exponential MLE, exponential
  forgetting), per-group link-bandwidth MLE from observed transfer
  times (``observe_transfers`` -> ``ClusterSpec.with_bandwidths``, so
  ``CommAware`` replans stop being comm-blind), and deadline-based
  failure detection.
* ``ElasticController`` — membership changes (workers join/leave, groups
  added on scale-up) trigger a closed-form re-plan (Theorem 2 is O(G) —
  no iterative optimizer in the failure path). Backed by a
  ``CodedComputeEngine``, so any registered ``AllocationScheme`` (with
  its params) survives every re-plan.
* ``deadline_for`` — converts a plan's expected latency into an
  actionable per-round deadline (latency x safety factor): workers that
  miss it are erasures for the MDS decode. Schemes without an analytic
  T* (uniform-n, reisizadeh, uncoded) get a Monte-Carlo estimate, so the
  deadline is finite for every registered scheme.
"""
from __future__ import annotations

import dataclasses

import numpy as np

from repro.core.engine import CodedComputeEngine, plan_deadline
from repro.core.planner import DeploymentPlan
from repro.core.runtime_model import ClusterSpec, GroupSpec
from repro.core.schemes import AllocationScheme


def deadline_for(
    plan: DeploymentPlan,
    safety: float = 3.0,
    *,
    key=None,
    num_trials: int = 2_048,
) -> float:
    """Per-round cutoff: expected latency times a safety factor.

    Uses the plan's analytic T* when finite; otherwise falls back to the
    scheme's own Monte-Carlo latency estimate so that uniform-n /
    reisizadeh / uncoded deployments still get a usable deadline. Thin
    alias of ``repro.core.engine.plan_deadline`` (one deadline policy).
    """
    return plan_deadline(plan, safety, key=key, num_trials=num_trials)


@dataclasses.dataclass
class StragglerTracker:
    """Tracks per-group runtime estimates and detects failed workers."""

    cluster: ClusterSpec
    forget: float = 0.9  # exponential forgetting of old estimates
    fail_after: int = 3  # consecutive missed deadlines => failed
    # paper Section IV: the shifted-exp latency model is only meaningful
    # for mu < ~750 (W_{-1} underflows beyond); clamp the MLE accordingly
    mu_max: float = 750.0
    mu_min: float = 1e-6

    def __post_init__(self):
        self._mu = np.asarray([g.mu for g in self.cluster.groups], float)
        self._alpha = np.asarray([g.alpha for g in self.cluster.groups], float)
        self._missed = np.zeros((self.cluster.total_workers,), int)
        self._bw = self.cluster.bandwidths.copy()
        self._bw_seen = np.zeros((self.cluster.num_groups,), bool)

    def observe_round(self, times: np.ndarray, loads: np.ndarray, k: int,
                      deadline: float | None = None):
        """Update estimates from one round of per-worker round-trip times.

        times: (N,) seconds (np.inf for workers that never responded).
        loads: (N,) rows assigned. Returns the boolean finished mask.
        """
        times = np.asarray(times, float)
        # defense in depth: the controller clamps at its ingest point,
        # but a direct caller feeding measured times can still hand us
        # non-positives (clock jitter) — the MLE normalization divides
        # and mins over these, so keep finite times positive here too
        times = np.where(np.isfinite(times), np.maximum(times, 1e-9), times)
        finished = np.isfinite(times)
        if deadline is not None:
            finished &= times <= deadline
        self._missed = np.where(finished, 0, self._missed + 1)
        # group-wise shifted-exp MLE on the finished workers
        start = 0
        for j, g in enumerate(self.cluster.groups):
            sl = slice(start, start + g.num_workers)
            t = times[sl][finished[sl]]
            l = loads[sl][finished[sl]]
            start += g.num_workers
            if t.size < 2:
                continue
            norm = t * (k / np.maximum(l, 1))  # normalize to full-task scale
            a_hat = float(norm.min())
            mu_hat = 1.0 / max(float(norm.mean() - a_hat), 1e-9)
            mu_hat = float(np.clip(mu_hat, self.mu_min, self.mu_max))
            self._alpha[j] = self.forget * self._alpha[j] + (1 - self.forget) * a_hat
            self._mu[j] = self.forget * self._mu[j] + (1 - self.forget) * mu_hat
        return finished

    def rebind(self, cluster: ClusterSpec) -> None:
        """Re-anchor per-worker state to a new membership (post-replan).

        The replanned cluster embeds the tracker's own estimates as its
        spec values (``estimated_cluster`` built it), so re-initializing
        from it preserves the (mu, alpha, bandwidth) state while the
        per-worker miss counters reset to the new fleet shape. Without
        this, ``observe_round`` would slice the next round's times with
        the OLD group sizes.
        """
        self.cluster = cluster
        self.__post_init__()

    def observe_transfers(self, transfer_times: np.ndarray,
                          payload: float = 1.0) -> np.ndarray:
        """Per-group bandwidth MLE from observed per-worker transfer times.

        Under the CommDelay model a group-j worker pays ``payload / b_j``
        time units of transfer per round, so given observed transfer
        times the MLE of the link bandwidth is ``payload / mean(t)``
        (the transfer shift is deterministic in the model; averaging
        de-noises real measurements). First observation replaces the
        spec prior (often ``inf`` = "never measured"); later ones are
        smoothed with the same exponential forgetting as (mu, alpha).
        Estimates flow into ``estimated_cluster`` and from there into
        elastic replans, so ``CommAware`` plans track measured links.

        transfer_times: (N,) per-worker transfer times (np.nan/np.inf or
        <= 0 for workers with no measurement this round). Returns the
        current per-group bandwidth estimates.
        """
        t = np.asarray(transfer_times, float)
        start = 0
        for j, g in enumerate(self.cluster.groups):
            tj = t[start:start + g.num_workers]
            start += g.num_workers
            tj = tj[np.isfinite(tj) & (tj > 0)]
            if tj.size == 0:
                continue
            b_hat = float(payload / tj.mean())
            if self._bw_seen[j] and np.isfinite(self._bw[j]):
                self._bw[j] = (
                    self.forget * self._bw[j] + (1 - self.forget) * b_hat
                )
            else:
                self._bw[j] = b_hat
            self._bw_seen[j] = True
        return self._bw.copy()

    @property
    def bandwidth_estimates(self) -> np.ndarray:
        """Current per-group bandwidth estimates (spec prior if unseen)."""
        return self._bw.copy()

    @property
    def mu_estimates(self) -> np.ndarray:
        """Current per-group straggling-rate estimates."""
        return self._mu.copy()

    @property
    def alpha_estimates(self) -> np.ndarray:
        """Current per-group shift estimates."""
        return self._alpha.copy()

    @property
    def failed_workers(self) -> np.ndarray:
        return np.flatnonzero(self._missed >= self.fail_after)

    def estimated_cluster(self) -> ClusterSpec:
        """Current membership (failed workers removed) + current estimates.

        Carries the per-group bandwidth estimates via
        ``ClusterSpec.with_bandwidths``: comm-aware schemes must not
        silently degenerate to comm-blind on replan, and measured links
        override the spec's static values.
        """
        groups, bws = [], []
        start = 0
        for j, g in enumerate(self.cluster.groups):
            sl = np.arange(start, start + g.num_workers)
            start += g.num_workers
            alive = int(np.sum(self._missed[sl] < self.fail_after))
            if alive > 0:
                groups.append(GroupSpec(alive, float(self._mu[j]),
                                        float(self._alpha[j])))
                bws.append(float(self._bw[j]))
        return ClusterSpec(tuple(groups)).with_bandwidths(bws)


class ElasticController:
    """Re-plans the coded deployment when the fleet changes.

    The plan is recomputed from the scheme's closed form — re-planning is
    O(G) and happens inline (no coordinator round trip), which is what
    makes elasticity practical at 1000+ workers. Thin wrapper over
    ``CodedComputeEngine.replan``; scheme params travel with the engine's
    typed scheme object across every membership change.

    With a ``threshold`` the controller applies the shared hysteresis
    rule of ``repro.runtime.control.replan_decision`` to estimate
    updates: membership changes still always replan, but pure parameter
    drift only replans when the estimated-latency improvement crosses
    the threshold (inclusive). ``threshold=None`` keeps the legacy
    replan-on-every-update behaviour.
    """

    def __init__(
        self,
        cluster: ClusterSpec,
        k: int,
        *,
        scheme: str | AllocationScheme = "optimal",
        scheme_params: dict | None = None,
        threshold: float | None = None,
        replan_cost: float = 0.0,
        horizon: int = 50,
    ):
        self.k = k
        self.engine = CodedComputeEngine(
            cluster, k, scheme, scheme_params=scheme_params
        )
        self.threshold = threshold
        self.replan_cost = replan_cost
        self.horizon = horizon
        self.last_decision = None  # the most recent hysteresis Decision

    @property
    def plan(self) -> DeploymentPlan:
        return self.engine.plan

    @property
    def replans(self) -> int:
        return self.engine.replans

    def on_membership_change(self, new_cluster: ClusterSpec) -> DeploymentPlan:
        return self.engine.replan(new_cluster)

    def on_estimates_update(self, tracker: StragglerTracker) -> DeploymentPlan:
        est = tracker.estimated_cluster()
        if self.threshold is not None:
            from repro.runtime.control import replan_decision

            self.last_decision = replan_decision(
                self.engine.scheme,
                self.engine.plan,
                est,
                threshold=self.threshold,
                replan_cost=self.replan_cost,
                horizon=self.horizon,
            )
            if not self.last_decision.replanned:
                return self.engine.plan
        return self.on_membership_change(est)
