"""Fault tolerance & elasticity for the coded-computation runtime.

Straggler mitigation IS the paper's contribution (the coded redundancy
lets the master proceed with the fastest responders); this module adds
the fleet-control pieces around it:

* ``StragglerTracker`` — online (mu, alpha) estimation per group from
  observed round-trip times (shifted-exponential MLE, exponential
  forgetting) and deadline-based failure detection.
* ``ElasticController`` — membership changes (workers join/leave, groups
  added on scale-up) trigger a closed-form re-plan (Theorem 2 is O(G) —
  no iterative optimizer in the failure path). Backed by a
  ``CodedComputeEngine``, so any registered ``AllocationScheme`` (with
  its params) survives every re-plan.
* ``deadline_for`` — converts a plan's expected latency into an
  actionable per-round deadline (latency x safety factor): workers that
  miss it are erasures for the MDS decode. Schemes without an analytic
  T* (uniform-n, reisizadeh, uncoded) get a Monte-Carlo estimate, so the
  deadline is finite for every registered scheme.
"""
from __future__ import annotations

import dataclasses

import numpy as np

from repro.core.engine import CodedComputeEngine, plan_deadline
from repro.core.planner import DeploymentPlan
from repro.core.runtime_model import ClusterSpec, GroupSpec
from repro.core.schemes import AllocationScheme


def deadline_for(
    plan: DeploymentPlan,
    safety: float = 3.0,
    *,
    key=None,
    num_trials: int = 2_048,
) -> float:
    """Per-round cutoff: expected latency times a safety factor.

    Uses the plan's analytic T* when finite; otherwise falls back to the
    scheme's own Monte-Carlo latency estimate so that uniform-n /
    reisizadeh / uncoded deployments still get a usable deadline. Thin
    alias of ``repro.core.engine.plan_deadline`` (one deadline policy).
    """
    return plan_deadline(plan, safety, key=key, num_trials=num_trials)


@dataclasses.dataclass
class StragglerTracker:
    """Tracks per-group runtime estimates and detects failed workers."""

    cluster: ClusterSpec
    forget: float = 0.9  # exponential forgetting of old estimates
    fail_after: int = 3  # consecutive missed deadlines => failed
    # paper Section IV: the shifted-exp latency model is only meaningful
    # for mu < ~750 (W_{-1} underflows beyond); clamp the MLE accordingly
    mu_max: float = 750.0
    mu_min: float = 1e-6

    def __post_init__(self):
        self._mu = np.asarray([g.mu for g in self.cluster.groups], float)
        self._alpha = np.asarray([g.alpha for g in self.cluster.groups], float)
        self._missed = np.zeros((self.cluster.total_workers,), int)

    def observe_round(self, times: np.ndarray, loads: np.ndarray, k: int,
                      deadline: float | None = None):
        """Update estimates from one round of per-worker round-trip times.

        times: (N,) seconds (np.inf for workers that never responded).
        loads: (N,) rows assigned. Returns the boolean finished mask.
        """
        times = np.asarray(times, float)
        finished = np.isfinite(times)
        if deadline is not None:
            finished &= times <= deadline
        self._missed = np.where(finished, 0, self._missed + 1)
        # group-wise shifted-exp MLE on the finished workers
        start = 0
        for j, g in enumerate(self.cluster.groups):
            sl = slice(start, start + g.num_workers)
            t = times[sl][finished[sl]]
            l = loads[sl][finished[sl]]
            start += g.num_workers
            if t.size < 2:
                continue
            norm = t * (k / np.maximum(l, 1))  # normalize to full-task scale
            a_hat = float(norm.min())
            mu_hat = 1.0 / max(float(norm.mean() - a_hat), 1e-9)
            mu_hat = float(np.clip(mu_hat, self.mu_min, self.mu_max))
            self._alpha[j] = self.forget * self._alpha[j] + (1 - self.forget) * a_hat
            self._mu[j] = self.forget * self._mu[j] + (1 - self.forget) * mu_hat
        return finished

    @property
    def failed_workers(self) -> np.ndarray:
        return np.flatnonzero(self._missed >= self.fail_after)

    def estimated_cluster(self) -> ClusterSpec:
        """Current membership (failed workers removed) + current estimates."""
        groups = []
        start = 0
        for j, g in enumerate(self.cluster.groups):
            sl = np.arange(start, start + g.num_workers)
            start += g.num_workers
            alive = int(np.sum(self._missed[sl] < self.fail_after))
            if alive > 0:
                # keep the group's link bandwidth: comm-aware schemes
                # must not silently degenerate to comm-blind on replan
                groups.append(GroupSpec(alive, float(self._mu[j]),
                                        float(self._alpha[j]), g.bandwidth))
        return ClusterSpec(tuple(groups))


class ElasticController:
    """Re-plans the coded deployment when the fleet changes.

    The plan is recomputed from the scheme's closed form — re-planning is
    O(G) and happens inline (no coordinator round trip), which is what
    makes elasticity practical at 1000+ workers. Thin wrapper over
    ``CodedComputeEngine.replan``; scheme params travel with the engine's
    typed scheme object across every membership change.
    """

    def __init__(
        self,
        cluster: ClusterSpec,
        k: int,
        *,
        scheme: str | AllocationScheme = "optimal",
        scheme_params: dict | None = None,
    ):
        self.k = k
        self.engine = CodedComputeEngine(
            cluster, k, scheme, scheme_params=scheme_params
        )

    @property
    def plan(self) -> DeploymentPlan:
        return self.engine.plan

    @property
    def replans(self) -> int:
        return self.engine.replans

    def on_membership_change(self, new_cluster: ClusterSpec) -> DeploymentPlan:
        return self.engine.replan(new_cluster)

    def on_estimates_update(self, tracker: StragglerTracker) -> DeploymentPlan:
        return self.on_membership_change(tracker.estimated_cluster())
