"""Persistent JAX compilation cache enablement (DESIGN.md §11).

The last layer of the replan-cost cache stack (eager → allocate memo →
plan bucket → THIS): ``jax.experimental.compilation_cache`` persists
compiled XLA executables to disk, so a cold process (a relaunched
benchmark, a CI job restoring the cache directory via ``actions/cache``)
pays dictionary-lookup + deserialization instead of a recompile for
every program shape it has ever seen — including every bucket branch.

Knobs (all env-overridable, all best-effort on older JAX):

* ``REPRO_COMPILE_CACHE_DIR`` — cache directory (default
  ``~/.cache/repro-jax``).
* ``REPRO_NO_COMPILE_CACHE`` — set non-empty to opt out entirely.

``enable_persistent_cache`` is wired into ``benchmarks/common.py`` and
both launchers; callers that want their own directory (tests) pass
``path`` explicitly. Idempotent and safe to call multiple times.
"""
from __future__ import annotations

import os

_DEFAULT_DIR = os.path.join(os.path.expanduser("~"), ".cache", "repro-jax")
_enabled_dir: str | None = None


def cache_dir() -> str | None:
    """Directory the persistent cache was enabled at (None = not enabled)."""
    return _enabled_dir


def enable_persistent_cache(path: str | None = None) -> str | None:
    """Enable JAX's on-disk compilation cache; returns the directory.

    Thresholds are dropped to zero so even the small allocation cores
    and sub-second bucket branches persist (the default only caches
    programs that took >= 1 s to compile). Returns None when opted out
    or when this JAX build lacks the cache knobs (each config update is
    independently best-effort).
    """
    global _enabled_dir
    if os.environ.get("REPRO_NO_COMPILE_CACHE"):
        return None
    path = (
        path
        or os.environ.get("REPRO_COMPILE_CACHE_DIR")
        or _DEFAULT_DIR
    )
    if _enabled_dir == path:
        return path
    import jax

    try:
        jax.config.update("jax_compilation_cache_dir", path)
    except Exception:
        return None
    for knob, value in (
        ("jax_persistent_cache_min_compile_time_secs", 0.0),
        ("jax_persistent_cache_min_entry_size_bytes", -1),
    ):
        try:
            jax.config.update(knob, value)
        except Exception:
            pass  # older JAX: keep its default persistence thresholds
    _enabled_dir = path
    return path
