"""Step-level telemetry: timing EMAs, tokens/s, events, JSONL sink.

``Telemetry`` is a context manager so file handles close
deterministically (tests create sinks in tempfiles)::

    with Telemetry(path) as tel:
        tel.tick(); tel.log(step, metrics)
        tel.event("all_workers_missed_deadline", step=step)

Besides per-step metric records, the runtime surfaces discrete
*events* (degraded aggregation, replans, adaptive-controller decisions,
deadline misses, spans from ``repro.obs.trace``) through ``event``; they
land in the same JSONL stream tagged with an ``event`` field and are
kept in memory for tests/operators to inspect. Every event record
carries a monotonic ``t`` sequence number (0, 1, 2, ... per sink), so
interleaved control decisions are totally ordered and post-hoc
analyzable even when wall clocks are useless (simulated rounds), plus a
``wall_s`` ``perf_counter`` stamp so events interleave with spans on a
real timeline — see DESIGN.md §8 for the event schema (generated from
``repro.obs.schema``).

Long-running sinks bound their in-memory footprint with ``max_events``
(a ring buffer over ``events``); the JSONL sink always stays complete.
"""
from __future__ import annotations

import json
import time
from collections import deque


class Telemetry:
    def __init__(self, path: str | None = None, ema: float = 0.9,
                 max_events: int | None = None):
        if max_events is not None and max_events <= 0:
            raise ValueError(f"max_events must be > 0, got {max_events}")
        self.path = path
        self.ema = ema
        self.step_time: float | None = None
        self._last: float | None = None
        #: in-memory event window; a deque ring when ``max_events`` is
        #: set (the JSONL file keeps every record regardless)
        self.events = (
            deque(maxlen=max_events) if max_events is not None else []
        )
        self._event_t = 0  # monotonic event sequence number
        self._fh = open(path, "a") if path else None

    def tick(self) -> float | None:
        """Call once per step; returns smoothed step time."""
        now = time.perf_counter()
        if self._last is not None:
            dt = now - self._last
            self.step_time = (
                dt if self.step_time is None
                else self.ema * self.step_time + (1 - self.ema) * dt
            )
        self._last = now
        return self.step_time

    def log(self, step: int, metrics: dict, tokens_per_step: int | None = None):
        # non-float-able metric values (a status string, a scheme name)
        # are kept as strings instead of raising mid-run: a telemetry
        # sink must never be the thing that kills a training job
        rec = {"step": step}
        for k, v in metrics.items():
            try:
                rec[k] = float(v)
            except (TypeError, ValueError):
                rec[k] = str(v)
        # explicit None checks: truthiness would silently drop tokens_per_s
        # when tokens_per_step == 0 (a valid rate of 0.0) or when the
        # smoothed step time is exactly 0.0 (report inf, not nothing)
        if self.step_time is not None and tokens_per_step is not None:
            rec["tokens_per_s"] = (
                tokens_per_step / self.step_time
                if self.step_time > 0 else float("inf")
            )
        self._write(rec)
        return rec

    def event(self, name: str, **fields) -> dict:
        """Record a discrete runtime event (degraded step, replan, ...).

        Stamps a monotonic ``t`` (per-sink sequence number) and a
        ``wall_s`` ``perf_counter`` stamp unless the caller provides its
        own — consumers that already carry a round index (or, like
        ``round_timing``, a measured wall duration) keep their fields;
        everyone else gets total ordering and a real-time anchor for
        free.
        """
        rec = {
            "event": name,
            "t": self._event_t,
            "wall_s": time.perf_counter(),
            **fields,
        }
        self._event_t += 1
        self.events.append(rec)
        self._write(rec)
        return rec

    def _write(self, rec: dict) -> None:
        if self._fh:
            self._fh.write(json.dumps(rec) + "\n")
            self._fh.flush()

    def close(self):
        if self._fh:
            self._fh.close()
            self._fh = None

    def __enter__(self) -> "Telemetry":
        return self

    def __exit__(self, *exc) -> None:
        self.close()
