"""AdaptiveController: one closed replan loop for serve AND train (§7).

Before this module the repo had two half-closed loops: serving replanned
through ``ElasticController.on_estimates_update`` (unconditionally, every
call) and training replanned only when a caller invoked
``Trainer.replan`` by hand. This module owns the full control policy
once, on top of the shared ``CodedRoundExecutor`` substrate:

* **cadence** — consume ``StragglerTracker`` estimates every ``every``
  rounds (estimates between cadence points only accumulate);
* **hysteresis** — replan only when the *estimated-latency improvement*
  clears ``threshold`` (relative), evaluated with the deterministic
  mean-field ``coverage_latency`` below, so decisions are reproducible
  and never flap on Monte-Carlo noise;
* **replan-cost model** — a replan recompiles the consumer's program
  (the coded train step retraces, the serve pipeline re-jits), so the
  projected saving ``(t_cur - t_new) * horizon`` must also exceed
  ``replan_cost`` (same units as round latency);
* **membership changes always replan** — a plan sized for departed (or
  unaware of joined) workers is wrong regardless of magnitude;
* **telemetry** — every decision (held or replanned) is emitted as an
  ``adapt_decision`` event, so the control loop is post-hoc analyzable
  from the JSONL stream (DESIGN.md §8).

``ElasticController`` (serving's membership-triggered replanner) now
routes its estimate updates through the same ``replan_decision`` rule
when constructed with a threshold.
"""
from __future__ import annotations

import dataclasses
from typing import Callable, Sequence

import numpy as np

from repro.core.planner import DeploymentPlan
from repro.core.runtime_model import (
    ClusterSpec,
    GroupSpec,
    LatencyModel,
    comm_terms,
)
from repro.core.schemes import AllocationScheme, allocate_cache_info
from repro.obs.trace import NULL_TRACER


def coverage_latency(
    cluster: ClusterSpec,
    loads_per_group: Sequence[float],
    k: int,
    *,
    model: LatencyModel = LatencyModel.MODEL_1,
    upload: float = 0.0,
    download: float = 0.0,
) -> float:
    """Deterministic mean-field round latency of per-group loads.

    The smallest ``t`` with ``sum_j N_j l_j F_j(t) >= k`` — the expected
    coded-row coverage reaching the decode threshold, the same fixed
    point the paper's allocation equalizes (at the optimal loads this
    recovers ``T*`` up to the paper's harmonic-number approximation).
    Used as the controller's decision metric precisely because it is
    noise-free: hysteresis comparisons of current-vs-candidate plans
    must not flap on Monte-Carlo resampling.

    ``F_j`` is the group's shifted-exponential CDF under ``model``
    (CommDelay transfer terms derived from the cluster's bandwidths and
    the given costs). Returns ``inf`` when the loads cannot cover ``k``
    even with every worker finished (e.g. after a leave burst) — the
    caller maps that to a deadline-timeout penalty. Group-code schemes
    (``uniform_r``) use per-group completion semantics this threshold
    approximation only bounds; for controller decisions that is
    acceptable (both sides of the comparison use the same metric).
    """
    l = np.asarray(loads_per_group, float)
    n_w = np.asarray([g.num_workers for g in cluster.groups], float)
    mu = np.asarray([g.mu for g in cluster.groups], float)
    al = np.asarray([g.alpha for g in cluster.groups], float)
    if l.shape != n_w.shape:
        raise ValueError(
            f"loads shape {l.shape} does not match the cluster's "
            f"{n_w.shape[0]} groups"
        )
    if model is LatencyModel.COMM_DELAY:
        shift_c, dal = comm_terms(cluster, upload, download)
        al = al + dal
    else:
        shift_c = np.zeros_like(al)
    live = (l > 0) & (n_w > 0)
    if not np.any(live) or float(np.sum(n_w[live] * l[live])) < k - 1e-9:
        return float("inf")
    l, n_w, mu, al, shift_c = (
        a[live] for a in (l, n_w, mu, al, shift_c)
    )
    scale = l if model.per_row else l / float(k)
    shift = al * scale + shift_c  # per-worker deterministic part
    rate = mu / scale  # exponential tail rate

    def coverage(t: float) -> float:
        f = 1.0 - np.exp(-rate * np.maximum(t - shift, 0.0))
        return float(np.sum(n_w * l * f))

    lo = float(np.min(shift))
    hi = float(np.max(shift)) + 1.0
    for _ in range(200):
        if coverage(hi) >= k - 1e-9:
            break
        hi *= 2.0
    else:
        return float("inf")  # coverage only reaches k asymptotically
    for _ in range(100):
        mid = 0.5 * (lo + hi)
        if coverage(mid) >= k - 1e-9:
            hi = mid
        else:
            lo = mid
    return hi


@dataclasses.dataclass(frozen=True)
class AdaptConfig:
    """Cadence + hysteresis policy of the adaptive controller."""

    every: int = 10  # consume estimates every R rounds
    threshold: float = 0.05  # relative latency improvement needed to act
    replan_cost: float = 0.0  # one replan's cost, in round-latency units
    horizon: int = 50  # rounds a replan's improvement amortizes over
    #: exponential forgetting of the default tracker's estimates — faster
    #: than StragglerTracker's 0.9 default because the control loop's
    #: whole point is reacting to drift within a few cadence periods
    forget: float = 0.7

    def __post_init__(self):
        if self.every <= 0:
            raise ValueError(f"AdaptConfig.every must be > 0, got {self.every}")
        if not 0 <= self.forget < 1:
            raise ValueError(
                f"AdaptConfig.forget must be in [0, 1), got {self.forget}"
            )
        if self.threshold < 0:
            raise ValueError(
                f"AdaptConfig.threshold must be >= 0, got {self.threshold}"
            )
        if self.replan_cost < 0 or self.horizon <= 0:
            raise ValueError(
                f"AdaptConfig needs replan_cost >= 0 and horizon > 0, got "
                f"replan_cost={self.replan_cost}, horizon={self.horizon}"
            )


@dataclasses.dataclass(frozen=True)
class Decision:
    """One controller decision (held OR replanned), telemetry-ready."""

    round: int
    replanned: bool
    reason: str  # "membership" | "improvement" | "hold" | "forced"
    current: float  # est. latency of the incumbent plan on the estimates
    candidate: float  # est. latency of a fresh plan on the estimates
    gain: float  # relative improvement (current - candidate) / current


def replan_decision(
    scheme: AllocationScheme,
    plan: DeploymentPlan,
    est_cluster: ClusterSpec,
    *,
    threshold: float,
    replan_cost: float = 0.0,
    horizon: int = 50,
    round: int = 0,
) -> Decision:
    """The controller's decision rule (pure — does not execute the replan).

    Membership changes (group count or any per-group worker count)
    always replan. Otherwise both the incumbent plan's loads and a
    candidate allocation are evaluated on the ESTIMATED cluster with
    ``coverage_latency``; the controller acts iff the relative gain
    crosses ``threshold`` (inclusive — a gain exactly at threshold
    replans) AND the absolute saving amortized over ``horizon`` rounds
    pays for ``replan_cost``.
    """
    cur_cluster = plan.cluster
    membership_changed = est_cluster.num_groups != cur_cluster.num_groups or any(
        a.num_workers != b.num_workers
        for a, b in zip(est_cluster.groups, cur_cluster.groups)
    )
    if membership_changed:
        return Decision(
            round=round, replanned=True, reason="membership",
            current=float("nan"), candidate=float("nan"), gain=float("nan"),
        )
    model = scheme.latency_model
    upload = float(getattr(scheme, "upload", 0.0))
    download = float(getattr(scheme, "download", 0.0))
    alloc = plan.allocation
    if alloc is not None:
        cur_loads = np.asarray(alloc.loads, float)
    else:  # legacy plan: recover per-group loads from the worker expansion
        loads_w = np.asarray(plan.loads_per_worker, float)
        gid = np.asarray(plan.group_of_worker)
        cur_loads = np.asarray(
            [loads_w[gid == j][0] if np.any(gid == j) else 0.0
             for j in range(cur_cluster.num_groups)]
        )
    t_cur = coverage_latency(
        est_cluster, cur_loads, plan.k,
        model=model, upload=upload, download=download,
    )
    cand = scheme.allocate(est_cluster, plan.k)
    t_new = coverage_latency(
        est_cluster, np.asarray(cand.loads, float), plan.k,
        model=model, upload=upload, download=download,
    )
    if not np.isfinite(t_cur):
        # the incumbent plan cannot cover k on the estimated cluster:
        # any feasible candidate is an unbounded improvement
        replan = np.isfinite(t_new)
        gain = 1.0 if replan else 0.0
    else:
        gain = (t_cur - t_new) / t_cur
        replan = gain >= threshold and (t_cur - t_new) * horizon >= replan_cost
    return Decision(
        round=round, replanned=bool(replan),
        reason="improvement" if replan else "hold",
        current=float(t_cur), candidate=float(t_new), gain=float(gain),
    )


class AdaptiveController:
    """Closed-loop straggler-adaptive replanning over one executor.

    Feed it one ``observe_round`` per executed round (per-worker round
    times; ``inf`` for workers that never responded, plus the current
    registration ``membership`` when the fleet can grow). Every
    ``cfg.every`` rounds it folds the tracker's (mu, alpha, bandwidth)
    estimates into an estimated cluster and applies ``replan_decision``;
    on a replan it drives ``executor.replan`` (scheme params preserved
    by the engine), re-anchors the tracker to the new membership, and
    invokes ``on_replan`` so the consumer can rebuild whatever it traced
    against the old shapes (the coded train step, the serve pipeline).
    """

    def __init__(
        self,
        executor,
        cfg: AdaptConfig | None = None,
        *,
        tracker=None,
        telemetry=None,
        on_replan: Callable[[], None] | None = None,
    ):
        self.executor = executor
        self.cfg = cfg or AdaptConfig()
        if tracker is None:
            from repro.runtime.fault_tolerance import StragglerTracker

            tracker = StragglerTracker(executor.cluster, forget=self.cfg.forget)
        self.tracker = tracker
        self.telemetry = telemetry
        # the executor emits plan_bucket_hit/miss events on replans; give
        # it this controller's stream unless the caller wired its own
        if telemetry is not None and getattr(executor, "telemetry", None) is None:
            executor.telemetry = telemetry
        self.on_replan = on_replan
        self.round = 0  # monotonic executed-round counter
        self.decisions: list[Decision] = []
        self._membership: tuple[int, ...] | None = None
        self._alloc_hits_seen = allocate_cache_info()["hits"]

    # ------------------------------------------------------------- views
    @property
    def plan(self) -> DeploymentPlan:
        return self.executor.plan

    @property
    def replans(self) -> int:
        return self.executor.replans

    # ------------------------------------------------------ observation
    def observe_round(
        self,
        times,
        *,
        loads=None,
        membership: Sequence[int] | None = None,
        transfer_times=None,
        payload: float = 1.0,
    ) -> Decision | None:
        """Ingest one round of observations; adapt when the cadence hits.

        ``times``: (W,) per-worker round-trip times for the CURRENT
        plan's workers (``inf`` = never responded — repeated infs are
        how leavers are detected). ``membership``: per-group registered
        worker counts from the cluster's membership service; required
        for join bursts to become visible (times alone can only shrink
        the fleet). ``transfer_times``: separately-measured per-worker
        UPLOAD delays — they feed the bandwidth MLE AND all comm terms
        (the upload shift directly, the per-load download term via the
        freshly-updated bandwidth estimates) are subtracted from
        ``times`` before the (mu, alpha) MLE, so comm delay is not
        double-counted as compute slowness when the scheme later adds
        its transfer terms back on top of the estimated alphas. Returns
        the cadence decision, or None off-cadence.
        """
        times = np.asarray(times, float)
        loads = np.asarray(
            self.executor.plan.loads_per_worker if loads is None else loads
        )
        if transfer_times is not None:
            tt = np.asarray(transfer_times, float)
            bw = self.tracker.observe_transfers(tt, payload)
            times = times - np.where(np.isfinite(tt), tt, 0.0)
            download = float(getattr(self.executor.scheme, "download", 0.0))
            if download > 0:
                gid = np.asarray(self.executor.plan.group_of_worker)
                inv_b = np.where(np.isfinite(bw), 1.0 / bw, 0.0)[gid]
                times = times - download * inv_b * np.asarray(loads, float) \
                    / self.executor.k
        # single ingest point for the MLE: finite times must be positive.
        # Non-positive values reach here two ways — bandwidth-estimate lag
        # overshooting the comm-term subtraction above, and (on the
        # measured path) wall-clock jitter — so the clamp sits outside
        # the transfer branch (inf = missing stays inf).
        times = np.where(np.isfinite(times), np.maximum(times, 1e-9), times)
        self.tracker.observe_round(times, loads, self.executor.k)
        if membership is not None:
            self._membership = tuple(int(m) for m in membership)
        self.round += 1
        if self.round % self.cfg.every:
            return None
        return self.update()

    def observe_truth(
        self, key, true_cluster: ClusterSpec | None = None
    ) -> Decision | None:
        """Sample one round of ground-truth observations and ingest them.

        The simulation-side loop every consumer repeats: map the CURRENT
        plan's workers onto the true cluster's parameters
        (``worker_param_arrays``), draw one round of times with the same
        sampler the compiled loops use (same ``key`` => the identical
        draw), feed the upload shifts as measured transfer times for
        CommDelay schemes, and derive the registration membership from
        the truth. ``true_cluster=None`` observes the plan's own cluster
        (stationary truth).
        """
        exe = self.executor
        times, shifts = exe.round_observation(key, true_cluster)
        sch = exe.scheme
        comm = (
            sch.latency_model is LatencyModel.COMM_DELAY
            and getattr(sch, "upload", 0.0) > 0
        )
        return self.observe_round(
            times,
            membership=(
                None if true_cluster is None
                else tuple(g.num_workers for g in true_cluster.groups)
            ),
            transfer_times=shifts if comm else None,
            payload=float(sch.upload) if comm else 1.0,
        )

    def observe_timing(self, timing) -> Decision | None:
        """Ingest one measured round (a ``RoundTiming`` from
        ``runtime.timing.RoundClock``). The wall-clock counterpart of
        ``observe_truth``: times/transfer shares were measured and
        decomposed by the clock, membership still comes from the
        scenario/registration layer via the timing. A timing the clock
        declined to feed (warmup, outlier, post-replan recompile —
        ``timing.times is None``) is a no-op so callers can feed every
        round unconditionally.
        """
        if timing is None or timing.times is None:
            return None
        return self.observe_round(
            timing.times,
            membership=timing.membership,
            transfer_times=timing.transfer_times,
            payload=timing.payload,
        )

    def estimated_cluster(self) -> ClusterSpec:
        """Tracker estimates + registration membership, as a ClusterSpec.

        Worker counts come from the registration truth when one has been
        observed (joins included), minus nothing — workers the tracker
        flagged as failed but registration still lists are the
        registration's problem; without a membership feed the tracker's
        own failure detection drives the counts. Parameters (mu, alpha,
        bandwidth) are always the tracker's current estimates. Groups
        with zero workers are dropped.
        """
        m = self._membership
        if m is None or len(m) != self.tracker.cluster.num_groups:
            return self.tracker.estimated_cluster()
        mu = self.tracker.mu_estimates
        al = self.tracker.alpha_estimates
        bw = self.tracker.bandwidth_estimates
        groups, bws = [], []
        for j, count in enumerate(m):
            if count <= 0:
                continue
            groups.append(GroupSpec(int(count), float(mu[j]), float(al[j])))
            bws.append(float(bw[j]))
        return ClusterSpec(tuple(groups)).with_bandwidths(bws)

    def coverage_latency(self, cluster: ClusterSpec | None = None) -> float:
        """Mean-field round latency of the DEPLOYED plan's loads (rounds).

        The serving front-end's admission-control signal: the scheduler
        scales each request's projected completion by
        ``coverage_latency() / reference`` so the fleet sheds load when
        the tracker's estimates say rounds are running slow. Evaluated
        on the tracker-estimated cluster by default (``cluster``
        overrides, e.g. for a no-drift baseline); returns ``inf`` when
        the deployed loads cannot cover ``k`` on the estimates.
        """
        exe = self.executor
        plan = exe.plan
        est = cluster if cluster is not None else self.estimated_cluster()
        alloc = plan.allocation
        if alloc is not None:
            loads = np.asarray(alloc.loads, float)
        else:
            loads_w = np.asarray(plan.loads_per_worker, float)
            gid = np.asarray(plan.group_of_worker)
            loads = np.asarray(
                [loads_w[gid == j][0] if np.any(gid == j) else 0.0
                 for j in range(plan.cluster.num_groups)]
            )
        if est.num_groups != len(loads):
            # membership drifted since the plan deployed (replan pending):
            # the plan's loads no longer map onto the estimated groups, so
            # evaluate on the plan's own cluster (conservative hold-over)
            est = plan.cluster
        sch = exe.scheme
        return coverage_latency(
            est, loads, plan.k,
            model=sch.latency_model,
            upload=float(getattr(sch, "upload", 0.0)),
            download=float(getattr(sch, "download", 0.0)),
        )

    def recommend_slots(
        self, *, base: int, lo: int = 1, hi: int | None = None,
        reference: float | None = None,
    ) -> int:
        """Pick the serve batch width from measured round latency.

        ``base`` slots are calibrated for ``reference`` round latency
        (default: the deployed plan's coverage latency on its OWN
        cluster — the planned, no-drift value). When the tracker's
        measured-reality estimates (RoundClock feed via
        ``observe_timing``) say rounds run ``r``× slower than planned,
        the recommended in-flight width shrinks to ``base / r`` — fewer
        concurrent streams keep per-request backlog projections inside
        their deadline budgets — and grows symmetrically when rounds run
        fast, clamped to ``[lo, hi]`` (``hi`` defaults to ``4 * base``).
        """
        if base <= 0:
            raise ValueError(f"base must be > 0, got {base}")
        hi = 4 * base if hi is None else hi
        if reference is None:
            reference = self.coverage_latency(self.executor.plan.cluster)
        cur = self.coverage_latency()
        if (
            not np.isfinite(cur) or not np.isfinite(reference)
            or cur <= 0 or reference <= 0
        ):
            return int(min(max(base, lo), hi))
        rec = int(round(base * reference / cur))
        return int(min(max(rec, lo), hi))

    # ---------------------------------------------------------- decision
    def update(self) -> Decision:
        """Run one decision now (the cadence calls this automatically).

        With a bucket-switch executor the replan-cost model sharpens:
        ``bucket_probe`` asks whether the candidate plan would land in an
        already-admitted bucket (a FREE replan — zero retraces), and only
        a true bucket miss is charged ``cfg.replan_cost``. Without
        bucketing every replan recompiles, so every replan is charged.
        """
        # share the executor's tracer (§14): the replan span the
        # executor records nests under this decision span
        tracer = getattr(self.executor, "tracer", NULL_TRACER)
        with tracer.span("adapt_update", round=self.round) as sp:
            est = self.estimated_cluster()
            probe = getattr(self.executor, "bucket_probe", lambda _c: None)(est)
            cost = 0.0 if probe else self.cfg.replan_cost
            d = replan_decision(
                self.executor.scheme,
                self.executor.plan,
                est,
                threshold=self.cfg.threshold,
                replan_cost=cost,
                horizon=self.cfg.horizon,
                round=self.round,
            )
            if d.replanned:
                self.executor.replan(est)
                self.tracker.rebind(self.executor.cluster)
                self._membership = tuple(
                    g.num_workers for g in self.executor.cluster.groups
                )
                if self.on_replan is not None:
                    self.on_replan()
            sp.set(replanned=d.replanned, reason=d.reason)
        self.decisions.append(d)
        if self.telemetry is not None:
            self.telemetry.event(
                "adapt_decision",
                round=d.round,
                replanned=d.replanned,
                reason=d.reason,
                current=d.current,
                candidate=d.candidate,
                gain=d.gain,
                deadline=float(self.executor.deadline),
                workers=int(self.executor.num_workers),
            )
            info = allocate_cache_info()
            new_hits = info["hits"] - self._alloc_hits_seen
            if new_hits > 0:
                self._alloc_hits_seen = info["hits"]
                self.telemetry.event(
                    "alloc_cache_hit",
                    round=d.round,
                    new_hits=new_hits,
                    hits=info["hits"],
                    misses=info["misses"],
                    size=info["size"],
                )
        return d
