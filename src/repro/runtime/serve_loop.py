"""Serving loop with the paper's coded matvec as the LM-head path.

Decode-time logits are exactly the paper's workload: ``logits = E h``
with ``E in R^{V x D}`` (the tied embedding) and one ``h in R^D`` per
sequence — a matrix-vector product whose rows can be MDS-coded and
spread over heterogeneous workers.

Block-level MDS: V rows are padded into ``kb`` row-blocks of ``R`` rows;
an ``(nb, kb)`` MDS code over BLOCKS yields coded blocks
``E~_i = sum_j G[i, j] E_j``. Worker w stores ``l_w`` coded blocks (the
paper's load allocation, in block units) and returns the (R,)-per-block
products ``E~_i h``. Any ``kb`` coded block-products reconstruct all
logits — workers missing the deadline (T* x safety) are erasures.

Engine integration: ``ClusterSpec -> CodedComputeEngine(k=kb)`` owns the
plan, the (nb, kb) generator and the deadline, so the per-worker block
counts follow the configured ``AllocationScheme`` (Theorem 2 by default;
any registered scheme via ``ServeConfig.scheme``).
"""
from __future__ import annotations

import dataclasses

import jax
import jax.numpy as jnp
import numpy as np

from repro.core.engine import CodedComputeEngine
from repro.core.planner import DeploymentPlan
from repro.core.runtime_model import ClusterSpec
from repro.core.schemes import AllocationScheme
from repro.models.model import Model, padded_vocab


@dataclasses.dataclass
class ServeConfig:
    block_rows: int = 256  # R: vocab rows per MDS block
    deadline_safety: float = 3.0
    max_decode_steps: int = 32
    scheme: str | AllocationScheme = "optimal"  # registry name or object


class CodedLMHead:
    """MDS-coded unembedding for straggler-tolerant decode."""

    def __init__(self, embed_table, cluster: ClusterSpec, *, block_rows: int = 256,
                 key=None, scheme: str | AllocationScheme = "optimal",
                 deadline_safety: float = 3.0):
        self.table = np.asarray(embed_table, np.float32)  # (Vp, D)
        vp, d = self.table.shape
        self.block_rows = block_rows
        self.kb = -(-vp // block_rows)  # blocks needed to cover the vocab
        self.engine = CodedComputeEngine(cluster, self.kb, scheme)
        self.plan: DeploymentPlan = self.engine.plan
        self.nb = self.plan.n
        self.generator = np.asarray(self.engine.generator(key=key))
        # coded blocks: (nb, R, D) = einsum over the block-reshaped table
        pad = self.kb * block_rows - vp
        tbl = np.pad(self.table, ((0, pad), (0, 0)))
        blocks = tbl.reshape(self.kb, block_rows, d)
        self.coded = jnp.asarray(
            np.einsum("nk,krd->nrd", self.generator, blocks, optimize=True)
        )
        self.deadline = self.engine.deadline(deadline_safety)
        self._rows_of_worker = self.plan.row_ranges  # block ranges per worker

    def worker_products(self, h):
        """All coded block-products for a batch of hiddens h: (B, D).

        Returns (nb, B, R). In deployment each worker computes only its
        slice; here the full product is computed and the erasure mask is
        applied at decode time (deadline semantics — see DESIGN.md §3).
        """
        return jnp.einsum("nrd,bd->nbr", self.coded, h.astype(jnp.float32))

    def decode_logits(self, products, finished_workers) -> tuple[np.ndarray, bool]:
        """Recover (B, Vp) logits from surviving coded block-products."""
        products = np.asarray(products)  # (nb, B, R)
        fin = np.asarray(finished_workers, bool)
        alive_blocks = np.zeros((self.nb,), bool)
        for w, (s, e) in enumerate(self._rows_of_worker):
            if fin[w]:
                alive_blocks[s:e] = True
        if alive_blocks.sum() < self.kb:
            return np.zeros((products.shape[1], self.kb * self.block_rows)), False
        use = np.flatnonzero(alive_blocks)[: self.kb]
        g = self.generator[use]  # (kb, kb)
        y = products[use]  # (kb, B, R)
        z = np.linalg.solve(g, y.reshape(self.kb, -1)).reshape(self.kb, *y.shape[1:])
        logits = z.transpose(1, 0, 2).reshape(products.shape[1], -1)
        return logits, True

    def sample_finish_mask(self, key) -> np.ndarray:
        """Simulate which workers meet the deadline (shifted-exp model)."""
        from repro.core.runtime_model import sample_worker_times

        loads = jnp.asarray(self.plan.loads_per_worker, jnp.float32)
        mus = jnp.asarray(
            [self.plan.cluster.groups[j].mu for j in self.plan.group_of_worker]
        )
        alphas = jnp.asarray(
            [self.plan.cluster.groups[j].alpha for j in self.plan.group_of_worker]
        )
        t = sample_worker_times(key, loads, mus, alphas, self.kb, 1)[0]
        return np.asarray(t <= self.deadline)


class Server:
    """Batched decode with an optional coded LM head."""

    def __init__(self, model: Model, params, cluster: ClusterSpec | None = None,
                 cfg: ServeConfig | None = None):
        self.model = model
        self.params = params
        self.cfg = cfg or ServeConfig()
        self.coded_head = (
            CodedLMHead(
                params["embed"]["table"], cluster,
                block_rows=self.cfg.block_rows,
                scheme=self.cfg.scheme,
                deadline_safety=self.cfg.deadline_safety,
            )
            if cluster is not None
            else None
        )
        self._decode = jax.jit(model.decode_step)

    def generate(self, prompts, max_new: int | None = None, *, key=None,
                 cache_len: int | None = None, extras=None):
        """Greedy decode. prompts: (B, S0) int32. Returns (B, S0+T)."""
        key = key or jax.random.PRNGKey(0)
        max_new = max_new or self.cfg.max_decode_steps
        b, s0 = prompts.shape
        cache_len = cache_len or (s0 + max_new)
        cache = self.model.init_cache(b, cache_len, extras)
        # prefill by stepping (simple and exact; a batched prefill kernel
        # is the obvious optimization, exercised via lm_logits elsewhere)
        tok = prompts[:, 0]
        logits = None
        for pos in range(s0):
            logits, cache = self._decode(self.params, cache, prompts[:, pos],
                                         jnp.int32(pos))
        out = [prompts]
        tok = jnp.argmax(logits, -1).astype(jnp.int32)
        for t in range(max_new):
            out.append(tok[:, None])
            if t == max_new - 1:
                break
            logits, cache = self._decode(self.params, cache, tok, jnp.int32(s0 + t))
            if self.coded_head is not None:
                logits = self._coded_logits(cache, logits, key, t)
            tok = jnp.argmax(logits, -1).astype(jnp.int32)
        return jnp.concatenate(out, axis=1)

    def _coded_logits(self, cache, fallback_logits, key, t):
        """Recompute the final logits through the coded LM head."""
        # Coded products are linear in the hidden state: (G (x) I_R) E h.
        # Since logits = E h, mixing logit BLOCKS with G is numerically
        # identical to what each worker computes from h directly — so the
        # erasure/decode path is exercised end-to-end without re-running
        # the unembed matmul. A sampled straggler mask (shifted-exp model,
        # deadline = T* x safety) marks the erasures.
        b = fallback_logits.shape[0]
        vp = self.coded_head.kb * self.coded_head.block_rows
        pad = vp - fallback_logits.shape[-1]
        lf = jnp.pad(fallback_logits.astype(jnp.float32), ((0, 0), (0, pad)))
        blocks = lf.reshape(b, self.coded_head.kb, self.coded_head.block_rows)
        products = jnp.einsum(
            "nk,bkr->nbr", jnp.asarray(self.coded_head.generator), blocks
        )
        mask = self.coded_head.sample_finish_mask(jax.random.fold_in(key, t))
        logits, ok = self.coded_head.decode_logits(products, mask)
        if not ok:  # insufficient survivors: fall back (and a real system
            return fallback_logits  # would extend the deadline)
        return jnp.asarray(logits[:, : fallback_logits.shape[-1]])
