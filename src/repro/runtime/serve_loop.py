"""Serving loop with the paper's coded matvec as the LM-head path.

Decode-time logits are exactly the paper's workload: ``logits = E h``
with ``E in R^{V x D}`` (the tied embedding) and one ``h in R^D`` per
sequence — a matrix-vector product whose rows can be MDS-coded and
spread over heterogeneous workers.

Block-level MDS: V rows are padded into ``kb`` row-blocks of ``R`` rows;
an ``(nb, kb)`` MDS code over BLOCKS yields coded blocks
``E~_i = sum_j G[i, j] E_j``. Worker w stores ``l_w`` coded blocks (the
paper's load allocation, in block units) and returns the (R,)-per-block
products ``E~_i h``. Any ``kb`` coded block-products reconstruct all
logits — workers missing the deadline (T* x safety) are erasures.

Jit-native decode pipeline (DESIGN.md §4): the whole generation —
prefill, per-token decode, straggler-mask sampling, erasure decode and
the insufficient-survivors fallback — is ONE compiled program driven by
``jax.lax.scan``. The coded head precomputes its worker->block scatter
map at init, samples finish masks inside the jitted step from
``fold_in``'d keys, and decodes with the fixed-shape
``decode_systematic_jit``; nothing touches the host between tokens. The
legacy per-token host loop (numpy ``np.linalg.solve`` decode) survives
behind ``ServeConfig(jit_pipeline=False)`` as the reference/baseline
path for ``benchmarks/serve_throughput.py``.

Substrate integration: the head's per-round mechanics — plan, (nb, kb)
generator, deadline, straggler-mask sampling, worker->block scatter map,
replan hooks — come from the shared ``CodedRoundExecutor``
(``runtime/executor.py``, DESIGN.md §5), the same substrate the coded
trainer consumes, so the per-worker block counts follow the configured
``AllocationScheme`` (Theorem 2 by default; any registered scheme via
``ServeConfig.scheme``).
"""
from __future__ import annotations

import dataclasses
import time

import jax
import jax.numpy as jnp
import numpy as np

from repro.core.coding import decode_systematic_jit, make_generator
from repro.core.planner import DeploymentPlan
from repro.core.runtime_model import ClusterSpec
from repro.core.schemes import AllocationScheme
from repro.models.model import DTYPES_LOGITS, Model, padded_vocab
from repro.obs.metrics import MetricsRegistry
from repro.obs.trace import NULL_TRACER, SpanTracer
from repro.runtime.executor import CodedRoundExecutor
from repro.runtime.plan_bucket import BucketConfig

NEG_INF = -1e30  # pad-vocab sentinel (matches Model._mask_pad_logits)


@dataclasses.dataclass
class ServeConfig:
    block_rows: int = 256  # R: vocab rows per MDS block
    deadline_safety: float = 3.0
    max_decode_steps: int = 32
    scheme: str | AllocationScheme = "optimal"  # registry name or object
    use_kernel: bool = False  # Pallas coded-matvec kernel for the block mix
    jit_pipeline: bool = True  # False: legacy per-token host loop (numpy)
    # paged KV serving (DESIGN.md §13): ``serve`` runs on a block-pooled
    # cache with chunked prefill; ``paged=False`` keeps the dense
    # slot-cache path (the bit-parity oracle).
    paged: bool = True
    block_len: int = 16  # tokens per physical KV block
    num_blocks: int | None = None  # pool size; None = dense-equivalent auto
    prefill_chunk: int | None = None  # admission chunk; None = prompt_cap
    # plan bucketing (DESIGN.md §11): set ``bucket_quantum`` to quantize
    # integer loads onto bucket shapes and replan in-program via a
    # runtime bucket switch — intra-capacity replans then retrace nothing
    bucket_quantum: int | None = None
    bucket_capacity: int = 8
    bucket_headroom: float = 1.5

    def bucket_config(self) -> BucketConfig | None:
        if self.bucket_quantum is None:
            return None
        return BucketConfig(
            quantum=self.bucket_quantum,
            capacity=self.bucket_capacity,
            n_headroom=self.bucket_headroom,
        )


class CodedLMHead:
    """MDS-coded unembedding for straggler-tolerant decode.

    Per-round mechanics (deadline, straggler-mask sampling, worker->block
    scatter map, replan hooks) come from the shared
    ``CodedRoundExecutor`` — the same substrate the coded trainer runs
    on (DESIGN.md §5). The head adds the workload-specific parts: the
    coded vocab blocks and the logits encode/decode. All ``*_jit``
    methods are traceable and run under the server's single compiled
    generation program.
    """

    def __init__(self, embed_table, cluster: ClusterSpec, *, block_rows: int = 256,
                 key=None, scheme: str | AllocationScheme = "optimal",
                 deadline_safety: float = 3.0,
                 bucket_config: BucketConfig | None = None, telemetry=None):
        self.table = np.asarray(embed_table, np.float32)  # (Vp, D)
        vp, _ = self.table.shape
        self.block_rows = block_rows
        self.kb = -(-vp // block_rows)  # blocks needed to cover the vocab
        self.executor = CodedRoundExecutor(
            cluster, self.kb, scheme, deadline_safety=deadline_safety,
            bucket_config=bucket_config, telemetry=telemetry,
        )
        self.engine = self.executor.engine
        self._generator_key = key
        self.refresh()

    def refresh(self) -> None:
        """(Re)bind all plan-derived state to the executor's current plan.

        Called at init and after every executor replan (e.g. driven by an
        ``AdaptiveController``): the code size ``nb``, the generator, the
        coded vocab blocks, the deadline and the worker->block scatter
        map all depend on the deployed plan. Consumers holding programs
        traced against the old shapes must re-jit (``Server`` does via
        ``refresh_coded_head``).
        """
        self.plan: DeploymentPlan = self.executor.plan
        buckets = self.executor.buckets
        # Bucket mode codes at slot CAPACITY: the first n rows of the
        # systematic (n_cap, kb) code form a valid (n, kb) code and the
        # capacity padding rows are never alive, so ONE generator + coded
        # tensor serves every admitted bucket (rebuilt only on structural
        # replans, never on a bucket switch).
        self.nb = buckets.n_cap if buckets is not None else self.plan.n
        self.generator = np.asarray(
            make_generator(self.nb, self.kb, key=self._generator_key)
            if buckets is not None
            else self.executor.generator(key=self._generator_key)
        )
        self.generator_j = jnp.asarray(self.generator)
        # coded blocks: (nb, R, D) = einsum over the block-reshaped table
        vp, d = self.table.shape
        pad = self.kb * self.block_rows - vp
        tbl = np.pad(self.table, ((0, pad), (0, 0)))
        blocks = tbl.reshape(self.kb, self.block_rows, d)
        self.coded = jnp.asarray(
            np.einsum("nk,krd->nrd", self.generator, blocks, optimize=True)
        )
        self.deadline = self.executor.deadline
        self._rows_of_worker = self.plan.row_ranges  # block ranges per worker
        # worker->block scatter map: block_owner[i] = worker holding coded
        # block i, so a (W,) finish mask gathers to an (nb,) erasure mask
        # in one device op (no per-worker Python loop at decode time).
        self.block_owner = self.executor.slot_owner

    def rebind_soft(self) -> None:
        """Rebind after a NON-structural bucket-switch replan.

        Shapes, generator and coded blocks are unchanged — compiled
        consumer programs stay valid, and the new branch state reaches
        them through ``executor.bucket_args()`` at the next dispatch.
        Only the cheap host-side plan views are refreshed here.
        """
        self.plan = self.executor.plan
        self.deadline = self.executor.deadline
        self._rows_of_worker = self.plan.row_ranges
        self.block_owner = self.executor.slot_owner

    def replan(self, new_cluster: ClusterSpec) -> DeploymentPlan:
        """Elastic replan + rebind (scheme params preserved by the engine)."""
        plan = self.executor.replan(new_cluster)
        if self.executor.last_replan_structural:
            self.refresh()
        else:
            self.rebind_soft()
        return plan

    # ------------------------------------------------------ jit pipeline
    def finish_mask_jit(self, key, deadline, *, mus=None, alphas=None,
                        shifts=None):
        """(W,) bool straggler mask, traceable (``CodedRoundExecutor``)."""
        return self.executor.finish_mask_jit(
            key, deadline, mus=mus, alphas=alphas, shifts=shifts
        )

    def encode_logits(self, logits, *, use_kernel: bool = False):
        """Mix plain logit BLOCKS with G: (B, V) -> (nb, B, R) products.

        Coded products are linear in the hidden state: (G (x) I_R) E h.
        Since logits = E h, mixing logit blocks with G is numerically
        identical to each worker computing E~_i h from h directly, so the
        erasure/decode path is exercised end-to-end without re-running
        the unembed matmul. ``use_kernel`` routes the mix through the
        Pallas coded-matvec kernel (one matvec per rhs column).
        """
        b, v = logits.shape
        vp = self.kb * self.block_rows
        lf = jnp.pad(logits.astype(jnp.float32), ((0, 0), (0, vp - v)))
        blocks = lf.reshape(b, self.kb, self.block_rows)
        if use_kernel:
            from repro.kernels.coded_matvec import ops as cmv_ops

            cols = blocks.transpose(1, 0, 2).reshape(self.kb, b * self.block_rows)
            mixed = jax.vmap(
                lambda col: cmv_ops.blocked_matvec(self.generator_j, col),
                in_axes=1, out_axes=1,
            )(cols)
            return mixed.reshape(self.nb, b, self.block_rows)
        return jnp.einsum("nk,bkr->nbr", self.generator_j, blocks)

    def decode_logits_jit(self, products, finished_workers):
        """Fixed-shape on-device decode: (nb, B, R) + (W,) -> ((B, kb*R), ok).

        The worker finish mask gathers through the precomputed scatter
        map to an (nb,) block-erasure mask; ``decode_systematic_jit``
        solves the static (kb, kb) system on-device. ``ok`` is a traced
        bool — the caller folds the insufficient-survivors fallback in
        with ``jnp.where`` instead of a Python branch.
        """
        alive = jnp.asarray(finished_workers, bool)[self.block_owner]
        nb, b, r = products.shape
        z, ok = decode_systematic_jit(
            self.generator_j, products.reshape(nb, b * r), alive
        )
        logits = z.reshape(self.kb, b, r).transpose(1, 0, 2).reshape(b, -1)
        return logits, ok

    def decode_logits_bucket_jit(self, products, alive_blocks):
        """``decode_logits_jit`` with a precomputed (nb,) block-alive mask.

        Bucket-switch path: the erasure mask comes from the selected
        bucket's owner/alive arrays (``slot_mask_bucket_jit`` — capacity
        padding rows always dead) instead of the static scatter map.
        """
        nb, b, r = products.shape
        z, ok = decode_systematic_jit(
            self.generator_j, products.reshape(nb, b * r),
            jnp.asarray(alive_blocks, bool),
        )
        logits = z.reshape(self.kb, b, r).transpose(1, 0, 2).reshape(b, -1)
        return logits, ok

    def worker_products(self, h, *, use_kernel: bool = False):
        """All coded block-products for a batch of hiddens h: (B, D).

        Returns (nb, B, R). In deployment each worker computes only its
        slice; here the full product is computed and the erasure mask is
        applied at decode time (deadline semantics — see DESIGN.md §3).
        ``use_kernel`` routes the per-worker matvec through the Pallas
        ``coded_matvec`` kernel.
        """
        hf = h.astype(jnp.float32)
        if use_kernel:
            from repro.kernels.coded_matvec import ops as cmv_ops

            per_seq = jax.vmap(lambda hb: cmv_ops.blocked_matvec_batch(self.coded, hb))
            return jnp.moveaxis(per_seq(hf), 0, 1)
        return jnp.einsum("nrd,bd->nbr", self.coded, hf)

    # ------------------------------------------- host-side reference path
    def decode_logits(self, products, finished_workers) -> tuple[np.ndarray, bool]:
        """Recover (B, Vp) logits from surviving coded block-products.

        Numpy reference oracle for ``decode_logits_jit`` (and the legacy
        ``jit_pipeline=False`` serving path).
        """
        products = np.asarray(products)  # (nb, B, R)
        fin = np.asarray(finished_workers, bool)
        alive_blocks = np.zeros((self.nb,), bool)
        for w, (s, e) in enumerate(self._rows_of_worker):
            if fin[w]:
                alive_blocks[s:e] = True
        if alive_blocks.sum() < self.kb:
            return np.zeros((products.shape[1], self.kb * self.block_rows)), False
        use = np.flatnonzero(alive_blocks)[: self.kb]
        g = self.generator[use]  # (kb, kb)
        y = products[use]  # (kb, B, R)
        z = np.linalg.solve(g, y.reshape(self.kb, -1)).reshape(self.kb, *y.shape[1:])
        logits = z.transpose(1, 0, 2).reshape(products.shape[1], -1)
        return logits, True

    def sample_finish_mask(self, key) -> np.ndarray:
        """Simulate which workers meet the deadline (shifted-exp model)."""
        return np.asarray(self.finish_mask_jit(key, self.deadline))


@dataclasses.dataclass(frozen=True)
class ServeReport:
    """Result of one ``Server.serve`` run over a request trace.

    Latencies and the clock are in ROUNDS (the serve loop's virtual
    clock: one slot-decode step = one round, one batched admit/prefill
    pass = one round) so scheduling outcomes are deterministic and
    CI-stable; ``wall_s`` is the measured wall time of the whole run for
    tokens/s comparisons.
    """

    finished: tuple  # FinishedRequest records, completion order
    tokens: int  # useful tokens emitted (done requests only)
    rounds: float  # final virtual-clock value
    decode_rounds: int
    prefill_rounds: int
    admitted: int
    shed: int
    wall_s: float

    @property
    def tokens_per_s(self) -> float:
        return self.tokens / self.wall_s if self.wall_s > 0 else float("inf")

    def latencies(self) -> np.ndarray:
        """Arrival-to-last-token latencies (rounds) of DONE requests."""
        return np.asarray(
            [f.latency for f in self.finished if f.outcome == "done"], float
        )

    def latency_percentile(self, q: float) -> float:
        lat = self.latencies()
        return float(np.percentile(lat, q)) if lat.size else float("nan")


class Server:
    """Batched decode with an optional coded LM head.

    The default path compiles a whole ``generate`` call — prefill scan,
    decode scan, coded erasure decode per token — into one XLA program;
    ``self.traces`` counts (re)traces so tests can assert that repeat
    calls with the same shapes never re-enter Python between tokens.

    ``serve`` is the continuous-batching mode (DESIGN.md §10): a
    slot-resident decode state driven by a ``SlotScheduler``, where
    request admits/evicts are pure buffer updates into ONE fused
    fixed-shape compiled program — a ``lax.cond``-gated prefill splice
    followed by a decode chunk (``serve_traces`` counts its (re)traces,
    one per distinct chunk size — slot swaps must not add any).
    """

    def __init__(self, model: Model, params, cluster: ClusterSpec | None = None,
                 cfg: ServeConfig | None = None):
        self.model = model
        self.params = params
        self.cfg = cfg or ServeConfig()
        self.coded_head = (
            CodedLMHead(
                params["embed"]["table"], cluster,
                block_rows=self.cfg.block_rows,
                scheme=self.cfg.scheme,
                deadline_safety=self.cfg.deadline_safety,
                bucket_config=self.cfg.bucket_config(),
            )
            if cluster is not None
            else None
        )
        self._decode = jax.jit(model.decode_step)
        self._prefill_fn = jax.jit(self._prefill_into_cache)
        self.traces = 0
        self.serve_traces = 0
        #: span tracer (§14); ``serve(tracer=...)`` rebinds it, and the
        #: no-op default keeps the untraced hot path allocation-free
        self.tracer = NULL_TRACER
        #: optional ground-truth (mus_w, alphas_w, shift_w) the next
        #: generate call samples straggling from (scenario closed loop)
        self._true_params = None
        #: the ClusterSpec behind _true_params (RoundClock decomposition
        #: needs the spec, not the flattened arrays)
        self._true_cluster = None
        self._generate_fn = jax.jit(
            self._gen_program, static_argnames=("max_new",)
        )
        # cache/logits/pos are donated: the serve loop threads them
        # through every dispatch and never reuses the old buffers, so XLA
        # can update the KV cache in place instead of copying it per call
        self._serve_step_fn = jax.jit(
            self._serve_step_program, static_argnames=("steps",),
            donate_argnums=(1, 2, 3),
        )
        self._serve_step_paged_fn = jax.jit(
            self._serve_step_paged_program, static_argnames=("steps",),
            donate_argnums=(1, 2, 3),
        )

    # --------------------------------------------------------- adaptivity
    def set_true_cluster(self, cluster: ClusterSpec | None) -> None:
        """Sample the NEXT generate call's straggling from this cluster.

        The scenario layer's ground truth: the coded head keeps planning
        against whatever the controller believes, but the in-program
        finish masks draw from the true cluster's parameters (leavers
        never respond, parameter drift shows up as missed deadlines).
        ``None`` restores sampling from the plan's own cluster.
        """
        if self.coded_head is None:
            raise ValueError("set_true_cluster requires a coded head")
        self._true_params = (
            None if cluster is None
            else self.coded_head.executor.worker_param_arrays(cluster)
        )
        self._true_cluster = cluster

    def refresh_coded_head(self) -> None:
        """Rebind the head to its executor's current plan and re-jit.

        The ``AdaptiveController.on_replan`` hook for serving: a replan
        changes the code size and scatter map, which are closure
        constants of the compiled generation program, so the jit cache
        must be dropped (the retrace IS the serve-side replan cost the
        controller's cost model charges for).

        Bucket-switch mode: after a NON-structural replan the compiled
        programs are still valid — the new branch state reaches them as
        runtime arguments — so only the cheap host views rebind and the
        jit caches survive (the whole point of DESIGN.md §11).
        """
        if self.coded_head is None:
            raise ValueError("refresh_coded_head requires a coded head")
        if not self.coded_head.executor.last_replan_structural:
            self.coded_head.rebind_soft()
            self._true_params = None  # possibly stale after any replan
            self._true_cluster = None
            return
        self.coded_head.refresh()
        self._true_params = None  # stale shapes after a replan
        self._true_cluster = None
        self._generate_fn = jax.jit(
            self._gen_program, static_argnames=("max_new",)
        )
        # cache/logits/pos are donated: the serve loop threads them
        # through every dispatch and never reuses the old buffers, so XLA
        # can update the KV cache in place instead of copying it per call
        self._serve_step_fn = jax.jit(
            self._serve_step_program, static_argnames=("steps",),
            donate_argnums=(1, 2, 3),
        )
        self._serve_step_paged_fn = jax.jit(
            self._serve_step_paged_program, static_argnames=("steps",),
            donate_argnums=(1, 2, 3),
        )

    def _bucket_args(self):
        """Fresh (bucket state, index) runtime args — None when off."""
        head = self.coded_head
        if head is None or head.executor.buckets is None:
            return None
        return head.executor.bucket_args()

    # ------------------------------------------------------- jit pipeline
    def _can_batch_prefill(self) -> bool:
        """True when ``Model.prefill`` covers this model (same support
        envelope as the slot/paged paths)."""
        c = self.model.config
        return (
            c.family in ("dense", "vlm", "moe")
            and not c.kv_quant
            and c.sliding_window is None
        )

    def _prefill_into_cache(self, params, cache, prompts):
        """Batched prefill spliced into an ``init_cache`` decode state.

        The generate-path counterpart of the serve splice: ONE
        ``Model.prefill`` pass computes every layer's prompt K/V and the
        last-position logits, which land in cache positions
        ``[0, s0)`` / the shared position map. Traceable — used inline by
        ``_gen_program`` and jitted standalone by the legacy host loop.
        """
        b, s0 = prompts.shape
        logits, ks, vs = self.model.prefill(
            params, prompts, jnp.full((b,), s0, jnp.int32)
        )
        kv = cache["kv"]
        cache = {
            **cache,
            "kv": {
                "k": kv["k"].at[:, :, :s0].set(ks),
                "v": kv["v"].at[:, :, :s0].set(vs),
                "pos": kv["pos"].at[:, :s0].set(
                    jnp.arange(s0, dtype=jnp.int32)
                ),
            },
        }
        return logits, cache

    def _coded_select(self, logits, step_key, deadline, true_params=None,
                      bucket_args=None):
        """One coded round on a (B, V) logits batch, fully traceable.

        Pad-vocab sentinels (-1e30) are zeroed before the block mix (they
        would otherwise dominate the float32 solve), decoded logits get
        them re-masked, and the insufficient-survivors fallback is a
        ``jnp.where`` on the decode-ok flag — no shape-dependent Python
        branch inside the compiled program. ``true_params`` optionally
        overrides the straggler-sampling parameters (ground-truth
        injection — see ``set_true_cluster``). ``bucket_args`` — the
        ``(stacked state, index)`` pair from ``executor.bucket_args()`` —
        switches the round onto the bucket-select path: loads, deadline
        and the slot-erasure mask all come from the branch picked
        in-program, so a replan within bucket capacity never retraces
        this program (DESIGN.md §11).
        """
        head = self.coded_head
        vocab = self.model.config.vocab_size
        ids = jnp.arange(logits.shape[-1])
        lf = logits.astype(jnp.float32)
        clean = jnp.where(ids[None, :] < vocab, lf, 0.0)
        products = head.encode_logits(clean, use_kernel=self.cfg.use_kernel)
        mus, alphas, shifts = (
            true_params if true_params is not None else (None, None, None)
        )
        if bucket_args is not None:
            state, index = bucket_args
            mask, sel = head.executor.finish_mask_bucket_jit(
                step_key, state, index, mus=mus, alphas=alphas, shifts=shifts
            )
            alive = head.executor.slot_mask_bucket_jit(mask, sel)
            dec, ok = head.decode_logits_bucket_jit(products, alive)
        else:
            mask = head.finish_mask_jit(
                step_key, deadline, mus=mus, alphas=alphas, shifts=shifts
            )
            dec, ok = head.decode_logits_jit(products, mask)
        dec = dec[:, : logits.shape[-1]]
        dec = jnp.where(ids[None, :] < vocab, dec, NEG_INF)
        return jnp.where(ok, dec, lf)

    def _gen_program(self, params, cache, prompts, key, deadline,
                     true_params=None, bucket_args=None, *, max_new):
        """The whole generation as one traceable program (two lax.scans)."""
        self.traces += 1  # python side effect: runs only while tracing
        b, s0 = prompts.shape
        c = self.model.config
        vp = padded_vocab(c.vocab_size)
        dt = DTYPES_LOGITS[c.logits_dtype]

        if self._can_batch_prefill():
            # one batched forward fills the whole prompt's KV (§4) — the
            # same ``Model.prefill`` splice the serve path uses, so both
            # generation paths share one prefill implementation
            logits, cache = self._prefill_into_cache(params, cache, prompts)
            logits = logits.astype(dt)
        else:
            # sequential fallback for families without a batched
            # cache-returning prefill (hybrid/ssm/audio, kv_quant, ...)
            def prefill_body(carry, inp):
                cache, _ = carry
                tok, pos = inp
                logits, cache = self.model.decode_step(params, cache, tok, pos)
                return (cache, logits), None

            (cache, logits), _ = jax.lax.scan(
                prefill_body,
                (cache, jnp.zeros((b, vp), dt)),
                (prompts.T, jnp.arange(s0, dtype=jnp.int32)),
            )

        def step_logits(logits, step):
            if self.coded_head is None:
                return logits
            return self._coded_select(
                logits, jax.random.fold_in(key, step), deadline, true_params,
                bucket_args,
            )

        # every sampled token goes through the coded head, including the
        # first post-prefill one (the old host loop skipped it)
        tok0 = jnp.argmax(step_logits(logits, 0), -1).astype(jnp.int32)

        def body(carry, t):
            cache, tok = carry
            logits, cache = self.model.decode_step(
                params, cache, tok, s0 + t
            )
            ntok = jnp.argmax(step_logits(logits, t + 1), -1).astype(jnp.int32)
            return (cache, ntok), ntok

        (cache, _), toks = jax.lax.scan(
            body, (cache, tok0), jnp.arange(max_new - 1, dtype=jnp.int32)
        )
        return jnp.concatenate([prompts, tok0[:, None], toks.T], axis=1)

    # ------------------------------------------- continuous batching mode
    def _serve_step_program(self, params, cache, logits, pos, prompts,
                            lengths, row_of_slot, active, key, deadline,
                            true_params=None, bucket_args=None, *, steps):
        """One fused serve iteration: optional admit splice + decode chunk.

        **Admit splice** (``lax.cond``-gated — the batched prefill costs
        nothing on rounds without admissions): ``prompts`` is the
        (S, prompt_cap) right-padded admission batch (row r = r-th
        request placed this round), ``row_of_slot`` maps slot -> admission
        row with −1 for slots keeping their current stream. Every splice
        target is a TRACED argument, so admitting into any slot pattern
        reuses the same compiled program. No token is sampled at admit —
        the prefill logits become the slot's pending-logits state and the
        chunk below samples from them, so every emitted token goes
        through the coded head at the same amortized place and a
        request's ``work`` is exactly 1 prefill round + ``out_len``
        decode rounds.

        **Decode chunk**: ``steps`` slot-decode rounds as one scan; each
        round samples every slot's next token from its pending logits
        (one coded round across the batch) and advances the model one
        step. ``active``: (S,) bool — frozen slots (done or empty)
        rewrite their current KV entry in place (idempotent) and keep
        logits/pos, so the program's shape never depends on which slots
        are live.
        """
        self.serve_traces += 1  # python side effect: runs only while tracing
        row_of_slot = jnp.asarray(row_of_slot, jnp.int32)
        fresh = row_of_slot >= 0  # (S,)

        def splice(ops):
            cache, logits, pos = ops
            plog, ks, vs = self.model.prefill(params, prompts, lengths)
            kv = cache["kv"]
            s_slots, cache_len = kv["pos"].shape
            prompt_cap = prompts.shape[1]
            row = jnp.clip(row_of_slot, 0, None)
            # prefilled K/V land in cache positions [0, prompt_cap) of
            # their slot; the padded tail stays masked via pos = -1
            k_new = jnp.zeros_like(kv["k"]).at[:, :, :prompt_cap].set(
                ks[:, row]
            )
            v_new = jnp.zeros_like(kv["v"]).at[:, :, :prompt_cap].set(
                vs[:, row]
            )
            fkv = fresh[None, :, None, None, None]
            plen = jnp.asarray(lengths, jnp.int32)[row]  # (S,)
            seq = jnp.arange(prompt_cap, dtype=jnp.int32)
            pos_rows = jnp.where(
                seq[None, :] < plen[:, None], seq[None, :], -1
            )
            pos_new = jnp.full((s_slots, cache_len), -1, jnp.int32)
            pos_new = pos_new.at[:, :prompt_cap].set(pos_rows)
            new_cache = {
                "kv": {
                    "k": jnp.where(fkv, k_new, kv["k"]),
                    "v": jnp.where(fkv, v_new, kv["v"]),
                    "pos": jnp.where(fresh[:, None], pos_new, kv["pos"]),
                }
            }
            new_logits = jnp.where(
                fresh[:, None], plog[row].astype(jnp.float32), logits
            )
            new_pos = jnp.where(fresh, plen, pos)
            return new_cache, new_logits, new_pos

        cache, logits, pos = jax.lax.cond(
            jnp.any(fresh), splice, lambda ops: ops,
            (cache, jnp.asarray(logits, jnp.float32),
             jnp.asarray(pos, jnp.int32)),
        )

        def body(carry, t):
            cache, logits, pos = carry
            sel = logits
            if self.coded_head is not None:
                sel = self._coded_select(
                    logits, jax.random.fold_in(key, t), deadline, true_params,
                    bucket_args,
                )
            tok = jnp.argmax(sel, -1).astype(jnp.int32)
            nlog, cache = self.model.decode_step_slots(
                params, cache, tok, pos
            )
            logits = jnp.where(
                active[:, None], nlog.astype(jnp.float32), logits
            )
            pos = jnp.where(active, pos + 1, pos)
            return (cache, logits, pos), tok

        (cache, logits, pos), toks = jax.lax.scan(
            body, (cache, logits, pos), jnp.arange(steps, dtype=jnp.int32)
        )
        return cache, logits, pos, toks

    def _serve_step_paged_program(self, params, cache, logits, pos,
                                  chunk_tokens, chunk_start, chunk_lens,
                                  finishing, tables, active, key, deadline,
                                  true_params=None, bucket_args=None, *,
                                  steps):
        """One fused PAGED serve iteration: prefill chunk + decode chunk.

        The paged twin of ``_serve_step_program`` (DESIGN.md §13). Shapes
        depend only on ``(num_blocks, block_len, S)`` and the prefill
        chunk width — never on any request's prompt length — so admitting
        a 4x-longer prompt retraces nothing: it just runs more admit
        rounds of the SAME program.

        **Prefill chunk** (``lax.cond``-gated): ``chunk_tokens`` is the
        (S, C) batch of this round's prompt chunks, row s covering
        prompt positions ``[chunk_start[s], chunk_start[s] +
        chunk_lens[s])`` of slot s's request (``chunk_lens == 0``: slot
        not prefilling). KV scatters into the slot's pool blocks through
        ``tables``; ``finishing`` marks slots whose prompt COMPLETES
        this round — their last-chunk logits become the slot's pending
        logits and ``pos`` jumps to the prompt length, exactly like the
        dense splice. Mid-prompt chunks update only the pool.

        **Decode chunk**: as in the dense program, but each step runs
        ``decode_step_paged`` — inactive slots (empty / done / still
        prefilling) write to the pool's sink block and keep logits/pos.
        """
        self.serve_traces += 1  # python side effect: runs only while tracing
        chunk_lens = jnp.asarray(chunk_lens, jnp.int32)
        finishing = jnp.asarray(finishing, bool)
        tables = jnp.asarray(tables, jnp.int32)
        active = jnp.asarray(active, bool)

        def splice(ops):
            cache, logits, pos = ops
            plog, new_cache = self.model.prefill_paged(
                params, cache, chunk_tokens, chunk_start, chunk_lens, tables
            )
            new_logits = jnp.where(
                finishing[:, None], plog.astype(jnp.float32), logits
            )
            new_pos = jnp.where(finishing, chunk_start + chunk_lens, pos)
            return new_cache, new_logits, new_pos

        cache, logits, pos = jax.lax.cond(
            jnp.any(chunk_lens > 0), splice, lambda ops: ops,
            (cache, jnp.asarray(logits, jnp.float32),
             jnp.asarray(pos, jnp.int32)),
        )

        def body(carry, t):
            cache, logits, pos = carry
            sel = logits
            if self.coded_head is not None:
                sel = self._coded_select(
                    logits, jax.random.fold_in(key, t), deadline, true_params,
                    bucket_args,
                )
            tok = jnp.argmax(sel, -1).astype(jnp.int32)
            nlog, cache = self.model.decode_step_paged(
                params, cache, tok, pos, tables, active,
                use_kernel=False,
            )
            logits = jnp.where(
                active[:, None], nlog.astype(jnp.float32), logits
            )
            pos = jnp.where(active, pos + 1, pos)
            return (cache, logits, pos), tok

        (cache, logits, pos), toks = jax.lax.scan(
            body, (cache, logits, pos), jnp.arange(steps, dtype=jnp.int32)
        )
        return cache, logits, pos, toks

    def serve(self, trace, *, slots: int = 4, prompt_cap: int | None = None,
              max_out: int | None = None, decode_block: int = 4,
              queue_cap: int = 64, admission_threshold: float = 1.0,
              controller=None, round_latency=None, telemetry=None,
              clock=None, key=None, paged: bool | None = None,
              block_len: int | None = None, num_blocks: int | None = None,
              prefill_chunk: int | None = None,
              tracer=None) -> ServeReport:
        """Continuous batching: serve a request trace through S slots.

        ``trace``: iterable of ``serve.workload.Request`` (arrivals in
        rounds). The scheduler (host) decides placements; the device side
        is ONE fused fixed-shape compiled program per chunk size — a
        ``lax.cond``-gated admit/prefill splice followed by the
        slot-decode chunk — whose arguments carry all per-round
        variation, so admits and evicts never retrace and an admission
        costs no extra dispatch. The program returns nothing the
        scheduler needs, so chunks dispatch asynchronously; the one
        ``block_until_ready`` sits at the end of the run.

        Admission control is wired to ``controller.coverage_latency``
        when an ``AdaptiveController`` is given (or any ``round_latency``
        callable, in round units): the reference latency is sampled once
        at start, and requests are shed when the backlog×slowdown
        projection blows their deadline class's budget
        (``serve.scheduler.SlotScheduler``).

        ``clock`` (a ``runtime.timing.RoundClock``) turns on the
        measured-reality loop (§12): each fused dispatch is timed
        (perf_counter + block_until_ready — chunks no longer overlap,
        that is the price of measuring), decomposed per worker, and —
        when ``controller`` is given — fed to
        ``controller.observe_timing`` so admission control and replans
        run on wall-clock evidence. Requires a coded head.

        ``paged`` (default from ``ServeConfig.paged``) serves from the
        block-pooled KV cache with chunked prefill (DESIGN.md §13):
        ``prompt_cap`` then only sets the default admission chunk width
        (``prefill_chunk``) — prompts longer than the chunk are admitted
        and prefilled across successive admit rounds instead of raising,
        and the cache shape is ``(num_blocks, block_len)``, independent
        of any prompt length. ``num_blocks=None`` sizes the pool so the
        trace can never exhaust it (dense-equivalent capacity);
        an explicit pool turns on memory admission control.
        """
        from repro.serve.scheduler import SlotScheduler

        if clock is not None and self.coded_head is None:
            raise ValueError("clock (measured serving) requires a coded head")

        # span tracing (§14): a telemetry sink implies spans on its
        # stream; an explicit tracer wins; neither means the shared
        # no-op (zero-allocation hot path)
        if tracer is None:
            tracer = (
                SpanTracer(telemetry) if telemetry is not None
                else NULL_TRACER
            )
        self.tracer = tracer
        if self.coded_head is not None:
            self.coded_head.executor.tracer = tracer

        paged = self.cfg.paged if paged is None else paged
        trace = sorted(trace, key=lambda r: (r.arrival, r.rid))
        if not trace:
            raise ValueError("serve needs a non-empty request trace")
        prompt_cap = int(
            prompt_cap if prompt_cap is not None
            else max(r.prompt_len for r in trace)
        )
        if not paged:
            # dense slot caches are (S, prompt_cap + max_out + 1): a
            # longer prompt cannot be represented. Paged mode has no such
            # bound — long prompts prefill chunk-by-chunk instead.
            too_long = [r.rid for r in trace if r.prompt_len > prompt_cap]
            if too_long:
                raise ValueError(
                    f"requests {too_long} exceed prompt_cap={prompt_cap}"
                )
        max_out = int(
            max_out if max_out is not None else max(r.out_len for r in trace)
        )
        # +1: a finished (frozen) slot idempotently rewrites the entry at
        # its final pos, which sits one past its last sampled token
        cache_len = prompt_cap + max_out + 1
        if round_latency is None and controller is not None:
            round_latency = controller.coverage_latency
        reference = 1.0
        if round_latency is not None:
            reference = float(round_latency())
            if not np.isfinite(reference) or reference <= 0:
                reference = 1.0
        if paged:
            return self._serve_paged(
                trace, slots=slots, prompt_cap=prompt_cap, max_out=max_out,
                decode_block=decode_block, queue_cap=queue_cap,
                admission_threshold=admission_threshold,
                controller=controller, round_latency=round_latency,
                reference=reference, telemetry=telemetry, clock=clock,
                key=key, block_len=block_len, num_blocks=num_blocks,
                prefill_chunk=prefill_chunk,
            )
        sched = SlotScheduler(
            slots, queue_cap=queue_cap,
            admission_threshold=admission_threshold,
            round_latency=round_latency, reference_latency=reference,
            telemetry=telemetry, metrics=MetricsRegistry(),
        )
        key = key if key is not None else jax.random.PRNGKey(0)
        deadline = jnp.float32(
            self.coded_head.deadline if self.coded_head is not None else 0.0
        )
        true_params = None
        if self.coded_head is not None:
            true_params = (
                self._true_params
                if self._true_params is not None
                else self.coded_head.executor.worker_params
            )
        bucket_args = self._bucket_args()
        cache = self.model.init_slot_cache(slots, cache_len)
        logits = jnp.zeros((slots, padded_vocab(self.model.config.vocab_size)),
                           jnp.float32)
        pos = jnp.zeros((slots,), jnp.int32)

        now, i, call = 0.0, 0, 0
        prefill_rounds = decode_rounds = 0
        # constant "no admissions this round" arguments (hoisted so the
        # common no-admit dispatch ships no fresh host arrays)
        no_prompts = jnp.zeros((slots, prompt_cap), jnp.int32)
        no_lengths = jnp.zeros((slots,), jnp.int32)
        no_rows = jnp.full((slots,), -1, jnp.int32)
        t0 = time.perf_counter()
        while i < len(trace) or not sched.idle:
            with tracer.span("admit", round=now) as asp:
                while i < len(trace) and trace[i].arrival <= now + 1e-9:
                    sched.offer(trace[i], now)
                    i += 1
                placed = sched.fill_slots(now)
                asp.set(placed=len(placed))
                if placed:
                    prompts_np = np.zeros((slots, prompt_cap), np.int32)
                    lengths_np = np.zeros((slots,), np.int32)
                    rows_np = np.full((slots,), -1, np.int32)
                    for r, (si, req) in enumerate(placed):
                        prompts_np[r, : req.prompt_len] = req.prompt
                        lengths_np[r] = req.prompt_len
                        rows_np[si] = r
                    prompts = jnp.asarray(prompts_np)
                    lengths = jnp.asarray(lengths_np)
                    rows = jnp.asarray(rows_np)
                else:
                    prompts, lengths, rows = no_prompts, no_lengths, no_rows
            active = [s.busy and not s.done for s in sched.slots]
            if any(active):
                # chunk exactly to the next finish event: slots free the
                # round their stream completes, with zero overshoot (at
                # most ``decode_block`` step-count variants ever compile)
                steps = min(
                    decode_block,
                    min(s.request.out_len - s.generated
                        for s in sched.slots if s.busy and not s.done),
                )
                if clock is not None:
                    # a measured replan may have moved the plan between
                    # dispatches: refresh the per-round runtime args
                    deadline = jnp.float32(self.coded_head.deadline)
                    true_params = (
                        self._true_params
                        if self._true_params is not None
                        else self.coded_head.executor.worker_params
                    )
                    bucket_args = self._bucket_args()
                skey = jax.random.fold_in(key, call)
                with tracer.span("decode_chunk", steps=steps,
                                 round=now, placed=len(placed)):
                    if clock is None:
                        with tracer.span("dispatch"):
                            cache, logits, pos, _ = self._serve_step_fn(
                                self.params, cache, logits, pos, prompts,
                                lengths, rows, jnp.asarray(active), skey,
                                deadline, true_params, bucket_args,
                                steps=steps,
                            )
                    else:
                        with tracer.span("dispatch"):
                            timing = clock.measure(
                                lambda: self._serve_step_fn(
                                    self.params, cache, logits, pos,
                                    prompts, lengths, rows,
                                    jnp.asarray(active), skey, deadline,
                                    true_params, bucket_args,
                                    steps=steps,
                                ),
                                key=skey,
                                true_cluster=self._true_cluster,
                            )
                        cache, logits, pos, _ = timing.result
                        if controller is not None:
                            d = controller.observe_timing(timing)
                            if (
                                d is not None and d.replanned
                                and self.coded_head
                                    .executor.last_replan_structural
                            ):
                                # next dispatch retraces the re-jitted
                                # program: compile, not round latency
                                clock.discard_next()
                call += 1
                if placed:  # the fused admit pass costs its own round
                    now += 1.0
                    prefill_rounds += 1
                now += float(steps)
                decode_rounds += steps
                sched.advance(steps)
                sched.retire_done(now)
            elif i < len(trace):
                now = max(now, trace[i].arrival)  # idle: jump to next arrival
            else:
                break
        jax.block_until_ready(logits)
        wall = time.perf_counter() - t0
        report = ServeReport(
            finished=tuple(sched.finished),
            tokens=sum(
                f.tokens for f in sched.finished if f.outcome == "done"
            ),
            rounds=now,
            decode_rounds=decode_rounds,
            prefill_rounds=prefill_rounds,
            admitted=sched.admitted,
            shed=sched.shed,
            wall_s=wall,
        )
        sched.metrics.emit(telemetry, phase="serve", rounds=float(now))
        return report

    def _serve_paged(self, trace, *, slots, prompt_cap, max_out,
                     decode_block, queue_cap, admission_threshold,
                     controller, round_latency, reference, telemetry, clock,
                     key, block_len, num_blocks, prefill_chunk) -> ServeReport:
        """Paged-KV host loop behind ``serve(paged=True)`` (DESIGN.md §13).

        Differences from the dense loop: physical KV lives in a shared
        ``BlockPool`` (full reservation at admission, freed at
        retirement); prompts prefill in ``chunk``-token pieces across
        admit rounds, so one compiled program per decode-chunk size
        covers EVERY prompt length; and rounds where every busy slot is
        still mid-prompt dispatch a prefill-only pass (``steps=0``).
        """
        from repro.serve.scheduler import BlockPool, SlotScheduler

        chunk = int(prefill_chunk if prefill_chunk is not None
                    else self.cfg.prefill_chunk if self.cfg.prefill_chunk
                    is not None else prompt_cap)
        bl = int(block_len if block_len is not None else self.cfg.block_len)
        nb = num_blocks if num_blocks is not None else self.cfg.num_blocks
        if nb is None:
            # dense-equivalent capacity: every slot can hold the trace's
            # largest request, so the pool never sheds — sizing DOWN from
            # this is the knob that turns on memory admission control
            per_req = max(
                -(-(r.prompt_len + r.out_len + 1) // bl) for r in trace
            )
            nb = slots * per_req
        nb = int(nb)
        cache = self.model.init_paged_cache(nb, bl)
        kv = cache["kv"]
        bytes_per_block = (kv["k"].nbytes + kv["v"].nbytes) // (nb + 1)
        tracer = self.tracer  # resolved by serve()
        # one registry for pool + scheduler: the run snapshots as a unit
        metrics = MetricsRegistry()
        pool = BlockPool(
            nb, bl, bytes_per_block=bytes_per_block, telemetry=telemetry,
            metrics=metrics,
        )
        sched = SlotScheduler(
            slots, queue_cap=queue_cap,
            admission_threshold=admission_threshold,
            round_latency=round_latency, reference_latency=reference,
            telemetry=telemetry, pool=pool, chunk=chunk, metrics=metrics,
        )
        key = key if key is not None else jax.random.PRNGKey(0)
        deadline = jnp.float32(
            self.coded_head.deadline if self.coded_head is not None else 0.0
        )
        true_params = None
        if self.coded_head is not None:
            true_params = (
                self._true_params
                if self._true_params is not None
                else self.coded_head.executor.worker_params
            )
        bucket_args = self._bucket_args()
        logits = jnp.zeros(
            (slots, padded_vocab(self.model.config.vocab_size)), jnp.float32
        )
        pos = jnp.zeros((slots,), jnp.int32)
        # host mirror of the device block tables, width = pool size (a
        # slot can never hold more than every block): shapes depend only
        # on (num_blocks, block_len, S)
        table_np = np.full((slots, nb), -1, np.int32)
        no_chunk = jnp.zeros((slots, chunk), jnp.int32)
        no_i32 = jnp.zeros((slots,), jnp.int32)
        no_bool = jnp.zeros((slots,), bool)

        now, i, call = 0.0, 0, 0
        prefill_rounds = decode_rounds = 0
        t0 = time.perf_counter()
        while i < len(trace) or not sched.idle:
            with tracer.span("admit", round=now) as asp:
                while i < len(trace) and trace[i].arrival <= now + 1e-9:
                    sched.offer(trace[i], now)
                    i += 1
                placed = sched.fill_slots(now)
                asp.set(placed=len(placed))
                for si, _req in placed:
                    blocks = sched.slots[si].blocks
                    table_np[si, :] = -1
                    table_np[si, : len(blocks)] = blocks
            # this round's prefill chunk: the next `chunk` unconsumed
            # prompt tokens of EVERY slot still mid-prompt (fresh admits
            # included) — one batched pass covers them all
            chunk_np = start_np = lens_np = fin_np = None
            notes = []
            for si, s in enumerate(sched.slots):
                if not s.prefilling:
                    continue
                if chunk_np is None:
                    chunk_np = np.zeros((slots, chunk), np.int32)
                    start_np = np.zeros((slots,), np.int32)
                    lens_np = np.zeros((slots,), np.int32)
                    fin_np = np.zeros((slots,), bool)
                take = min(chunk, s.request.prompt_len - s.prefilled)
                chunk_np[si, :take] = s.request.prompt[
                    s.prefilled : s.prefilled + take
                ]
                start_np[si] = s.prefilled
                lens_np[si] = take
                fin_np[si] = s.prefilled + take >= s.request.prompt_len
                notes.append((si, take))
            prefilling = chunk_np is not None
            # decode-eligible AFTER the splice: done prefilling already,
            # or finishing it in this very dispatch (so a short prompt
            # still costs exactly 1 admit round + out_len decode rounds,
            # matching the dense path's accounting)
            active = [
                s.busy and not s.done
                and (not s.prefilling or (fin_np is not None and fin_np[si]))
                for si, s in enumerate(sched.slots)
            ]
            steps = 0
            if any(active):
                steps = min(
                    decode_block,
                    min(s.request.out_len - s.generated
                        for si, s in enumerate(sched.slots) if active[si]),
                )
            if prefilling or steps > 0:
                if clock is not None:
                    deadline = jnp.float32(self.coded_head.deadline)
                    true_params = (
                        self._true_params
                        if self._true_params is not None
                        else self.coded_head.executor.worker_params
                    )
                    bucket_args = self._bucket_args()
                skey = jax.random.fold_in(key, call)
                args = (
                    self.params, cache, logits, pos,
                    jnp.asarray(chunk_np) if prefilling else no_chunk,
                    jnp.asarray(start_np) if prefilling else no_i32,
                    jnp.asarray(lens_np) if prefilling else no_i32,
                    jnp.asarray(fin_np) if prefilling else no_bool,
                    jnp.asarray(table_np), jnp.asarray(active), skey,
                    deadline, true_params, bucket_args,
                )
                # a round that splices prompt chunks is a prefill round
                # even when finishing slots decode in the same dispatch
                with tracer.span(
                    "prefill_chunk" if prefilling else "decode_chunk",
                    steps=steps, round=now, placed=len(placed),
                ):
                    if clock is None:
                        with tracer.span("dispatch"):
                            cache, logits, pos, _ = (
                                self._serve_step_paged_fn(
                                    *args, steps=steps
                                )
                            )
                    else:
                        with tracer.span("dispatch"):
                            timing = clock.measure(
                                lambda: self._serve_step_paged_fn(
                                    *args, steps=steps
                                ),
                                key=skey, true_cluster=self._true_cluster,
                            )
                        cache, logits, pos, _ = timing.result
                        if controller is not None:
                            d = controller.observe_timing(timing)
                            if (
                                d is not None and d.replanned
                                and self.coded_head
                                    .executor.last_replan_structural
                            ):
                                clock.discard_next()
                call += 1
                for si, take in notes:
                    sched.note_prefill(si, take)
                if prefilling:  # the batched chunk pass costs one round
                    now += 1.0
                    prefill_rounds += 1
                if steps > 0:
                    now += float(steps)
                    decode_rounds += steps
                    sched.advance(steps)
                for si, _fin in sched.retire_done(now):
                    table_np[si, :] = -1
            elif i < len(trace):
                now = max(now, trace[i].arrival)  # idle: jump to next arrival
            else:
                break
        jax.block_until_ready(logits)
        wall = time.perf_counter() - t0
        report = ServeReport(
            finished=tuple(sched.finished),
            tokens=sum(
                f.tokens for f in sched.finished if f.outcome == "done"
            ),
            rounds=now,
            decode_rounds=decode_rounds,
            prefill_rounds=prefill_rounds,
            admitted=sched.admitted,
            shed=sched.shed,
            wall_s=wall,
        )
        metrics.emit(telemetry, phase="serve", rounds=float(now))
        return report

    # ------------------------------------------------------------ public
    def generate(self, prompts, max_new: int | None = None, *, key=None,
                 cache_len: int | None = None, extras=None):
        """Greedy decode. prompts: (B, S0) int32. Returns (B, S0+T)."""
        key = key if key is not None else jax.random.PRNGKey(0)
        max_new = int(self.cfg.max_decode_steps if max_new is None else max_new)
        if max_new == 0:
            return jnp.asarray(prompts, jnp.int32)
        b, s0 = prompts.shape
        cache_len = cache_len or (s0 + max_new)
        cache = self.model.init_cache(b, cache_len, extras)
        if not self.cfg.jit_pipeline:
            return self._generate_hostloop(prompts, max_new, key, cache)
        deadline = jnp.float32(
            self.coded_head.deadline if self.coded_head is not None else 0.0
        )
        # straggler-sampling parameters ride along as (W,) arrays so the
        # scenario layer can change the truth every round without a
        # retrace (shapes only change on replan, which re-jits anyway)
        true_params = None
        if self.coded_head is not None:
            true_params = (
                self._true_params
                if self._true_params is not None
                else self.coded_head.executor.worker_params
            )
        with self.tracer.span("dispatch", kind="generate",
                              max_new=max_new, batch=b):
            return self._generate_fn(
                self.params, cache, jnp.asarray(prompts, jnp.int32), key,
                deadline, true_params, self._bucket_args(), max_new=max_new,
            )

    # ------------------------------------------------- legacy host loop
    def _generate_hostloop(self, prompts, max_new, key, cache):
        """Per-token Python loop with numpy decode (reference/baseline).

        Kept for ``benchmarks/serve_throughput.py``: this is the path the
        jit pipeline replaces — one host round-trip per decoded token.
        Prefill routes through the same jitted ``Model.prefill`` splice
        as the compiled pipeline (one shared prefill implementation)
        where supported; only the token loop stays sequential.
        """
        b, s0 = prompts.shape
        prompts = jnp.asarray(prompts, jnp.int32)
        if self._can_batch_prefill():
            logits, cache = self._prefill_fn(self.params, cache, prompts)
        else:
            logits = None
            for pos in range(s0):
                logits, cache = self._decode(
                    self.params, cache, prompts[:, pos], jnp.int32(pos)
                )
        out = [prompts]
        if self.coded_head is not None:
            logits = self._coded_logits(logits, key, 0)
        tok = jnp.argmax(logits, -1).astype(jnp.int32)
        for t in range(max_new):
            out.append(tok[:, None])
            if t == max_new - 1:
                break
            logits, cache = self._decode(self.params, cache, tok, jnp.int32(s0 + t))
            if self.coded_head is not None:
                logits = self._coded_logits(logits, key, t + 1)
            tok = jnp.argmax(logits, -1).astype(jnp.int32)
        return jnp.concatenate(out, axis=1)

    def _coded_logits(self, fallback_logits, key, step):
        """Recompute the final logits through the coded LM head (host path)."""
        head = self.coded_head
        vocab = self.model.config.vocab_size
        with self.tracer.span("erasure_solve", step=step) as sp:
            ids = np.arange(fallback_logits.shape[-1])
            lf = np.asarray(fallback_logits, np.float32)
            clean = np.where(ids[None, :] < vocab, lf, 0.0)
            products = head.encode_logits(
                jnp.asarray(clean), use_kernel=self.cfg.use_kernel
            )
            mask = head.sample_finish_mask(jax.random.fold_in(key, step))
            logits, ok = head.decode_logits(products, mask)
            sp.set(ok=bool(ok))
            if not ok:  # insufficient survivors: fall back (a real
                return fallback_logits  # system would extend the deadline)
            logits = logits[:, : fallback_logits.shape[-1]]
            logits = np.where(ids[None, :] < vocab, logits, NEG_INF)
            return jnp.asarray(logits)
