"""CodedRoundExecutor: the shared coded-execution substrate (DESIGN.md §5).

Serving and training run the same per-round protocol — plan a coded
deployment, derive a deadline, sample which workers make it, map worker
erasures onto coded-slot erasures, decode, and re-plan when the fleet
changes. Before this module the serving loop owned all of that
(``CodedLMHead`` precomputed scatter maps and straggler parameters
inline) and the training loop had none of it (host-side numpy helpers
exercised only by tests). ``CodedRoundExecutor`` extracts the mechanics
once:

* **deadline** — the scheme's expected latency x safety, finite for
  every registered scheme (``CodedComputeEngine.deadline``);
* **erasure-mask sampling** — ``finish_mask_jit`` draws per-worker
  round-trip times under the scheme's OWN latency model (comm-delay
  shifts included) inside the caller's compiled program;
* **worker->slot scatter map** — ``slot_owner[i]`` is the worker holding
  coded slot ``i`` (rows for the matvec head, coded gradients for
  training), so a (W,) finish mask gathers to an (n,) slot-erasure mask
  in one device op;
* **elastic replan** — ``replan``/``on_estimates_update`` rebuild the
  plan, deadline and scatter map on a membership or estimate change,
  scheme params riding on the engine's typed scheme object.

``CodedLMHead`` (serve) and ``Trainer`` (train) both consume one; the
registry/engine is therefore the single planning authority for every
coded workload.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp
import numpy as np

from repro.core.engine import CodedComputeEngine
from repro.core.planner import DeploymentPlan
from repro.core.runtime_model import (
    ClusterSpec,
    LatencyModel,
    comm_terms,
    sample_worker_times,
)
from repro.core.schemes import AllocationScheme


class CodedRoundExecutor:
    """Per-round mechanics for one coded workload (serve OR train).

    Device-resident state is recomputed on every (re)plan: the
    worker->slot scatter map and the per-worker shifted-exponential
    parameters the jitted finish-mask sampler draws from. All ``*_jit``
    methods are traceable and safe to close over in a compiled program;
    after a ``replan`` the consumer must rebuild anything traced against
    the old shapes (worker count and slot count may change).
    """

    def __init__(
        self,
        cluster: ClusterSpec,
        k: int,
        scheme: str | AllocationScheme = "optimal",
        *,
        scheme_params: dict | None = None,
        deadline_safety: float = 3.0,
    ):
        self.engine = CodedComputeEngine(
            cluster, k, scheme, scheme_params=scheme_params
        )
        self.deadline_safety = float(deadline_safety)
        self._refresh()

    # ----------------------------------------------------------- plan state
    def _refresh(self) -> None:
        """Recompute deadline + device arrays from the engine's plan."""
        plan = self.engine.plan
        self.plan: DeploymentPlan = plan
        self.deadline = self._integer_load_deadline(self.deadline_safety)
        owner = np.zeros((plan.n,), np.int32)
        for w, (s, e) in enumerate(plan.row_ranges):
            owner[s:e] = w
        #: (n,) worker index holding each coded slot
        self.slot_owner = jnp.asarray(owner)
        self._loads_w = jnp.asarray(plan.loads_per_worker, jnp.float32)
        self._mus_w, self._alphas_w, self._shift_w = self.worker_param_arrays()

    def worker_param_arrays(self, cluster: ClusterSpec | None = None):
        """(mus_w, alphas_w, shift_w) for the plan's workers under ``cluster``.

        Defaults to the plan's own cluster (the arrays the jitted finish
        mask samples from). Passing a different cluster maps the CURRENT
        plan's workers onto that cluster's group parameters — the
        scenario layer's ground truth, so a closed-loop simulation can
        sample what *actually* happens to a possibly-stale plan. Group
        correspondence is by index; when a true group has fewer workers
        than planned (a leave burst) the planned tail gets an infinite
        shift — those workers never respond — and extra true workers
        (joins) are invisible until a replan deploys them. Comm-delay
        schemes derive their transfer terms from the given cluster's
        bandwidths, so link changes flow through too.
        """
        plan = self.plan
        if cluster is None:
            cluster = plan.cluster
        sch = self.engine.scheme
        ng = cluster.num_groups
        if sch.latency_model is LatencyModel.COMM_DELAY:
            shift_g, dal_g = comm_terms(cluster, sch.upload, sch.download)
        else:
            shift_g, dal_g = np.zeros(ng), np.zeros(ng)
        planned = [g.num_workers for g in plan.cluster.groups]
        mus, alphas, shifts = [], [], []
        rank_in_group = dict.fromkeys(range(len(planned)), 0)
        for j in plan.group_of_worker:
            j = int(j)
            alive_j = (
                cluster.groups[j].num_workers if j < ng else 0
            )
            if rank_in_group[j] < alive_j:
                g = cluster.groups[j]
                mus.append(g.mu)
                alphas.append(g.alpha + dal_g[j])
                shifts.append(shift_g[j])
            else:  # departed worker: never responds
                mus.append(1.0)
                alphas.append(1.0)
                shifts.append(np.inf)
            rank_in_group[j] += 1
        return (
            jnp.asarray(mus),
            jnp.asarray(alphas),
            jnp.asarray(shifts, jnp.float32),
        )

    # convenience views ----------------------------------------------------
    @property
    def worker_params(self):
        """(mus_w, alphas_w, shift_w) the finish-mask sampler defaults to."""
        return self._mus_w, self._alphas_w, self._shift_w

    @property
    def scheme(self) -> AllocationScheme:
        return self.engine.scheme

    @property
    def cluster(self) -> ClusterSpec:
        return self.plan.cluster

    @property
    def k(self) -> int:
        return self.engine.k

    @property
    def n(self) -> int:
        """Total coded slots deployed."""
        return self.plan.n

    @property
    def num_workers(self) -> int:
        return self.plan.num_workers

    def generator(self, key=None, kind: str = "systematic_gaussian"):
        """(n, k) MDS generator / assignment matrix sized to the plan."""
        return self.engine.generator(key=key, kind=kind)

    #: integer/real load ratio beyond which the analytic deadline is
    #: distrusted and the deployment's integer loads are Monte-Carlo'd
    INTEGERIZATION_SLACK = 1.05

    def _integer_load_deadline(self, safety: float, *, key=None,
                               num_trials: int = 2_048) -> float:
        """Deadline commensurate with the INTEGERIZED deployment.

        ``plan_deadline``'s analytic ``T*`` describes the real-valued
        allocation, but ``finish_mask_jit`` samples the integer
        per-worker loads that actually run; at small ``k`` (few gradient
        partitions) the ``ceil`` can inflate a load several-fold and the
        analytic deadline would erase every round. Policy: when the
        integerization is benign (every ``ceil(l)/l`` within
        ``INTEGERIZATION_SLACK`` — the serving case, where k is in the
        thousands) keep ``plan_deadline``'s cheap analytic/MC-fallback
        path so (re)plans stay closed-form in the failure path;
        otherwise Monte-Carlo the scheme's expected latency ON the
        integer loads, floored by the analytic bound.
        """
        plan = self.plan
        alloc = plan.allocation
        if alloc is not None:
            real = np.asarray(alloc.loads, float)
            live = real > 0
            inflation = float(
                np.max(alloc.loads_int[live] / real[live], initial=1.0)
            )
        else:  # legacy plan without the real-valued allocation attached
            inflation = float("inf")
        if inflation <= self.INTEGERIZATION_SLACK:
            # PR-2 serving policy unchanged: analytic T* when the scheme
            # has one, the scheme's own MC estimate otherwise
            return self.engine.deadline(safety, key=key,
                                        num_trials=num_trials)
        if key is None:
            key = jax.random.PRNGKey(0)
        t = float(
            self.engine.expected_latency(
                key, num_trials, use_integer_loads=True
            )
        )
        analytic = float(plan.t_star)
        if np.isfinite(analytic):
            t = max(t, analytic)
        return t * safety

    # ------------------------------------------------------- jitted methods
    def round_times_jit(self, key, *, mus=None, alphas=None, shifts=None):
        """(W,) per-worker round times, traceable (shifted-exp model).

        Samples the plan's integer loads under the scheme's OWN latency
        model. The ``mus``/``alphas``/``shifts`` overrides (shapes (W,))
        let a closed-loop caller sample under the scenario layer's TRUE
        cluster parameters (``worker_param_arrays(true_cluster)``) while
        the plan — and therefore the loads and deadline — stays whatever
        the controller last believed; they may be traced arrays, so the
        truth can change every round without retracing.
        """
        t = sample_worker_times(
            key,
            self._loads_w,
            self._mus_w if mus is None else mus,
            self._alphas_w if alphas is None else alphas,
            self.k,
            1,
            model=self.engine.scheme.latency_model,
            shift_per_worker=self._shift_w if shifts is None else shifts,
        )[0]
        return t

    def finish_mask_jit(self, key, deadline=None, *, mus=None, alphas=None,
                        shifts=None):
        """(W,) bool straggler mask, traceable (shifted-exp model).

        Samples under the scheme's OWN latency model so the times are
        commensurate with the deadline (which ``plan_deadline`` computes
        under that same model — e.g. reisizadeh is per-row MODEL_30,
        comm-aware adds per-worker transfer shifts). ``deadline`` may be
        a traced scalar; defaults to the executor's planned one. The
        parameter overrides are ``round_times_jit``'s (ground-truth
        injection for closed-loop scenarios).
        """
        if deadline is None:
            deadline = self.deadline
        t = self.round_times_jit(key, mus=mus, alphas=alphas, shifts=shifts)
        return t <= deadline

    def slot_mask_jit(self, worker_mask):
        """Gather a (W,) worker finish mask to the (n,) slot-erasure mask."""
        return jnp.asarray(worker_mask, bool)[self.slot_owner]

    def sample_finish_mask(self, key) -> np.ndarray:
        """Host-side convenience: one sampled mask at the planned deadline."""
        return np.asarray(self.finish_mask_jit(key, self.deadline))

    def sample_round_times(self, key, cluster: ClusterSpec | None = None
                           ) -> np.ndarray:
        """Host-side: one (W,) round-time draw, optionally under a TRUE
        cluster's parameters (the observation feed for a
        ``StragglerTracker``/``AdaptiveController`` closed loop). Same
        computation as the in-program sampler, so feeding it the same
        key yields times consistent with the compiled step's mask.
        """
        if cluster is None:
            mus = alphas = shifts = None
        else:
            mus, alphas, shifts = self.worker_param_arrays(cluster)
        return np.asarray(
            self.round_times_jit(key, mus=mus, alphas=alphas, shifts=shifts)
        )

    # ----------------------------------------------------------- elasticity
    def replan(self, new_cluster: ClusterSpec) -> DeploymentPlan:
        """Re-plan on a membership/estimate change; scheme params preserved.

        Rebuilds the deadline, scatter map and sampling arrays. Consumers
        holding compiled programs traced against the old worker/slot
        shapes must rebuild them (both loops do).
        """
        plan = self.engine.replan(new_cluster)
        self._refresh()
        return plan

    def on_estimates_update(self, tracker) -> DeploymentPlan:
        """Replan from a ``StragglerTracker``'s current estimated cluster."""
        return self.replan(tracker.estimated_cluster())

    @property
    def replans(self) -> int:
        return self.engine.replans
