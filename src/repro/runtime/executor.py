"""CodedRoundExecutor: the shared coded-execution substrate (DESIGN.md §5).

Serving and training run the same per-round protocol — plan a coded
deployment, derive a deadline, sample which workers make it, map worker
erasures onto coded-slot erasures, decode, and re-plan when the fleet
changes. Before this module the serving loop owned all of that
(``CodedLMHead`` precomputed scatter maps and straggler parameters
inline) and the training loop had none of it (host-side numpy helpers
exercised only by tests). ``CodedRoundExecutor`` extracts the mechanics
once:

* **deadline** — the scheme's expected latency x safety, finite for
  every registered scheme (``CodedComputeEngine.deadline``);
* **erasure-mask sampling** — ``finish_mask_jit`` draws per-worker
  round-trip times under the scheme's OWN latency model (comm-delay
  shifts included) inside the caller's compiled program;
* **worker->slot scatter map** — ``slot_owner[i]`` is the worker holding
  coded slot ``i`` (rows for the matvec head, coded gradients for
  training), so a (W,) finish mask gathers to an (n,) slot-erasure mask
  in one device op;
* **elastic replan** — ``replan``/``on_estimates_update`` rebuild the
  plan, deadline and scatter map on a membership or estimate change,
  scheme params riding on the engine's typed scheme object.

``CodedLMHead`` (serve) and ``Trainer`` (train) both consume one; the
registry/engine is therefore the single planning authority for every
coded workload.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp
import numpy as np

from repro.core.engine import CodedComputeEngine, plan_deadline
from repro.core.planner import DeploymentPlan
from repro.core.runtime_model import (
    ClusterSpec,
    LatencyModel,
    comm_terms,
    sample_worker_times,
)
from repro.core.schemes import AllocationScheme
from repro.obs.trace import NULL_TRACER
from repro.runtime.plan_bucket import (
    BucketConfig,
    PlanBucketSet,
    bucket_signature,
    quantize_loads_int,
    quantize_plan,
    select_bucket,
)


class CodedRoundExecutor:
    """Per-round mechanics for one coded workload (serve OR train).

    Device-resident state is recomputed on every (re)plan: the
    worker->slot scatter map and the per-worker shifted-exponential
    parameters the jitted finish-mask sampler draws from. All ``*_jit``
    methods are traceable and safe to close over in a compiled program;
    after a ``replan`` the consumer must rebuild anything traced against
    the old shapes (worker count and slot count may change).

    **Bucket-switch mode** (``bucket_config`` set, DESIGN.md §11):
    integer loads are quantized to bucket shapes, admitted buckets are
    held as stacked runtime-argument state (``bucket_args``), and the
    ``*_bucket_jit`` methods select the active branch in-program via
    ``lax.switch`` on a runtime bucket index — so a replan that stays
    within the admitted worker count and slot capacity NEVER retraces a
    consumer program (``last_replan_structural`` tells consumers whether
    a rebuild is required; ``plan_bucket_hit``/``plan_bucket_miss``
    telemetry events surface the cache behaviour).
    """

    def __init__(
        self,
        cluster: ClusterSpec,
        k: int,
        scheme: str | AllocationScheme = "optimal",
        *,
        scheme_params: dict | None = None,
        deadline_safety: float = 3.0,
        bucket_config: BucketConfig | None = None,
        telemetry=None,
        tracer=None,
    ):
        self.engine = CodedComputeEngine(
            cluster, k, scheme, scheme_params=scheme_params
        )
        self.deadline_safety = float(deadline_safety)
        self.bucket_config = bucket_config
        self.telemetry = telemetry
        #: span tracer (§14); the owning loop shares its tracer so
        #: ``replan``/``bucket_switch`` spans nest under loop spans
        self.tracer = tracer if tracer is not None else NULL_TRACER
        #: admitted bucket branches (None = bucketing off)
        self.buckets: PlanBucketSet | None = None
        #: row of ``buckets`` the current plan lives in
        self.active_bucket = 0
        #: did the last (re)plan change shapes (consumer must rebuild)?
        self.last_replan_structural = True
        #: did the last replan land in an already-admitted bucket?
        self.last_bucket_hit = False
        self._refresh()

    # ----------------------------------------------------------- plan state
    def _refresh(self) -> None:
        """Structural (re)build from the engine's plan."""
        plan = self.engine.plan
        if self.bucket_config is not None:
            plan = quantize_plan(plan, self.bucket_config.quantum)
        self._bind_plan(plan)
        if self.bucket_config is not None:
            self._init_buckets()

    def _bind_plan(self, plan: DeploymentPlan) -> None:
        """Recompute deadline + device arrays for the active plan."""
        self.plan: DeploymentPlan = plan
        self.deadline = self._integer_load_deadline(self.deadline_safety)
        owner = np.zeros((plan.n,), np.int32)
        for w, (s, e) in enumerate(plan.row_ranges):
            owner[s:e] = w
        #: (n,) worker index holding each coded slot
        self.slot_owner = jnp.asarray(owner)
        self._loads_w = jnp.asarray(plan.loads_per_worker, jnp.float32)
        self._mus_w, self._alphas_w, self._shift_w = self.worker_param_arrays()

    def _init_buckets(self) -> None:
        cfg = self.bucket_config
        plan = self.plan
        n_cap = int(np.ceil(plan.n * cfg.n_headroom))
        self.buckets = PlanBucketSet(plan.num_workers, n_cap, cfg.capacity)
        sig = bucket_signature(
            plan.cluster, plan.allocation.loads_int, self.k
        )
        self.active_bucket, _ = self.buckets.admit(
            sig, plan, self.deadline, *self.worker_params
        )

    def _emit_bucket_event(self, *, hit: bool, structural: bool) -> None:
        if self.telemetry is None:
            return
        self.telemetry.event(
            "plan_bucket_hit" if hit else "plan_bucket_miss",
            structural=structural,
            bucket=self.active_bucket,
            buckets=len(self.buckets) if self.buckets is not None else 0,
            n=self.plan.n,
            n_cap=self.buckets.n_cap if self.buckets is not None else 0,
            workers=self.plan.num_workers,
        )

    def worker_param_arrays(self, cluster: ClusterSpec | None = None):
        """(mus_w, alphas_w, shift_w) for the plan's workers under ``cluster``.

        Defaults to the plan's own cluster (the arrays the jitted finish
        mask samples from). Passing a different cluster maps the CURRENT
        plan's workers onto that cluster's group parameters — the
        scenario layer's ground truth, so a closed-loop simulation can
        sample what *actually* happens to a possibly-stale plan. Group
        correspondence is by index; when a true group has fewer workers
        than planned (a leave burst) the planned tail gets an infinite
        shift — those workers never respond — and extra true workers
        (joins) are invisible until a replan deploys them. Comm-delay
        schemes derive their transfer terms from the given cluster's
        bandwidths, so link changes flow through too.
        """
        plan = self.plan
        if cluster is None:
            cluster = plan.cluster
        sch = self.engine.scheme
        ng = cluster.num_groups
        if sch.latency_model is LatencyModel.COMM_DELAY:
            shift_g, dal_g = comm_terms(cluster, sch.upload, sch.download)
        else:
            shift_g, dal_g = np.zeros(ng), np.zeros(ng)
        planned = [g.num_workers for g in plan.cluster.groups]
        mus, alphas, shifts = [], [], []
        rank_in_group = dict.fromkeys(range(len(planned)), 0)
        for j in plan.group_of_worker:
            j = int(j)
            alive_j = (
                cluster.groups[j].num_workers if j < ng else 0
            )
            if rank_in_group[j] < alive_j:
                g = cluster.groups[j]
                mus.append(g.mu)
                alphas.append(g.alpha + dal_g[j])
                shifts.append(shift_g[j])
            else:  # departed worker: never responds
                mus.append(1.0)
                alphas.append(1.0)
                shifts.append(np.inf)
            rank_in_group[j] += 1
        return (
            jnp.asarray(mus),
            jnp.asarray(alphas),
            jnp.asarray(shifts, jnp.float32),
        )

    # convenience views ----------------------------------------------------
    @property
    def worker_params(self):
        """(mus_w, alphas_w, shift_w) the finish-mask sampler defaults to."""
        return self._mus_w, self._alphas_w, self._shift_w

    @property
    def scheme(self) -> AllocationScheme:
        return self.engine.scheme

    @property
    def cluster(self) -> ClusterSpec:
        return self.plan.cluster

    @property
    def k(self) -> int:
        return self.engine.k

    @property
    def n(self) -> int:
        """Total coded slots deployed."""
        return self.plan.n

    @property
    def num_workers(self) -> int:
        return self.plan.num_workers

    def generator(self, key=None, kind: str = "systematic_gaussian"):
        """(n, k) MDS generator / assignment matrix sized to the plan."""
        return self.engine.generator(key=key, kind=kind)

    #: integer/real load ratio beyond which the analytic deadline is
    #: distrusted and the deployment's integer loads are Monte-Carlo'd
    INTEGERIZATION_SLACK = 1.05

    def _integer_load_deadline(self, safety: float, *, key=None,
                               num_trials: int = 2_048) -> float:
        """Deadline commensurate with the INTEGERIZED deployment.

        ``plan_deadline``'s analytic ``T*`` describes the real-valued
        allocation, but ``finish_mask_jit`` samples the integer
        per-worker loads that actually run; at small ``k`` (few gradient
        partitions) the ``ceil`` can inflate a load several-fold and the
        analytic deadline would erase every round. Policy: when the
        integerization is benign (every ``ceil(l)/l`` within
        ``INTEGERIZATION_SLACK`` — the serving case, where k is in the
        thousands) keep ``plan_deadline``'s cheap analytic/MC-fallback
        path so (re)plans stay closed-form in the failure path;
        otherwise Monte-Carlo the scheme's expected latency ON the
        integer loads, floored by the analytic bound.
        """
        plan = self.plan
        alloc = plan.allocation
        if alloc is not None:
            real = np.asarray(alloc.loads, float)
            live = real > 0
            inflation = float(
                np.max(alloc.loads_int[live] / real[live], initial=1.0)
            )
        else:  # legacy plan without the real-valued allocation attached
            inflation = float("inf")
        if inflation <= self.INTEGERIZATION_SLACK:
            # PR-2 serving policy unchanged: analytic T* when the scheme
            # has one, the scheme's own MC estimate otherwise. Computed
            # from the EXECUTOR's plan (not the engine's) so bucket
            # quantization flows into the MC fallback.
            return plan_deadline(self.plan, safety, key=key,
                                 num_trials=num_trials)
        if key is None:
            key = jax.random.PRNGKey(0)
        t = float(
            jnp.mean(
                self.engine.scheme.simulate(
                    key, plan.cluster, alloc, num_trials,
                    use_integer_loads=True,
                )
            )
        )
        analytic = float(plan.t_star)
        if np.isfinite(analytic):
            t = max(t, analytic)
        return t * safety

    # ------------------------------------------------------- jitted methods
    def round_times_jit(self, key, *, mus=None, alphas=None, shifts=None):
        """(W,) per-worker round times, traceable (shifted-exp model).

        Samples the plan's integer loads under the scheme's OWN latency
        model. The ``mus``/``alphas``/``shifts`` overrides (shapes (W,))
        let a closed-loop caller sample under the scenario layer's TRUE
        cluster parameters (``worker_param_arrays(true_cluster)``) while
        the plan — and therefore the loads and deadline — stays whatever
        the controller last believed; they may be traced arrays, so the
        truth can change every round without retracing.
        """
        t = sample_worker_times(
            key,
            self._loads_w,
            self._mus_w if mus is None else mus,
            self._alphas_w if alphas is None else alphas,
            self.k,
            1,
            model=self.engine.scheme.latency_model,
            shift_per_worker=self._shift_w if shifts is None else shifts,
        )[0]
        return t

    def finish_mask_jit(self, key, deadline=None, *, mus=None, alphas=None,
                        shifts=None):
        """(W,) bool straggler mask, traceable (shifted-exp model).

        Samples under the scheme's OWN latency model so the times are
        commensurate with the deadline (which ``plan_deadline`` computes
        under that same model — e.g. reisizadeh is per-row MODEL_30,
        comm-aware adds per-worker transfer shifts). ``deadline`` may be
        a traced scalar; defaults to the executor's planned one. The
        parameter overrides are ``round_times_jit``'s (ground-truth
        injection for closed-loop scenarios).
        """
        if deadline is None:
            deadline = self.deadline
        t = self.round_times_jit(key, mus=mus, alphas=alphas, shifts=shifts)
        return t <= deadline

    def slot_mask_jit(self, worker_mask):
        """Gather a (W,) worker finish mask to the (n,) slot-erasure mask."""
        return jnp.asarray(worker_mask, bool)[self.slot_owner]

    def sample_finish_mask(self, key) -> np.ndarray:
        """Host-side convenience: one sampled mask at the planned deadline."""
        return np.asarray(self.finish_mask_jit(key, self.deadline))

    def sample_round_times(self, key, cluster: ClusterSpec | None = None
                           ) -> np.ndarray:
        """Host-side: one (W,) round-time draw, optionally under a TRUE
        cluster's parameters (the observation feed for a
        ``StragglerTracker``/``AdaptiveController`` closed loop). Same
        computation as the in-program sampler, so feeding it the same
        key yields times consistent with the compiled step's mask.
        """
        if cluster is None:
            mus = alphas = shifts = None
        else:
            mus, alphas, shifts = self.worker_param_arrays(cluster)
        return np.asarray(
            self.round_times_jit(key, mus=mus, alphas=alphas, shifts=shifts)
        )

    def round_observation(
        self, key, cluster: ClusterSpec | None = None
    ) -> tuple[np.ndarray, np.ndarray]:
        """Host-side: one round's ((W,) times, (W,) per-worker shifts).

        The single observation feed shared by the simulated closed loop
        (``AdaptiveController.observe_truth``) and the measured one
        (``runtime.timing.RoundClock``, which uses the times as the
        relative split of a wall-clock round and the shifts as the
        transfer shares). ``cluster`` injects the scenario layer's TRUE
        parameters; leavers carry ``inf`` shift, so their times come
        back ``inf`` (never responded).
        """
        if cluster is None:
            mus, alphas, shifts = self.worker_params
        else:
            mus, alphas, shifts = self.worker_param_arrays(cluster)
        times = np.asarray(
            self.round_times_jit(key, mus=mus, alphas=alphas, shifts=shifts)
        )
        return times, np.asarray(shifts)

    # ------------------------------------------------------- bucket switch
    def bucket_args(self):
        """(stacked bucket state, active index) for a compiled program.

        Fetch FRESH on every dispatch and pass both as runtime arguments
        (never close over them): replans rewrite array values and the
        index, and runtime arguments are the only way those updates
        reach an already-compiled program without a retrace.
        """
        if self.buckets is None:
            raise RuntimeError("bucket_args requires bucket_config")
        return self.buckets.device_state(), jnp.int32(self.active_bucket)

    def round_times_bucket_jit(self, key, state, index, *, mus=None,
                               alphas=None, shifts=None):
        """((W,) round times, selected bucket) — bucket-switch sampler.

        Like ``round_times_jit`` but loads/params/deadline come from the
        bucket branch selected in-program (``lax.switch`` on ``index``),
        so the SAME trace serves every admitted plan. The selected
        branch dict is returned for deadline/slot-mask reuse. Overrides
        inject ground truth exactly as in ``round_times_jit``.
        """
        sel = select_bucket(state, index)
        t = sample_worker_times(
            key,
            sel["loads"],
            sel["mus"] if mus is None else mus,
            sel["alphas"] if alphas is None else alphas,
            self.k,
            1,
            model=self.engine.scheme.latency_model,
            shift_per_worker=sel["shifts"] if shifts is None else shifts,
        )[0]
        return t, sel

    def finish_mask_bucket_jit(self, key, state, index, *, mus=None,
                               alphas=None, shifts=None):
        """((W,) finish mask, selected bucket) at the bucket's deadline."""
        t, sel = self.round_times_bucket_jit(
            key, state, index, mus=mus, alphas=alphas, shifts=shifts
        )
        return t <= sel["deadline"], sel

    def slot_mask_bucket_jit(self, worker_mask, sel):
        """(n_cap,) slot-erasure mask from a (W,) worker mask.

        Capacity padding rows are masked dead via the bucket's alive
        mask, so decoders treat them exactly like erasures.
        """
        return jnp.asarray(worker_mask, bool)[sel["owner"]] & sel["alive"]

    def bucket_probe(self, candidate_cluster: ClusterSpec) -> bool | None:
        """Would replanning onto ``candidate_cluster`` be retrace-free?

        True iff the candidate plan's quantized signature is already
        admitted (no structural rebuild, no new branch compile) — the
        controller charges ``replan_cost`` only when this is False.
        Cheap: ``allocate`` is memoized and the fast path is jitted.
        None when bucketing is off.
        """
        if self.buckets is None:
            return None
        if candidate_cluster.total_workers != self.buckets.num_workers:
            return False
        alloc = self.engine.scheme.allocate(candidate_cluster, self.k)
        q = quantize_loads_int(alloc.loads_int, self.bucket_config.quantum)
        n_w = np.asarray(
            [g.num_workers for g in candidate_cluster.groups], np.int64
        )
        if int(np.sum(n_w * q)) > self.buckets.n_cap:
            return False
        return bucket_signature(candidate_cluster, q, self.k) in self.buckets

    # ----------------------------------------------------------- elasticity
    def replan(self, new_cluster: ClusterSpec) -> DeploymentPlan:
        """Re-plan on a membership/estimate change; scheme params preserved.

        Rebuilds the deadline, scatter map and sampling arrays. Without
        bucketing, consumers holding compiled programs traced against
        the old worker/slot shapes must rebuild them (both loops do).
        With bucketing, a replan that keeps the worker count and fits
        the slot capacity only updates bucket state + the active index
        (``last_replan_structural`` False): compiled bucket-switch
        programs keep running with zero retraces.
        """
        with self.tracer.span("replan") as sp:
            self.engine.replan(new_cluster)
            if self.bucket_config is None:
                self._refresh()
                self.last_replan_structural = True
                sp.set(structural=True, workers=self.plan.num_workers)
                return self.plan
            qplan = quantize_plan(
                self.engine.plan, self.bucket_config.quantum
            )
            structural = (
                self.buckets is None
                or qplan.num_workers != self.buckets.num_workers
                or qplan.n > self.buckets.n_cap
            )
            if structural:
                self._refresh()
                self.last_replan_structural = True
                self.last_bucket_hit = False
                self._emit_bucket_event(hit=False, structural=True)
                sp.set(structural=True, workers=self.plan.num_workers)
                return self.plan
            with self.tracer.span("bucket_switch") as bsp:
                self._bind_plan(qplan)
                sig = bucket_signature(
                    qplan.cluster, qplan.allocation.loads_int, self.k
                )
                self.active_bucket, hit = self.buckets.admit(
                    sig, qplan, self.deadline, *self.worker_params
                )
                self.last_replan_structural = False
                self.last_bucket_hit = hit
                self._emit_bucket_event(hit=hit, structural=False)
                bsp.set(hit=hit, bucket=self.active_bucket)
            sp.set(structural=False, hit=hit,
                   workers=self.plan.num_workers)
            return self.plan

    def on_estimates_update(self, tracker) -> DeploymentPlan:
        """Replan from a ``StragglerTracker``'s current estimated cluster."""
        return self.replan(tracker.estimated_cluster())

    @property
    def replans(self) -> int:
        return self.engine.replans
