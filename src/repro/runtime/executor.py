"""CodedRoundExecutor: the shared coded-execution substrate (DESIGN.md §5).

Serving and training run the same per-round protocol — plan a coded
deployment, derive a deadline, sample which workers make it, map worker
erasures onto coded-slot erasures, decode, and re-plan when the fleet
changes. Before this module the serving loop owned all of that
(``CodedLMHead`` precomputed scatter maps and straggler parameters
inline) and the training loop had none of it (host-side numpy helpers
exercised only by tests). ``CodedRoundExecutor`` extracts the mechanics
once:

* **deadline** — the scheme's expected latency x safety, finite for
  every registered scheme (``CodedComputeEngine.deadline``);
* **erasure-mask sampling** — ``finish_mask_jit`` draws per-worker
  round-trip times under the scheme's OWN latency model (comm-delay
  shifts included) inside the caller's compiled program;
* **worker->slot scatter map** — ``slot_owner[i]`` is the worker holding
  coded slot ``i`` (rows for the matvec head, coded gradients for
  training), so a (W,) finish mask gathers to an (n,) slot-erasure mask
  in one device op;
* **elastic replan** — ``replan``/``on_estimates_update`` rebuild the
  plan, deadline and scatter map on a membership or estimate change,
  scheme params riding on the engine's typed scheme object.

``CodedLMHead`` (serve) and ``Trainer`` (train) both consume one; the
registry/engine is therefore the single planning authority for every
coded workload.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp
import numpy as np

from repro.core.engine import CodedComputeEngine
from repro.core.planner import DeploymentPlan
from repro.core.runtime_model import (
    ClusterSpec,
    LatencyModel,
    comm_terms,
    sample_worker_times,
)
from repro.core.schemes import AllocationScheme


class CodedRoundExecutor:
    """Per-round mechanics for one coded workload (serve OR train).

    Device-resident state is recomputed on every (re)plan: the
    worker->slot scatter map and the per-worker shifted-exponential
    parameters the jitted finish-mask sampler draws from. All ``*_jit``
    methods are traceable and safe to close over in a compiled program;
    after a ``replan`` the consumer must rebuild anything traced against
    the old shapes (worker count and slot count may change).
    """

    def __init__(
        self,
        cluster: ClusterSpec,
        k: int,
        scheme: str | AllocationScheme = "optimal",
        *,
        scheme_params: dict | None = None,
        deadline_safety: float = 3.0,
    ):
        self.engine = CodedComputeEngine(
            cluster, k, scheme, scheme_params=scheme_params
        )
        self.deadline_safety = float(deadline_safety)
        self._refresh()

    # ----------------------------------------------------------- plan state
    def _refresh(self) -> None:
        """Recompute deadline + device arrays from the engine's plan."""
        plan = self.engine.plan
        self.plan: DeploymentPlan = plan
        self.deadline = self._integer_load_deadline(self.deadline_safety)
        owner = np.zeros((plan.n,), np.int32)
        for w, (s, e) in enumerate(plan.row_ranges):
            owner[s:e] = w
        #: (n,) worker index holding each coded slot
        self.slot_owner = jnp.asarray(owner)
        self._loads_w = jnp.asarray(plan.loads_per_worker, jnp.float32)
        self._mus_w = jnp.asarray(
            [plan.cluster.groups[j].mu for j in plan.group_of_worker]
        )
        # comm-delay schemes: fold the per-load download cost into alpha
        # and add the fixed transfer shift, so sampled times stay
        # commensurate with the comm-aware deadline
        sch = self.engine.scheme
        if sch.latency_model is LatencyModel.COMM_DELAY:
            shift_g, dal_g = comm_terms(plan.cluster, sch.upload, sch.download)
        else:
            ng = plan.cluster.num_groups
            shift_g, dal_g = np.zeros(ng), np.zeros(ng)
        self._alphas_w = jnp.asarray(
            [plan.cluster.groups[j].alpha + dal_g[j]
             for j in plan.group_of_worker]
        )
        self._shift_w = jnp.asarray(
            [shift_g[j] for j in plan.group_of_worker], jnp.float32
        )

    # convenience views ----------------------------------------------------
    @property
    def scheme(self) -> AllocationScheme:
        return self.engine.scheme

    @property
    def cluster(self) -> ClusterSpec:
        return self.plan.cluster

    @property
    def k(self) -> int:
        return self.engine.k

    @property
    def n(self) -> int:
        """Total coded slots deployed."""
        return self.plan.n

    @property
    def num_workers(self) -> int:
        return self.plan.num_workers

    def generator(self, key=None, kind: str = "systematic_gaussian"):
        """(n, k) MDS generator / assignment matrix sized to the plan."""
        return self.engine.generator(key=key, kind=kind)

    #: integer/real load ratio beyond which the analytic deadline is
    #: distrusted and the deployment's integer loads are Monte-Carlo'd
    INTEGERIZATION_SLACK = 1.05

    def _integer_load_deadline(self, safety: float, *, key=None,
                               num_trials: int = 2_048) -> float:
        """Deadline commensurate with the INTEGERIZED deployment.

        ``plan_deadline``'s analytic ``T*`` describes the real-valued
        allocation, but ``finish_mask_jit`` samples the integer
        per-worker loads that actually run; at small ``k`` (few gradient
        partitions) the ``ceil`` can inflate a load several-fold and the
        analytic deadline would erase every round. Policy: when the
        integerization is benign (every ``ceil(l)/l`` within
        ``INTEGERIZATION_SLACK`` — the serving case, where k is in the
        thousands) keep ``plan_deadline``'s cheap analytic/MC-fallback
        path so (re)plans stay closed-form in the failure path;
        otherwise Monte-Carlo the scheme's expected latency ON the
        integer loads, floored by the analytic bound.
        """
        plan = self.plan
        alloc = plan.allocation
        if alloc is not None:
            real = np.asarray(alloc.loads, float)
            live = real > 0
            inflation = float(
                np.max(alloc.loads_int[live] / real[live], initial=1.0)
            )
        else:  # legacy plan without the real-valued allocation attached
            inflation = float("inf")
        if inflation <= self.INTEGERIZATION_SLACK:
            # PR-2 serving policy unchanged: analytic T* when the scheme
            # has one, the scheme's own MC estimate otherwise
            return self.engine.deadline(safety, key=key,
                                        num_trials=num_trials)
        if key is None:
            key = jax.random.PRNGKey(0)
        t = float(
            self.engine.expected_latency(
                key, num_trials, use_integer_loads=True
            )
        )
        analytic = float(plan.t_star)
        if np.isfinite(analytic):
            t = max(t, analytic)
        return t * safety

    # ------------------------------------------------------- jitted methods
    def finish_mask_jit(self, key, deadline=None):
        """(W,) bool straggler mask, traceable (shifted-exp model).

        Samples under the scheme's OWN latency model so the times are
        commensurate with the deadline (which ``plan_deadline`` computes
        under that same model — e.g. reisizadeh is per-row MODEL_30,
        comm-aware adds per-worker transfer shifts). ``deadline`` may be
        a traced scalar; defaults to the executor's planned one.
        """
        if deadline is None:
            deadline = self.deadline
        t = sample_worker_times(
            key, self._loads_w, self._mus_w, self._alphas_w, self.k, 1,
            model=self.engine.scheme.latency_model,
            shift_per_worker=self._shift_w,
        )[0]
        return t <= deadline

    def slot_mask_jit(self, worker_mask):
        """Gather a (W,) worker finish mask to the (n,) slot-erasure mask."""
        return jnp.asarray(worker_mask, bool)[self.slot_owner]

    def sample_finish_mask(self, key) -> np.ndarray:
        """Host-side convenience: one sampled mask at the planned deadline."""
        return np.asarray(self.finish_mask_jit(key, self.deadline))

    # ----------------------------------------------------------- elasticity
    def replan(self, new_cluster: ClusterSpec) -> DeploymentPlan:
        """Re-plan on a membership/estimate change; scheme params preserved.

        Rebuilds the deadline, scatter map and sampling arrays. Consumers
        holding compiled programs traced against the old worker/slot
        shapes must rebuild them (both loops do).
        """
        plan = self.engine.replan(new_cluster)
        self._refresh()
        return plan

    def on_estimates_update(self, tracker) -> DeploymentPlan:
        """Replan from a ``StragglerTracker``'s current estimated cluster."""
        return self.replan(tracker.estimated_cluster())

    @property
    def replans(self) -> int:
        return self.engine.replans
