"""RoundClock: wall-clock round timing for the measured-reality loop (§12).

Every closed loop before this module fed the ``AdaptiveController``
*simulated* round times (``observe_truth`` samples the scenario layer's
ground-truth parameters); deployments therefore adapted to what the
simulator said, never to what the hardware did. ``RoundClock`` makes
measured time the first-class observation:

* **measure** — each compiled dispatch (the coded train step, a serve
  round) is wrapped in ``perf_counter`` + ``block_until_ready``, so the
  measured ``dispatch_s`` is the real device round, not the async
  dispatch stub;
* **decompose** — one wall-clock number cannot feed a per-group MLE, so
  the clock splits it into per-worker round times using the in-program
  finish-mask/latency draw the executor already exposes
  (``CodedRoundExecutor.round_observation`` — the SAME sampler, and with
  the same key the SAME draw, the compiled step's finish mask came
  from): worker ``w`` gets ``v_w * dispatch_s / max(v)``. What is
  *measured* is the round total (and any per-worker pad, below); the
  per-worker *split* is derived — DESIGN.md §12 spells out which is
  which;
* **calibrate** — the first fed round pins ``unit_s`` (wall seconds per
  virtual-time unit) and every observation is reported in
  virtual-commensurate units (``scale = (dispatch_s / max(v)) /
  unit_s``). This is a fixed change of units, not an estimate: plans,
  deadlines and scenario ground-truth injection all live in the
  planner's virtual units, and a calibrated feed keeps measured
  observations commensurate with them while real slowdowns still arrive
  at full magnitude (a 2x wall-clock round is a 2x observation);
* **guard rails** — the first ``warmup`` rounds are timed but not fed
  (the first dispatch of a compiled program pays its trace+compile,
  which would poison the calibration), ``discard_next`` lets a consumer
  flag a known recompile (post-replan), and a dispatch slower than
  ``outlier_factor`` times the smoothed round is dropped automatically
  (GC pause, CI neighbor); every round — fed or skipped — is emitted as
  a ``round_timing`` telemetry event (§8);
* **pad injection** — ``pad_s`` (per-worker seconds) really sleeps
  ``max(pad_s)`` inside the measured window and attributes each
  worker's share of the measured sleep to that worker: the single-
  process stand-in for per-worker RPC timestamps, and the fault
  injector the measured-adaptation tests use (a sleep-padded worker
  group must trigger a replan from wall-clock evidence alone).

For CommDelay schemes the per-worker upload shifts are scaled by the
same factor and handed to the controller as measured transfer shares,
so the bandwidth MLE and the comm-term subtraction keep working on the
measured path. Feed the result to
``AdaptiveController.observe_timing`` (or read ``.times`` directly).
"""
from __future__ import annotations

import dataclasses
import time
from typing import Any, Callable, Sequence

import jax
import numpy as np

from repro.core.runtime_model import ClusterSpec, LatencyModel


@dataclasses.dataclass
class RoundTiming:
    """One measured round: wall-clock facts + the derived decomposition.

    ``times`` is ``None`` when the round was measured but not fed
    (warmup / outlier / flagged recompile — see ``skipped``);
    ``observe_timing`` treats that as a no-op, so callers can feed every
    timing unconditionally.
    """

    round: int
    result: Any  # the dispatch's own return value (already blocked on)
    wall_s: float  # measured: dispatch + injected pad
    dispatch_s: float  # measured: dispatch + block_until_ready only
    pad_wall_s: float  # measured: the injected sleep actually slept
    scale: float  # this round's common factor, in calibrated units
    times: np.ndarray | None  # (W,) derived per-worker round times
    transfer_times: np.ndarray | None  # (W,) derived upload shares (comm)
    payload: float  # bandwidth-MLE payload matching transfer_times
    membership: tuple[int, ...] | None  # registration counts (truth feed)
    skipped: str | None  # None = fed; "warmup" | "outlier" | custom


class RoundClock:
    """Measured round times for one executor's dispatches.

    One clock per control loop: it owns the unit calibration and the
    outlier state, so interleaving two measured loops through one clock
    would corrupt both. ``pad_s`` may be set (or re-set) at any time
    between rounds — tests flip it mid-run to inject a slowdown.
    """

    def __init__(
        self,
        executor,
        *,
        telemetry=None,
        pad_s: Sequence[float] | np.ndarray | None = None,
        warmup: int = 1,
        outlier_factor: float = 50.0,
        smooth: float = 0.7,
    ):
        if warmup < 0:
            raise ValueError(f"warmup must be >= 0, got {warmup}")
        if outlier_factor <= 1:
            raise ValueError(
                f"outlier_factor must be > 1, got {outlier_factor}"
            )
        if not 0 <= smooth < 1:
            raise ValueError(f"smooth must be in [0, 1), got {smooth}")
        self.executor = executor
        self.telemetry = telemetry
        self.pad_s = pad_s
        self.warmup = int(warmup)
        self.outlier_factor = float(outlier_factor)
        self.smooth = float(smooth)
        #: wall seconds per virtual-time unit; pinned on the first fed
        #: round and FROZEN (a units choice, not a tracked estimate)
        self.unit_s: float | None = None
        self.rounds = 0  # measured rounds (fed or not)
        self.fed = 0  # rounds that produced an observation
        self._smoothed: float | None = None  # EMA of non-outlier dispatches
        self._discard: str | None = None

    def discard_next(self, reason: str = "recompile") -> None:
        """Flag the next dispatch as not-an-observation (e.g. a replan
        recompile: its wall time is compile, not round latency)."""
        self._discard = reason

    # ------------------------------------------------------------ measure
    def measure(
        self,
        dispatch: Callable[[], Any],
        *,
        key,
        true_cluster: ClusterSpec | None = None,
    ) -> RoundTiming:
        """Run one compiled dispatch under the clock and decompose it.

        ``key`` must be the round's straggler-sampling key (the one the
        dispatched program folded its finish mask from) so the derived
        per-worker split matches the draw that actually gated the round;
        ``true_cluster`` is the scenario layer's ground truth when one
        is being injected (leavers decompose to ``inf`` — never
        responded).
        """
        pad = None if self.pad_s is None else np.asarray(self.pad_s, float)
        t0 = time.perf_counter()
        result = dispatch()
        jax.block_until_ready(result)
        t1 = time.perf_counter()
        dispatch_s = t1 - t0
        pad_wall = 0.0
        pad_share = None
        if pad is not None and float(pad.max()) > 0:
            # padded workers run concurrently: the slowest pad gates the
            # round; each worker is attributed its share of the sleep
            # that was actually measured (not the nominal request)
            time.sleep(float(pad.max()))
            pad_wall = time.perf_counter() - t1
            pad_share = pad / float(pad.max()) * pad_wall
        wall = time.perf_counter() - t0
        self.rounds += 1

        skipped = None
        if self._discard is not None:
            skipped, self._discard = self._discard, None
        elif self.rounds <= self.warmup:
            skipped = "warmup"
        elif (
            self._smoothed is not None
            and dispatch_s > self.outlier_factor * self._smoothed
        ):
            skipped = "outlier"
        if skipped is None:
            self._smoothed = (
                dispatch_s if self._smoothed is None
                else self.smooth * self._smoothed
                + (1 - self.smooth) * dispatch_s
            )

        times = transfer = None
        scale = float("nan")
        payload = 1.0
        membership = (
            tuple(g.num_workers for g in true_cluster.groups)
            if true_cluster is not None else None
        )
        if skipped is None:
            times, transfer, payload, scale = self._decompose(
                key, true_cluster, dispatch_s, pad_share
            )
            self.fed += 1
        timing = RoundTiming(
            round=self.rounds,
            result=result,
            wall_s=wall,
            dispatch_s=dispatch_s,
            pad_wall_s=pad_wall,
            scale=scale,
            times=times,
            transfer_times=transfer,
            payload=payload,
            membership=membership,
            skipped=skipped,
        )
        self._emit(timing)
        return timing

    def _decompose(self, key, true_cluster, dispatch_s, pad_share):
        """(W,) per-worker observation from one measured dispatch."""
        v, shifts = self.executor.round_observation(key, true_cluster)
        finite = np.isfinite(v)
        if not finite.any():
            # every planned worker has left: all-miss observation (the
            # tracker's failure detection needs the infs), no new scale
            return np.full(v.shape, np.inf), None, 1.0, float("nan")
        sec_per_v = dispatch_s / float(v[finite].max())
        if self.unit_s is None:
            self.unit_s = sec_per_v  # calibration: this round reads 1.0
        scale = sec_per_v / self.unit_s
        times = np.where(finite, v * scale, np.inf)
        if pad_share is not None:
            times = np.where(finite, times + pad_share / self.unit_s, times)
        transfer, payload = None, 1.0
        sch = self.executor.scheme
        if (
            sch.latency_model is LatencyModel.COMM_DELAY
            and getattr(sch, "upload", 0.0) > 0
        ):
            transfer = np.where(np.isfinite(shifts), shifts * scale, np.inf)
            payload = float(sch.upload)
        return times, transfer, payload, scale

    def _emit(self, t: RoundTiming) -> None:
        if self.telemetry is None:
            return
        finite = (
            t.times[np.isfinite(t.times)] if t.times is not None else None
        )
        self.telemetry.event(
            "round_timing",
            round=t.round,
            wall_s=float(t.wall_s),
            dispatch_s=float(t.dispatch_s),
            pad_wall_s=float(t.pad_wall_s),
            # NaN (skipped rounds) is not valid strict JSON -> null
            scale=float(t.scale) if np.isfinite(t.scale) else None,
            unit_s=float(self.unit_s) if self.unit_s is not None else None,
            workers=int(self.executor.num_workers),
            fed=t.skipped is None,
            skipped=t.skipped,
            t_max=(
                float(finite.max())
                if finite is not None and finite.size else None
            ),
            t_mean=(
                float(finite.mean())
                if finite is not None and finite.size else None
            ),
        )
