"""Unified model builder for every assigned architecture family.

One ``Model`` object wraps a ``ModelConfig`` and exposes the same five
entry points regardless of family, so the launcher/dry-run treats every
arch uniformly:

* ``init_params(key)``                      -> param pytree
* ``loss_fn(params, batch)``                -> (scalar loss, metrics)
* ``lm_logits(params, tokens, extras)``     -> (B, S, V) (prefill path)
* ``init_cache(batch, cache_len)``          -> decode-state pytree
* ``decode_step(params, cache, tok, pos)``  -> ((B, V) logits, cache')

Families
--------
dense   llama-style pre-norm GQA + SwiGLU, scan over stacked layers.
moe     same attention; FFN replaced by top-k routed experts.
ssm     xLSTM: mLSTM layers with periodic sLSTM layers (python loop —
        layers are heterogeneous and L is small).
hybrid  Zamba2: Mamba2 backbone (scan) + one SHARED attention+MLP block
        applied every ``attn_every`` layers (weights reused; each
        invocation has its own KV cache slot).
vlm     PaliGemma: precomputed SigLIP patch embeddings (frontend stub)
        prepended to token embeddings; Gemma-style decoder.
audio   Whisper: encoder (non-causal, sinusoidal positions) over
        precomputed conv-frontend frame embeddings (stub) + decoder with
        self- and cross-attention.

Homogeneous stacks use ``jax.lax.scan`` over stacked params (keeps the
HLO one-layer-sized: critical for 512-device dry-run compile times);
``jax.checkpoint`` per layer when ``config.remat``.
"""
from __future__ import annotations

import dataclasses
import functools
from typing import Any

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs.base import ModelConfig
from repro.models import attention as attn_mod
from repro.models import layers as L
from repro.models import moe as moe_mod
from repro.models import ssm as ssm_mod
from repro.models import xlstm as xlstm_mod

PyTree = Any

DTYPES_LOGITS = {"float32": jnp.float32, "bfloat16": jnp.bfloat16}


def _stack_init(fn, key, n, *args, **kwargs):
    keys = jax.random.split(key, n)
    return jax.vmap(lambda k: fn(k, *args, **kwargs))(keys)


def padded_vocab(v: int, multiple: int = 256) -> int:
    """Vocab padded so embedding/logit dims shard evenly on the mesh."""
    return int(-(-v // multiple) * multiple)


def _sinusoidal(seq: int, d: int):
    pos = np.arange(seq)[:, None]
    i = np.arange(d // 2)[None, :]
    ang = pos / (10_000 ** (2 * i / d))
    return jnp.asarray(
        np.concatenate([np.sin(ang), np.cos(ang)], axis=-1), dtype=jnp.float32
    )


@dataclasses.dataclass(frozen=True)
class Model:
    config: ModelConfig

    # ------------------------------------------------------------ params
    def init_params(self, key) -> PyTree:
        c = self.config
        dt = c.pdtype
        kemb, kblocks, kfinal, kextra = jax.random.split(key, 4)
        pv = padded_vocab(c.vocab_size)
        params: dict = {
            "embed": L.init_embedding(kemb, pv, c.d_model, dt),
            "final_norm": (
                L.init_layernorm(c.d_model, dt)
                if c.family == "audio"
                else L.init_rmsnorm(c.d_model, dt)
            ),
        }
        hd = c.resolved_head_dim

        def dense_block(k):
            k1, k2 = jax.random.split(k)
            return {
                "ln1": L.init_rmsnorm(c.d_model, dt),
                "attn": attn_mod.init_attention(
                    k1, c.d_model, c.num_heads, c.num_kv_heads, hd, dt,
                    qk_norm=c.qk_norm,
                ),
                "ln2": L.init_rmsnorm(c.d_model, dt),
                "mlp": L.init_mlp(k2, c.d_model, c.d_ff, dt, c.activation),
            }

        if c.family in ("dense", "vlm"):
            params["blocks"] = _stack_init(dense_block, kblocks, c.num_layers)
        elif c.family == "moe":
            def moe_block(k):
                k1, k2 = jax.random.split(k)
                return {
                    "ln1": L.init_rmsnorm(c.d_model, dt),
                    "attn": attn_mod.init_attention(
                        k1, c.d_model, c.num_heads, c.num_kv_heads, hd, dt,
                        qk_norm=c.qk_norm,
                    ),
                    "ln2": L.init_rmsnorm(c.d_model, dt),
                    "moe": moe_mod.init_moe(k2, c.d_model, c.d_ff, c.num_experts, dt),
                }

            params["blocks"] = _stack_init(moe_block, kblocks, c.num_layers)
        elif c.family == "hybrid":
            def mamba_block(k):
                return {
                    "ln": L.init_rmsnorm(c.d_model, dt),
                    "mamba": ssm_mod.init_mamba2(
                        k, c.d_model, c.ssm_state, dt,
                        expand=c.mamba_expand, head_dim=c.mamba_head_dim,
                    ),
                }

            params["blocks"] = _stack_init(mamba_block, kblocks, c.num_layers)
            params["shared_attn"] = dense_block(kextra)  # ONE shared block
        elif c.family == "ssm":  # xLSTM
            blocks = []
            keys = jax.random.split(kblocks, c.num_layers)
            for i in range(c.num_layers):
                if self._is_slstm(i):
                    blocks.append(
                        {
                            "ln": L.init_rmsnorm(c.d_model, dt),
                            "cell": xlstm_mod.init_slstm(keys[i], c.d_model, c.num_heads, dt),
                        }
                    )
                else:
                    blocks.append(
                        {
                            "ln": L.init_rmsnorm(c.d_model, dt),
                            "cell": xlstm_mod.init_mlstm(
                                keys[i], c.d_model, c.num_heads, dt, c.proj_factor
                            ),
                        }
                    )
            params["blocks"] = blocks
        elif c.family == "audio":  # whisper enc-dec
            kenc, kdec = jax.random.split(kblocks)

            def enc_block(k):
                k1, k2 = jax.random.split(k)
                return {
                    "ln1": L.init_layernorm(c.d_model, dt),
                    "attn": attn_mod.init_attention(
                        k1, c.d_model, c.num_heads, c.num_kv_heads, hd, dt
                    ),
                    "ln2": L.init_layernorm(c.d_model, dt),
                    "mlp": L.init_mlp(k2, c.d_model, c.d_ff, dt, "gelu"),
                }

            def dec_block(k):
                k1, k2, k3 = jax.random.split(k, 3)
                return {
                    "ln1": L.init_layernorm(c.d_model, dt),
                    "self_attn": attn_mod.init_attention(
                        k1, c.d_model, c.num_heads, c.num_kv_heads, hd, dt
                    ),
                    "ln_x": L.init_layernorm(c.d_model, dt),
                    "cross_attn": attn_mod.init_attention(
                        k2, c.d_model, c.num_heads, c.num_kv_heads, hd, dt
                    ),
                    "ln2": L.init_layernorm(c.d_model, dt),
                    "mlp": L.init_mlp(k3, c.d_model, c.d_ff, dt, "gelu"),
                }

            params["encoder"] = _stack_init(enc_block, kenc, c.num_encoder_layers)
            params["blocks"] = _stack_init(dec_block, kdec, c.num_layers)
            params["enc_norm"] = L.init_layernorm(c.d_model, dt)
        else:
            raise ValueError(f"unknown family {c.family}")
        return params

    def _is_slstm(self, layer_idx: int) -> bool:
        c = self.config
        return bool(c.slstm_every) and (layer_idx + 1) % c.slstm_every == 0

    def _mask_pad_logits(self, logits):
        """Padded vocab slots never win argmax / contribute to softmax."""
        v = self.config.vocab_size
        if logits.shape[-1] == v:
            return logits
        ids = jnp.arange(logits.shape[-1])
        return jnp.where(ids < v, logits, -1e30)

    # -------------------------------------------------------- primitives
    def _dense_apply(self, p, x, positions, *, causal=True):
        c = self.config
        h = x + attn_mod.attention(
            p["attn"], L.rmsnorm(p["ln1"], x), positions,
            num_heads=c.num_heads, num_kv_heads=c.num_kv_heads,
            head_dim=c.resolved_head_dim, causal=causal,
            window=c.sliding_window, rope_theta=c.rope_theta,
            q_block=c.attn_q_block, kv_block=c.attn_kv_block,
            causal_skip=c.causal_block_skip,
        )
        h = h + L.mlp(p["mlp"], L.rmsnorm(p["ln2"], h))
        return h

    def _moe_apply(self, p, x, positions):
        c = self.config
        h = x + attn_mod.attention(
            p["attn"], L.rmsnorm(p["ln1"], x), positions,
            num_heads=c.num_heads, num_kv_heads=c.num_kv_heads,
            head_dim=c.resolved_head_dim, causal=True,
            window=c.sliding_window, rope_theta=c.rope_theta,
            q_block=c.attn_q_block, kv_block=c.attn_kv_block,
            causal_skip=c.causal_block_skip,
        )
        h = h + moe_mod.moe_ffn(
            p["moe"], L.rmsnorm(p["ln2"], h),
            num_experts=c.num_experts, top_k=c.top_k,
            capacity_factor=c.capacity_factor,
        )
        return h

    def _mamba_apply(self, p, x):
        c = self.config
        return x + ssm_mod.mamba2(
            p["mamba"], L.rmsnorm(p["ln"], x),
            d_state=c.ssm_state, expand=c.mamba_expand,
            head_dim=c.mamba_head_dim, chunk=c.mamba_chunk,
        )

    # ----------------------------------------------------------- forward
    def _stack_apply(self, fn, x, stacked):
        """Apply fn(layer_params, h) over stacked layers.

        scan_layers=True: lax.scan (one-layer HLO, fast compile).
        scan_layers=False: unrolled python loop — used by the dry-run so
        XLA cost analysis sees every layer (a while body is counted once).
        """
        if self.config.scan_layers:
            x, _ = jax.lax.scan(lambda h, p: (fn(p, h), None), x, stacked)
            return x
        for i in range(self.config.num_layers):
            p = jax.tree.map(lambda t: t[i], stacked)
            x = fn(p, x)
        return x

    def _backbone(self, params, x, positions):
        """(B, S, D) -> (B, S, D) through all blocks (train/prefill)."""
        c = self.config

        if c.family in ("dense", "vlm"):
            fn = lambda p, h: self._dense_apply(p, h, positions)
            fn = jax.checkpoint(fn) if c.remat else fn
            x = self._stack_apply(fn, x, params["blocks"])
        elif c.family == "moe":
            fn = lambda p, h: self._moe_apply(p, h, positions)
            fn = jax.checkpoint(fn) if c.remat else fn
            x = self._stack_apply(fn, x, params["blocks"])
        elif c.family == "hybrid":
            shared = params["shared_attn"]
            every = max(c.attn_every, 1)

            def layer(p, h, i):
                h = jax.lax.cond(
                    i % every == 0,
                    lambda hh: self._dense_apply(shared, hh, positions),
                    lambda hh: hh,
                    h,
                )
                return self._mamba_apply(p, h)

            fn = jax.checkpoint(layer) if c.remat else layer

            if c.scan_layers:
                def body(h, inp):
                    p, i = inp
                    return fn(p, h, i), None

                x, _ = jax.lax.scan(
                    body, x, (params["blocks"], jnp.arange(c.num_layers))
                )
            else:
                for i in range(c.num_layers):
                    p = jax.tree.map(lambda t: t[i], params["blocks"])
                    x = fn(p, x, jnp.int32(i))
        elif c.family == "ssm":
            for i, p in enumerate(params["blocks"]):
                h = L.rmsnorm(p["ln"], x)
                if self._is_slstm(i):
                    y = xlstm_mod.slstm(p["cell"], h, num_heads=c.num_heads)
                else:
                    y = xlstm_mod.mlstm(
                        p["cell"], h, num_heads=c.num_heads, proj_factor=c.proj_factor
                    )
                x = x + y
        elif c.family == "audio":
            raise RuntimeError("audio uses _encdec_forward")
        return x

    def _encode_audio(self, params, frames):
        """Whisper encoder over precomputed frame embeddings (stub frontend)."""
        c = self.config
        s = frames.shape[1]
        x = frames.astype(c.cdtype) + _sinusoidal(s, c.d_model).astype(c.cdtype)
        positions = jnp.arange(s, dtype=jnp.int32)

        def enc_apply(p, h):
            h = h + attn_mod.attention(
                p["attn"], L.layernorm(p["ln1"], h), positions,
                num_heads=c.num_heads, num_kv_heads=c.num_kv_heads,
                head_dim=c.resolved_head_dim, causal=False, use_rope=False,
                q_block=c.attn_q_block, kv_block=c.attn_kv_block,
            )
            h = h + L.mlp(p["mlp"], L.layernorm(p["ln2"], h))
            return h

        fn = jax.checkpoint(enc_apply) if c.remat else enc_apply
        if c.scan_layers:
            x, _ = jax.lax.scan(lambda h, p: (fn(p, h), None), x, params["encoder"])
        else:
            for i in range(c.num_encoder_layers):
                x = fn(jax.tree.map(lambda t: t[i], params["encoder"]), x)
        return L.layernorm(params["enc_norm"], x)

    def _decoder_audio(self, params, x, positions, enc_out, enc_positions):
        c = self.config

        def dec_apply(p, h):
            h = h + attn_mod.attention(
                p["self_attn"], L.layernorm(p["ln1"], h), positions,
                num_heads=c.num_heads, num_kv_heads=c.num_kv_heads,
                head_dim=c.resolved_head_dim, causal=True, use_rope=False,
                q_block=c.attn_q_block, kv_block=c.attn_kv_block,
            )
            h = h + attn_mod.attention(
                p["cross_attn"], L.layernorm(p["ln_x"], h), positions,
                num_heads=c.num_heads, num_kv_heads=c.num_kv_heads,
                head_dim=c.resolved_head_dim, causal=False, use_rope=False,
                xkv=enc_out, kv_positions=enc_positions,
                q_block=c.attn_q_block, kv_block=c.attn_kv_block,
            )
            h = h + L.mlp(p["mlp"], L.layernorm(p["ln2"], h))
            return h

        fn = jax.checkpoint(dec_apply) if c.remat else dec_apply
        return self._stack_apply(fn, x, params["blocks"])

    # ------------------------------------------------------------ logits
    def lm_logits(self, params, tokens, extras: dict | None = None):
        """Full-sequence logits. tokens: (B, S) int32.

        extras:
          vlm   -> {"image_embeds": (B, T_img, D)} prepended to the text.
          audio -> {"frames": (B, enc_S, D)} run through the encoder.
        """
        c = self.config
        extras = extras or {}
        x = L.embed(params["embed"], tokens, c.cdtype)
        b, s = tokens.shape

        if c.family == "vlm":
            img = extras["image_embeds"].astype(c.cdtype)
            x = jnp.concatenate([img, x], axis=1)
            positions = jnp.arange(x.shape[1], dtype=jnp.int32)
            x = self._backbone(params, x, positions)
            x = x[:, img.shape[1]:]
        elif c.family == "audio":
            enc_out = self._encode_audio(params, extras["frames"])
            positions = jnp.arange(s, dtype=jnp.int32)
            enc_pos = jnp.arange(enc_out.shape[1], dtype=jnp.int32)
            x = self._decoder_audio(params, x, positions, enc_out, enc_pos)
        else:
            positions = jnp.arange(s, dtype=jnp.int32)
            x = self._backbone(params, x, positions)

        norm = L.layernorm if c.family == "audio" else L.rmsnorm
        x = norm(params["final_norm"], x)
        logits = L.unembed(params["embed"], x, DTYPES_LOGITS[c.logits_dtype])
        return self._mask_pad_logits(logits)

    # -------------------------------------------------------------- loss
    def loss_fn(self, params, batch):
        """batch: {"tokens": (B,S), "labels": (B,S)} (+ family extras).

        labels < 0 are masked. Logits over the PADDED vocab; pad ids are
        never produced as labels so the softmax treats them as negatives.
        """
        tokens = batch["tokens"]
        labels = batch["labels"]
        logits = self.lm_logits(params, tokens, batch.get("extras"))
        mask = labels >= 0
        loss = L.cross_entropy_loss(logits, jnp.maximum(labels, 0), mask)
        acc = jnp.sum(
            (jnp.argmax(logits, -1) == labels) & mask
        ) / jnp.maximum(jnp.sum(mask), 1)
        return loss, {"loss": loss, "accuracy": acc}

    # ------------------------------------------------------------- cache
    def n_shared_attn_calls(self) -> int:
        c = self.config
        every = max(c.attn_every, 1)
        return -(-c.num_layers // every)

    def init_cache(self, batch: int, cache_len: int, extras: dict | None = None):
        """Decode state.

        cache_len: KV capacity. Sliding-window models may pass
        min(cache_len, window) to get the rolling cache.
        """
        c = self.config
        dt = c.cdtype
        hd = c.resolved_head_dim
        if c.sliding_window is not None:
            cache_len = min(cache_len, c.sliding_window)

        def kv(n_layers, length):
            if c.kv_quant:  # int8 + per-(token, head) f16 scales (§Perf)
                return {
                    "k": jnp.zeros((n_layers, batch, length, c.num_kv_heads, hd),
                                   jnp.int8),
                    "v": jnp.zeros((n_layers, batch, length, c.num_kv_heads, hd),
                                   jnp.int8),
                    "k_scale": jnp.zeros((n_layers, batch, length, c.num_kv_heads),
                                         jnp.float16),
                    "v_scale": jnp.zeros((n_layers, batch, length, c.num_kv_heads),
                                         jnp.float16),
                    "pos": jnp.full((n_layers, length), -1, jnp.int32),
                }
            return {
                "k": jnp.zeros((n_layers, batch, length, c.num_kv_heads, hd), dt),
                "v": jnp.zeros((n_layers, batch, length, c.num_kv_heads, hd), dt),
                "pos": jnp.full((n_layers, length), -1, jnp.int32),
            }

        if c.family in ("dense", "vlm", "moe"):
            return {"kv": kv(c.num_layers, cache_len)}
        if c.family == "hybrid":
            n_inv = self.n_shared_attn_calls()
            d_inner = c.mamba_expand * c.d_model
            n_heads = d_inner // c.mamba_head_dim
            conv_dim = d_inner + 2 * c.ssm_state
            return {
                "kv": kv(n_inv, cache_len),
                "ssm": jnp.zeros(
                    (c.num_layers, batch, n_heads, c.ssm_state, c.mamba_head_dim),
                    jnp.float32,
                ),
                "conv": jnp.zeros(
                    (c.num_layers, batch, ssm_mod.CONV_K - 1, conv_dim), dt
                ),
            }
        if c.family == "ssm":
            states = []
            for i in range(c.num_layers):
                if c.slstm_every and (i + 1) % c.slstm_every == 0:
                    states.append(xlstm_mod.init_slstm_state(batch, c.d_model, c.num_heads))
                else:
                    states.append(
                        xlstm_mod.init_mlstm_state(
                            batch, c.d_model, c.num_heads, c.proj_factor
                        )
                    )
            return {"xlstm": states}
        if c.family == "audio":
            assert extras is not None and "enc_out" in extras, (
                "whisper decode cache needs the encoder output "
                "(run model.encode(params, frames) once per request batch)"
            )
            return {
                "kv": kv(c.num_layers, cache_len),
                "enc_out": extras["enc_out"],
            }
        raise ValueError(c.family)

    def encode(self, params, frames):
        """Audio only: one-time encoder pass for a request batch."""
        return self._encode_audio(params, frames)

    # ------------------------------------------------------------ decode
    def decode_step(self, params, cache, tokens, pos):
        """One new token for every sequence in the batch.

        tokens: (B,) int32; pos: scalar int32 (uniform decode position).
        Returns (logits (B, V_padded), new_cache).
        """
        c = self.config
        hd = c.resolved_head_dim
        x = L.embed(params["embed"], tokens[:, None], c.cdtype)  # (B, 1, D)

        def attn_decode(p, h, kv_slice):
            y, new = attn_mod.decode_attention(
                p["attn"], L.rmsnorm(p["ln1"], h), kv_slice, pos,
                num_heads=c.num_heads, num_kv_heads=c.num_kv_heads,
                head_dim=hd, window=c.sliding_window, rope_theta=c.rope_theta,
            )
            h = h + y
            return h, new

        def _kv_stack_apply(body, h, blocks, kv):
            """Scan-or-unroll a decode body carrying per-layer KV slices."""
            if c.scan_layers:
                return jax.lax.scan(body, h, (blocks, kv))
            news = []
            for i in range(c.num_layers):
                inp = jax.tree.map(lambda t: t[i], (blocks, kv))
                h, new = body(h, inp)
                news.append(new)
            stacked = jax.tree.map(lambda *ts: jnp.stack(ts), *news)
            return h, stacked

        if c.family in ("dense", "vlm"):
            def body(h, inp):
                p, kv_slice = inp
                h, new = attn_decode(p, h, kv_slice)
                h = h + L.mlp(p["mlp"], L.rmsnorm(p["ln2"], h))
                return h, new

            x, new_kv = _kv_stack_apply(body, x, params["blocks"], cache["kv"])
            cache = {**cache, "kv": new_kv}
        elif c.family == "moe":
            def body(h, inp):
                p, kv_slice = inp
                h, new = attn_decode(p, h, kv_slice)
                h = h + moe_mod.moe_ffn(
                    p["moe"], L.rmsnorm(p["ln2"], h),
                    num_experts=c.num_experts, top_k=c.top_k,
                    capacity_factor=c.capacity_factor,
                )
                return h, new

            x, new_kv = _kv_stack_apply(body, x, params["blocks"], cache["kv"])
            cache = {**cache, "kv": new_kv}
        elif c.family == "hybrid":
            shared = params["shared_attn"]
            every = max(c.attn_every, 1)
            n_inv = self.n_shared_attn_calls()

            def body(carry, inp):
                h, kv_all = carry
                p, ssm_s, conv_s, i = inp
                inv = i // every

                def with_attn(operand):
                    h, kv_all = operand
                    kv_slice = jax.tree.map(lambda t: t[inv], kv_all)
                    y, new = attn_mod.decode_attention(
                        shared["attn"], L.rmsnorm(shared["ln1"], h), kv_slice, pos,
                        num_heads=c.num_heads, num_kv_heads=c.num_kv_heads,
                        head_dim=hd, rope_theta=c.rope_theta,
                    )
                    h = h + y
                    h = h + L.mlp(shared["mlp"], L.rmsnorm(shared["ln2"], h))
                    kv_all = jax.tree.map(
                        lambda all_, n: jax.lax.dynamic_update_index_in_dim(
                            all_, n, inv, 0
                        ),
                        kv_all, new,
                    )
                    return h, kv_all

                h, kv_all = jax.lax.cond(
                    i % every == 0, with_attn, lambda o: o, (h, kv_all)
                )
                y, new_state = ssm_mod.mamba2(
                    p["mamba"], L.rmsnorm(p["ln"], h),
                    d_state=c.ssm_state, expand=c.mamba_expand,
                    head_dim=c.mamba_head_dim, chunk=c.mamba_chunk,
                    state={"ssm": ssm_s, "conv": conv_s},
                )
                h = h + y
                return (h, kv_all), (new_state["ssm"], new_state["conv"])

            if c.scan_layers:
                (x, new_kv), (new_ssm, new_conv) = jax.lax.scan(
                    body,
                    (x, cache["kv"]),
                    (params["blocks"], cache["ssm"], cache["conv"],
                     jnp.arange(c.num_layers)),
                )
            else:
                carry = (x, cache["kv"])
                ssm_list, conv_list = [], []
                for i in range(c.num_layers):
                    inp = jax.tree.map(
                        lambda t: t[i],
                        (params["blocks"], cache["ssm"], cache["conv"]),
                    ) + (jnp.int32(i),)
                    carry, (s_i, c_i) = body(carry, inp)
                    ssm_list.append(s_i)
                    conv_list.append(c_i)
                x, new_kv = carry
                new_ssm = jnp.stack(ssm_list)
                new_conv = jnp.stack(conv_list)
            cache = {"kv": new_kv, "ssm": new_ssm, "conv": new_conv}
        elif c.family == "ssm":
            new_states = []
            for i, (p, st) in enumerate(zip(params["blocks"], cache["xlstm"])):
                h = L.rmsnorm(p["ln"], x)
                if self._is_slstm(i):
                    y, new = xlstm_mod.slstm(
                        p["cell"], h, num_heads=c.num_heads, state=st
                    )
                else:
                    y, new = xlstm_mod.mlstm(
                        p["cell"], h, num_heads=c.num_heads,
                        proj_factor=c.proj_factor, state=st,
                    )
                x = x + y
                new_states.append(new)
            cache = {"xlstm": new_states}
        elif c.family == "audio":
            enc_out = cache["enc_out"]
            enc_pos = jnp.arange(enc_out.shape[1], dtype=jnp.int32)

            def body(h, inp):
                p, kv_slice = inp
                y, new = attn_mod.decode_attention(
                    p["self_attn"], L.layernorm(p["ln1"], h), kv_slice, pos,
                    num_heads=c.num_heads, num_kv_heads=c.num_kv_heads,
                    head_dim=hd, use_rope=False,
                )
                h = h + y
                h = h + attn_mod.attention(
                    p["cross_attn"], L.layernorm(p["ln_x"], h),
                    jnp.full((1,), pos, jnp.int32),
                    num_heads=c.num_heads, num_kv_heads=c.num_kv_heads,
                    head_dim=hd, causal=False, use_rope=False,
                    xkv=enc_out, kv_positions=enc_pos,
                    q_block=1, kv_block=min(c.attn_kv_block, enc_out.shape[1]),
                )
                h = h + L.mlp(p["mlp"], L.layernorm(p["ln2"], h))
                return h, new

            x, new_kv = _kv_stack_apply(body, x, params["blocks"], cache["kv"])
            cache = {**cache, "kv": new_kv}
        else:
            raise ValueError(c.family)

        norm = L.layernorm if c.family == "audio" else L.rmsnorm
        x = norm(params["final_norm"], x)
        logits = L.unembed(params["embed"], x, DTYPES_LOGITS[c.logits_dtype])
        logits = self._mask_pad_logits(logits[:, 0])
        return logits, cache

    # ------------------------------------------------- slot-resident decode
    # The continuous-batching serve front-end (runtime/serve_loop.py
    # ``Server.serve``, DESIGN.md §10) keeps one independent request per
    # batch slot: each slot has its own sequence length, so the cache
    # carries per-slot absolute positions and ``decode_step_slots`` takes
    # a (B,) position vector instead of ``decode_step``'s uniform scalar.
    # ``prefill`` fills a newly admitted request's per-layer KV from ONE
    # batched forward pass (the cache-returning path §4 called for)
    # instead of a per-position decode scan.

    def _check_slot_support(self) -> None:
        c = self.config
        if c.family not in ("dense", "vlm", "moe"):
            raise NotImplementedError(
                f"slot-resident decode supports the attention-cache "
                f"families (dense/vlm/moe), not {c.family!r}"
            )
        if c.kv_quant:
            raise NotImplementedError(
                "slot-resident decode does not support int8 KV caches yet"
            )
        if c.sliding_window is not None:
            raise NotImplementedError(
                "slot-resident decode allocates full-context caches; "
                "sliding-window models are not supported yet"
            )

    def init_slot_cache(self, batch: int, cache_len: int):
        """Decode state for ``decode_step_slots``: per-slot positions.

        Layout matches ``init_cache``'s attention families except ``pos``
        is (B, cache_len) — each slot tracks its own absolute positions
        (−1 = empty). Shared across layers (every layer writes the same
        positions), so the serve loop can splice a prefilled request into
        one slot with a single row update.
        """
        c = self.config
        self._check_slot_support()
        hd = c.resolved_head_dim
        return {
            "kv": {
                "k": jnp.zeros(
                    (c.num_layers, batch, cache_len, c.num_kv_heads, hd),
                    c.cdtype,
                ),
                "v": jnp.zeros(
                    (c.num_layers, batch, cache_len, c.num_kv_heads, hd),
                    c.cdtype,
                ),
                "pos": jnp.full((batch, cache_len), -1, jnp.int32),
            }
        }

    def prefill(self, params, tokens, length):
        """Batched prefill: one pass -> (last logits, per-layer K/V).

        tokens: (B, S0) int32, right-padded to a fixed prompt capacity;
        length: (B,) actual prompt lengths. Runs the full-sequence
        chunked-attention forward ONCE, capturing each layer's post-rope
        K/V (``attention(return_kv=True)``) — the tensors ``decode_step``
        would have written into its cache over S0 sequential steps — and
        returns the logits at each row's last real position (predicting
        token ``length``). Padded tail positions produce garbage K/V but
        sit causally AFTER every real query and are masked out of the
        decode cache by the splice's ``pos = -1`` rows.

        Returns ``(logits (B, V_padded), k (L, B, S0, KV, hd), v ...)``.
        """
        c = self.config
        self._check_slot_support()
        hd = c.resolved_head_dim
        b, s = tokens.shape
        x = L.embed(params["embed"], tokens, c.cdtype)
        positions = jnp.arange(s, dtype=jnp.int32)

        def attn_with_kv(p, h):
            y, k, v = attn_mod.attention(
                p["attn"], L.rmsnorm(p["ln1"], h), positions,
                num_heads=c.num_heads, num_kv_heads=c.num_kv_heads,
                head_dim=hd, causal=True, window=c.sliding_window,
                rope_theta=c.rope_theta, q_block=c.attn_q_block,
                kv_block=c.attn_kv_block, causal_skip=c.causal_block_skip,
                return_kv=True,
            )
            return h + y, k, v

        if c.family == "moe":
            def block(p, h):
                h, k, v = attn_with_kv(p, h)
                h = h + moe_mod.moe_ffn(
                    p["moe"], L.rmsnorm(p["ln2"], h),
                    num_experts=c.num_experts, top_k=c.top_k,
                    capacity_factor=c.capacity_factor,
                )
                return h, k, v
        else:
            def block(p, h):
                h, k, v = attn_with_kv(p, h)
                h = h + L.mlp(p["mlp"], L.rmsnorm(p["ln2"], h))
                return h, k, v

        if c.scan_layers:
            def body(h, p):
                h, k, v = block(p, h)
                return h, (k, v)

            x, (ks, vs) = jax.lax.scan(body, x, params["blocks"])
        else:
            k_list, v_list = [], []
            for i in range(c.num_layers):
                p = jax.tree.map(lambda t: t[i], params["blocks"])
                x, k, v = block(p, x)
                k_list.append(k)
                v_list.append(v)
            ks, vs = jnp.stack(k_list), jnp.stack(v_list)

        last = jnp.clip(length - 1, 0, s - 1).astype(jnp.int32)
        x_last = x[jnp.arange(b), last][:, None]  # (B, 1, D)
        x_last = L.rmsnorm(params["final_norm"], x_last)
        logits = L.unembed(
            params["embed"], x_last, DTYPES_LOGITS[c.logits_dtype]
        )[:, 0]
        return self._mask_pad_logits(logits), ks, vs

    def decode_step_slots(self, params, cache, tokens, pos):
        """One token per slot, each at its OWN position.

        tokens: (B,) int32; pos: (B,) int32 absolute write positions
        (frozen slots simply rewrite the same entry — idempotent).
        Returns (logits (B, V_padded), new_cache).
        """
        c = self.config
        self._check_slot_support()
        hd = c.resolved_head_dim
        x = L.embed(params["embed"], tokens[:, None], c.cdtype)
        kv = cache["kv"]
        b, cache_len = kv["pos"].shape
        pos = jnp.asarray(pos, jnp.int32)
        bidx = jnp.arange(b)
        slot = jnp.mod(pos, cache_len).astype(jnp.int32)
        # one shared position map: every layer writes the same positions
        pos_map = kv["pos"].at[bidx, slot].set(pos)

        def attn_decode(p, h, kv_slice):
            y, new = attn_mod.decode_attention_slots(
                p["attn"], L.rmsnorm(p["ln1"], h), kv_slice, pos_map, pos,
                slot, num_heads=c.num_heads, num_kv_heads=c.num_kv_heads,
                head_dim=hd, rope_theta=c.rope_theta,
            )
            return h + y, new

        if c.family == "moe":
            def body(h, inp):
                p, kv_slice = inp
                h, new = attn_decode(p, h, kv_slice)
                h = h + moe_mod.moe_ffn(
                    p["moe"], L.rmsnorm(p["ln2"], h),
                    num_experts=c.num_experts, top_k=c.top_k,
                    capacity_factor=c.capacity_factor,
                )
                return h, new
        else:
            def body(h, inp):
                p, kv_slice = inp
                h, new = attn_decode(p, h, kv_slice)
                h = h + L.mlp(p["mlp"], L.rmsnorm(p["ln2"], h))
                return h, new

        layer_kv = {"k": kv["k"], "v": kv["v"]}
        if c.scan_layers:
            x, new_kv = jax.lax.scan(body, x, (params["blocks"], layer_kv))
        else:
            news = []
            for i in range(c.num_layers):
                inp = jax.tree.map(lambda t: t[i], (params["blocks"], layer_kv))
                x, new = body(x, inp)
                news.append(new)
            new_kv = jax.tree.map(lambda *ts: jnp.stack(ts), *news)

        x = L.rmsnorm(params["final_norm"], x)
        logits = L.unembed(params["embed"], x, DTYPES_LOGITS[c.logits_dtype])
        return self._mask_pad_logits(logits[:, 0]), {
            "kv": {**new_kv, "pos": pos_map}
        }

    # --------------------------------------------------- paged KV decode
    # Paged serving (DESIGN.md §13): physical KV memory is a fixed pool
    # of (block_len,)-token blocks shared across slots, and each slot
    # maps logical positions to pool blocks through a block table. The
    # program's shapes depend only on (num_blocks, block_len, S) — never
    # on any request's length — so admitting an arbitrarily long prompt
    # (prefilled chunk-by-chunk across admit rounds) retraces nothing.

    def init_paged_cache(self, num_blocks: int, block_len: int):
        """KV block pool for ``decode_step_paged``/``prefill_paged``.

        Allocates ``num_blocks + 1`` physical blocks per layer: the last
        block is the write SINK — inactive/frozen/padded rows scatter
        there, so a frozen slot can never corrupt a block that was freed
        and reassigned. No position array: validity is derived from the
        per-dispatch block tables and positions (runtime arguments).
        """
        c = self.config
        self._check_slot_support()
        hd = c.resolved_head_dim
        shape = (c.num_layers, num_blocks + 1, block_len, c.num_kv_heads, hd)
        return {
            "kv": {
                "k": jnp.zeros(shape, c.cdtype),
                "v": jnp.zeros(shape, c.cdtype),
            }
        }

    def _paged_stack_apply(self, body, x, blocks, cache):
        """Scan-or-unroll over layers carrying per-layer pool slices."""
        layer_kv = {"k": cache["kv"]["k"], "v": cache["kv"]["v"]}
        if self.config.scan_layers:
            x, new_kv = jax.lax.scan(body, x, (blocks, layer_kv))
        else:
            news = []
            for i in range(self.config.num_layers):
                inp = jax.tree.map(lambda t: t[i], (blocks, layer_kv))
                x, new = body(x, inp)
                news.append(new)
            new_kv = jax.tree.map(lambda *ts: jnp.stack(ts), *news)
        return x, {"kv": new_kv}

    def _paged_block_body(self, attn_fn):
        """Residual block body around a paged attention fn (dense/moe)."""
        c = self.config
        if c.family == "moe":
            def body(h, inp):
                p, kv_slice = inp
                h, new = attn_fn(p, h, kv_slice)
                h = h + moe_mod.moe_ffn(
                    p["moe"], L.rmsnorm(p["ln2"], h),
                    num_experts=c.num_experts, top_k=c.top_k,
                    capacity_factor=c.capacity_factor,
                )
                return h, new
        else:
            def body(h, inp):
                p, kv_slice = inp
                h, new = attn_fn(p, h, kv_slice)
                h = h + L.mlp(p["mlp"], L.rmsnorm(p["ln2"], h))
                return h, new
        return body

    def decode_step_paged(self, params, cache, tokens, pos, table, active,
                          *, use_kernel: bool = False):
        """One token per slot against the shared block pool.

        tokens: (S,) int32; pos: (S,) write positions; table: (S, MB)
        block table; active: (S,) bool (inactive rows write to the
        sink). Returns (logits (S, V_padded), new_cache). The attend
        math bit-matches ``decode_step_slots`` under an order-preserving
        block layout.
        """
        c = self.config
        self._check_slot_support()
        hd = c.resolved_head_dim
        x = L.embed(params["embed"], tokens[:, None], c.cdtype)
        pos = jnp.asarray(pos, jnp.int32)
        table = jnp.asarray(table, jnp.int32)
        active = jnp.asarray(active, bool)

        def attn_fn(p, h, kv_slice):
            y, new = attn_mod.decode_attention_paged(
                p["attn"], L.rmsnorm(p["ln1"], h), kv_slice, table, pos,
                active, num_heads=c.num_heads, num_kv_heads=c.num_kv_heads,
                head_dim=hd, rope_theta=c.rope_theta, use_kernel=use_kernel,
            )
            return h + y, new

        x, new_cache = self._paged_stack_apply(
            self._paged_block_body(attn_fn), x, params["blocks"], cache
        )
        x = L.rmsnorm(params["final_norm"], x)
        logits = L.unembed(params["embed"], x, DTYPES_LOGITS[c.logits_dtype])
        return self._mask_pad_logits(logits[:, 0]), new_cache

    def prefill_paged(self, params, cache, tokens, start, chunk_len, table):
        """One chunked-prefill admit round: C prompt tokens per slot.

        tokens: (S, C) int32 — row s holds prompt positions
        ``[start[s], start[s] + chunk_len[s])`` of slot s's request
        (right-padded; rows with ``chunk_len == 0`` are slots not
        prefilling this round). KV for the chunk is scattered into the
        slot's pool blocks, every query attends the slot's full gathered
        history (earlier chunks included), and the returned logits are
        taken at each row's last real chunk position — for the chunk
        that COMPLETES a prompt these are the request's pending first-
        decode logits, exactly like the dense splice. Returns
        ``(logits (S, V_padded), new_cache)``.
        """
        c = self.config
        self._check_slot_support()
        hd = c.resolved_head_dim
        b, cc = tokens.shape
        x = L.embed(params["embed"], tokens, c.cdtype)
        start = jnp.asarray(start, jnp.int32)
        chunk_len = jnp.asarray(chunk_len, jnp.int32)
        table = jnp.asarray(table, jnp.int32)

        def attn_fn(p, h, kv_slice):
            y, new = attn_mod.prefill_attention_paged(
                p["attn"], L.rmsnorm(p["ln1"], h), kv_slice, table, start,
                chunk_len, num_heads=c.num_heads,
                num_kv_heads=c.num_kv_heads, head_dim=hd,
                rope_theta=c.rope_theta,
            )
            return h + y, new

        x, new_cache = self._paged_stack_apply(
            self._paged_block_body(attn_fn), x, params["blocks"], cache
        )
        last = jnp.clip(chunk_len - 1, 0, cc - 1)
        x_last = x[jnp.arange(b), last][:, None]  # (S, 1, D)
        x_last = L.rmsnorm(params["final_norm"], x_last)
        logits = L.unembed(
            params["embed"], x_last, DTYPES_LOGITS[c.logits_dtype]
        )[:, 0]
        return self._mask_pad_logits(logits), new_cache

    # --------------------------------------------------------- analytics
    def param_count(self) -> int:
        shapes = jax.eval_shape(
            lambda k: self.init_params(k), jax.random.PRNGKey(0)
        )
        return sum(int(np.prod(t.shape)) for t in jax.tree.leaves(shapes))

    def active_param_count(self) -> int:
        """Params touched per token (MoE: top_k of num_experts FFNs)."""
        total = self.param_count()
        c = self.config
        if c.family != "moe" or not c.num_experts:
            return total
        expert_p = 3 * c.d_model * c.d_ff * c.num_experts * c.num_layers
        active = expert_p * c.top_k / c.num_experts
        return int(total - expert_p + active)


# Public functional aliases -------------------------------------------------
def init_params(config: ModelConfig, key):
    return Model(config).init_params(key)


def loss_fn(config: ModelConfig, params, batch):
    return Model(config).loss_fn(params, batch)


def lm_logits(config: ModelConfig, params, tokens, extras=None):
    return Model(config).lm_logits(params, tokens, extras)


def init_cache(config: ModelConfig, batch, cache_len, extras=None):
    return Model(config).init_cache(batch, cache_len, extras)


def decode_step(config: ModelConfig, params, cache, tokens, pos):
    return Model(config).decode_step(params, cache, tokens, pos)
