"""Model zoo: every assigned architecture family, pure JAX.

Families: dense (llama-style GQA), moe (top-k routed experts),
ssm (xLSTM: mLSTM/sLSTM), hybrid (Zamba2: Mamba2 + shared attention),
vlm (PaliGemma: SigLIP-stub + Gemma decoder), audio (Whisper enc-dec
with conv-frontend stub).
"""
from repro.models.model import (
    Model,
    init_cache,
    init_params,
    loss_fn,
    lm_logits,
    decode_step,
)

__all__ = [
    "Model",
    "decode_step",
    "init_cache",
    "init_params",
    "lm_logits",
    "loss_fn",
]
