"""Shared neural-net layers (pure JAX, explicit param pytrees).

Every layer is a pair of functions: ``init_*(key, ...) -> params`` and
``apply`` (the function itself). Params are plain dicts so the sharding
layer can pattern-match on path names.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp
import numpy as np


def _dense_init(key, shape, dtype, scale=None):
    fan_in = shape[0]
    if scale is None:
        scale = 1.0 / np.sqrt(fan_in)
    return (jax.random.normal(key, shape) * scale).astype(dtype)


def init_rmsnorm(d, dtype):
    return {"scale": jnp.ones((d,), dtype=dtype)}


def rmsnorm(params, x, eps: float = 1e-6):
    dt = x.dtype
    x32 = x.astype(jnp.float32)
    var = jnp.mean(x32 * x32, axis=-1, keepdims=True)
    y = x32 * jax.lax.rsqrt(var + eps)
    return (y * params["scale"].astype(jnp.float32)).astype(dt)


def init_layernorm(d, dtype):
    return {"scale": jnp.ones((d,), dtype=dtype), "bias": jnp.zeros((d,), dtype=dtype)}


def layernorm(params, x, eps: float = 1e-5):
    dt = x.dtype
    x32 = x.astype(jnp.float32)
    mean = jnp.mean(x32, axis=-1, keepdims=True)
    var = jnp.var(x32, axis=-1, keepdims=True)
    y = (x32 - mean) * jax.lax.rsqrt(var + eps)
    y = y * params["scale"].astype(jnp.float32) + params["bias"].astype(jnp.float32)
    return y.astype(dt)


def init_linear(key, d_in, d_out, dtype, bias=False):
    p = {"w": _dense_init(key, (d_in, d_out), dtype)}
    if bias:
        p["b"] = jnp.zeros((d_out,), dtype=dtype)
    return p


def linear(params, x):
    y = x @ params["w"].astype(x.dtype)
    if "b" in params:
        y = y + params["b"].astype(x.dtype)
    return y


def init_mlp(key, d_model, d_ff, dtype, activation="silu"):
    k1, k2, k3 = jax.random.split(key, 3)
    if activation == "silu":  # SwiGLU: gate + up + down
        return {
            "w_gate": _dense_init(k1, (d_model, d_ff), dtype),
            "w_up": _dense_init(k2, (d_model, d_ff), dtype),
            "w_down": _dense_init(k3, (d_ff, d_model), dtype),
        }
    return {  # plain GELU MLP (gemma/whisper style)
        "w_up": _dense_init(k1, (d_model, d_ff), dtype),
        "w_down": _dense_init(k2, (d_ff, d_model), dtype),
    }


def mlp(params, x):
    if "w_gate" in params:
        g = jax.nn.silu(x @ params["w_gate"].astype(x.dtype))
        u = x @ params["w_up"].astype(x.dtype)
        return (g * u) @ params["w_down"].astype(x.dtype)
    h = jax.nn.gelu(x @ params["w_up"].astype(x.dtype))
    return h @ params["w_down"].astype(x.dtype)


def init_embedding(key, vocab, d_model, dtype):
    return {"table": (jax.random.normal(key, (vocab, d_model)) * 0.02).astype(dtype)}


def embed(params, tokens, compute_dtype):
    return params["table"][tokens].astype(compute_dtype)


def unembed(params, x, logit_dtype=jnp.float32):
    """Tied LM head: x @ table^T. logit_dtype bf16 halves the dominant
    (B, S, V) activation bytes; the contraction still accumulates f32."""
    return jnp.einsum(
        "...d,vd->...v", x, params["table"].astype(x.dtype),
        preferred_element_type=jnp.float32,
    ).astype(logit_dtype)


def rope(x, positions, theta: float = 10_000.0):
    """Rotary embeddings. x: (..., S, H, hd); positions: (..., S)."""
    hd = x.shape[-1]
    half = hd // 2
    freq = theta ** (-jnp.arange(0, half, dtype=jnp.float32) / half)
    angles = positions[..., :, None].astype(jnp.float32) * freq  # (..., S, half)
    cos = jnp.cos(angles)[..., :, None, :]  # broadcast over heads
    sin = jnp.sin(angles)[..., :, None, :]
    x1, x2 = x[..., :half], x[..., half:]
    y1 = x1 * cos - x2 * sin
    y2 = x2 * cos + x1 * sin
    return jnp.concatenate([y1.astype(x.dtype), y2.astype(x.dtype)], axis=-1)


def cross_entropy_loss(logits, labels, mask=None, z_loss: float = 1e-4):
    """Token-mean cross entropy with optional z-loss.

    Works on bf16 or f32 logits WITHOUT materializing an upcast copy:
    the max/exp/sum chain is elementwise-into-reduction (XLA fuses it, so
    the only HBM traffic over the (B, S, V) tensor is reading the logits
    once per reduction), with f32 accumulation for stability.
    """
    m = jnp.max(logits, axis=-1).astype(jnp.float32)  # fused reduce
    sumexp = jnp.sum(
        jnp.exp(logits.astype(jnp.float32) - m[..., None]), axis=-1
    )  # elementwise+reduce: fuses, no f32 copy materialized
    lse = m + jnp.log(sumexp)
    ll = jnp.take_along_axis(logits, labels[..., None], axis=-1)[..., 0]
    nll = lse - ll.astype(jnp.float32)
    if z_loss:
        nll = nll + z_loss * lse**2
    if mask is None:
        return jnp.mean(nll)
    mask = mask.astype(jnp.float32)
    return jnp.sum(nll * mask) / jnp.maximum(jnp.sum(mask), 1.0)
