"""xLSTM blocks (arXiv:2405.04517): mLSTM (matrix memory) + sLSTM.

mLSTM: per-head matrix memory C in R^{hd x hd} with exponential gating,
    C_t = f_t C_{t-1} + i_t v_t k_t^T,   n_t = f_t n_{t-1} + i_t k_t,
    h_t = (C_t q_t) / max(|n_t^T q_t|, 1)
stabilized in log space (m_t tracks the running max exponent). Computed
with a lax.scan over time (training) and a single-step state update for
decode (O(hd^2) per token — qualifies for the 500k decode shape).

sLSTM: scalar memory with recurrent gate connections; strictly
sequential, implemented as a lax.scan over time.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp
import numpy as np

from repro.models.layers import _dense_init, rmsnorm


# ---------------------------------------------------------------- mLSTM
def init_mlstm(key, d_model, num_heads, dtype, proj_factor=2.0):
    d_in = int(d_model * proj_factor)
    hd = d_in // num_heads
    ks = jax.random.split(key, 7)
    return {
        "w_up": _dense_init(ks[0], (d_model, 2 * d_in), dtype),  # [x_in, z gate]
        "wq": _dense_init(ks[1], (d_in, d_in), dtype),
        "wk": _dense_init(ks[2], (d_in, d_in), dtype),
        "wv": _dense_init(ks[3], (d_in, d_in), dtype),
        "w_if": _dense_init(ks[4], (d_in, 2 * num_heads), jnp.float32, scale=0.01),
        "b_i": jnp.zeros((num_heads,), jnp.float32),
        "b_f": jnp.linspace(3.0, 6.0, num_heads).astype(jnp.float32),
        "norm_scale": jnp.ones((d_in,), dtype=dtype),
        "w_down": _dense_init(ks[5], (d_in, d_model), dtype),
    }


def _mlstm_scan(q, k, v, log_i, log_f, c0, n0, m0):
    """Sequential mLSTM. q,k,v: (B,S,H,hd); gates (B,S,H). Returns h + state."""

    def step(carry, inp):
        c, n, m = carry  # (B,H,hd,hd), (B,H,hd), (B,H)
        qt, kt, vt, li, lf = inp  # (B,H,hd) x3, (B,H) x2
        m_new = jnp.maximum(lf + m, li)
        i_s = jnp.exp(li - m_new)
        f_s = jnp.exp(lf + m - m_new)
        c_new = f_s[..., None, None] * c + i_s[..., None, None] * (
            vt[..., :, None] * kt[..., None, :]
        )
        n_new = f_s[..., None] * n + i_s[..., None] * kt
        num = jnp.einsum("bhij,bhj->bhi", c_new, qt)
        den = jnp.maximum(
            jnp.abs(jnp.einsum("bhj,bhj->bh", n_new, qt)), jnp.exp(-m_new)
        )
        h = num / den[..., None]
        return (c_new, n_new, m_new), h

    xs = (
        q.transpose(1, 0, 2, 3),
        k.transpose(1, 0, 2, 3),
        v.transpose(1, 0, 2, 3),
        log_i.transpose(1, 0, 2),
        log_f.transpose(1, 0, 2),
    )
    (c, n, m), hs = jax.lax.scan(step, (c0, n0, m0), xs)
    return hs.transpose(1, 0, 2, 3), (c, n, m)  # (B,S,H,hd)


def mlstm(params, x, *, num_heads, proj_factor=2.0, state=None):
    """x: (B,S,D). state (decode): {"c","n","m"}; S must be 1 then."""
    b, s, d_model = x.shape
    d_in = int(d_model * proj_factor)
    hd = d_in // num_heads
    up = x @ params["w_up"].astype(x.dtype)
    x_in, z = jnp.split(up, 2, axis=-1)
    q = (x_in @ params["wq"].astype(x.dtype)).reshape(b, s, num_heads, hd)
    k = (x_in @ params["wk"].astype(x.dtype)).reshape(b, s, num_heads, hd) / np.sqrt(
        hd
    )
    v = (x_in @ params["wv"].astype(x.dtype)).reshape(b, s, num_heads, hd)
    gates = x_in.astype(jnp.float32) @ params["w_if"]
    log_i = jax.nn.log_sigmoid(gates[..., :num_heads] + params["b_i"])
    log_f = jax.nn.log_sigmoid(gates[..., num_heads:] + params["b_f"])

    if state is None:
        c0 = jnp.zeros((b, num_heads, hd, hd), jnp.float32)
        n0 = jnp.zeros((b, num_heads, hd), jnp.float32)
        m0 = jnp.zeros((b, num_heads), jnp.float32)
    else:
        c0, n0, m0 = state["c"], state["n"], state["m"]
    h, (c, n, m) = _mlstm_scan(
        q.astype(jnp.float32), k.astype(jnp.float32), v.astype(jnp.float32),
        log_i, log_f, c0, n0, m0,
    )
    h = h.reshape(b, s, d_in).astype(x.dtype)
    h = rmsnorm({"scale": params["norm_scale"]}, h)
    out = (h * jax.nn.silu(z)) @ params["w_down"].astype(x.dtype)
    if state is None:
        return out
    return out, {"c": c, "n": n, "m": m}


def init_mlstm_state(batch, d_model, num_heads, proj_factor=2.0):
    d_in = int(d_model * proj_factor)
    hd = d_in // num_heads
    return {
        "c": jnp.zeros((batch, num_heads, hd, hd), jnp.float32),
        "n": jnp.zeros((batch, num_heads, hd), jnp.float32),
        "m": jnp.zeros((batch, num_heads), jnp.float32),
    }


# ---------------------------------------------------------------- sLSTM
def init_slstm(key, d_model, num_heads, dtype):
    hd = d_model // num_heads
    ks = jax.random.split(key, 3)
    return {
        # input projections for gates (i, f, z, o)
        "w_x": _dense_init(ks[0], (d_model, 4 * d_model), dtype),
        # recurrent (block-diagonal per head): (H, hd, 4*hd)
        "w_h": (jax.random.normal(ks[1], (num_heads, hd, 4 * hd)) / np.sqrt(hd)).astype(
            dtype
        ),
        "b": jnp.concatenate(
            [
                jnp.zeros((d_model,)),
                jnp.ones((d_model,)),  # forget-gate bias > 0
                jnp.zeros((2 * d_model,)),
            ]
        ).astype(jnp.float32),
        "norm_scale": jnp.ones((d_model,), dtype=dtype),
        "w_out": _dense_init(ks[2], (d_model, d_model), dtype),
    }


def slstm(params, x, *, num_heads, state=None):
    """x: (B,S,D). Recurrent scalar-memory LSTM with exponential gating."""
    b, s, d_model = x.shape
    hd = d_model // num_heads
    xg = (x @ params["w_x"].astype(x.dtype)).astype(jnp.float32) + params["b"]
    xg = xg.reshape(b, s, 4, num_heads, hd)

    def step(carry, xg_t):
        c, n, m, h = carry  # (B,H,hd) x3 + hidden (B,H,hd)
        rec = jnp.einsum("bhi,hij->bhj", h, params["w_h"].astype(jnp.float32))
        rec = rec.reshape(b, num_heads, 4, hd).transpose(0, 2, 1, 3)
        gi, gf, gz, go = [xg_t[:, i] + rec[:, i] for i in range(4)]
        m_new = jnp.maximum(gf + m, gi)
        i_s = jnp.exp(gi - m_new)
        f_s = jnp.exp(gf + m - m_new)
        c_new = f_s * c + i_s * jnp.tanh(gz)
        n_new = f_s * n + i_s
        h_new = jax.nn.sigmoid(go) * c_new / jnp.maximum(n_new, 1.0)
        return (c_new, n_new, m_new, h_new), h_new

    if state is None:
        zeros = jnp.zeros((b, num_heads, hd), jnp.float32)
        carry0 = (zeros, zeros, jnp.zeros((b, num_heads, hd), jnp.float32), zeros)
    else:
        carry0 = (state["c"], state["n"], state["m"], state["h"])
    carry, hs = jax.lax.scan(step, carry0, xg.transpose(1, 0, 2, 3, 4))
    h = hs.transpose(1, 0, 2, 3).reshape(b, s, d_model).astype(x.dtype)
    h = rmsnorm({"scale": params["norm_scale"]}, h)
    out = h @ params["w_out"].astype(x.dtype)
    if state is None:
        return out
    c, n, m, hh = carry
    return out, {"c": c, "n": n, "m": m, "h": hh}


def init_slstm_state(batch, d_model, num_heads):
    hd = d_model // num_heads
    z = jnp.zeros((batch, num_heads, hd), jnp.float32)
    return {"c": z, "n": z, "m": z, "h": z}
