"""Attention: GQA/MQA with flash-style chunked softmax, pure JAX.

Train/prefill path processes queries and keys in blocks with an online
softmax (running max + normalizer) so the full (S x S) score matrix is
never materialized — the working set per step is (B, H, qblk, kblk).
Causal masking is applied per block pair; block pairs that are entirely
above the diagonal still lower (masked) in the baseline — the §Perf
hillclimb replaces this with lower-triangular block iteration.

Decode path attends a single query against a KV cache; sliding-window
models use a rolling (modulo) cache so a 4k window serves a 500k context
in O(window) memory.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
import numpy as np

from repro.models.layers import _dense_init, rope

NEG_INF = -1e30


def init_attention(key, d_model, num_heads, num_kv_heads, head_dim, dtype,
                   qk_norm=False, with_rope=True):
    k1, k2, k3, k4 = jax.random.split(key, 4)
    p = {
        "wq": _dense_init(k1, (d_model, num_heads * head_dim), dtype),
        "wk": _dense_init(k2, (d_model, num_kv_heads * head_dim), dtype),
        "wv": _dense_init(k3, (d_model, num_kv_heads * head_dim), dtype),
        "wo": _dense_init(k4, (num_heads * head_dim, d_model), dtype),
    }
    if qk_norm:
        p["q_norm"] = jnp.ones((head_dim,), dtype=dtype)
        p["k_norm"] = jnp.ones((head_dim,), dtype=dtype)
    return p


def _qkv(params, x, xkv, num_heads, num_kv_heads, head_dim):
    b, s, _ = x.shape
    skv = xkv.shape[1]
    q = (x @ params["wq"].astype(x.dtype)).reshape(b, s, num_heads, head_dim)
    k = (xkv @ params["wk"].astype(x.dtype)).reshape(b, skv, num_kv_heads, head_dim)
    v = (xkv @ params["wv"].astype(x.dtype)).reshape(b, skv, num_kv_heads, head_dim)
    return q, k, v


def _maybe_qk_norm(params, q, k, eps=1e-6):
    if "q_norm" not in params:
        return q, k

    def rn(t, scale):
        t32 = t.astype(jnp.float32)
        var = jnp.mean(t32 * t32, axis=-1, keepdims=True)
        return (t32 * jax.lax.rsqrt(var + eps) * scale.astype(jnp.float32)).astype(
            t.dtype
        )

    return rn(q, params["q_norm"]), rn(k, params["k_norm"])


def _block_attn_scores(q, k, scale):
    # q: (B, qb, KV, G, hd), k: (B, kb, KV, hd) -> (B, KV, G, qb, kb)
    return jnp.einsum("bqkgh,bskh->bkgqs", q, k) * scale


@functools.partial(
    jax.jit,
    static_argnames=(
        "num_heads", "num_kv_heads", "head_dim", "causal", "window",
        "q_block", "kv_block", "causal_skip",
    ),
)
def chunked_attention(
    q, k, v, q_pos, kv_pos, *,
    num_heads, num_kv_heads, head_dim,
    causal=True, window=None, q_block=512, kv_block=1024,
    causal_skip=False,
):
    """Flash-style attention. q: (B,S,H,hd); k,v: (B,Skv,KV,hd).

    q_pos: (S,) absolute positions of queries; kv_pos: (Skv,) of keys.
    Returns (B, S, H, hd).

    causal_skip: iterate kv blocks with DYNAMIC bounds so blocks that are
    entirely above the causal diagonal (or entirely outside the sliding
    window) are never computed — ~2x attention-FLOP cut at long seq
    (§Perf hillclimb; baseline lowers every masked block).
    """
    b, s, _, _ = q.shape
    skv = k.shape[1]
    g = num_heads // num_kv_heads
    scale = 1.0 / np.sqrt(head_dim)
    qb = min(q_block, s)
    kb = min(kv_block, skv)
    nq, nk = s // qb, skv // kb
    assert s % qb == 0 and skv % kb == 0, (s, qb, skv, kb)

    qr = q.reshape(b, nq, qb, num_kv_heads, g, head_dim)
    kr = k.reshape(b, nk, kb, num_kv_heads, head_dim)
    vr = v.reshape(b, nk, kb, num_kv_heads, head_dim)
    qp = q_pos.reshape(nq, qb)
    kp = kv_pos.reshape(nk, kb)

    def q_step(_, qi):
        q_i = qr[:, qi]  # (B, qb, KV, G, hd)
        qp_i = qp[qi]

        def kv_body(carry, kj):
            m, l, acc = carry
            k_j = kr[:, kj]
            v_j = vr[:, kj]
            kp_j = kp[kj]
            sc = _block_attn_scores(q_i, k_j, scale).astype(jnp.float32)
            # (B, KV, G, qb, kb). kv_pos < 0 marks padded key slots.
            mask = jnp.broadcast_to(kp_j[None, :] >= 0, (qb, kb))
            if causal:
                mask &= qp_i[:, None] >= kp_j[None, :]
            if window is not None:
                mask &= qp_i[:, None] - kp_j[None, :] < window
            sc = jnp.where(mask[None, None, None], sc, NEG_INF)
            m_new = jnp.maximum(m, jnp.max(sc, axis=-1))
            p = jnp.exp(sc - m_new[..., None])
            corr = jnp.exp(m - m_new)
            l_new = l * corr + jnp.sum(p, axis=-1)
            pv = jnp.einsum(
                "bkgqs,bskh->bkgqh", p.astype(v_j.dtype), v_j
            ).astype(jnp.float32)
            acc_new = acc * corr[..., None] + pv
            return (m_new, l_new, acc_new)

        m0 = jnp.full((b, num_kv_heads, g, qb), NEG_INF, dtype=jnp.float32)
        l0 = jnp.zeros((b, num_kv_heads, g, qb), dtype=jnp.float32)
        a0 = jnp.zeros((b, num_kv_heads, g, qb, head_dim), dtype=jnp.float32)
        if causal_skip:
            # runtime-skip blocks entirely above the causal diagonal (or
            # outside the sliding window): scan over all block indices
            # with a lax.cond — only the needed branch executes, and the
            # construct stays reverse-differentiable (a dynamic-bound
            # fori_loop would not be).
            qmax = jnp.max(qp_i)
            qmin = jnp.min(qp_i)
            kmins = jnp.min(kp, axis=1)  # (nk,)
            kmaxs = jnp.max(kp, axis=1)
            needed = jnp.ones((nk,), bool)
            if causal:
                needed &= kmins <= qmax
            if window is not None:
                needed &= kmaxs >= qmin - window + 1

            def maybe(carry, inp):
                kj, need = inp
                new = jax.lax.cond(
                    need, lambda c: kv_body(c, kj), lambda c: c, carry
                )
                return new, None

            (m, l, acc), _ = jax.lax.scan(
                maybe, (m0, l0, a0), (jnp.arange(nk), needed)
            )
        else:
            (m, l, acc), _ = jax.lax.scan(
                lambda c, kj: (kv_body(c, kj), None), (m0, l0, a0),
                jnp.arange(nk),
            )
        out = acc / jnp.maximum(l[..., None], 1e-30)
        # (B, KV, G, qb, hd) -> (B, qb, KV*G, hd)
        out = out.transpose(0, 3, 1, 2, 4).reshape(b, qb, num_heads, head_dim)
        return None, out.astype(q.dtype)

    _, outs = jax.lax.scan(q_step, None, jnp.arange(nq))
    # outs: (nq, B, qb, H, hd) -> (B, S, H, hd)
    return outs.transpose(1, 0, 2, 3, 4).reshape(b, s, num_heads, head_dim)


def attention(
    params, x, positions, *,
    num_heads, num_kv_heads, head_dim,
    causal=True, window=None, use_rope=True, rope_theta=10_000.0,
    xkv=None, kv_positions=None, q_block=512, kv_block=1024,
    causal_skip=False, return_kv=False,
):
    """Full attention layer (train/prefill). x: (B, S, D).

    Sequences that do not divide the block sizes are padded: queries with
    continuation positions (output sliced back), keys with position -1
    (masked inside the online softmax).

    ``return_kv``: additionally return the post-rope (B, S, KV, hd) key
    and value tensors — exactly what ``decode_attention`` would have
    written into its cache one position at a time, so a batched prefill
    can fill a decode cache from this single pass (DESIGN.md §4/§10).
    """
    xkv = x if xkv is None else xkv
    kv_positions = positions if kv_positions is None else kv_positions
    q, k, v = _qkv(params, x, xkv, num_heads, num_kv_heads, head_dim)
    q, k = _maybe_qk_norm(params, q, k)
    if use_rope:
        q = rope(q, jnp.broadcast_to(positions, x.shape[:1] + positions.shape[-1:]),
                 rope_theta)
        k = rope(k, jnp.broadcast_to(kv_positions, xkv.shape[:1] + kv_positions.shape[-1:]),
                 rope_theta)
    k_cache, v_cache = k, v  # pre-padding views (the decode-cache payload)
    b, s = x.shape[:2]
    skv = k.shape[1]
    qb = min(q_block, s)
    kb = min(kv_block, skv)
    pad_q = (-s) % qb
    pad_k = (-skv) % kb
    if pad_q:
        q = jnp.pad(q, ((0, 0), (0, pad_q), (0, 0), (0, 0)))
        last = positions[-1]
        positions = jnp.concatenate(
            [positions, last + 1 + jnp.arange(pad_q, dtype=positions.dtype)]
        )
    if pad_k:
        k = jnp.pad(k, ((0, 0), (0, pad_k), (0, 0), (0, 0)))
        v = jnp.pad(v, ((0, 0), (0, pad_k), (0, 0), (0, 0)))
        kv_positions = jnp.concatenate(
            [kv_positions, jnp.full((pad_k,), -1, dtype=kv_positions.dtype)]
        )
    out = chunked_attention(
        q, k, v, positions, kv_positions,
        num_heads=num_heads, num_kv_heads=num_kv_heads, head_dim=head_dim,
        causal=causal, window=window, q_block=qb, kv_block=kb,
        causal_skip=causal_skip,
    )
    if pad_q:
        out = out[:, :s]
    y = out.reshape(b, s, num_heads * head_dim) @ params["wo"].astype(x.dtype)
    if return_kv:
        return y, k_cache, v_cache
    return y


def init_attn_cache(batch, cache_len, num_kv_heads, head_dim, dtype,
                    quantized: bool = False):
    """KV cache. cache_len = full context, or window size (rolling).

    quantized: int8 storage with per-(token, head) symmetric scales —
    halves the dominant decode cache-read bytes at ~0.4% quantization
    noise (scales add 2/head_dim relative overhead).
    """
    if quantized:
        return {
            "k": jnp.zeros((batch, cache_len, num_kv_heads, head_dim), jnp.int8),
            "v": jnp.zeros((batch, cache_len, num_kv_heads, head_dim), jnp.int8),
            "k_scale": jnp.zeros((batch, cache_len, num_kv_heads), jnp.float16),
            "v_scale": jnp.zeros((batch, cache_len, num_kv_heads), jnp.float16),
            "pos": jnp.full((cache_len,), -1, dtype=jnp.int32),
        }
    return {
        "k": jnp.zeros((batch, cache_len, num_kv_heads, head_dim), dtype=dtype),
        "v": jnp.zeros((batch, cache_len, num_kv_heads, head_dim), dtype=dtype),
        "pos": jnp.full((cache_len,), -1, dtype=jnp.int32),  # absolute pos per slot
    }


def _quantize_kv(t):
    """(B, 1, KV, hd) -> int8 values + per-(B,1,KV) f16 scales."""
    amax = jnp.max(jnp.abs(t.astype(jnp.float32)), axis=-1)
    scale = jnp.maximum(amax / 127.0, 1e-8)
    q = jnp.clip(
        jnp.round(t.astype(jnp.float32) / scale[..., None]), -127, 127
    ).astype(jnp.int8)
    return q, scale.astype(jnp.float16)


def decode_attention(
    params, x, cache, pos, *,
    num_heads, num_kv_heads, head_dim,
    window=None, use_rope=True, rope_theta=10_000.0,
):
    """Single-token decode. x: (B, 1, D); pos: scalar int32 (uniform batch).

    Writes the new KV at slot ``pos % cache_len`` (rolling when the cache
    is smaller than the context — sliding-window models), then attends
    over every valid slot. Cost is one matvec per head over the cache:
    exactly the paper's matvec shape.
    """
    b = x.shape[0]
    q, k_new, v_new = _qkv(params, x, x, num_heads, num_kv_heads, head_dim)
    q, k_new = _maybe_qk_norm(params, q, k_new)
    if use_rope:
        p = jnp.full((1,), pos, dtype=jnp.int32)
        q = rope(q, jnp.broadcast_to(p, (b, 1)), rope_theta)
        k_new = rope(k_new, jnp.broadcast_to(p, (b, 1)), rope_theta)
    cache_len = cache["k"].shape[1]
    slot = jnp.mod(pos, cache_len).astype(jnp.int32)
    zero = jnp.zeros((), jnp.int32)  # all indices same dtype (x64-safe)
    quantized = "k_scale" in cache
    if quantized:
        k_q, k_s = _quantize_kv(k_new)
        v_q, v_s = _quantize_kv(v_new)
        k_int = jax.lax.dynamic_update_slice(cache["k"], k_q, (zero, slot, zero, zero))
        v_int = jax.lax.dynamic_update_slice(cache["v"], v_q, (zero, slot, zero, zero))
        k_sc = jax.lax.dynamic_update_slice(cache["k_scale"], k_s, (zero, slot, zero))
        v_sc = jax.lax.dynamic_update_slice(cache["v_scale"], v_s, (zero, slot, zero))
        k = k_int.astype(x.dtype) * k_sc[..., None].astype(x.dtype)
        v = v_int.astype(x.dtype) * v_sc[..., None].astype(x.dtype)
        new_cache = {"k": k_int, "v": v_int, "k_scale": k_sc, "v_scale": v_sc}
    else:
        k = jax.lax.dynamic_update_slice(cache["k"], k_new, (zero, slot, zero, zero))
        v = jax.lax.dynamic_update_slice(cache["v"], v_new, (zero, slot, zero, zero))
        new_cache = {"k": k, "v": v}
    slot_pos = jax.lax.dynamic_update_slice(
        cache["pos"], jnp.full((1,), pos, dtype=jnp.int32), (slot,)
    )
    new_cache["pos"] = slot_pos
    g = num_heads // num_kv_heads
    scale = 1.0 / np.sqrt(head_dim)
    qr = q.reshape(b, num_kv_heads, g, head_dim)
    sc = jnp.einsum("bkgh,bskh->bkgs", qr, k).astype(jnp.float32) * scale
    valid = (slot_pos >= 0) & (slot_pos <= pos)
    if window is not None:
        valid &= pos - slot_pos < window
    sc = jnp.where(valid[None, None, None, :], sc, NEG_INF)
    w = jax.nn.softmax(sc, axis=-1).astype(v.dtype)
    out = jnp.einsum("bkgs,bskh->bkgh", w, v)
    out = out.reshape(b, 1, num_heads * head_dim)
    y = out @ params["wo"].astype(x.dtype)
    return y, new_cache


def decode_attention_paged(
    params, x, cache, table, pos, active, *,
    num_heads, num_kv_heads, head_dim,
    use_rope=True, rope_theta=10_000.0, use_kernel=False,
):
    """Per-slot decode against a shared KV block pool (DESIGN.md §13).

    Like ``decode_attention_slots`` but the KV state is a fixed pool of
    physical blocks shared across slots: ``cache`` holds ``{"k", "v"}``
    of shape (num_blocks + 1, block_len, KV, hd) (last block = write
    sink), ``table``: (S, max_blocks) maps each slot's logical blocks to
    pool blocks (−1 = unallocated), ``pos``: (S,) write positions,
    ``active``: (S,) bool — inactive rows write to the sink so frozen
    slots can never corrupt reassigned blocks. The attend math mirrors
    ``decode_attention_slots`` exactly so paged decode logits bit-match
    the dense oracle under an order-preserving layout.
    """
    from repro.kernels.paged_attention import ops as paged_ops

    b = x.shape[0]
    q, k_new, v_new = _qkv(params, x, x, num_heads, num_kv_heads, head_dim)
    q, k_new = _maybe_qk_norm(params, q, k_new)
    if use_rope:
        p = pos[:, None].astype(jnp.int32)
        q = rope(q, p, rope_theta)
        k_new = rope(k_new, p, rope_theta)
    k_pool, v_pool = paged_ops.scatter_decode(
        cache["k"], cache["v"], k_new[:, 0], v_new[:, 0], table, pos, active
    )
    g = num_heads // num_kv_heads
    qr = q.reshape(b, num_kv_heads, g, head_dim)
    if use_kernel:
        out = paged_ops.paged_decode_attend_kernel(
            qr, k_pool, v_pool, table, pos
        )
    else:
        out = paged_ops.paged_decode_attend(qr, k_pool, v_pool, table, pos)
    out = out.reshape(b, 1, num_heads * head_dim)
    y = out @ params["wo"].astype(x.dtype)
    return y, {"k": k_pool, "v": v_pool}


def prefill_attention_paged(
    params, x, cache, table, start, chunk_len, *,
    num_heads, num_kv_heads, head_dim,
    use_rope=True, rope_theta=10_000.0,
):
    """One chunked-prefill pass of C prompt tokens per slot into the pool.

    x: (S, C, D) chunk embeddings; chunk row ``i`` of slot ``s`` is the
    prompt token at absolute position ``start[s] + i`` (rows past
    ``chunk_len[s]`` are padding — their KV goes to the sink and their
    outputs are discarded by the caller). KV for the chunk is scattered
    FIRST, then every query attends the slot's full gathered history up
    to itself, so cross-chunk context (earlier admit rounds) and
    in-chunk causality share one mask.
    """
    from repro.kernels.paged_attention import ops as paged_ops

    b, c = x.shape[:2]
    q, k_new, v_new = _qkv(params, x, x, num_heads, num_kv_heads, head_dim)
    q, k_new = _maybe_qk_norm(params, q, k_new)
    p = start[:, None] + jnp.arange(c, dtype=jnp.int32)[None, :]  # (S, C)
    if use_rope:
        q = rope(q, p, rope_theta)
        k_new = rope(k_new, p, rope_theta)
    k_pool, v_pool = paged_ops.scatter_chunk(
        cache["k"], cache["v"], k_new, v_new, table, start, chunk_len
    )
    g = num_heads // num_kv_heads
    qr = q.reshape(b, c, num_kv_heads, g, head_dim)
    out = paged_ops.paged_chunk_attend(qr, k_pool, v_pool, table, p)
    out = out.reshape(b, c, num_heads * head_dim).astype(x.dtype)
    y = out @ params["wo"].astype(x.dtype)
    return y, {"k": k_pool, "v": v_pool}


def decode_attention_slots(
    params, x, cache, pos_map, pos, slot, *,
    num_heads, num_kv_heads, head_dim,
    use_rope=True, rope_theta=10_000.0,
):
    """Per-slot decode: every batch row advances at its OWN position.

    The continuous-batching serve loop keeps one independent request per
    batch slot, so unlike ``decode_attention`` (uniform scalar ``pos``
    for the whole batch) each row writes its new KV at, and attends up
    to, its own absolute position.

    x: (B, 1, D); cache: {"k", "v"} of shape (B, S, KV, hd);
    pos_map: (B, S) absolute position held by each cache entry (−1 =
    empty — the caller computes the post-write map once, it is shared by
    every layer); pos: (B,) this step's write positions; slot: (B,)
    cache indices to write (``pos % S``). Returns (y, {"k", "v"}).
    Rolling/sliding-window caches and int8 KV are not supported here —
    the slot server allocates full-context caches per slot.
    """
    b = x.shape[0]
    q, k_new, v_new = _qkv(params, x, x, num_heads, num_kv_heads, head_dim)
    q, k_new = _maybe_qk_norm(params, q, k_new)
    if use_rope:
        p = pos[:, None].astype(jnp.int32)  # (B, 1) per-slot positions
        q = rope(q, p, rope_theta)
        k_new = rope(k_new, p, rope_theta)
    bidx = jnp.arange(b)
    k = cache["k"].at[bidx, slot].set(k_new[:, 0])
    v = cache["v"].at[bidx, slot].set(v_new[:, 0])
    g = num_heads // num_kv_heads
    scale = 1.0 / np.sqrt(head_dim)
    qr = q.reshape(b, num_kv_heads, g, head_dim)
    sc = jnp.einsum("bkgh,bskh->bkgs", qr, k).astype(jnp.float32) * scale
    valid = (pos_map >= 0) & (pos_map <= pos[:, None])  # (B, S)
    sc = jnp.where(valid[:, None, None, :], sc, NEG_INF)
    w = jax.nn.softmax(sc, axis=-1).astype(v.dtype)
    out = jnp.einsum("bkgs,bskh->bkgh", w, v)
    out = out.reshape(b, 1, num_heads * head_dim)
    y = out @ params["wo"].astype(x.dtype)
    return y, {"k": k, "v": v}
