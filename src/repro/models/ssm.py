"""Mamba2 (SSD) block — chunked parallel scan, pure JAX.

State-space: per head h with state size N and head dim P,
    S_t = exp(dt_t A_h) S_{t-1} + dt_t B_t x_t^T        (S in R^{N x P})
    y_t = C_t^T S_t + D_h x_t
computed with the SSD block decomposition: quadratic attention-like
intra-chunk term + a lax.scan over chunk states for the inter-chunk
recurrence. O(S * Q) work per sequence for chunk length Q instead of
O(S^2); decode is a single O(N*P) state update per token (this is what
makes the hybrid/ssm archs eligible for the 500k-context decode shape).
"""
from __future__ import annotations

import jax
import jax.numpy as jnp
import numpy as np

from repro.models.layers import _dense_init, rmsnorm

CONV_K = 4  # causal depthwise conv kernel size


def init_mamba2(key, d_model, d_state, dtype, expand=2, head_dim=64):
    d_inner = expand * d_model
    n_heads = d_inner // head_dim
    conv_dim = d_inner + 2 * d_state
    ks = jax.random.split(key, 4)
    return {
        # fused in_proj: [z, x, B, C, dt]
        "w_in": _dense_init(
            ks[0], (d_model, 2 * d_inner + 2 * d_state + n_heads), dtype
        ),
        "conv_w": (jax.random.normal(ks[1], (CONV_K, conv_dim)) * 0.1).astype(dtype),
        "conv_b": jnp.zeros((conv_dim,), dtype=dtype),
        "a_log": jnp.log(jnp.linspace(1.0, 16.0, n_heads)).astype(jnp.float32),
        "dt_bias": jnp.full((n_heads,), np.log(np.expm1(0.01)), dtype=jnp.float32),
        "d_skip": jnp.ones((n_heads,), dtype=jnp.float32),
        "norm_scale": jnp.ones((d_inner,), dtype=dtype),
        "w_out": _dense_init(ks[2], (d_inner, d_model), dtype),
    }


def _split_in(params, x, d_model, d_state, d_inner, n_heads):
    zxbcdt = x @ params["w_in"].astype(x.dtype)
    z, xs, b, c, dt = jnp.split(
        zxbcdt,
        [d_inner, 2 * d_inner, 2 * d_inner + d_state, 2 * d_inner + 2 * d_state],
        axis=-1,
    )
    return z, xs, b, c, dt


def _causal_conv(xbc, conv_w, conv_b, conv_state=None):
    """Depthwise causal conv over time. xbc: (B, S, C)."""
    if conv_state is not None:  # decode: (B, CONV_K-1, C) history
        window = jnp.concatenate([conv_state, xbc], axis=1)  # (B, K, C)
        y = jnp.einsum("bkc,kc->bc", window, conv_w.astype(xbc.dtype))[:, None]
        new_state = window[:, 1:]
        return jax.nn.silu(y + conv_b.astype(xbc.dtype)), new_state
    pad = jnp.zeros(xbc.shape[:1] + (CONV_K - 1,) + xbc.shape[2:], xbc.dtype)
    xp = jnp.concatenate([pad, xbc], axis=1)
    # stack K shifted views: (B, S, K, C)
    views = jnp.stack([xp[:, i : i + xbc.shape[1]] for i in range(CONV_K)], axis=2)
    y = jnp.einsum("bskc,kc->bsc", views, conv_w.astype(xbc.dtype))
    return jax.nn.silu(y + conv_b.astype(xbc.dtype)), None


def _segsum(a):
    """Lower-triangular pairwise cumulative sums: out[.., i, j] = sum_{j<t<=i} a_t."""
    q = a.shape[-1]
    cs = jnp.cumsum(a, axis=-1)
    diff = cs[..., :, None] - cs[..., None, :]
    mask = jnp.tril(jnp.ones((q, q), dtype=bool))
    return jnp.where(mask, diff, -jnp.inf)


def mamba2(params, x, *, d_state, expand=2, head_dim=64, chunk=256, state=None):
    """x: (B, S, D). If ``state`` given (decode), S must be 1.

    state = {"ssm": (B, H, N, P), "conv": (B, CONV_K-1, conv_dim)}.
    Returns (y, new_state) in decode mode, else y.
    """
    b, s, d_model = x.shape
    d_inner = expand * d_model
    n_heads = d_inner // head_dim
    z, xs, bmat, cmat, dt = _split_in(params, x, d_model, d_state, d_inner, n_heads)
    xbc = jnp.concatenate([xs, bmat, cmat], axis=-1)

    a = -jnp.exp(params["a_log"])  # (H,) negative
    decode = state is not None
    if decode:
        conv_out, new_conv = _causal_conv(
            xbc, params["conv_w"], params["conv_b"], state["conv"]
        )
    else:
        conv_out, _ = _causal_conv(xbc, params["conv_w"], params["conv_b"])
    xs, bmat, cmat = jnp.split(conv_out, [d_inner, d_inner + d_state], axis=-1)
    xh = xs.reshape(b, s, n_heads, head_dim)
    dt = jax.nn.softplus(dt.astype(jnp.float32) + params["dt_bias"])  # (B,S,H)

    if decode:
        # one step: S' = exp(dt a) S + dt B x^T ; y = C S' + D x
        ssm = state["ssm"]  # (B, H, N, P)
        da = jnp.exp(dt[:, 0] * a)  # (B, H)
        dbx = jnp.einsum(
            "bh,bn,bhp->bhnp", dt[:, 0], bmat[:, 0].astype(jnp.float32),
            xh[:, 0].astype(jnp.float32),
        )
        ssm_new = da[..., None, None] * ssm + dbx
        y = jnp.einsum("bn,bhnp->bhp", cmat[:, 0].astype(jnp.float32), ssm_new)
        y = y + params["d_skip"][None, :, None] * xh[:, 0].astype(jnp.float32)
        y = y.reshape(b, 1, d_inner).astype(x.dtype)
        y = y * jax.nn.silu(z)
        y = rmsnorm({"scale": params["norm_scale"]}, y)
        out = y @ params["w_out"].astype(x.dtype)
        return out, {"ssm": ssm_new, "conv": new_conv}

    # ---- chunked SSD (train/prefill) ----
    q = min(chunk, s)
    assert s % q == 0, (s, q)
    nc = s // q
    xh = xh.reshape(b, nc, q, n_heads, head_dim)
    bm = bmat.reshape(b, nc, q, d_state).astype(jnp.float32)
    cm = cmat.reshape(b, nc, q, d_state).astype(jnp.float32)
    dtc = dt.reshape(b, nc, q, n_heads)
    ac = dtc * a  # (B, NC, Q, H) log-decay increments
    ac_cum = jnp.cumsum(ac, axis=2)  # within-chunk cumulative
    xdt = xh.astype(jnp.float32) * dtc[..., None]  # dt-weighted inputs

    # intra-chunk: attention-like quadratic term
    lmat = jnp.exp(_segsum(ac.transpose(0, 1, 3, 2)))  # (B,NC,H,Q,Q)
    scores = jnp.einsum("bcin,bcjn->bcij", cm, bm)  # (B,NC,Q,Q)
    y_intra = jnp.einsum("bchij,bcij,bcjhp->bcihp", lmat, scores, xdt)
    # chunk states: S_c = sum_j exp(a_end - a_j) dt_j B_j x_j^T
    decay_to_end = jnp.exp(ac_cum[:, :, -1:, :] - ac_cum)  # (B,NC,Q,H)
    s_local = jnp.einsum("bcjh,bcjn,bcjhp->bchnp", decay_to_end, bm, xdt)

    # inter-chunk recurrence over chunk index
    chunk_decay = jnp.exp(ac_cum[:, :, -1, :])  # (B, NC, H)

    def scan_fn(carry, inp):
        s_prev = carry  # (B, H, N, P)
        dec, s_loc = inp  # (B,H), (B,H,N,P)
        s_new = dec[..., None, None] * s_prev + s_loc
        return s_new, s_prev

    init = jnp.zeros((b, n_heads, d_state, head_dim), jnp.float32)
    s_final, s_prevs = jax.lax.scan(
        scan_fn,
        init,
        (chunk_decay.transpose(1, 0, 2), s_local.transpose(1, 0, 2, 3, 4)),
    )
    s_prevs = s_prevs.transpose(1, 0, 2, 3, 4)  # (B, NC, H, N, P)

    # inter-chunk contribution: C_i exp(cum_a_i) S_{c-1}
    y_inter = jnp.einsum(
        "bcin,bcih,bchnp->bcihp", cm, jnp.exp(ac_cum), s_prevs
    )
    y = y_intra + y_inter  # (B, NC, Q, H, P)
    y = y + params["d_skip"][None, None, None, :, None] * xh.astype(jnp.float32)
    y = y.reshape(b, s, d_inner).astype(x.dtype)
    y = y * jax.nn.silu(z)
    y = rmsnorm({"scale": params["norm_scale"]}, y)
    return y @ params["w_out"].astype(x.dtype)


def init_mamba2_state(batch, d_model, d_state, dtype, expand=2, head_dim=64):
    d_inner = expand * d_model
    n_heads = d_inner // head_dim
    conv_dim = d_inner + 2 * d_state
    return {
        "ssm": jnp.zeros((batch, n_heads, d_state, head_dim), jnp.float32),
        "conv": jnp.zeros((batch, CONV_K - 1, conv_dim), dtype),
    }
