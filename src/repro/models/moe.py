"""Mixture-of-Experts FFN: top-k routing, sort-based capacity dispatch.

Design notes (TPU/XLA):
* Dispatch is sort-based (GShard-style one-hot (T, E, C) tensors would be
  O(T*E*C) memory — hopeless at 32k sequences). Tokens*slots are sorted
  by expert id and scattered into an (E, C) buffer with
  ``C = ceil(T*K/E * capacity_factor)``; overflow tokens are dropped
  (standard capacity dropping) and their combine weight is zero.
* Expert weights are stacked (E, ...) so the expert dimension shards on
  the ``model`` mesh axis (expert parallelism). XLA inserts the
  all-to-all-equivalent collectives at the einsum boundaries.
* FLOPs scale with T*K*cf (active experts), not T*E — keeps the
  roofline's MODEL_FLOPS/HLO_FLOPs ratio honest.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp
import numpy as np

from repro.models.layers import _dense_init


def init_moe(key, d_model, d_ff, num_experts, dtype):
    k1, k2, k3, k4 = jax.random.split(key, 4)
    return {
        "w_router": _dense_init(k1, (d_model, num_experts), jnp.float32),
        "w_gate": _dense_init(k2, (num_experts, d_model, d_ff), dtype),
        "w_up": _dense_init(k3, (num_experts, d_model, d_ff), dtype),
        "w_down": _dense_init(k4, (num_experts, d_ff, d_model), dtype),
    }


def moe_ffn(params, x, *, num_experts, top_k, capacity_factor=1.25):
    """x: (B, S, D) -> (B, S, D). Static shapes throughout."""
    b, s, d = x.shape
    t = b * s
    e = num_experts
    k = top_k
    xf = x.reshape(t, d)

    # --- routing ---
    logits = (xf.astype(jnp.float32) @ params["w_router"]).astype(jnp.float32)
    gate_vals, expert_idx = jax.lax.top_k(logits, k)  # (T, K)
    gates = jax.nn.softmax(gate_vals, axis=-1)  # renormalized over selected

    # --- capacity-bounded placement ---
    cap = int(np.ceil(t * k / e * capacity_factor))
    e_flat = expert_idx.reshape(-1)  # (T*K,)
    tok_flat = jnp.repeat(jnp.arange(t, dtype=jnp.int32), k)
    gate_flat = gates.reshape(-1)
    order = jnp.argsort(e_flat)  # stable: ties keep token order
    e_sorted = e_flat[order]
    tok_sorted = tok_flat[order]
    gate_sorted = gate_flat[order]
    # rank of each entry within its expert bucket
    start_of = jnp.searchsorted(e_sorted, jnp.arange(e), side="left")
    rank = jnp.arange(t * k, dtype=jnp.int32) - start_of[e_sorted]
    keep = rank < cap
    slot = jnp.where(keep, e_sorted * cap + rank, e * cap)  # overflow -> trash row

    # gather tokens into (E*C + 1, D) buffer (last row = trash)
    buf = jnp.zeros((e * cap + 1, d), dtype=x.dtype)
    buf = buf.at[slot].set(xf[tok_sorted], mode="drop", unique_indices=True)
    expert_in = buf[: e * cap].reshape(e, cap, d)

    # --- expert computation (SwiGLU) ---
    g = jax.nn.silu(
        jnp.einsum("ecd,edf->ecf", expert_in, params["w_gate"].astype(x.dtype))
    )
    u = jnp.einsum("ecd,edf->ecf", expert_in, params["w_up"].astype(x.dtype))
    h = jnp.einsum("ecf,efd->ecd", g * u, params["w_down"].astype(x.dtype))
    h = h.reshape(e * cap, d)

    # --- combine back to tokens, weighted by gates ---
    vals = jnp.where(keep, gate_sorted, 0.0).astype(x.dtype)[:, None] * h[
        jnp.minimum(slot, e * cap - 1)
    ]
    out = jnp.zeros((t, d), dtype=x.dtype).at[tok_sorted].add(
        jnp.where(keep[:, None], vals, 0), mode="drop"
    )
    return out.reshape(b, s, d)


def aux_load_balance_loss(params, x, *, num_experts, top_k):
    """Switch-style auxiliary loss: E * sum_e f_e * p_e (optional)."""
    b, s, d = x.shape
    xf = x.reshape(-1, d)
    logits = (xf.astype(jnp.float32) @ params["w_router"]).astype(jnp.float32)
    probs = jax.nn.softmax(logits, axis=-1)
    _, expert_idx = jax.lax.top_k(logits, top_k)
    onehot = jax.nn.one_hot(expert_idx, num_experts, dtype=jnp.float32)
    frac = jnp.mean(jnp.sum(onehot, axis=1), axis=0)  # tokens per expert
    prob = jnp.mean(probs, axis=0)
    return num_experts * jnp.sum(frac * prob) / top_k
