"""Ops report: render a telemetry JSONL stream into markdown (§14).

``python -m repro.launch.obsreport artifacts/bench/measured_train.jsonl``

Every serving/training loop already writes its behaviour to a telemetry
JSONL (events per DESIGN.md §8, declared in ``repro.obs.schema``); this
module turns one run's stream into the page an operator actually reads:

* **overview** — event counts by type, log-line count, span coverage;
* **span waterfall** — per-span-name wall-time totals from the
  ``SpanTracer`` records (`admit`/`prefill_chunk`/`decode_chunk`/
  `dispatch`/`erasure_solve`/`replan`/...), unicode share bars;
* **request latency** — p50/p95/p99 per deadline class from
  ``request_done``, shed counts by reason from ``request_evicted``;
* **replan timeline** — every ``adapt_decision`` / ``replan`` /
  ``plan_bucket_*`` in round order, so a replan storm is legible;
* **straggler drift** — the ``round_timing`` ``scale`` series (measured
  seconds-per-unit vs the frozen calibration) as a sparkline, the §12
  "is the fleet the one we planned for" signal;
* **KV pool** — peak/final occupancy and frees from the §13 block-pool
  events;
* **metrics** — the final ``metrics_snapshot`` (counters, gauges,
  histogram percentiles);
* optionally the perf gate's per-phase XLA profile summary
  (``--profile-summary artifacts/bench/perf_gate.json``).

``--require-spans`` makes the exit status assert observability itself:
a stream with no ``span`` events means the loop ran untraced (or the
tracer was wired out), and CI should notice that, not just a human.

Stdlib-only; ``--html`` wraps the same markdown in a minimal page.
"""
from __future__ import annotations

import argparse
import html as _html
import json
import os
from collections import Counter, defaultdict

from repro.obs.schema import EVENT_SCHEMAS

__all__ = ["load_records", "render_report", "main"]

#: sparkline glyphs, low to high
_SPARKS = "▁▂▃▄▅▆▇█"
_BAR_WIDTH = 24


def load_records(path: str) -> list[dict]:
    """Parse a telemetry JSONL file (event records AND bare ``log()``
    metric lines) into dicts; blank lines are skipped."""
    out = []
    with open(path) as f:
        for line in f:
            line = line.strip()
            if line:
                out.append(json.loads(line))
    return out


def _pct(values, q: float) -> float:
    """Nearest-rank percentile (q in [0, 100]) of a non-empty list."""
    vs = sorted(values)
    idx = min(len(vs) - 1, max(0, round(q / 100.0 * (len(vs) - 1))))
    return vs[idx]


def _spark(values) -> str:
    vals = [v for v in values if v is not None]
    if not vals:
        return ""
    lo, hi = min(vals), max(vals)
    span = (hi - lo) or 1.0
    out = []
    for v in values:
        if v is None:
            out.append("·")
        else:
            out.append(_SPARKS[int((v - lo) / span * (len(_SPARKS) - 1))])
    return "".join(out)


def _bar(frac: float) -> str:
    n = int(round(max(0.0, min(frac, 1.0)) * _BAR_WIDTH))
    return "█" * n + "░" * (_BAR_WIDTH - n)


def _fmt(v) -> str:
    if v is None:
        return "-"
    if isinstance(v, bool):
        return str(v).lower()
    if isinstance(v, float):
        return f"{v:.4g}"
    return str(v)


def _table(rows, cols) -> list[str]:
    lines = ["| " + " | ".join(cols) + " |",
             "|" + "|".join("---" for _ in cols) + "|"]
    for r in rows:
        lines.append("| " + " | ".join(_fmt(r.get(c)) for c in cols) + " |")
    return lines


# ------------------------------------------------------------ sections
def _overview(events, logs) -> list[str]:
    counts = Counter(e["event"] for e in events)
    rows = []
    for name, n in counts.most_common():
        known = "yes" if name in EVENT_SCHEMAS else "**UNDECLARED**"
        rows.append({"event": f"`{name}`", "count": n, "declared": known})
    lines = ["## Overview", ""]
    lines.append(f"{len(events)} events across {len(counts)} types, "
                 f"{len(logs)} scalar log lines.")
    lines.append("")
    lines += _table(rows, ["event", "count", "declared"])
    return lines


def _span_waterfall(events) -> list[str]:
    spans = [e for e in events if e["event"] == "span"]
    if not spans:
        return ["## Span waterfall", "",
                "_No `span` events — the run was not traced "
                "(pass `--telemetry` so the loop builds a SpanTracer)._"]
    agg = defaultdict(lambda: {"n": 0, "total": 0.0, "max": 0.0,
                               "depth": 0, "parents": Counter()})
    for s in spans:
        a = agg[s["span"]]
        a["n"] += 1
        a["total"] += s["dur_s"]
        a["max"] = max(a["max"], s["dur_s"])
        a["depth"] = max(a["depth"], s.get("depth", 0))
        if s.get("parent"):
            a["parents"][s["parent"]] += 1
    # wall share against top-level span time only: nested spans (e.g.
    # dispatch inside decode_chunk) double-count wall time by design
    top_total = sum(s["dur_s"] for s in spans if s.get("depth", 0) == 0)
    rows = []
    for name, a in sorted(agg.items(), key=lambda kv: -kv[1]["total"]):
        parent = a["parents"].most_common(1)
        rows.append({
            "span": "  " * min(a["depth"], 4) + f"`{name}`",
            "count": a["n"],
            "total_s": a["total"],
            "mean_ms": a["total"] / a["n"] * 1e3,
            "max_ms": a["max"] * 1e3,
            "share": _bar(a["total"] / top_total if top_total else 0.0),
            "under": parent[0][0] if parent else "-",
        })
    lines = ["## Span waterfall", "",
             f"{len(spans)} spans, {top_total:.3f}s of top-level traced "
             f"wall time (share bars are vs that; nested spans overlap "
             f"their parents).", ""]
    lines += _table(rows, ["span", "count", "total_s", "mean_ms",
                           "max_ms", "share", "under"])
    return lines


def _latency(events) -> list[str]:
    done = [e for e in events if e["event"] == "request_done"]
    shed = [e for e in events if e["event"] == "request_evicted"]
    admitted = [e for e in events if e["event"] == "request_admitted"]
    if not (done or shed or admitted):
        return []
    lines = ["## Request latency (rounds) and shedding", ""]
    by_cls = defaultdict(list)
    for e in done:
        by_cls[e["deadline_class"]].append(e["latency"])
    rows = []
    for cls in sorted(by_cls):
        lat = by_cls[cls]
        rows.append({
            "class": f"`{cls}`", "done": len(lat),
            "p50": _pct(lat, 50), "p95": _pct(lat, 95),
            "p99": _pct(lat, 99), "max": max(lat),
        })
    if rows:
        lines += _table(rows, ["class", "done", "p50", "p95", "p99",
                               "max"])
        lines.append("")
    total = len(done) + len(shed)
    shed_by = Counter((e["reason"], e["deadline_class"]) for e in shed)
    lines.append(f"admitted {len(admitted)}, finished {len(done)}, "
                 f"shed {len(shed)}"
                 + (f" ({len(shed) / total:.0%} of outcomes)" if total
                    else "") + ".")
    if shed_by:
        lines.append("")
        lines += _table(
            [{"reason": f"`{r}`", "class": f"`{c}`", "shed": n}
             for (r, c), n in shed_by.most_common()],
            ["reason", "class", "shed"],
        )
    return lines


def _replan_timeline(events) -> list[str]:
    names = ("adapt_decision", "replan", "plan_bucket_hit",
             "plan_bucket_miss")
    recs = [e for e in events if e["event"] in names]
    if not recs:
        return []
    rows = []
    for e in recs:
        if e["event"] == "adapt_decision":
            what = ("replanned" if e["replanned"] else "held")
            detail = (f"reason={e['reason']} gain={_fmt(e.get('gain'))} "
                      f"deadline={_fmt(e.get('deadline'))}")
            rnd = e.get("round")
        elif e["event"] == "replan":
            what, rnd = "replanned (caller)", None
            detail = (f"workers={e['workers']} n={e['n']} "
                      f"deadline={_fmt(e['deadline'])}")
        else:
            hit = e["event"] == "plan_bucket_hit"
            what = "bucket hit" if hit else (
                "bucket admit" if not e["structural"] else
                "structural miss")
            rnd = None
            detail = (f"bucket={e['bucket']}/{e['buckets']} "
                      f"n={e['n']}/{e['n_cap']}")
        rows.append({"t": e.get("t"), "round": rnd,
                     "event": f"`{e['event']}`", "what": what,
                     "detail": detail})
    replans = sum(1 for r in rows if "replanned" in r["what"])
    lines = ["## Replan / decision timeline", "",
             f"{len(rows)} control events, {replans} replans.", ""]
    lines += _table(rows, ["t", "round", "event", "what", "detail"])
    return lines


def _straggler_drift(events) -> list[str]:
    timing = [e for e in events if e["event"] == "round_timing"]
    if not timing:
        return []
    timing.sort(key=lambda e: e["round"])
    scales = [e.get("scale") for e in timing]
    fed = sum(1 for e in timing if e.get("fed"))
    skipped = Counter(e["skipped"] for e in timing
                      if e.get("skipped") is not None)
    walls = [e["wall_s"] for e in timing]
    lines = ["## Straggler-estimate drift (`round_timing`)", ""]
    lines.append(f"{len(timing)} measured rounds, {fed} fed to the "
                 f"controller"
                 + (f", skipped: "
                    + ", ".join(f"{k}={n}" for k, n in skipped.items())
                    if skipped else "") + ".")
    lines.append("")
    real = [s for s in scales if s is not None]
    if real:
        lines.append(f"`scale` (measured round time / calibration unit; "
                     f"1.0 = the fleet we planned for):")
        lines.append("")
        lines.append(f"    {_spark(scales)}   "
                     f"min {min(real):.3g}  mean "
                     f"{sum(real) / len(real):.3g}  max {max(real):.3g}")
        lines.append("")
    lines.append(f"round wall time: min {min(walls):.4g}s, "
                 f"mean {sum(walls) / len(walls):.4g}s, "
                 f"max {max(walls):.4g}s.")
    return lines


def _kv_pool(events) -> list[str]:
    occ = [e for e in events if e["event"] == "blocks_in_use"]
    byt = [e for e in events if e["event"] == "kv_bytes"]
    freed = [e for e in events if e["event"] == "blocks_freed"]
    if not (occ or byt):
        return []
    lines = ["## KV block pool", ""]
    if occ:
        cap = occ[-1]["capacity"]
        peak = max(e["in_use"] for e in occ)
        lines.append(f"capacity {cap} blocks; peak in use {peak} "
                     f"({peak / cap:.0%}), final {occ[-1]['in_use']}; "
                     f"{freed[-1]['total_freed'] if freed else 0} blocks "
                     f"freed over {len(freed)} releases.")
        lines.append("")
        lines.append("    occupancy  " + _spark([e["in_use"] for e in occ]))
    if byt:
        peak_b = max(e["bytes_in_use"] for e in byt)
        lines.append("")
        lines.append(f"KV bytes: peak {peak_b / 2**20:.2f} MiB of "
                     f"{byt[-1]['bytes_total'] / 2**20:.2f} MiB "
                     f"(peak utilization "
                     f"{max(e['utilization'] for e in byt):.0%}).")
    return lines


def _metrics(events) -> list[str]:
    snaps = [e for e in events if e["event"] == "metrics_snapshot"]
    if not snaps:
        return []
    snap = snaps[-1]
    rows = []
    for m in snap["metrics"]:
        labels = ",".join(f"{k}={v}" for k, v in
                          sorted(m.get("labels", {}).items()))
        name = f"`{m['name']}" + (f"{{{labels}}}" if labels else "") + "`"
        if m["type"] == "histogram":
            rows.append({"metric": name, "type": m["type"],
                         "value": m["count"], "p50": m.get("p50"),
                         "p95": m.get("p95"), "p99": m.get("p99"),
                         "max": m.get("max")})
        else:
            rows.append({"metric": name, "type": m["type"],
                         "value": m["value"]})
    lines = ["## Metrics snapshot", ""]
    phase = snap.get("phase")
    lines.append(f"final registry dump"
                 + (f" (phase `{phase}`" +
                    (f", {snap['rounds']:.0f} rounds)" if
                     snap.get("rounds") is not None else ")")
                    if phase else "")
                 + f": {snap['size']} metrics.")
    lines.append("")
    lines += _table(rows, ["metric", "type", "value", "p50", "p95",
                           "p99", "max"])
    return lines


def _profile(summary: dict) -> list[str]:
    lines = ["## XLA profile summary (per phase)", ""]
    rows = [{"phase": f"`{p}`", "wall_ms": s["wall_us"] / 1e3,
             "ops": s["n_ops"],
             "top op": (f"`{s['ops'][0]['name'][:40]}` "
                        f"({s['ops'][0]['total_us'] / 1e3:.2f} ms)"
                        if s.get("ops") else "-")}
            for p, s in sorted(summary.items(),
                               key=lambda kv: -kv[1]["wall_us"])]
    lines += _table(rows, ["phase", "wall_ms", "ops", "top op"])
    return lines


# -------------------------------------------------------------- report
def render_report(records: list[dict], *, source: str = "",
                  profile_summary: dict | None = None) -> str:
    """The full markdown report for one telemetry stream."""
    events = [r for r in records if "event" in r]
    logs = [r for r in records if "event" not in r]
    parts = [f"# Ops report — `{source or 'telemetry'}`", ""]
    sections = [
        _overview(events, logs),
        _span_waterfall(events),
        _latency(events),
        _replan_timeline(events),
        _straggler_drift(events),
        _kv_pool(events),
        _metrics(events),
    ]
    if profile_summary:
        sections.append(_profile(profile_summary))
    for sec in sections:
        if sec:
            parts += sec + [""]
    return "\n".join(parts).rstrip() + "\n"


def _to_html(markdown: str, title: str) -> str:
    """Minimal self-contained HTML wrapper (stdlib only — the markdown
    is readable as-is in monospace; no renderer dependency)."""
    return (
        "<!doctype html><html><head><meta charset='utf-8'>"
        f"<title>{_html.escape(title)}</title>"
        "<style>body{background:#111;color:#ddd;font:14px/1.5 monospace;"
        "max-width:110ch;margin:2em auto;padding:0 1em}</style>"
        "</head><body><pre>"
        + _html.escape(markdown)
        + "</pre></body></html>"
    )


def _load_profile_summary(path: str) -> dict:
    with open(path) as f:
        doc = json.load(f)
    # accept a bare summary dict or a bench record carrying one
    return doc.get("profile_summary", doc) or {}


def main(argv=None):
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("telemetry", help="telemetry JSONL to report on")
    ap.add_argument("-o", "--out", default=None,
                    help="write the markdown here instead of stdout")
    ap.add_argument("--html", default=None, metavar="PATH",
                    help="also write a self-contained HTML page")
    ap.add_argument("--profile-summary", default=None, metavar="JSON",
                    help="bench record (perf_gate.json / "
                         "serve_throughput.json) whose profile_summary "
                         "to append")
    ap.add_argument("--require-spans", action="store_true",
                    help="exit non-zero when the stream has no span "
                         "events (the run was not traced)")
    args = ap.parse_args(argv)

    records = load_records(args.telemetry)
    summary = (_load_profile_summary(args.profile_summary)
               if args.profile_summary else None)
    report = render_report(records, source=os.path.basename(args.telemetry),
                           profile_summary=summary)
    if args.out:
        with open(args.out, "w") as f:
            f.write(report)
        print(f"wrote {args.out}")
    else:
        print(report, end="")
    if args.html:
        with open(args.html, "w") as f:
            f.write(_to_html(report, title=args.telemetry))
        print(f"wrote {args.html}")
    if args.require_spans:
        n = sum(1 for r in records if r.get("event") == "span")
        if n == 0:
            raise SystemExit(
                f"{args.telemetry}: no span events — the loop ran "
                f"untraced (--require-spans)"
            )
        print(f"span coverage: {n} spans")


if __name__ == "__main__":
    main()
