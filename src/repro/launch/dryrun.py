"""Multi-pod dry-run: lower + compile every (arch x shape x mesh) cell.

MUST be the very first two lines (jax locks the device count on first
init; smoke tests and benches must keep seeing 1 device, so this flag is
set here and ONLY here):
"""
import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=512"

import argparse  # noqa: E402
import dataclasses  # noqa: E402
import json  # noqa: E402
import re  # noqa: E402
import time  # noqa: E402

import jax  # noqa: E402
import jax.numpy as jnp  # noqa: E402
import numpy as np  # noqa: E402

from repro.configs import ARCHS, SHAPES_BY_NAME, get_arch, shapes_for  # noqa: E402
from repro.configs.base import ModelConfig, ShapeConfig  # noqa: E402
from repro.core.engine import CodedComputeEngine  # noqa: E402
from repro.core.runtime_model import ClusterSpec  # noqa: E402
from repro.core.schemes import make_scheme, scheme_names  # noqa: E402
from repro.data.pipeline import make_batch_specs  # noqa: E402
from repro.launch.mesh import make_production_mesh  # noqa: E402
from repro.models.model import Model, padded_vocab  # noqa: E402
from repro.optim import AdamWConfig, adamw_init  # noqa: E402
from repro.runtime.train_loop import make_train_step_fn  # noqa: E402
from repro.sharding import (  # noqa: E402
    make_batch_sharding,
    make_cache_sharding,
    make_param_sharding,
)

# TPU v5e hardware constants (roofline targets; see EXPERIMENTS.md).
PEAK_FLOPS = 197e12  # bf16 FLOP/s per chip
HBM_BW = 819e9  # bytes/s per chip
ICI_BW = 50e9  # bytes/s per link

_COLLECTIVE_RE = re.compile(
    r"=\s*([a-z0-9\[\],\{\} ]+?)\s+"
    r"(all-gather|all-reduce|reduce-scatter|all-to-all|collective-permute)",
)
_SHAPE_RE = re.compile(r"(\w+)\[([\d,]*)\]")

_DTYPE_BYTES = {
    "f64": 8, "f32": 4, "f16": 2, "bf16": 2, "s64": 8, "u64": 8,
    "s32": 4, "u32": 4, "s16": 2, "u16": 2, "s8": 1, "u8": 1, "pred": 1,
    "f8e4m3fn": 1, "f8e5m2": 1,
}


def _shape_bytes(type_str: str) -> int:
    """Bytes of an HLO result type like 'bf16[8,128]{1,0}' (or tuples)."""
    total = 0
    for dt, dims in _SHAPE_RE.findall(type_str):
        if dt not in _DTYPE_BYTES:
            continue
        n = 1
        for d in dims.split(","):
            if d:
                n *= int(d)
        total += n * _DTYPE_BYTES[dt]
    return total


def collective_bytes(hlo_text: str) -> dict:
    """Per-device bytes moved through collectives, by op kind.

    Shapes in the partitioned module are per-device; the RESULT size of
    each collective is used (for all-gather this upper-bounds the operand
    by the axis size — conservative in the right direction for a
    bandwidth bound).
    """
    out: dict = {"all-gather": 0, "all-reduce": 0, "reduce-scatter": 0,
                 "all-to-all": 0, "collective-permute": 0, "count": 0}
    for m in _COLLECTIVE_RE.finditer(hlo_text):
        out[m.group(2)] += _shape_bytes(m.group(1))
        out["count"] += 1
    out["total"] = sum(v for k, v in out.items() if k not in ("count", "total"))
    return out


def analytic_inner_costs(config: ModelConfig, shape: ShapeConfig) -> dict:
    """Analytic FLOPs/bytes of INNER scanned loops (counted once by XLA).

    HLO cost analysis counts a while-loop body once; layer stacking is
    fixed by unrolling/delta-compiles, but the flash-attention q/kv block
    scans, the Mamba2 chunk scan and the xLSTM time scan remain while
    loops inside a single layer. Their work is added analytically:

    * attention:  4*B*H*Sq*Skv*hd fwd (scores + AV, both sides of the
      softmax); x3 for train (backward ~2x fwd) + x1 remat recompute.
      Baseline computes masked causal blocks, so Skv is NOT halved.
      bytes: flash streams K,V once per q block: nq * Skv * KV * hd * 2.
    * mamba2: 2*B*S*(Q*d_inner + Q*N + 2*N*d_inner) fwd per layer.
    * xlstm: mLSTM 4*B*S*d_in*hd + sLSTM 8*B*S*d*hd fwd per layer.

    Decode cells have no inner scans (single-token attention is a plain
    einsum over the cache) -> zero correction.
    """
    c = config
    b, s = shape.global_batch, shape.seq_len
    if shape.kind == "decode":
        return {"flops": 0.0, "bytes": 0.0}
    train_mult = 4.0 if shape.kind == "train" else 1.0  # fwd+remat+~2x bwd
    bytes_dt = 2  # bf16 compute
    flops = 0.0
    byts = 0.0
    hd = c.resolved_head_dim
    if c.family in ("dense", "vlm", "moe", "audio"):
        n_attn_layers = c.num_layers + (
            c.num_encoder_layers if c.family == "audio" else 0
        )
        sq = s + (c.num_image_tokens if c.family == "vlm" else 0)
        skv_eff = min(c.sliding_window or sq, sq)
        if c.causal_block_skip:  # lower-triangular iteration: ~half
            skv_eff = skv_eff / 2.0 + min(c.attn_kv_block, sq) / 2.0
        nq = max(sq // min(c.attn_q_block, sq), 1)
        flops += n_attn_layers * 4.0 * b * c.num_heads * sq * skv_eff * hd
        byts += (n_attn_layers * nq * skv_eff * c.num_kv_heads * hd
                 * 2 * bytes_dt * b)
        if c.family == "audio":  # cross-attention to encoder frames
            flops += c.num_layers * 4.0 * b * c.num_heads * s * c.encoder_seq * hd
    if c.family == "hybrid":
        d_inner = c.mamba_expand * c.d_model
        q = c.mamba_chunk
        flops += c.num_layers * 2.0 * b * s * (
            q * d_inner + q * c.ssm_state + 2 * c.ssm_state * d_inner
        )
        n_inv = -(-c.num_layers // max(c.attn_every, 1))
        flops += n_inv * 4.0 * b * c.num_heads * s * s * hd
        byts += n_inv * (s // min(c.attn_q_block, s)) * s * c.num_kv_heads * hd \
            * 2 * bytes_dt * b
    if c.family == "ssm":  # xLSTM time scans
        d_in = int(c.d_model * c.proj_factor)
        hd_x = d_in // c.num_heads
        n_s = sum(
            1 for i in range(c.num_layers)
            if c.slstm_every and (i + 1) % c.slstm_every == 0
        )
        n_m = c.num_layers - n_s
        flops += n_m * 4.0 * b * s * d_in * hd_x
        flops += n_s * 8.0 * b * s * c.d_model * (c.d_model // c.num_heads)
    return {"flops": flops * train_mult, "bytes": byts * train_mult}


def coded_head_record(config: ModelConfig, cluster: ClusterSpec, *,
                      scheme="optimal", block_rows: int = 256) -> dict:
    """Closed-form coded-LM-head deployment stats for one arch (no compile).

    Uses the same ``CodedComputeEngine`` path the serving loop deploys:
    kb vocab blocks of ``block_rows`` rows (ceil, matching CodedLMHead),
    MDS-coded over the cluster under the requested registered scheme
    (name or AllocationScheme object).
    """
    kb = -(-padded_vocab(config.vocab_size) // block_rows)
    eng = CodedComputeEngine(cluster, kb, scheme)
    return {
        "scheme": eng.plan.scheme,
        "block_rows": block_rows,
        "kb": kb,
        "nb": eng.plan.n,
        "rate": eng.plan.rate,
        "workers": eng.plan.num_workers,
        "max_blocks_per_worker": eng.plan.max_load,
        "t_star": eng.t_star,
        "deadline": eng.deadline(),
    }


def _parse_cluster(groups: str, bandwidth: float | None = None) -> ClusterSpec:
    """'6:2.0,6:0.5[:bw]' -> ClusterSpec (same syntax as launch/serve.py)."""
    return ClusterSpec.parse(groups, bandwidth)


def model_flops(config: ModelConfig, shape: ShapeConfig) -> float:
    """MODEL_FLOPS = 6 N D (train) / 2 N_active per token (decode)."""
    m = Model(config)
    n_active = m.active_param_count()
    if shape.kind == "train":
        tokens = shape.global_batch * shape.seq_len
        return 6.0 * n_active * tokens
    if shape.kind == "prefill":
        tokens = shape.global_batch * shape.seq_len
        return 2.0 * n_active * tokens
    return 2.0 * n_active * shape.global_batch  # decode: one token/seq


def _abstract_train_inputs(model: Model, shape: ShapeConfig, mesh,
                           strategy: str = "2d"):
    params_s = jax.eval_shape(model.init_params, jax.random.PRNGKey(0))
    opt_cfg = AdamWConfig(
        moment_dtype="bfloat16" if model.param_count() > 5e10 else "float32"
    )
    opt_s = jax.eval_shape(lambda p: adamw_init(opt_cfg, p), params_s)
    batch_s = make_batch_specs(model.config, shape)
    include_model = strategy == "replicated"
    shardings = (
        make_param_sharding(mesh, params_s, strategy=strategy),
        make_param_sharding(mesh, opt_s, strategy=strategy),
        make_batch_sharding(mesh, batch_s, include_model=include_model),
    )
    return (params_s, opt_s, batch_s), shardings, opt_cfg


def _abstract_prefill_inputs(model: Model, shape: ShapeConfig, mesh,
                             strategy: str = "2d"):
    """Prefill = full forward over (B, S) producing logits."""
    c = model.config
    params_s = jax.eval_shape(model.init_params, jax.random.PRNGKey(0))
    b, s = shape.global_batch, shape.seq_len
    tok_s = jax.ShapeDtypeStruct((b, s), jnp.int32)
    extras_s = None
    if c.family == "vlm":
        extras_s = {
            "image_embeds": jax.ShapeDtypeStruct(
                (b, c.num_image_tokens, c.d_model), c.cdtype
            )
        }
    if c.family == "audio":
        extras_s = {
            "frames": jax.ShapeDtypeStruct((b, c.encoder_seq, c.d_model), c.cdtype)
        }
    batch_tree = {"tokens": tok_s}
    if extras_s:
        batch_tree["extras"] = extras_s
    if strategy == "dp_seq":
        # context parallelism: params replicated, batch on data axes and
        # SEQUENCE on the model axis — tiny-model long-context prefill
        # keeps full 256-way work partitioning with only k/v gathers.
        from jax.sharding import NamedSharding, PartitionSpec as P

        def seq_shard(path, leaf):
            if len(leaf.shape) == 2:  # (B, S) tokens
                return NamedSharding(mesh, P("data", "model"))
            return NamedSharding(mesh, P("data", *([None] * (len(leaf.shape) - 1))))

        shardings = (
            make_param_sharding(mesh, params_s, strategy="replicated"),
            jax.tree_util.tree_map_with_path(seq_shard, batch_tree),
        )
        return (params_s, batch_tree), shardings
    shardings = (
        make_param_sharding(mesh, params_s, strategy=strategy),
        make_batch_sharding(mesh, batch_tree,
                            include_model=strategy == "replicated"),
    )
    return (params_s, batch_tree), shardings


def _abstract_decode_inputs(model: Model, shape: ShapeConfig, mesh):
    c = model.config
    params_s = jax.eval_shape(model.init_params, jax.random.PRNGKey(0))
    b = shape.global_batch
    extras = None
    if c.family == "audio":
        extras = {
            "enc_out": jax.ShapeDtypeStruct((b, c.encoder_seq, c.d_model), c.cdtype)
        }
    if extras is None:
        cache_s = jax.eval_shape(lambda: model.init_cache(b, shape.seq_len))
    else:
        cache_s = jax.eval_shape(
            lambda e: model.init_cache(b, shape.seq_len, e), extras
        )
    tok_s = jax.ShapeDtypeStruct((b,), jnp.int32)
    pos_s = jax.ShapeDtypeStruct((), jnp.int32)
    from jax.sharding import NamedSharding, PartitionSpec as P

    shardings = (
        make_param_sharding(mesh, params_s),
        make_cache_sharding(mesh, cache_s),
        NamedSharding(mesh, P(None)),
        NamedSharding(mesh, P()),
    )
    return (params_s, cache_s, tok_s, pos_s), shardings


def dryrun_cell(config: ModelConfig, shape: ShapeConfig, *, multi_pod: bool,
                verbose: bool = True, with_hlo: bool = False,
                scan_layers: bool = False, donate_cache: bool = False,
                param_strategy: str = "2d") -> dict:
    """Lower + compile one cell; return the roofline record.

    scan_layers=False (default): layers unrolled so cost_analysis and the
    collective-bytes parse see every layer (XLA counts a while body once).
    donate_cache: alias the decode KV cache in/out (in-place update).
    param_strategy: "2d" (FSDP+TP) or "replicated" (pure DP) — §Perf.
    """
    config = dataclasses.replace(config, scan_layers=scan_layers)
    mesh = make_production_mesh(multi_pod=multi_pod)
    chips = int(np.prod(list(mesh.shape.values())))
    model = Model(config)
    # perf_counter, not time.time(): wall clock is not monotonic (an NTP
    # step mid-compile would report a negative/garbage compile_s)
    t0 = time.perf_counter()

    if shape.kind == "train":
        (p_s, o_s, b_s), shardings, opt_cfg = _abstract_train_inputs(
            model, shape, mesh, strategy=param_strategy)
        step = make_train_step_fn(model, opt_cfg)
        jitted = jax.jit(step, in_shardings=shardings)
        with mesh:
            lowered = jitted.lower(p_s, o_s, b_s)
    elif shape.kind == "prefill":
        (p_s, b_s), shardings = _abstract_prefill_inputs(
            model, shape, mesh, strategy=param_strategy)

        def prefill_step(params, batch):
            return model.lm_logits(params, batch["tokens"], batch.get("extras"))

        jitted = jax.jit(prefill_step, in_shardings=shardings)
        with mesh:
            lowered = jitted.lower(p_s, b_s)
    else:
        (p_s, c_s, t_s, pos_s), shardings = _abstract_decode_inputs(model, shape, mesh)
        donate = {"donate_argnums": (1,)} if donate_cache else {}
        jitted = jax.jit(model.decode_step, in_shardings=shardings, **donate)
        with mesh:
            lowered = jitted.lower(p_s, c_s, t_s, pos_s)

    compiled = lowered.compile()
    compile_s = time.perf_counter() - t0

    cost = compiled.cost_analysis() or {}
    if isinstance(cost, (list, tuple)):  # jax<=0.4.x wraps the dict in a list
        cost = cost[0] if cost else {}
    try:
        mem = compiled.memory_analysis()
        mem_rec = {
            "argument_bytes": getattr(mem, "argument_size_in_bytes", None),
            "output_bytes": getattr(mem, "output_size_in_bytes", None),
            "temp_bytes": getattr(mem, "temp_size_in_bytes", None),
            "generated_code_bytes": getattr(mem, "generated_code_size_in_bytes", None),
        }
    except Exception as e:  # CPU backend may not implement it
        mem_rec = {"error": str(e)}

    hlo = compiled.as_text()
    coll = collective_bytes(hlo)

    flops_dev = float(cost.get("flops", 0.0))
    bytes_dev = float(cost.get("bytes accessed", 0.0))
    mflops = model_flops(config, shape)
    inner = analytic_inner_costs(config, shape)
    flops_c = flops_dev + inner["flops"] / chips
    bytes_c = bytes_dev + inner["bytes"] / chips

    record = {
        "arch": config.name,
        "shape": shape.name,
        "kind": shape.kind,
        "scan_layers": scan_layers,
        "mesh": "multi_pod_2x16x16" if multi_pod else "single_pod_16x16",
        "chips": chips,
        "compile_seconds": round(compile_s, 1),
        "hlo_flops_per_device": flops_dev,
        "hlo_bytes_per_device": bytes_dev,
        "inner_scan_correction": inner,
        "flops_per_device_corrected": flops_c,
        "bytes_per_device_corrected": bytes_c,
        "collective_bytes_per_device": coll,
        "memory_analysis": mem_rec,
        "model_flops": mflops,
        # --- roofline terms (seconds; inner-scan-corrected) ---
        "t_compute": flops_c / PEAK_FLOPS,
        "t_memory": bytes_c / HBM_BW,
        "t_collective": coll["total"] / ICI_BW,
        "useful_flops_ratio": mflops / max(flops_c * chips, 1.0),
    }
    terms = {k: record[k] for k in ("t_compute", "t_memory", "t_collective")}
    record["bottleneck"] = max(terms, key=terms.get)
    record["roofline_fraction"] = (
        record["t_compute"] / max(max(terms.values()), 1e-30)
    )
    if with_hlo:
        record["hlo_text"] = hlo
    if verbose:
        print(
            f"[dryrun] {config.name:24s} {shape.name:12s} {record['mesh']:20s} "
            f"compile={compile_s:6.1f}s flops/dev={flops_dev:.3e} "
            f"bytes/dev={bytes_dev:.3e} coll/dev={coll['total']:.3e} "
            f"bottleneck={record['bottleneck']}"
        )
    return record


def _raw_costs(config: ModelConfig, shape: ShapeConfig, *, multi_pod: bool,
               **cell_kwargs):
    """(flops, bytes, coll_total, compile_s) per device for one compile."""
    rec = dryrun_cell(config, shape, multi_pod=multi_pod, verbose=False,
                      scan_layers=config.scan_layers, **cell_kwargs)
    return (
        rec["hlo_flops_per_device"],
        rec["hlo_bytes_per_device"],
        rec["collective_bytes_per_device"]["total"],
        rec["compile_seconds"],
        rec,
    )


def roofline_cell(config: ModelConfig, shape: ShapeConfig, *,
                  multi_pod: bool = False, verbose: bool = True,
                  **cell_kwargs) -> dict:
    """Roofline record via the per-layer finite-difference method.

    XLA counts a scanned (while-loop) body once and fully-unrolled
    compiles of the 40-64-layer archs are prohibitive on this CPU host,
    so per-layer costs are measured EXACTLY by compiling the same
    (shape x mesh x sharding) cell at 1 and 2 layers (python-unrolled)
    and differencing:  total = cost(1L) + (num_layers - 1) * delta.
    Small archs (ssm/audio: layers are python loops anyway) compile
    fully unrolled directly. Validated against full unrolls of yi-9b
    prefill and xlstm train (EXPERIMENTS.md §Roofline methodology).
    """
    c = dataclasses.replace(config, scan_layers=False)
    small_families = ("ssm", "audio")
    if c.family in small_families or c.num_layers <= 4:
        rec = dryrun_cell(c, shape, multi_pod=multi_pod, verbose=False,
                          scan_layers=False, **cell_kwargs)
        rec["method"] = "full_unroll"
        if verbose:
            _print_roofline(rec)
        return rec

    if c.family == "hybrid":
        no_attn = 10 ** 6
        base = _raw_costs(
            dataclasses.replace(c, num_layers=1, attn_every=no_attn), shape,
            multi_pod=multi_pod, **cell_kwargs)
        two = _raw_costs(
            dataclasses.replace(c, num_layers=2, attn_every=no_attn), shape,
            multi_pod=multi_pod, **cell_kwargs)
        attn1 = _raw_costs(
            dataclasses.replace(c, num_layers=1, attn_every=1), shape,
            multi_pod=multi_pod, **cell_kwargs)
        n_inv = -(-c.num_layers // max(c.attn_every, 1))
        d_layer = tuple(two[i] - base[i] for i in range(3))
        d_attn = tuple(attn1[i] - base[i] for i in range(3))
        flops, byts, coll = (
            base[i] + (c.num_layers - 1) * d_layer[i] + n_inv * d_attn[i]
            for i in range(3)
        )
        compile_s = base[3] + two[3] + attn1[3]
        proto = base[4]
    else:  # dense / moe / vlm — homogeneous stacks
        # MoE modules show +-1.5e12 FLOP jitter between compiles (XLA
        # fusion decisions around the sort-based dispatch), which swamps
        # a 1-layer delta; widen the spacing so the jitter amortizes.
        l_lo, l_hi = (2, 8) if c.family == "moe" else (1, 2)
        base = _raw_costs(dataclasses.replace(c, num_layers=l_lo), shape,
                          multi_pod=multi_pod, **cell_kwargs)
        hi = _raw_costs(dataclasses.replace(c, num_layers=l_hi), shape,
                        multi_pod=multi_pod, **cell_kwargs)
        span = l_hi - l_lo
        d_layer = tuple((hi[i] - base[i]) / span for i in range(3))
        flops, byts, coll = (
            max(base[i] + (c.num_layers - l_lo) * d_layer[i], base[i])
            for i in range(3)
        )
        compile_s = base[3] + hi[3]
        proto = base[4]

    mflops = model_flops(config, shape)
    chips = proto["chips"]
    inner = analytic_inner_costs(config, shape)
    flops_c = flops + inner["flops"] / chips
    bytes_c = byts + inner["bytes"] / chips
    record = dict(proto)
    record.update(
        arch=config.name,
        method="layer_delta",
        compile_seconds=round(compile_s, 1),
        hlo_flops_per_device=flops,
        hlo_bytes_per_device=byts,
        inner_scan_correction=inner,
        flops_per_device_corrected=flops_c,
        bytes_per_device_corrected=bytes_c,
        collective_bytes_per_device={"total": coll},
        model_flops=mflops,
        t_compute=flops_c / PEAK_FLOPS,
        t_memory=bytes_c / HBM_BW,
        t_collective=coll / ICI_BW,
        useful_flops_ratio=mflops / max(flops_c * chips, 1.0),
    )
    terms = {k: record[k] for k in ("t_compute", "t_memory", "t_collective")}
    record["bottleneck"] = max(terms, key=terms.get)
    record["roofline_fraction"] = record["t_compute"] / max(
        max(terms.values()), 1e-30
    )
    if verbose:
        _print_roofline(record)
    return record


def _print_roofline(r: dict):
    print(
        f"[roofline] {r['arch']:24s} {r['shape']:12s} {r['mesh']:20s} "
        f"method={r.get('method', '?'):12s} compile={r['compile_seconds']:6.1f}s "
        f"flops/dev={r['hlo_flops_per_device']:.3e} "
        f"bytes/dev={r['hlo_bytes_per_device']:.3e} "
        f"coll/dev={r['collective_bytes_per_device']['total']:.3e} "
        f"bottleneck={r['bottleneck']} frac={r['roofline_fraction']:.3f}"
    )


def main():
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--arch", default=None, help="one arch id (default: all)")
    ap.add_argument("--shape", default=None, help="one shape name (default: all)")
    ap.add_argument("--mesh", choices=("single", "multi", "both"), default="both")
    ap.add_argument("--out", default="artifacts/dryrun")
    ap.add_argument("--scan-layers", action="store_true",
                    help="compile with scanned layers (fast compile; use for "
                         "the multi-pod compilability proof — roofline terms "
                         "then undercount per-layer work)")
    ap.add_argument("--roofline", action="store_true",
                    help="use the per-layer finite-difference method for "
                         "accurate roofline terms (see roofline_cell)")
    ap.add_argument("--coded-groups", default=None,
                    help="N:mu worker groups; attaches the coded-LM-head "
                         "deployment record (CodedComputeEngine) to every "
                         "decode cell")
    ap.add_argument("--coded-scheme", default="optimal",
                    choices=scheme_names(),
                    help="registered allocation scheme for --coded-groups")
    ap.add_argument("--coded-n", type=float, default=None,
                    help="code size n for --coded-scheme uniform_n")
    ap.add_argument("--coded-r", type=int, default=None,
                    help="completion count r for --coded-scheme uniform_r")
    ap.add_argument("--coded-bandwidth", type=float, default=None,
                    help="link bandwidth for --coded-groups entries without "
                         "an explicit N:mu:bw value (default: infinite)")
    ap.add_argument("--coded-upload", type=float, default=None,
                    help="fixed transfer cost for --coded-scheme comm_aware "
                         "/ comm_uniform")
    ap.add_argument("--coded-download", type=float, default=None,
                    help="per-row transfer cost for --coded-scheme "
                         "comm_aware / comm_uniform")
    args = ap.parse_args()
    # resolve cluster + scheme up front so bad params fail before any compile
    coded_cluster = (
        _parse_cluster(args.coded_groups, args.coded_bandwidth)
        if args.coded_groups
        else None
    )
    coded_scheme = (
        make_scheme(args.coded_scheme, n=args.coded_n, r=args.coded_r,
                    upload=args.coded_upload, download=args.coded_download)
        if coded_cluster is not None
        else None
    )

    os.makedirs(args.out, exist_ok=True)
    archs = [get_arch(args.arch)] if args.arch else list(ARCHS.values())
    meshes = {"single": (False,), "multi": (True,), "both": (False, True)}[args.mesh]

    failures = []
    for cfg in archs:
        shapes = shapes_for(cfg)
        if args.shape:
            shapes = [s for s in shapes if s.name == args.shape]
            if not shapes and args.shape in SHAPES_BY_NAME:
                print(f"[dryrun] {cfg.name}: shape {args.shape} SKIPPED "
                      f"(not applicable; see DESIGN.md)")
        for shape in shapes:
            for mp in meshes:
                tag = f"{cfg.name}_{shape.name}_{'multi' if mp else 'single'}"
                if args.scan_layers:
                    tag += "_scanned"
                try:
                    if args.roofline:
                        rec = roofline_cell(cfg, shape, multi_pod=mp)
                    else:
                        rec = dryrun_cell(cfg, shape, multi_pod=mp,
                                          scan_layers=args.scan_layers)
                    if coded_cluster is not None and shape.kind == "decode":
                        rec["coded_lm_head"] = coded_head_record(
                            cfg, coded_cluster, scheme=coded_scheme
                        )
                    with open(os.path.join(args.out, tag + ".json"), "w") as f:
                        json.dump(rec, f, indent=1)
                except Exception as e:
                    failures.append((tag, repr(e)))
                    print(f"[dryrun] FAIL {tag}: {e!r}")
    if failures:
        print(f"\n{len(failures)} FAILURES:")
        for tag, err in failures:
            print(f"  {tag}: {err[:200]}")
        raise SystemExit(1)
    print("\nAll dry-run cells compiled successfully.")


if __name__ == "__main__":
    main()
