"""Serving driver: batched greedy decode with the coded LM head.

``python -m repro.launch.serve --arch qwen3-0.6b --reduced --coded``

Demonstrates the paper's technique live: the unembedding matvec is
MDS-coded over a heterogeneous worker fleet (simulated shifted-exp
runtimes); stragglers that miss the deadline (T* x safety) are erasures
and the logits are recovered from the surviving coded block-products.
"""
from __future__ import annotations

import argparse
import time

import jax
import jax.numpy as jnp

from repro.configs import get_arch
from repro.core.runtime_model import ClusterSpec
from repro.core.schemes import make_scheme, scheme_names
from repro.data.pipeline import make_extras
from repro.models.model import Model
from repro.runtime.compile_cache import enable_persistent_cache
from repro.runtime.serve_loop import ServeConfig, Server
from repro.serve import make_workload, workload_names
from repro.sim import make_scenario, scenario_names


def main(argv=None):
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--arch", required=True)
    ap.add_argument("--reduced", action="store_true")
    ap.add_argument("--batch", type=int, default=4)
    ap.add_argument("--prompt-len", type=int, default=16)
    ap.add_argument("--max-new", type=int, default=16)
    ap.add_argument("--coded", action="store_true",
                    help="serve logits through the coded LM head")
    ap.add_argument("--groups", default="6:2.0,6:0.5",
                    help="heterogeneous fleet as N:mu or N:mu:bandwidth "
                         "groups (bandwidth feeds the comm-delay schemes)")
    ap.add_argument("--bandwidth", type=float, default=None,
                    help="link bandwidth for groups without an explicit "
                         "per-group value (default: infinite = comm-free)")
    ap.add_argument("--scheme", default="optimal", choices=scheme_names(),
                    help="registered allocation scheme for the coded head")
    ap.add_argument("--scheme-n", type=float, default=None,
                    help="code size n for --scheme uniform_n / comm_uniform")
    ap.add_argument("--scheme-r", type=int, default=None,
                    help="completion count r for --scheme uniform_r")
    ap.add_argument("--comm-upload", type=float, default=None,
                    help="fixed per-round transfer cost for --scheme "
                         "comm_aware / comm_uniform (divided by bandwidth)")
    ap.add_argument("--comm-download", type=float, default=None,
                    help="per-row transfer cost for --scheme comm_aware / "
                         "comm_uniform (divided by bandwidth)")
    ap.add_argument("--use-kernel", action="store_true",
                    help="route the coded block mix through the Pallas "
                         "coded_matvec kernel")
    ap.add_argument("--legacy-decode", action="store_true",
                    help="per-token host loop with numpy decode (the path "
                         "the jit pipeline replaces; for A/B timing)")
    ap.add_argument("--scenario", default=None, choices=scenario_names(),
                    help="cluster-dynamics scenario: serve rounds against "
                         "a drifting TRUE fleet (requires --coded)")
    ap.add_argument("--adapt-every", type=int, default=None,
                    help="closed-loop cadence: consume straggler estimates "
                         "and maybe replan the coded head every R serve "
                         "rounds (requires --scenario)")
    ap.add_argument("--adapt-threshold", type=float, default=None,
                    help="hysteresis: replan only when the estimated "
                         "latency improves by this fraction (default 0.05)")
    ap.add_argument("--bucket-quantum", type=int, default=None,
                    help="quantize the coded head's integer loads to this "
                         "multiple and replan via an in-program bucket "
                         "switch: replans within the admitted capacity "
                         "retrace nothing (DESIGN.md §11)")
    ap.add_argument("--rounds", type=int, default=None,
                    help="serve rounds to run under --scenario (default: "
                         "min(scenario horizon, 24))")
    ap.add_argument("--trace", default=None, choices=workload_names(),
                    help="continuous-batching mode: replay this seeded "
                         "request workload through Server.serve instead "
                         "of one fixed-batch generate")
    ap.add_argument("--arrival-rate", type=float, default=None,
                    help="requests per decode round for --trace workloads "
                         "that accept it (poisson, chat)")
    ap.add_argument("--num-requests", type=int, default=None,
                    help="trace length for --trace (default: the "
                         "workload preset)")
    ap.add_argument("--slots", default="4",
                    help="in-flight stream slots for --trace; 'auto' asks "
                         "the AdaptiveController for a width from measured "
                         "round latency (requires --coded)")
    ap.add_argument("--dense-kv", action="store_true",
                    help="serve --trace from the dense per-slot KV cache "
                         "(the parity oracle) instead of the paged block "
                         "pool (DESIGN.md §13)")
    ap.add_argument("--block-len", type=int, default=None,
                    help="tokens per physical KV block for paged --trace "
                         "serving (default 16)")
    ap.add_argument("--num-blocks", type=int, default=None,
                    help="KV block pool size for paged --trace serving "
                         "(default: sized so the trace never exhausts it; "
                         "smaller pools shed on memory pressure)")
    ap.add_argument("--prefill-chunk", type=int, default=None,
                    help="admission chunk width for paged --trace serving: "
                         "longer prompts prefill across several admit "
                         "rounds of the same compiled program")
    ap.add_argument("--admission-threshold", type=float, default=1.0,
                    help="admission-control strictness for --trace "
                         "(higher sheds earlier; deadline budgets are "
                         "divided by it)")
    ap.add_argument("--trace-seed", type=int, default=0,
                    help="workload trace seed for --trace")
    ap.add_argument("--measure-times", action="store_true",
                    help="measured-reality loop (DESIGN.md §12): time "
                         "each compiled dispatch with a RoundClock and "
                         "adapt from wall-clock observations instead of "
                         "simulated ground truth (requires --coded)")
    ap.add_argument("--telemetry", default=None,
                    help="JSONL telemetry sink (round_timing / "
                         "adapt_decision / request events; feed it to "
                         "repro.launch.obsreport for the ops report)")
    ap.add_argument("--chrome-trace", default=None, metavar="PATH",
                    help="export the run's spans as Chrome trace_event "
                         "JSON (open in Perfetto / chrome://tracing)")
    args = ap.parse_args(argv)
    if args.trace is not None and args.scenario is not None:
        raise SystemExit("--trace and --scenario are separate serving "
                         "modes; pick one")
    if args.trace is not None and args.legacy_decode:
        raise SystemExit("--trace requires the jit pipeline "
                         "(continuous batching splices into compiled "
                         "programs); drop --legacy-decode")
    if args.scenario is not None and not args.coded:
        raise SystemExit("--scenario requires --coded (a fleet to perturb)")
    if args.adapt_every is not None and args.scenario is None:
        raise SystemExit("--adapt-every requires --scenario (closed-loop "
                         "serving is driven by a scenario trace)")
    if args.measure_times and not args.coded:
        raise SystemExit("--measure-times requires --coded (round times "
                         "are decomposed over the coded fleet)")
    if args.measure_times and args.legacy_decode:
        raise SystemExit("--measure-times times compiled dispatches; "
                         "drop --legacy-decode")
    if args.slots == "auto":
        if not args.coded:
            raise SystemExit("--slots auto derives the width from the coded "
                             "fleet's round latency; requires --coded")
    else:
        try:
            args.slots = int(args.slots)
        except ValueError:
            raise SystemExit(f"--slots must be an int or 'auto', "
                             f"got {args.slots!r}")

    # cold-start compile reuse: every program this process builds
    # (bucket branches included) persists to the on-disk JAX cache
    enable_persistent_cache()

    config = get_arch(args.arch)
    if args.reduced:
        config = config.reduced()
    model = Model(config)
    params = model.init_params(jax.random.PRNGKey(0))

    cluster = None
    scheme = make_scheme(
        args.scheme, n=args.scheme_n, r=args.scheme_r,
        upload=args.comm_upload, download=args.comm_download,
    )
    if args.coded:
        cluster = ClusterSpec.parse(args.groups, args.bandwidth)
    server = Server(
        model, params, cluster,
        ServeConfig(max_decode_steps=args.max_new, scheme=scheme,
                    use_kernel=args.use_kernel,
                    jit_pipeline=not args.legacy_decode,
                    bucket_quantum=args.bucket_quantum),
    )
    if server.coded_head is not None:
        h = server.coded_head
        print(f"coded LM head [{h.plan.scheme}]: "
              f"kb={h.kb} blocks x {h.block_rows} rows, "
              f"(n,k)=({h.nb},{h.kb}) rate={h.kb/h.nb:.3f}, "
              f"loads/worker={h.plan.loads_per_worker.tolist()}, "
              f"deadline={h.deadline:.4f}")

    if args.trace is not None:
        _serve_trace(server, args, config)
        return
    prompts = jax.random.randint(
        jax.random.PRNGKey(1), (args.batch, args.prompt_len), 0, config.vocab_size
    ).astype(jnp.int32)
    extras = make_extras(config, args.batch)
    if config.family == "audio":
        extras = {"enc_out": model.encode(params, extras["frames"])}
    if args.scenario is not None:
        _serve_scenario(server, prompts, extras, args, cluster)
        return
    tracer = _attach_tracer(server, args)
    t0 = time.perf_counter()
    out = server.generate(prompts, args.max_new, extras=extras)
    dt = time.perf_counter() - t0
    print(f"generated {out.shape} in {dt:.2f}s "
          f"({args.batch * args.max_new / dt:.1f} tok/s)")
    print("sample:", out[0, -args.max_new:].tolist())
    _export_chrome(tracer, args)


def _attach_tracer(server, args, telemetry=None):
    """A ``SpanTracer`` on the server (and its coded executor) when
    ``--chrome-trace`` asks for one; mirrors spans to ``telemetry``
    when the run has a JSONL sink too."""
    if args.chrome_trace is None:
        return None
    from repro.obs.trace import SpanTracer

    tracer = SpanTracer(telemetry)
    server.tracer = tracer
    if server.coded_head is not None:
        server.coded_head.executor.tracer = tracer
    return tracer


def _export_chrome(tracer, args):
    if tracer is not None:
        path = tracer.export_chrome(args.chrome_trace)
        print(f"chrome trace: {path} ({len(tracer.spans)} spans)")


def _serve_trace(server, args, config):
    """Continuous-batching mode: replay a seeded workload end to end.

    Requests are admitted into ``--slots`` in-flight stream slots by the
    ``SlotScheduler`` (deadline-class priority, load shedding at
    ``--admission-threshold``); per-request latency is reported in
    virtual rounds (1 decode step = 1 round, 1 batched prefill = 1
    round), throughput in wall-clock tokens/s.
    """
    from repro.runtime.telemetry import Telemetry

    wl = make_workload(
        args.trace, arrival_rate=args.arrival_rate,
        num_requests=args.num_requests, vocab=config.vocab_size,
    )
    trace = wl.trace(seed=args.trace_seed)
    slots = args.slots
    if slots == "auto":
        from repro.runtime.control import AdaptiveController

        # width from measured reality: the controller's coverage-latency
        # view of the fleet (tracker estimates once RoundClock feeds
        # arrive; the planned latency before any) scales a base of 4
        controller = AdaptiveController(server.coded_head.executor)
        slots = controller.recommend_slots(base=4)
        print(f"slots auto -> {slots} "
              f"(coverage latency {controller.coverage_latency():.4f})")
    with Telemetry(args.telemetry) as tel:
        tracer = _attach_tracer(server, args, telemetry=tel)
        clock = None
        if args.measure_times:
            from repro.runtime.timing import RoundClock

            clock = RoundClock(server.coded_head.executor, telemetry=tel)
        rep = server.serve(
            trace, slots=slots,
            admission_threshold=args.admission_threshold,
            telemetry=tel, clock=clock, tracer=tracer,
            paged=not args.dense_kv, block_len=args.block_len,
            num_blocks=args.num_blocks, prefill_chunk=args.prefill_chunk,
        )
    _export_chrome(tracer, args)
    if clock is not None:
        unit = "-" if clock.unit_s is None else f"{clock.unit_s:.3e}"
        print(f"measured: {clock.fed}/{clock.rounds} rounds fed, "
              f"unit_s={unit}")
    lat = rep.latencies()
    print(f"workload {wl.name!r}: {len(trace)} requests "
          f"(rate={wl.arrival_rate}/round, seed={args.trace_seed})")
    print(f"served {rep.admitted} ({rep.shed} shed), {rep.tokens} tokens "
          f"in {rep.rounds:.0f} rounds "
          f"({rep.prefill_rounds} prefill + {rep.decode_rounds} decode) "
          f"/ {rep.wall_s:.2f}s = {rep.tokens_per_s:.1f} tok/s")
    if len(lat):
        import numpy as np

        print(f"latency rounds: p50={np.percentile(lat, 50):.1f} "
              f"p99={np.percentile(lat, 99):.1f}")


def _serve_scenario(server, prompts, extras, args, cluster):
    """Serve rounds against a drifting TRUE fleet, optionally closed-loop.

    Each round is one ``generate`` call whose straggler masks sample from
    the scenario's current cluster; with ``--adapt-every`` an
    ``AdaptiveController`` observes the round times and replans the
    coded head (rebuilding the compiled pipeline) when its hysteresis
    rule fires — the same controller the trainer runs (DESIGN.md §7).
    With ``--measure-times`` each generate call runs under a
    ``RoundClock`` and the controller ingests MEASURED wall-clock round
    times instead of simulated ground truth (DESIGN.md §12).
    """
    from repro.runtime.control import AdaptConfig, AdaptiveController
    from repro.runtime.telemetry import Telemetry

    # build the scenario AT the round budget so its factory anchors
    # event times/drift rates to the rounds actually served (a default
    # 120-round spec truncated to 24 rounds would never reach its events)
    rounds = args.rounds if args.rounds is not None else 24
    spec = make_scenario(args.scenario, horizon=max(rounds, 1))
    trace = spec.trace(cluster, seed=0)
    head = server.coded_head
    tel = Telemetry(args.telemetry)
    tracer = _attach_tracer(server, args, telemetry=tel)
    controller = None
    if args.adapt_every is not None:
        controller = AdaptiveController(
            head.executor,
            AdaptConfig(
                every=args.adapt_every,
                threshold=(0.05 if args.adapt_threshold is None
                           else args.adapt_threshold),
            ),
            telemetry=tel,
            on_replan=server.refresh_coded_head,
        )
    clock = None
    if args.measure_times:
        from repro.runtime.timing import RoundClock

        clock = RoundClock(head.executor, telemetry=tel)
    key = jax.random.PRNGKey(7)
    t0 = time.perf_counter()
    toks = 0
    for t in range(rounds):
        true_cluster = trace.at(t)
        server.set_true_cluster(true_cluster)
        gkey = jax.random.fold_in(key, t)
        # the observation key matches the simulated path round for round,
        # so measured and simulated runs are comparable draw by draw
        okey = jax.random.fold_in(key, 10_000 + t)
        d = None
        if clock is not None:
            timing = clock.measure(
                lambda: server.generate(
                    prompts, args.max_new, key=gkey, extras=extras
                ),
                key=okey, true_cluster=true_cluster,
            )
            out = timing.result
            if controller is not None:
                d = controller.observe_timing(timing)
        else:
            out = server.generate(
                prompts, args.max_new, key=gkey, extras=extras
            )
            if controller is not None:
                d = controller.observe_truth(okey, true_cluster)
        toks += out.shape[0] * args.max_new
        if d is not None and d.replanned:
            if clock is not None and head.executor.last_replan_structural:
                clock.discard_next()  # next round pays the retrace
            print(f"[round {t}] replanned ({d.reason}): "
                  f"deadline -> {head.deadline:.4f}, "
                  f"loads {head.plan.loads_per_worker.tolist()}")
    dt = time.perf_counter() - t0
    print(f"scenario {spec.name!r}: {rounds} rounds, {toks} tokens in "
          f"{dt:.2f}s ({toks / dt:.1f} tok/s)")
    if clock is not None:
        unit = "-" if clock.unit_s is None else f"{clock.unit_s:.3e}"
        print(f"measured: {clock.fed}/{clock.rounds} rounds fed, "
              f"unit_s={unit}")
    if controller is not None:
        replans = [d for d in controller.decisions if d.replanned]
        print(f"controller: {len(controller.decisions)} decisions, "
              f"{len(replans)} replans at rounds "
              f"{[d.round for d in replans]}")
    _export_chrome(tracer, args)
    tel.close()


if __name__ == "__main__":
    main()
