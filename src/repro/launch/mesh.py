"""Production mesh definitions.

Functions, not module-level constants, so importing this module never
touches jax device state (the dry-run sets the fake-device XLA flag
before any jax initialization; smoke tests must keep seeing 1 device).
"""
from __future__ import annotations

import jax


def make_production_mesh(*, multi_pod: bool = False):
    """16x16 = 256 chips per pod; 2 pods = 512 chips when multi_pod."""
    shape = (2, 16, 16) if multi_pod else (16, 16)
    axes = ("pod", "data", "model") if multi_pod else ("data", "model")
    return jax.make_mesh(shape, axes)


def make_local_mesh(model_axis: int = 1):
    """Whatever this host has — used by examples/tests on CPU."""
    n = len(jax.devices())
    assert n % model_axis == 0
    return jax.make_mesh((n // model_axis, model_axis), ("data", "model"))
