"""Training driver: ``python -m repro.launch.train --arch qwen3-0.6b ...``

Runs a real training loop on the local devices (the production meshes
are exercised by dryrun.py; this driver is sized for the end-to-end
example: a ~100M-param model for a few hundred steps on CPU, or a real
slice on accelerators). Supports checkpoint/restart (--resume picks up
the latest step) and heterogeneity-aware batch splitting.
"""
from __future__ import annotations

import argparse
import dataclasses

import jax

from repro.configs import get_arch
from repro.configs.base import ShapeConfig
from repro.core.runtime_model import ClusterSpec
from repro.data import SyntheticLMData
from repro.models.model import Model
from repro.optim import AdamWConfig
from repro.runtime.train_loop import (
    TrainConfig,
    Trainer,
    heterogeneous_batch_split,
)


def main():
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--arch", required=True)
    ap.add_argument("--reduced", action="store_true",
                    help="use the CPU-sized smoke variant of the arch")
    ap.add_argument("--steps", type=int, default=200)
    ap.add_argument("--seq-len", type=int, default=256)
    ap.add_argument("--batch", type=int, default=8)
    ap.add_argument("--lr", type=float, default=3e-4)
    ap.add_argument("--checkpoint-dir", default=None)
    ap.add_argument("--checkpoint-every", type=int, default=50)
    ap.add_argument("--resume", action="store_true",
                    help="resume from the latest checkpoint in --checkpoint-dir")
    ap.add_argument("--telemetry", default=None)
    ap.add_argument("--hetero-groups", default=None,
                    help="e.g. '4:2.0,4:0.5' = N:mu pairs; prints the "
                         "paper-optimal per-group batch split")
    args = ap.parse_args()

    config = get_arch(args.arch)
    if args.reduced:
        config = config.reduced()
    model = Model(config)
    shape = ShapeConfig("cli", args.seq_len, args.batch, "train")
    data = SyntheticLMData(config, shape)

    if args.hetero_groups:
        pairs = [p.split(":") for p in args.hetero_groups.split(",")]
        cluster = ClusterSpec.make(
            [int(n) for n, _ in pairs], [float(m) for _, m in pairs]
        )
        split = heterogeneous_batch_split(cluster, args.batch)
        print(f"heterogeneity-aware batch split (Theorem 2): {split.tolist()} "
              f"over groups {[(g.num_workers, g.mu) for g in cluster.groups]}")

    opt_cfg = AdamWConfig(lr=args.lr, total_steps=args.steps,
                          warmup_steps=max(args.steps // 20, 1))
    cfg = TrainConfig(
        steps=args.steps,
        checkpoint_dir=args.checkpoint_dir,
        checkpoint_every=args.checkpoint_every,
        telemetry_path=args.telemetry,
    )
    if args.checkpoint_dir and not args.resume:
        # fresh run: ignore stale checkpoints by training from step 0 only
        # if the dir is empty; otherwise demand an explicit --resume.
        from repro.checkpoint import latest_step

        last = latest_step(args.checkpoint_dir)
        if last is not None:
            raise SystemExit(
                f"{args.checkpoint_dir} already has step_{last}; "
                f"pass --resume to continue it"
            )

    print(f"training {config.name}: {model.param_count():,} params, "
          f"{len(jax.devices())} device(s)")
    trainer = Trainer(model, data, opt_cfg, cfg)
    params, _, history = trainer.run()
    if history:
        first, last = history[0], history[-1]
        print(f"loss {first['loss']:.4f} -> {last['loss']:.4f} "
              f"({cfg.steps} steps)")
    return params


if __name__ == "__main__":
    main()
