"""Training driver: ``python -m repro.launch.train --arch qwen3-0.6b ...``

Runs a real training loop on the local devices (the production meshes
are exercised by dryrun.py; this driver is sized for the end-to-end
example: a ~100M-param model for a few hundred steps on CPU, or a real
slice on accelerators). Supports checkpoint/restart (--resume picks up
the latest step) and coded execution: ``--hetero-groups`` plans a
straggler fleet and runs gradient-coded training (``--scheme``, any
registered allocation scheme; ``grad_coding`` by default — see
DESIGN.md §5), with the per-round deadline/erasure machinery shared
with the serving loop.
"""
from __future__ import annotations

import argparse

import jax

from repro.configs import get_arch
from repro.configs.base import ShapeConfig
from repro.core.runtime_model import ClusterSpec
from repro.core.schemes import scheme_names
from repro.sim import scenario_names
from repro.data import SyntheticLMData
from repro.models.model import Model
from repro.optim import AdamWConfig
from repro.runtime.compile_cache import enable_persistent_cache
from repro.runtime.train_loop import (
    TrainConfig,
    Trainer,
    heterogeneous_batch_split,
)


def main(argv=None):
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--arch", required=True)
    ap.add_argument("--reduced", action="store_true",
                    help="use the CPU-sized smoke variant of the arch")
    ap.add_argument("--steps", type=int, default=200)
    ap.add_argument("--seq-len", type=int, default=256)
    ap.add_argument("--batch", type=int, default=8)
    ap.add_argument("--lr", type=float, default=3e-4)
    ap.add_argument("--checkpoint-dir", default=None)
    ap.add_argument("--checkpoint-every", type=int, default=50)
    ap.add_argument("--resume", action="store_true",
                    help="resume from the latest checkpoint in --checkpoint-dir")
    ap.add_argument("--telemetry", default=None)
    ap.add_argument("--hetero-groups", default=None,
                    help="straggler fleet as N:mu[:bandwidth] groups, e.g. "
                         "'4:2.0,4:0.5' — turns on coded training against "
                         "this fleet (and prints the Theorem-2 batch split)")
    ap.add_argument("--scheme", default=None, choices=scheme_names(),
                    help="allocation scheme for coded training "
                         "(default: grad_coding; requires --hetero-groups)")
    ap.add_argument("--partitions", type=int, default=None,
                    help="gradient partitions k (must divide --batch; "
                         "default: one per batch row)")
    ap.add_argument("--deadline-safety", type=float, default=None,
                    help="per-round deadline = expected latency x this "
                         "(default: 3.0)")
    ap.add_argument("--scenario", default=None, choices=scenario_names(),
                    help="cluster-dynamics scenario perturbing the TRUE "
                         "fleet over the run (requires --hetero-groups); "
                         "pair with --adapt-every to close the loop")
    ap.add_argument("--adapt-every", type=int, default=None,
                    help="closed-loop control cadence: consume straggler "
                         "estimates and maybe replan every R steps "
                         "(requires --hetero-groups)")
    ap.add_argument("--adapt-threshold", type=float, default=None,
                    help="hysteresis: replan only when the estimated "
                         "latency improves by this fraction (default 0.05)")
    ap.add_argument("--bucket-quantum", type=int, default=None,
                    help="quantize integer partition loads to this multiple "
                         "and replan via an in-program bucket switch: "
                         "adaptive replans within the admitted capacity "
                         "skip the step recompile (DESIGN.md §11)")
    ap.add_argument("--measure-times", action="store_true",
                    help="measured-reality loop (DESIGN.md §12): time each "
                         "coded dispatch with a RoundClock and adapt from "
                         "wall-clock observations instead of simulated "
                         "ground truth (requires --hetero-groups)")
    args = ap.parse_args(argv)
    if args.hetero_groups is None:
        # coded flags must not silently no-op without a fleet to plan for
        coded_flags = [
            name for name, v in (("--scheme", args.scheme),
                                 ("--partitions", args.partitions),
                                 ("--deadline-safety", args.deadline_safety),
                                 ("--scenario", args.scenario),
                                 ("--adapt-every", args.adapt_every),
                                 ("--adapt-threshold", args.adapt_threshold),
                                 ("--bucket-quantum", args.bucket_quantum),
                                 ("--measure-times",
                                  args.measure_times or None))
            if v is not None
        ]
        if coded_flags:
            raise SystemExit(
                f"{', '.join(coded_flags)} require --hetero-groups "
                f"(coded training needs a fleet to plan against)"
            )

    # cold-start compile reuse: every program this process builds
    # (bucket branches included) persists to the on-disk JAX cache
    enable_persistent_cache()

    config = get_arch(args.arch)
    if args.reduced:
        config = config.reduced()
    model = Model(config)
    shape = ShapeConfig("cli", args.seq_len, args.batch, "train")
    data = SyntheticLMData(config, shape)

    cluster = None
    if args.hetero_groups:
        cluster = ClusterSpec.parse(args.hetero_groups)
        split = heterogeneous_batch_split(cluster, args.batch)
        print(f"heterogeneity-aware batch split (Theorem 2): {split.tolist()} "
              f"over groups {[(g.num_workers, g.mu) for g in cluster.groups]}")

    opt_cfg = AdamWConfig(lr=args.lr, total_steps=args.steps,
                          warmup_steps=max(args.steps // 20, 1))
    cfg = TrainConfig(
        steps=args.steps,
        checkpoint_dir=args.checkpoint_dir,
        checkpoint_every=args.checkpoint_every,
        telemetry_path=args.telemetry,
        cluster=cluster,
        scheme=args.scheme or "grad_coding",
        partitions=args.partitions,
        deadline_safety=(
            3.0 if args.deadline_safety is None else args.deadline_safety
        ),
        scenario=args.scenario,
        adapt_every=args.adapt_every,
        adapt_threshold=(
            0.05 if args.adapt_threshold is None else args.adapt_threshold
        ),
        bucket_quantum=args.bucket_quantum,
        measure_times=args.measure_times,
    )
    if args.checkpoint_dir and not args.resume:
        # fresh run: ignore stale checkpoints by training from step 0 only
        # if the dir is empty; otherwise demand an explicit --resume.
        from repro.checkpoint import latest_step

        last = latest_step(args.checkpoint_dir)
        if last is not None:
            raise SystemExit(
                f"{args.checkpoint_dir} already has step_{last}; "
                f"pass --resume to continue it"
            )

    print(f"training {config.name}: {model.param_count():,} params, "
          f"{len(jax.devices())} device(s)")
    trainer = Trainer(model, data, opt_cfg, cfg)
    if trainer.executor is not None:
        plan = trainer.executor.plan
        print(f"coded training: scheme={trainer.executor.scheme.name} "
              f"k={trainer.partitions} n={plan.n} "
              f"loads={plan.loads_per_worker.tolist()} "
              f"deadline={trainer.executor.deadline:.4f}")
    if trainer.controller is not None:
        print(f"adaptive control: every {cfg.adapt_every} steps, "
              f"threshold {cfg.adapt_threshold:.0%}"
              + (f", scenario={args.scenario}" if args.scenario else ""))
    params, _, history = trainer.run()
    if history:
        first, last = history[0], history[-1]
        print(f"loss {first['loss']:.4f} -> {last['loss']:.4f} "
              f"({cfg.steps} steps)")
        if trainer.executor is not None:
            skipped = sum(h.get("skipped", 0.0) for h in history)
            print(f"coded rounds logged: {len(history)}, skipped steps "
                  f"among them: {int(skipped)}")
    if trainer.clock is not None:
        ck = trainer.clock
        unit = "-" if ck.unit_s is None else f"{ck.unit_s:.3e}"
        print(f"measured: {ck.fed}/{ck.rounds} rounds fed, unit_s={unit}")
    if trainer.controller is not None:
        ctl = trainer.controller
        replanned = [d for d in ctl.decisions if d.replanned]
        print(f"controller: {len(ctl.decisions)} decisions, "
              f"{len(replanned)} replans "
              f"(rounds {[d.round for d in replanned]}), "
              f"final deadline {trainer.executor.deadline:.4f}")
    return params


if __name__ == "__main__":
    main()
