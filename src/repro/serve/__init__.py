"""Continuous-batching serving front-end (DESIGN.md §10).

Request streams -> admission queue -> padded stream slots -> the one
compiled decode scan. ``workload`` generates seeded request traces,
``scheduler`` owns slot assignment and admission control; the device
side (batched prefill splice, slot-resident decode) lives in
``runtime/serve_loop.py`` / ``models/model.py``.
"""
from repro.serve.workload import (  # noqa: F401
    CLASS_PRIORITY,
    DEADLINE_SLACK,
    Request,
    WorkloadSpec,
    make_workload,
    register_workload,
    workload_names,
)
from repro.serve.scheduler import (  # noqa: F401
    BlockPool,
    FinishedRequest,
    SlotScheduler,
    SlotState,
)
