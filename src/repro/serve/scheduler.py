"""Slot-based in-flight batching scheduler with admission control.

``SlotScheduler`` owns the HOST side of continuous batching: which
request occupies which of the ``S`` padded stream slots, the FIFO
admission queue, and the load-shedding rule. It never touches device
buffers — the serve loop (``runtime/serve_loop.py``) asks it *what* to
do each round (which requests to splice into which slots, which finished
slots to retire) and performs the actual buffer updates inside the
compiled programs. That split keeps every scheduling decision
deterministic, replayable from the seeded trace alone, and testable
without a model.

Admission control (DESIGN.md §10): a request is shed at enqueue time
when its projected completion — queue backlog drained at ``slots``
requests at a time, scaled by the fleet's current mean-field round
latency relative to a reference — exceeds its deadline class's slack
budget. ``round_latency`` is wired to
``AdaptiveController.coverage_latency`` by the server, so the fleet
sheds load *before* deadlines collapse when the tracker sees rounds
slowing down. ``batch``-class requests are never shed for deadline risk;
a full queue rejects any class.

Paged serving (DESIGN.md §13) adds the physical-memory dimension: a
``BlockPool`` free list of fixed KV blocks. Admission then requires the
request's full block reservation (prompt + out_len + 1 tokens, rounded
up to blocks) to be allocatable: a request that can NEVER fit the pool
is shed at enqueue time with reason ``pool_exhausted`` (admission
control on memory, not queue depth alone), while transient pressure
just holds the queue head until blocks free. Blocks are freed on
retirement/eviction and reused LIFO.
"""
from __future__ import annotations

import dataclasses
from typing import Callable

from repro.obs.metrics import MetricsRegistry
from repro.serve.workload import CLASS_PRIORITY, DEADLINE_SLACK, Request


class BlockPool:
    """Free list over a fixed pool of physical KV blocks.

    The device side never sees this object — it only receives the block
    tables the scheduler builds from these allocations. LIFO reuse keeps
    recently-freed (cache-warm) blocks hot and makes reuse assertable in
    tests. Telemetry (``kv_bytes`` / ``blocks_in_use`` /
    ``blocks_freed`` events, DESIGN.md §8) makes pool pressure
    observable alongside ``round_timing``; occupancy tallies live in a
    ``MetricsRegistry`` (§14) so a run's final ``metrics_snapshot``
    carries the pool view without replaying the event stream.
    """

    def __init__(self, num_blocks: int, block_len: int, *,
                 bytes_per_block: int = 0, telemetry=None, metrics=None):
        if num_blocks <= 0:
            raise ValueError(f"num_blocks must be > 0, got {num_blocks}")
        if block_len <= 0:
            raise ValueError(f"block_len must be > 0, got {block_len}")
        self.num_blocks = int(num_blocks)
        self.block_len = int(block_len)
        self.bytes_per_block = int(bytes_per_block)
        self.telemetry = telemetry
        self.metrics = metrics if metrics is not None else MetricsRegistry()
        self._freed = self.metrics.counter("kv_blocks_freed")
        self._in_use_gauge = self.metrics.gauge("kv_blocks_in_use")
        self._util_gauge = self.metrics.gauge("kv_pool_utilization")
        # stack: first allocations get blocks 0, 1, ...; frees push back
        # on top so the most recently freed blocks are reused first
        self._free = list(range(num_blocks - 1, -1, -1))

    @property
    def blocks_freed(self) -> int:
        """Cumulative blocks returned to the pool."""
        return self._freed.value

    @property
    def free_blocks(self) -> int:
        return len(self._free)

    @property
    def blocks_in_use(self) -> int:
        return self.num_blocks - len(self._free)

    def blocks_for(self, tokens: int) -> int:
        """Blocks needed to hold ``tokens`` KV entries."""
        return -(-int(tokens) // self.block_len)

    def alloc(self, n: int, *, rid=None, now: float = 0.0) -> list[int] | None:
        """Take ``n`` blocks off the free list; None if unavailable."""
        if n > len(self._free):
            return None
        got = [self._free.pop() for _ in range(n)]
        self._emit(rid, now, freed=0)
        return got

    def free(self, blocks, *, rid=None, now: float = 0.0) -> None:
        self._free.extend(blocks)
        if blocks:
            self._freed.inc(len(blocks))
            self._emit(rid, now, freed=len(blocks))

    def _emit(self, rid, now: float, *, freed: int) -> None:
        self._in_use_gauge.set(self.blocks_in_use)
        self._util_gauge.set(self.blocks_in_use / self.num_blocks)
        if self.telemetry is None:
            return
        common = dict(request_id=rid, round=float(now))
        if freed:
            self.telemetry.event(
                "blocks_freed", blocks=freed,
                total_freed=self.blocks_freed, **common,
            )
        self.telemetry.event(
            "blocks_in_use", in_use=self.blocks_in_use,
            free=self.free_blocks, capacity=self.num_blocks, **common,
        )
        self.telemetry.event(
            "kv_bytes",
            bytes_in_use=self.blocks_in_use * self.bytes_per_block,
            bytes_total=self.num_blocks * self.bytes_per_block,
            utilization=self.blocks_in_use / self.num_blocks, **common,
        )


@dataclasses.dataclass
class SlotState:
    """One padded stream slot of the running decode scan."""

    request: Request | None = None
    admitted_at: float = 0.0  # round the request entered the slot
    generated: int = 0  # tokens emitted so far (first token lands at admit)
    prefilled: int = 0  # prompt tokens prefilled so far (chunked prefill)
    blocks: tuple[int, ...] = ()  # physical KV blocks reserved (paged)

    @property
    def busy(self) -> bool:
        return self.request is not None

    @property
    def prefilling(self) -> bool:
        """Still consuming prompt chunks (not yet decode-eligible)."""
        return self.busy and self.prefilled < self.request.prompt_len

    @property
    def done(self) -> bool:
        return (
            self.busy and not self.prefilling
            and self.generated >= self.request.out_len
        )


@dataclasses.dataclass(frozen=True)
class FinishedRequest:
    """Terminal record of one request (done or shed)."""

    request: Request
    outcome: str  # "done" | "shed"
    reason: str  # "finished" | "queue_full" | "deadline_risk"
    queue_wait: float  # rounds between arrival and admission (0 if shed)
    finish_round: float
    tokens: int

    @property
    def latency(self) -> float:
        """Arrival-to-last-token latency in rounds (shed => inf)."""
        if self.outcome != "done":
            return float("inf")
        return self.finish_round - self.request.arrival


class SlotScheduler:
    """Admission queue + slot assignment for ``S`` in-flight streams.

    Drive it with the serve loop's virtual clock: ``offer(req, now)``
    when a request arrives, ``fill_slots(now)`` whenever slots may be
    free, ``advance(emitted, now)`` after each decode round,
    ``retire_done(now)`` to evict finished streams. All decisions are
    pure functions of the call sequence — replaying the same trace
    reproduces the same schedule exactly.
    """

    def __init__(
        self,
        slots: int,
        *,
        queue_cap: int = 64,
        admission_threshold: float = 1.0,
        round_latency: Callable[[], float] | None = None,
        reference_latency: float = 1.0,
        telemetry=None,
        pool: BlockPool | None = None,
        chunk: int | None = None,
        metrics: MetricsRegistry | None = None,
    ):
        if slots <= 0:
            raise ValueError(f"slots must be > 0, got {slots}")
        if queue_cap < 0:
            raise ValueError(f"queue_cap must be >= 0, got {queue_cap}")
        if not admission_threshold > 0:
            raise ValueError(
                f"admission_threshold must be > 0, got {admission_threshold}"
            )
        self.slots = [SlotState() for _ in range(slots)]
        self.queue: list[tuple[Request, float]] = []  # (request, arrival)
        self.queue_cap = queue_cap
        self.admission_threshold = admission_threshold
        self.round_latency = round_latency
        self.reference_latency = float(reference_latency)
        self.telemetry = telemetry
        self.pool = pool
        self.chunk = chunk
        # shed/admitted tallies and per-deadline-class latency
        # percentiles live in the registry (§14); the serve loop shares
        # one registry between scheduler and pool so a run snapshots as
        # a unit
        self.metrics = metrics if metrics is not None else MetricsRegistry()
        self._admitted = self.metrics.counter("requests_admitted")
        self._shed_total = self.metrics.counter("requests_shed_total")
        self._queue_gauge = self.metrics.gauge("queue_depth")
        self.finished: list[FinishedRequest] = []

    @property
    def shed(self) -> int:
        """Requests shed at enqueue time (all reasons)."""
        return self._shed_total.value

    @property
    def admitted(self) -> int:
        """Requests that entered a stream slot."""
        return self._admitted.value

    # ------------------------------------------------------------- views
    @property
    def num_slots(self) -> int:
        return len(self.slots)

    @property
    def busy_slots(self) -> int:
        return sum(s.busy for s in self.slots)

    @property
    def idle(self) -> bool:
        return not self.queue and all(not s.busy for s in self.slots)

    def _work(self, req: Request) -> float:
        """Rounds of compute a request costs; chunked prefill counts
        one round per prompt chunk instead of one flat admit round."""
        if self.chunk is None:
            return float(req.work)
        return float(-(-req.prompt_len // self.chunk) + req.out_len)

    def blocks_needed(self, req: Request) -> int:
        """Full KV reservation: prompt + generated tokens + next write."""
        assert self.pool is not None
        return self.pool.blocks_for(req.prompt_len + req.out_len + 1)

    def _latency_factor(self) -> float:
        """Current round latency relative to the reference (>= 0)."""
        if self.round_latency is None:
            return 1.0
        t = float(self.round_latency())
        if t != t or t == float("inf"):  # NaN/inf: fleet cannot cover k
            return float("inf")
        return max(t, 0.0) / self.reference_latency

    # --------------------------------------------------------- admission
    def offer(self, req: Request, now: float) -> bool:
        """Enqueue a newly arrived request, or shed it. True = accepted."""
        if len(self.queue) >= self.queue_cap:
            self._shed(req, now, "queue_full")
            return False
        if self.pool is not None and self.blocks_needed(req) > self.pool.num_blocks:
            # memory admission control: the reservation can NEVER be
            # satisfied, even by an empty pool — shed now rather than
            # deadlocking at the queue head (transient pressure from
            # in-flight requests just waits for frees instead).
            self._shed(req, now, "pool_exhausted")
            return False
        slack = DEADLINE_SLACK[req.deadline_class]
        if slack != float("inf"):
            # projected completion: the backlog ahead of this request
            # drains ``slots`` streams at a time, then the request runs
            # its own prefill + decode — all scaled by how slow the
            # fleet's rounds currently are vs the reference.
            work = self._work(req)
            backlog = sum(self._work(r) for r, _ in self.queue) + sum(
                self._work(s.request) - s.generated
                for s in self.slots if s.busy and s.request is not None
            )
            est = (backlog / self.num_slots + work) * self._latency_factor()
            budget = slack * work / self.admission_threshold
            if est > budget:
                self._shed(req, now, "deadline_risk")
                return False
        self.queue.append((req, now))
        self._queue_gauge.set(len(self.queue))
        return True

    def _shed(self, req: Request, now: float, reason: str) -> None:
        self._shed_total.inc()
        self.metrics.counter("requests_shed", reason=reason).inc()
        self.finished.append(
            FinishedRequest(
                request=req, outcome="shed", reason=reason,
                queue_wait=0.0, finish_round=now, tokens=0,
            )
        )
        if self.telemetry is not None:
            self.telemetry.event(
                "request_evicted",
                request_id=req.rid, reason=reason,
                deadline_class=req.deadline_class, round=float(now),
                queue_depth=len(self.queue),
            )

    # ------------------------------------------------------ slot control
    def fill_slots(self, now: float) -> list[tuple[int, Request]]:
        """Admit queued requests into free slots; deadline class first.

        Within a class the queue stays FIFO (stable sort on priority).
        Returns the (slot index, request) assignments made this call —
        the serve loop splices each one's prefilled cache into that slot.
        """
        free = [i for i, s in enumerate(self.slots) if not s.busy]
        if not free or not self.queue:
            return []
        self.queue.sort(key=lambda e: CLASS_PRIORITY[e[0].deadline_class])
        placed = []
        for slot_idx in free:
            if not self.queue:
                break
            blocks: tuple[int, ...] = ()
            if self.pool is not None:
                # full reservation up front: admission is the only point
                # that can fail on memory, so a slotted request always
                # runs to completion. Head-of-line waits (FIFO, no
                # deadlock: its reservation fits an empty pool or offer
                # would have shed it).
                req_head = self.queue[0][0]
                got = self.pool.alloc(
                    self.blocks_needed(req_head), rid=req_head.rid, now=now
                )
                if got is None:
                    break
                blocks = tuple(got)
            req, arrived = self.queue.pop(0)
            # without chunked prefill the whole prompt is spliced in at
            # admission; with it, the serve loop reports progress via
            # note_prefill() as chunks land across admit rounds.
            done_prefill = req.prompt_len if self.chunk is None else 0
            self.slots[slot_idx] = SlotState(
                request=req, admitted_at=now, generated=0,
                prefilled=done_prefill, blocks=blocks,
            )
            self._admitted.inc()
            self._queue_gauge.set(len(self.queue))
            placed.append((slot_idx, req))
            if self.telemetry is not None:
                self.telemetry.event(
                    "request_admitted",
                    request_id=req.rid, slot=slot_idx,
                    queue_wait=float(now - arrived),
                    deadline_class=req.deadline_class, round=float(now),
                )
        return placed

    def advance(self, emitted: int = 1, now: float | None = None) -> None:
        """Account ``emitted`` new tokens on every busy, unfinished slot.

        Slots still prefilling (chunked prefill in flight) are not
        decoding yet and accrue nothing.
        """
        for s in self.slots:
            if s.busy and not s.prefilling and not s.done:
                s.generated = min(
                    s.generated + emitted, s.request.out_len
                )

    def note_prefill(self, slot_idx: int, tokens: int) -> None:
        """Record ``tokens`` prompt tokens prefilled into a slot."""
        s = self.slots[slot_idx]
        if s.busy:
            s.prefilled = min(s.prefilled + tokens, s.request.prompt_len)

    def retire_done(self, now: float) -> list[tuple[int, FinishedRequest]]:
        """Evict finished streams; their slots become admissible again."""
        out = []
        for i, s in enumerate(self.slots):
            if not s.done:
                continue
            req = s.request
            fin = FinishedRequest(
                request=req, outcome="done", reason="finished",
                queue_wait=0.0, finish_round=now, tokens=s.generated,
            )
            self.finished.append(fin)
            out.append((i, fin))
            self.metrics.histogram(
                "request_latency", deadline_class=req.deadline_class
            ).observe(fin.latency)
            self.metrics.counter("tokens_emitted").inc(s.generated)
            if self.pool is not None and s.blocks:
                self.pool.free(s.blocks, rid=req.rid, now=now)
            self.slots[i] = SlotState()
            if self.telemetry is not None:
                self.telemetry.event(
                    "request_done",
                    request_id=req.rid, slot=i, tokens=s.generated,
                    latency=float(now - req.arrival),
                    deadline_class=req.deadline_class, round=float(now),
                )
        return out
