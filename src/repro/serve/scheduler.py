"""Slot-based in-flight batching scheduler with admission control.

``SlotScheduler`` owns the HOST side of continuous batching: which
request occupies which of the ``S`` padded stream slots, the FIFO
admission queue, and the load-shedding rule. It never touches device
buffers — the serve loop (``runtime/serve_loop.py``) asks it *what* to
do each round (which requests to splice into which slots, which finished
slots to retire) and performs the actual buffer updates inside the
compiled programs. That split keeps every scheduling decision
deterministic, replayable from the seeded trace alone, and testable
without a model.

Admission control (DESIGN.md §10): a request is shed at enqueue time
when its projected completion — queue backlog drained at ``slots``
requests at a time, scaled by the fleet's current mean-field round
latency relative to a reference — exceeds its deadline class's slack
budget. ``round_latency`` is wired to
``AdaptiveController.coverage_latency`` by the server, so the fleet
sheds load *before* deadlines collapse when the tracker sees rounds
slowing down. ``batch``-class requests are never shed for deadline risk;
a full queue rejects any class.
"""
from __future__ import annotations

import dataclasses
from typing import Callable

from repro.serve.workload import CLASS_PRIORITY, DEADLINE_SLACK, Request


@dataclasses.dataclass
class SlotState:
    """One padded stream slot of the running decode scan."""

    request: Request | None = None
    admitted_at: float = 0.0  # round the request entered the slot
    generated: int = 0  # tokens emitted so far (first token lands at admit)

    @property
    def busy(self) -> bool:
        return self.request is not None

    @property
    def done(self) -> bool:
        return self.busy and self.generated >= self.request.out_len


@dataclasses.dataclass(frozen=True)
class FinishedRequest:
    """Terminal record of one request (done or shed)."""

    request: Request
    outcome: str  # "done" | "shed"
    reason: str  # "finished" | "queue_full" | "deadline_risk"
    queue_wait: float  # rounds between arrival and admission (0 if shed)
    finish_round: float
    tokens: int

    @property
    def latency(self) -> float:
        """Arrival-to-last-token latency in rounds (shed => inf)."""
        if self.outcome != "done":
            return float("inf")
        return self.finish_round - self.request.arrival


class SlotScheduler:
    """Admission queue + slot assignment for ``S`` in-flight streams.

    Drive it with the serve loop's virtual clock: ``offer(req, now)``
    when a request arrives, ``fill_slots(now)`` whenever slots may be
    free, ``advance(emitted, now)`` after each decode round,
    ``retire_done(now)`` to evict finished streams. All decisions are
    pure functions of the call sequence — replaying the same trace
    reproduces the same schedule exactly.
    """

    def __init__(
        self,
        slots: int,
        *,
        queue_cap: int = 64,
        admission_threshold: float = 1.0,
        round_latency: Callable[[], float] | None = None,
        reference_latency: float = 1.0,
        telemetry=None,
    ):
        if slots <= 0:
            raise ValueError(f"slots must be > 0, got {slots}")
        if queue_cap < 0:
            raise ValueError(f"queue_cap must be >= 0, got {queue_cap}")
        if not admission_threshold > 0:
            raise ValueError(
                f"admission_threshold must be > 0, got {admission_threshold}"
            )
        self.slots = [SlotState() for _ in range(slots)]
        self.queue: list[tuple[Request, float]] = []  # (request, arrival)
        self.queue_cap = queue_cap
        self.admission_threshold = admission_threshold
        self.round_latency = round_latency
        self.reference_latency = float(reference_latency)
        self.telemetry = telemetry
        self.finished: list[FinishedRequest] = []
        self.shed = 0
        self.admitted = 0

    # ------------------------------------------------------------- views
    @property
    def num_slots(self) -> int:
        return len(self.slots)

    @property
    def busy_slots(self) -> int:
        return sum(s.busy for s in self.slots)

    @property
    def idle(self) -> bool:
        return not self.queue and all(not s.busy for s in self.slots)

    def _latency_factor(self) -> float:
        """Current round latency relative to the reference (>= 0)."""
        if self.round_latency is None:
            return 1.0
        t = float(self.round_latency())
        if t != t or t == float("inf"):  # NaN/inf: fleet cannot cover k
            return float("inf")
        return max(t, 0.0) / self.reference_latency

    # --------------------------------------------------------- admission
    def offer(self, req: Request, now: float) -> bool:
        """Enqueue a newly arrived request, or shed it. True = accepted."""
        if len(self.queue) >= self.queue_cap:
            self._shed(req, now, "queue_full")
            return False
        slack = DEADLINE_SLACK[req.deadline_class]
        if slack != float("inf"):
            # projected completion: the backlog ahead of this request
            # drains ``slots`` streams at a time, then the request runs
            # its own prefill + decode — all scaled by how slow the
            # fleet's rounds currently are vs the reference.
            backlog = sum(r.work for r, _ in self.queue) + sum(
                s.request.work - s.generated
                for s in self.slots if s.busy and s.request is not None
            )
            est = (backlog / self.num_slots + req.work) * self._latency_factor()
            budget = slack * req.work / self.admission_threshold
            if est > budget:
                self._shed(req, now, "deadline_risk")
                return False
        self.queue.append((req, now))
        return True

    def _shed(self, req: Request, now: float, reason: str) -> None:
        self.shed += 1
        self.finished.append(
            FinishedRequest(
                request=req, outcome="shed", reason=reason,
                queue_wait=0.0, finish_round=now, tokens=0,
            )
        )
        if self.telemetry is not None:
            self.telemetry.event(
                "request_evicted",
                request_id=req.rid, reason=reason,
                deadline_class=req.deadline_class, round=float(now),
                queue_depth=len(self.queue),
            )

    # ------------------------------------------------------ slot control
    def fill_slots(self, now: float) -> list[tuple[int, Request]]:
        """Admit queued requests into free slots; deadline class first.

        Within a class the queue stays FIFO (stable sort on priority).
        Returns the (slot index, request) assignments made this call —
        the serve loop splices each one's prefilled cache into that slot.
        """
        free = [i for i, s in enumerate(self.slots) if not s.busy]
        if not free or not self.queue:
            return []
        self.queue.sort(key=lambda e: CLASS_PRIORITY[e[0].deadline_class])
        placed = []
        for slot_idx in free:
            if not self.queue:
                break
            req, arrived = self.queue.pop(0)
            self.slots[slot_idx] = SlotState(
                request=req, admitted_at=now, generated=0
            )
            self.admitted += 1
            placed.append((slot_idx, req))
            if self.telemetry is not None:
                self.telemetry.event(
                    "request_admitted",
                    request_id=req.rid, slot=slot_idx,
                    queue_wait=float(now - arrived),
                    deadline_class=req.deadline_class, round=float(now),
                )
        return placed

    def advance(self, emitted: int = 1, now: float | None = None) -> None:
        """Account ``emitted`` new tokens on every busy, unfinished slot."""
        for s in self.slots:
            if s.busy and not s.done:
                s.generated = min(
                    s.generated + emitted, s.request.out_len
                )

    def retire_done(self, now: float) -> list[tuple[int, FinishedRequest]]:
        """Evict finished streams; their slots become admissible again."""
        out = []
        for i, s in enumerate(self.slots):
            if not s.done:
                continue
            req = s.request
            fin = FinishedRequest(
                request=req, outcome="done", reason="finished",
                queue_wait=0.0, finish_round=now, tokens=s.generated,
            )
            self.finished.append(fin)
            out.append((i, fin))
            self.slots[i] = SlotState()
            if self.telemetry is not None:
                self.telemetry.event(
                    "request_done",
                    request_id=req.rid, slot=i, tokens=s.generated,
                    latency=float(now - req.arrival),
                    deadline_class=req.deadline_class, round=float(now),
                )
        return out
