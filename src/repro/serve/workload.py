"""Seeded request workloads for the continuous-batching serve front-end.

A workload is a deterministic trace of independent requests — arrival
time, prompt, output budget and deadline class — that the slot scheduler
(``serve/scheduler.py``) admits into the running decode scan. Arrival
times are measured in DECODE ROUNDS (the serve loop's virtual clock: one
compiled decode step = one round, one batched prefill pass = one round),
so traces are reproducible independent of wall-clock speed and the same
trace drives both the continuous-batching server and the sequential
full-batch baseline in ``benchmarks/serve_frontend.py``.

Named workloads mirror the scenario registry (``repro/sim``): factories
are registered by name, every factory's named keyword params are its
accepted overrides, and ``make_workload(name, seed=..., ...)`` is
deterministic in (name, params, seed).
"""
from __future__ import annotations

import dataclasses
import inspect
from typing import Callable, Sequence

import numpy as np

#: admission-control deadline classes: completion budget multiplier over
#: a request's own work (prefill + out_len rounds). ``batch`` requests
#: are never shed for deadline risk (only a full queue rejects them).
DEADLINE_SLACK: dict[str, float] = {
    "strict": 4.0,
    "standard": 10.0,
    "batch": float("inf"),
}

#: queue pick order when a slot frees (lower = sooner)
CLASS_PRIORITY: dict[str, int] = {"strict": 0, "standard": 1, "batch": 2}


@dataclasses.dataclass(frozen=True)
class Request:
    """One independent generation request."""

    rid: int
    arrival: float  # rounds (virtual clock)
    prompt: tuple[int, ...]  # token ids
    out_len: int  # tokens to generate (completion = out_len emitted)
    deadline_class: str = "standard"

    def __post_init__(self):
        if self.out_len <= 0:
            raise ValueError(f"request {self.rid}: out_len must be > 0")
        if not self.prompt:
            raise ValueError(f"request {self.rid}: empty prompt")
        if self.deadline_class not in DEADLINE_SLACK:
            raise ValueError(
                f"request {self.rid}: unknown deadline class "
                f"{self.deadline_class!r}; known: {sorted(DEADLINE_SLACK)}"
            )

    @property
    def prompt_len(self) -> int:
        return len(self.prompt)

    @property
    def work(self) -> int:
        """Slot-rounds this request occupies (1 prefill pass + decode)."""
        return 1 + self.out_len


@dataclasses.dataclass(frozen=True)
class WorkloadSpec:
    """Parameters of a Poisson request stream (frozen, hashable)."""

    name: str
    arrival_rate: float  # mean requests per decode round
    num_requests: int
    prompt_len: tuple[int, int]  # inclusive [lo, hi]; lo == hi -> fixed
    out_len: tuple[int, int]
    vocab: int = 512
    #: (class, weight) mix the per-request deadline class is drawn from
    class_mix: tuple[tuple[str, float], ...] = (
        ("strict", 0.25), ("standard", 0.65), ("batch", 0.10),
    )
    #: optional bimodal/multimodal output lengths: ((lo, hi), weight)
    #: ranges the per-request draw picks from; overrides ``out_len``
    out_len_mix: tuple[tuple[tuple[int, int], float], ...] | None = None

    def __post_init__(self):
        if not self.arrival_rate > 0:
            raise ValueError(
                f"arrival_rate must be > 0, got {self.arrival_rate!r}"
            )
        if self.num_requests <= 0:
            raise ValueError(f"num_requests must be > 0, got {self.num_requests}")
        for lo, hi in (self.prompt_len, self.out_len):
            if not 0 < lo <= hi:
                raise ValueError(
                    f"length ranges must satisfy 0 < lo <= hi, got ({lo}, {hi})"
                )
        for cls, w in self.class_mix:
            if cls not in DEADLINE_SLACK:
                raise ValueError(f"unknown deadline class {cls!r}")
            if w < 0:
                raise ValueError(f"class weight must be >= 0, got {w}")
        for (lo, hi), w in self.out_len_mix or ():
            if not 0 < lo <= hi or w < 0:
                raise ValueError(
                    f"out_len_mix entries need 0 < lo <= hi and weight >= 0, "
                    f"got (({lo}, {hi}), {w})"
                )

    def trace(self, seed: int = 0) -> list[Request]:
        """Materialize the seeded request trace (sorted by arrival)."""
        rng = np.random.RandomState(seed)
        t = 0.0
        classes = [c for c, _ in self.class_mix]
        weights = np.asarray([w for _, w in self.class_mix], float)
        weights = weights / weights.sum()
        reqs = []
        mix = self.out_len_mix
        if mix:
            mix_w = np.asarray([w for _, w in mix], float)
            mix_w = mix_w / mix_w.sum()
        for rid in range(self.num_requests):
            t += float(rng.exponential(1.0 / self.arrival_rate))
            p_lo, p_hi = self.prompt_len
            if mix:
                o_lo, o_hi = mix[int(rng.choice(len(mix), p=mix_w))][0]
            else:
                o_lo, o_hi = self.out_len
            plen = int(rng.randint(p_lo, p_hi + 1))
            olen = int(rng.randint(o_lo, o_hi + 1))
            prompt = tuple(
                int(x) for x in rng.randint(0, self.vocab, size=plen)
            )
            cls = classes[int(rng.choice(len(classes), p=weights))]
            reqs.append(
                Request(rid=rid, arrival=t, prompt=prompt, out_len=olen,
                        deadline_class=cls)
            )
        return reqs


# ------------------------------------------------------------- registry
WorkloadFactory = Callable[..., WorkloadSpec]

_REGISTRY: dict[str, WorkloadFactory] = {}
_PARAMS: dict[str, frozenset] = {}


def register_workload(name: str, factory: WorkloadFactory) -> None:
    if name in _REGISTRY:
        raise ValueError(f"workload {name!r} already registered")
    sig = inspect.signature(factory)
    _PARAMS[name] = frozenset(
        p.name for p in sig.parameters.values()
        if p.kind in (p.POSITIONAL_OR_KEYWORD, p.KEYWORD_ONLY)
    )
    _REGISTRY[name] = factory


def workload_names() -> tuple[str, ...]:
    return tuple(sorted(_REGISTRY))


def make_workload(name: str, **params) -> WorkloadSpec:
    """Named workload -> spec; None params mean "use the preset default"."""
    if name not in _REGISTRY:
        raise ValueError(
            f"unknown workload {name!r}; registered: "
            f"{', '.join(workload_names())}"
        )
    params = {k: v for k, v in params.items() if v is not None}
    unknown = sorted(set(params) - _PARAMS[name])
    if unknown:
        raise ValueError(
            f"workload {name!r} does not accept parameter(s) "
            f"{', '.join(unknown)}; accepted: "
            f"{', '.join(sorted(_PARAMS[name])) or '(none)'}"
        )
    return _REGISTRY[name](**params)


def _poisson(*, arrival_rate=0.15, num_requests=24, prompt_len=16,
             out_len=(8, 24), vocab=512):
    pl = (prompt_len, prompt_len) if isinstance(prompt_len, int) else tuple(prompt_len)
    ol = (out_len, out_len) if isinstance(out_len, int) else tuple(out_len)
    return WorkloadSpec(
        name="poisson", arrival_rate=float(arrival_rate),
        num_requests=int(num_requests), prompt_len=pl, out_len=ol,
        vocab=int(vocab),
    )


def _trickle(*, num_requests=12, prompt_len=16, out_len=(8, 24), vocab=512):
    """Well under any fleet's capacity: admission control must not shed."""
    w = _poisson(arrival_rate=0.02, num_requests=num_requests,
                 prompt_len=prompt_len, out_len=out_len, vocab=vocab)
    return dataclasses.replace(w, name="trickle")


def _overload(*, num_requests=24, prompt_len=16, out_len=(8, 24), vocab=512):
    """Arrivals far beyond slot capacity: the queue MUST shed load."""
    w = _poisson(arrival_rate=2.0, num_requests=num_requests,
                 prompt_len=prompt_len, out_len=out_len, vocab=vocab)
    return dataclasses.replace(w, name="overload")


def _chat(*, arrival_rate=0.6, num_requests=24, prompt_len=(8, 16),
          vocab=512):
    """Bimodal interactive traffic: mostly short replies, a long tail.

    The shape that makes fixed full-batch serving pay the most for
    padding everyone to the longest output — and where continuous
    batching's slot recycling wins.
    """
    w = _poisson(arrival_rate=arrival_rate, num_requests=num_requests,
                 prompt_len=prompt_len, out_len=(2, 28), vocab=vocab)
    return dataclasses.replace(
        w, name="chat",
        out_len_mix=(((2, 10), 2.0 / 3.0), ((20, 28), 1.0 / 3.0)),
    )


register_workload("poisson", _poisson)
register_workload("trickle", _trickle)
register_workload("overload", _overload)
register_workload("chat", _chat)
