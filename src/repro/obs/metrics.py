"""Typed metrics registry: counters, gauges, fixed-bucket histograms (§14).

Before this module every layer kept its own ad-hoc tallies — bare ints
on ``SlotScheduler`` (``shed``/``admitted``), a module dict in
``core/schemes.py`` (the ``allocate`` memo hit/miss stats), occupancy
recomputed inline by ``BlockPool``. ``MetricsRegistry`` gives them one
typed home:

* **Counter** — monotonic int (``requests_shed{reason=...}``,
  ``alloc_cache_hits``, ``replans{kind=...}``);
* **Gauge** — last-set float (``kv_blocks_in_use``, ``queue_depth``);
* **Histogram** — fixed-bucket, *mergeable* (two histograms with the
  same bounds add counts), with percentile estimation by linear
  interpolation inside the owning bucket. Request latency lands here
  per deadline class, so p50/p95/p99 come straight off the registry.

Metrics are keyed ``(name, sorted labels)``; ``counter``/``gauge``/
``histogram`` are get-or-create, so emitters just call them inline.
``snapshot()`` renders everything JSON-safe, and ``emit()`` writes one
``metrics_snapshot`` telemetry event — how a serve/train run's final
counters reach the JSONL stream and ``launch/obsreport.py``.

A process-global ``REGISTRY`` exists for module-level emitters with no
object to hang state on (the ``allocate`` cache); loops that need
isolation (one registry per serve run) construct their own.
"""
from __future__ import annotations

import bisect

__all__ = ["Counter", "Gauge", "Histogram", "MetricsRegistry",
           "LATENCY_BUCKETS", "REGISTRY"]

#: default latency buckets (virtual rounds / seconds): geometric, wide
#: enough for both sub-round erasure solves and hundred-round tails
LATENCY_BUCKETS = (
    0.5, 1.0, 2.0, 4.0, 8.0, 16.0, 32.0, 64.0, 128.0, 256.0, 512.0,
)


class Counter:
    """Monotonic event count."""

    __slots__ = ("value",)

    def __init__(self):
        self.value = 0

    def inc(self, n: int = 1) -> int:
        if n < 0:
            raise ValueError(f"counters only go up, got inc({n})")
        self.value += n
        return self.value

    def reset(self) -> None:
        self.value = 0

    def merge(self, other: "Counter") -> None:
        self.value += other.value


class Gauge:
    """Last-observed value (occupancy, depth, utilization)."""

    __slots__ = ("value",)

    def __init__(self):
        self.value = 0.0

    def set(self, v: float) -> float:
        self.value = float(v)
        return self.value

    def merge(self, other: "Gauge") -> None:
        self.value = other.value  # last writer wins


class Histogram:
    """Fixed-bucket histogram with interpolated percentiles.

    ``bounds`` are upper bucket edges; observations past the last edge
    land in an overflow bucket. Mergeable: two histograms with equal
    bounds add counts (the multi-host aggregation path — per-host
    registries merge into one fleet view).
    """

    __slots__ = ("bounds", "counts", "count", "sum", "min", "max")

    def __init__(self, bounds=LATENCY_BUCKETS):
        b = tuple(float(x) for x in bounds)
        if not b or list(b) != sorted(set(b)):
            raise ValueError(
                f"bucket bounds must be distinct and ascending, got {bounds}"
            )
        self.bounds = b
        self.counts = [0] * (len(b) + 1)  # +1: overflow
        self.count = 0
        self.sum = 0.0
        self.min = float("inf")
        self.max = float("-inf")

    def observe(self, v: float) -> None:
        v = float(v)
        self.counts[bisect.bisect_left(self.bounds, v)] += 1
        self.count += 1
        self.sum += v
        self.min = min(self.min, v)
        self.max = max(self.max, v)

    @property
    def mean(self) -> float:
        return self.sum / self.count if self.count else float("nan")

    def percentile(self, q: float) -> float:
        """Estimated q-quantile (q in [0, 1]): linear interpolation
        inside the owning bucket, clamped to the observed min/max so
        sparse histograms do not report impossible values."""
        if not 0 <= q <= 1:
            raise ValueError(f"q must be in [0, 1], got {q}")
        if self.count == 0:
            return float("nan")
        rank = q * self.count
        seen = 0
        for i, c in enumerate(self.counts):
            if c == 0:
                continue
            if seen + c >= rank:
                lo = self.bounds[i - 1] if i > 0 else min(self.min, self.bounds[0])
                hi = self.bounds[i] if i < len(self.bounds) else self.max
                frac = (rank - seen) / c
                est = lo + (hi - lo) * max(0.0, min(frac, 1.0))
                return max(self.min, min(est, self.max))
            seen += c
        return self.max

    def merge(self, other: "Histogram") -> None:
        if other.bounds != self.bounds:
            raise ValueError(
                f"cannot merge histograms with different bounds: "
                f"{self.bounds} vs {other.bounds}"
            )
        self.counts = [a + b for a, b in zip(self.counts, other.counts)]
        self.count += other.count
        self.sum += other.sum
        self.min = min(self.min, other.min)
        self.max = max(self.max, other.max)


class MetricsRegistry:
    """Get-or-create home for named, labeled metrics."""

    def __init__(self):
        self._metrics: dict[tuple, object] = {}

    @staticmethod
    def _key(name: str, labels: dict) -> tuple:
        return (name, tuple(sorted(labels.items())))

    def _get(self, cls, name: str, labels: dict, **kwargs):
        key = self._key(name, labels)
        m = self._metrics.get(key)
        if m is None:
            m = cls(**kwargs)
            self._metrics[key] = m
        elif type(m) is not cls:
            raise TypeError(
                f"metric {name!r} {labels} already registered as "
                f"{type(m).__name__}, requested {cls.__name__}"
            )
        return m

    def counter(self, name: str, **labels) -> Counter:
        return self._get(Counter, name, labels)

    def gauge(self, name: str, **labels) -> Gauge:
        return self._get(Gauge, name, labels)

    def histogram(self, name: str, *, bounds=LATENCY_BUCKETS,
                  **labels) -> Histogram:
        return self._get(Histogram, name, labels, bounds=bounds)

    def __len__(self) -> int:
        return len(self._metrics)

    # ----------------------------------------------------------- export
    def snapshot(self) -> list[dict]:
        """JSON-safe dump of every metric, sorted by (name, labels)."""
        out = []
        for (name, labels), m in sorted(self._metrics.items()):
            row = {"name": name, "labels": dict(labels)}
            if isinstance(m, Counter):
                row.update(type="counter", value=m.value)
            elif isinstance(m, Gauge):
                row.update(type="gauge", value=m.value)
            else:
                row.update(
                    type="histogram",
                    count=m.count,
                    sum=m.sum,
                    p50=m.percentile(0.50),
                    p95=m.percentile(0.95),
                    p99=m.percentile(0.99),
                    max=m.max if m.count else None,
                )
            out.append(row)
        return out

    def merge(self, other: "MetricsRegistry") -> None:
        """Fold another registry in (same-keyed metrics must agree in
        type and, for histograms, bounds)."""
        for key, om in other._metrics.items():
            m = self._metrics.get(key)
            if m is None:
                self._metrics[key] = om
            else:
                m.merge(om)

    def emit(self, telemetry, **fields) -> dict | None:
        """Write the snapshot as ONE ``metrics_snapshot`` event."""
        if telemetry is None:
            return None
        snap = self.snapshot()
        # NaN (empty histograms) is not strict JSON -> null
        for row in snap:
            for k, v in row.items():
                if isinstance(v, float) and v != v:
                    row[k] = None
        return telemetry.event(
            "metrics_snapshot", metrics=snap, size=len(snap), **fields
        )


#: process-global registry for module-level emitters (the ``allocate``
#: memo cache); per-run loops construct their own for isolation
REGISTRY = MetricsRegistry()
