"""Unified observability layer (DESIGN.md §14).

Four pieces, one import point:

* :mod:`repro.obs.trace` — nested wall-clock span tracing over the
  telemetry JSONL stream, exportable to Chrome ``trace_event`` JSON;
* :mod:`repro.obs.metrics` — typed counters/gauges/mergeable
  histograms with per-deadline-class latency percentiles;
* :mod:`repro.obs.schema` — the central event-schema registry every
  ``Telemetry.event`` emitter declares through (validated by tier-1
  tests, rendered into DESIGN.md §8);
* :mod:`repro.obs.profile` — XLA chrome-trace capture summarizer for
  ``benchmarks/perf_gate.py --profile`` (per-phase top-K op
  attribution and golden diffs).
"""
from repro.obs.metrics import (  # noqa: F401
    Counter,
    Gauge,
    Histogram,
    LATENCY_BUCKETS,
    MetricsRegistry,
    REGISTRY,
)
from repro.obs.schema import (  # noqa: F401
    EVENT_SCHEMAS,
    EventSchema,
    render_markdown,
    validate_event,
    validate_events,
)
from repro.obs.trace import (  # noqa: F401
    NULL_TRACER,
    NullTracer,
    Span,
    SpanTracer,
    spans_to_chrome,
)

__all__ = [
    "Counter", "Gauge", "Histogram", "LATENCY_BUCKETS",
    "MetricsRegistry", "REGISTRY",
    "EVENT_SCHEMAS", "EventSchema", "render_markdown",
    "validate_event", "validate_events",
    "NULL_TRACER", "NullTracer", "Span", "SpanTracer",
    "spans_to_chrome",
]
