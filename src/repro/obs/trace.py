"""Span tracing: nested, low-overhead wall-clock spans (DESIGN.md §14).

``SpanTracer`` is the host-side phase recorder of the observability
layer: the serve loop wraps each round's admission work and fused
dispatch, the trainer wraps each coded step, the executor wraps replans
and bucket switches, and the controller wraps its cadence decisions.
Every span is

* kept **in memory** (``tracer.spans``, a bounded ring) for tests and
  end-of-run summaries,
* mirrored to the **telemetry JSONL** stream (when the tracer owns a
  ``Telemetry``) as a ``span`` event carrying the monotonic ``t``
  sequence number plus ``perf_counter`` wall stamps (``t0_s`` start,
  ``dur_s`` duration), so spans interleave with every other event on
  one real timeline, and
* exportable to **Chrome ``trace_event`` JSON** (``export_chrome``) —
  loadable in Perfetto / ``chrome://tracing`` for a visual waterfall.

Overhead discipline: a span costs two ``perf_counter`` calls, one list
append and (with telemetry) one JSONL line. Call sites that may run
with tracing off hold ``NULL_TRACER`` — its ``span()`` returns one
shared no-op context manager, so the disabled path is a single
attribute lookup and never allocates. A slow tier-1 test
(``tests/test_obs.py``) serves the same workload traced and untraced
end to end and asserts the enabled path stays within 2% of untraced
throughput.

Span taxonomy (DESIGN.md §14): ``admit`` | ``prefill_chunk`` |
``decode_chunk`` | ``dispatch`` | ``erasure_solve`` | ``replan`` |
``bucket_switch`` | ``adapt_update``.
"""
from __future__ import annotations

import dataclasses
import json
import time
from collections import deque

__all__ = ["Span", "SpanTracer", "NullTracer", "NULL_TRACER",
           "spans_to_chrome"]


@dataclasses.dataclass(frozen=True)
class Span:
    """One finished span: name + wall stamps + nesting + attributes."""

    name: str
    t0_s: float  # perf_counter at entry
    dur_s: float
    depth: int  # 0 = top-level
    parent: str | None  # enclosing span's name (None at depth 0)
    attrs: dict


class _NullSpan:
    """Shared no-op context manager: the disabled-tracing fast path."""

    __slots__ = ()

    def __enter__(self) -> "_NullSpan":
        return self

    def __exit__(self, *exc) -> bool:
        return False

    def set(self, **attrs) -> None:
        """Attribute setter, ignored (parity with ``_ActiveSpan.set``)."""


_NULL_SPAN = _NullSpan()


class NullTracer:
    """Tracing disabled: every ``span()`` is the same shared no-op."""

    enabled = False
    spans: tuple = ()

    def span(self, name: str, **attrs) -> _NullSpan:
        return _NULL_SPAN


NULL_TRACER = NullTracer()


class _ActiveSpan:
    """Context manager recording one span into its tracer on exit."""

    __slots__ = ("_tracer", "name", "attrs", "_t0")

    def __init__(self, tracer: "SpanTracer", name: str, attrs: dict):
        self._tracer = tracer
        self.name = name
        self.attrs = attrs

    def set(self, **attrs) -> None:
        """Attach attributes discovered mid-span (e.g. placed count)."""
        self.attrs.update(attrs)

    def __enter__(self) -> "_ActiveSpan":
        self._tracer._stack.append(self.name)
        self._t0 = time.perf_counter()
        return self

    def __exit__(self, *exc) -> bool:
        t1 = time.perf_counter()
        tracer = self._tracer
        stack = tracer._stack
        stack.pop()
        span = Span(
            name=self.name,
            t0_s=self._t0,
            dur_s=t1 - self._t0,
            depth=len(stack),
            parent=stack[-1] if stack else None,
            attrs=self.attrs,
        )
        tracer.spans.append(span)
        tel = tracer.telemetry
        if tel is not None:
            tel.event(
                "span",
                span=span.name,
                t0_s=span.t0_s,
                dur_s=span.dur_s,
                depth=span.depth,
                parent=span.parent,
                attrs=span.attrs,
            )
        return False  # never swallow exceptions

    # exceptions propagate; the span still records its wall time, so a
    # crashing dispatch leaves a trace of where the run died


class SpanTracer:
    """Nested wall-clock spans over an optional ``Telemetry`` sink.

    One tracer per control loop (serve run, trainer); sharing it with
    the loop's executor/controller puts their replan/decision spans on
    the same nesting stack. Not thread-safe — the loops it instruments
    are single-threaded host code.
    """

    enabled = True

    def __init__(self, telemetry=None, *, max_spans: int = 100_000):
        if max_spans <= 0:
            raise ValueError(f"max_spans must be > 0, got {max_spans}")
        self.telemetry = telemetry
        #: finished spans, oldest dropped past ``max_spans`` (the JSONL
        #: sink, when present, keeps every span regardless)
        self.spans: deque[Span] = deque(maxlen=max_spans)
        self._stack: list[str] = []

    def span(self, name: str, **attrs) -> _ActiveSpan:
        """``with tracer.span("decode_chunk", steps=4): ...``"""
        return _ActiveSpan(self, name, attrs)

    # ------------------------------------------------------------- export
    def summary(self) -> dict:
        """Per-name aggregate: count, total/mean/max seconds."""
        agg: dict[str, dict] = {}
        for s in self.spans:
            a = agg.setdefault(
                s.name, {"count": 0, "total_s": 0.0, "max_s": 0.0}
            )
            a["count"] += 1
            a["total_s"] += s.dur_s
            a["max_s"] = max(a["max_s"], s.dur_s)
        for a in agg.values():
            a["mean_s"] = a["total_s"] / a["count"]
        return agg

    def export_chrome(self, path: str) -> str:
        """Write the recorded spans as Chrome ``trace_event`` JSON."""
        recs = [
            {"span": s.name, "t0_s": s.t0_s, "dur_s": s.dur_s,
             "depth": s.depth, "parent": s.parent, "attrs": s.attrs}
            for s in self.spans
        ]
        return spans_to_chrome(recs, path)


def spans_to_chrome(span_records, path: str) -> str:
    """Render ``span`` records (tracer spans OR telemetry JSONL rows)
    into a Perfetto-loadable Chrome ``trace_event`` JSON file.

    Timestamps are microseconds relative to the earliest span, all on
    one pid/tid — nesting renders from the containment of the complete
    (``ph == "X"``) events, exactly how XLA's own traces lay out.
    """
    recs = [r for r in span_records if "t0_s" in r and "dur_s" in r]
    t0 = min((r["t0_s"] for r in recs), default=0.0)
    events = [
        {
            "name": r.get("span", r.get("name", "span")),
            "cat": "repro",
            "ph": "X",
            "ts": (r["t0_s"] - t0) * 1e6,
            "dur": r["dur_s"] * 1e6,
            "pid": 0,
            "tid": 0,
            "args": {
                **(r.get("attrs") or {}),
                "depth": r.get("depth"),
                "parent": r.get("parent"),
            },
        }
        for r in recs
    ]
    with open(path, "w") as f:
        json.dump(
            {"traceEvents": events, "displayTimeUnit": "ms"}, f
        )
    return path
