"""Central telemetry event-schema registry (DESIGN.md §8/§14).

Every ``Telemetry.event`` emitter declares its event here ONCE: name,
emitter, and per-field documentation, split into required and optional
fields. Three consumers keep the declaration honest:

* ``validate_event`` / ``validate_events`` — a tier-1 test drives the
  serve, train and bench paths and validates every emitted record:
  missing required fields, unknown events and undeclared fields all
  fail (typo'd field names no longer ship silently);
* ``render_markdown`` — generates the DESIGN.md §8 event table between
  its ``GENERATED`` markers, so the docs cannot drift from the code
  (``python -m repro.obs.schema`` prints the table,
  ``python -m repro.obs.schema --check DESIGN.md`` verifies it, and a
  tier-1 test does the same);
* ``launch/obsreport.py`` — renders reports from the same field names.

Every event record also carries the sink-stamped common fields
(``COMMON_FIELDS``): the monotonic per-sink sequence number ``t`` and a
``perf_counter`` stamp ``wall_s`` (caller-overridable — ``round_timing``
reuses ``wall_s`` for its measured window).
"""
from __future__ import annotations

import dataclasses
from types import MappingProxyType

__all__ = ["EventSchema", "EVENT_SCHEMAS", "COMMON_FIELDS",
           "validate_event", "validate_events", "render_markdown",
           "BEGIN_MARK", "END_MARK"]

#: stamped by ``Telemetry.event`` itself (absent only in test doubles)
COMMON_FIELDS = ("t", "wall_s")


@dataclasses.dataclass(frozen=True)
class EventSchema:
    """One event's contract: who emits it and what its fields mean."""

    name: str
    emitter: str
    fields: MappingProxyType  # required field -> one-line doc
    optional: MappingProxyType  # optional field -> one-line doc

    def validate(self, rec: dict) -> None:
        # common stamps are implicit — unless the schema declares one
        # explicitly (e.g. round_timing's overriding wall_s), in which
        # case it counts toward the contract like any other field
        implicit = set(COMMON_FIELDS) - set(self.fields)
        present = set(rec) - {"event"} - implicit
        missing = set(self.fields) - present
        if missing:
            raise ValueError(
                f"event {self.name!r} missing required fields "
                f"{sorted(missing)}: {rec}"
            )
        unknown = present - set(self.fields) - set(self.optional)
        if unknown:
            raise ValueError(
                f"event {self.name!r} has undeclared fields "
                f"{sorted(unknown)} (declare them in repro.obs.schema): "
                f"{rec}"
            )


def _schema(name, emitter, fields, optional=()):
    return EventSchema(
        name=name,
        emitter=emitter,
        fields=MappingProxyType(dict(fields)),
        optional=MappingProxyType(dict(optional)),
    )


_SCHEMAS = (
    _schema(
        "adapt_decision",
        "`AdaptiveController.update` — every cadence decision, held or not",
        [
            ("round", "executed-round counter"),
            ("replanned", "bool"),
            ("reason", "`membership` \\| `improvement` \\| `hold`"),
            ("current", "estimated latency of the incumbent plan on the "
                        "estimated cluster; NaN on membership replans"),
            ("candidate", "estimated latency of a fresh plan on the same "
                          "estimates"),
            ("gain", "relative improvement"),
            ("deadline", "post-decision round deadline"),
            ("workers", "post-decision fleet size"),
        ],
    ),
    _schema(
        "replan",
        "`Trainer.replan` — caller-initiated replans (controller replans "
        "emit `adapt_decision` instead)",
        [
            ("workers", "post-replan fleet size"),
            ("n", "coded slots"),
            ("deadline", "post-replan round deadline"),
        ],
    ),
    _schema(
        "all_workers_missed_deadline",
        "`aggregate_with_erasures` — degraded step (previous gradient "
        "reused / zero)",
        [("workers", "fleet size at the degraded step")],
    ),
    _schema(
        "request_admitted",
        "`SlotScheduler.fill_slots` — a queued request entered a stream "
        "slot",
        [
            ("request_id", "workload request id"),
            ("slot", "stream slot index"),
            ("queue_wait", "rounds between arrival and admission"),
            ("deadline_class", "`strict` \\| `standard` \\| `batch`"),
            ("round", "virtual round of the admission"),
        ],
    ),
    _schema(
        "request_evicted",
        "`SlotScheduler.offer` — a request was shed at enqueue time",
        [
            ("request_id", "workload request id"),
            ("reason", "`queue_full` \\| `deadline_risk` \\| "
                       "`pool_exhausted`"),
            ("deadline_class", "the shed request's class"),
            ("round", "virtual round of the shed"),
            ("queue_depth", "queue length at the shed"),
        ],
    ),
    _schema(
        "request_done",
        "`SlotScheduler.retire_done` — a stream finished and freed its "
        "slot",
        [
            ("request_id", "workload request id"),
            ("slot", "stream slot index"),
            ("tokens", "tokens emitted"),
            ("latency", "arrival→last-token rounds"),
            ("deadline_class", "the finished request's class"),
            ("round", "virtual round of the retirement"),
        ],
    ),
    _schema(
        "blocks_freed",
        "`BlockPool.free` (§13) — a retired/evicted request returned its "
        "KV blocks to the pool",
        [
            ("blocks", "blocks returned this call"),
            ("total_freed", "cumulative frees"),
            ("request_id", "owning request (may be null)"),
            ("round", "virtual round"),
        ],
    ),
    _schema(
        "blocks_in_use",
        "`BlockPool.alloc` / `BlockPool.free` (§13) — pool occupancy "
        "after every allocation or release",
        [
            ("in_use", "blocks allocated"),
            ("free", "blocks on the free list"),
            ("capacity", "pool size in blocks"),
            ("request_id", "request that moved the occupancy"),
            ("round", "virtual round"),
        ],
    ),
    _schema(
        "kv_bytes",
        "`BlockPool.alloc` / `BlockPool.free` (§13) — the same "
        "transition in bytes (`bytes_per_block` × blocks)",
        [
            ("bytes_in_use", "bytes allocated"),
            ("bytes_total", "pool size in bytes"),
            ("utilization", "`in_use / capacity`"),
            ("request_id", "request that moved the occupancy"),
            ("round", "virtual round"),
        ],
    ),
    _schema(
        "plan_bucket_hit",
        "`CodedRoundExecutor.replan` (bucket mode, §11) — the new plan's "
        "quantized signature was already admitted: in-program switch, "
        "zero retraces",
        [
            ("structural", "always `false` on a hit"),
            ("bucket", "active bucket slot"),
            ("buckets", "admitted bucket count"),
            ("n", "quantized coded slots"),
            ("n_cap", "padded slot capacity"),
            ("workers", "fleet size"),
        ],
    ),
    _schema(
        "plan_bucket_miss",
        "`CodedRoundExecutor.replan` (bucket mode) — a new bucket was "
        "admitted (`structural=false`, values-only for consumers already "
        "padded to `n_cap`) or the plan escaped the bucket set entirely "
        "(`structural=true`: membership change or `n > n_cap` — the only "
        "replans that still recompile)",
        [
            ("structural", "did the replan change compiled shapes"),
            ("bucket", "active bucket slot"),
            ("buckets", "admitted bucket count"),
            ("n", "quantized coded slots"),
            ("n_cap", "padded slot capacity"),
            ("workers", "fleet size"),
        ],
    ),
    _schema(
        "alloc_cache_hit",
        "`AdaptiveController.update` — the decision's allocation solves "
        "were served from the `allocate` memo cache",
        [
            ("round", "executed-round counter"),
            ("new_hits", "hits since the last decision"),
            ("hits", "cumulative cache hits (`allocate_cache_info()`)"),
            ("misses", "cumulative cache misses"),
            ("size", "entries currently cached"),
        ],
    ),
    _schema(
        "round_timing",
        "`RoundClock.measure` (§12) — one record per measured dispatch, "
        "fed to the controller or not",
        [
            ("round", "clock-local counter"),
            ("wall_s", "full measure window (overrides the common "
                       "`wall_s` stamp)"),
            ("dispatch_s", "dispatch + `block_until_ready`, minus "
                           "injected pad"),
            ("pad_wall_s", "measured injected-pad wall time"),
            ("scale", "this round's seconds-per-unit ÷ the frozen "
                      "calibration `unit_s`; `null` on skipped rounds"),
            ("unit_s", "frozen after the first fed round"),
            ("workers", "fleet size"),
            ("fed", "bool: decomposed times reached the controller"),
            ("skipped", "`null` when fed \\| `warmup` \\| `outlier` \\| "
                        "the `discard_next` reason, e.g. `recompile`"),
            ("t_max", "max decomposed per-worker seconds (finite "
                      "workers only)"),
            ("t_mean", "mean decomposed per-worker seconds"),
        ],
    ),
    _schema(
        "perf_gate",
        "`benchmarks/perf_gate.py` (§12) — one record per gated metric",
        [
            ("metric", "gated metric name"),
            ("measured", "fresh measurement"),
            ("golden", "committed golden value"),
            ("bound", "one-sided tolerance edge"),
            ("tolerance", "allowed relative regression"),
            ("passed", "bool"),
            ("enforced", "bool: ratio metrics always, absolutes only "
                         "under `--absolute`"),
        ],
    ),
    _schema(
        "span",
        "`repro.obs.trace.SpanTracer` (§14) — one finished wall-clock "
        "span from the serve/train/executor/controller loops",
        [
            ("span", "span name (`admit` \\| `prefill_chunk` \\| "
                     "`decode_chunk` \\| `dispatch` \\| `erasure_solve` "
                     "\\| `replan` \\| `bucket_switch` \\| "
                     "`adapt_update`)"),
            ("t0_s", "`perf_counter` at span entry"),
            ("dur_s", "span wall duration, seconds"),
            ("depth", "nesting depth (0 = top-level)"),
            ("parent", "enclosing span's name (`null` at depth 0)"),
            ("attrs", "span attributes (free-form dict: steps, placed, "
                      "structural, ...)"),
        ],
    ),
    _schema(
        "metrics_snapshot",
        "`repro.obs.metrics.MetricsRegistry.emit` (§14) — end-of-run "
        "dump of a loop's counters/gauges/histograms",
        [
            ("metrics", "list of metric rows (name, labels, type, "
                        "value or count/sum/p50/p95/p99/max)"),
            ("size", "number of metric rows"),
        ],
        optional=[
            ("phase", "which loop emitted (`serve` \\| `train`)"),
            ("rounds", "virtual rounds covered by the snapshot"),
        ],
    ),
)

EVENT_SCHEMAS: dict[str, EventSchema] = {s.name: s for s in _SCHEMAS}


def validate_event(rec: dict, *, source: str = "") -> EventSchema:
    """Validate one event record (a ``Telemetry.events`` row, a parsed
    JSONL line, or a test double's ``(name, fields)`` fields dict with
    the name merged in). Raises ``ValueError`` on any violation."""
    name = rec.get("event")
    if name is None:
        raise ValueError(f"record has no 'event' field{source}: {rec}")
    schema = EVENT_SCHEMAS.get(name)
    if schema is None:
        raise ValueError(
            f"unknown event {name!r}{source} — declare it in "
            f"repro.obs.schema: {rec}"
        )
    schema.validate(rec)
    return schema


def validate_events(events, *, source: str = "") -> int:
    """Validate an iterable of event records; returns the count."""
    n = 0
    src = f" (from {source})" if source else ""
    for rec in events:
        validate_event(rec, source=src)
        n += 1
    return n


# ---------------------------------------------------------------- docs
BEGIN_MARK = "<!-- BEGIN GENERATED EVENT SCHEMA (repro.obs.schema) -->"
END_MARK = "<!-- END GENERATED EVENT SCHEMA (repro.obs.schema) -->"


def render_markdown() -> str:
    """The DESIGN.md §8 event table, generated from the registry."""
    lines = [
        "| `event` | emitted by | fields |",
        "|---------|------------|--------|",
    ]
    for s in _SCHEMAS:
        fields = ", ".join(
            f"`{f}` ({doc})" for f, doc in s.fields.items()
        )
        if s.optional:
            fields += "; optional: " + ", ".join(
                f"`{f}` ({doc})" for f, doc in s.optional.items()
            )
        lines.append(f"| `{s.name}` | {s.emitter} | {fields} |")
    return "\n".join(lines)


def extract_generated_block(text: str) -> str:
    """The table between the DESIGN.md markers (raises if absent)."""
    try:
        after = text.split(BEGIN_MARK, 1)[1]
        return after.split(END_MARK, 1)[0].strip()
    except IndexError:
        raise ValueError(
            f"no generated-schema markers ({BEGIN_MARK!r}) found"
        ) from None


def main(argv=None):
    import argparse

    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--check", metavar="DESIGN_MD", default=None,
                    help="verify the file's generated block matches the "
                         "registry instead of printing the table")
    args = ap.parse_args(argv)
    table = render_markdown()
    if args.check is None:
        print(table)
        return
    with open(args.check) as f:
        block = extract_generated_block(f.read())
    if block != table:
        raise SystemExit(
            f"{args.check} event-schema table is stale — regenerate it "
            f"with: python -m repro.obs.schema"
        )
    print(f"{args.check} event-schema table is in sync "
          f"({len(EVENT_SCHEMAS)} events)")


if __name__ == "__main__":
    main()
