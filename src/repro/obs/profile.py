"""XLA chrome-trace summarization for the perf gate (DESIGN.md §14).

``jax.profiler.trace(dir)`` writes a gzipped Chrome ``trace_event``
JSON under ``<dir>/plugins/profile/<ts>/<host>.trace.json.gz``. This
module turns that capture into *attribution*:

* the benchmark wraps each measured phase in a
  ``jax.profiler.TraceAnnotation`` (near-free when no profiler is
  active, so the annotations always stay on), which lands in the trace
  as a complete (``ph == "X"``) event whose ``[ts, ts + dur]`` window
  encloses that phase's op events;
* ``summarize`` buckets every op event into the phase window containing
  its midpoint and aggregates per-phase wall time plus top-K op totals;
* ``diff_summaries`` compares a fresh summary against the golden one
  committed with the gate baseline, names the phase with the worst
  wall-time ratio, and tabulates its op-level deltas — so a failing
  gate row says *which phase regressed and what the ops were doing*,
  not just that a ratio moved.

Stdlib-only parsing (``gzip`` + ``json``): no profiler-analysis deps.
"""
from __future__ import annotations

import glob
import gzip
import json
import os

__all__ = ["find_trace_file", "find_trace_files", "load_trace_events",
           "summarize", "diff_summaries", "format_diff", "TOP_K"]

#: ops kept per phase in summaries and diffs
TOP_K = 5


def find_trace_files(profile_dir: str) -> list[str]:
    """Every ``*.trace.json.gz`` under a profile dir, sorted by mtime.

    Benchmarks capture each phase in its OWN ``jax.profiler`` session
    (written to a per-phase subdir) because the profiler's host event
    buffer is fixed-size — one long session drops the later annotation
    windows. Summaries therefore merge all captures under the dir.
    """
    hits = glob.glob(os.path.join(
        profile_dir, "**", "plugins", "profile", "*", "*.trace.json.gz"
    ), recursive=True)
    return sorted(hits, key=os.path.getmtime)


def find_trace_file(profile_dir: str) -> str | None:
    """Newest ``*.trace.json.gz`` under a ``jax.profiler.trace`` dir."""
    hits = find_trace_files(profile_dir)
    return hits[-1] if hits else None


def load_trace_events(trace_path: str) -> list[dict]:
    """The ``traceEvents`` list of a (gzipped) Chrome trace JSON."""
    opener = gzip.open if trace_path.endswith(".gz") else open
    with opener(trace_path, "rt") as f:
        doc = json.load(f)
    return doc.get("traceEvents", [])


def _is_phase(name: str, phase: str) -> bool:
    # TraceAnnotation names may carry a '#metadata#' suffix in XLA traces
    return name == phase or name.startswith(phase + "#")


def _summarize_events(events: list[dict], phases, out: dict) -> None:
    """Fold one trace's events into the accumulating per-phase summary.

    Timestamps are only compared WITHIN a trace (windows vs midpoints),
    so merging captures with different time bases is sound.
    """
    windows: dict[str, list[tuple[float, float]]] = {p: [] for p in phases}
    ops = []
    for e in events:
        if e.get("ph") != "X" or e.get("dur") is None:
            continue
        name = e.get("name", "")
        for p in phases:
            if _is_phase(name, p):
                windows[p].append((e["ts"], e["ts"] + e["dur"]))
                break
        else:
            ops.append(e)

    for p, wins in windows.items():
        if not wins:
            continue
        summ = out.setdefault(p, {
            "wall_us": 0.0, "op_total_us": 0.0, "n_ops": 0, "ops": {},
        })
        summ["wall_us"] += sum(hi - lo for lo, hi in wins)
    for e in ops:
        mid = e["ts"] + e["dur"] / 2.0
        for p, wins in windows.items():
            if wins and any(lo <= mid <= hi for lo, hi in wins):
                summ = out[p]
                summ["op_total_us"] += e["dur"]
                summ["n_ops"] += 1
                agg = summ["ops"].setdefault(
                    e["name"], {"total_us": 0.0, "count": 0}
                )
                agg["total_us"] += e["dur"]
                agg["count"] += 1


def summarize(profile_dir: str, phases, *, top_k: int = TOP_K) -> dict:
    """Per-phase wall time + top-K op totals from profiler captures.

    Returns ``{phase: {"wall_us", "op_total_us", "n_ops", "ops":
    [{"name", "total_us", "count"}, ...]}}`` for every phase whose
    annotation appears in ANY trace under ``profile_dir`` (benchmarks
    write one capture session per phase — see ``find_trace_files``).
    Op events (any non-annotation ``ph == "X"`` event with a duration)
    are attributed to the phase window containing their midpoint; XLA
    traces nest events across threads, so totals are an attribution
    signal consistent between golden and fresh captures, not an
    exclusive wall-time decomposition.
    """
    trace_paths = find_trace_files(profile_dir)
    if not trace_paths:
        raise FileNotFoundError(
            f"no profiler capture (*.trace.json.gz) under {profile_dir}"
        )
    out: dict[str, dict] = {}
    for trace_path in trace_paths:
        _summarize_events(load_trace_events(trace_path), phases, out)
    for summ in out.values():
        summ["ops"] = [
            {"name": n, **v}
            for n, v in sorted(
                summ["ops"].items(),
                key=lambda kv: kv[1]["total_us"],
                reverse=True,
            )[:top_k]
        ]
    return out


def diff_summaries(measured: dict, golden: dict, *,
                   top_k: int = TOP_K) -> dict:
    """Compare a fresh phase summary against the golden one.

    Returns ``{"phases": {phase: {"wall_ratio", "measured_wall_us",
    "golden_wall_us"}}, "worst_phase", "worst_ratio", "worst_ops":
    [{"name", "measured_us", "golden_us", "ratio"}, ...]}`` over the
    phases present in both summaries; ``worst_phase`` is the one whose
    wall time grew the most relative to golden.
    """
    shared = sorted(set(measured) & set(golden))
    phases = {}
    for p in shared:
        m, g = measured[p]["wall_us"], golden[p]["wall_us"]
        phases[p] = {
            "wall_ratio": (m / g) if g > 0 else float("inf"),
            "measured_wall_us": m,
            "golden_wall_us": g,
        }
    if not phases:
        return {"phases": {}, "worst_phase": None, "worst_ratio": None,
                "worst_ops": []}
    worst = max(phases, key=lambda p: phases[p]["wall_ratio"])
    m_ops = {o["name"]: o for o in measured[worst].get("ops", [])}
    g_ops = {o["name"]: o for o in golden[worst].get("ops", [])}
    rows = []
    for name in sorted(set(m_ops) | set(g_ops)):
        mu = m_ops.get(name, {}).get("total_us", 0.0)
        gu = g_ops.get(name, {}).get("total_us", 0.0)
        rows.append({
            "name": name,
            "measured_us": mu,
            "golden_us": gu,
            "ratio": (mu / gu) if gu > 0 else float("inf"),
        })
    rows.sort(key=lambda r: max(r["measured_us"], r["golden_us"]),
              reverse=True)
    return {
        "phases": phases,
        "worst_phase": worst,
        "worst_ratio": phases[worst]["wall_ratio"],
        "worst_ops": rows[:top_k],
    }


def format_diff(diff: dict) -> str:
    """Human-readable rendering of a ``diff_summaries`` result."""
    if not diff.get("phases"):
        return "profile diff: no shared phases between capture and golden"
    lines = ["profile attribution (phase wall time vs golden):"]
    for p, row in sorted(diff["phases"].items(),
                         key=lambda kv: kv[1]["wall_ratio"],
                         reverse=True):
        mark = "  <-- regressed" if p == diff["worst_phase"] else ""
        lines.append(
            f"  {p:<16s} {row['measured_wall_us'] / 1e3:10.2f} ms vs "
            f"{row['golden_wall_us'] / 1e3:10.2f} ms  "
            f"(x{row['wall_ratio']:.2f}){mark}"
        )
    lines.append(
        f"top ops in regressed phase '{diff['worst_phase']}' "
        f"(measured vs golden, us):"
    )
    for o in diff["worst_ops"]:
        ratio = ("inf" if o["ratio"] == float("inf")
                 else f"{o['ratio']:.2f}")
        lines.append(
            f"  {o['name'][:48]:<48s} {o['measured_us']:10.0f} vs "
            f"{o['golden_us']:10.0f}  (x{ratio})"
        )
    if not diff["worst_ops"]:
        lines.append(
            "  (no ops attributed — wall-time growth is host-side: "
            "sleeps, Python overhead, or dispatch gaps)"
        )
    return "\n".join(lines)
