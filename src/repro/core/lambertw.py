"""Lambert W function in pure JAX (jit/vmap/grad-able).

The paper's optimal allocation (Theorem 2) is built on the lower branch
``W_{-1}(z)`` for ``z = -exp(-(alpha*mu + 1)) in [-1/e, 0)``. We provide
both real branches:

* ``lambertw0(z)``  — principal branch, ``z >= -1/e``, ``W >= -1``.
* ``lambertwm1(z)`` — lower branch, ``z in [-1/e, 0)``, ``W <= -1``.

Implementation: branch-appropriate initial guess followed by a fixed
number of Halley iterations (quadratic+ convergence; 8 iterations reach
float64 machine precision over the full domain — validated against
``scipy.special.lambertw`` in tests/test_lambertw.py).
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

_HALLEY_ITERS = 12


def _halley(w, z, iters: int = _HALLEY_ITERS):
    """Halley iterations for f(w) = w e^w - z."""

    def body(w, _):
        ew = jnp.exp(w)
        f = w * ew - z
        # Halley: w' = w - f / (ew*(w+1) - (w+2)*f / (2w+2))
        denom = ew * (w + 1.0) - (w + 2.0) * f / (2.0 * w + 2.0)
        # Guard against exactly-converged points (denom fine there) and the
        # branch point w = -1 where denom -> 0.
        step = f / jnp.where(jnp.abs(denom) > 0, denom, 1.0)
        w_new = w - step
        return w_new, None

    w, _ = jax.lax.scan(body, w, None, length=iters)
    return w


def lambertwm1(z):
    """Lower real branch ``W_{-1}`` on ``[-1/e, 0)``.

    Returns ``W`` with ``W(z) e^{W(z)} = z`` and ``W <= -1``. Values
    outside the domain return NaN (z > 0 or z < -1/e).
    """
    z = jnp.asarray(z, dtype=jnp.result_type(z, jnp.float64))
    # Branch-point series: z = -1/e + eps; p = -sqrt(2(1 + e z)) (negative
    # root selects the lower branch). W ~ -1 + p - p^2/3 + 11 p^3 / 72.
    ez1 = 1.0 + jnp.e * z
    p = -jnp.sqrt(jnp.maximum(2.0 * ez1, 0.0))
    w_series = -1.0 + p - p * p / 3.0 + 11.0 * p**3 / 72.0
    # Asymptotic for z -> 0^-: W ~ log(-z) - log(-log(-z)).
    lz = jnp.log(jnp.maximum(-z, jnp.finfo(z.dtype).tiny))
    w_asym = lz - jnp.log(-lz)
    w0 = jnp.where(ez1 < 0.05, w_series, w_asym)
    # Keep strictly below -1 so Halley stays on the lower branch.
    w0 = jnp.minimum(w0, -1.0 - 1e-12)
    w = _halley(w0, z)
    valid = (z >= -jnp.exp(-1.0) - 1e-300) & (z < 0)
    return jnp.where(valid, w, jnp.nan)


def lambertwm1_neg_exp(c):
    """``W_{-1}(-exp(-c))`` for c >= 1, stable even when exp(-c) underflows.

    The allocation formulas only ever evaluate W_{-1} at z = -e^{-(alpha
    mu + 1)}; for alpha*mu beyond ~700 the argument underflows to -0.0
    and the direct branch returns NaN. In log space the defining equation
    w e^w = -e^{-c} becomes u = c + log(u) with w = -u, a fast-converging
    fixed point for large c.

    The whole function is a handful of fused element-wise ops with a
    static-trip ``fori_loop`` (reverse-differentiable: static bounds
    lower to scan), so it jits into the single-program allocation cores
    of ``core/alloc_fastpath.py`` with no host round-trips.
    """
    c = jnp.asarray(c, dtype=jnp.result_type(c, jnp.float64))
    direct = lambertwm1(-jnp.exp(-jnp.minimum(c, 30.0)))
    u0 = c + jnp.log(jnp.maximum(c, 1.1))
    u = jax.lax.fori_loop(0, 5, lambda _, u: c + jnp.log(u), u0)
    return jnp.where(c < 30.0, direct, -u)


def lambertw0(z):
    """Principal real branch ``W_0`` on ``[-1/e, inf)``."""
    z = jnp.asarray(z, dtype=jnp.result_type(z, jnp.float64))
    ez1 = 1.0 + jnp.e * z
    p = jnp.sqrt(jnp.maximum(2.0 * ez1, 0.0))
    w_series = -1.0 + p - p * p / 3.0 + 11.0 * p**3 / 72.0
    # For large z: W ~ log z - log log z.
    lz = jnp.log(jnp.maximum(z, jnp.finfo(z.dtype).tiny))
    w_large = lz - jnp.log(jnp.maximum(lz, jnp.finfo(z.dtype).tiny))
    w0 = jnp.where(z < 0.25, w_series, jnp.where(z < 3.0, jnp.log1p(z) * 0.7, w_large))
    w0 = jnp.maximum(w0, -1.0 + 1e-12)
    w = _halley(w0, z)
    valid = z >= -jnp.exp(-1.0) - 1e-300
    return jnp.where(valid, w, jnp.nan)
