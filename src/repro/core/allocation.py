"""Load-allocation algorithms (the paper's core contribution, Section III).

Implemented schemes
-------------------
* ``optimal_allocation``        — Theorem 2 (model (1)); with
  ``per_row=True`` this is Corollary 2 (Section III-E, the model of [32]).
* ``t_star``                    — minimum expected latency, eq. (18)/(33).
* ``uniform_given_n``           — Section III-D-1: ``l = n/N``.
* ``uniform_given_r``           — Section III-D-2 / Theorem 4 (= [33]):
  ``l = k/r`` with the per-group split ``r_j`` solved from eq. (28)+(26).
* ``reisizadeh_allocation``     — Appendix D (the scheme of [32]).
* ``comm_aware_allocation``     — communication-delay-aware optimum
  under the CommDelay model (arXiv:2109.11246): per-group transfer
  terms shift the Lambert-W inner problem and break the closed form of
  the outer deadline equation, which is solved numerically. Degenerates
  exactly to ``optimal_allocation`` when every transfer term vanishes.
* ``comm_uniform_allocation``   — uniform-split baseline under the same
  comm model (the comparison scheme of ``benchmarks/fig_comm.py``).
* ``gradient_coding_allocation`` — Theorem-2 balancing applied to
  gradient partitions (Wang et al. 2019, arXiv:1901.09339): same
  equalized-finish-time loads, clamped to the partition count ``k``
  (the coding itself lives in ``core/gradient_coding.py``).

All functions are pure jnp (jittable, differentiable where meaningful)
and operate on per-group arrays ``(N, mu, alpha)``; ``ClusterSpec`` from
``runtime_model`` is the user-facing wrapper.
"""
from __future__ import annotations

import contextlib
import dataclasses

import jax
import jax.numpy as jnp
import numpy as np

from repro.core import alloc_fastpath
from repro.core.lambertw import lambertwm1_neg_exp
from repro.core.runtime_model import (
    ClusterSpec,
    LatencyModel,
    comm_terms,
    resolve_latency_model,
    xi,
)

# --------------------------------------------------------------- fast path
#
# Every solver below has two implementations: the original eager/numpy
# path (the parity ORACLE — small eager jnp ops plus host bisections)
# and a jitted core in ``core/alloc_fastpath.py`` that fuses the whole
# solve into one compiled program (~sub-ms warm vs ~0.4 s eager). The
# fast path is the default; ``eager_oracle()`` forces the oracle for
# parity tests and A/B timing. Host-side integerization and plan
# assembly are shared by both paths.

_USE_FASTPATH = True

#: residual tolerance of the eager bisections' early exit
BISECT_TOL = 1e-12
#: residual bound ASSERTED after every eager bisection (satellite of
#: ISSUE 7; also pinned by tests/test_alloc_fastpath.py)
BISECT_RESIDUAL_BOUND = 1e-9


def fastpath_enabled() -> bool:
    """Whether allocation solves route through the jitted cores."""
    return _USE_FASTPATH


def set_fastpath(enabled: bool) -> bool:
    """Toggle the jitted fast path globally; returns the previous value."""
    global _USE_FASTPATH
    prev = _USE_FASTPATH
    _USE_FASTPATH = bool(enabled)
    return prev


@contextlib.contextmanager
def eager_oracle():
    """Force the eager/numpy oracle path within the block."""
    prev = set_fastpath(False)
    try:
        yield
    finally:
        set_fastpath(prev)


def _fastpath(flag: bool | None) -> bool:
    return _USE_FASTPATH if flag is None else bool(flag)


@dataclasses.dataclass(frozen=True)
class AllocationPlan:
    """Result of a load-allocation computation.

    Attributes:
      loads: per-group real-valued loads ``l_(j)`` (rows of coded A per
        worker in group j).
      loads_int: integerized loads ``ceil(l_(j))`` used for deployment.
      r: per-group expected completion counts ``r_j`` (real).
      n: total coded rows ``n = sum_j N_j l_(j)`` (real).
      n_int: integer total coded rows from ``loads_int``.
      k: number of uncoded rows.
      t_star: the scheme's expected-latency value (lower bound for the
        optimal scheme; analytic expectation otherwise; NaN if unknown).
      scheme: name tag (derived from ``scheme_obj`` when one is attached).
      scheme_obj: the typed ``AllocationScheme`` that produced this plan
        (set by ``repro.core.schemes``; None for plans built by calling
        the bare allocation functions below).
    """

    loads: np.ndarray
    loads_int: np.ndarray
    r: np.ndarray
    n: float
    n_int: int
    k: int
    t_star: float
    scheme: str
    scheme_obj: object | None = None

    @property
    def rate(self) -> float:
        """MDS code rate k/n."""
        return self.k / self.n


def _w_term(mu, alpha):
    """W_{-1}(-exp(-(alpha*mu + 1))) — appears throughout Theorem 2.

    Evaluated in log space so large alpha*mu (near-deterministic workers)
    stays finite instead of underflowing to NaN.
    """
    return lambertwm1_neg_exp(alpha * mu + 1.0)


def optimal_r(n_workers, mu, alpha):
    """r*_j = N_j (1 + 1 / W_{-1}(-e^{-(alpha mu + 1)}))  (eq. (15)).

    Identical under both probabilistic models (the W-term does not see
    the load scaling).
    """
    return n_workers * (1.0 + 1.0 / _w_term(mu, alpha))


def xi_star(mu, alpha):
    """xi(r*_j, N_j, mu_j) = alpha + log(-W_{-1}(.))/mu  (eq. (17))."""
    return alpha + jnp.log(-_w_term(mu, alpha)) / mu


def t_star(
    n_workers,
    mu,
    alpha,
    k: int | None = None,
    *,
    per_row: bool | None = None,
    model: LatencyModel | None = None,
):
    """Minimum expected latency T* (eq. (18)); T*_b (eq. (33)) for MODEL_30."""
    model = resolve_latency_model(model, per_row)
    denom = jnp.sum(-mu * n_workers / _w_term(mu, alpha))
    t = 1.0 / denom
    if model.per_row:
        if k is None:
            raise ValueError("per-row model (30) latency scales with k")
        t = t * k
    return t


def optimal_allocation(
    cluster: ClusterSpec,
    k: int,
    *,
    per_row: bool | None = None,
    model: LatencyModel | None = None,
    fastpath: bool | None = None,
) -> AllocationPlan:
    """Theorem 2 (or Corollary 2 under ``LatencyModel.MODEL_30``).

    Returns the optimal per-group loads l*_(j), the optimal (n*, k) MDS
    code, and the lower-bound latency T*.
    """
    model = resolve_latency_model(model, per_row)
    n_w, mu, al = cluster.arrays()
    if _fastpath(fastpath):
        loads, r, n, t = alloc_fastpath.optimal_core(n_w, mu, al, float(k))
        if model.per_row:
            t = float(t) * k
    else:
        r = optimal_r(n_w, mu, al)
        xs = xi_star(mu, al)
        # l*_j = k / (r_j + sum_{j'!=j} r_j' xi_j / xi_j')   (eq. (16))
        # = k / (xi_j * sum_{j'} r_j' / xi_j')
        s = jnp.sum(r / xs)
        loads = k / (xs * s)
        n = jnp.sum(n_w * loads)
        t = t_star(n_w, mu, al, k, model=model)
    loads_np = np.asarray(loads)
    loads_int = np.ceil(loads_np - 1e-9).astype(np.int64)
    return AllocationPlan(
        loads=loads_np,
        loads_int=loads_int,
        r=np.asarray(r),
        n=float(n),
        n_int=int(np.sum(np.asarray(n_w, dtype=np.int64) * loads_int)),
        k=k,
        t_star=float(t),
        scheme="optimal_per_row" if model.per_row else "optimal",
    )


def uniform_given_n(cluster: ClusterSpec, k: int, n: float) -> AllocationPlan:
    """Section III-D-1: every worker gets l = n/N rows of the (n,k) code.

    The master needs ceil(kN/n) finished workers (eq. (26)). t_star is
    left NaN — the heterogeneous-mixture order statistic has no simple
    closed form; use the Monte Carlo simulator.
    """
    n_w, mu, al = cluster.arrays()
    big_n = cluster.total_workers
    l = n / big_n
    loads = np.full((cluster.num_groups,), l)
    # Completion split is not fixed a priori for uniform-n; record the
    # total requirement r = kN/n spread proportionally (informational).
    r_total = k * big_n / n
    r = np.asarray(n_w) / big_n * r_total
    loads_int = np.ceil(loads - 1e-9).astype(np.int64)
    return AllocationPlan(
        loads=loads,
        loads_int=loads_int,
        r=r,
        n=float(n),
        n_int=int(np.sum(np.asarray(n_w, dtype=np.int64) * loads_int)),
        k=k,
        t_star=float("nan"),
        scheme="uniform_n",
    )


def group_code_split(
    cluster: ClusterSpec, r: int, *, fastpath: bool | None = None
) -> np.ndarray:
    """Solve eq. (28)+(26) for the per-group split (r_1..r_G), sum = r.

    From eq. (28) the equalized tail gives r_j = N_j (1 - exp(-mu_j c))
    for a common c > 0; eq. (26) fixes c by sum_j r_j = r. The left side
    is strictly increasing in c with range (0, N), so bisection always
    converges for 0 < r < N. (The paper notes eq. (29) written per-group
    may have no simultaneous integer solution for G > 2; the equalized-c
    form is the continuous relaxation that Corollary 1 optimizes.)
    """
    assert 0 < r < cluster.total_workers, "need r in (0, N)"
    n_w, mu, _ = cluster.arrays()
    if _fastpath(fastpath):
        return np.asarray(
            alloc_fastpath.group_split_core(n_w, mu, float(r))
        )
    n_w = np.asarray(n_w)
    mu = np.asarray(mu)

    def total(c):
        return float(np.sum(n_w * (1.0 - np.exp(-mu * c))))

    scale = max(1.0, float(r))
    lo, hi = 0.0, 1.0
    while total(hi) < r:
        hi *= 2.0
    for _ in range(200):
        mid = 0.5 * (lo + hi)
        res = total(mid) - r
        if abs(res) <= BISECT_TOL * scale:  # converged: stop early
            lo = hi = mid
            break
        if res < 0:
            lo = mid
        else:
            hi = mid
    c = 0.5 * (lo + hi)
    residual = abs(total(c) - r)
    assert residual < BISECT_RESIDUAL_BOUND * scale, (
        f"group split bisection residual {residual:.3e} (r={r})"
    )
    return n_w * (1.0 - np.exp(-mu * c))


def uniform_given_r(cluster: ClusterSpec, k: int, r: int) -> AllocationPlan:
    """Section III-D-2 / Theorem 4 — the group-code scheme of [33].

    Every worker stores l = k/r rows; group j uses an (N_j, r_j) MDS code
    with the split from eq. (28)+(26). As N -> inf the expected latency
    converges to 1/r (the paper's explanation of the scheme's latency
    floor). t_star records that floor.
    """
    n_w, mu, al = cluster.arrays()
    l = k / r
    loads = np.full((cluster.num_groups,), l)
    r_split = group_code_split(cluster, r)
    loads_int = np.ceil(loads - 1e-9).astype(np.int64)
    n = float(l * cluster.total_workers)
    return AllocationPlan(
        loads=loads,
        loads_int=loads_int,
        r=r_split,
        n=n,
        n_int=int(np.sum(np.asarray(n_w, dtype=np.int64) * loads_int)),
        k=k,
        t_star=1.0 / r,
        scheme="uniform_r_group_code",
    )


def reisizadeh_allocation(
    cluster: ClusterSpec, k: int, *, fastpath: bool | None = None
) -> AllocationPlan:
    """Appendix D — the heterogeneous allocation of [32].

    l~_j = k / (s * delta_j) with
    delta_j = -(W_{-1}(-e^{-(alpha mu + 1)}) + 1)/mu and
    s = sum_j N_j mu_j / (1 + mu_j delta_j). Defined for the per-row
    model (30); the paper shows it coincides with Corollary 2's optimum.
    """
    n_w, mu, al = cluster.arrays()
    if _fastpath(fastpath):
        loads, r, n = alloc_fastpath.reisizadeh_core(n_w, mu, al, float(k))
        r = np.asarray(r)
    else:
        w = _w_term(mu, al)
        delta = -(w + 1.0) / mu
        s = jnp.sum(n_w * mu / (1.0 + mu * delta))
        loads = k / (s * delta)
        n = jnp.sum(n_w * loads)
        # Expected completion counts at the equalized deadline = r*_j.
        r = np.asarray(optimal_r(n_w, mu, al))
    loads_np = np.asarray(loads)
    loads_int = np.ceil(loads_np - 1e-9).astype(np.int64)
    return AllocationPlan(
        loads=loads_np,
        loads_int=loads_int,
        r=r,
        n=float(n),
        n_int=int(np.sum(np.asarray(n_w, dtype=np.int64) * loads_int)),
        k=k,
        t_star=float("nan"),
        scheme="reisizadeh",
    )


def comm_deadline_terms(cluster: ClusterSpec, upload: float, download: float):
    """CommDelay per-group terms ``(c, g, xi*)`` of the deadline equation.

    ``c_j = upload/b_j`` is the fixed transfer shift; the download cost
    ``download/b_j`` adds to ``alpha_j`` before the Lambert-W inner
    problem, giving throughput slope ``g_j = r*_j/xi*_j = -mu_j N_j/W_j``
    and ``xi*_j = -(1 + W_j)/mu_j``. The comm-augmented lower bound is
    the root of ``sum_j g_j (t - c_j)_+ = 1`` (see
    ``comm_aware_allocation``).
    """
    n_w, mu, al = cluster.arrays()
    c, dal = comm_terms(cluster, upload, download)
    a_eff = np.asarray(al) + dal
    w = _w_term(np.asarray(mu), a_eff)
    g = np.asarray(-np.asarray(mu) * np.asarray(n_w) / w)
    xs = np.asarray(-(1.0 + w) / np.asarray(mu))
    return c, g, xs


def comm_t_star(
    cluster: ClusterSpec,
    upload: float,
    download: float,
    *,
    fastpath: bool | None = None,
) -> float:
    """Comm-augmented minimum expected latency (numeric; bound of fig_comm).

    Solves ``sum_j g_j (t - c_j)_+ = 1`` for t. The left side is a
    piecewise-linear increasing function of t (kinks at the per-group
    transfer shifts c_j), so bisection on
    ``[min c, max c + 1/sum g]`` always converges; with all ``c_j = 0``
    the closed form ``t = 1/sum_j g_j`` (= eq. (18) at the comm-shifted
    alphas) is returned directly — the Lambert-W fast path.
    """
    if _fastpath(fastpath):
        n_w, mu, al = cluster.arrays()
        c, dal = comm_terms(cluster, upload, download)
        # t does not depend on k; any k gives the same deadline root
        _, _, _, t = alloc_fastpath.comm_core(
            n_w, mu, al + jnp.asarray(dal), jnp.asarray(c), 1.0
        )
        return float(t)
    c, g, _ = comm_deadline_terms(cluster, upload, download)
    if np.all(c == 0.0):
        return float(1.0 / np.sum(g))

    def covered(t):
        return float(np.sum(g * np.maximum(t - c, 0.0)))

    lo = float(np.min(c))
    hi = float(np.max(c) + 1.0 / np.sum(g))
    for _ in range(200):
        mid = 0.5 * (lo + hi)
        res = covered(mid) - 1.0
        if abs(res) <= BISECT_TOL:  # converged: stop early
            lo = hi = mid
            break
        if res < 0:
            lo = mid
        else:
            hi = mid
    t = 0.5 * (lo + hi)
    residual = abs(covered(t) - 1.0)
    assert residual < BISECT_RESIDUAL_BOUND, (
        f"comm deadline bisection residual {residual:.3e}"
    )
    return t


def comm_aware_allocation(
    cluster: ClusterSpec,
    k: int,
    *,
    upload: float = 1.0,
    download: float = 1.0,
    fastpath: bool | None = None,
) -> AllocationPlan:
    """Communication-delay-aware optimal allocation (arXiv:2109.11246).

    Under the CommDelay model each group pays a fixed input-broadcast
    shift ``c_j = upload/b_j`` and a per-load download cost that shifts
    ``alpha_j`` by ``download/b_j``. The paper's inner problem (the best
    completion fraction per group) is untouched by ``c_j`` — maximizing
    ``r_j/xi_j(r_j)`` still gives the Lambert-W solution at the shifted
    alpha — but the outer deadline equation becomes

        sum_j g_j * max(t - c_j, 0) = 1,    g_j = -mu_j N_j / W_j,

    which has no closed form for heterogeneous ``c_j`` and is solved by
    bisection (``comm_t_star``). Loads follow as
    ``l_j = k (t* - c_j)_+ / xi*_j``: groups whose transfer shift
    exceeds the optimal deadline get ZERO load — slow links are excluded
    entirely, the qualitative change communication awareness buys.

    With every transfer term zero (infinite bandwidths, or
    ``upload == download == 0``) this delegates to
    ``optimal_allocation`` and reproduces its plan exactly.
    """
    # unlike the paper's schemes, the transfer costs are NOT recoverable
    # from the plan's own fields, so attach the typed scheme here (lazy
    # import; schemes.py imports us) — replan/deadline on a plan built
    # from this bare function must not silently fall back to default costs
    from repro.core.schemes import CommAware

    scheme_obj = CommAware(upload=float(upload), download=float(download))
    c, dal = comm_terms(cluster, upload, download)
    if np.all(c == 0.0) and np.all(dal == 0.0):
        # transfer terms vanish entirely -> exact Theorem 2 plan
        plan = optimal_allocation(cluster, k, fastpath=fastpath)
        return dataclasses.replace(
            plan, scheme="comm_aware", scheme_obj=scheme_obj
        )
    n_w, mu, al = cluster.arrays()
    if _fastpath(fastpath):
        loads, r, _n, t = alloc_fastpath.comm_core(
            n_w, mu, al + jnp.asarray(dal), jnp.asarray(c), float(k)
        )
        loads_np = np.asarray(loads)
        r = np.asarray(r)
        t = float(t)
    else:
        _, g, xs = comm_deadline_terms(cluster, upload, download)
        t = comm_t_star(cluster, upload, download, fastpath=False)
        slack = np.maximum(t - c, 0.0)
        loads_np = np.asarray(k * slack / xs)
        active = loads_np > 0
        r_star = np.asarray(optimal_r(n_w, mu, np.asarray(al) + dal))
        r = np.where(active, r_star, 0.0)
    loads_int = np.ceil(loads_np - 1e-9).astype(np.int64)
    n = float(np.sum(np.asarray(n_w) * loads_np))
    return AllocationPlan(
        loads=loads_np,
        loads_int=loads_int,
        r=r,
        n=n,
        n_int=int(np.sum(np.asarray(n_w, dtype=np.int64) * loads_int)),
        k=k,
        t_star=float(t),
        scheme="comm_aware",
        scheme_obj=scheme_obj,
    )


def comm_uniform_allocation(
    cluster: ClusterSpec,
    k: int,
    *,
    n: float | None = None,
    upload: float = 1.0,
    download: float = 1.0,
) -> AllocationPlan:
    """Uniform-split baseline under the CommDelay model.

    Every worker (slow links included) gets ``l = n/N`` rows of an
    ``(n, k)`` code; ``n`` defaults to the comm-aware optimum's code
    size, i.e. "same redundancy, comm-blind uniform split". No analytic
    latency (heterogeneous mixture + per-group shifts) — t_star is NaN
    and consumers fall back to Monte Carlo, like ``uniform_given_n``.
    """
    from repro.core.schemes import CommUniform  # lazy: schemes imports us

    if n is None:
        n = comm_aware_allocation(
            cluster, k, upload=upload, download=download
        ).n
    plan = uniform_given_n(cluster, k, float(n))
    return dataclasses.replace(
        plan,
        scheme="comm_uniform",
        scheme_obj=CommUniform(
            n=float(n), upload=float(upload), download=float(download)
        ),
    )


def gradient_coding_allocation(
    cluster: ClusterSpec,
    k: int,
    *,
    model: LatencyModel | None = None,
) -> AllocationPlan:
    """Theorem-2 load balancing applied to gradient partitions (Wang et
    al. 2019, arXiv:1901.09339).

    The global batch is split into ``k`` partitions; a group-j worker
    computes ``l_j`` coded partition-gradients per step, and the master
    needs any ``k`` coded rows to recover the full-batch gradient
    (``core/gradient_coding.py``). The per-group balancing problem is
    IDENTICAL to the paper's coded-matvec one — equalize the expected
    per-group finish time under the shifted-exponential model — so the
    loads are Theorem 2's, with one gradient-specific constraint: no
    worker can usefully hold more than ``k`` partitions (computing the
    whole batch), so loads are clamped to ``k``. The clamp only binds on
    degenerate fleets (a near-solo worker); Theorem 2's ``T*`` remains a
    valid lower bound either way.
    """
    model = resolve_latency_model(model)
    plan = optimal_allocation(cluster, k, model=model)
    loads = np.minimum(plan.loads, float(k))
    loads_int = np.minimum(plan.loads_int, k)
    n_w = np.asarray([g.num_workers for g in cluster.groups], dtype=np.int64)
    return dataclasses.replace(
        plan,
        loads=loads,
        loads_int=loads_int,
        n=float(np.sum(n_w * loads)),
        n_int=int(np.sum(n_w * loads_int)),
        scheme="grad_coding_per_row" if model.per_row else "grad_coding",
    )


def uncoded(cluster: ClusterSpec, k: int) -> AllocationPlan:
    """Uncoded baseline: n = k, uniform split, wait for every worker."""
    plan = uniform_given_n(cluster, k, float(k))
    return dataclasses.replace(plan, scheme="uncoded")
