"""Cluster planner: ClusterSpec -> deployable coded-computation plan.

Bridges a scheme's real-valued allocation and an executable assignment:
integer per-worker row counts, generator size, worker->rows map, and
re-planning hooks for elasticity (the closed-form solution makes
re-planning O(G) — this is what makes the scheme practical at fleet
scale: no iterative optimizer in the failure path).

Scheme selection is object-based: ``deploy(scheme, cluster, k)`` takes a
typed ``AllocationScheme`` from ``repro.core.schemes``; the plan carries
the scheme object so ``replan_on_membership_change`` preserves every
scheme parameter (n, r, latency model) across membership changes.
``plan_deployment(scheme="optimal", ...)`` remains as a thin shim that
resolves string names through the registry.
"""
from __future__ import annotations

import dataclasses
from typing import Sequence

import numpy as np

from repro.core.allocation import AllocationPlan
from repro.core.runtime_model import ClusterSpec, LatencyModel
from repro.core.schemes import AllocationScheme, make_scheme, scheme_for_plan


@dataclasses.dataclass(frozen=True)
class DeploymentPlan:
    """Integerized, executable plan for one coded matvec deployment."""

    cluster: ClusterSpec
    k: int
    loads_per_worker: np.ndarray  # (N,) int rows of coded A per worker
    group_of_worker: np.ndarray  # (N,) int group index per worker
    row_ranges: tuple  # worker -> (start, stop) into coded rows
    n: int  # total coded rows actually deployed
    t_star: float  # paper lower bound for the underlying real plan
    scheme: str
    scheme_obj: AllocationScheme | None = None
    allocation: AllocationPlan | None = None  # underlying real-valued plan

    @property
    def num_workers(self) -> int:
        return int(self.loads_per_worker.shape[0])

    @property
    def rate(self) -> float:
        return self.k / self.n

    @property
    def max_load(self) -> int:
        return int(self.loads_per_worker.max())


def _expand(cluster: ClusterSpec, per_group: Sequence[int]):
    loads, gid = [], []
    for j, g in enumerate(cluster.groups):
        loads += [int(per_group[j])] * g.num_workers
        gid += [j] * g.num_workers
    return np.asarray(loads, dtype=np.int64), np.asarray(gid, dtype=np.int64)


def integerize(cluster: ClusterSpec, plan: AllocationPlan) -> DeploymentPlan:
    """Expand a per-group AllocationPlan into a per-worker DeploymentPlan."""
    loads_w, gid = _expand(cluster, plan.loads_int)
    starts = np.concatenate([[0], np.cumsum(loads_w)[:-1]])
    ranges = tuple(
        (int(s), int(s + l)) for s, l in zip(starts, loads_w)
    )
    return DeploymentPlan(
        cluster=cluster,
        k=plan.k,
        loads_per_worker=loads_w,
        group_of_worker=gid,
        row_ranges=ranges,
        n=int(loads_w.sum()),
        t_star=plan.t_star,
        scheme=plan.scheme,
        scheme_obj=plan.scheme_obj,
        allocation=plan,
    )


def deploy(
    scheme: AllocationScheme, cluster: ClusterSpec, k: int
) -> DeploymentPlan:
    """Allocate with a typed scheme and integerize for deployment."""
    return integerize(cluster, scheme.allocate(cluster, k))


def plan_deployment(
    cluster: ClusterSpec,
    k: int,
    *,
    scheme: str | AllocationScheme = "optimal",
    per_row: bool | None = None,
    model: LatencyModel | None = None,
    n: float | None = None,
    r: int | None = None,
) -> DeploymentPlan:
    """Compute an integerized deployment plan for the requested scheme.

    Deprecation shim: string names (plus the legacy per_row/n/r params)
    are resolved through the scheme registry; prefer passing an
    ``AllocationScheme`` object (or calling ``deploy``) directly.
    """
    if not isinstance(scheme, AllocationScheme):
        scheme = make_scheme(scheme, per_row=per_row, model=model, n=n, r=r)
    return deploy(scheme, cluster, k)


def replan_on_membership_change(
    plan: DeploymentPlan, new_cluster: ClusterSpec
) -> DeploymentPlan:
    """Elastic re-planning: the plan's scheme on the new membership.

    Called by the fault-tolerance layer when workers join/leave or when
    online mu estimates are refreshed. O(G) cost. The scheme object rides
    on the plan, so scheme parameters (n, r, latency model) survive the
    re-plan for every scheme — not just the optimal one.
    """
    return deploy(scheme_for_plan(plan), new_cluster, plan.k)


def estimate_mu_online(samples_per_group: Sequence[np.ndarray], k: int, loads):
    """MLE of (mu_j, alpha_j) from observed per-worker round-trip times.

    Shifted exponential MLE: alpha_hat = min(t) * k / l;
    mu_hat = 1 / (mean(t - min(t)) * k / l). Feeds the planner's
    re-planning loop (straggler-parameter drift tracking).
    """
    mus, alphas = [], []
    for t, l in zip(samples_per_group, loads):
        t = np.asarray(t, dtype=np.float64) * (k / float(l))
        t0 = float(t.min())
        alphas.append(t0)
        excess = float(t.mean() - t0)
        mus.append(1.0 / max(excess, 1e-12))
    return np.asarray(mus), np.asarray(alphas)
