"""Cluster planner: ClusterSpec -> deployable coded-computation plan.

Bridges the paper's real-valued optimum (Theorem 2) and an executable
assignment: integer per-worker row counts, generator size, worker->rows
map, and re-planning hooks for elasticity (the closed-form solution makes
re-planning O(G) — this is what makes the scheme practical at fleet
scale: no iterative optimizer in the failure path).
"""
from __future__ import annotations

import dataclasses
from typing import Sequence

import numpy as np

from repro.core import allocation
from repro.core.runtime_model import ClusterSpec


@dataclasses.dataclass(frozen=True)
class DeploymentPlan:
    """Integerized, executable plan for one coded matvec deployment."""

    cluster: ClusterSpec
    k: int
    loads_per_worker: np.ndarray  # (N,) int rows of coded A per worker
    group_of_worker: np.ndarray  # (N,) int group index per worker
    row_ranges: tuple  # worker -> (start, stop) into coded rows
    n: int  # total coded rows actually deployed
    t_star: float  # paper lower bound for the underlying real plan
    scheme: str

    @property
    def num_workers(self) -> int:
        return int(self.loads_per_worker.shape[0])

    @property
    def rate(self) -> float:
        return self.k / self.n

    @property
    def max_load(self) -> int:
        return int(self.loads_per_worker.max())


def _expand(cluster: ClusterSpec, per_group: Sequence[int]):
    loads, gid = [], []
    for j, g in enumerate(cluster.groups):
        loads += [int(per_group[j])] * g.num_workers
        gid += [j] * g.num_workers
    return np.asarray(loads, dtype=np.int64), np.asarray(gid, dtype=np.int64)


def plan_deployment(
    cluster: ClusterSpec,
    k: int,
    *,
    scheme: str = "optimal",
    per_row: bool = False,
    n: float | None = None,
    r: int | None = None,
) -> DeploymentPlan:
    """Compute an integerized deployment plan for the requested scheme."""
    if scheme == "optimal":
        plan = allocation.optimal_allocation(cluster, k, per_row=per_row)
    elif scheme == "uniform_n":
        assert n is not None
        plan = allocation.uniform_given_n(cluster, k, n)
    elif scheme == "uniform_r":
        assert r is not None
        plan = allocation.uniform_given_r(cluster, k, r)
    elif scheme == "reisizadeh":
        plan = allocation.reisizadeh_allocation(cluster, k)
    elif scheme == "uncoded":
        plan = allocation.uncoded(cluster, k)
    else:
        raise ValueError(f"unknown scheme {scheme}")
    loads_w, gid = _expand(cluster, plan.loads_int)
    starts = np.concatenate([[0], np.cumsum(loads_w)[:-1]])
    ranges = tuple(
        (int(s), int(s + l)) for s, l in zip(starts, loads_w)
    )
    return DeploymentPlan(
        cluster=cluster,
        k=k,
        loads_per_worker=loads_w,
        group_of_worker=gid,
        row_ranges=ranges,
        n=int(loads_w.sum()),
        t_star=plan.t_star,
        scheme=plan.scheme,
    )


def replan_on_membership_change(
    plan: DeploymentPlan, new_cluster: ClusterSpec
) -> DeploymentPlan:
    """Elastic re-planning: closed-form Theorem 2 on the new membership.

    Called by the fault-tolerance layer when workers join/leave or when
    online mu estimates are refreshed. O(G) cost.
    """
    scheme = "optimal" if plan.scheme.startswith("optimal") else plan.scheme
    per_row = plan.scheme == "optimal_per_row"
    return plan_deployment(new_cluster, plan.k, scheme=scheme, per_row=per_row)


def estimate_mu_online(samples_per_group: Sequence[np.ndarray], k: int, loads):
    """MLE of (mu_j, alpha_j) from observed per-worker round-trip times.

    Shifted exponential MLE: alpha_hat = min(t) * k / l;
    mu_hat = 1 / (mean(t - min(t)) * k / l). Feeds the planner's
    re-planning loop (straggler-parameter drift tracking).
    """
    mus, alphas = [], []
    for t, l in zip(samples_per_group, loads):
        t = np.asarray(t, dtype=np.float64) * (k / float(l))
        t0 = float(t.min())
        alphas.append(t0)
        excess = float(t.mean() - t0)
        mus.append(1.0 / max(excess, 1e-12))
    return np.asarray(mus), np.asarray(alphas)
