"""Gradient coding over batch partitions (Wang et al. 2019, arXiv:1901.09339).

Heterogeneity-aware gradient coding assigns each worker a *fraction* of
the gradient work proportional to its speed: the global batch is split
into ``k`` partitions, worker ``w`` computes coded combinations of
partition gradients, and the master recovers the FULL-batch gradient
from whichever coded rows arrive by the deadline. The per-group loads
come from the same Theorem-2 balancing the paper derives for coded
matvec rows (``allocation.gradient_coding_allocation``); this module
owns the coding itself:

* **Assignment matrix** ``B in R^{n x k}`` — row ``i`` is the linear
  combination of partition gradients coded row ``i`` carries. We use
  the systematic-Gaussian construction shared with the matvec path
  (``coding.make_generator``): the first ``k`` rows are plain partition
  gradients, parity rows mix all partitions. Any ``k`` rows of ``B``
  are linearly independent with probability 1 (MDS property), so any
  ``k`` surviving coded gradients recover the batch gradient.

* **Decode vectors** — gradient descent only needs the SUM of partition
  gradients, never the individual partitions, so the master solves for
  one vector ``a`` with ``a^T B_S = 1^T`` (support on the surviving
  rows ``S``) and aggregates ``g = sum_i a_i g~_i`` directly: a single
  ``(k, k)`` solve plus one weighted reduction, instead of a full
  per-partition decode. With the survivors-first stable-argsort gather
  of the serving pipeline this is fixed-shape and device-resident
  (``decode_vector_jit``), composable under ``jax.lax.scan``/``jit``;
  ``decode_vector`` is the numpy reference oracle.

When no worker misses the deadline the gathered system is the identity
(systematic rows) and the decode vector is EXACTLY ones on the
systematic rows — coded training reproduces plain data-parallel
training bit-for-bit modulo partition summation order
(``tests/test_coded_train.py`` pins the parity).
"""
from __future__ import annotations

import jax
import jax.numpy as jnp
import numpy as np

from repro.core.coding import make_generator


def assignment_matrix(n: int, k: int, key=None, kind: str = "systematic_gaussian"):
    """(n, k) gradient-coding assignment matrix B.

    Row i holds the coefficients of the partition gradients coded
    gradient i carries. Systematic by default: rows 0..k-1 are the plain
    partition gradients (support 1), parity rows are dense Gaussian
    mixes. The construction is shared with the coded-matvec generator so
    serve and train ride one coding substrate.
    """
    return make_generator(n, k, key=key, kind=kind)


def partition_weights(b_matrix, decode_vec) -> np.ndarray:
    """Effective per-partition weights ``w = a^T B`` of a decode vector.

    ``w == 1`` componentwise iff the decode is exact: the aggregated
    gradient ``sum_i a_i g~_i`` equals ``sum_j w_j g_j``.
    """
    return np.asarray(decode_vec) @ np.asarray(b_matrix)


def decode_vector(b_matrix, finished_rows) -> tuple[np.ndarray, bool]:
    """Numpy oracle: decode vector a with ``a^T B_S = 1^T``.

    Args:
      b_matrix: (n, k) assignment matrix.
      finished_rows: (n,) bool — coded gradients that arrived in time.

    Returns (a, ok): a is (n,) with zeros on erased rows; ok is False
    when fewer than k rows survived (a is zeroed — the caller skips the
    step or falls back to the previous gradient).
    """
    b = np.asarray(b_matrix, np.float64)
    fin = np.asarray(finished_rows, bool)
    n, k = b.shape
    a = np.zeros((n,), np.float64)
    if fin.sum() < k:
        return a, False
    use = np.flatnonzero(fin)[:k]
    coeff = np.linalg.solve(b[use].T, np.ones((k,)))
    a[use] = coeff
    return a, True


@jax.jit
def decode_vector_jit(b_matrix, finished_rows):
    """Fixed-shape, device-resident decode vector (the training hot path).

    Survivors-first stable argsort on the erasure mask (the same gather
    as ``coding.decode_systematic_jit``) selects the first k surviving
    rows ``B_S``; ``B_S^T a_S = 1`` is a static (k, k) LU solve with one
    refinement step, and the coefficients scatter back to an (n,) vector
    that is zero on every unused row. Returns (a, ok) with ``ok`` a
    traced bool — the caller folds the fewer-than-k-survivors fallback
    in with ``jnp.where``, never a Python branch.
    """
    b = jnp.asarray(b_matrix)
    mask = jnp.asarray(finished_rows, bool)
    n, k = b.shape
    order = jnp.argsort(~mask, stable=True)
    idx = order[:k]
    bs_t = b[idx].T  # (k, k)
    rhs = jnp.ones((k, 1), b.dtype)
    lu, piv = jax.scipy.linalg.lu_factor(bs_t)
    c = jax.scipy.linalg.lu_solve((lu, piv), rhs)
    c = c + jax.scipy.linalg.lu_solve((lu, piv), rhs - bs_t @ c)  # refine
    ok = jnp.sum(mask) >= k
    a = jnp.zeros((n,), b.dtype).at[idx].set(c[:, 0])
    return jnp.where(ok, a, jnp.zeros_like(a)), ok


def aggregate_coded(coded_grads, decode_vec):
    """Master-side aggregation ``g = sum_i a_i g~_i`` over a pytree.

    ``coded_grads`` is a pytree whose leaves have a leading (n,) coded-row
    axis; ``decode_vec`` is the (n,) decode vector (zeros on erasures).
    Traceable — used by tests to cross-check the fused train-step path,
    which folds ``a^T B`` into per-partition weights instead of
    materializing the n coded gradient copies.
    """
    a = jnp.asarray(decode_vec)
    return jax.tree.map(lambda g: jnp.tensordot(a, g, axes=1), coded_grads)


def encode_gradients(partition_grads, b_matrix):
    """Worker-side encoding ``g~_i = sum_j B[i, j] g_j`` over a pytree.

    ``partition_grads`` leaves have a leading (k,) partition axis; the
    result's leaves have a leading (n,) coded-row axis. Reference /
    test helper: the fused train step never materializes this (it
    weights partitions by ``a^T B`` directly — mathematically identical
    because the coding is linear).
    """
    b = jnp.asarray(b_matrix)
    return jax.tree.map(lambda g: jnp.tensordot(b, g, axes=1), partition_grads)
