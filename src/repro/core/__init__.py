"""Core: optimal load allocation for coded distributed computation in
heterogeneous clusters (Kim, Park, Choi 2019), behind a typed scheme API.

Layout
------
* ``runtime_model``  — the shifted-exponential runtime models as a typed
  ``LatencyModel`` enum (``MODEL_1``: paper model (1), normalized by k;
  ``MODEL_30``: per-row model of Section III-E / [32]), plus ClusterSpec
  and order-statistic closed forms.
* ``allocation``     — the paper's allocation math (Theorems 1-4,
  Appendix D) as pure functions returning ``AllocationPlan``.
* ``schemes``        — the scheme API: every allocation policy is a
  frozen-dataclass ``AllocationScheme`` (``Optimal``, ``UniformN(n=...)``,
  ``UniformR(r=...)``, ``Reisizadeh``, ``Uncoded``) registered by name;
  new schemes are one dataclass + one ``register_scheme`` call.
* ``planner``        — integerizes an ``AllocationPlan`` into a
  per-worker ``DeploymentPlan``; plans carry their scheme object so
  elastic re-planning preserves scheme parameters.
* ``engine``         — ``CodedComputeEngine``, the facade owning the
  ``ClusterSpec -> plan -> generator -> simulate / deadline -> replan``
  lifecycle consumed by serving, fault tolerance and the benchmarks.
* ``simulator``      — vectorized Monte-Carlo latency simulation;
  per-scheme semantics dispatch through the scheme objects.
* ``coding`` / ``coded_matvec`` / ``lambertw`` — real-valued MDS codes,
  the end-to-end coded matvec, and the Lambert-W branch used by Thm 2.
"""
from repro.core.allocation import (
    AllocationPlan,
    comm_aware_allocation,
    comm_t_star,
    comm_uniform_allocation,
    gradient_coding_allocation,
    optimal_allocation,
    optimal_r,
    reisizadeh_allocation,
    t_star,
    uncoded,
    uniform_given_n,
    uniform_given_r,
    xi_star,
)
from repro.core.engine import CodedComputeEngine
from repro.core.lambertw import lambertw0, lambertwm1
from repro.core.planner import (
    DeploymentPlan,
    deploy,
    plan_deployment,
    replan_on_membership_change,
)
from repro.core.runtime_model import (
    ClusterSpec,
    GroupSpec,
    LatencyModel,
    expected_order_stat,
    xi,
)
from repro.core.schemes import (
    AllocationScheme,
    CommAware,
    CommUniform,
    GradCoding,
    Optimal,
    Reisizadeh,
    Uncoded,
    UniformN,
    UniformR,
    make_scheme,
    register_scheme,
    scheme_for_plan,
    scheme_names,
    scheme_params,
)

__all__ = [
    "AllocationPlan",
    "AllocationScheme",
    "ClusterSpec",
    "CodedComputeEngine",
    "CommAware",
    "CommUniform",
    "DeploymentPlan",
    "GradCoding",
    "GroupSpec",
    "LatencyModel",
    "Optimal",
    "Reisizadeh",
    "Uncoded",
    "UniformN",
    "UniformR",
    "comm_aware_allocation",
    "comm_t_star",
    "comm_uniform_allocation",
    "deploy",
    "expected_order_stat",
    "gradient_coding_allocation",
    "lambertw0",
    "lambertwm1",
    "make_scheme",
    "optimal_allocation",
    "optimal_r",
    "plan_deployment",
    "register_scheme",
    "reisizadeh_allocation",
    "replan_on_membership_change",
    "scheme_for_plan",
    "scheme_names",
    "scheme_params",
    "t_star",
    "uncoded",
    "uniform_given_n",
    "uniform_given_r",
    "xi",
    "xi_star",
]
