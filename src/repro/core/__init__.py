"""Core: the paper's contribution — optimal load allocation for coded
distributed computation in heterogeneous clusters (Kim, Park, Choi 2019).
"""
from repro.core.allocation import (
    AllocationPlan,
    optimal_allocation,
    optimal_r,
    reisizadeh_allocation,
    t_star,
    uncoded,
    uniform_given_n,
    uniform_given_r,
    xi_star,
)
from repro.core.lambertw import lambertw0, lambertwm1
from repro.core.planner import DeploymentPlan, plan_deployment, replan_on_membership_change
from repro.core.runtime_model import ClusterSpec, GroupSpec, expected_order_stat, xi

__all__ = [
    "AllocationPlan",
    "ClusterSpec",
    "DeploymentPlan",
    "GroupSpec",
    "expected_order_stat",
    "lambertw0",
    "lambertwm1",
    "optimal_allocation",
    "optimal_r",
    "plan_deployment",
    "reisizadeh_allocation",
    "replan_on_membership_change",
    "t_star",
    "uncoded",
    "uniform_given_n",
    "uniform_given_r",
    "xi",
    "xi_star",
]
