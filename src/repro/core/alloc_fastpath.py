"""Jitted allocation cores — the planner fast path (DESIGN.md §11).

The eager path in ``core/allocation.py`` evaluates Theorem 2 as a chain
of small eager jnp ops (plus two 200-iteration host bisections for the
comm-aware deadline and the group-code split), costing ~0.4 s per
``allocate`` call on CPU — enough to dominate oracle sweeps and to gate
how often an adaptive controller can afford to replan. This module
reimplements each solve as ONE jitted function over per-group ``(G,)``
arrays: the Lambert-W evaluation, the load formulas, and the bisections
(as fixed-trip ``lax.while_loop``s) all fuse into a single compiled
program, so a warm replan is a dispatch plus a handful of scalar
transfers (~sub-millisecond; ≥50x is asserted by
``benchmarks/alloc_fastpath.py``).

Division of labour: the cores return REAL-valued results only; the
callers in ``allocation.py`` keep doing host-side integerization
(``ceil(loads - 1e-9)``) and ``AllocationPlan`` assembly, identically
on both paths, so the eager path stays a drop-in parity oracle
(``tests/test_alloc_fastpath.py`` pins loads/t*/n_int agreement for
every registered scheme).

``k`` is passed as a traced scalar so plans for different row counts
share one compiled program per ``(G,)`` shape/dtype.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp
from jax import lax

from repro.core.lambertw import lambertwm1_neg_exp

#: iteration cap for the device bisections; with the relative interval
#: tolerance below they exit in ~50 trips, the cap only bounds tracing
BISECT_MAX_ITERS = 200
#: relative interval width at which a bisection stops tightening
BISECT_RTOL = 1e-15


def _w_term(mu, alpha):
    """W_{-1}(-exp(-(alpha*mu + 1))) — the Theorem-2 Lambert-W term."""
    return lambertwm1_neg_exp(alpha * mu + 1.0)


def _bisect(cover, lo, hi, target):
    """Root of increasing ``cover(t) = target`` on [lo, hi], on device.

    Same midpoint updates as the eager host loops, as a fixed-trip
    ``lax.while_loop``: trips are bounded by ``BISECT_MAX_ITERS`` and cut
    short once the bracket is relatively tighter than ``BISECT_RTOL``
    (f64 exhaustion — matching the eager path's early exit).
    """

    def keep_going(state):
        i, lo, hi = state
        tight = (hi - lo) <= BISECT_RTOL * jnp.maximum(jnp.abs(hi), 1.0)
        return (i < BISECT_MAX_ITERS) & ~tight

    def step(state):
        i, lo, hi = state
        mid = 0.5 * (lo + hi)
        below = cover(mid) < target
        return i + 1, jnp.where(below, mid, lo), jnp.where(below, hi, mid)

    _, lo, hi = lax.while_loop(keep_going, step, (jnp.int32(0), lo, hi))
    return 0.5 * (lo + hi)


@jax.jit
def optimal_core(n_w, mu, al, k):
    """Theorem 2 in one fused program: (loads, r, n, t_base).

    ``t_base`` is eq. (18)'s T*; the caller scales by ``k`` for the
    per-row model (33) — the W-term never sees the load scaling.
    """
    w = _w_term(mu, al)
    r = n_w * (1.0 + 1.0 / w)  # eq. (15)
    xs = al + jnp.log(-w) / mu  # eq. (17)
    s = jnp.sum(r / xs)
    loads = k / (xs * s)  # eq. (16)
    n = jnp.sum(n_w * loads)
    t = 1.0 / jnp.sum(-mu * n_w / w)  # eq. (18)
    return loads, r, n, t


@jax.jit
def reisizadeh_core(n_w, mu, al, k):
    """Appendix D (the scheme of [32]): (loads, r, n)."""
    w = _w_term(mu, al)
    delta = -(w + 1.0) / mu
    s = jnp.sum(n_w * mu / (1.0 + mu * delta))
    loads = k / (s * delta)
    n = jnp.sum(n_w * loads)
    r = n_w * (1.0 + 1.0 / w)
    return loads, r, n


@jax.jit
def comm_core(n_w, mu, a_eff, c, k):
    """Comm-aware allocation (arXiv:2109.11246): (loads, r, n, t).

    ``a_eff = alpha + download/b`` is the comm-shifted alpha of the
    Lambert-W inner problem; ``c = upload/b`` the fixed transfer shift.
    The outer deadline equation ``sum_j g_j (t - c_j)_+ = 1`` is
    piecewise-linear increasing and bisected on
    ``[min c, max c + 1/sum g]`` (``cover(hi) >= 1`` because every term
    has slack at least ``1/sum g`` there). With all ``c = 0`` the root
    sits exactly on the bracket endpoint, so the closed form
    ``t = 1/sum g`` is selected instead — keeping parity with the eager
    path's Lambert-W fast path bit-for-bit.
    """
    w = _w_term(mu, a_eff)
    g = -mu * n_w / w
    xs = -(1.0 + w) / mu
    lo = jnp.min(c)
    hi = jnp.max(c) + 1.0 / jnp.sum(g)
    t = _bisect(
        lambda t: jnp.sum(g * jnp.maximum(t - c, 0.0)), lo, hi, 1.0
    )
    t = jnp.where(jnp.all(c == 0.0), 1.0 / jnp.sum(g), t)
    slack = jnp.maximum(t - c, 0.0)
    loads = k * slack / xs
    r = jnp.where(loads > 0, n_w * (1.0 + 1.0 / w), 0.0)
    n = jnp.sum(n_w * loads)
    return loads, r, n, t


@jax.jit
def group_split_core(n_w, mu, r):
    """eq. (28)+(26): per-group split with sum_j N_j (1 - e^{-mu_j c}) = r.

    The closed-form bracket replaces the eager path's doubling phase:
    ``total(c) >= N (1 - e^{-mu_min c})``, so
    ``hi = -log(1 - r/N)/mu_min`` always covers the root for r < N.
    """
    big_n = jnp.sum(n_w)
    hi = -jnp.log1p(-(r / big_n)) / jnp.min(mu)
    c = _bisect(
        lambda c: jnp.sum(n_w * (1.0 - jnp.exp(-mu * c))),
        jnp.zeros_like(hi),
        hi,
        r,
    )
    return n_w * (1.0 - jnp.exp(-mu * c))
