"""Typed allocation-scheme registry (the repo's scheme API).

Every load-allocation scheme is a frozen dataclass implementing
``AllocationScheme``: it carries its own typed parameters, knows which
``LatencyModel`` it is defined under, produces ``AllocationPlan``s, and
owns its Monte-Carlo simulation semantics. Schemes are registered by name
so CLIs / configs / checkpoints can refer to them as strings without any
call site growing an if/elif chain:

    scheme = make_scheme("uniform_r", r=100)   # -> UniformR(r=100)
    plan = scheme.allocate(cluster, k)
    lat = scheme.simulate(key, cluster, plan, num_trials=4000)
    plan2 = scheme.replan(new_cluster, k)      # params travel with the object

Adding a scheme from related work (e.g. communication-delay-aware
allocation, arXiv:2109.11246, or heterogeneity-aware gradient coding,
arXiv:1901.09339) is one dataclass + one ``register_scheme`` call; the
planner, simulator, engine, fault-tolerance and benchmark layers pick it
up through the registry with no further edits.
"""
from __future__ import annotations

import dataclasses
from typing import Callable, Mapping

import jax.numpy as jnp

from repro.core import allocation, simulator
from repro.core.allocation import AllocationPlan
from repro.core.runtime_model import (
    ClusterSpec,
    LatencyModel,
    resolve_latency_model,
)


@dataclasses.dataclass(frozen=True)
class AllocationScheme:
    """Base class for typed, registered load-allocation schemes.

    Subclasses are frozen dataclasses: their fields ARE the scheme's
    parameters, so re-planning after a membership change is simply
    ``scheme.allocate(new_cluster, k)`` — nothing is lost in a name tag.
    """

    #: registry name (subclasses override)
    name = "base"

    @property
    def latency_model(self) -> LatencyModel:
        """The runtime model this scheme's math is defined under."""
        return LatencyModel.MODEL_1

    @property
    def tag(self) -> str:
        """Derived name tag stored on plans (back-compat with old strings)."""
        return self.name

    # -- planning ----------------------------------------------------------
    def _allocate(self, cluster: ClusterSpec, k: int) -> AllocationPlan:
        raise NotImplementedError

    def allocate(self, cluster: ClusterSpec, k: int) -> AllocationPlan:
        """Per-group real/integer loads for ``cluster``; attaches self."""
        plan = self._allocate(cluster, k)
        return dataclasses.replace(plan, scheme_obj=self, scheme=self.tag)

    def replan(self, new_cluster: ClusterSpec, k: int) -> AllocationPlan:
        """Closed-form re-plan on a new membership, params preserved."""
        return self.allocate(new_cluster, k)

    # -- simulation --------------------------------------------------------
    def simulate(
        self,
        key,
        cluster: ClusterSpec,
        plan: AllocationPlan,
        num_trials: int = 10_000,
        *,
        model: LatencyModel | None = None,
        use_integer_loads: bool = False,
    ):
        """Monte-Carlo latency samples for one of this scheme's plans.

        Default semantics: threshold decoding (collect until k coded rows
        are covered). Schemes with different master semantics override.
        """
        loads = plan.loads_int if use_integer_loads else plan.loads
        return simulator.simulate_threshold(
            key, cluster, loads, plan.k, num_trials,
            model=model or self.latency_model,
        )

    def expected_latency(
        self,
        key,
        cluster: ClusterSpec,
        plan: AllocationPlan,
        num_trials: int = 10_000,
        **kwargs,
    ) -> float:
        """Mean of ``simulate`` (convenience)."""
        return float(jnp.mean(self.simulate(key, cluster, plan, num_trials,
                                            **kwargs)))

    def lower_bound(self, cluster: ClusterSpec, k: int) -> float:
        """The scheme's analytic expected latency (NaN when unknown)."""
        return float(self.allocate(cluster, k).t_star)


@dataclasses.dataclass(frozen=True)
class Optimal(AllocationScheme):
    """The paper's optimum: Theorem 2 (MODEL_1) / Corollary 2 (MODEL_30)."""

    name = "optimal"
    model: LatencyModel = LatencyModel.MODEL_1

    @property
    def latency_model(self) -> LatencyModel:
        return self.model

    @property
    def tag(self) -> str:
        return "optimal_per_row" if self.model.per_row else "optimal"

    def _allocate(self, cluster: ClusterSpec, k: int) -> AllocationPlan:
        return allocation.optimal_allocation(cluster, k, model=self.model)


@dataclasses.dataclass(frozen=True)
class UniformN(AllocationScheme):
    """Section III-D-1: uniform split of a fixed-size (n, k) code."""

    name = "uniform_n"
    n: float = 0.0

    def __post_init__(self):
        if not self.n > 0:
            raise ValueError(
                f"UniformN needs the total coded rows n > 0, got n={self.n!r}"
            )

    def _allocate(self, cluster: ClusterSpec, k: int) -> AllocationPlan:
        return allocation.uniform_given_n(cluster, k, self.n)


@dataclasses.dataclass(frozen=True)
class UniformR(AllocationScheme):
    """Section III-D-2 / Theorem 4: the fixed-r group code of [33]."""

    name = "uniform_r"
    r: int = 0

    def __post_init__(self):
        if not self.r > 0:
            raise ValueError(
                f"UniformR needs the completion count r > 0, got r={self.r!r}"
            )

    @property
    def tag(self) -> str:
        return "uniform_r_group_code"

    def _allocate(self, cluster: ClusterSpec, k: int) -> AllocationPlan:
        return allocation.uniform_given_r(cluster, k, self.r)

    def simulate(
        self,
        key,
        cluster: ClusterSpec,
        plan: AllocationPlan,
        num_trials: int = 10_000,
        *,
        model: LatencyModel | None = None,
        use_integer_loads: bool = False,
    ):
        loads = plan.loads_int if use_integer_loads else plan.loads
        return simulator.simulate_group_code(
            key, cluster, float(loads[0]), plan.r, plan.k, num_trials,
            model=model or self.latency_model,
        )


@dataclasses.dataclass(frozen=True)
class Reisizadeh(AllocationScheme):
    """Appendix D: the heterogeneous allocation of [32] (per-row model)."""

    name = "reisizadeh"

    @property
    def latency_model(self) -> LatencyModel:
        return LatencyModel.MODEL_30

    def _allocate(self, cluster: ClusterSpec, k: int) -> AllocationPlan:
        return allocation.reisizadeh_allocation(cluster, k)


@dataclasses.dataclass(frozen=True)
class Uncoded(AllocationScheme):
    """Uncoded baseline: n = k uniform split, wait for every worker."""

    name = "uncoded"

    def _allocate(self, cluster: ClusterSpec, k: int) -> AllocationPlan:
        return allocation.uncoded(cluster, k)


# --------------------------------------------------------------- registry
SchemeFactory = Callable[..., AllocationScheme]

_REGISTRY: dict[str, SchemeFactory] = {}


def register_scheme(name: str, factory: SchemeFactory) -> None:
    """Register a scheme factory under a lookup name.

    ``factory(**params)`` must return an ``AllocationScheme``; it receives
    the keyword params handed to ``make_scheme`` and may ignore extras
    (legacy callers pass the full ``per_row``/``n``/``r`` trio).
    """
    if name in _REGISTRY:
        raise ValueError(f"scheme {name!r} already registered")
    _REGISTRY[name] = factory


def scheme_names() -> tuple[str, ...]:
    """All registered lookup names (CLI choices, config validation)."""
    return tuple(sorted(_REGISTRY))


def make_scheme(
    name: str,
    *,
    per_row: bool | None = None,
    model: LatencyModel | None = None,
    n: float | None = None,
    r: int | None = None,
    **params,
) -> AllocationScheme:
    """Resolve a registered scheme name + params to a typed scheme object."""
    if name not in _REGISTRY:
        raise ValueError(
            f"unknown scheme {name!r}; registered: {', '.join(scheme_names())}"
        )
    return _REGISTRY[name](per_row=per_row, model=model, n=n, r=r, **params)


def _make_optimal(*, per_row=None, model=None, **_):
    return Optimal(model=resolve_latency_model(model, per_row))


def _make_optimal_per_row(**_):
    return Optimal(model=LatencyModel.MODEL_30)


def _make_uniform_n(*, n=None, **_):
    if n is None:
        raise ValueError("scheme 'uniform_n' requires the code size n")
    return UniformN(n=float(n))


def _make_uniform_r(*, r=None, **_):
    if r is None:
        raise ValueError("scheme 'uniform_r' requires the completion count r")
    return UniformR(r=int(r))


register_scheme("optimal", _make_optimal)
register_scheme("optimal_per_row", _make_optimal_per_row)
register_scheme("uniform_n", _make_uniform_n)
register_scheme("uniform_r", _make_uniform_r)
register_scheme("uniform_r_group_code", _make_uniform_r)
register_scheme("reisizadeh", lambda **_: Reisizadeh())
register_scheme("uncoded", lambda **_: Uncoded())


def scheme_for_plan(plan) -> AllocationScheme:
    """The scheme object behind a plan (Allocation- or DeploymentPlan).

    Plans produced through the registry carry their scheme object; for
    legacy plans built from the bare allocation functions the scheme is
    reconstructed best-effort from the name tag and the plan's own fields
    (n from the deployed code size, r from k / per-worker load).
    """
    obj = getattr(plan, "scheme_obj", None)
    if obj is not None:
        return obj
    alloc = getattr(plan, "allocation", None)
    if alloc is not None:
        if alloc.scheme_obj is not None:
            return alloc.scheme_obj
        # the real-valued allocation is exact; reconstruct from it rather
        # than from the integerized per-worker loads (which round r/n)
        plan = alloc
    tag = plan.scheme
    loads = getattr(plan, "loads", None)
    if loads is None:
        loads = plan.loads_per_worker  # DeploymentPlan without allocation
    if tag in ("optimal", "optimal_per_row"):
        return Optimal(model=LatencyModel.from_per_row(tag == "optimal_per_row"))
    if tag == "uniform_n":
        return UniformN(n=float(plan.n))
    if tag in ("uniform_r", "uniform_r_group_code"):
        return UniformR(r=int(round(plan.k / float(loads[0]))))
    return make_scheme(tag)


SCHEME_PARAM_DOC: Mapping[str, str] = {
    "optimal": "model: LatencyModel (default MODEL_1)",
    "uniform_n": "n: total coded rows (float > 0)",
    "uniform_r": "r: completion count (int in (0, N))",
    "reisizadeh": "(no params; per-row model)",
    "uncoded": "(no params)",
}
