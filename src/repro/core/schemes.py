"""Typed allocation-scheme registry (the repo's scheme API).

Every load-allocation scheme is a frozen dataclass implementing
``AllocationScheme``: it carries its own typed parameters, knows which
``LatencyModel`` it is defined under, produces ``AllocationPlan``s, and
owns its Monte-Carlo simulation semantics. Schemes are registered by name
so CLIs / configs / checkpoints can refer to them as strings without any
call site growing an if/elif chain:

    scheme = make_scheme("uniform_r", r=100)   # -> UniformR(r=100)
    plan = scheme.allocate(cluster, k)
    lat = scheme.simulate(key, cluster, plan, num_trials=4000)
    plan2 = scheme.replan(new_cluster, k)      # params travel with the object

Adding a scheme from related work is one dataclass + one
``register_scheme`` call; the planner, simulator, engine,
fault-tolerance and benchmark layers pick it up through the registry
with no further edits. The communication-delay-aware family of Sun et
al. (arXiv:2109.11246) landed exactly that way: ``CommAware`` /
``CommUniform`` below are plain registry citizens whose transfer-cost
params ride on the dataclass, with per-group link bandwidths coming
from ``ClusterSpec``.

``make_scheme`` validates parameters against what each factory declares:
unknown or inapplicable kwargs raise instead of being silently dropped
(a typo'd ``--scheme uniform_n --r 3`` used to no-op).
"""
from __future__ import annotations

import dataclasses
import inspect
from typing import Callable, Mapping

import jax.numpy as jnp

from repro.core import allocation, simulator
from repro.core.allocation import AllocationPlan
from repro.core.runtime_model import (
    ClusterSpec,
    LatencyModel,
    resolve_latency_model,
)
from repro.obs.metrics import REGISTRY as _METRICS


#: allocate() memoization (see AllocationScheme.allocate). Keys are
#: (scheme, cluster, k) — schemes and ClusterSpec are frozen dataclasses,
#: so equality covers every parameter that feeds the solve.
_ALLOC_CACHE: dict = {}
_ALLOC_CACHE_CAP = 512
# hit/miss tallies live in the process-global metrics registry (§14):
# schemes.py is module-level state with no run-scoped object to hang a
# per-run registry on, and the controller reads totals either way
_ALLOC_HITS = _METRICS.counter("alloc_cache_hits")
_ALLOC_MISSES = _METRICS.counter("alloc_cache_misses")


def allocate_cache_clear() -> None:
    """Drop all memoized allocations (tests / manual invalidation)."""
    _ALLOC_CACHE.clear()
    _ALLOC_HITS.reset()
    _ALLOC_MISSES.reset()


def allocate_cache_info() -> dict:
    """Memo-cache stats; hit/miss counters feed the ``alloc_cache_hit``
    telemetry event the adaptive controller emits (DESIGN.md §8/§11)."""
    return {
        "size": len(_ALLOC_CACHE),
        "cap": _ALLOC_CACHE_CAP,
        "hits": _ALLOC_HITS.value,
        "misses": _ALLOC_MISSES.value,
    }


@dataclasses.dataclass(frozen=True)
class AllocationScheme:
    """Base class for typed, registered load-allocation schemes.

    Subclasses are frozen dataclasses: their fields ARE the scheme's
    parameters, so re-planning after a membership change is simply
    ``scheme.allocate(new_cluster, k)`` — nothing is lost in a name tag.
    """

    #: registry name (subclasses override)
    name = "base"

    @property
    def latency_model(self) -> LatencyModel:
        """The runtime model this scheme's math is defined under."""
        return LatencyModel.MODEL_1

    @property
    def tag(self) -> str:
        """Derived name tag stored on plans (back-compat with old strings)."""
        return self.name

    # -- planning ----------------------------------------------------------
    def _allocate(self, cluster: ClusterSpec, k: int) -> AllocationPlan:
        raise NotImplementedError

    def allocate(self, cluster: ClusterSpec, k: int) -> AllocationPlan:
        """Per-group real/integer loads for ``cluster``; attaches self.

        Memoized on (scheme params, cluster, k) — all frozen/hashable —
        so per-admission coverage checks and oracle sweeps don't re-pay
        the eager Lambert-W solve. A membership change IS a different
        ``cluster`` key, so stale plans can never be served; the cache
        evicts FIFO at ``_ALLOC_CACHE_CAP`` entries
        (``allocate_cache_clear`` / ``allocate_cache_info`` to manage).
        ``scheme_obj``/``scheme`` are re-attached on every return, cache
        hit or miss, so plan identity semantics (``plan.scheme_obj is
        scheme``) are preserved.
        """
        # the solver path is part of the key so eager_oracle() blocks
        # can never be served a fastpath-computed plan (or vice versa)
        cache_key = (self, cluster, int(k), allocation.fastpath_enabled())
        plan = _ALLOC_CACHE.get(cache_key)
        if plan is None:
            _ALLOC_MISSES.inc()
            plan = self._allocate(cluster, k)
            if len(_ALLOC_CACHE) >= _ALLOC_CACHE_CAP:
                _ALLOC_CACHE.pop(next(iter(_ALLOC_CACHE)))
            _ALLOC_CACHE[cache_key] = plan
        else:
            _ALLOC_HITS.inc()
        # fresh array views per call: a caller mutating plan.loads must
        # not corrupt the cached solve
        return dataclasses.replace(
            plan, loads=plan.loads.copy(), loads_int=plan.loads_int.copy(),
            r=plan.r.copy(), scheme_obj=self, scheme=self.tag,
        )

    def replan(self, new_cluster: ClusterSpec, k: int) -> AllocationPlan:
        """Closed-form re-plan on a new membership, params preserved."""
        return self.allocate(new_cluster, k)

    # -- simulation --------------------------------------------------------
    def simulate(
        self,
        key,
        cluster: ClusterSpec,
        plan: AllocationPlan,
        num_trials: int = 10_000,
        *,
        model: LatencyModel | None = None,
        use_integer_loads: bool = False,
    ):
        """Monte-Carlo latency samples for one of this scheme's plans.

        Default semantics: threshold decoding (collect until k coded rows
        are covered). Schemes with different master semantics override.
        """
        loads = plan.loads_int if use_integer_loads else plan.loads
        return simulator.simulate_threshold(
            key, cluster, loads, plan.k, num_trials,
            model=model or self.latency_model,
        )

    def expected_latency(
        self,
        key,
        cluster: ClusterSpec,
        plan: AllocationPlan,
        num_trials: int = 10_000,
        **kwargs,
    ) -> float:
        """Mean of ``simulate`` (convenience)."""
        return float(jnp.mean(self.simulate(key, cluster, plan, num_trials,
                                            **kwargs)))

    def lower_bound(self, cluster: ClusterSpec, k: int) -> float:
        """The scheme's analytic expected latency (NaN when unknown)."""
        return float(self.allocate(cluster, k).t_star)


@dataclasses.dataclass(frozen=True)
class Optimal(AllocationScheme):
    """The paper's optimum: Theorem 2 (MODEL_1) / Corollary 2 (MODEL_30)."""

    name = "optimal"
    model: LatencyModel = LatencyModel.MODEL_1

    @property
    def latency_model(self) -> LatencyModel:
        return self.model

    @property
    def tag(self) -> str:
        return "optimal_per_row" if self.model.per_row else "optimal"

    def _allocate(self, cluster: ClusterSpec, k: int) -> AllocationPlan:
        return allocation.optimal_allocation(cluster, k, model=self.model)


@dataclasses.dataclass(frozen=True)
class UniformN(AllocationScheme):
    """Section III-D-1: uniform split of a fixed-size (n, k) code."""

    name = "uniform_n"
    n: float = 0.0

    def __post_init__(self):
        if not self.n > 0:
            raise ValueError(
                f"UniformN needs the total coded rows n > 0, got n={self.n!r}"
            )

    def _allocate(self, cluster: ClusterSpec, k: int) -> AllocationPlan:
        return allocation.uniform_given_n(cluster, k, self.n)


@dataclasses.dataclass(frozen=True)
class UniformR(AllocationScheme):
    """Section III-D-2 / Theorem 4: the fixed-r group code of [33]."""

    name = "uniform_r"
    r: int = 0

    def __post_init__(self):
        if not self.r > 0:
            raise ValueError(
                f"UniformR needs the completion count r > 0, got r={self.r!r}"
            )

    @property
    def tag(self) -> str:
        return "uniform_r_group_code"

    def _allocate(self, cluster: ClusterSpec, k: int) -> AllocationPlan:
        return allocation.uniform_given_r(cluster, k, self.r)

    def simulate(
        self,
        key,
        cluster: ClusterSpec,
        plan: AllocationPlan,
        num_trials: int = 10_000,
        *,
        model: LatencyModel | None = None,
        use_integer_loads: bool = False,
    ):
        loads = plan.loads_int if use_integer_loads else plan.loads
        return simulator.simulate_group_code(
            key, cluster, float(loads[0]), plan.r, plan.k, num_trials,
            model=model or self.latency_model,
        )


@dataclasses.dataclass(frozen=True)
class Reisizadeh(AllocationScheme):
    """Appendix D: the heterogeneous allocation of [32] (per-row model)."""

    name = "reisizadeh"

    @property
    def latency_model(self) -> LatencyModel:
        return LatencyModel.MODEL_30

    def _allocate(self, cluster: ClusterSpec, k: int) -> AllocationPlan:
        return allocation.reisizadeh_allocation(cluster, k)


@dataclasses.dataclass(frozen=True)
class Uncoded(AllocationScheme):
    """Uncoded baseline: n = k uniform split, wait for every worker."""

    name = "uncoded"

    def _allocate(self, cluster: ClusterSpec, k: int) -> AllocationPlan:
        return allocation.uncoded(cluster, k)


@dataclasses.dataclass(frozen=True)
class GradCoding(AllocationScheme):
    """Heterogeneity-aware gradient coding (Wang et al., arXiv:1901.09339).

    The training-side citizen of the registry: ``k`` is the number of
    gradient PARTITIONS of the global batch, loads are coded
    partition-gradients per worker (Theorem-2 balancing clamped to k —
    ``allocation.gradient_coding_allocation``), and the master decodes
    the full-batch gradient from any k surviving coded rows via the
    decode vectors of ``core/gradient_coding.py``. Master semantics are
    threshold decoding, so simulation/deadline/replan all come from the
    base class unchanged.
    """

    name = "grad_coding"
    model: LatencyModel = LatencyModel.MODEL_1

    @property
    def latency_model(self) -> LatencyModel:
        return self.model

    @property
    def tag(self) -> str:
        # like Optimal's per-row tag: a plan that loses its scheme_obj
        # must reconstruct under the SAME latency model
        return "grad_coding_per_row" if self.model.per_row else "grad_coding"

    def _allocate(self, cluster: ClusterSpec, k: int) -> AllocationPlan:
        return allocation.gradient_coding_allocation(cluster, k, model=self.model)


@dataclasses.dataclass(frozen=True)
class _CommDelayScheme(AllocationScheme):
    """Shared CommDelay behaviour: transfer-cost params + comm simulation.

    ``upload``/``download`` are the per-round transfer costs divided by
    each group's ``ClusterSpec`` bandwidth to form the comm terms
    (``runtime_model.comm_terms``); infinite bandwidths make both vanish.
    """

    upload: float = 1.0
    download: float = 1.0

    def __post_init__(self):
        if self.upload < 0 or self.download < 0:
            raise ValueError(
                f"{type(self).__name__} transfer costs must be >= 0, got "
                f"upload={self.upload!r}, download={self.download!r}"
            )

    @property
    def latency_model(self) -> LatencyModel:
        return LatencyModel.COMM_DELAY

    def simulate(
        self,
        key,
        cluster: ClusterSpec,
        plan: AllocationPlan,
        num_trials: int = 10_000,
        *,
        model: LatencyModel | None = None,
        use_integer_loads: bool = False,
    ):
        loads = plan.loads_int if use_integer_loads else plan.loads
        if model is not None and model is not LatencyModel.COMM_DELAY:
            # explicit override: evaluate the plan comm-blind
            return simulator.simulate_threshold(
                key, cluster, loads, plan.k, num_trials, model=model
            )
        return simulator.simulate_comm_threshold(
            key, cluster, loads, plan.k, num_trials,
            upload=self.upload, download=self.download,
        )


@dataclasses.dataclass(frozen=True)
class CommAware(_CommDelayScheme):
    """Communication-delay-aware optimum (Sun et al., arXiv:2109.11246).

    Numeric optimizer over the comm-augmented lower bound: the Lambert-W
    inner problem survives at comm-shifted alphas, the outer deadline
    equation is solved by bisection, and groups whose transfer shift
    exceeds the optimal deadline get zero load. Where every transfer
    term vanishes (infinite bandwidths / zero costs) the plan is exactly
    ``Optimal``'s (the Lambert-W fast path).
    """

    name = "comm_aware"

    def _allocate(self, cluster: ClusterSpec, k: int) -> AllocationPlan:
        return allocation.comm_aware_allocation(
            cluster, k, upload=self.upload, download=self.download
        )


@dataclasses.dataclass(frozen=True)
class CommUniform(_CommDelayScheme):
    """Uniform-split baseline under the CommDelay model.

    ``n`` defaults to the comm-aware optimum's code size, i.e. the same
    redundancy split uniformly over every worker, slow links included —
    the comm-blind comparator of ``benchmarks/fig_comm.py``.
    """

    name = "comm_uniform"

    n: float | None = None

    def __post_init__(self):
        super().__post_init__()
        if self.n is not None and not self.n > 0:
            raise ValueError(
                f"CommUniform needs the total coded rows n > 0, got n={self.n!r}"
            )

    def _allocate(self, cluster: ClusterSpec, k: int) -> AllocationPlan:
        return allocation.comm_uniform_allocation(
            cluster, k, n=self.n, upload=self.upload, download=self.download
        )


# --------------------------------------------------------------- registry
SchemeFactory = Callable[..., AllocationScheme]


@dataclasses.dataclass(frozen=True)
class _Registration:
    factory: SchemeFactory
    params: frozenset  # keyword params this factory accepts


_REGISTRY: dict[str, _Registration] = {}


def _factory_params(factory: SchemeFactory) -> frozenset:
    """Keyword parameters a factory declares (its accepted scheme params).

    ``**kwargs`` catch-alls do NOT widen the set: only named parameters
    count, so ``make_scheme`` can reject typo'd or inapplicable params
    instead of silently swallowing them.
    """
    try:
        sig = inspect.signature(factory)
    except (TypeError, ValueError):  # builtins / exotic callables
        return frozenset()
    return frozenset(
        p.name
        for p in sig.parameters.values()
        if p.kind in (p.POSITIONAL_OR_KEYWORD, p.KEYWORD_ONLY)
    )


def register_scheme(
    name: str, factory: SchemeFactory, *, params=None
) -> None:
    """Register a scheme factory under a lookup name.

    ``factory(**params)`` must return an ``AllocationScheme``. The set of
    accepted parameters is taken from the factory's named keyword
    arguments (or the explicit ``params`` override); ``make_scheme``
    rejects anything outside it.
    """
    if name in _REGISTRY:
        raise ValueError(f"scheme {name!r} already registered")
    accepted = _factory_params(factory) if params is None else frozenset(params)
    _REGISTRY[name] = _Registration(factory, accepted)


def scheme_names() -> tuple[str, ...]:
    """All registered lookup names (CLI choices, config validation)."""
    return tuple(sorted(_REGISTRY))


def scheme_params(name: str) -> tuple[str, ...]:
    """The keyword parameters a registered scheme accepts (sorted).

    Lets generic callers (CLI help, the scheme-invariant test suite)
    construct any registered scheme without per-scheme knowledge.
    """
    if name not in _REGISTRY:
        raise ValueError(
            f"unknown scheme {name!r}; registered: {', '.join(scheme_names())}"
        )
    return tuple(sorted(_REGISTRY[name].params))


def make_scheme(
    name: str,
    *,
    per_row: bool | None = None,
    model: LatencyModel | None = None,
    n: float | None = None,
    r: int | None = None,
    **params,
) -> AllocationScheme:
    """Resolve a registered scheme name + params to a typed scheme object.

    Only parameters the scheme's factory declares are accepted; ``None``
    values mean "not provided" (legacy callers pass the full
    ``per_row``/``n``/``r`` trio unconditionally) and are dropped before
    the check, so a typo'd or inapplicable parameter raises instead of
    silently no-opping.
    """
    if name not in _REGISTRY:
        raise ValueError(
            f"unknown scheme {name!r}; registered: {', '.join(scheme_names())}"
        )
    reg = _REGISTRY[name]
    provided = {"per_row": per_row, "model": model, "n": n, "r": r, **params}
    provided = {key: v for key, v in provided.items() if v is not None}
    unknown = sorted(set(provided) - reg.params)
    if unknown:
        accepted = ", ".join(sorted(reg.params)) or "(none)"
        raise ValueError(
            f"scheme {name!r} does not accept parameter(s) "
            f"{', '.join(unknown)}; accepted: {accepted}"
        )
    return reg.factory(**provided)


def _make_optimal(*, per_row=None, model=None):
    return Optimal(model=resolve_latency_model(model, per_row))


def _make_optimal_per_row(*, per_row=None, model=None):
    m = resolve_latency_model(model, per_row, default=LatencyModel.MODEL_30)
    if m is not LatencyModel.MODEL_30:
        raise ValueError(
            "scheme 'optimal_per_row' is fixed to MODEL_30; use 'optimal' "
            "with model=MODEL_1 instead"
        )
    return Optimal(model=LatencyModel.MODEL_30)


def _make_uniform_n(*, n=None):
    if n is None:
        raise ValueError("scheme 'uniform_n' requires the code size n")
    return UniformN(n=float(n))


def _make_uniform_r(*, r=None):
    if r is None:
        raise ValueError("scheme 'uniform_r' requires the completion count r")
    return UniformR(r=int(r))


def _make_comm_aware(*, upload=None, download=None):
    kw = {}
    if upload is not None:
        kw["upload"] = float(upload)
    if download is not None:
        kw["download"] = float(download)
    return CommAware(**kw)


def _make_comm_uniform(*, n=None, upload=None, download=None):
    kw = {}
    if n is not None:
        kw["n"] = float(n)
    if upload is not None:
        kw["upload"] = float(upload)
    if download is not None:
        kw["download"] = float(download)
    return CommUniform(**kw)


def _make_grad_coding(*, per_row=None, model=None):
    return GradCoding(model=resolve_latency_model(model, per_row))


def _make_grad_coding_per_row(*, per_row=None, model=None):
    m = resolve_latency_model(model, per_row, default=LatencyModel.MODEL_30)
    if m is not LatencyModel.MODEL_30:
        raise ValueError(
            "scheme 'grad_coding_per_row' is fixed to MODEL_30; use "
            "'grad_coding' with model=MODEL_1 instead"
        )
    return GradCoding(model=LatencyModel.MODEL_30)


register_scheme("optimal", _make_optimal)
register_scheme("grad_coding", _make_grad_coding)
register_scheme("grad_coding_per_row", _make_grad_coding_per_row)
register_scheme("optimal_per_row", _make_optimal_per_row)
register_scheme("uniform_n", _make_uniform_n)
register_scheme("uniform_r", _make_uniform_r)
register_scheme("uniform_r_group_code", _make_uniform_r)
register_scheme("reisizadeh", lambda: Reisizadeh())
register_scheme("uncoded", lambda: Uncoded())
register_scheme("comm_aware", _make_comm_aware)
register_scheme("comm_uniform", _make_comm_uniform)


def scheme_for_plan(plan) -> AllocationScheme:
    """The scheme object behind a plan (Allocation- or DeploymentPlan).

    Plans produced through the registry carry their scheme object; for
    legacy plans built from the bare allocation functions the scheme is
    reconstructed best-effort from the name tag and the plan's own fields
    (n from the deployed code size, r from k / per-worker load).
    """
    obj = getattr(plan, "scheme_obj", None)
    if obj is not None:
        return obj
    alloc = getattr(plan, "allocation", None)
    if alloc is not None:
        if alloc.scheme_obj is not None:
            return alloc.scheme_obj
        # the real-valued allocation is exact; reconstruct from it rather
        # than from the integerized per-worker loads (which round r/n)
        plan = alloc
    tag = plan.scheme
    loads = getattr(plan, "loads", None)
    if loads is None:
        loads = plan.loads_per_worker  # DeploymentPlan without allocation
    if tag in ("optimal", "optimal_per_row"):
        return Optimal(model=LatencyModel.from_per_row(tag == "optimal_per_row"))
    if tag == "uniform_n":
        return UniformN(n=float(plan.n))
    if tag in ("uniform_r", "uniform_r_group_code"):
        return UniformR(r=int(round(plan.k / float(loads[0]))))
    if tag == "comm_uniform":
        # transfer costs are not recorded on legacy plans; keep the code
        # size so the redundancy survives, default the costs
        return CommUniform(n=float(plan.n))
    return make_scheme(tag)


SCHEME_PARAM_DOC: Mapping[str, str] = {
    "optimal": "model: LatencyModel (default MODEL_1)",
    "grad_coding": "model: LatencyModel (default MODEL_1); "
                   "k = gradient partitions of the global batch",
    "uniform_n": "n: total coded rows (float > 0)",
    "uniform_r": "r: completion count (int in (0, N))",
    "reisizadeh": "(no params; per-row model)",
    "uncoded": "(no params)",
    "comm_aware": "upload, download: transfer costs >= 0 "
                  "(divided by ClusterSpec group bandwidths)",
    "comm_uniform": "n: code size (default: comm-aware n*); upload, download",
}
