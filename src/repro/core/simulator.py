"""Vectorized Monte Carlo latency simulator (paper Section IV).

The master sends x to all N workers; worker i finishes its ``l_i``-row
subtask at a random time drawn from the shifted-exponential model. The
master's completion time is the first instant at which the finished
workers jointly cover ``k`` coded rows (MDS property). Everything is
vectorized over trials in JAX: sample a (trials, N) time matrix, sort
each row, cumulative-sum the loads in finish order, and take the time of
the first crossing of ``k``.

Also provides the group-code semantics of [33] (per-group (N_j, r_j) MDS
codes: latency = max_j of the r_j-th order statistic within group j).

Scheme dispatch lives in ``repro.core.schemes``: ``expected_latency``
resolves the plan's ``AllocationScheme`` object and calls its
``simulate`` method, so new schemes bring their own simulation semantics
without this module growing per-scheme branches.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
import numpy as np

from repro.core.allocation import AllocationPlan
from repro.core.runtime_model import (
    ClusterSpec,
    LatencyModel,
    comm_terms,
    expand_groups,
    resolve_latency_model,
    sample_worker_times,
)


@functools.partial(
    jax.jit, static_argnames=("num_trials", "model", "k")
)
def _threshold_latency(
    key, loads_w, mus_w, alphas_w, shift_w, k, num_trials, model
):
    times = sample_worker_times(
        key, loads_w, mus_w, alphas_w, k, num_trials, model=model,
        shift_per_worker=shift_w,
    )
    order = jnp.argsort(times, axis=1)
    sorted_times = jnp.take_along_axis(times, order, axis=1)
    sorted_loads = loads_w[order]
    covered = jnp.cumsum(sorted_loads, axis=1)
    # First worker index at which covered rows >= k. If the total coded
    # rows are < k the task never completes -> inf.
    done = covered >= k - 1e-6
    idx = jnp.argmax(done, axis=1)
    lat = jnp.take_along_axis(sorted_times, idx[:, None], axis=1)[:, 0]
    feasible = jnp.any(done, axis=1)
    return jnp.where(feasible, lat, jnp.inf)


def simulate_threshold(
    key,
    cluster: ClusterSpec,
    loads_per_group,
    k: int,
    num_trials: int = 10_000,
    *,
    per_row: bool | None = None,
    model: LatencyModel | None = None,
):
    """Latency samples for 'collect until k coded rows' (paper's master)."""
    model = resolve_latency_model(model, per_row)
    loads_w = expand_groups(cluster, loads_per_group)
    mus_w = expand_groups(cluster, [g.mu for g in cluster.groups])
    alphas_w = expand_groups(cluster, [g.alpha for g in cluster.groups])
    return _threshold_latency(
        key,
        loads_w.astype(jnp.float32),
        mus_w.astype(jnp.float32),
        alphas_w.astype(jnp.float32),
        jnp.zeros_like(loads_w, dtype=jnp.float32),
        k,
        num_trials,
        model,
    )


def simulate_comm_threshold(
    key,
    cluster: ClusterSpec,
    loads_per_group,
    k: int,
    num_trials: int = 10_000,
    *,
    upload: float = 1.0,
    download: float = 1.0,
):
    """Latency samples under the CommDelay model (arXiv:2109.11246).

    Completion times are compute + transfer: the fixed input-broadcast
    shift ``upload/b_j`` is added per worker and the per-load download
    cost ``download/b_j`` is folded into ``alpha_j`` (see
    ``runtime_model.comm_terms``); the master semantics are unchanged —
    collect until the finished workers cover k coded rows. Zero-load
    workers (groups excluded by the comm-aware optimum) contribute rows
    at their transfer shift but cover nothing, so they never advance the
    threshold. With all-infinite bandwidths this is exactly
    ``simulate_threshold`` under ``MODEL_1``.
    """
    shift_g, dalpha_g = comm_terms(cluster, upload, download)
    loads_w = expand_groups(cluster, loads_per_group)
    mus_w = expand_groups(cluster, [g.mu for g in cluster.groups])
    alphas_w = expand_groups(
        cluster,
        [g.alpha + d for g, d in zip(cluster.groups, dalpha_g)],
    )
    shift_w = expand_groups(cluster, shift_g)
    return _threshold_latency(
        key,
        loads_w.astype(jnp.float32),
        mus_w.astype(jnp.float32),
        alphas_w.astype(jnp.float32),
        shift_w.astype(jnp.float32),
        k,
        num_trials,
        LatencyModel.COMM_DELAY,
    )


@functools.partial(jax.jit, static_argnames=("num_trials", "model"))
def _group_code_latency(
    key, load, mus_g, alphas_g, valid, r_idx, k, num_trials, model
):
    """Padded single-jit group-code latency: one sample, one sort.

    Groups are padded to the widest group (``valid`` marks real workers;
    pad slots sample +inf so they sort last and can never be the r_j-th
    order statistic), mirroring the threshold path's vectorization —
    no Python loop over groups, one fused program for any cluster shape.
    """
    g, nmax = valid.shape
    e = jax.random.exponential(key, (num_trials, g, nmax), dtype=jnp.float32)
    scale = load if model.per_row else load / k
    t = scale * (alphas_g + e / mus_g)
    t = jnp.where(valid, t, jnp.inf)
    t = jnp.sort(t, axis=2)
    idx = jnp.broadcast_to(r_idx[None, :, None], (num_trials, g, 1))
    per_group = jnp.take_along_axis(t, idx, axis=2)[:, :, 0]
    return jnp.max(per_group, axis=1)


def simulate_group_code(
    key,
    cluster: ClusterSpec,
    load: float,
    r_split,
    k: int,
    num_trials: int = 10_000,
    *,
    per_row: bool | None = None,
    model: LatencyModel | None = None,
):
    """Latency samples for the [33] group-code scheme.

    Each group j independently runs an (N_j, r_j) MDS code over uniform
    loads; the master must decode every group, so the latency is the max
    over groups of the r_j-th order statistic.
    """
    model = resolve_latency_model(model, per_row)
    nmax = max(g.num_workers for g in cluster.groups)
    ng = cluster.num_groups
    valid = np.zeros((ng, nmax), dtype=bool)
    r_idx = np.zeros((ng,), dtype=np.int32)
    for j, g in enumerate(cluster.groups):
        valid[j, : g.num_workers] = True
        r_j = int(np.ceil(r_split[j] - 1e-9))
        r_idx[j] = max(1, min(r_j, g.num_workers)) - 1
    mus = jnp.asarray([g.mu for g in cluster.groups], jnp.float32)
    alphas = jnp.asarray([g.alpha for g in cluster.groups], jnp.float32)
    return _group_code_latency(
        key,
        jnp.float32(load),
        mus[:, None],
        alphas[:, None],
        jnp.asarray(valid),
        jnp.asarray(r_idx),
        jnp.float32(k),
        num_trials,
        model,
    )


def expected_latency(
    key,
    cluster: ClusterSpec,
    plan: AllocationPlan,
    num_trials: int = 10_000,
    *,
    per_row: bool | None = None,
    model: LatencyModel | None = None,
    use_integer_loads: bool = False,
) -> float:
    """Mean Monte-Carlo latency of an AllocationPlan under a cluster.

    Simulation semantics come from the plan's scheme object (threshold
    decoding by default; per-group order statistics for the group code),
    and the latency model defaults to the scheme's own unless overridden
    via ``model`` (or the legacy ``per_row`` flag).
    """
    from repro.core.schemes import scheme_for_plan  # deferred: schemes uses us

    scheme = scheme_for_plan(plan)
    model = resolve_latency_model(model, per_row, default=None)
    lat = scheme.simulate(
        key,
        cluster,
        plan,
        num_trials,
        model=model,
        use_integer_loads=use_integer_loads,
    )
    return float(jnp.mean(lat))
