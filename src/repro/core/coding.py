"""Real-valued MDS coding for distributed matrix-vector multiplication.

The paper applies an (n, k) MDS code to the ROWS of the data matrix
``A in R^{k x d}``: ``A~ = G A`` with a generator ``G in R^{n x k}`` whose
every k-row submatrix is invertible. The master recovers ``A x`` from any
k coded inner products by solving ``G_S z = y~_S``.

Generators provided:

* ``systematic_gaussian`` — ``G = [I_k; P]`` with i.i.d. Gaussian parity
  ``P`` (MDS with probability 1; decode touches only the missing
  systematic rows, which keeps the solve small and well-conditioned when
  few stragglers are erased).
* ``chebyshev_vandermonde`` — Vandermonde on Chebyshev nodes (determinis-
  tic, every minor nonsingular; conditioning degrades with k, fine for
  k <= a few hundred as used in tests/examples).

Encoding is a matmul (performed once, offline, like the paper's setup
phase); the Pallas kernel in ``repro/kernels/mds_encode`` provides the
TPU-tiled version of the same contraction.

Decoding comes in two flavours: ``decode_systematic_jit`` — the
fixed-shape, device-resident decode used by the serving pipeline (one
compiled gather+solve per round, composable under ``jax.lax.scan``) —
and the numpy ``decode_systematic`` / ``decode_from_rows`` pair kept as
reference oracles for tests and the legacy host-loop path.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
import numpy as np


def make_generator(n: int, k: int, key=None, kind: str = "systematic_gaussian"):
    """Build an (n, k) real MDS generator matrix."""
    assert n >= k >= 1
    if kind == "systematic_gaussian":
        if key is None:
            key = jax.random.PRNGKey(0)
        p = jax.random.normal(key, (n - k, k), dtype=jnp.float32) / np.sqrt(k)
        return jnp.concatenate([jnp.eye(k, dtype=jnp.float32), p], axis=0)
    if kind == "chebyshev_vandermonde":
        i = np.arange(n)
        nodes = np.cos((2 * i + 1) * np.pi / (2 * n))  # distinct in (-1, 1)
        powers = np.arange(k)
        g = nodes[:, None] ** powers[None, :]
        return jnp.asarray(g, dtype=jnp.float32)
    raise ValueError(f"unknown generator kind: {kind}")


def encode(generator, a):
    """A~ = G A  (rows of A are coded; columns untouched)."""
    return generator @ a


def split_loads(loads_int_per_worker):
    """Row ranges [(start, stop)) of A~ for each worker, from integer loads."""
    starts = np.concatenate([[0], np.cumsum(loads_int_per_worker)[:-1]])
    return [
        (int(s), int(s + l)) for s, l in zip(starts, loads_int_per_worker)
    ]


@functools.partial(jax.jit, static_argnames=())
def decode_from_rows(generator_rows, coded_values):
    """Recover A x from >= k coded inner products.

    Args:
      generator_rows: (m, k) the generator rows of the surviving coded
        inner products, m >= k.
      coded_values: (m,) or (m, c) the corresponding values of A~ x.

    Returns the least-squares solution z (= A x when G_S has rank k).
    """
    sol = jnp.linalg.lstsq(generator_rows, coded_values)[0]
    return sol


@jax.jit
def decode_systematic_jit(generator, coded_values, finished_mask):
    """Fixed-shape, device-resident erasure decode (the serving hot path).

    Unlike ``decode_systematic`` (the numpy reference oracle below) this
    never leaves the device and never branches on data: the surviving
    coded rows are selected with a stable argsort on the erasure mask —
    survivors first, in index order — and the first k of them are
    gathered into a static ``(k, k)`` system solved on-device. For a
    systematic generator with few erasures that system is mostly identity
    rows, so it stays well-conditioned; one step of iterative refinement
    recovers oracle-level accuracy at float32.

    Args:
      generator: (n, k) MDS generator used at encode time.
      coded_values: (n,) or (n, c) coded products; garbage where
        ``finished_mask`` is False (garbage rows are never gathered
        while >= k rows survive).
      finished_mask: (n,) bool — which coded rows arrived by the deadline.

    Returns (z, ok): the decoded (k,) or (k, c) product and a traced
    bool that is False when fewer than k rows survived (z is zeroed; the
    caller selects a fallback with ``jnp.where`` — see DESIGN.md §4).
    """
    g = jnp.asarray(generator)
    n, k = g.shape
    y = jnp.asarray(coded_values)
    mask = jnp.asarray(finished_mask, dtype=bool)
    # Survivors first, original order preserved -> static (k,) gather.
    order = jnp.argsort(~mask, stable=True)
    idx = order[:k]
    g_s = g[idx]
    y_s = y[idx].astype(g.dtype)
    rhs = y_s if y_s.ndim == 2 else y_s[:, None]
    lu, piv = jax.scipy.linalg.lu_factor(g_s)
    z = jax.scipy.linalg.lu_solve((lu, piv), rhs)
    z = z + jax.scipy.linalg.lu_solve((lu, piv), rhs - g_s @ z)  # refine
    z = z if y_s.ndim == 2 else z[:, 0]
    ok = jnp.sum(mask) >= k
    return jnp.where(ok, z.astype(y.dtype), jnp.zeros_like(z, dtype=y.dtype)), ok


def decode_systematic(generator, coded_values, finished_mask, k: int):
    """Fast decode for systematic generators.

    Uses surviving systematic rows directly and solves only for the
    missing ones using parity rows — an O(e^3) solve for e erasures
    instead of O(k^3). Falls back to a dense solve when not systematic.

    Args:
      generator: (n, k) systematic generator [I; P].
      coded_values: (n,) or (n, c) coded products, garbage where
        ``finished_mask`` is False.
      finished_mask: (n,) bool — which coded rows arrived in time.
      k: number of uncoded rows.

    Returns (z, ok): the decoded A x and whether enough rows survived.
    This path is numpy (decode happens on the master, tiny cost compared
    to the distributed matvec itself).
    """
    g = np.asarray(generator)
    y = np.asarray(coded_values)
    mask = np.asarray(finished_mask)
    n = g.shape[0]
    assert mask.shape == (n,)
    if mask.sum() < k:
        return np.zeros((k,) + y.shape[1:], dtype=y.dtype), False
    sys_alive = mask[:k]
    missing = np.flatnonzero(~sys_alive)
    out_shape = (k,) + y.shape[1:]
    z = np.zeros(out_shape, dtype=y.dtype)
    z[np.flatnonzero(sys_alive)] = y[:k][sys_alive]
    if missing.size == 0:
        return z, True
    parity_alive = np.flatnonzero(mask[k:]) + k
    if parity_alive.size < missing.size:
        return z, False
    # Choose the first e surviving parity rows; G_par @ z_full = y_par.
    use = parity_alive[: max(missing.size, min(parity_alive.size, 2 * missing.size))]
    g_par = g[use]  # (p, k)
    rhs = y[use] - g_par[:, np.flatnonzero(sys_alive)] @ z[np.flatnonzero(sys_alive)]
    sub = g_par[:, missing]  # (p, e)
    sol, *_ = np.linalg.lstsq(sub, rhs, rcond=None)
    z[missing] = sol
    return z, True
