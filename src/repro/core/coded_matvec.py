"""Distributed coded matvec: the paper's master/worker pattern on a mesh.

TPU adaptation (see DESIGN.md §3): SPMD collectives cannot early-exit on
"first k rows", so the runtime path is deadline-based — every worker
computes its block, an erasure mask marks which workers met the deadline
(injected by tests; produced by the telemetry layer in deployment), and
the master decodes A·x from the surviving coded rows.

Layout: the coded matrix ``A~`` is laid out worker-major with per-worker
blocks PADDED to ``max_load`` rows so the array shards evenly over the
``workers`` mesh axis: shape (W, max_load, d). shard_map gives each
device its block; the local product is one matvec (the Pallas kernel in
``repro/kernels/coded_matvec`` is the TPU-tiled version, selectable with
``use_kernel=True``); results are all-gathered and decoded.

The hot path is ``DecodePipeline``: matvec, erasure-mask application and
the fixed-shape decode fused into ONE jitted master step, so a coded
round never round-trips through the host (DESIGN.md §4). The split
``coded_matvec`` / ``decode_coded_result`` pair remains as the host-side
reference path.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
import numpy as np
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P

from repro.core.coding import (
    decode_from_rows,
    decode_systematic_jit,
    encode,
    make_generator,
)
from repro.core.planner import DeploymentPlan

if hasattr(jax, "shard_map"):  # jax >= 0.6: top-level API, check_vma kwarg
    _shard_map = jax.shard_map
    _SHARD_MAP_NO_CHECK = {"check_vma": False}
else:  # older jax: experimental module, check_rep kwarg
    from jax.experimental.shard_map import shard_map as _shard_map

    _SHARD_MAP_NO_CHECK = {"check_rep": False}


def pack_coded_matrix(generator, a, plan: DeploymentPlan):
    """Encode A and pack per-worker blocks padded to max_load.

    Returns:
      packed: (W, max_load, d) float32 — worker i's rows in [i, :load_i].
      row_of: (W, max_load) int32 — index into coded rows for each packed
        slot (used to select generator rows at decode time); -1 = pad.
    """
    coded = np.asarray(encode(generator, a))
    w = plan.num_workers
    ml = plan.max_load
    d = coded.shape[1]
    packed = np.zeros((w, ml, d), dtype=np.float32)
    row_of = np.full((w, ml), -1, dtype=np.int32)
    for i, (s, e) in enumerate(plan.row_ranges):
        packed[i, : e - s] = coded[s:e]
        row_of[i, : e - s] = np.arange(s, e, dtype=np.int32)
    return packed, row_of


def _local_matvec(a_block, x):
    # a_block: (1, max_load, d) on this shard; x replicated (d,)
    return jnp.einsum("wld,d->wl", a_block, x)


def coded_matvec(
    mesh: Mesh,
    packed,
    x,
    *,
    axis: str = "workers",
    use_kernel: bool = False,
):
    """All-workers coded product: (W, max_load) of A~_i x, sharded on axis.

    This is the hot path (the paper's per-worker subtask). Decode is
    separate (`decode_coded_result`) because the erasure mask is only
    known at the deadline.
    """
    if use_kernel:
        from repro.kernels.coded_matvec import ops as cmv_ops

        local = lambda a_block, xv: cmv_ops.blocked_matvec_batch(a_block, xv)
    else:
        local = _local_matvec

    fn = jax.jit(
        _shard_map(
            lambda a_block, xv: local(a_block, xv),
            mesh=mesh,
            in_specs=(P(axis, None, None), P()),
            out_specs=P(axis, None),
            # pallas_call outputs carry no varying-mesh-axes metadata
            **_SHARD_MAP_NO_CHECK,
        )
    )
    return fn(packed, x)


def decode_coded_result(
    generator, row_of, partials, finished_workers, k: int
):
    """Master-side decode from the workers that met the deadline.

    Args:
      generator: (n, k) MDS generator used at pack time.
      row_of: (W, max_load) packed-slot -> coded-row map (-1 pads).
      partials: (W, max_load) per-slot inner products.
      finished_workers: (W,) bool mask.
      k: uncoded rows.

    Returns (z, ok): least-squares recovery of A x.
    """
    row_of = np.asarray(row_of)
    partials = np.asarray(partials)
    fin = np.asarray(finished_workers)
    slot_ok = (row_of >= 0) & fin[:, None]
    rows = row_of[slot_ok]
    vals = partials[slot_ok]
    if rows.size < k:
        return np.zeros((k,), dtype=partials.dtype), False
    g_rows = np.asarray(generator)[rows]
    z = np.asarray(decode_from_rows(jnp.asarray(g_rows), jnp.asarray(vals)))
    return z, True


def masked_decode(generator, row_of, partials, finished_workers):
    """Fuse erasure-mask application + decode, entirely on-device.

    Scatters the packed per-slot products into coded-row order (pad slots
    and straggler workers dropped via out-of-bounds indices), marks the
    surviving rows, and runs the fixed-shape jit decode. Traceable — the
    jitted master step of ``DecodePipeline`` inlines it after the
    shard_map matvec so compute -> mask -> decode is one XLA program.

    Returns (z, ok) with ``ok`` a traced bool (False: < k rows survived).
    """
    generator = jnp.asarray(generator)
    n = generator.shape[0]
    row_of = jnp.asarray(row_of)
    partials = jnp.asarray(partials)
    fin = jnp.asarray(finished_workers, dtype=bool)
    # row index per packed slot; dead/pad slots pushed out of bounds
    rows = jnp.where((row_of >= 0) & fin[:, None], row_of, n).ravel()
    y = jnp.zeros((n,), partials.dtype).at[rows].set(
        partials.ravel(), mode="drop"
    )
    alive = jnp.zeros((n,), bool).at[rows].set(True, mode="drop")
    return decode_systematic_jit(generator, y, alive)


@functools.partial(
    jax.jit, static_argnames=("mesh", "axis", "use_kernel")
)
def _fused_master_step(
    packed, x, finished_workers, generator, row_of, *, mesh, axis, use_kernel
):
    """One compiled coded round: sharded matvec -> mask -> decode.

    Module-level so the jit cache is shared across ``DecodePipeline``
    instances (Mesh objects are hashable): repeated pipelines over the
    same deployment shapes reuse one compiled program.
    """
    if use_kernel:
        from repro.kernels.coded_matvec import ops as cmv_ops

        local = cmv_ops.blocked_matvec_batch
    else:
        local = _local_matvec
    sharded = _shard_map(
        lambda a_block, xv: local(a_block, xv),
        mesh=mesh,
        in_specs=(P(axis, None, None), P()),
        out_specs=P(axis, None),
        **_SHARD_MAP_NO_CHECK,
    )
    partials = sharded(packed, x)
    return masked_decode(generator, row_of, partials, finished_workers)


class DecodePipeline:
    """Jit-native master step: matvec -> erasure mask -> decode, one jit.

    Binds the deployment state (mesh, generator, slot->row map, kernel
    choice) at construction; each call runs the whole coded round as a
    single compiled program with no host transfer between the
    distributed compute and the decode (see DESIGN.md §4).
    """

    def __init__(self, mesh: Mesh, generator, row_of, *,
                 axis: str = "workers", use_kernel: bool = False):
        self.mesh = mesh
        self.axis = axis
        self.use_kernel = use_kernel
        self.generator = jnp.asarray(generator)
        self.row_of = jnp.asarray(row_of)

    def __call__(self, packed, x, finished_workers):
        return _fused_master_step(
            packed, x, finished_workers, self.generator, self.row_of,
            mesh=self.mesh, axis=self.axis, use_kernel=self.use_kernel,
        )


def end_to_end_coded_matvec(
    mesh: Mesh,
    a,
    x,
    plan: DeploymentPlan,
    finished_workers=None,
    *,
    key=None,
    use_kernel: bool = False,
    jit_decode: bool = True,
):
    """Convenience wrapper: encode -> distribute -> compute -> decode.

    ``jit_decode=True`` (default) runs the fused ``DecodePipeline`` —
    the result never leaves the device between compute and decode.
    ``jit_decode=False`` keeps the legacy host-side numpy decode as a
    reference path.
    """
    k = a.shape[0]
    assert k == plan.k
    gen = make_generator(plan.n, k, key=key)
    packed, row_of = pack_coded_matrix(gen, a, plan)
    if finished_workers is None:
        finished_workers = np.ones((plan.num_workers,), dtype=bool)
    if jit_decode:
        pipeline = DecodePipeline(mesh, gen, row_of, use_kernel=use_kernel)
        return pipeline(
            jnp.asarray(packed), jnp.asarray(x), jnp.asarray(finished_workers)
        )
    partials = coded_matvec(mesh, jnp.asarray(packed), jnp.asarray(x),
                            use_kernel=use_kernel)
    return decode_coded_result(gen, row_of, partials, finished_workers, k)
