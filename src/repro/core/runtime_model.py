"""Runtime-distribution model of the paper (Section II-B).

Two probabilistic models appear in the paper:

* **Model (1)** (the paper's main model): a group-*j* worker assigned
  ``l_j`` coded rows has round-trip time

      T = alpha_j * l_j / k + (l_j / (k * mu_j)) * Exp(1)

  i.e. CDF ``1 - exp(-(k mu_j / l_j)(t - alpha_j l_j / k))``. Time is
  normalized by the problem size ``k`` (computing all ``k`` rows on one
  unit-speed worker takes ``alpha + 1/mu`` on average).

* **Model (30)** (Section III-E, the model of [32]): per-row scaling,

      T_b = alpha_j * l_j + (l_j / mu_j) * Exp(1).

* **CommDelay** (the communication-delay extension of Sun et al.,
  arXiv:2109.11246): model (1) plus per-worker transfer terms paid
  against the group's link bandwidth ``b_j``,

      T = upload/b_j + (l_j/k) * (alpha_j + download/b_j)
                     + (l_j/(k*mu_j)) * Exp(1)

  i.e. a fixed input-broadcast shift ``c_j = upload/b_j`` (independent
  of the load) and a result-download term proportional to the load,
  which simply adds ``download/b_j`` to the compute shift ``alpha_j``.
  With ``b_j = inf`` (the default bandwidth) both terms vanish and the
  model degenerates exactly to model (1).

The first two are shifted exponentials that scale linearly in the load;
all formulas below take a ``per_row`` flag selecting model (30). The
comm-delay terms are produced by ``comm_terms`` from the cluster's
per-group bandwidths and enter the simulator as a per-worker constant
shift plus an alpha adjustment.

Key closed forms (paper eq. (6) and Appendix A): the expected r-th order
statistic of N i.i.d. such times is

    lambda_{r:N}^{l} = (l/k) (alpha + (H_N - H_{N-r}) / mu)      [model 1]
    lambda_{r:N}^{l} =  l    (alpha + (H_N - H_{N-r}) / mu)      [model 30]

with harmonic numbers H. The paper's analysis uses the approximation
``H_N - H_{N-r} ~ log(N / (N - r))``; both exact and approximate forms
are provided.
"""
from __future__ import annotations

import dataclasses
import enum
from typing import Sequence

import jax
import jax.numpy as jnp
import numpy as np


class LatencyModel(enum.Enum):
    """Which shifted-exponential runtime model the math runs under.

    ``MODEL_1`` is the paper's main model (1): round-trip time scales with
    ``l/k`` (normalized by problem size). ``MODEL_30`` is the per-row model
    (30) of Section III-E / [32]: time scales with ``l`` directly.
    ``COMM_DELAY`` is model (1) augmented with per-worker transfer terms
    (arXiv:2109.11246): the load scaling is the same as ``MODEL_1``; the
    comm shift/alpha adjustments are derived from the cluster's per-group
    bandwidths via ``comm_terms`` and carried separately (they depend on
    the cluster, not just the load). This enum replaces the ``per_row``
    boolean that used to be threaded through every layer; the old keyword
    is still accepted as a deprecated alias.
    """

    MODEL_1 = "model_1"
    MODEL_30 = "model_30"
    COMM_DELAY = "comm_delay"

    @property
    def per_row(self) -> bool:
        """Legacy flag view: True iff this is the per-row model (30)."""
        return self is LatencyModel.MODEL_30

    @classmethod
    def from_per_row(cls, per_row: bool) -> "LatencyModel":
        return cls.MODEL_30 if per_row else cls.MODEL_1


def resolve_latency_model(
    model: "LatencyModel | str | None",
    per_row: bool | None = None,
    default: "LatencyModel | None" = LatencyModel.MODEL_1,
) -> "LatencyModel | None":
    """Collapse the (model, legacy per_row flag) pair into one LatencyModel.

    ``model`` wins when given; otherwise an explicit ``per_row`` flag is
    honoured; otherwise ``default``.
    """
    if model is not None:
        return model if isinstance(model, LatencyModel) else LatencyModel(model)
    if per_row is not None:
        return LatencyModel.from_per_row(per_row)
    return default


@dataclasses.dataclass(frozen=True)
class GroupSpec:
    """One heterogeneous worker group."""

    num_workers: int  # N_j
    mu: float  # straggling (rate) parameter mu_(j)
    alpha: float = 1.0  # shift parameter alpha_(j)
    #: link bandwidth b_(j) for the CommDelay model; inf (the default)
    #: means transfer is free and every comm term vanishes, so existing
    #: call sites and saved plans are unchanged.
    bandwidth: float = float("inf")

    def __post_init__(self):
        if not self.bandwidth > 0:
            raise ValueError(
                f"GroupSpec bandwidth must be > 0, got {self.bandwidth!r}"
            )


@dataclasses.dataclass(frozen=True)
class ClusterSpec:
    """A heterogeneous cluster = a list of groups (paper Section II-A)."""

    groups: tuple[GroupSpec, ...]

    @classmethod
    def make(
        cls,
        num_workers: Sequence[int],
        mus: Sequence[float],
        alphas: Sequence[float] | float = 1.0,
        bandwidths: Sequence[float] | float = float("inf"),
    ) -> "ClusterSpec":
        if not hasattr(alphas, "__len__"):
            alphas = [float(alphas)] * len(num_workers)
        if not hasattr(bandwidths, "__len__"):
            bandwidths = [float(bandwidths)] * len(num_workers)
        assert len(num_workers) == len(mus) == len(alphas) == len(bandwidths)
        return cls(
            tuple(
                GroupSpec(int(n), float(m), float(a), float(b))
                for n, m, a, b in zip(num_workers, mus, alphas, bandwidths)
            )
        )

    @classmethod
    def parse(
        cls, groups: str, default_bandwidth: float | None = None
    ) -> "ClusterSpec":
        """CLI group syntax: ``'6:2.0,6:0.5'`` or ``'6:2.0:8.0,6:0.5:1.0'``.

        Each comma-separated entry is ``N:mu`` or ``N:mu:bandwidth``;
        groups without an explicit bandwidth get ``default_bandwidth``
        (infinite, i.e. comm-free, when that is None). Shared by
        ``launch/serve.py --groups`` and ``launch/dryrun.py
        --coded-groups``.
        """
        fallback = float("inf") if default_bandwidth is None else float(
            default_bandwidth
        )
        if not fallback > 0:
            raise ValueError(
                f"default bandwidth must be > 0, got {default_bandwidth!r}"
            )
        ns, mus, bws = [], [], []
        for part in groups.split(","):
            fields = part.split(":")
            if len(fields) not in (2, 3):
                raise ValueError(
                    f"bad group {part!r}: expected N:mu or N:mu:bandwidth"
                )
            try:
                n = int(fields[0])
            except ValueError:
                raise ValueError(
                    f"bad group {part!r}: worker count {fields[0]!r} is not "
                    f"an integer"
                ) from None
            if n <= 0:
                raise ValueError(
                    f"bad group {part!r}: worker count must be a positive "
                    f"integer, got {n}"
                )
            try:
                mu = float(fields[1])
            except ValueError:
                raise ValueError(
                    f"bad group {part!r}: straggling parameter mu "
                    f"{fields[1]!r} is not a number"
                ) from None
            if not mu > 0:
                raise ValueError(
                    f"bad group {part!r}: straggling parameter mu must be "
                    f"> 0, got {mu}"
                )
            if len(fields) == 3:
                try:
                    bw = float(fields[2])
                except ValueError:
                    raise ValueError(
                        f"bad group {part!r}: bandwidth {fields[2]!r} is "
                        f"not a number"
                    ) from None
                if not bw > 0:
                    raise ValueError(
                        f"bad group {part!r}: bandwidth must be > 0, got "
                        f"{bw} (use inf or omit it for a free link)"
                    )
            else:
                bw = fallback
            ns.append(n)
            mus.append(mu)
            bws.append(bw)
        return cls.make(ns, mus, 1.0, bws)

    @property
    def num_groups(self) -> int:
        return len(self.groups)

    @property
    def total_workers(self) -> int:
        return sum(g.num_workers for g in self.groups)

    def arrays(self):
        """(N_j, mu_j, alpha_j) as float arrays (f64 when x64 is enabled)."""
        dt = jnp.float64 if jax.config.jax_enable_x64 else jnp.float32
        n = jnp.asarray([g.num_workers for g in self.groups], dtype=dt)
        mu = jnp.asarray([g.mu for g in self.groups], dtype=dt)
        al = jnp.asarray([g.alpha for g in self.groups], dtype=dt)
        return n, mu, al

    def scale_mu(self, q: float) -> "ClusterSpec":
        """Scale every group's straggling parameter by q (paper's Fig 2/5)."""
        return ClusterSpec(
            tuple(
                GroupSpec(g.num_workers, g.mu * q, g.alpha, g.bandwidth)
                for g in self.groups
            )
        )

    def with_bandwidths(
        self, bandwidths: Sequence[float] | float
    ) -> "ClusterSpec":
        """Same cluster with per-group (or shared scalar) link bandwidths."""
        if not hasattr(bandwidths, "__len__"):
            bandwidths = [float(bandwidths)] * self.num_groups
        assert len(bandwidths) == self.num_groups
        return ClusterSpec(
            tuple(
                GroupSpec(g.num_workers, g.mu, g.alpha, float(b))
                for g, b in zip(self.groups, bandwidths)
            )
        )

    @property
    def bandwidths(self) -> np.ndarray:
        """Per-group link bandwidths b_(j) as a float array (inf = free)."""
        return np.asarray([g.bandwidth for g in self.groups], dtype=np.float64)


def harmonic(n):
    """H_n for real n >= 0 via digamma (exact for integer n)."""
    n = jnp.asarray(n, dtype=jnp.float64)
    return jax.scipy.special.digamma(n + 1.0) + jnp.euler_gamma


def xi(r, n_workers, mu, alpha):
    """xi(r_j, N_j, mu_j) = alpha + log(N/(N-r))/mu  (paper eq. (9))."""
    return alpha + jnp.log(n_workers / (n_workers - r)) / mu


def expected_order_stat(
    load,
    r,
    n_workers,
    mu,
    alpha,
    k,
    *,
    per_row: bool | None = None,
    model: LatencyModel | None = None,
    exact_harmonic: bool = False,
):
    """lambda^{l}_{r:N} — expected r-th order statistic (paper eq. (6)).

    With ``exact_harmonic`` uses H_N - H_{N-r}; otherwise the paper's
    log(N/(N-r)) approximation.
    """
    model = resolve_latency_model(model, per_row)
    if exact_harmonic:
        tail = (harmonic(n_workers) - harmonic(n_workers - r)) / mu
    else:
        tail = jnp.log(n_workers / (n_workers - r)) / mu
    scale = load if model.per_row else load / k
    return scale * (alpha + tail)


def sample_worker_times(
    key,
    loads_per_worker,
    mus_per_worker,
    alphas_per_worker,
    k,
    num_trials: int,
    *,
    per_row: bool | None = None,
    model: LatencyModel | None = None,
    shift_per_worker=None,
    dtype=jnp.float32,
):
    """Sample (num_trials, N) round-trip times under model (1), (30) or comm.

    ``loads_per_worker`` etc. are length-N arrays (already expanded from
    groups). ``shift_per_worker`` is the CommDelay fixed transfer shift
    ``c_j`` (expanded per worker, added load-independently); for the
    comm model the download term is folded into the alphas by the caller
    (see ``comm_terms``). Returns times with shape (num_trials, N).
    """
    model = resolve_latency_model(model, per_row)
    l = jnp.asarray(loads_per_worker, dtype=dtype)
    mu = jnp.asarray(mus_per_worker, dtype=dtype)
    al = jnp.asarray(alphas_per_worker, dtype=dtype)
    e = jax.random.exponential(key, (num_trials, l.shape[0]), dtype=dtype)
    if model.per_row:
        t = al * l + (l / mu) * e
    else:
        t = al * l / k + (l / (k * mu)) * e
    if shift_per_worker is not None:
        t = t + jnp.asarray(shift_per_worker, dtype=dtype)
    return t


def comm_terms(cluster: ClusterSpec, upload: float, download: float):
    """Per-group CommDelay transfer terms ``(c_j, dalpha_j)``.

    ``c_j = upload / b_j`` is the fixed input-broadcast shift (paid once
    per round, independent of the load); ``dalpha_j = download / b_j`` is
    the per-unit-load result-download cost that adds to the compute shift
    ``alpha_j``. Groups with infinite bandwidth (the default) contribute
    exactly zero, so the model degenerates to model (1).
    """
    if upload < 0 or download < 0:
        raise ValueError(
            f"comm costs must be >= 0, got upload={upload}, download={download}"
        )
    b = cluster.bandwidths
    inv_b = np.where(np.isinf(b), 0.0, 1.0 / b)
    return upload * inv_b, download * inv_b


def expand_groups(cluster: ClusterSpec, per_group_values: Sequence[float]):
    """Repeat per-group values to per-worker arrays (length N)."""
    out = []
    for g, v in zip(cluster.groups, per_group_values):
        out.append(np.full((g.num_workers,), float(v)))
    return jnp.asarray(np.concatenate(out))
