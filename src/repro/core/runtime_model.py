"""Runtime-distribution model of the paper (Section II-B).

Two probabilistic models appear in the paper:

* **Model (1)** (the paper's main model): a group-*j* worker assigned
  ``l_j`` coded rows has round-trip time

      T = alpha_j * l_j / k + (l_j / (k * mu_j)) * Exp(1)

  i.e. CDF ``1 - exp(-(k mu_j / l_j)(t - alpha_j l_j / k))``. Time is
  normalized by the problem size ``k`` (computing all ``k`` rows on one
  unit-speed worker takes ``alpha + 1/mu`` on average).

* **Model (30)** (Section III-E, the model of [32]): per-row scaling,

      T_b = alpha_j * l_j + (l_j / mu_j) * Exp(1).

Both are shifted exponentials that scale linearly in the load; all
formulas below take a ``per_row`` flag selecting model (30).

Key closed forms (paper eq. (6) and Appendix A): the expected r-th order
statistic of N i.i.d. such times is

    lambda_{r:N}^{l} = (l/k) (alpha + (H_N - H_{N-r}) / mu)      [model 1]
    lambda_{r:N}^{l} =  l    (alpha + (H_N - H_{N-r}) / mu)      [model 30]

with harmonic numbers H. The paper's analysis uses the approximation
``H_N - H_{N-r} ~ log(N / (N - r))``; both exact and approximate forms
are provided.
"""
from __future__ import annotations

import dataclasses
import enum
from typing import Sequence

import jax
import jax.numpy as jnp
import numpy as np


class LatencyModel(enum.Enum):
    """Which shifted-exponential runtime model the math runs under.

    ``MODEL_1`` is the paper's main model (1): round-trip time scales with
    ``l/k`` (normalized by problem size). ``MODEL_30`` is the per-row model
    (30) of Section III-E / [32]: time scales with ``l`` directly. This enum
    replaces the ``per_row`` boolean that used to be threaded through every
    layer; the old keyword is still accepted as a deprecated alias.
    """

    MODEL_1 = "model_1"
    MODEL_30 = "model_30"

    @property
    def per_row(self) -> bool:
        """Legacy flag view: True iff this is the per-row model (30)."""
        return self is LatencyModel.MODEL_30

    @classmethod
    def from_per_row(cls, per_row: bool) -> "LatencyModel":
        return cls.MODEL_30 if per_row else cls.MODEL_1


def resolve_latency_model(
    model: "LatencyModel | str | None",
    per_row: bool | None = None,
    default: "LatencyModel | None" = LatencyModel.MODEL_1,
) -> "LatencyModel | None":
    """Collapse the (model, legacy per_row flag) pair into one LatencyModel.

    ``model`` wins when given; otherwise an explicit ``per_row`` flag is
    honoured; otherwise ``default``.
    """
    if model is not None:
        return model if isinstance(model, LatencyModel) else LatencyModel(model)
    if per_row is not None:
        return LatencyModel.from_per_row(per_row)
    return default


@dataclasses.dataclass(frozen=True)
class GroupSpec:
    """One heterogeneous worker group."""

    num_workers: int  # N_j
    mu: float  # straggling (rate) parameter mu_(j)
    alpha: float = 1.0  # shift parameter alpha_(j)


@dataclasses.dataclass(frozen=True)
class ClusterSpec:
    """A heterogeneous cluster = a list of groups (paper Section II-A)."""

    groups: tuple[GroupSpec, ...]

    @classmethod
    def make(
        cls,
        num_workers: Sequence[int],
        mus: Sequence[float],
        alphas: Sequence[float] | float = 1.0,
    ) -> "ClusterSpec":
        if not hasattr(alphas, "__len__"):
            alphas = [float(alphas)] * len(num_workers)
        assert len(num_workers) == len(mus) == len(alphas)
        return cls(
            tuple(
                GroupSpec(int(n), float(m), float(a))
                for n, m, a in zip(num_workers, mus, alphas)
            )
        )

    @property
    def num_groups(self) -> int:
        return len(self.groups)

    @property
    def total_workers(self) -> int:
        return sum(g.num_workers for g in self.groups)

    def arrays(self):
        """(N_j, mu_j, alpha_j) as float arrays (f64 when x64 is enabled)."""
        dt = jnp.float64 if jax.config.jax_enable_x64 else jnp.float32
        n = jnp.asarray([g.num_workers for g in self.groups], dtype=dt)
        mu = jnp.asarray([g.mu for g in self.groups], dtype=dt)
        al = jnp.asarray([g.alpha for g in self.groups], dtype=dt)
        return n, mu, al

    def scale_mu(self, q: float) -> "ClusterSpec":
        """Scale every group's straggling parameter by q (paper's Fig 2/5)."""
        return ClusterSpec(
            tuple(
                GroupSpec(g.num_workers, g.mu * q, g.alpha) for g in self.groups
            )
        )


def harmonic(n):
    """H_n for real n >= 0 via digamma (exact for integer n)."""
    n = jnp.asarray(n, dtype=jnp.float64)
    return jax.scipy.special.digamma(n + 1.0) + jnp.euler_gamma


def xi(r, n_workers, mu, alpha):
    """xi(r_j, N_j, mu_j) = alpha + log(N/(N-r))/mu  (paper eq. (9))."""
    return alpha + jnp.log(n_workers / (n_workers - r)) / mu


def expected_order_stat(
    load,
    r,
    n_workers,
    mu,
    alpha,
    k,
    *,
    per_row: bool | None = None,
    model: LatencyModel | None = None,
    exact_harmonic: bool = False,
):
    """lambda^{l}_{r:N} — expected r-th order statistic (paper eq. (6)).

    With ``exact_harmonic`` uses H_N - H_{N-r}; otherwise the paper's
    log(N/(N-r)) approximation.
    """
    model = resolve_latency_model(model, per_row)
    if exact_harmonic:
        tail = (harmonic(n_workers) - harmonic(n_workers - r)) / mu
    else:
        tail = jnp.log(n_workers / (n_workers - r)) / mu
    scale = load if model.per_row else load / k
    return scale * (alpha + tail)


def sample_worker_times(
    key,
    loads_per_worker,
    mus_per_worker,
    alphas_per_worker,
    k,
    num_trials: int,
    *,
    per_row: bool | None = None,
    model: LatencyModel | None = None,
    dtype=jnp.float32,
):
    """Sample (num_trials, N) round-trip times under model (1) or (30).

    ``loads_per_worker`` etc. are length-N arrays (already expanded from
    groups). Returns times with shape (num_trials, N).
    """
    model = resolve_latency_model(model, per_row)
    l = jnp.asarray(loads_per_worker, dtype=dtype)
    mu = jnp.asarray(mus_per_worker, dtype=dtype)
    al = jnp.asarray(alphas_per_worker, dtype=dtype)
    e = jax.random.exponential(key, (num_trials, l.shape[0]), dtype=dtype)
    if model.per_row:
        return al * l + (l / mu) * e
    return al * l / k + (l / (k * mu)) * e


def expand_groups(cluster: ClusterSpec, per_group_values: Sequence[float]):
    """Repeat per-group values to per-worker arrays (length N)."""
    out = []
    for g, v in zip(cluster.groups, per_group_values):
        out.append(np.full((g.num_workers,), float(v)))
    return jnp.asarray(np.concatenate(out))
