"""CodedComputeEngine: one object owning the coded-computation lifecycle.

``ClusterSpec -> scheme -> AllocationPlan -> DeploymentPlan -> generator
-> simulate / deadline / replan`` used to be five separate calls spread
over the planner, coding, simulator and fault-tolerance modules, each
re-threading the scheme name and its params. The engine bundles them:

    eng = CodedComputeEngine(cluster, k=100_000, scheme="uniform_r",
                             scheme_params={"r": 100})
    eng.plan                    # integerized DeploymentPlan
    eng.expected_latency(key)   # Monte-Carlo mean under the scheme's model
    eng.deadline()              # finite per-round cutoff (MC fallback)
    eng.generator()             # (n, k) MDS generator sized to the plan
    eng.replan(new_cluster)     # elastic re-plan, scheme params preserved

Consumed by the serving loop (coded LM head), the fault-tolerance layer
(ElasticController), the launch drivers, and the paper-figure benchmarks.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp
import numpy as np

from repro.core import planner
from repro.core.allocation import AllocationPlan
from repro.core.coding import make_generator
from repro.core.runtime_model import ClusterSpec, LatencyModel
from repro.core.schemes import AllocationScheme, make_scheme, scheme_for_plan


def plan_deadline(
    plan: "planner.DeploymentPlan",
    safety: float = 3.0,
    *,
    key=None,
    num_trials: int = 2_048,
) -> float:
    """Per-round cutoff for a deployment: expected latency x safety, finite.

    The single deadline policy shared by ``CodedComputeEngine.deadline``
    and the fault-tolerance layer's ``deadline_for``: the analytic T*
    when the scheme has one; otherwise the scheme's own Monte-Carlo
    latency estimate (uniform-n, reisizadeh, uncoded have NaN T*).
    """
    t = float(plan.t_star)
    if not np.isfinite(t) or t <= 0:
        scheme = scheme_for_plan(plan)
        alloc = plan.allocation
        if alloc is None:  # legacy plan: rebuild through the scheme
            alloc = scheme.allocate(plan.cluster, plan.k)
        if key is None:
            key = jax.random.PRNGKey(0)
        t = scheme.expected_latency(key, plan.cluster, alloc, num_trials)
    return t * safety


class CodedComputeEngine:
    """Facade over plan -> deploy -> encode -> simulate for one workload."""

    def __init__(
        self,
        cluster: ClusterSpec,
        k: int,
        scheme: str | AllocationScheme = "optimal",
        *,
        scheme_params: dict | None = None,
    ):
        if not isinstance(scheme, AllocationScheme):
            scheme = make_scheme(scheme, **(scheme_params or {}))
        elif scheme_params:
            raise ValueError("scheme_params only apply to string scheme names")
        self.scheme = scheme
        self.k = int(k)
        self.replans = 0
        self._plan_for(cluster)

    def _plan_for(self, cluster: ClusterSpec) -> None:
        self.cluster = cluster
        self.plan: planner.DeploymentPlan = planner.deploy(
            self.scheme, cluster, self.k
        )

    # -- plan views --------------------------------------------------------
    @property
    def allocation(self) -> AllocationPlan:
        """The underlying real-valued per-group allocation."""
        return self.plan.allocation

    @property
    def t_star(self) -> float:
        """The scheme's analytic expected latency (NaN when unknown)."""
        return float(self.plan.t_star)

    # -- coding ------------------------------------------------------------
    def generator(self, key=None, kind: str = "systematic_gaussian"):
        """(n, k) MDS generator sized to the deployed plan."""
        if key is None:
            key = jax.random.PRNGKey(0)
        return make_generator(self.plan.n, self.k, key=key, kind=kind)

    # -- evaluation --------------------------------------------------------
    def simulate(
        self,
        key,
        num_trials: int = 10_000,
        *,
        model: LatencyModel | None = None,
        use_integer_loads: bool = False,
    ):
        """Monte-Carlo latency samples under the scheme's own semantics."""
        return self.scheme.simulate(
            key,
            self.cluster,
            self.allocation,
            num_trials,
            model=model,
            use_integer_loads=use_integer_loads,
        )

    def expected_latency(
        self, key, num_trials: int = 10_000, **kwargs
    ) -> float:
        return float(jnp.mean(self.simulate(key, num_trials, **kwargs)))

    def deadline(
        self,
        safety: float = 3.0,
        *,
        key=None,
        num_trials: int = 2_048,
    ) -> float:
        """Per-round cutoff: expected latency x safety factor, always finite.

        See ``plan_deadline`` (shared with the fault-tolerance layer).
        """
        return plan_deadline(
            self.plan, safety, key=key, num_trials=num_trials
        )

    # -- elasticity --------------------------------------------------------
    def replan(self, new_cluster: ClusterSpec) -> planner.DeploymentPlan:
        """Re-plan on a membership change; scheme params are preserved."""
        self._plan_for(new_cluster)
        self.replans += 1
        return self.plan
