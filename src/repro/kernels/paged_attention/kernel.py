"""Pallas TPU kernel: paged single-query attention over a KV block pool.

Grid = (S, MB): program (s, j) processes logical block j of slot s. The
block table and per-slot positions ride in as SCALAR-PREFETCH operands
(``pltpu.PrefetchScalarGridSpec``), so the K/V BlockSpec index maps can
resolve ``table[s, j]`` to a physical pool block *before* the body runs
— the DMA engine fetches exactly the (1, BL, KV, hd) block the table
points at (unallocated entries fetch the sink block and are masked).

Accumulation across the MB grid dimension is the standard online
softmax: running max ``m``, normalizer ``l`` and weighted-value ``acc``
live in VMEM scratch, initialized at j == 0 and stored at j == MB-1
(same revisiting-output pattern as ``kernels/coded_matvec``). ``m`` is
initialized to the finite ``NEG_INF`` sentinel (not −inf) so fully
masked blocks contribute exp(0) terms that the next valid block's
correction factor underflows to exactly zero.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

NEG_INF = -1e30


def _kernel(table_ref, pos_ref, q_ref, k_ref, v_ref, o_ref,
            m_ref, l_ref, acc_ref):
    s = pl.program_id(0)
    j = pl.program_id(1)

    @pl.when(j == 0)
    def _init():
        m_ref[...] = jnp.full_like(m_ref, NEG_INF)
        l_ref[...] = jnp.zeros_like(l_ref)
        acc_ref[...] = jnp.zeros_like(acc_ref)

    q = q_ref[0].astype(jnp.float32)  # (KV, G, hd)
    k = k_ref[0].astype(jnp.float32)  # (BL, KV, hd)
    v = v_ref[0].astype(jnp.float32)
    bl = k.shape[0]
    scale = 1.0 / jnp.sqrt(jnp.float32(q.shape[-1]))
    sc = jnp.einsum("kgh,bkh->kgb", q, k) * scale  # (KV, G, BL)
    logical = j * bl + jnp.arange(bl, dtype=jnp.int32)
    ok = (table_ref[s, j] >= 0) & (logical <= pos_ref[s])
    sc = jnp.where(ok[None, None, :], sc, NEG_INF)
    m_new = jnp.maximum(m_ref[...], jnp.max(sc, axis=-1))
    p = jnp.exp(sc - m_new[..., None])
    corr = jnp.exp(m_ref[...] - m_new)
    l_ref[...] = l_ref[...] * corr + jnp.sum(p, axis=-1)
    acc_ref[...] = acc_ref[...] * corr[..., None] + jnp.einsum(
        "kgb,bkh->kgh", p, v
    )
    m_ref[...] = m_new

    @pl.when(j == pl.num_programs(1) - 1)
    def _store():
        out = acc_ref[...] / jnp.maximum(l_ref[...][..., None], 1e-30)
        o_ref[0] = out.astype(o_ref.dtype)


@functools.partial(jax.jit, static_argnames=("interpret",))
def paged_decode_kernel(q, k_pool, v_pool, table, pos, *,
                        interpret: bool = True):
    """Paged decode attend. q: (S, KV, G, hd); pools: (NBp, BL, KV, hd);
    table: (S, MB) int32; pos: (S,) int32. Returns (S, KV, G, hd)."""
    s, kv, g, hd = q.shape
    nbp, bl = k_pool.shape[:2]
    mb = table.shape[1]
    sink = nbp - 1

    def kv_index(si, j, table_ref, pos_ref):
        t = table_ref[si, j]
        return (jnp.where(t >= 0, t, sink), 0, 0, 0)

    grid_spec = pltpu.PrefetchScalarGridSpec(
        num_scalar_prefetch=2,  # table, pos
        grid=(s, mb),
        in_specs=[
            pl.BlockSpec((1, kv, g, hd), lambda si, j, t, p: (si, 0, 0, 0)),
            pl.BlockSpec((1, bl, kv, hd), kv_index),
            pl.BlockSpec((1, bl, kv, hd), kv_index),
        ],
        out_specs=pl.BlockSpec(
            (1, kv, g, hd), lambda si, j, t, p: (si, 0, 0, 0)
        ),
        scratch_shapes=[
            pltpu.VMEM((kv, g), jnp.float32),  # running max
            pltpu.VMEM((kv, g), jnp.float32),  # normalizer
            pltpu.VMEM((kv, g, hd), jnp.float32),  # weighted values
        ],
    )
    return pl.pallas_call(
        _kernel,
        grid_spec=grid_spec,
        out_shape=jax.ShapeDtypeStruct((s, kv, g, hd), v_pool.dtype),
        interpret=interpret,
    )(table.astype(jnp.int32), pos.astype(jnp.int32), q, k_pool, v_pool)
