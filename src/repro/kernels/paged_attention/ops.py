"""Jax gather/scatter paged-attention path (the production serve path).

The pool is ``(num_blocks + 1, block_len, KV, hd)`` per layer: physical
block ``num_blocks`` is the WRITE SINK — inactive / frozen / padded
writes are routed there so no predicate is needed around the scatter and
a frozen slot can never corrupt a block that was freed and reassigned to
another stream. The sink is never referenced by any block table, so the
gather+mask path never reads it as valid history.

The decode attend mirrors ``models.attention.decode_attention_slots``
operation-for-operation (same einsums, same f32 promotion points, same
softmax) so that with an equivalent layout (blocks in logical order) the
paged decode logits BIT-MATCH the dense slot-cache oracle.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
import numpy as np

NEG_INF = -1e30


def _phys(table, sink):
    """Physical block per table entry; unallocated -> sink."""
    return jnp.where(table >= 0, table, sink)


def gather_kv(pool, table):
    """(NBp, BL, KV, hd), (S, MB) -> (S, MB*BL, KV, hd) logical view."""
    sink = pool.shape[0] - 1
    s, mb = table.shape
    bl = pool.shape[1]
    return pool[_phys(table, sink)].reshape(s, mb * bl, *pool.shape[2:])


def valid_mask(table, block_len, q_pos):
    """(S, MB), BL, (S,) -> (S, MB*BL) attendable-entry mask."""
    alloc = jnp.repeat(table >= 0, block_len, axis=1)
    j = jnp.arange(alloc.shape[1])
    return alloc & (j[None, :] <= q_pos[:, None])


def scatter_decode(k_pool, v_pool, k_new, v_new, table, pos, active):
    """Write one token per slot into the pool at logical position ``pos``.

    k_new/v_new: (S, KV, hd); pos: (S,) int32; active: (S,) bool — rows
    that are not actively decoding write to the sink block.
    """
    sink = jnp.int32(k_pool.shape[0] - 1)
    bl = k_pool.shape[1]
    mb = table.shape[1]
    bidx = jnp.clip(pos // bl, 0, mb - 1)
    blk = jnp.take_along_axis(table, bidx[:, None], axis=1)[:, 0]
    blk = jnp.where(active & (blk >= 0), blk, sink).astype(jnp.int32)
    off = jnp.mod(pos, bl).astype(jnp.int32)
    return k_pool.at[blk, off].set(k_new), v_pool.at[blk, off].set(v_new)


def scatter_chunk(k_pool, v_pool, k_new, v_new, table, start, chunk_len):
    """Write a prefill chunk per slot into the pool.

    k_new/v_new: (S, C, KV, hd); chunk row ``i`` of slot ``s`` lands at
    logical position ``start[s] + i`` when ``i < chunk_len[s]``; padded
    rows (and rows of slots not prefilling this round) go to the sink.
    """
    s, c = k_new.shape[:2]
    sink = jnp.int32(k_pool.shape[0] - 1)
    bl = k_pool.shape[1]
    mb = table.shape[1]
    p = start[:, None] + jnp.arange(c, dtype=jnp.int32)[None, :]  # (S, C)
    writing = jnp.arange(c)[None, :] < chunk_len[:, None]
    bidx = jnp.clip(p // bl, 0, mb - 1)
    blk = jnp.take_along_axis(table, bidx, axis=1)
    blk = jnp.where(writing & (blk >= 0), blk, sink).astype(jnp.int32)
    off = jnp.mod(p, bl).astype(jnp.int32)
    flat = lambda t: t.reshape(s * c, *t.shape[2:])
    return (
        k_pool.at[flat(blk), flat(off)].set(flat(k_new)),
        v_pool.at[flat(blk), flat(off)].set(flat(v_new)),
    )


def paged_decode_attend(q, k_pool, v_pool, table, pos):
    """Single-query paged attention over the gathered pool.

    q: (S, KV, G, hd) post-rope; pos: (S,) write positions (already
    scattered). Mirrors ``decode_attention_slots``'s attend math exactly
    (bit-parity with the dense oracle under an order-preserving layout).
    Returns (S, KV, G, hd) in v's dtype.
    """
    bl = k_pool.shape[1]
    # python-float scale (f64 sqrt), matching decode_attention_slots
    # bit-for-bit — a traced f32 rsqrt can differ in the last ulp
    scale = 1.0 / np.sqrt(q.shape[-1])
    k = gather_kv(k_pool, table)
    v = gather_kv(v_pool, table)
    sc = jnp.einsum("bkgh,bskh->bkgs", q, k).astype(jnp.float32) * scale
    valid = valid_mask(table, bl, pos)
    sc = jnp.where(valid[:, None, None, :], sc, NEG_INF)
    w = jax.nn.softmax(sc, axis=-1).astype(v.dtype)
    return jnp.einsum("bkgs,bskh->bkgh", w, v)


def paged_chunk_attend(q, k_pool, v_pool, table, q_pos):
    """Chunked-prefill paged attention: C queries per slot.

    q: (S, C, KV, G, hd) post-rope; q_pos: (S, C) absolute positions.
    One mask covers cross-chunk history (earlier admit rounds' blocks)
    and in-chunk causality. Returns (S, C, KV, G, hd).
    """
    bl = k_pool.shape[1]
    scale = 1.0 / np.sqrt(q.shape[-1])
    k = gather_kv(k_pool, table)
    v = gather_kv(v_pool, table)
    sc = jnp.einsum("bqkgh,bskh->bkgqs", q, k).astype(jnp.float32) * scale
    alloc = jnp.repeat(table >= 0, bl, axis=1)  # (S, L)
    j = jnp.arange(alloc.shape[1])
    valid = alloc[:, None, :] & (j[None, None, :] <= q_pos[:, :, None])
    sc = jnp.where(valid[:, None, None, :, :], sc, NEG_INF)
    w = jax.nn.softmax(sc, axis=-1).astype(v.dtype)
    out = jnp.einsum("bkgqs,bskh->bkgqh", w, v)
    return out.transpose(0, 3, 1, 2, 4)  # (S, C, KV, G, hd)


@functools.partial(jax.jit, static_argnames=("interpret",))
def paged_decode_attend_kernel(q, k_pool, v_pool, table, pos, *,
                               interpret: bool = True):
    """Pallas-kernel route for the decode attend (ops-compatible API)."""
    from repro.kernels.paged_attention.kernel import paged_decode_kernel

    return paged_decode_kernel(q, k_pool, v_pool, table, pos,
                               interpret=interpret)
