"""Pure-numpy oracle for the paged-attention kernel family.

Reference semantics for the block-pooled KV cache (DESIGN.md §13): each
slot ``s`` owns an ordered list of physical blocks ``table[s]`` (−1 =
unallocated); logical token ``j`` of slot ``s`` lives at physical block
``table[s, j // block_len]``, offset ``j % block_len``. A KV entry is
attendable iff its block is allocated and ``j <= q_pos`` (causal).
"""
from __future__ import annotations

import numpy as np

NEG_INF = -1e30


def gather_ref(pool, table):
    """(NBp, BL, KV, hd), (S, MB) -> (S, MB*BL, KV, hd) logical view.

    Unallocated table entries gather the sink block (last physical
    block); callers mask them out via ``valid_ref``.
    """
    pool = np.asarray(pool)
    table = np.asarray(table)
    sink = pool.shape[0] - 1
    phys = np.where(table >= 0, table, sink)
    s, mb = table.shape
    bl = pool.shape[1]
    return pool[phys].reshape(s, mb * bl, *pool.shape[2:])


def valid_ref(table, block_len, q_pos):
    """(S, MB), BL, (S,) -> (S, MB*BL) bool attendable-entry mask."""
    table = np.asarray(table)
    q_pos = np.asarray(q_pos)
    alloc = np.repeat(table >= 0, block_len, axis=1)  # (S, MB*BL)
    j = np.arange(alloc.shape[1])
    return alloc & (j[None, :] <= q_pos[:, None])


def paged_decode_attend_ref(q, k_pool, v_pool, table, pos):
    """Single-query paged attention, f32 softmax.

    q: (S, KV, G, hd) post-rope queries; pools: (NBp, BL, KV, hd);
    table: (S, MB) int; pos: (S,) per-slot write positions (entry ``pos``
    already written). Returns (S, KV, G, hd).
    """
    q = np.asarray(q, np.float32)
    k = gather_ref(k_pool, table).astype(np.float32)
    v = gather_ref(v_pool, table).astype(np.float32)
    bl = np.asarray(k_pool).shape[1]
    scale = 1.0 / np.sqrt(q.shape[-1])
    sc = np.einsum("bkgh,bskh->bkgs", q, k) * scale
    valid = valid_ref(table, bl, pos)
    sc = np.where(valid[:, None, None, :], sc, NEG_INF)
    sc = sc - sc.max(axis=-1, keepdims=True)
    w = np.exp(sc)
    w = w / np.maximum(w.sum(axis=-1, keepdims=True), 1e-30)
    return np.einsum("bkgs,bskh->bkgh", w, v)


def paged_chunk_attend_ref(q, k_pool, v_pool, table, q_pos):
    """Chunked-prefill paged attention: C queries per slot.

    q: (S, C, KV, G, hd); q_pos: (S, C) absolute query positions. Every
    query attends the slot's full gathered history up to itself
    (cross-chunk history and in-chunk causality share one mask).
    Returns (S, C, KV, G, hd).
    """
    q = np.asarray(q, np.float32)
    k = gather_ref(k_pool, table).astype(np.float32)
    v = gather_ref(v_pool, table).astype(np.float32)
    bl = np.asarray(k_pool).shape[1]
    q_pos = np.asarray(q_pos)
    scale = 1.0 / np.sqrt(q.shape[-1])
    sc = np.einsum("bqkgh,bskh->bkgqs", q, k) * scale  # (S, KV, G, C, L)
    alloc = np.repeat(np.asarray(table) >= 0, bl, axis=1)  # (S, L)
    j = np.arange(alloc.shape[1])
    valid = alloc[:, None, :] & (j[None, None, :] <= q_pos[:, :, None])
    sc = np.where(valid[:, None, None, :, :], sc, NEG_INF)
    sc = sc - sc.max(axis=-1, keepdims=True)
    w = np.exp(sc)
    w = w / np.maximum(w.sum(axis=-1, keepdims=True), 1e-30)
    out = np.einsum("bkgqs,bskh->bkgqh", w, v)
    return out.transpose(0, 3, 1, 2, 4)  # (S, C, KV, G, hd)
