from repro.kernels.paged_attention.ops import (
    gather_kv,
    paged_chunk_attend,
    paged_decode_attend,
    paged_decode_attend_kernel,
    scatter_chunk,
    scatter_decode,
    valid_mask,
)
from repro.kernels.paged_attention.ref import (
    paged_chunk_attend_ref,
    paged_decode_attend_ref,
)

__all__ = [
    "gather_kv",
    "paged_chunk_attend",
    "paged_decode_attend",
    "paged_decode_attend_kernel",
    "scatter_chunk",
    "scatter_decode",
    "valid_mask",
    "paged_chunk_attend_ref",
    "paged_decode_attend_ref",
]
