from repro.kernels.coded_matvec.ops import blocked_matvec, blocked_matvec_batch
from repro.kernels.coded_matvec.ref import matvec_ref

__all__ = ["blocked_matvec", "blocked_matvec_batch", "matvec_ref"]
