"""Pure-jnp oracle for the coded-matvec kernel."""
import jax.numpy as jnp


def matvec_ref(a, x):
    """y = A x with f32 accumulation. a: (R, D); x: (D,)."""
    return jnp.dot(
        a.astype(jnp.float32), x.astype(jnp.float32)
    ).astype(a.dtype)


def matvec_batch_ref(a, x):
    """a: (W, L, D); x: (D,) -> (W, L)."""
    return jnp.einsum(
        "wld,d->wl", a.astype(jnp.float32), x.astype(jnp.float32)
    ).astype(a.dtype)
