"""Pallas TPU kernel: per-worker coded subtask ``y = A~_i x``.

TPU adaptation of the paper's worker computation (a plain matvec on the
paper's CPU workers). Tiling targets the v5e memory hierarchy:

* grid = (R/BR, D/BD); each step loads an A tile (BR, BD) HBM->VMEM and a
  matching x slice (BD,), accumulates a (BR,) partial in f32.
* BR = 256 rows (8x128-lane aligned: reductions over BD run on the VPU's
  8x128 vregs; a matvec has no MXU-shaped contraction unless batched).
* BD = 1024 (bf16: 256*1024*2 = 512 KiB per A tile, well under the
  ~16 MiB VMEM budget, leaving room for double buffering).
* Accumulation across the D-grid dimension uses the standard
  revisiting-output pattern: zero the accumulator when j == 0, add every
  step. The output BlockSpec maps all j to the same (BR,) block.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

BR = 256  # rows per tile (VPU 8x128-aligned)
BD = 1024  # d-columns per tile


def _kernel(a_ref, x_ref, o_ref, acc_ref):
    j = pl.program_id(1)

    @pl.when(j == 0)
    def _init():
        acc_ref[...] = jnp.zeros_like(acc_ref)

    a = a_ref[...].astype(jnp.float32)  # (BR, BD)
    x = x_ref[...].astype(jnp.float32)  # (BD,)
    acc_ref[...] += jax.lax.dot_general(
        a, x, (((1,), (0,)), ((), ())), preferred_element_type=jnp.float32
    )

    @pl.when(j == pl.num_programs(1) - 1)
    def _store():
        o_ref[...] = acc_ref[...].astype(o_ref.dtype)


@functools.partial(jax.jit, static_argnames=("br", "bd", "interpret"))
def matvec_kernel(a, x, *, br: int = BR, bd: int = BD, interpret: bool = True):
    """y = A x. Shapes must be multiples of (br, bd) — ops.py pads."""
    r, d = a.shape
    assert r % br == 0 and d % bd == 0, (a.shape, br, bd)
    grid = (r // br, d // bd)
    return pl.pallas_call(
        _kernel,
        grid=grid,
        in_specs=[
            pl.BlockSpec((br, bd), lambda i, j: (i, j)),
            pl.BlockSpec((bd,), lambda i, j: (j,)),
        ],
        out_specs=pl.BlockSpec((br,), lambda i, j: (i,)),
        out_shape=jax.ShapeDtypeStruct((r,), a.dtype),
        scratch_shapes=[pltpu.VMEM((br,), jnp.float32)],
        interpret=interpret,
    )(a, x)
