"""Jit'd wrappers for the coded-matvec kernel (padding + batching)."""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp

from repro.kernels.coded_matvec.kernel import BD, BR, matvec_kernel


def _pad_to(n: int, m: int) -> int:
    return -(-n // m) * m


@functools.partial(jax.jit, static_argnames=("br", "bd", "interpret"))
def blocked_matvec(a, x, *, br: int = BR, bd: int = BD, interpret: bool = True):
    """y = A x for arbitrary (R, D): pads to tile multiples, slices back."""
    r, d = a.shape
    br = min(br, _pad_to(r, 8))
    bd = min(bd, _pad_to(d, 128))
    rp, dp = _pad_to(r, br), _pad_to(d, bd)
    if (rp, dp) != (r, d):
        a = jnp.pad(a, ((0, rp - r), (0, dp - d)))
        x = jnp.pad(x, (0, dp - d))
    y = matvec_kernel(a, x, br=br, bd=bd, interpret=interpret)
    return y[:r]


@functools.partial(jax.jit, static_argnames=("br", "bd", "interpret"))
def blocked_matvec_batch(a, x, *, br: int = BR, bd: int = BD, interpret: bool = True):
    """a: (W, L, D), x: (D,) -> (W, L): vmap over the worker dim.

    (On TPU the W dim becomes an extra grid dimension; in interpret mode
    vmap runs the kernel body per worker.)
    """
    fn = lambda aw: blocked_matvec(aw, x, br=br, bd=bd, interpret=interpret)
    return jax.vmap(fn)(a)
