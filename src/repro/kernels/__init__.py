"""Pallas TPU kernels for the compute hot-spots.

The paper's per-worker subtask is the matvec ``A~_i x`` and the one-time
encode is the matmul ``A~ = G A``; both get explicit-BlockSpec TPU
kernels (``coded_matvec``, ``mds_encode``). The allocation math itself
(the paper's contribution) is pure JAX — no kernel is warranted there.
"""
