"""Pallas TPU kernel: fused linear cross-entropy (Liger-style).

The dominant memory term of every train_4k cell is the (B*S, V) logits
round-trip (EXPERIMENTS.md §Roofline): materializing f32 logits at 1M
tokens x 100k+ vocab costs hundreds of GB of HBM traffic per device.
This kernel never writes logits to HBM: it tiles the unembedding matmul
``logits = H @ E^T`` over (token-tile, vocab-tile) grid cells, keeps
each (BT, BV) logit tile in VMEM, and folds it directly into an online
logsumexp (running max + rescaled sumexp, the flash-attention trick
applied to the softmax denominator) plus the label logit.

Grid: (T/BT, V/BV) with the vocab dimension innermost; per token tile
the accumulators (m, s, ll) are (BT,) VMEM scratch, carried across
vocab tiles via the revisiting-output pattern.

HBM traffic: H read V/BV... no — H tile is re-read per vocab tile
(nv * T * D * 2 bytes) and E read once (V * D * 2): both orders of
magnitude below the T*V*4 logit write it replaces whenever
nv * D << V (e.g. nv=26, D=4096, V=131k).

out: per-token (lse, label_logit) pairs -> loss = mean(lse - ll).
Backward (dH, dE) recomputes the tile softmax — provided as a
custom-vjp in ops.py using the same tiling in pure jnp (the recompute
is again logit-materialization-free per tile).
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

BT = 256  # tokens per tile
BV = 512  # vocab rows per tile (BT*BV f32 tile = 512 KiB VMEM)

NEG = -1e30


def _kernel(h_ref, e_ref, lab_ref, lse_ref, ll_ref, m_ref, s_ref, ll_acc):
    vj = pl.program_id(1)

    @pl.when(vj == 0)
    def _init():
        m_ref[...] = jnp.full_like(m_ref, NEG)
        s_ref[...] = jnp.zeros_like(s_ref)
        ll_acc[...] = jnp.zeros_like(ll_acc)

    h = h_ref[...].astype(jnp.float32)  # (BT, D)
    e = e_ref[...].astype(jnp.float32)  # (BV, D)
    logits = jax.lax.dot_general(
        h, e, (((1,), (1,)), ((), ())), preferred_element_type=jnp.float32
    )  # (BT, BV) — lives only in VMEM
    m_old = m_ref[...]
    m_new = jnp.maximum(m_old, jnp.max(logits, axis=-1))
    corr = jnp.exp(m_old - m_new)
    s_ref[...] = s_ref[...] * corr + jnp.sum(
        jnp.exp(logits - m_new[:, None]), axis=-1
    )
    m_ref[...] = m_new
    # label logit if it falls inside this vocab tile
    bv = logits.shape[1]
    local = lab_ref[...] - vj * bv  # (BT,)
    hit = (local >= 0) & (local < bv)
    idx = jnp.clip(local, 0, bv - 1)
    picked = jnp.take_along_axis(logits, idx[:, None], axis=1)[:, 0]
    ll_acc[...] += jnp.where(hit, picked, 0.0)

    @pl.when(vj == pl.num_programs(1) - 1)
    def _store():
        lse_ref[...] = m_ref[...] + jnp.log(s_ref[...])
        ll_ref[...] = ll_acc[...]


@functools.partial(jax.jit, static_argnames=("bt", "bv", "interpret"))
def fused_ce_kernel(h, table, labels, *, bt: int = BT, bv: int = BV,
                    interpret: bool = True):
    """Per-token (lse, label_logit). Shapes must divide (bt, bv)."""
    t, d = h.shape
    v, _ = table.shape
    assert t % bt == 0 and v % bv == 0, (t, bt, v, bv)
    grid = (t // bt, v // bv)
    return pl.pallas_call(
        _kernel,
        grid=grid,
        in_specs=[
            pl.BlockSpec((bt, d), lambda i, j: (i, 0)),
            pl.BlockSpec((bv, d), lambda i, j: (j, 0)),
            pl.BlockSpec((bt,), lambda i, j: (i,)),
        ],
        out_specs=[
            pl.BlockSpec((bt,), lambda i, j: (i,)),
            pl.BlockSpec((bt,), lambda i, j: (i,)),
        ],
        out_shape=[
            jax.ShapeDtypeStruct((t,), jnp.float32),
            jax.ShapeDtypeStruct((t,), jnp.float32),
        ],
        scratch_shapes=[
            pltpu.VMEM((bt,), jnp.float32),
            pltpu.VMEM((bt,), jnp.float32),
            pltpu.VMEM((bt,), jnp.float32),
        ],
        interpret=interpret,
    )(h, table, labels)
