from repro.kernels.fused_ce.ops import fused_linear_ce
from repro.kernels.fused_ce.ref import linear_ce_ref

__all__ = ["fused_linear_ce", "linear_ce_ref"]
