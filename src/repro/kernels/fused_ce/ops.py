"""Jit'd wrapper + custom VJP for the fused linear-cross-entropy kernel.

Forward: the Pallas kernel (logits never touch HBM).
Backward: d_logits = (softmax - onehot) / T, folded tile-by-tile into
dH = d_logits @ E and dE = d_logits^T @ H with the lse from the forward
— again without materializing the full (T, V) tensor (a lax.scan over
vocab tiles; each tile's logits are recomputed in registers/VMEM).
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp

from repro.kernels.fused_ce.kernel import BT, BV, fused_ce_kernel


def _pad_to(n: int, m: int) -> int:
    return -(-n // m) * m


@functools.partial(jax.custom_vjp, nondiff_argnums=(3, 4, 5))
def fused_linear_ce(h, table, labels, bt: int = BT, bv: int = BV,
                    interpret: bool = True):
    """Mean cross entropy of ``h @ table^T`` vs labels, fused.

    h: (T, D); table: (V, D); labels: (T,) int32 (negatives = masked).
    """
    loss, _ = _forward(h, table, labels, bt, bv, interpret)
    return loss


def _forward(h, table, labels, bt, bv, interpret):
    t, d = h.shape
    v, _ = table.shape
    bt = min(bt, _pad_to(t, 8))
    bv = min(bv, _pad_to(v, 128))
    tp, vp = _pad_to(t, bt), _pad_to(v, bv)
    mask = labels >= 0
    safe_labels = jnp.where(mask, labels, 0).astype(jnp.int32)
    hp = jnp.pad(h, ((0, tp - t), (0, 0))) if tp != t else h
    # pad table with -inf-producing rows? zero rows give logit 0 which
    # perturbs the lse; instead pad and mask via a huge negative bias on
    # padded labels never being hit, and subtract their contribution is
    # messy — pad with a large-negative constant row instead:
    if vp != v:
        pad_rows = jnp.full((vp - v, d), 0.0, table.dtype)
        tablep = jnp.concatenate([table, pad_rows], axis=0)
    else:
        tablep = table
    labp = jnp.pad(safe_labels, (0, tp - t)) if tp != t else safe_labels
    lse, ll = fused_ce_kernel(hp, tablep, labp, bt=bt, bv=bv,
                              interpret=interpret)
    lse, ll = lse[:t], ll[:t]
    if vp != v:
        # remove the padded rows' exp(h . 0) = 1 contributions exactly:
        # lse' = log(exp(lse) - n_pad) computed stably.
        n_pad = float(vp - v)
        lse = lse + jnp.log1p(-n_pad * jnp.exp(-lse))
    nll = (lse - ll) * mask
    denom = jnp.maximum(jnp.sum(mask), 1)
    loss = jnp.sum(nll) / denom
    return loss, (h, table, safe_labels, mask, lse, denom)


def _fwd(h, table, labels, bt, bv, interpret):
    loss, res = _forward(h, table, labels, bt, bv, interpret)
    return loss, res


def _bwd(bt, bv, interpret, res, g):
    h, table, labels, mask, lse, denom = res
    t, d = h.shape
    v, _ = table.shape
    bvp = min(bv, _pad_to(v, 128))
    scale = (g * mask / denom).astype(jnp.float32)  # (T,)
    h32 = h.astype(jnp.float32)
    nv = -(-v // bvp)
    vp = nv * bvp
    tablep = jnp.pad(table, ((0, vp - v), (0, 0))) if vp != v else table

    def tile(carry, j):
        dh = carry
        start = j * bvp
        e_tile = jax.lax.dynamic_slice(
            tablep, (start, 0), (bvp, d)
        ).astype(jnp.float32)  # (BV, D) — padded table: no start clamping
        logits = h32 @ e_tile.T  # (T, BV) one tile at a time
        # mask rows beyond the true vocab
        ids = start + jnp.arange(bvp)
        p = jnp.exp(logits - lse[:, None])
        p = jnp.where((ids < v)[None, :], p, 0.0)
        onehot = (labels[:, None] == ids[None, :]).astype(jnp.float32)
        dl = (p - onehot) * scale[:, None]  # (T, BV)
        de_tile = dl.T @ h32  # (BV, D)
        dh = dh + dl @ e_tile
        return dh, (de_tile, j)

    dh0 = jnp.zeros((t, d), jnp.float32)
    dh, (de_tiles, _) = jax.lax.scan(tile, dh0, jnp.arange(nv))
    de = de_tiles.reshape(nv * bvp, d)[:v]
    return dh.astype(h.dtype), de.astype(table.dtype), None


fused_linear_ce.defvjp(_fwd, _bwd)
