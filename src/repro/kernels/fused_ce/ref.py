"""Pure-jnp oracle for the fused linear-cross-entropy kernel."""
import jax.numpy as jnp


def linear_ce_ref(h, table, labels):
    """Mean CE of logits = h @ table^T without any fusion tricks.

    h: (T, D); table: (V, D); labels: (T,) int32. Returns scalar f32.
    """
    logits = jnp.dot(
        h.astype(jnp.float32), table.astype(jnp.float32).T
    )  # (T, V)
    m = jnp.max(logits, axis=-1)
    lse = m + jnp.log(jnp.sum(jnp.exp(logits - m[:, None]), axis=-1))
    ll = jnp.take_along_axis(logits, labels[:, None], axis=-1)[:, 0]
    return jnp.mean(lse - ll)
