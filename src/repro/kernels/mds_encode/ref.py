"""Pure-jnp oracle for the MDS-encode kernel."""
import jax.numpy as jnp


def encode_ref(g, a):
    """A~ = G A with f32 accumulation. g: (n, k); a: (k, d)."""
    return jnp.dot(
        g.astype(jnp.float32), a.astype(jnp.float32),
        preferred_element_type=jnp.float32,
    ).astype(a.dtype)
