"""Jit'd wrapper for the MDS-encode kernel (padding to tile multiples)."""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp

from repro.kernels.mds_encode.kernel import BD, BK, BN, encode_kernel


def _pad_to(n: int, m: int) -> int:
    return -(-n // m) * m


@functools.partial(jax.jit, static_argnames=("bn", "bd", "bk", "interpret"))
def mds_encode(g, a, *, bn: int = BN, bd: int = BD, bk: int = BK,
               interpret: bool = True):
    """A~ = G A for arbitrary shapes: pad, run kernel, slice."""
    n, k = g.shape
    _, d = a.shape
    bn = min(bn, _pad_to(n, 8))
    bk = min(bk, _pad_to(k, 128))
    bd = min(bd, _pad_to(d, 128))
    np_, kp, dp = _pad_to(n, bn), _pad_to(k, bk), _pad_to(d, bd)
    if (np_, kp) != (n, k):
        g = jnp.pad(g, ((0, np_ - n), (0, kp - k)))
    if (kp, dp) != (k, d):
        a = jnp.pad(a, ((0, kp - k), (0, dp - d)))
    out = encode_kernel(g, a, bn=bn, bd=bd, bk=bk, interpret=interpret)
    return out[:n, :d]
