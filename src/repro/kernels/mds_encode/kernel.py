"""Pallas TPU kernel: one-time MDS encode ``A~ = G A``.

The paper's setup phase — encoding the data matrix with the (n, k)
generator — is a dense matmul. MXU-native tiling:

* grid = (n/BN, d/BD, k/BK); each step multiplies a (BN, BK) G tile by a
  (BK, BD) A tile on the MXU (all dims multiples of 128) and accumulates
  into a (BN, BD) f32 VMEM scratch.
* BN = BD = BK = 256: three tiles of 256x256 bf16 (128 KiB each) plus
  the f32 accumulator (256 KiB) stay far under VMEM with double
  buffering; 256 keeps MXU (128x128 systolic) fully fed with 2x2 passes.
* k-accumulation uses the revisiting-output pattern (zero at kk == 0,
  flush at kk == last).
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

BN = 256
BD = 256
BK = 256


def _kernel(g_ref, a_ref, o_ref, acc_ref):
    kk = pl.program_id(2)

    @pl.when(kk == 0)
    def _init():
        acc_ref[...] = jnp.zeros_like(acc_ref)

    acc_ref[...] += jax.lax.dot(
        g_ref[...].astype(jnp.float32),
        a_ref[...].astype(jnp.float32),
        preferred_element_type=jnp.float32,
    )

    @pl.when(kk == pl.num_programs(2) - 1)
    def _store():
        o_ref[...] = acc_ref[...].astype(o_ref.dtype)


@functools.partial(jax.jit, static_argnames=("bn", "bd", "bk", "interpret"))
def encode_kernel(g, a, *, bn: int = BN, bd: int = BD, bk: int = BK,
                  interpret: bool = True):
    n, k = g.shape
    k2, d = a.shape
    assert k == k2 and n % bn == 0 and d % bd == 0 and k % bk == 0
    grid = (n // bn, d // bd, k // bk)
    return pl.pallas_call(
        _kernel,
        grid=grid,
        in_specs=[
            pl.BlockSpec((bn, bk), lambda i, j, kk: (i, kk)),
            pl.BlockSpec((bk, bd), lambda i, j, kk: (kk, j)),
        ],
        out_specs=pl.BlockSpec((bn, bd), lambda i, j, kk: (i, j)),
        out_shape=jax.ShapeDtypeStruct((n, d), a.dtype),
        scratch_shapes=[pltpu.VMEM((bn, bd), jnp.float32)],
        interpret=interpret,
    )(g, a)
