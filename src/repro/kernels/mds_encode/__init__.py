from repro.kernels.mds_encode.ops import mds_encode
from repro.kernels.mds_encode.ref import encode_ref

__all__ = ["encode_ref", "mds_encode"]
