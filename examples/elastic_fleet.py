"""Fleet elasticity + straggler drift: the closed-form re-planning loop.

    PYTHONPATH=src python examples/elastic_fleet.py

Simulates a long-running coded-computation service where
  * worker speeds DRIFT (mu halves mid-run for one group),
  * two workers FAIL outright,
  * a new fast group JOINS,
and shows the tracker's online (mu, alpha) estimates feeding Theorem 2
re-plans — each re-plan is O(G) closed-form, no iterative optimizer —
with the achieved latency tracking the moving optimum T*.
"""
import jax
import numpy as np

from repro.core.allocation import optimal_allocation
from repro.core.runtime_model import ClusterSpec, GroupSpec, sample_worker_times
from repro.core.simulator import expected_latency
from repro.runtime.fault_tolerance import ElasticController, StragglerTracker

rng = jax.random.PRNGKey(0)
k = 50_000

cluster = ClusterSpec.make([30, 50], [6.0, 1.5])
ctl = ElasticController(cluster, k)
tracker = StragglerTracker(cluster, forget=0.8, fail_after=3)
print(f"t=0  plan loads={np.unique(ctl.plan.loads_per_worker).tolist()} "
      f"n={ctl.plan.n} T*={ctl.plan.t_star:.5f}")


def one_round(true_cluster, plan, key):
    loads = np.asarray(plan.loads_per_worker, float)
    mus = np.concatenate([
        np.full(g.num_workers, g.mu) for g in true_cluster.groups
    ])
    alphas = np.concatenate([
        np.full(g.num_workers, g.alpha) for g in true_cluster.groups
    ])
    t = np.asarray(sample_worker_times(key, loads, mus, alphas, k, 1)[0])
    return t


# phase 1: steady state, estimates converge to the truth
for i in range(30):
    t = one_round(cluster, ctl.plan, jax.random.fold_in(rng, i))
    tracker.observe_round(t, np.asarray(ctl.plan.loads_per_worker), k)
est = tracker.estimated_cluster()
print(f"t=30 estimated mu: {[round(g.mu, 2) for g in est.groups]} "
      f"(truth: [6.0, 1.5])")

# phase 2: group 2 degrades (mu 1.5 -> 0.6) -> tracker notices -> replan
degraded = ClusterSpec.make([30, 50], [6.0, 0.6])
for i in range(60):
    t = one_round(degraded, ctl.plan, jax.random.fold_in(rng, 100 + i))
    tracker.observe_round(t, np.asarray(ctl.plan.loads_per_worker), k)
plan2 = ctl.on_estimates_update(tracker)
print(f"t=90 after drift: estimated mu = "
      f"{[round(g.mu, 2) for g in tracker.estimated_cluster().groups]}, "
      f"replanned T* = {plan2.t_star:.5f} (replans={ctl.replans})")

# phase 3: a fast group of 20 joins; instant O(G) replan
grown = ClusterSpec(tracker.estimated_cluster().groups + (GroupSpec(20, 10.0),))
plan3 = ctl.on_membership_change(grown)
print(f"t=91 +20 fast workers: T* {plan2.t_star:.5f} -> {plan3.t_star:.5f} "
      f"({plan2.t_star / plan3.t_star:.2f}x faster, replans={ctl.replans})")

# sanity: achieved latency under the final plan ~ its lower bound
ach = expected_latency(rng, grown, optimal_allocation(grown, k), num_trials=4000)
print(f"achieved latency: {ach:.5f} vs bound {plan3.t_star:.5f} "
      f"({ach / plan3.t_star:.3f}x)")
