"""Quickstart: the paper's optimal load allocation in five minutes.

    PYTHONPATH=src python examples/quickstart.py

1. Define a heterogeneous cluster (groups of workers with different
   straggling parameters mu and shifts alpha).
2. Compute the paper's optimal allocation (Theorem 2) and the optimal
   (n*, k) MDS code.
3. Monte-Carlo the actual latency and compare with the lower bound T*
   and with the uniform baseline.
4. Run one real coded matvec end-to-end (encode -> distribute ->
   compute with the Pallas kernel -> straggler erasure -> decode).
"""
import jax
import jax.numpy as jnp
import numpy as np

from repro.core.allocation import optimal_allocation, uniform_given_n
from repro.core.coded_matvec import end_to_end_coded_matvec
from repro.core.planner import plan_deployment
from repro.core.runtime_model import ClusterSpec
from repro.core.simulator import expected_latency

# ---------------------------------------------------------------- step 1
# Three groups: 40 fast, 60 medium, 100 slow workers.
cluster = ClusterSpec.make(
    num_workers=[40, 60, 100], mus=[8.0, 2.0, 0.5], alphas=1.0
)
k = 20_000  # rows of the data matrix A

# ---------------------------------------------------------------- step 2
plan = optimal_allocation(cluster, k)
print("optimal per-group loads l*_j:", np.round(plan.loads, 1).tolist())
print(f"optimal (n*, k) MDS code: n* = {plan.n:.0f}, rate = {plan.rate:.3f}")
print(f"lower-bound expected latency T* = {plan.t_star:.5f}")

# ---------------------------------------------------------------- step 3
key = jax.random.PRNGKey(0)
mc = expected_latency(key, cluster, plan, num_trials=8_000)
uni = expected_latency(
    key, cluster, uniform_given_n(cluster, k, plan.n), num_trials=8_000
)
print(f"Monte-Carlo latency (proposed): {mc:.5f}  ({mc / plan.t_star:.3f} x T*)")
print(f"Monte-Carlo latency (uniform, same code): {uni:.5f} "
      f"({100 * (1 - mc / uni):.1f}% slower than proposed)")

# ---------------------------------------------------------------- step 4
small = ClusterSpec.make([4, 4], [4.0, 1.0])
dep = plan_deployment(small, k=96)
a = jax.random.normal(key, (96, 128))
x = jax.random.normal(jax.random.fold_in(key, 1), (128,))
mesh = jax.make_mesh((len(jax.devices()),), ("workers",))
finished = np.ones(dep.num_workers, dtype=bool)
finished[-2:] = False  # two slow-group stragglers miss the deadline
y, ok = end_to_end_coded_matvec(mesh, a, x, dep, finished, use_kernel=True)
err = float(jnp.max(jnp.abs(jnp.asarray(y) - a @ x)))
print(f"coded matvec with 2 erasures: recovered={ok}, max|err|={err:.2e}")
