"""Coded LM-head serving under injected stragglers.

    PYTHONPATH=src python examples/coded_serving.py

Serves batched greedy decoding from a small dense LM where the final
unembedding matvec — exactly the paper's workload shape — runs through
an (n, k) MDS code over a heterogeneous simulated fleet. Workers that
miss the deadline (T* x safety factor, from the paper's Theorem 2) are
erasures; logits are recovered from any k surviving coded blocks. The
demo verifies coded output == uncoded output even with stragglers.

The whole generation — prefill, straggler-mask sampling, erasure decode,
fallback — is ONE compiled program (jax.lax.scan; see DESIGN.md §4);
pass ServeConfig(jit_pipeline=False) to see the legacy per-token host
loop it replaced.
"""
import time
import jax
import jax.numpy as jnp
import numpy as np

from repro.configs import get_arch
from repro.core.runtime_model import ClusterSpec
from repro.models.model import Model
from repro.runtime.serve_loop import CodedLMHead, ServeConfig, Server

config = get_arch("qwen3-0.6b").reduced()
model = Model(config)
params = model.init_params(jax.random.PRNGKey(0))

# 12 workers in two speed groups; the slow group straggles hard.
fleet = ClusterSpec.make([6, 6], [8.0, 0.7])
server = Server(model, params, fleet, ServeConfig(block_rows=64))
head: CodedLMHead = server.coded_head
print(f"coded LM head: V={config.vocab_size} -> kb={head.kb} blocks, "
      f"(n,k)=({head.nb},{head.kb}) rate={head.kb / head.nb:.3f}")
print(f"per-worker block loads (Theorem 2): "
      f"{head.plan.loads_per_worker.tolist()}")
print(f"deadline = T* x 3 = {head.deadline:.4f}")

# how often does the fleet miss (insufficient survivors)?
misses, trials = 0, 200
for t in range(trials):
    mask = head.sample_finish_mask(jax.random.PRNGKey(t))
    blocks = sum(
        int(head.plan.loads_per_worker[w]) for w in np.flatnonzero(mask)
    )
    misses += blocks < head.kb
print(f"decode-failure rate at this deadline: {misses / trials:.1%}")

max_new = 12
prompts = jax.random.randint(
    jax.random.PRNGKey(7), (4, 8), 0, config.vocab_size
).astype(jnp.int32)
out_coded = server.generate(prompts, max_new=max_new)  # compiles once...
t0 = time.perf_counter()
out_coded = server.generate(prompts, max_new=max_new)
dt = time.perf_counter() - t0
print(f"jit pipeline: {prompts.shape[0] * max_new / dt:.1f} tok/s "
      f"({server.traces} trace(s) across 2 generate calls)")
plain = Server(model, params, None, ServeConfig())
out_plain = plain.generate(prompts, max_new=max_new)
match = bool(jnp.all(out_coded == out_plain))
print(f"coded == uncoded greedy outputs: {match}")
print("sample continuation:", out_coded[0, 8:].tolist())
