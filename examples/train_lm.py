"""End-to-end driver: train a ~100M-param LM for a few hundred steps.

    PYTHONPATH=src python examples/train_lm.py [--steps 300]

Uses the xlstm-125m assigned architecture at full width but trimmed
depth/context so a few hundred steps run on CPU in minutes, with the
whole production substrate engaged: synthetic data pipeline, AdamW +
cosine schedule + clipping, async checkpointing, telemetry, and the
heterogeneity-aware batch split from the paper's Theorem 2.

The synthetic stream has conditional entropy ~= ln(17) ~= 2.83 nats, so
a successful run drives loss from ~ln(50304) ~= 10.8 toward 2.83.
"""
import argparse
import dataclasses

import numpy as np

from repro.configs import get_arch
from repro.configs.base import ShapeConfig
from repro.core.runtime_model import ClusterSpec
from repro.data import SyntheticLMData
from repro.models.model import Model
from repro.optim import AdamWConfig
from repro.runtime.train_loop import (
    TrainConfig,
    Trainer,
    heterogeneous_batch_split,
)


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--steps", type=int, default=300)
    ap.add_argument("--batch", type=int, default=8)
    ap.add_argument("--seq-len", type=int, default=64)  # xLSTM time-scan
    # is sequential — short contexts keep the CPU demo snappy; on TPU
    # use the full train_4k shape via repro.launch.train
    ap.add_argument("--checkpoint-dir", default="/tmp/repro_train_lm")
    args = ap.parse_args()

    # full-width xlstm-125m, trimmed depth for CPU wall-clock
    config = dataclasses.replace(
        get_arch("xlstm-125m"), num_layers=4, compute_dtype="float32"
    )
    model = Model(config)
    print(f"model: {config.name} ({model.param_count() / 1e6:.1f}M params)")

    # the paper's allocation applied to the data-parallel batch split
    fleet = ClusterSpec.make([2, 2], [4.0, 1.0])
    split = heterogeneous_batch_split(fleet, args.batch)
    print(f"heterogeneous fleet {[(g.num_workers, g.mu) for g in fleet.groups]}"
          f" -> per-group batch shares {split.tolist()} (Theorem 2)")

    shape = ShapeConfig("train_lm", args.seq_len, args.batch, "train")
    data = SyntheticLMData(config, shape, seed=0)
    opt = AdamWConfig(lr=1e-3, warmup_steps=20, total_steps=args.steps)
    cfg = TrainConfig(
        steps=args.steps,
        checkpoint_dir=args.checkpoint_dir,
        checkpoint_every=100,
        log_every=20,
    )
    trainer = Trainer(model, data, opt, cfg)
    _, _, history = trainer.run()
    losses = [h["loss"] for h in history]
    print("loss trajectory:", np.round(losses, 3).tolist())
    assert losses[-1] < losses[0] - 1.0, "loss must drop substantially"
    print(f"final loss {losses[-1]:.3f} (entropy floor ~2.83)")


if __name__ == "__main__":
    main()
