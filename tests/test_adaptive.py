"""Cluster-dynamics scenarios + closed-loop adaptive replanning (§7).

Deterministic scenario replay: traces are exact functions of
(spec, base, seed), the controller's decision metric is the noise-free
mean-field ``coverage_latency``, and the observation stream is seeded —
so every assertion below is a replayable regression, not a flaky MC
bound. Covers the ISSUE acceptance set: the controller replans on a mu
step-change, holds under hysteresis on noise-only traces, and preserves
scheme params across every replan for ALL registered schemes.
"""
import dataclasses

import jax
import numpy as np
import pytest
from test_scheme_invariants import instantiate

from repro.core.runtime_model import ClusterSpec
from repro.core.schemes import make_scheme, scheme_names
from repro.runtime.control import (
    AdaptConfig,
    AdaptiveController,
    coverage_latency,
    replan_decision,
)
from repro.runtime.executor import CodedRoundExecutor
from repro.runtime.fault_tolerance import StragglerTracker
from repro.runtime.telemetry import Telemetry
from repro.sim import (
    BadRack,
    MuRandomWalk,
    MuStep,
    ScenarioSpec,
    WorkerChurn,
    make_scenario,
    scenario_names,
)

KEY = jax.random.PRNGKey(11)
BASE = ClusterSpec.make([8, 16, 8], [4.0, 1.0, 0.25], 1.0, [16.0, 8.0, 4.0])
K = 1_000


# ------------------------------------------------------------- scenarios
def test_traces_deterministic_and_seed_sensitive():
    spec = make_scenario("mu_drift", horizon=40)
    t1 = spec.trace(BASE, seed=5)
    t2 = spec.trace(BASE, seed=5)
    t3 = spec.trace(BASE, seed=6)
    assert t1.clusters == t2.clusters
    assert t1.clusters != t3.clusters
    assert t1.horizon == 40
    # clamped indexing never raises
    assert t1.at(-3) == t1.clusters[0]
    assert t1.at(10_000) == t1.clusters[-1]


def test_registry_mirrors_scheme_registry_semantics():
    names = scenario_names()
    for required in ("static", "noise", "mu_drift", "mu_step", "churn",
                     "bw_collapse", "bad_rack"):
        assert required in names
    with pytest.raises(ValueError, match="unknown scenario"):
        make_scenario("no_such_scenario")
    with pytest.raises(ValueError, match="does not accept"):
        make_scenario("static", bogus_param=3)
    # None params mean "not provided" (CLI passes flags unconditionally)
    assert make_scenario("mu_step", horizon=None).horizon == 120


def test_event_primitives_validate():
    with pytest.raises(ValueError, match="sigma"):
        MuRandomWalk(sigma=-0.1)
    with pytest.raises(ValueError, match="factor"):
        MuStep(at=3, group=0, factor=0.0)
    with pytest.raises(ValueError, match="frac"):
        WorkerChurn(at=3, group=0, frac=0.0)
    with pytest.raises(ValueError, match="start < end"):
        BadRack(start=10, end=10, group=0)


def test_windowed_event_restores_state():
    """A bad-rack window perturbs DURING the window and undoes itself."""
    spec = ScenarioSpec(
        name="w", kind="drift",
        events=(BadRack(start=5, end=10, group=0, mu_factor=0.1,
                        bw_factor=0.1),),
        horizon=20,
    )
    tr = spec.trace(BASE, seed=0)
    assert tr.at(0) == BASE
    inside = tr.at(7).groups[0]
    assert inside.mu == pytest.approx(BASE.groups[0].mu * 0.1)
    assert inside.bandwidth == pytest.approx(BASE.groups[0].bandwidth * 0.1)
    after = tr.at(12).groups[0]
    assert after.mu == pytest.approx(BASE.groups[0].mu)
    assert after.bandwidth == pytest.approx(BASE.groups[0].bandwidth)


def test_churn_changes_membership_and_never_empties_groups():
    spec = make_scenario("churn", horizon=40)
    tr = spec.trace(BASE, seed=0)
    before, during = tr.membership(5), tr.membership(15)
    assert during[1] < before[1]
    assert all(m >= 1 for c in tr.clusters for m in
               (g.num_workers for g in c.groups))
    # the join burst restores the ORIGINAL capacity (frac compounds on
    # the shrunken size, so the factory uses f/(1-f) for the rejoin)
    assert tr.membership(35) == tr.membership(5)


# ------------------------------------------------- decision metric / rule
def test_coverage_latency_matches_analytic_t_star():
    """At the optimal loads the mean-field fixed point recovers T*."""
    sch = make_scheme("optimal")
    plan = sch.allocate(BASE, K)
    lat = coverage_latency(BASE, plan.loads, K)
    assert lat == pytest.approx(float(plan.t_star), rel=1e-5)


def test_coverage_latency_infeasible_loads_are_inf():
    # loads too small to ever cover k
    assert np.isinf(coverage_latency(BASE, [1.0, 1.0, 1.0], K))


def test_decision_rule_membership_always_replans():
    sch = make_scheme("optimal")
    exe = CodedRoundExecutor(BASE, K, "optimal")
    groups = list(BASE.groups)
    groups[1] = dataclasses.replace(groups[1], num_workers=10)
    d = replan_decision(sch, exe.plan, ClusterSpec(tuple(groups)),
                        threshold=1e9)  # threshold can never be cleared
    assert d.replanned and d.reason == "membership"


def test_decision_rule_exact_threshold_crossing_replans():
    """gain == threshold replans (inclusive crossing), gain < holds."""
    sch = make_scheme("optimal")
    exe = CodedRoundExecutor(BASE, K, "optimal")
    groups = list(BASE.groups)
    groups[0] = dataclasses.replace(groups[0], mu=groups[0].mu * 0.05)
    drifted = ClusterSpec(tuple(groups))
    probe = replan_decision(sch, exe.plan, drifted, threshold=0.0)
    assert probe.gain > 0
    at = replan_decision(sch, exe.plan, drifted, threshold=probe.gain)
    assert at.replanned and at.reason == "improvement"
    above = replan_decision(sch, exe.plan, drifted,
                            threshold=np.nextafter(probe.gain, 2.0))
    assert not above.replanned and above.reason == "hold"


def test_decision_rule_replan_cost_gates_small_absolute_gains():
    sch = make_scheme("optimal")
    exe = CodedRoundExecutor(BASE, K, "optimal")
    groups = list(BASE.groups)
    groups[0] = dataclasses.replace(groups[0], mu=groups[0].mu * 0.05)
    drifted = ClusterSpec(tuple(groups))
    free = replan_decision(sch, exe.plan, drifted, threshold=0.05,
                           replan_cost=0.0, horizon=10)
    assert free.replanned
    # absolute saving * horizon below the recompile cost: hold
    saving = (free.current - free.candidate) * 10
    costly = replan_decision(sch, exe.plan, drifted, threshold=0.05,
                             replan_cost=saving * 1.01, horizon=10)
    assert not costly.replanned


# ------------------------------------------------- closed-loop replays
def _drive(name, scheme, *, horizon=60, every=5, threshold=0.05, seed=0,
           telemetry=None, k=K):
    """Replay one scenario through the full observe->estimate->act loop."""
    spec = make_scenario(name, horizon=horizon)
    trace = spec.trace(BASE, seed=seed)
    exe = CodedRoundExecutor(BASE, k, scheme)
    ctl = AdaptiveController(
        exe, AdaptConfig(every=every, threshold=threshold),
        telemetry=telemetry,
    )
    for t in range(trace.horizon):
        ctl.observe_truth(jax.random.fold_in(KEY, 1_000 + t), trace.at(t))
    return ctl, trace


def test_controller_replans_on_mu_step_change():
    """ISSUE acceptance: a mu step-change triggers a replan soon after."""
    ctl, _ = _drive("mu_step", "optimal")
    replans = [d for d in ctl.decisions if d.replanned]
    assert replans, "controller never replanned on a 20x mu collapse"
    # the step lands at horizon//3 = 20; the replan must come after it
    # and within a few cadence periods (estimates need a few rounds)
    assert 20 < replans[0].round <= 40
    assert replans[0].reason == "improvement"
    # the new plan shifts load off the collapsed group
    old = ctl.executor.engine.scheme.allocate(BASE, K).loads
    new = ctl.plan.allocation.loads
    assert new[0] < old[0]


def test_controller_holds_under_hysteresis_on_noise_only_trace():
    """ISSUE acceptance: estimation noise alone never triggers a replan."""
    for seed in (0, 1, 2):
        ctl, _ = _drive("noise", "optimal", seed=seed)
        assert ctl.replans == 0, (
            f"seed {seed}: replanned on noise-only trace: {ctl.decisions}"
        )
        assert all(d.reason == "hold" for d in ctl.decisions)


def test_controller_membership_replans_on_churn():
    ctl, trace = _drive("churn", "optimal")
    reasons = [d.reason for d in ctl.decisions if d.replanned]
    assert "membership" in reasons
    # after the final join burst the controller's plan covers the full
    # restored fleet (joins become load-bearing only through a replan)
    assert ctl.plan.num_workers == sum(trace.membership(trace.horizon - 1))


@pytest.mark.parametrize("name", scheme_names())
def test_replan_preserves_scheme_params_for_all_registered_schemes(name):
    """ISSUE acceptance: every registered scheme survives controller
    replans with its typed params intact (zero edits for new schemes)."""
    scheme = instantiate(name, BASE, K)
    exe = CodedRoundExecutor(BASE, K, scheme)
    ctl = AdaptiveController(exe, AdaptConfig(every=1, threshold=0.05))
    # force a membership-change replan via the registration feed
    times = np.asarray(exe.sample_round_times(KEY))
    counts = [g.num_workers for g in BASE.groups]
    counts[1] -= 2
    d = ctl.observe_round(times, membership=counts)
    assert d is not None and d.replanned and d.reason == "membership"
    assert exe.engine.scheme == scheme, name
    assert exe.plan.scheme_obj == scheme, name
    assert exe.num_workers == sum(counts)


def test_controller_decisions_land_in_telemetry(tmp_path):
    path = str(tmp_path / "events.jsonl")
    with Telemetry(path) as tel:
        ctl, _ = _drive("mu_step", "optimal", telemetry=tel, horizon=30)
    recs = [e for e in tel.events if e["event"] == "adapt_decision"]
    assert len(recs) == len(ctl.decisions) == 6  # horizon 30 / cadence 5
    # monotonic t stamps make the decision stream totally ordered
    ts = [r["t"] for r in recs]
    assert ts == sorted(ts) and len(set(ts)) == len(ts)
    for r in recs:
        for field in ("round", "replanned", "reason", "gain", "deadline",
                      "workers"):
            assert field in r, r


def test_tracker_rebind_preserves_estimates_and_resizes():
    tracker = StragglerTracker(BASE, forget=0.5)
    times = np.asarray(
        CodedRoundExecutor(BASE, K, "optimal").sample_round_times(KEY)
    )
    loads = CodedRoundExecutor(BASE, K, "optimal").plan.loads_per_worker
    tracker.observe_round(times, np.asarray(loads), K)
    mu_before = tracker.mu_estimates
    groups = list(BASE.groups)
    groups[1] = dataclasses.replace(groups[1], num_workers=10)
    smaller = ClusterSpec(tuple(groups))
    est = tracker.estimated_cluster()  # embeds the current estimates
    tracker.rebind(smaller.with_bandwidths([g.bandwidth
                                            for g in est.groups]))
    assert tracker.cluster.total_workers == smaller.total_workers
    assert tracker._missed.shape == (smaller.total_workers,)
    # estimates come from the new spec (which the controller builds FROM
    # the estimates), so a spec-value rebind keeps them
    np.testing.assert_allclose(tracker.mu_estimates,
                               [g.mu for g in smaller.groups])
    assert mu_before.shape == tracker.mu_estimates.shape


# --------------------------------------------- trainer closed loop (e2e)
def test_trainer_adaptive_scenario_replans_and_recompiles():
    """End to end: scenario drift -> controller replan -> step recompile,
    scheme params preserved, training stays finite."""
    from repro.configs import ARCHS
    from repro.configs.base import ShapeConfig
    from repro.data import SyntheticLMData
    from repro.models.model import Model
    from repro.optim import AdamWConfig
    from repro.runtime.train_loop import TrainConfig, Trainer

    c = ARCHS["qwen3-0.6b"].reduced()
    m = Model(c)
    data = SyntheticLMData(c, ShapeConfig("t", 16, 4, "train"), seed=1)
    cluster = ClusterSpec.make([4, 4], [4.0, 0.5])
    cfg = TrainConfig(
        steps=10, log_every=1, cluster=cluster, scheme="grad_coding",
        scenario="mu_step", adapt_every=2, adapt_threshold=0.05,
    )
    t = Trainer(m, data, AdamWConfig(lr=1e-3, warmup_steps=0,
                                     total_steps=10), cfg)
    scheme_before = t.executor.engine.scheme
    # the scenario is built AT the trainer's step budget, so the mu step
    # fires at steps//3 = 3 (not at a never-reached default-horizon time)
    assert t.trace.change_rounds() == (3,)
    _, _, history = t.run()
    assert all(np.isfinite(h["loss"]) for h in history)
    assert t.controller is not None and len(t.controller.decisions) == 5
    replans = [d for d in t.controller.decisions if d.replanned]
    assert replans, "mu_step scenario never triggered a trainer replan"
    # the replans respond to the step change, not to pre-step noise
    assert all(d.round > 3 for d in replans)
    # every replan recompiled the coded step (trace per program build)
    assert t.traces == 1 + len(replans)
    assert t.executor.engine.scheme == scheme_before
    # decisions were surfaced through telemetry with monotonic t
    recs = [e for e in t.telemetry.events if e["event"] == "adapt_decision"]
    assert len(recs) == 5


def test_trainer_scenario_requires_cluster():
    from repro.configs import ARCHS
    from repro.configs.base import ShapeConfig
    from repro.data import SyntheticLMData
    from repro.models.model import Model
    from repro.optim import AdamWConfig
    from repro.runtime.train_loop import TrainConfig, Trainer

    c = ARCHS["qwen3-0.6b"].reduced()
    data = SyntheticLMData(c, ShapeConfig("t", 16, 4, "train"), seed=1)
    with pytest.raises(ValueError, match="require coded training"):
        Trainer(Model(c), data,
                AdamWConfig(lr=1e-3, warmup_steps=0, total_steps=5),
                TrainConfig(steps=5, scenario="mu_step"))


# ----------------------------------------------- fig_adapt acceptance
def test_fig_adapt_acceptance_reduced(tmp_path, monkeypatch):
    """The benchmark's own acceptance gates on a short horizon."""
    import benchmarks.common as bench_common
    from benchmarks import fig_adapt

    monkeypatch.setattr(bench_common, "ARTIFACTS", str(tmp_path))
    rec = fig_adapt.run(verbose=False, horizon=36,
                        scenarios=["static", "noise", "mu_step", "churn"])
    assert rec["adaptive_within_1p5x_oracle"], rec
    assert rec["adaptive_beats_static_on_dynamic"], rec
    assert rec["no_replans_on_control"], rec
