"""Golden-value regression tests for the fig2–fig9 + fig_comm drivers.

Each benchmark driver runs through its real ``run()`` entry point —
closed-form figures (fig2/3/6) at the paper's own settings, Monte-Carlo
figures (fig4/5/7/8/9, fig_comm) on tiny seeded clusters via the run()
keyword params — and the tests assert the scheme latency ORDERING the
paper claims (optimal <= uniform_n* <= uncoded, bounds respected) plus a
few frozen closed-form values. Fast by construction (seconds, no
compile-heavy cells), so they run in the CI fast lane — deliberately NOT
marked ``slow``.

Artifacts are redirected to a tmp dir so running the tests never
clobbers ``artifacts/bench/``.
"""
import numpy as np
import pytest

import benchmarks.common as bench_common

# tolerance for MC-vs-MC ordering assertions on tiny clusters
MC_SLACK = 1.05


@pytest.fixture(autouse=True)
def _redirect_artifacts(tmp_path, monkeypatch):
    monkeypatch.setattr(bench_common, "ARTIFACTS", str(tmp_path))


def test_fig2_theta_one_over_n_golden():
    from benchmarks import fig2

    rec = fig2.run(verbose=False)
    # T* = Theta(1/N): N*T* identical across x1/x2/x4 cluster scalings
    assert rec["theta_1_over_N"]
    np.testing.assert_allclose(
        rec["N_invariance"], rec["N_invariance"][0], rtol=1e-9
    )
    # frozen closed-form value at q=1 (paper setting, Lambert-W math)
    q1 = next(r for r in rec["rows"] if abs(r["q"] - 1.0) < 1e-9)
    assert q1["N*T*"] == pytest.approx(3.4968381270239273, rel=1e-9)
    # monotone decreasing in q (faster workers -> lower latency)
    vals = [r["N*T*"] for r in rec["rows"]]
    assert all(a > b for a, b in zip(vals, vals[1:]))


def test_fig3_rate_nonmonotone_golden():
    from benchmarks import fig3

    rec = fig3.run(verbose=False)
    # the paper's counter-intuitive claim: rate NOT monotone in mu2
    assert rec["nonmonotone_exists"]
    n2_100 = next(r for r in rec["rows"] if r["N2"] == 100)
    assert not n2_100["monotone"]
    assert n2_100["rate_min"] == pytest.approx(0.5809321649804432, rel=1e-9)
    assert n2_100["rate_max"] == pytest.approx(0.8732432178369728, rel=1e-9)


def test_fig6_rate_limits_golden():
    from benchmarks import fig6

    rec = fig6.run(verbose=False)
    # rate ~1/2 on the mid-q plateau, ~0.99 at q = 10^1.5 (paper claims)
    assert all(0.4 <= r <= 0.65 for r in rec["rate_near_half_mid_q"])
    assert rec["rate_at_large_q"] == pytest.approx(0.9894349048369616,
                                                   rel=1e-9)


def test_fig4_ordering_tiny():
    from benchmarks import fig4

    rec = fig4.run(verbose=False, ns=[50, 100], trials=800, k=2_000,
                   r_fixed=10)
    for row in rec["rows"]:
        # the paper's Fig-4 ordering at every N
        assert row["proposed"] <= row["uniform_n*"] * MC_SLACK, row
        assert row["uniform_n*"] <= row["uncoded"] * MC_SLACK, row
        assert row["proposed"] >= row["lower_bound_T*"] * 0.95, row
        assert row["group_code_r100"] >= row["group_code_floor"], row
    # latency shrinks as the fleet grows
    assert rec["rows"][1]["proposed"] < rec["rows"][0]["proposed"]


def test_fig5_ordering_tiny():
    from benchmarks import fig5

    rec = fig5.run(verbose=False, n_total=100, qs=[0.1, 1.0], trials=800,
                   k=2_000, r_fixed=10)
    for row in rec["rows"]:
        assert row["proposed"] <= row["uniform_n*"] * MC_SLACK, row
        assert row["uniform_n*"] <= row["uncoded"] * MC_SLACK, row
        assert row["proposed"] >= row["T*"] * 0.95, row
    # latency decreases in q (mu scale): faster workers, lower latency
    assert rec["rows"][1]["proposed"] < rec["rows"][0]["proposed"]


def test_fig7_proposed_beats_uniform_rates_tiny():
    from benchmarks import fig7

    rec = fig7.run(verbose=False, n_total=100, qs=[1.0], trials=800,
                   k=2_000)
    row = rec["rows"][0]
    rate_cols = [v for key, v in row.items() if key.startswith("rate_")]
    assert row["proposed"] <= min(rate_cols) * MC_SLACK
    assert row["proposed"] <= row["uniform_n*"] * MC_SLACK


def test_fig8_proposed_beats_best_uniform_tiny():
    from benchmarks import fig8
    from repro.core import ClusterSpec

    rec = fig8.run(
        verbose=False,
        cluster=ClusterSpec.make([30, 60], [4.0, 0.5], 1.0),
        rates=[0.45, 0.6, 0.75, 0.9],
        trials=800,
        k=2_000,
    )
    assert rec["proposed"] <= rec["best_uniform_latency"] * MC_SLACK
    assert 0 <= rec["reduction_vs_best_uniform"] < 1


def test_fig9_matches_reisizadeh_tiny():
    from benchmarks import fig9

    rec = fig9.run(verbose=False, ns=[100, 200], trials=800, k=2_000)
    for row in rec["rows"]:
        # Corollary 2 achieves the bound and coincides with [32]
        assert row["ours_cor2"] >= row["T*_b"] * 0.95, row
        assert row["ours_cor2"] == pytest.approx(row["reisizadeh"], rel=0.1)
    assert rec["rows"][1]["ours_cor2"] < rec["rows"][0]["ours_cor2"]


def test_fig_grad_ordering_tiny():
    from benchmarks import fig_grad
    from repro.core import ClusterSpec

    rec = fig_grad.run(
        verbose=False,
        cluster=ClusterSpec.make([10, 20, 10], [4.0, 1.0, 0.25], 1.0),
        conv_cluster=ClusterSpec.make([2, 2], [4.0, 0.5], 1.0),
        trials=600,
        k=1_000,
        conv_steps=6,
        conv_batch=4,
        conv_seq=16,
    )
    # the subsystem's acceptance ordering: coded beats drop-straggler
    # beats uniform DP on expected step latency, and tracks its bound
    assert rec["coded_beats_drop"]
    assert rec["coded_beats_uniform"]
    assert rec["drop_straggler"] <= rec["uniform_dp"] * MC_SLACK
    assert rec["grad_coding"] >= rec["bound_T*"] * 0.95
    assert rec["speedup_vs_drop"] > 1.0
    # gradient quality at an equal latency budget: coded decodes the
    # exact full-batch gradient; drop's error can only be >= that
    err = rec["convergence"]["grad_error"]
    assert err["uniform_dp"] == 0.0
    assert err["grad_coding"] < 1e-3
    assert err["drop_straggler"] >= err["grad_coding"] - 1e-9


def test_fig_comm_ordering_tiny():
    from benchmarks import fig_comm

    rec = fig_comm.run(verbose=False, bs=[0.3, 30.0], trials=800)
    assert rec["aware_never_loses_to_blind"]
    assert rec["infinite_bandwidth_matches_optimal"]
    assert rec["slow_links_excluded_at_low_b"]
    low, high = rec["rows"]
    # comm-awareness matters most when links are slow
    assert low["gain_vs_blind"] > high["gain_vs_blind"] > 1.0
    for row in rec["rows"]:
        assert row["comm_aware"] >= row["bound"] * 0.95, row
        assert row["comm_aware"] <= row["comm_uniform"] * MC_SLACK, row
