"""§Perf hillclimb knobs preserve correctness (EXPERIMENTS.md §Perf)."""
import dataclasses

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import ARCHS
from repro.models.model import Model

KEY = jax.random.PRNGKey(3)


def _toks(c, b=2, s=64):
    return jax.random.randint(KEY, (b, s), 0, c.vocab_size).astype(jnp.int32)


def test_causal_block_skip_matches_baseline_fwd_and_bwd():
    c0 = ARCHS["granite-3-2b"].reduced()
    c1 = dataclasses.replace(c0, causal_block_skip=True)
    params = Model(c0).init_params(KEY)
    toks = _toks(c0, s=96)
    batch = {"tokens": toks, "labels": toks}
    l0, _ = Model(c0).loss_fn(params, batch)
    l1, _ = Model(c1).loss_fn(params, batch)
    assert float(l0) == pytest.approx(float(l1), rel=1e-5)
    g0 = jax.grad(lambda p: Model(c0).loss_fn(p, batch)[0])(params)
    g1 = jax.grad(lambda p: Model(c1).loss_fn(p, batch)[0])(params)
    for a, b_ in zip(jax.tree.leaves(g0), jax.tree.leaves(g1)):
        np.testing.assert_allclose(
            np.asarray(a, np.float32), np.asarray(b_, np.float32),
            rtol=1e-3, atol=1e-4,
        )


def test_causal_block_skip_with_sliding_window():
    c0 = ARCHS["h2o-danube-3-4b"].reduced()  # window 64
    c1 = dataclasses.replace(c0, causal_block_skip=True)
    params = Model(c0).init_params(KEY)
    toks = _toks(c0, s=96)
    l0 = Model(c0).lm_logits(params, toks)
    l1 = Model(c1).lm_logits(params, toks)
    np.testing.assert_allclose(
        np.asarray(l0, np.float32), np.asarray(l1, np.float32),
        rtol=1e-4, atol=1e-4,
    )


def test_bf16_logits_close_and_loss_finite():
    c0 = ARCHS["qwen3-0.6b"].reduced()
    c1 = dataclasses.replace(c0, logits_dtype="bfloat16")
    params = Model(c0).init_params(KEY)
    toks = _toks(c0)
    batch = {"tokens": toks, "labels": toks}
    l0, _ = Model(c0).loss_fn(params, batch)
    l1, _ = Model(c1).loss_fn(params, batch)
    assert float(l1) == pytest.approx(float(l0), rel=2e-2)


def test_int8_kv_cache_decode_close():
    c0 = ARCHS["granite-3-2b"].reduced()
    c1 = dataclasses.replace(c0, kv_quant=True)
    m0, m1 = Model(c0), Model(c1)
    params = m0.init_params(KEY)
    toks = _toks(c0, s=24)

    def run(m):
        cache = m.init_cache(2, 32)
        outs = []
        step = jax.jit(m.decode_step)
        for pos in range(24):
            lg, cache = step(params, cache, toks[:, pos], jnp.int32(pos))
            outs.append(lg)
        return jnp.stack(outs, 1)

    base, quant = run(m0), run(m1)
    agree = float(jnp.mean(jnp.argmax(base, -1) == jnp.argmax(quant, -1)))
    assert agree > 0.9, agree
    assert float(jnp.max(jnp.abs(base - quant))) < 0.1


def test_int8_cache_is_actually_int8():
    c = dataclasses.replace(ARCHS["granite-3-2b"].reduced(), kv_quant=True)
    cache = Model(c).init_cache(2, 16)
    assert cache["kv"]["k"].dtype == jnp.int8
    assert cache["kv"]["k_scale"].dtype == jnp.float16
