"""Integration: the dry-run lowers+compiles real cells in a subprocess.

Runs the cheapest cell (whisper-tiny prefill) end-to-end on the actual
512-placeholder-device production mesh. Subprocess because the XLA
device-count flag must be set before jax initializes.
"""
import json
import os
import subprocess
import sys

import pytest

REPO = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))


@pytest.mark.slow
def test_dryrun_smallest_cell_single_pod(tmp_path):
    env = dict(os.environ, PYTHONPATH=os.path.join(REPO, "src"))
    env.pop("XLA_FLAGS", None)
    out = subprocess.run(
        [sys.executable, "-m", "repro.launch.dryrun",
         "--arch", "whisper-tiny", "--shape", "prefill_32k",
         "--mesh", "single", "--out", str(tmp_path)],
        env=env, capture_output=True, text=True, timeout=900, cwd=REPO,
    )
    assert out.returncode == 0, out.stdout + out.stderr
    path = tmp_path / "whisper-tiny_prefill_32k_single.json"
    rec = json.loads(path.read_text())
    assert rec["chips"] == 256
    assert rec["hlo_flops_per_device"] > 0
    assert rec["t_compute"] > 0 and rec["t_memory"] > 0
    assert rec["bottleneck"] in ("t_compute", "t_memory", "t_collective")
