"""Pallas kernels vs pure-jnp oracles: shape/dtype sweeps + hypothesis."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from _hypothesis_compat import given, settings, st
from repro.kernels.coded_matvec.ops import blocked_matvec, blocked_matvec_batch
from repro.kernels.coded_matvec.ref import matvec_batch_ref, matvec_ref
from repro.kernels.mds_encode.ops import mds_encode
from repro.kernels.mds_encode.ref import encode_ref

KEY = jax.random.PRNGKey(7)


def _tol(dt):
    return (2e-2, 2e-1) if dt == jnp.bfloat16 else (1e-5, 1e-4)


@pytest.mark.parametrize("r,d", [(8, 128), (256, 1024), (100, 333),
                                 (513, 2050), (1, 1), (7, 4096)])
@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
def test_matvec_kernel_matches_ref(r, d, dtype):
    a = jax.random.normal(KEY, (r, d), dtype)
    x = jax.random.normal(jax.random.fold_in(KEY, 1), (d,), dtype)
    rtol, atol = _tol(dtype)
    np.testing.assert_allclose(
        np.asarray(blocked_matvec(a, x), np.float32),
        np.asarray(matvec_ref(a, x), np.float32),
        rtol=rtol, atol=atol,
    )


@pytest.mark.parametrize("w,l,d", [(3, 16, 64), (5, 100, 257)])
def test_matvec_batch_matches_ref(w, l, d):
    a = jax.random.normal(KEY, (w, l, d))
    x = jax.random.normal(KEY, (d,))
    np.testing.assert_allclose(
        np.asarray(blocked_matvec_batch(a, x)),
        np.asarray(matvec_batch_ref(a, x)),
        rtol=1e-5, atol=1e-4,
    )


@pytest.mark.parametrize("n,k,d", [(256, 128, 256), (300, 200, 77),
                                   (17, 9, 5), (512, 512, 512)])
@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
def test_encode_kernel_matches_ref(n, k, d, dtype):
    g = jax.random.normal(KEY, (n, k), dtype)
    a = jax.random.normal(jax.random.fold_in(KEY, 2), (k, d), dtype)
    rtol, atol = _tol(dtype)
    np.testing.assert_allclose(
        np.asarray(mds_encode(g, a), np.float32),
        np.asarray(encode_ref(g, a), np.float32),
        rtol=rtol, atol=atol * 10,
    )


@settings(max_examples=20, deadline=None)
@given(
    r=st.integers(1, 300), d=st.integers(1, 600),
    seed=st.integers(0, 2**31 - 1),
)
def test_matvec_property_random_shapes(r, d, seed):
    k = jax.random.PRNGKey(seed)
    a = jax.random.normal(k, (r, d))
    x = jax.random.normal(jax.random.fold_in(k, 1), (d,))
    np.testing.assert_allclose(
        np.asarray(blocked_matvec(a, x)), np.asarray(matvec_ref(a, x)),
        rtol=1e-5, atol=1e-4,
    )


@settings(max_examples=15, deadline=None)
@given(
    n=st.integers(1, 150), k=st.integers(1, 120), d=st.integers(1, 150),
    seed=st.integers(0, 2**31 - 1),
)
def test_encode_property_random_shapes(n, k, d, seed):
    key = jax.random.PRNGKey(seed)
    g = jax.random.normal(key, (n, k))
    a = jax.random.normal(jax.random.fold_in(key, 1), (k, d))
    np.testing.assert_allclose(
        np.asarray(mds_encode(g, a)), np.asarray(encode_ref(g, a)),
        rtol=1e-5, atol=1e-4,
    )


def test_kernel_linearity_invariant():
    """Coded matvec must be linear: kernel(G A, x) == G kernel-rows(A, x)."""
    g = jax.random.normal(KEY, (24, 16))
    a = jax.random.normal(jax.random.fold_in(KEY, 3), (16, 80))
    x = jax.random.normal(jax.random.fold_in(KEY, 4), (80,))
    coded = mds_encode(g, a)
    lhs = blocked_matvec(coded, x)
    rhs = g @ blocked_matvec(a, x)
    np.testing.assert_allclose(np.asarray(lhs), np.asarray(rhs), rtol=1e-4, atol=1e-4)
