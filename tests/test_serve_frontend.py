"""Continuous-batching serve front-end: scheduler policy units, the
batched-prefill model path, and the no-retrace guarantee of slot swaps
in ``Server.serve``."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import ARCHS
from repro.core import ClusterSpec
from repro.models.model import Model
from repro.runtime.serve_loop import ServeConfig, Server
from repro.serve import (
    CLASS_PRIORITY,
    Request,
    SlotScheduler,
    make_workload,
    workload_names,
)

KEY = jax.random.PRNGKey(0)


def _req(rid, arrival=0.0, out_len=4, cls="standard", plen=3):
    return Request(rid=rid, arrival=arrival, prompt=tuple(range(1, plen + 1)),
                   out_len=out_len, deadline_class=cls)


class _Sink:
    """Telemetry stand-in capturing (name, fields) event records."""

    def __init__(self):
        self.events = []

    def event(self, name, **fields):
        self.events.append((name, fields))


# ------------------------------------------------------- scheduler units
def test_full_queue_sheds_queue_full():
    sched = SlotScheduler(1, queue_cap=2)
    sched.fill_slots(0.0)
    assert [sched.offer(_req(i), 0.0) for i in range(3)] == [True, True, False]
    assert sched.shed == 1
    shed = [f for f in sched.finished if f.outcome == "shed"]
    assert [f.reason for f in shed] == ["queue_full"]
    assert shed[0].latency == float("inf")


def test_all_slots_busy_places_nothing():
    sched = SlotScheduler(2)
    for i in range(3):
        sched.offer(_req(i), 0.0)
    assert [si for si, _ in sched.fill_slots(0.0)] == [0, 1]
    assert sched.fill_slots(1.0) == []  # both busy, third must wait
    assert sched.busy_slots == 2 and len(sched.queue) == 1
    sched.advance(4)  # out_len reached on both
    sched.retire_done(4.0)
    assert [r.rid for _, r in sched.fill_slots(4.0)] == [2]


def test_deadline_class_priority_fifo_within_class():
    sched = SlotScheduler(4, queue_cap=8)
    order = [("batch", 0), ("standard", 1), ("strict", 2), ("standard", 3)]
    for cls, rid in order:
        sched.offer(_req(rid, cls=cls), 0.0)
    placed = sched.fill_slots(0.0)
    # strict first, then the standards in arrival order, batch last
    assert [r.rid for _, r in placed] == [2, 1, 3, 0]
    prios = [CLASS_PRIORITY[r.deadline_class] for _, r in placed]
    assert prios == sorted(prios)


def test_deadline_risk_sheds_strict_but_never_batch():
    sched = SlotScheduler(1, queue_cap=64)
    for i in range(20):  # deep backlog of long requests
        assert sched.offer(_req(i, out_len=30, cls="batch"), 0.0)
    assert not sched.offer(_req(99, out_len=4, cls="strict"), 0.0)
    assert [f.reason for f in sched.finished if f.outcome == "shed"] == [
        "deadline_risk"
    ]
    # identical pressure: batch class is only ever shed by a full queue
    assert sched.offer(_req(100, out_len=4, cls="batch"), 0.0)


def test_slow_fleet_latency_factor_triggers_shedding():
    slow = SlotScheduler(4, round_latency=lambda: 50.0,
                         reference_latency=1.0)
    assert not slow.offer(_req(0, out_len=8, cls="standard"), 0.0)
    # the same offer sails through at reference speed
    ok = SlotScheduler(4, round_latency=lambda: 1.0, reference_latency=1.0)
    assert ok.offer(_req(0, out_len=8, cls="standard"), 0.0)
    # a fleet that cannot cover k (inf latency) sheds every non-batch
    dead = SlotScheduler(4, round_latency=lambda: float("inf"),
                         reference_latency=1.0)
    assert not dead.offer(_req(1, out_len=8, cls="strict"), 0.0)
    assert dead.offer(_req(2, out_len=8, cls="batch"), 0.0)


def test_scheduler_replay_is_deterministic():
    def drive(seed):
        trace = make_workload("poisson", num_requests=12).trace(seed)
        sched = SlotScheduler(2, queue_cap=3)
        now, i = 0.0, 0
        log = []
        while i < len(trace) or not sched.idle:
            while i < len(trace) and trace[i].arrival <= now:
                sched.offer(trace[i], now)
                i += 1
            for si, r in sched.fill_slots(now):
                log.append(("admit", si, r.rid, now))
            sched.advance(1)
            now += 1.0
            for si, f in sched.retire_done(now):
                log.append(("done", si, f.request.rid, now))
        return log, sched.shed

    assert drive(7) == drive(7)
    assert drive(7) != drive(8)


def test_telemetry_events_schema():
    sink = _Sink()
    sched = SlotScheduler(1, queue_cap=1, telemetry=sink)
    sched.offer(_req(0, out_len=2), 0.0)
    sched.offer(_req(1), 0.0)  # queue full -> evicted
    sched.fill_slots(1.0)
    sched.advance(2)
    sched.retire_done(3.0)
    names = [n for n, _ in sink.events]
    assert names == ["request_evicted", "request_admitted", "request_done"]
    by = dict(sink.events)
    assert by["request_evicted"]["reason"] == "queue_full"
    assert by["request_evicted"]["request_id"] == 1
    assert by["request_admitted"]["queue_wait"] == 1.0
    assert by["request_done"]["tokens"] == 2
    assert by["request_done"]["latency"] == 3.0
    # field coverage is the schema registry's job (repro.obs.schema):
    # every emitted record must satisfy its declared contract
    from repro.obs.schema import validate_event

    for name, fields in sink.events:
        validate_event({"event": name, **fields})


# -------------------------------------------------------------- workload
def test_workload_traces_are_seeded_and_validated():
    wl = make_workload("chat", num_requests=10)
    t1, t2 = wl.trace(seed=3), wl.trace(seed=3)
    assert t1 == t2
    assert t1 != wl.trace(seed=4)
    assert all(a.arrival <= b.arrival for a, b in zip(t1, t1[1:]))
    assert {"poisson", "trickle", "overload", "chat"} <= set(workload_names())
    with pytest.raises(ValueError, match="unknown workload"):
        make_workload("nope")
    with pytest.raises(ValueError, match="does not accept"):
        make_workload("poisson", slots=4)
    with pytest.raises(ValueError, match="out_len"):
        _req(0, out_len=0)


# --------------------------------------------- batched prefill model path
def test_prefill_matches_full_forward_last_position():
    """One batched prefill pass == the full forward at each row's end."""
    c = ARCHS["qwen3-0.6b"].reduced()
    m = Model(c)
    params = m.init_params(KEY)
    s0 = 10
    tokens = jax.random.randint(jax.random.PRNGKey(1), (3, s0), 0,
                                c.vocab_size).astype(jnp.int32)
    lengths = jnp.asarray([s0, 6, 3], jnp.int32)
    plog, ks, vs = m.prefill(params, tokens, lengths)
    assert ks.shape == (c.num_layers, 3, s0, c.num_kv_heads,
                        c.resolved_head_dim)
    for b, ln in enumerate([s0, 6, 3]):
        full = m.lm_logits(params, tokens[b: b + 1, :ln])
        np.testing.assert_allclose(
            np.asarray(plog[b]), np.asarray(full[0, -1]), rtol=2e-4,
            atol=2e-4,
        )


def test_slot_decode_continues_prefilled_stream():
    """Splice + per-slot decode == teacher-forced full-forward logits."""
    c = ARCHS["qwen3-0.6b"].reduced()
    m = Model(c)
    params = m.init_params(KEY)
    s0, steps, slots = 6, 3, 2
    cache_len = s0 + steps + 1
    tokens = jax.random.randint(jax.random.PRNGKey(2), (slots, s0), 0,
                                c.vocab_size).astype(jnp.int32)
    lengths = jnp.full((slots,), s0, jnp.int32)
    plog, ks, vs = m.prefill(params, tokens, lengths)
    cache = m.init_slot_cache(slots, cache_len)
    kv = cache["kv"]
    seq = jnp.arange(s0, dtype=jnp.int32)
    cache = {"kv": {
        "k": kv["k"].at[:, :, :s0].set(ks),
        "v": kv["v"].at[:, :, :s0].set(vs),
        "pos": kv["pos"].at[:, :s0].set(jnp.broadcast_to(seq, (slots, s0))),
    }}
    pos = jnp.full((slots,), s0, jnp.int32)
    logits, ctx = plog, tokens
    for _ in range(steps):
        tok = jnp.argmax(logits, -1).astype(jnp.int32)
        ctx = jnp.concatenate([ctx, tok[:, None]], axis=1)
        logits, cache = m.decode_step_slots(params, cache, tok, pos)
        full = m.lm_logits(params, ctx)[:, -1]
        np.testing.assert_allclose(np.asarray(logits), np.asarray(full),
                                   rtol=2e-4, atol=2e-4)
        pos = pos + 1


# ------------------------------------------------- serve(): no retraces
def test_serve_slot_swaps_never_retrace_and_replay_is_deterministic():
    """Admits/evicts across a whole trace reuse the fused compiled
    program (at most one trace per chunk size); an identical replay
    compiles nothing and reproduces the schedule exactly."""
    c = ARCHS["qwen3-0.6b"].reduced()
    m = Model(c)
    params = m.init_params(KEY)
    server = Server(m, params, ClusterSpec.make([2, 2], [4.0, 0.8]),
                    ServeConfig(block_rows=64))
    wl = make_workload("poisson", num_requests=8, prompt_len=(4, 8),
                       out_len=(2, 6), vocab=c.vocab_size)
    trace = wl.trace(seed=5)
    decode_block = 2
    rep1 = server.serve(trace, slots=2, decode_block=decode_block)
    traces_after_first = server.serve_traces
    assert 1 <= traces_after_first <= decode_block
    rep2 = server.serve(trace, slots=2, decode_block=decode_block)
    assert server.serve_traces == traces_after_first, (
        "slot admits/evicts must be pure buffer updates, not retraces"
    )
    done1 = {f.request.rid: f for f in rep1.finished if f.outcome == "done"}
    done2 = {f.request.rid: f for f in rep2.finished if f.outcome == "done"}
    assert len(done1) == 8 and rep1.shed == 0
    for rid, f in done1.items():
        assert f.tokens == f.request.out_len
        assert done2[rid].finish_round == f.finish_round
    assert rep1.rounds == rep2.rounds and rep1.tokens == rep2.tokens
    assert rep1.latency_percentile(99) == rep2.latency_percentile(99)


def test_serve_overload_sheds_and_reports():
    c = ARCHS["qwen3-0.6b"].reduced()
    m = Model(c)
    params = m.init_params(KEY)
    server = Server(m, params, None, ServeConfig())  # uncoded head is fine
    sink = _Sink()
    wl = make_workload("overload", num_requests=10, prompt_len=(4, 6),
                       out_len=(4, 8), vocab=c.vocab_size)
    rep = server.serve(wl.trace(seed=1), slots=2, decode_block=2,
                       queue_cap=2, telemetry=sink)
    assert rep.shed > 0 and rep.admitted + rep.shed == 10
    assert rep.tokens == sum(
        f.request.out_len for f in rep.finished if f.outcome == "done"
    )
    names = {n for n, _ in sink.events}
    assert {"request_admitted", "request_evicted", "request_done"} <= names
