"""Scheme registry, engine facade, elastic replan, and deadline tests.

Covers the ISSUE-1 acceptance criteria: every registered scheme
round-trips name -> object -> allocate -> simulate; integer loads always
cover k; replanning preserves scheme params for every scheme; deadlines
are finite and positive for every scheme (including those with NaN T*).
"""
import dataclasses

import jax
import numpy as np
import pytest

from repro.core import (
    ClusterSpec,
    CodedComputeEngine,
    LatencyModel,
    Optimal,
    Reisizadeh,
    Uncoded,
    UniformN,
    UniformR,
    make_scheme,
    plan_deployment,
    replan_on_membership_change,
    scheme_for_plan,
    scheme_names,
)
from repro.core.planner import deploy
from repro.runtime.fault_tolerance import deadline_for

KEY = jax.random.PRNGKey(0)
K = 512

# params needed to instantiate each registry name on the test cluster
PARAMS = {
    "uniform_n": {"n": 700.0},
    "uniform_r": {"r": 8},
    "uniform_r_group_code": {"r": 8},
}


def cluster3() -> ClusterSpec:
    return ClusterSpec.make([6, 10, 8], [4.0, 1.0, 0.4], 1.0)


def all_schemes():
    return [make_scheme(name, **PARAMS.get(name, {})) for name in scheme_names()]


# ------------------------------------------------------------- registry
def test_every_name_round_trips_allocate_simulate():
    """name -> object -> allocate -> simulate on a 3-group cluster."""
    c = cluster3()
    for name in scheme_names():
        scheme = make_scheme(name, **PARAMS.get(name, {}))
        plan = scheme.allocate(c, K)
        assert plan.scheme_obj is scheme
        assert plan.k == K
        assert np.all(plan.loads > 0)
        # integer loads always cover k rows
        assert plan.n_int >= K, f"{name}: n_int={plan.n_int} < k={K}"
        lat = scheme.simulate(KEY, c, plan, num_trials=500)
        lat = np.asarray(lat)
        assert lat.shape == (500,)
        assert np.all(np.isfinite(lat)) and np.all(lat > 0), name


def test_unknown_scheme_name_rejected():
    with pytest.raises(ValueError, match="unknown scheme"):
        make_scheme("no_such_scheme")


def test_missing_params_rejected():
    with pytest.raises(ValueError, match="uniform_n"):
        make_scheme("uniform_n")
    with pytest.raises(ValueError, match="uniform_r"):
        make_scheme("uniform_r")
    with pytest.raises(ValueError):
        UniformN(n=-3.0)
    with pytest.raises(ValueError):
        UniformR(r=0)


def test_schemes_are_frozen_value_objects():
    assert UniformR(r=8) == UniformR(r=8)
    assert UniformR(r=8) != UniformR(r=9)
    with pytest.raises(dataclasses.FrozenInstanceError):
        UniformR(r=8).r = 9


def test_legacy_string_shim_matches_objects():
    """plan_deployment(scheme=<str>) == deploy(<object>) for all schemes."""
    c = cluster3()
    pairs = [
        (dict(scheme="optimal"), Optimal()),
        (dict(scheme="optimal", per_row=True), Optimal(LatencyModel.MODEL_30)),
        (dict(scheme="uniform_n", n=700.0), UniformN(n=700.0)),
        (dict(scheme="uniform_r", r=8), UniformR(r=8)),
        (dict(scheme="reisizadeh"), Reisizadeh()),
        (dict(scheme="uncoded"), Uncoded()),
    ]
    for kwargs, obj in pairs:
        old = plan_deployment(c, K, **kwargs)
        new = deploy(obj, c, K)
        assert old.scheme == new.scheme
        np.testing.assert_array_equal(old.loads_per_worker, new.loads_per_worker)
        assert old.scheme_obj == obj


# -------------------------------------------------------------- replan
def test_replan_preserves_params_for_every_scheme():
    """Regression: replanning used to crash for uniform_n/uniform_r
    (params dropped, bare assert) and string-match on 'optimal*'."""
    c = cluster3()
    c2 = ClusterSpec.make([6, 5, 8], [4.0, 1.0, 0.4], 1.0)  # group 2 shrank
    for scheme in all_schemes():
        plan = deploy(scheme, c, K)
        plan2 = replan_on_membership_change(plan, c2)
        assert plan2.scheme_obj == scheme, plan.scheme
        assert plan2.scheme == plan.scheme
        assert plan2.num_workers == c2.total_workers
        assert plan2.n >= K or plan.scheme == "uncoded"
        # uniform_n keeps its code size; uniform_r keeps its r
        if isinstance(scheme, UniformN):
            assert plan2.allocation.n == pytest.approx(scheme.n)
        if isinstance(scheme, UniformR):
            np.testing.assert_allclose(
                plan2.allocation.loads, K / scheme.r, rtol=1e-12
            )


def test_replan_per_row_model_survives():
    c = cluster3()
    plan = deploy(Optimal(LatencyModel.MODEL_30), c, K)
    assert plan.scheme == "optimal_per_row"
    plan2 = replan_on_membership_change(plan, ClusterSpec.make([6, 10], [4.0, 1.0]))
    assert plan2.scheme == "optimal_per_row"
    assert plan2.scheme_obj.latency_model is LatencyModel.MODEL_30


def test_scheme_for_plan_reconstructs_legacy_plans():
    """Plans built from the bare allocation functions still resolve."""
    from repro.core import allocation

    c = cluster3()
    for plan, expect in [
        (allocation.optimal_allocation(c, K), Optimal()),
        (allocation.uniform_given_n(c, K, 700.0), UniformN(n=700.0)),
        (allocation.uniform_given_r(c, K, 8), UniformR(r=8)),
        (allocation.reisizadeh_allocation(c, K), Reisizadeh()),
        (allocation.uncoded(c, K), Uncoded()),
    ]:
        assert plan.scheme_obj is None
        got = scheme_for_plan(plan)
        assert type(got) is type(expect)
        if isinstance(expect, UniformR):
            assert got.r == expect.r


def test_scheme_for_plan_prefers_exact_allocation_over_integer_loads():
    """Integerized loads round (66.67 -> 67); reconstruction must use the
    attached real-valued allocation so r does not drift (150 -> 149)."""
    from repro.core import allocation
    from repro.core.planner import integerize

    c = ClusterSpec.make([100, 200, 100], [4.0, 1.0, 0.4], 1.0)
    dep = integerize(c, allocation.uniform_given_r(c, 10_000, 150))
    assert dep.scheme_obj is None  # legacy-style plan
    got = scheme_for_plan(dep)
    assert got == UniformR(r=150)


# -------------------------------------------------------------- engine
def test_engine_lifecycle():
    c = cluster3()
    eng = CodedComputeEngine(c, K, "uniform_r", scheme_params={"r": 8})
    assert eng.plan.scheme == "uniform_r_group_code"
    g = np.asarray(eng.generator())
    assert g.shape == (eng.plan.n, K)
    lat = eng.expected_latency(KEY, num_trials=500)
    assert np.isfinite(lat) and lat > 0
    c2 = ClusterSpec.make([6, 10], [4.0, 1.0], 1.0)
    plan2 = eng.replan(c2)
    assert eng.replans == 1
    assert plan2.num_workers == 16
    assert plan2.scheme == "uniform_r_group_code"  # r preserved


def test_engine_rejects_params_with_object_scheme():
    with pytest.raises(ValueError):
        CodedComputeEngine(cluster3(), K, Uncoded(), scheme_params={"r": 3})


# ------------------------------------------------------------ deadlines
def test_deadline_finite_positive_for_all_schemes():
    """Schemes with NaN T* (uniform_n, reisizadeh, uncoded) fall back to
    a Monte-Carlo estimate instead of returning NaN."""
    c = cluster3()
    for scheme in all_schemes():
        plan = deploy(scheme, c, K)
        d = deadline_for(plan, num_trials=500)
        assert np.isfinite(d) and d > 0, plan.scheme
        d_eng = CodedComputeEngine(c, K, scheme).deadline(num_trials=500)
        assert np.isfinite(d_eng) and d_eng > 0, plan.scheme


# --------------------------------------------------- allocate memoization
def test_allocate_is_memoized_per_scheme_cluster_k():
    from repro.core.schemes import allocate_cache_clear, allocate_cache_info

    allocate_cache_clear()
    c = cluster3()
    scheme = make_scheme("optimal")
    p1 = scheme.allocate(c, K)
    assert allocate_cache_info()["size"] == 1
    p2 = scheme.allocate(c, K)  # hit: same key, no new entry
    assert allocate_cache_info()["size"] == 1
    np.testing.assert_array_equal(p1.loads, p2.loads)
    assert p2.scheme_obj is scheme and p2.scheme == scheme.tag
    # a caller mutating a returned plan must not poison the cache
    p1.loads[:] = -1.0
    np.testing.assert_array_equal(scheme.allocate(c, K).loads, p2.loads)
    # membership change = different cluster key -> fresh solve, and an
    # equal-parameter scheme OBJECT shares the cache entry (frozen eq)
    c2 = ClusterSpec.make([6, 10], [4.0, 1.0], 1.0)
    scheme.allocate(c2, K)
    assert allocate_cache_info()["size"] == 2
    make_scheme("optimal").allocate(c, K)
    assert allocate_cache_info()["size"] == 2
    # different k is a different solve
    scheme.allocate(c, K // 2)
    assert allocate_cache_info()["size"] == 3
    allocate_cache_clear()
    assert allocate_cache_info()["size"] == 0
