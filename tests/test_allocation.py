"""Allocation math vs the paper's claims (Theorems 1-4, Remark 1, App D)."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest
from scipy.special import lambertw as scipy_lambertw

from _hypothesis_compat import given, settings, st

from repro.core import (
    ClusterSpec,
    optimal_allocation,
    optimal_r,
    reisizadeh_allocation,
    t_star,
    uniform_given_n,
    uniform_given_r,
    xi_star,
)
from repro.core.allocation import group_code_split
from repro.core.runtime_model import expected_order_stat, harmonic, xi


def paper_cluster_fig4(N: int) -> ClusterSpec:
    """Fig. 4 setting: N_j = (3,4,5,6,7)N/25, mu = (16,12,8,4,1)."""
    frac = np.array([3, 4, 5, 6, 7]) / 25.0
    return ClusterSpec.make((frac * N).astype(int), [16, 12, 8, 4, 1], 1.0)


def test_optimal_r_formula():
    """eq. (15) against a direct scipy computation."""
    c = ClusterSpec.make([100, 200], [1.0, 2.0], [1.0, 0.5])
    n, mu, al = c.arrays()
    r = np.asarray(optimal_r(n, mu, al))
    for j, g in enumerate(c.groups):
        w = scipy_lambertw(-np.exp(-(g.alpha * g.mu + 1.0)), k=-1).real
        np.testing.assert_allclose(r[j], g.num_workers * (1 + 1 / w), rtol=1e-10)
        assert 0 < r[j] < g.num_workers


def test_theorem1_equalization():
    """The optimal plan equalizes per-group expected latencies (Thm 1)."""
    c = ClusterSpec.make([1000, 2000, 3000], [2.0, 1.0, 0.5], 1.0)
    k = 10_000
    plan = optimal_allocation(c, k)
    n, mu, al = c.arrays()
    lam = np.asarray(
        expected_order_stat(jnp.asarray(plan.loads), jnp.asarray(plan.r), n, mu, al, k)
    )
    np.testing.assert_allclose(lam, lam[0], rtol=1e-9)
    # ... and each equals the lower bound T* (eq. (21)).
    np.testing.assert_allclose(lam, plan.t_star, rtol=1e-9)


def test_mds_constraint():
    """sum_j r_j * l_j = k  (eq. (5)) holds for the real-valued optimum."""
    c = ClusterSpec.make([300, 600], [4.0, 0.5], 1.0)
    k = 5000
    plan = optimal_allocation(c, k)
    np.testing.assert_allclose(
        np.sum(plan.r * plan.loads * np.array([1.0])), k, rtol=1e-9
    )
    got = sum(
        r * l for r, l in zip(plan.r, plan.loads)
    )
    np.testing.assert_allclose(got, k, rtol=1e-9)


def test_remark1_homogeneous_reduces_to_lee_et_al():
    """Remark 1: equal (mu, alpha) groups -> the [4] homogeneous optimum."""
    mu, alpha, k = 2.0, 1.0, 4096
    c = ClusterSpec.make([100, 200, 300], [mu, mu, mu], alpha)
    plan = optimal_allocation(c, k)
    w = scipy_lambertw(-np.exp(-(alpha * mu + 1.0)), k=-1).real
    N = c.total_workers
    l_expected = k / (N * (1 + 1 / w))
    np.testing.assert_allclose(plan.loads, l_expected, rtol=1e-10)
    np.testing.assert_allclose(plan.t_star, -w / (mu * N), rtol=1e-10)


def test_t_star_theta_1_over_N():
    """T* = Theta(1/N) (paper Fig. 2 discussion)."""
    ts = []
    for scale in [1, 2, 4, 8]:
        c = ClusterSpec.make(
            [1000 * scale, 2000 * scale, 3000 * scale], [2.0, 1.0, 0.5], 1.0
        )
        n, mu, al = c.arrays()
        ts.append(float(t_star(n, mu, al)))
    ratios = np.array(ts[:-1]) / np.array(ts[1:])
    np.testing.assert_allclose(ratios, 2.0, rtol=1e-9)


def test_optimal_beats_baselines_on_lower_bound():
    """f(r) is minimized at r* (Lemma 2/3): any perturbation is worse."""
    c = ClusterSpec.make([100, 150], [3.0, 0.7], 1.0)
    n, mu, al = c.arrays()
    r_star = np.asarray(optimal_r(n, mu, al))

    def f(r):
        x = xi(jnp.asarray(r), n, mu, al)
        return float(1.0 / jnp.sum(jnp.asarray(r) / x))

    base = f(r_star)
    rng = np.random.default_rng(0)
    for _ in range(50):
        pert = r_star + rng.uniform(-1, 1, size=2) * 0.1 * r_star
        pert = np.clip(pert, 1e-3, np.asarray(n) - 1e-3)
        assert f(pert) >= base - 1e-12


def test_group_code_split_solves_eq28_26():
    c = ClusterSpec.make([100, 200, 300], [3.0, 2.0, 1.0], 1.0)
    r = 200
    split = group_code_split(c, r)
    np.testing.assert_allclose(split.sum(), r, rtol=1e-9)
    # eq. (28): equalized exponential tails
    n, mu, _ = c.arrays()
    tails = np.log(np.asarray(n) / (np.asarray(n) - split)) / np.asarray(mu)
    np.testing.assert_allclose(tails, tails[0], rtol=1e-6)


def test_uniform_r_latency_floor():
    """[33] scheme's latency floor is 1/r (Section III-D-2)."""
    c = paper_cluster_fig4(2500)
    plan = uniform_given_r(c, k=10_000, r=100)
    assert plan.t_star == pytest.approx(1.0 / 100)
    np.testing.assert_allclose(plan.loads, 100.0)  # k/r rows each


def test_reisizadeh_matches_corollary2_optimum():
    """Paper Fig. 9 claim: [32]'s allocation == Cor. 2 optimum (per-row)."""
    c = ClusterSpec.make([300, 300, 400], [1.0, 4.0, 8.0], [1.0, 4.0, 12.0])
    k = 100_000
    ours = optimal_allocation(c, k, per_row=True)
    theirs = reisizadeh_allocation(c, k)
    np.testing.assert_allclose(theirs.loads, ours.loads, rtol=1e-8)
    np.testing.assert_allclose(theirs.n, ours.n, rtol=1e-8)


def test_harmonic_matches_direct_sum():
    for n in [1, 5, 100]:
        np.testing.assert_allclose(
            float(harmonic(n)), sum(1.0 / i for i in range(1, n + 1)), rtol=1e-12
        )


@settings(max_examples=60, deadline=None)
@given(
    st.lists(st.integers(min_value=20, max_value=500), min_size=1, max_size=5),
    st.lists(st.floats(min_value=0.05, max_value=50.0), min_size=5, max_size=5),
    st.floats(min_value=0.2, max_value=5.0),
)
def test_property_plan_invariants(ns, mus, alpha):
    """Invariants for arbitrary clusters: positivity, r_j < N_j, eq. (5),
    equalization, and n >= k (code rate <= 1)."""
    mus = mus[: len(ns)]
    c = ClusterSpec.make(ns, mus, alpha)
    k = 10_000
    plan = optimal_allocation(c, k)
    assert np.all(plan.loads > 0)
    assert np.all(plan.r > 0)
    assert np.all(plan.r < np.array([g.num_workers for g in c.groups]))
    np.testing.assert_allclose(np.dot(plan.r, plan.loads), k, rtol=1e-8)
    assert plan.n >= k - 1e-6
    assert plan.t_star > 0
    # integerized loads cover at least as much as the real plan
    assert plan.n_int >= np.floor(plan.n) - 1e-6
