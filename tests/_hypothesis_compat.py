"""Graceful degradation when `hypothesis` is not installed.

The property-based tests use hypothesis, but the library is an optional
dev dependency (see requirements-dev.txt). Importing hypothesis at test
module top level used to abort collection of the WHOLE file — including
the plain example-based tests — on machines without it. Import the
decorators from here instead:

    from _hypothesis_compat import given, settings, st

With hypothesis installed this is a pass-through. Without it, `@given`
replaces the test with a skip (reason: hypothesis not installed) in the
spirit of ``pytest.importorskip``, while every non-property test in the
module still collects and runs.
"""
from __future__ import annotations

import pytest

try:
    from hypothesis import given, settings, strategies as st  # noqa: F401

    HAVE_HYPOTHESIS = True
except ImportError:
    HAVE_HYPOTHESIS = False

    def settings(*_args, **_kwargs):
        return lambda f: f

    def given(*_args, **_kwargs):
        def deco(f):
            @pytest.mark.skip(reason="hypothesis not installed")
            def skipped():
                pass

            skipped.__name__ = getattr(f, "__name__", "skipped_property_test")
            skipped.__doc__ = f.__doc__
            return skipped

        return deco

    class _AnyStrategy:
        """Stand-in for `hypothesis.strategies`: any call returns None."""

        def __getattr__(self, _name):
            return lambda *a, **k: None

    st = _AnyStrategy()
