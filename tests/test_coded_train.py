"""Coded training: gradient coding on the shared round substrate.

Covers the PR-4 subsystem (DESIGN.md §5): decode-vector correctness
against the numpy oracle over an erasure grid, exact parity between the
coded train step and plain DP when nobody misses the deadline, skip-step
degradation when everybody does, replans mid-training preserving scheme
params, the host-side drop-straggler fallback, the bandwidth MLE feeding
elastic replans, and telemetry handle hygiene.
"""
import itertools

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import ARCHS
from repro.configs.base import ShapeConfig
from repro.core.gradient_coding import (
    assignment_matrix,
    decode_vector,
    decode_vector_jit,
    encode_gradients,
    aggregate_coded,
)
from repro.core.runtime_model import ClusterSpec
from repro.core.schemes import CommAware, make_scheme
from repro.data import SyntheticLMData
from repro.models.model import Model
from repro.optim import AdamWConfig, adamw_init, adamw_update
from repro.runtime.executor import CodedRoundExecutor
from repro.runtime.fault_tolerance import ElasticController, StragglerTracker
from repro.runtime.telemetry import Telemetry
from repro.runtime.train_loop import (
    TrainConfig,
    Trainer,
    aggregate_with_erasures,
    make_coded_train_step_fn,
)

KEY = jax.random.PRNGKey(0)


# ------------------------------------------------------- decode vectors
def test_decode_vector_oracle_erasure_grid():
    """jit decode vector == numpy oracle across an erasure grid."""
    n, k = 9, 5
    b = np.asarray(assignment_matrix(n, k, key=KEY))
    for erased in itertools.chain.from_iterable(
        itertools.combinations(range(n), e) for e in range(0, n - k + 2)
    ):
        mask = np.ones(n, bool)
        mask[list(erased)] = False
        a_np, ok_np = decode_vector(b, mask)
        a_j, ok_j = decode_vector_jit(b, mask)
        assert bool(ok_j) == ok_np == (mask.sum() >= k)
        if ok_np:
            # both satisfy a^T B = 1 and zero the erased rows
            np.testing.assert_allclose(a_np @ b, np.ones(k), atol=1e-9)
            np.testing.assert_allclose(np.asarray(a_j) @ b, np.ones(k),
                                       atol=1e-4)
            assert np.all(a_np[~mask] == 0)
            assert np.all(np.asarray(a_j)[~mask] == 0)
        else:
            assert np.all(a_np == 0) and np.all(np.asarray(a_j) == 0)


def test_decode_vector_no_erasures_is_exact_ones():
    """Systematic B + full survival -> decode vector is EXACTLY e_1..e_k."""
    b = assignment_matrix(7, 4, key=KEY)
    a, ok = decode_vector_jit(b, np.ones(7, bool))
    assert bool(ok)
    np.testing.assert_array_equal(np.asarray(a)[:4], np.ones(4))
    np.testing.assert_array_equal(np.asarray(a)[4:], np.zeros(3))


def test_encode_aggregate_roundtrip_matches_weighting():
    """sum_i a_i (B g)_i == sum_j (a^T B)_j g_j on a pytree."""
    n, k = 6, 3
    b = assignment_matrix(n, k, key=KEY)
    grads = {"w": jax.random.normal(KEY, (k, 4, 2)),
             "b": jax.random.normal(jax.random.fold_in(KEY, 1), (k, 5))}
    mask = np.array([True, False, True, True, False, True])
    a, ok = decode_vector(np.asarray(b), mask)
    assert ok
    coded = encode_gradients(grads, b)
    agg = aggregate_coded(coded, a)
    w = a @ np.asarray(b)
    for leaf, ref in ((agg["w"], grads["w"]), (agg["b"], grads["b"])):
        direct = jnp.tensordot(jnp.asarray(w, leaf.dtype), ref, axes=1)
        np.testing.assert_allclose(np.asarray(leaf), np.asarray(direct),
                                   rtol=1e-4, atol=1e-4)


# ----------------------------------------------------- coded train step
def _mk(model_batch=4, seq=32, steps=4, cluster=None, **cfg_kw):
    c = ARCHS["qwen3-0.6b"].reduced()
    m = Model(c)
    sh = ShapeConfig("t", seq, model_batch, "train")
    data = SyntheticLMData(c, sh, seed=1)
    opt_cfg = AdamWConfig(lr=1e-3, warmup_steps=0, total_steps=10)
    cfg = TrainConfig(steps=steps, log_every=1, cluster=cluster, **cfg_kw)
    return Trainer(m, data, opt_cfg, cfg)


def test_coded_step_parity_with_uncoded_when_no_erasures():
    """Huge deadline -> nobody misses -> coded == plain DP training."""
    cluster = ClusterSpec.make([2, 2], [4.0, 1.0])
    coded = _mk(cluster=cluster)
    coded.executor.deadline = 1e9  # nobody ever misses
    p_coded, _, hist_coded = coded.run()
    assert coded.traces == 1  # ONE compiled program across all steps

    plain = _mk(cluster=None)
    p_plain, _, hist_plain = plain.run()

    for a, b in zip(jax.tree.leaves(p_coded), jax.tree.leaves(p_plain)):
        np.testing.assert_allclose(
            np.asarray(a, np.float32), np.asarray(b, np.float32),
            rtol=5e-4, atol=5e-5,
        )
    for hc, hp in zip(hist_coded, hist_plain):
        assert hc["loss"] == pytest.approx(hp["loss"], rel=1e-4)
        assert hc["skipped"] == 0.0


def test_coded_step_erasures_match_numpy_oracle():
    """Fixed erasure pattern: jitted step == oracle decode + adamw."""
    cluster = ClusterSpec.make([2, 2], [4.0, 1.0])
    t = _mk(cluster=cluster)
    exe = t.executor
    wmask = np.ones(exe.num_workers, bool)
    wmask[0] = False  # one worker's coded rows erased
    exe.finish_mask_jit = lambda key, deadline: jnp.asarray(wmask)
    t._build_coded_step()

    params = t.model.init_params(jax.random.PRNGKey(0))
    opt_state = adamw_init(t.opt_cfg, params)
    batch = t.data.next_batch()
    new_p, _, metrics = t.coded_step_fn(
        params, opt_state, batch, KEY, jnp.float32(exe.deadline)
    )
    assert metrics["skipped"] == 0.0

    # ------- numpy/jax oracle: per-partition grads, oracle decode vector
    # (fresh params/opt: the jitted step donated the originals)
    params = t.model.init_params(jax.random.PRNGKey(0))
    opt_state = adamw_init(t.opt_cfg, params)
    k = t.partitions
    row_alive = np.asarray(wmask)[np.asarray(exe.slot_owner)]
    a, ok = decode_vector(t.b_matrix, row_alive)
    assert ok
    w_part = a @ t.b_matrix
    toks = np.asarray(batch["tokens"]).reshape(k, 1, -1)
    labs = np.asarray(batch["labels"]).reshape(k, 1, -1)
    agg = None
    for j in range(k):
        _, g = jax.value_and_grad(t.model.loss_fn, has_aux=True)(
            params, {"tokens": jnp.asarray(toks[j]),
                     "labels": jnp.asarray(labs[j])}
        )
        term = jax.tree.map(
            lambda x: (w_part[j] / k) * x.astype(jnp.float32), g
        )
        agg = term if agg is None else jax.tree.map(jnp.add, agg, term)
    ref_p, _, _ = adamw_update(t.opt_cfg, agg, opt_state, params)
    for got, ref in zip(jax.tree.leaves(new_p), jax.tree.leaves(ref_p)):
        np.testing.assert_allclose(
            np.asarray(got, np.float32), np.asarray(ref, np.float32),
            rtol=2e-4, atol=2e-5,
        )


def test_coded_step_skips_when_all_miss():
    """Zero deadline -> every round undecodable -> params/opt unchanged."""
    cluster = ClusterSpec.make([2, 2], [4.0, 1.0])
    t = _mk(cluster=cluster)
    params = t.model.init_params(jax.random.PRNGKey(0))
    opt_state = adamw_init(t.opt_cfg, params)
    batch = t.data.next_batch()
    new_p, new_o, metrics = t.coded_step_fn(
        params, opt_state, batch, KEY, jnp.float32(0.0)
    )
    assert metrics["skipped"] == 1.0
    assert metrics["survivors"] == 0.0
    p0 = t.model.init_params(jax.random.PRNGKey(0))  # donated originals
    for got, ref in zip(jax.tree.leaves(new_p), jax.tree.leaves(p0)):
        np.testing.assert_array_equal(np.asarray(got), np.asarray(ref))


def test_replan_mid_training_preserves_scheme_params():
    """Membership change: scheme object survives, step recompiles, runs."""
    cluster = ClusterSpec.make([3, 3], [4.0, 0.5])
    t = _mk(cluster=cluster, steps=2, scheme="grad_coding")
    scheme0 = t.executor.scheme
    t.run()
    traces0 = t.traces

    smaller = ClusterSpec.make([2, 3], [4.0, 0.5])
    plan = t.replan(smaller)
    assert t.executor.scheme is scheme0  # typed params preserved exactly
    assert plan.num_workers == 5
    assert t.executor.replans == 1
    assert any(e["event"] == "replan" for e in t.telemetry.events)

    t.cfg.steps = 4
    t.run()  # re-runs from scratch on the new fleet
    assert t.traces == traces0 + 1  # exactly one retrace for new shapes


def test_trainer_rejects_bad_partitions():
    cluster = ClusterSpec.make([2], [1.0])
    with pytest.raises(ValueError, match="divide"):
        _mk(cluster=cluster, partitions=3)


# ------------------------------------------- host-side degraded fallback
def test_aggregate_with_erasures_all_missed_degrades():
    """All workers missing no longer crashes: zero grads (or previous),
    with the stall surfaced as a telemetry event."""
    g1 = {"w": jnp.ones(3)}
    g2 = {"w": 2 * jnp.ones(3)}
    tel = Telemetry()
    out = aggregate_with_erasures([g1, g2], [5, 5], [False, False],
                                  telemetry=tel)
    np.testing.assert_array_equal(np.asarray(out["w"]), np.zeros(3))
    assert tel.events and tel.events[0]["event"] == "all_workers_missed_deadline"

    prev = {"w": 7 * jnp.ones(3)}
    out = aggregate_with_erasures([g1, g2], [5, 5], [False, False],
                                  prev_grads=prev, telemetry=tel)
    np.testing.assert_array_equal(np.asarray(out["w"]), 7 * np.ones(3))
    assert len(tel.events) == 2


# ------------------------------------------------ bandwidth estimation
def test_bandwidth_mle_and_comm_aware_replan():
    """observe_transfers MLEs per-group bandwidth and feeds it into the
    estimated cluster, so CommAware elastic replans see measured links."""
    cluster = ClusterSpec.make([4, 4], [2.0, 2.0])  # spec: infinite links
    tracker = StragglerTracker(cluster, forget=0.5)
    b_true = np.array([8.0, 0.1])
    rng = np.random.default_rng(0)
    for _ in range(50):
        # noisy transfer measurements around payload / b_j
        t = np.concatenate([
            (1.0 / b_true[0]) * rng.uniform(0.9, 1.1, 4),
            (1.0 / b_true[1]) * rng.uniform(0.9, 1.1, 4),
        ])
        tracker.observe_transfers(t, payload=1.0)
    est = tracker.bandwidth_estimates
    assert est[0] == pytest.approx(8.0, rel=0.1)
    assert est[1] == pytest.approx(0.1, rel=0.1)
    est_cluster = tracker.estimated_cluster()
    np.testing.assert_allclose(est_cluster.bandwidths, est, rtol=1e-12)

    # comm-aware replan on the estimates: the slow measured link gets
    # ZERO load even though the spec said links were free
    ctl = ElasticController(cluster, k=512, scheme=CommAware(upload=2.0,
                                                             download=2.0))
    plan = ctl.engine.plan
    assert np.all(np.asarray(plan.loads_per_worker) > 0)  # comm-blind spec
    new_plan = ctl.on_estimates_update(tracker)
    assert ctl.engine.scheme == CommAware(upload=2.0, download=2.0)
    loads = np.asarray(new_plan.loads_per_worker)
    assert np.all(loads[:4] > 0)
    assert np.all(loads[4:] == 0), "slow measured link must be excluded"


def test_bandwidth_estimates_default_to_spec():
    """No observations -> estimated cluster keeps the spec bandwidths."""
    cluster = ClusterSpec.make([3, 3], [2.0, 1.0], 1.0, [5.0, float("inf")])
    tracker = StragglerTracker(cluster)
    est = tracker.estimated_cluster()
    np.testing.assert_array_equal(est.bandwidths, cluster.bandwidths)


# -------------------------------------------------------- telemetry
def test_telemetry_context_manager_closes_file(tmp_path):
    path = tmp_path / "tel.jsonl"
    with Telemetry(str(path)) as tel:
        tel.tick()
        tel.log(1, {"loss": 1.5})
        tel.event("replan", workers=3)
        assert tel._fh is not None
    assert tel._fh is None  # closed deterministically on exit
    lines = path.read_text().strip().splitlines()
    assert len(lines) == 2
    assert '"event": "replan"' in lines[1]


# ---------------------------------------------- executor substrate bits
def test_executor_slot_map_and_deadline():
    cluster = ClusterSpec.make([2, 2], [4.0, 0.5])
    exe = CodedRoundExecutor(cluster, 16, "grad_coding")
    plan = exe.plan
    assert exe.n == int(np.sum(plan.loads_per_worker))
    owner = np.asarray(exe.slot_owner)
    for w, (s, e) in enumerate(plan.row_ranges):
        assert np.all(owner[s:e] == w)
    # deadline is finite, positive, and at least the analytic bound
    assert np.isfinite(exe.deadline) and exe.deadline > 0
    assert exe.deadline >= plan.t_star
    # slot gather: worker mask -> per-row mask
    wmask = np.zeros(exe.num_workers, bool)
    wmask[1] = True
    rows = np.asarray(exe.slot_mask_jit(wmask))
    s, e = plan.row_ranges[1]
    assert rows[s:e].all() and rows.sum() == e - s


def test_executor_serves_every_registered_scheme_mask():
    """finish_mask_jit is commensurate with each scheme's own model."""
    from repro.core.schemes import scheme_names, scheme_params

    cluster = ClusterSpec.make([4, 4], [4.0, 1.0], 1.0, [8.0, 2.0])
    fallbacks = {"n": 24.0, "r": 4}
    for name in scheme_names():
        try:
            scheme = make_scheme(name)
        except ValueError:
            scheme = make_scheme(name, **{
                p: fallbacks[p] for p in scheme_params(name) if p in fallbacks
            })
        exe = CodedRoundExecutor(cluster, 16, scheme)
        mask = exe.sample_finish_mask(KEY)
        assert mask.shape == (cluster.total_workers,)
        assert mask.dtype == bool
