"""MDS coding: encode/decode correctness, erasure tolerance, planner."""
import subprocess
import sys

import jax
import jax.numpy as jnp
import numpy as np
import pytest
from _hypothesis_compat import given, settings, st
from repro.core import ClusterSpec, plan_deployment
from repro.core.coding import (
    decode_from_rows,
    decode_systematic,
    encode,
    make_generator,
    split_loads,
)
from repro.core.planner import estimate_mu_online, replan_on_membership_change

KEY = jax.random.PRNGKey(0)


def test_any_k_rows_decode_gaussian():
    k, d, n = 32, 8, 48
    g = make_generator(n, k, KEY)
    a = jax.random.normal(jax.random.PRNGKey(1), (k, d))
    y = encode(g, a @ jnp.ones((d,)))
    rng = np.random.default_rng(0)
    for _ in range(10):
        rows = rng.choice(n, size=k, replace=False)
        z = decode_from_rows(g[rows], y[rows])
        np.testing.assert_allclose(
            np.asarray(z), np.asarray(a @ jnp.ones((d,))), rtol=1e-3, atol=1e-4
        )


def test_vandermonde_decode():
    k, n = 16, 24
    g = make_generator(n, k, kind="chebyshev_vandermonde")
    x = jax.random.normal(KEY, (k,))
    y = encode(g, x)
    rows = np.arange(n)[-k:]  # all-parity worst case
    z = decode_from_rows(g[rows], y[rows])
    np.testing.assert_allclose(np.asarray(z), np.asarray(x), rtol=1e-3, atol=1e-4)


def test_systematic_fast_decode():
    k, n = 64, 96
    g = make_generator(n, k, KEY)
    x = np.asarray(jax.random.normal(jax.random.PRNGKey(2), (k,)))
    y = np.asarray(encode(g, jnp.asarray(x)))
    # erase 10 systematic rows and 5 parity rows
    mask = np.ones((n,), dtype=bool)
    mask[[3, 7, 11, 20, 31, 40, 41, 50, 60, 63]] = False
    mask[[70, 80, 90, 94, 95]] = False
    z, ok = decode_systematic(g, y, mask, k)
    assert ok
    np.testing.assert_allclose(z, x, rtol=1e-4, atol=1e-5)


def test_systematic_decode_insufficient():
    k, n = 8, 10
    g = make_generator(n, k, KEY)
    y = np.zeros((n,), dtype=np.float32)
    mask = np.zeros((n,), dtype=bool)
    mask[:5] = True
    _, ok = decode_systematic(g, y, mask, k)
    assert not ok


@settings(max_examples=25, deadline=None)
@given(
    st.integers(min_value=4, max_value=40),
    st.integers(min_value=0, max_value=16),
    st.integers(min_value=0, max_value=999),
)
def test_property_mds_recovery(k, extra, seed):
    """Any k surviving coded rows recover the product (MDS property)."""
    n = k + extra
    g = make_generator(n, k, jax.random.PRNGKey(seed))
    x = np.asarray(jax.random.normal(jax.random.PRNGKey(seed + 1), (k,)))
    y = np.asarray(encode(g, jnp.asarray(x)))
    rng = np.random.default_rng(seed)
    alive = rng.choice(n, size=k, replace=False)
    mask = np.zeros((n,), dtype=bool)
    mask[alive] = True
    z, ok = decode_systematic(g, y, mask, k)
    assert ok
    np.testing.assert_allclose(z, x, rtol=5e-2, atol=5e-3)


def test_split_loads():
    assert split_loads([3, 2, 4]) == [(0, 3), (3, 5), (5, 9)]


def test_planner_deployment_and_replan():
    c = ClusterSpec.make([4, 8], [4.0, 1.0], 1.0)
    plan = plan_deployment(c, k=256, scheme="optimal")
    assert plan.num_workers == 12
    assert plan.n == plan.loads_per_worker.sum() >= 256
    assert len(plan.row_ranges) == 12
    # elastic: group 2 loses half its workers -> replan keeps invariants
    c2 = ClusterSpec.make([4, 4], [4.0, 1.0], 1.0)
    plan2 = replan_on_membership_change(plan, c2)
    assert plan2.num_workers == 8
    assert plan2.n >= 256
    assert plan2.t_star > plan.t_star  # fewer workers -> higher latency


def test_estimate_mu_online():
    rng = np.random.default_rng(0)
    k, load = 1000, 50.0
    mu_true, alpha_true = 3.0, 1.0
    t = alpha_true * load / k + (load / (k * mu_true)) * rng.exponential(
        size=(20000,)
    )
    mus, alphas = estimate_mu_online([t], k, [load])
    assert mus[0] == pytest.approx(mu_true, rel=0.05)
    assert alphas[0] == pytest.approx(alpha_true, rel=0.05)


DISTRIBUTED_SCRIPT = r"""
import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"
import jax, numpy as np, jax.numpy as jnp
from jax.sharding import Mesh
from repro.core import ClusterSpec, plan_deployment
from repro.core.coded_matvec import end_to_end_coded_matvec

c = ClusterSpec.make([4, 4], [4.0, 1.0], 1.0)
plan = plan_deployment(c, k=128, scheme="optimal")
assert plan.num_workers == 8
mesh = Mesh(np.array(jax.devices()).reshape(8), ("workers",))
key = jax.random.PRNGKey(0)
a = jax.random.normal(key, (128, 64))
x = jax.random.normal(jax.random.PRNGKey(1), (64,))
# all workers finish
z, ok = end_to_end_coded_matvec(mesh, a, x, plan)
assert ok
np.testing.assert_allclose(z, np.asarray(a @ x), rtol=2e-2, atol=2e-3)
# stragglers: two slow-group workers miss the deadline (34 of the 40
# redundant rows -- within the plan's straggler tolerance)
fin = np.ones(8, bool); fin[[6, 7]] = False
z2, ok2 = end_to_end_coded_matvec(mesh, a, x, plan, finished_workers=fin)
assert ok2
np.testing.assert_allclose(z2, np.asarray(a @ x), rtol=2e-2, atol=2e-3)
print("DISTRIBUTED_OK")
"""


def test_distributed_coded_matvec_8_devices():
    """shard_map coded matvec on 8 placeholder devices (own process so the
    device-count flag never leaks into this test session)."""
    r = subprocess.run(
        [sys.executable, "-c", DISTRIBUTED_SCRIPT],
        capture_output=True,
        text=True,
        env={"PYTHONPATH": "src", "PATH": "/usr/bin:/bin:/usr/local/bin"},
        cwd="/root/repo",
        timeout=600,
    )
    assert r.returncode == 0, r.stderr[-2000:]
    assert "DISTRIBUTED_OK" in r.stdout
