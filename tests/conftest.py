import jax

# Core allocation math is validated at float64 (scipy oracle comparison).
# NOTE: do NOT set xla_force_host_platform_device_count here — smoke
# tests must see the real single-device CPU; only launch/dryrun.py uses
# 512 placeholder devices (in its own process).
jax.config.update("jax_enable_x64", True)
