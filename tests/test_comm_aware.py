"""CommDelay model + CommAware/CommUniform scheme tests.

Covers the ISSUE-3 limit criteria: bandwidth -> inf recovers Optimal's
allocation and T* exactly (the Lambert-W fast path), the numeric
deadline solve satisfies its defining equation, the download-only case
cross-checks against the closed form at comm-shifted alphas, and the
Monte-Carlo mean tracks the comm-augmented lower bound.
"""
import dataclasses

import jax
import numpy as np
import pytest

from repro.core import (
    ClusterSpec,
    CodedComputeEngine,
    CommAware,
    CommUniform,
    Optimal,
    comm_aware_allocation,
    comm_t_star,
    optimal_allocation,
)
from repro.core.allocation import comm_deadline_terms
from repro.core.planner import deploy, replan_on_membership_change
from repro.core.runtime_model import comm_terms
from repro.core.simulator import simulate_comm_threshold, simulate_threshold
from repro.runtime.fault_tolerance import deadline_for

KEY = jax.random.PRNGKey(3)
K = 2_000


def finite_bw_cluster() -> ClusterSpec:
    # fast compute behind slow links (the adversarial case)
    return ClusterSpec.make(
        [40, 80, 40], [4.0, 1.0, 0.5], 1.0, [1.0, 4.0, 16.0]
    )


# ------------------------------------------------------ limit: b -> inf
def test_infinite_bandwidth_recovers_optimal_exactly():
    """The ISSUE-3 analytic cross-check: with free links the comm-aware
    plan IS Theorem 2's — same loads array, same T*, bit for bit."""
    c = ClusterSpec.make([6, 10, 8], [4.0, 1.0, 0.4], 1.0)  # bw defaults inf
    comm = CommAware().allocate(c, K)
    opt = Optimal().allocate(c, K)
    np.testing.assert_array_equal(comm.loads, opt.loads)
    np.testing.assert_array_equal(comm.loads_int, opt.loads_int)
    assert comm.t_star == opt.t_star
    assert comm.n == opt.n
    assert comm.scheme == "comm_aware"  # tag still the scheme's own


def test_zero_transfer_costs_recover_optimal_exactly():
    """upload = download = 0 kills the comm terms even on finite links."""
    c = finite_bw_cluster()
    comm = CommAware(upload=0.0, download=0.0).allocate(c, K)
    opt = Optimal().allocate(c, K)
    np.testing.assert_array_equal(comm.loads, opt.loads)
    assert comm.t_star == opt.t_star


def test_large_bandwidth_converges_to_optimal():
    """T*(b) -> T* monotonically from above as every link speeds up."""
    base = ClusterSpec.make([40, 80, 40], [4.0, 1.0, 0.5], 1.0)
    t_opt = float(Optimal().allocate(base, K).t_star)
    prev = np.inf
    for b in [1.0, 10.0, 100.0, 1e4, 1e8]:
        t_b = comm_t_star(base.with_bandwidths(b), 1.0, 1.0)
        assert t_opt < t_b < prev + 1e-15, (b, t_b)
        prev = t_b
    assert prev == pytest.approx(t_opt, rel=1e-6)


# ----------------------------------------------------- numeric optimum
def test_numeric_deadline_solves_defining_equation():
    """Bisection root satisfies sum_j g_j (t - c_j)_+ = 1 to ~1e-12."""
    c = finite_bw_cluster()
    t = comm_t_star(c, 2.0, 1.0)
    cc, g, _ = comm_deadline_terms(c, 2.0, 1.0)
    residual = float(np.sum(g * np.maximum(t - cc, 0.0))) - 1.0
    assert abs(residual) < 1e-9


def test_download_only_matches_closed_form_at_shifted_alphas():
    """With upload = 0 the comm optimum is Theorem 2 at alpha + d/b:
    the Lambert-W fast path must agree with optimal_allocation on the
    alpha-shifted cluster (analytic cross-check of the comm terms)."""
    c = finite_bw_cluster()
    d = 1.5
    comm = comm_aware_allocation(c, K, upload=0.0, download=d)
    shifted = ClusterSpec.make(
        [g.num_workers for g in c.groups],
        [g.mu for g in c.groups],
        [g.alpha + d / g.bandwidth for g in c.groups],
    )
    opt = optimal_allocation(shifted, K)
    np.testing.assert_allclose(comm.loads, opt.loads, rtol=1e-9)
    assert comm.t_star == pytest.approx(opt.t_star, rel=1e-9)


def test_slow_links_excluded_and_deadline_equation_feasible():
    """A group whose transfer shift exceeds the optimal deadline gets
    zero load — the qualitative change vs the comm-blind optimum."""
    c = ClusterSpec.make(
        [20, 20], [1.0, 4.0], 1.0, [10.0, 0.01]  # group 2: fast CPU, dead link
    )
    plan = comm_aware_allocation(c, K, upload=1.0, download=1.0)
    assert plan.loads[1] == 0.0 and plan.loads_int[1] == 0
    assert plan.loads[0] > 0
    assert plan.n_int >= K  # still a feasible code
    # the comm-blind optimum loads BOTH groups (it cannot see the link)
    blind = optimal_allocation(c, K)
    assert np.all(blind.loads > 0)


# ------------------------------------------------------- MC vs bound
def test_monte_carlo_tracks_comm_bound():
    """MC mean within tolerance of the comm-augmented lower bound
    (ISSUE-3: simulator Monte-Carlo mean vs analytic bound)."""
    c = ClusterSpec.make(
        [100, 200, 100], [4.0, 1.0, 0.5], 1.0, [0.5, 2.0, 8.0]
    )
    scheme = CommAware()
    plan = scheme.allocate(c, 10_000)
    lat = float(np.mean(np.asarray(
        scheme.simulate(KEY, c, plan, num_trials=4000)
    )))
    assert lat >= plan.t_star * (1 - 0.02)
    assert lat == pytest.approx(plan.t_star, rel=0.10)


def test_comm_simulation_reduces_to_threshold_on_free_links():
    """simulate_comm_threshold == simulate_threshold when bandwidth=inf
    (same key, same samples — the shift is exactly zero)."""
    c = ClusterSpec.make([6, 10], [4.0, 1.0], 1.0)
    loads = [30.0, 20.0]
    a = simulate_comm_threshold(KEY, c, loads, K, 512)
    b = simulate_threshold(KEY, c, loads, K, num_trials=512)
    np.testing.assert_array_equal(np.asarray(a), np.asarray(b))


def test_comm_shift_lower_bounds_every_sample():
    """No completion can beat the fixed transfer shift of the fastest
    active group."""
    c = ClusterSpec.make([8, 8], [4.0, 1.0], 1.0, [0.5, 0.25])
    upload = 2.0
    shift, _ = comm_terms(c, upload, 1.0)
    lat = np.asarray(
        simulate_comm_threshold(KEY, c, [100.0, 100.0], K, 512, upload=upload)
    )
    assert np.all(lat >= shift.min() - 1e-6)


# --------------------------------------------------- scheme mechanics
def test_comm_uniform_defaults_to_comm_aware_code_size():
    c = finite_bw_cluster()
    aware = CommAware().allocate(c, K)
    uni = CommUniform().allocate(c, K)
    assert uni.n == pytest.approx(aware.n)
    assert np.ptp(uni.loads) == 0  # uniform split over every group
    assert np.isnan(uni.t_star)  # no closed form -> MC fallback paths


def test_comm_uniform_explicit_n_respected():
    c = finite_bw_cluster()
    uni = CommUniform(n=3_000.0).allocate(c, K)
    assert uni.n == pytest.approx(3_000.0)


def test_invalid_comm_params_rejected():
    with pytest.raises(ValueError):
        CommAware(upload=-1.0)
    with pytest.raises(ValueError):
        CommUniform(n=-5.0)
    with pytest.raises(ValueError):
        ClusterSpec.make([4], [1.0], 1.0, [0.0])  # bandwidth must be > 0


def test_engine_replan_deadline_with_comm_scheme():
    """comm_aware is usable from every layer with no dispatch edits:
    engine lifecycle, elastic replan (params + bandwidths preserved),
    and the fault-tolerance deadline."""
    c = finite_bw_cluster()
    eng = CodedComputeEngine(
        c, K, "comm_aware", scheme_params={"upload": 2.0, "download": 0.5}
    )
    assert eng.scheme == CommAware(upload=2.0, download=0.5)
    assert np.isfinite(eng.t_star)
    lat = eng.expected_latency(KEY, num_trials=500)
    assert np.isfinite(lat) and lat > 0
    d = eng.deadline(num_trials=500)
    assert np.isfinite(d) and d > 0

    groups = list(c.groups)
    groups[1] = dataclasses.replace(groups[1], num_workers=60)
    plan2 = eng.replan(ClusterSpec(tuple(groups)))
    assert plan2.scheme_obj == CommAware(upload=2.0, download=0.5)
    assert plan2.num_workers == 140
    assert deadline_for(plan2, num_trials=500) > 0


def test_bare_allocation_plans_keep_transfer_costs():
    """Regression: plans built from the bare comm allocation functions
    must carry their transfer costs — scheme_for_plan used to rebuild
    them with DEFAULT costs (upload=download=1.0), so a later replan or
    deadline silently used the wrong comm model."""
    from repro.core import scheme_for_plan
    from repro.core.planner import integerize

    c = finite_bw_cluster()
    plan = comm_aware_allocation(c, K, upload=5.0, download=5.0)
    got = scheme_for_plan(plan)
    assert got == CommAware(upload=5.0, download=5.0)
    dep = integerize(c, plan)
    dep2 = replan_on_membership_change(
        dep, ClusterSpec.make([40, 80], [4.0, 1.0], 1.0, [1.0, 4.0])
    )
    assert dep2.scheme_obj == CommAware(upload=5.0, download=5.0)

    from repro.core import comm_uniform_allocation

    uni = comm_uniform_allocation(c, K, n=3_000.0, upload=2.0, download=0.0)
    assert scheme_for_plan(uni) == CommUniform(n=3_000.0, upload=2.0,
                                               download=0.0)


def test_straggler_tracker_preserves_bandwidths_on_replan():
    """Regression: estimated_cluster() used to rebuild GroupSpec without
    the bandwidth field, so an on_estimates_update replan silently
    loaded excluded slow-link groups again (comm-blind degeneration)."""
    from repro.runtime.fault_tolerance import ElasticController, StragglerTracker

    c = ClusterSpec.make([20, 20], [1.0, 4.0], 1.0, [10.0, 0.01])
    ctl = ElasticController(c, K, scheme="comm_aware")
    assert ctl.plan.allocation.loads[1] == 0.0  # dead link excluded
    tracker = StragglerTracker(c)
    assert tracker.estimated_cluster().bandwidths.tolist() == [10.0, 0.01]
    plan2 = ctl.on_estimates_update(tracker)
    assert plan2.allocation.loads[1] == 0.0  # still excluded after replan


def test_cluster_parse_bandwidth_syntax():
    """CLI group syntax shared by launch/serve.py and launch/dryrun.py."""
    c = ClusterSpec.parse("6:2.0,6:0.5")
    assert c.total_workers == 12
    assert np.all(np.isinf(c.bandwidths))
    c2 = ClusterSpec.parse("6:2.0:8.0,6:0.5", 2.0)
    assert c2.bandwidths.tolist() == [8.0, 2.0]
    with pytest.raises(ValueError):
        ClusterSpec.parse("6:2.0:8.0:9.0")
