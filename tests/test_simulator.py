"""Monte Carlo simulator vs analytic order statistics + paper claims."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core import ClusterSpec, optimal_allocation, uniform_given_r, uncoded
from repro.core.allocation import uniform_given_n
from repro.core.runtime_model import expected_order_stat
from repro.core.simulator import (
    expected_latency,
    simulate_group_code,
    simulate_threshold,
)

KEY = jax.random.PRNGKey(42)


def test_single_group_matches_order_statistic():
    """One homogeneous group: MC mean == analytic lambda_{r:N} (eq. 6)."""
    c = ClusterSpec.make([400], [1.5], 1.0)
    k = 1000
    r = 300
    load = k / r  # (N, r) MDS code with uniform loads
    lat = simulate_threshold(KEY, c, [load], k, num_trials=40_000)
    n, mu, al = c.arrays()
    analytic = float(
        expected_order_stat(load, r, n[0], mu[0], al[0], k, exact_harmonic=True)
    )
    assert float(jnp.mean(lat)) == pytest.approx(analytic, rel=0.01)


def test_optimal_plan_achieves_lower_bound_asymptotically():
    """Theorem 3: MC latency of (l*, r*) -> T* as N grows."""
    gaps = []
    for N in [250, 2500, 12500]:
        frac = np.array([3, 4, 5, 6, 7]) / 25.0
        c = ClusterSpec.make((frac * N).astype(int), [16, 12, 8, 4, 1], 1.0)
        plan = optimal_allocation(c, k=10_000)
        mc = expected_latency(KEY, c, plan, num_trials=4000)
        gaps.append(mc / plan.t_star - 1.0)
        assert mc >= plan.t_star * (1 - 0.02)  # lower bound holds (MC noise)
    # monotone-ish convergence to the bound
    assert gaps[-1] < gaps[0]
    assert gaps[-1] < 0.05


def test_optimal_beats_uniform_and_uncoded():
    """Fig. 4 ordering: optimal < uniform(n*) < uncoded, at finite N."""
    frac = np.array([3, 4, 5, 6, 7]) / 25.0
    c = ClusterSpec.make((frac * 2500).astype(int), [16, 12, 8, 4, 1], 1.0)
    k = 10_000
    opt = optimal_allocation(c, k)
    t_opt = expected_latency(KEY, c, opt, num_trials=4000)
    t_uni = expected_latency(
        KEY, c, uniform_given_n(c, k, opt.n), num_trials=4000
    )
    t_unc = expected_latency(KEY, c, uncoded(c, k), num_trials=4000)
    assert t_opt < t_uni < t_unc
    # paper: ~18% gain over uniform with the same (n*, k) code; allow slack
    assert (t_uni - t_opt) / t_uni > 0.05


def test_group_code_floor():
    """[33]'s scheme flattens at 1/r while the optimal keeps improving."""
    r = 100
    k = 10_000
    lats = []
    for N in [2500, 25_000]:
        frac = np.array([3, 4, 5, 6, 7]) / 25.0
        c = ClusterSpec.make((frac * N).astype(int), [16, 12, 8, 4, 1], 1.0)
        plan = uniform_given_r(c, k, r)
        lat = float(
            jnp.mean(
                simulate_group_code(
                    KEY, c, float(plan.loads[0]), plan.r, k, num_trials=3000
                )
            )
        )
        lats.append(lat)
    # both near (above) the 1/r floor; big-N case pinned to it
    assert lats[1] == pytest.approx(1.0 / r, rel=0.05)
    # optimal at N=25000 is order(s) of magnitude below the floor
    frac = np.array([3, 4, 5, 6, 7]) / 25.0
    c = ClusterSpec.make((frac * 25_000).astype(int), [16, 12, 8, 4, 1], 1.0)
    opt = optimal_allocation(c, k)
    t_opt = expected_latency(KEY, c, opt, num_trials=2000)
    assert t_opt < lats[1] / 5.0  # "orders of magnitude" at large N


def test_group_code_vectorized_matches_analytic_single_group():
    """Padded single-jit formulation: one group == analytic order stat."""
    c = ClusterSpec.make([200], [1.5], 1.0)
    lat = simulate_group_code(KEY, c, 5.0, [120], k=1000, num_trials=40_000)
    n, mu, al = c.arrays()
    analytic = float(
        expected_order_stat(5.0, 120, n[0], mu[0], al[0], 1000,
                            exact_harmonic=True)
    )
    assert float(jnp.mean(lat)) == pytest.approx(analytic, rel=0.02)


def test_group_code_vectorized_heterogeneous_max_over_groups():
    """Ragged groups (padding in play): the slow group's order stat wins."""
    c = ClusterSpec.make([40, 60], [6.0, 0.5], 1.0)
    lat = simulate_group_code(
        KEY, c, 5.0, [20, 30], k=1000, num_trials=40_000
    )
    slow = float(
        expected_order_stat(5.0, 30, 60, 0.5, 1.0, 1000, exact_harmonic=True)
    )
    fast = float(
        expected_order_stat(5.0, 20, 40, 6.0, 1.0, 1000, exact_harmonic=True)
    )
    assert slow > 2 * fast  # the max is dominated by the slow group
    assert float(jnp.mean(lat)) == pytest.approx(slow, rel=0.03)


def test_infeasible_returns_inf():
    c = ClusterSpec.make([10], [1.0], 1.0)
    lat = simulate_threshold(KEY, c, [1.0], k=100, num_trials=8)
    assert np.all(np.isinf(np.asarray(lat)))


def test_integer_loads_close_to_real():
    """Ceil-rounding has negligible latency effect for large k (paper §III-B)."""
    c = ClusterSpec.make([300, 600], [4.0, 0.5], 1.0)
    plan = optimal_allocation(c, k=100_000)
    t_real = expected_latency(KEY, c, plan, num_trials=4000)
    t_int = expected_latency(
        KEY, c, plan, num_trials=4000, use_integer_loads=True
    )
    assert abs(t_int - t_real) / t_real < 0.02
