"""Runtime layers: trainer resume, fault tolerance, coded serving."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import ARCHS
from repro.configs.base import ShapeConfig
from repro.core.planner import plan_deployment
from repro.core.runtime_model import ClusterSpec
from repro.data import SyntheticLMData
from repro.models.model import Model
from repro.optim import AdamWConfig
from repro.runtime.fault_tolerance import (
    ElasticController,
    StragglerTracker,
    deadline_for,
)
from repro.runtime.serve_loop import CodedLMHead, ServeConfig, Server
from repro.runtime.train_loop import (
    TrainConfig,
    Trainer,
    aggregate_with_erasures,
)

KEY = jax.random.PRNGKey(0)


# ------------------------------------------------------------------ trainer
def _mk_trainer(tmp_path, steps, ckpt_every=5, schedule_steps=10):
    c = ARCHS["qwen3-0.6b"].reduced()
    m = Model(c)
    sh = ShapeConfig("t", 32, 2, "train")
    data = SyntheticLMData(c, sh, seed=1)
    # schedule_steps fixed across runs so resume sees the same LR curve
    opt_cfg = AdamWConfig(lr=1e-3, warmup_steps=0, total_steps=schedule_steps)
    cfg = TrainConfig(steps=steps, checkpoint_dir=str(tmp_path),
                      checkpoint_every=ckpt_every, log_every=1)
    return Trainer(m, data, opt_cfg, cfg)


def test_trainer_runs_and_loss_finite(tmp_path):
    t = _mk_trainer(tmp_path, steps=6)
    params, _, history = t.run()
    assert all(np.isfinite(h["loss"]) for h in history)


def test_trainer_resume_bitwise_equal(tmp_path):
    """10 straight steps == 5 steps + checkpoint restart + 5 steps."""
    t_full = _mk_trainer(tmp_path / "a", steps=10, ckpt_every=100)
    p_full, _, _ = t_full.run()

    t1 = _mk_trainer(tmp_path / "b", steps=5, ckpt_every=5)
    t1.run()
    t2 = _mk_trainer(tmp_path / "b", steps=10, ckpt_every=5)
    p_resumed, _, _ = t2.run()

    for a, b in zip(jax.tree.leaves(p_full), jax.tree.leaves(p_resumed)):
        np.testing.assert_allclose(
            np.asarray(a, np.float32), np.asarray(b, np.float32),
            rtol=1e-6, atol=1e-6,
        )


def test_aggregate_with_erasures_rescales():
    g1 = {"w": jnp.ones(4)}
    g2 = {"w": 3 * jnp.ones(4)}
    g3 = {"w": 100 * jnp.ones(4)}  # straggler — dropped
    out = aggregate_with_erasures([g1, g2, g3], [10, 10, 10], [True, True, False])
    np.testing.assert_allclose(np.asarray(out["w"]), 2 * np.ones(4))


# ---------------------------------------------------------- fault tolerance
def test_straggler_tracker_estimates_mu():
    cluster = ClusterSpec.make([50, 50], [4.0, 1.0])
    plan = plan_deployment(cluster, k=1000)
    tracker = StragglerTracker(cluster, forget=0.0)  # no smoothing: one shot
    key = KEY
    from repro.core.runtime_model import sample_worker_times

    loads = jnp.asarray(plan.loads_per_worker, jnp.float32)
    mus = jnp.repeat(jnp.asarray([4.0, 1.0]), 50)
    alphas = jnp.ones(100)
    t = np.asarray(sample_worker_times(key, loads, mus, alphas, 1000, 200))
    for i in range(200):
        tracker.observe_round(t[i], np.asarray(plan.loads_per_worker), 1000)
    est = tracker.estimated_cluster()
    assert est.groups[0].mu == pytest.approx(4.0, rel=0.35)
    assert est.groups[1].mu == pytest.approx(1.0, rel=0.35)


def test_failure_detection_and_elastic_replan():
    cluster = ClusterSpec.make([10, 10], [2.0, 1.0])
    tracker = StragglerTracker(cluster, fail_after=2)
    plan0 = plan_deployment(cluster, k=100)
    times = np.ones(20)
    times[3] = np.inf  # worker 3 dead
    loads = np.asarray(plan0.loads_per_worker)
    tracker.observe_round(times, loads, 100)
    tracker.observe_round(times, loads, 100)
    assert 3 in tracker.failed_workers
    est = tracker.estimated_cluster()
    assert est.total_workers == 19

    ctl = ElasticController(cluster, k=100)
    new_plan = ctl.on_estimates_update(tracker)
    assert ctl.replans == 1
    assert new_plan.num_workers == 19
    assert new_plan.n >= 100  # still a valid (n, k) code


def test_deadline_positive():
    cluster = ClusterSpec.make([20], [1.0])
    plan = plan_deployment(cluster, k=100)
    assert deadline_for(plan) > plan.t_star > 0


# ------------------------------------------- elastic-controller hysteresis
def _converged_tracker(cluster, k=512, rounds=60, seed=3):
    """Tracker whose estimates have settled on the cluster's true params."""
    from repro.core.runtime_model import sample_worker_times

    plan = plan_deployment(cluster, k=k)
    tracker = StragglerTracker(cluster, forget=0.5)
    loads = jnp.asarray(plan.loads_per_worker, jnp.float32)
    mus = jnp.concatenate([jnp.full((g.num_workers,), g.mu)
                           for g in cluster.groups])
    alphas = jnp.ones(cluster.total_workers)
    t = np.asarray(sample_worker_times(
        jax.random.PRNGKey(seed), loads, mus, alphas, k, rounds
    ))
    for i in range(rounds):
        tracker.observe_round(t[i], np.asarray(plan.loads_per_worker), k)
    return tracker


def test_elastic_controller_noop_updates_hold_under_hysteresis():
    """Repeated estimate updates with an unchanged fleet never replan."""
    cluster = ClusterSpec.make([10, 10], [2.0, 1.0])
    tracker = _converged_tracker(cluster)
    ctl = ElasticController(cluster, k=512, threshold=0.05)
    for _ in range(5):
        ctl.on_estimates_update(tracker)
    assert ctl.replans == 0
    assert ctl.last_decision is not None
    assert ctl.last_decision.reason == "hold"


def test_elastic_controller_exact_threshold_crossing_replans():
    """An estimate update whose gain lands exactly ON the threshold acts."""
    cluster = ClusterSpec.make([10, 10], [4.0, 1.0])
    slowed = ClusterSpec.make([10, 10], [0.2, 1.0])  # group 0 collapsed
    tracker = _converged_tracker(slowed)
    probe = ElasticController(cluster, k=512, threshold=0.0)
    probe.on_estimates_update(tracker)
    gain = probe.last_decision.gain
    assert gain > 0
    at = ElasticController(cluster, k=512, threshold=gain)
    at.on_estimates_update(tracker)
    assert at.replans == 1  # inclusive crossing
    above = ElasticController(
        cluster, k=512, threshold=np.nextafter(gain, 2.0)
    )
    above.on_estimates_update(tracker)
    assert above.replans == 0


def test_elastic_controller_membership_change_always_replans():
    """A dead worker forces a replan even with an uncrossable threshold."""
    cluster = ClusterSpec.make([10, 10], [2.0, 1.0])
    tracker = StragglerTracker(cluster, fail_after=2)
    plan0 = plan_deployment(cluster, k=100)
    times = np.ones(20)
    times[3] = np.inf
    loads = np.asarray(plan0.loads_per_worker)
    tracker.observe_round(times, loads, 100)
    tracker.observe_round(times, loads, 100)
    ctl = ElasticController(cluster, k=100, threshold=1e9)
    new_plan = ctl.on_estimates_update(tracker)
    assert ctl.replans == 1
    assert ctl.last_decision.reason == "membership"
    assert new_plan.num_workers == 19


def test_elastic_controller_legacy_default_always_replans():
    """threshold=None (the default) keeps replan-on-every-update."""
    cluster = ClusterSpec.make([10, 10], [2.0, 1.0])
    tracker = _converged_tracker(cluster)
    ctl = ElasticController(cluster, k=512)
    ctl.on_estimates_update(tracker)
    ctl.on_estimates_update(tracker)
    assert ctl.replans == 2


# --------------------------------------------------- ClusterSpec.parse
def test_cluster_parse_accepts_well_formed_specs():
    c = ClusterSpec.parse("6:2.0,6:0.5:8.0", 2.0)
    assert c.groups[0].num_workers == 6
    assert c.groups[0].bandwidth == 2.0  # default applied
    assert c.groups[1].bandwidth == 8.0


@pytest.mark.parametrize("spec,match", [
    ("0:2.0", "worker count must be a positive"),
    ("-3:2.0", "worker count must be a positive"),
    ("2.5:2.0", "worker count '2.5' is not an integer"),
    ("x:2.0", "worker count 'x' is not an integer"),
    ("4:0", "mu must be > 0"),
    ("4:-1.0", "mu must be > 0"),
    ("4:fast", "mu 'fast' is not a number"),
    ("4:2.0:0", "bandwidth must be > 0"),
    ("4:2.0:-8", "bandwidth must be > 0"),
    ("4:2.0:wide", "bandwidth 'wide' is not a number"),
    ("4", "expected N:mu or N:mu:bandwidth"),
    ("4:2.0:8.0:9.0", "expected N:mu or N:mu:bandwidth"),
    ("6:2.0,,6:0.5", "expected N:mu or N:mu:bandwidth"),
])
def test_cluster_parse_rejects_malformed_specs(spec, match):
    """Actionable errors instead of bare int()/float() tracebacks."""
    with pytest.raises(ValueError, match=match):
        ClusterSpec.parse(spec)


def test_cluster_parse_rejects_bad_default_bandwidth():
    with pytest.raises(ValueError, match="default bandwidth must be > 0"):
        ClusterSpec.parse("4:2.0", 0.0)


# ------------------------------------------------------------ coded serving
def test_coded_lm_head_exact_recovery_all_finish():
    c = ARCHS["granite-3-2b"].reduced()
    m = Model(c)
    params = m.init_params(KEY)
    cluster = ClusterSpec.make([4, 4], [2.0, 0.5])
    head = CodedLMHead(params["embed"]["table"], cluster, block_rows=64)
    h = jax.random.normal(KEY, (3, c.d_model))
    products = head.worker_products(h)
    logits, ok = head.decode_logits(products, np.ones(head.plan.num_workers, bool))
    assert ok
    expected = np.asarray(h @ head.table.T)
    np.testing.assert_allclose(
        logits[:, : head.table.shape[0]], expected, rtol=1e-3, atol=1e-3
    )


def test_coded_lm_head_tolerates_erasures():
    c = ARCHS["granite-3-2b"].reduced()
    m = Model(c)
    params = m.init_params(KEY)
    cluster = ClusterSpec.make([6, 6], [2.0, 0.5])
    head = CodedLMHead(params["embed"]["table"], cluster, block_rows=64)
    h = jax.random.normal(KEY, (2, c.d_model))
    products = head.worker_products(h)
    # kill workers until just enough blocks survive
    mask = np.ones(head.plan.num_workers, bool)
    blocks_alive = head.nb
    for w in range(head.plan.num_workers):
        load = int(head.plan.loads_per_worker[w])
        if blocks_alive - load >= head.kb:
            mask[w] = False
            blocks_alive -= load
    logits, ok = head.decode_logits(products, mask)
    assert ok
    expected = np.asarray(h @ head.table.T)
    np.testing.assert_allclose(
        logits[:, : head.table.shape[0]], expected, rtol=1e-3, atol=1e-3
    )
    # below threshold -> explicit failure signal
    logits, ok = head.decode_logits(products, np.zeros_like(mask))
    assert not ok


def test_server_generate_coded_matches_uncoded():
    """With no stragglers (huge deadline) coded decode == plain decode."""
    c = ARCHS["qwen3-0.6b"].reduced()
    m = Model(c)
    params = m.init_params(KEY)
    prompts = jax.random.randint(KEY, (2, 4), 0, c.vocab_size).astype(jnp.int32)

    plain = Server(m, params, None, ServeConfig(max_decode_steps=6))
    out_plain = plain.generate(prompts, 6)

    cluster = ClusterSpec.make([8], [5.0])  # fast workers
    coded = Server(m, params, cluster, ServeConfig(max_decode_steps=6))
    coded.coded_head.deadline = 1e9  # nobody misses
    out_coded = coded.generate(prompts, 6)
    np.testing.assert_array_equal(np.asarray(out_plain), np.asarray(out_coded))
