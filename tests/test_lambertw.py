"""Lambert W validation against the scipy oracle + property tests."""
import jax.numpy as jnp
import numpy as np
import pytest
from scipy.special import lambertw as scipy_lambertw

from _hypothesis_compat import given, settings, st

from repro.core.lambertw import lambertw0, lambertwm1


def test_wm1_matches_scipy_grid():
    z = -np.exp(-np.linspace(1.0001, 50, 500))  # spans [-1/e, ~0)
    ours = np.asarray(lambertwm1(z))
    ref = scipy_lambertw(z, k=-1).real
    np.testing.assert_allclose(ours, ref, rtol=1e-10, atol=1e-12)


def test_w0_matches_scipy_grid():
    z = np.concatenate([
        -np.exp(-np.linspace(1.0001, 30, 200)),
        np.linspace(0.0, 100.0, 300),
        np.logspace(2, 8, 50),
    ])
    ours = np.asarray(lambertw0(z))
    ref = scipy_lambertw(z, k=0).real
    np.testing.assert_allclose(ours, ref, rtol=1e-9, atol=1e-12)


def test_wm1_near_branch_point():
    # scipy snaps to -1.0 very near the branch point, so use the
    # defining equation + the exact local expansion as the oracle:
    # z = -e^{-1}(1 - eps)  =>  W_{-1}(z) = -1 - sqrt(2 eps) + O(eps).
    eps = np.logspace(-12, -2, 40)
    z = -np.exp(-1.0) + eps * np.exp(-1.0)
    ours = np.asarray(lambertwm1(z))
    assert np.all(ours <= -1.0)
    # local expansion to 2 orders: -1 + p - p^2/3 with p = -sqrt(2 eps)
    p = -np.sqrt(2 * eps)
    approx = -1.0 + p - p * p / 3.0
    # 1e-10 slack: computing 1 + e*z in float64 loses ~2.5e-16 absolute,
    # which perturbs p = -sqrt(2(1+ez)) by up to ~2e-10 for eps ~ 1e-12.
    assert np.all(np.abs(ours - approx) <= np.abs(p**3) + 1e-9)
    # defining equation residual (scaled by local curvature |z + 1/e|)
    resid = ours * np.exp(ours) - z
    np.testing.assert_allclose(resid, 0.0, atol=1e-9 * np.exp(-1.0))
    # strictly decreasing in eps
    assert np.all(np.diff(ours) < 0)


def test_wm1_domain():
    assert np.isnan(float(lambertwm1(0.1)))
    assert np.isnan(float(lambertwm1(-1.0)))  # below -1/e


@settings(max_examples=200, deadline=None)
@given(st.floats(min_value=1.0001, max_value=200.0))
def test_wm1_inverse_property(t):
    """W_{-1}(z) e^{W_{-1}(z)} = z for z = -e^{-t}, t > 1."""
    z = -np.exp(-t)
    w = float(lambertwm1(z))
    assert w <= -1.0
    np.testing.assert_allclose(w * np.exp(w), z, rtol=1e-8, atol=1e-300)


@settings(max_examples=100, deadline=None)
@given(st.floats(min_value=-0.35, max_value=1e6))
def test_w0_inverse_property(z):
    w = float(lambertw0(z))
    assert w >= -1.0
    np.testing.assert_allclose(w * np.exp(w), z, rtol=1e-7, atol=1e-9)


def test_jit_and_vmap():
    import jax

    z = jnp.asarray([-0.3, -0.1, -0.01])
    a = jax.jit(lambertwm1)(z)
    b = jax.vmap(lambertwm1)(z)
    np.testing.assert_allclose(np.asarray(a), np.asarray(b), rtol=1e-12)
