"""Fused linear-cross-entropy kernel vs oracle: value + gradients."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.kernels.fused_ce.ops import fused_linear_ce
from repro.kernels.fused_ce.ref import linear_ce_ref

KEY = jax.random.PRNGKey(11)


@pytest.mark.parametrize("t,v,d", [(256, 512, 128), (100, 300, 64),
                                   (8, 1000, 32)])
def test_fused_ce_value_matches_ref(t, v, d):
    h = jax.random.normal(KEY, (t, d)) * 0.5
    e = jax.random.normal(jax.random.fold_in(KEY, 1), (v, d)) * 0.5
    labels = jax.random.randint(jax.random.fold_in(KEY, 2), (t,), 0, v)
    got = fused_linear_ce(h, e, labels)
    want = linear_ce_ref(h, e, labels)
    np.testing.assert_allclose(float(got), float(want), rtol=1e-5)


def test_fused_ce_masked_labels():
    t, v, d = 64, 256, 32
    h = jax.random.normal(KEY, (t, d))
    e = jax.random.normal(jax.random.fold_in(KEY, 1), (v, d))
    labels = jax.random.randint(jax.random.fold_in(KEY, 2), (t,), 0, v)
    masked = labels.at[: t // 2].set(-1)
    got = fused_linear_ce(h, e, masked)
    want = linear_ce_ref(h[t // 2:], e, labels[t // 2:])
    np.testing.assert_allclose(float(got), float(want), rtol=1e-5)


def test_fused_ce_gradients_match_ref():
    t, v, d = 64, 384, 48
    h = jax.random.normal(KEY, (t, d)) * 0.3
    e = jax.random.normal(jax.random.fold_in(KEY, 1), (v, d)) * 0.3
    labels = jax.random.randint(jax.random.fold_in(KEY, 2), (t,), 0, v)
    gh, ge = jax.grad(fused_linear_ce, argnums=(0, 1))(h, e, labels)
    gh_r, ge_r = jax.grad(
        lambda hh, ee: linear_ce_ref(hh, ee, labels), argnums=(0, 1)
    )(h, e)
    np.testing.assert_allclose(np.asarray(gh), np.asarray(gh_r),
                               rtol=1e-4, atol=1e-6)
    np.testing.assert_allclose(np.asarray(ge), np.asarray(ge_r),
                               rtol=1e-4, atol=1e-6)
