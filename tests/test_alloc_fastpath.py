"""Jitted planner fast path + plan bucketing (ISSUE 7 / DESIGN.md §11).

Parity: every registered scheme's ``allocate`` through the jitted cores
(``core/alloc_fastpath``) must match the eager/numpy oracle
(``allocation.eager_oracle()``) — real loads and t_star to float64
round-off, integerized loads and code size EXACTLY — across a cluster
grid that covers the hard corners: heterogeneous G=6, comm-shifted
finite links (zero-load excluded groups), and near-deterministic
workers (large alpha*mu, the Lambert-W log-space regime).

Also pinned here: the eager bisections' asserted residual bound
(< 1e-9, an ISSUE 7 satellite), the allocate memo-cache hit/miss
counters, bucket quantization/signature semantics, and the headline
property of bucket-switch replanning — a non-structural replan through
``CodedRoundExecutor.replan`` leaves a compiled consumer program's
trace count pinned at 1.
"""
import dataclasses

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core import (
    ClusterSpec,
    allocation,
    make_scheme,
    scheme_names,
    scheme_params,
)
from repro.core.schemes import allocate_cache_clear, allocate_cache_info
from repro.runtime.executor import CodedRoundExecutor
from repro.runtime.plan_bucket import (
    BucketConfig,
    bucket_signature,
    quantize_loads_int,
)
from repro.runtime.telemetry import Telemetry

K = 512

# same generic instantiation as test_scheme_invariants: canonical
# fallback per accepted PARAM NAME, no per-scheme knowledge
PARAM_FALLBACKS = {
    "n": lambda cluster, k: 1.5 * k,
    "r": lambda cluster, k: max(1, cluster.total_workers // 2),
}


def instantiate(name: str, cluster: ClusterSpec, k: int):
    try:
        return make_scheme(name)
    except ValueError:
        params = {
            p: fb(cluster, k)
            for p, fb in PARAM_FALLBACKS.items()
            if p in scheme_params(name)
        }
        return make_scheme(name, **params)


CLUSTERS = {
    "base_g3": lambda: ClusterSpec.make(
        [8, 16, 8], [4.0, 1.0, 0.25], 1.0, [16.0, 8.0, 4.0]
    ),
    "hetero_g6": lambda: ClusterSpec.make(
        [8, 16, 8, 4, 6, 10],
        [4.0, 1.0, 0.25, 2.0, 0.5, 8.0],
        1.0,
        [16.0, 8.0, 4.0, 2.0, 8.0, 32.0],
    ),
    # slow links: comm_aware's transfer shifts exceed the deadline for
    # the worst group -> zero-load exclusion on both paths
    "comm_shifted": lambda: ClusterSpec.make(
        [6, 10, 8], [4.0, 1.0, 0.4], 1.0, [8.0, 2.0, 0.5]
    ),
    # alpha*mu up to 1000: W_{-1}(-e^{-(alpha mu + 1)}) underflows
    # unless evaluated in log space (both paths share lambertwm1_neg_exp)
    "near_deterministic": lambda: ClusterSpec.make(
        [8, 8], [50.0, 1.0], [20.0, 1.0], [16.0, 8.0]
    ),
}


# ------------------------------------------------------------ parity
@pytest.mark.parametrize("cluster_kind", sorted(CLUSTERS))
@pytest.mark.parametrize("name", scheme_names())
def test_fastpath_matches_eager_oracle(name, cluster_kind):
    cluster = CLUSTERS[cluster_kind]()
    scheme = instantiate(name, cluster, K)
    allocate_cache_clear()
    fast = scheme.allocate(cluster, K)
    allocate_cache_clear()
    with allocation.eager_oracle():
        eager = scheme.allocate(cluster, K)
    np.testing.assert_allclose(
        fast.loads, eager.loads, rtol=1e-9, atol=1e-9, err_msg=name
    )
    np.testing.assert_allclose(
        fast.r, eager.r, rtol=1e-9, atol=1e-9, err_msg=name
    )
    np.testing.assert_allclose(fast.n, eager.n, rtol=1e-9, err_msg=name)
    if np.isnan(eager.t_star):
        assert np.isnan(fast.t_star), name
    else:
        np.testing.assert_allclose(
            fast.t_star, eager.t_star, rtol=1e-9, err_msg=name
        )
    # deployment must be bit-identical: the integerized loads decide
    # shapes, and a one-row disagreement would change compiled programs
    assert fast.loads_int.tolist() == eager.loads_int.tolist(), name
    assert fast.n_int == eager.n_int, name


def test_eager_oracle_restores_flag():
    assert allocation.fastpath_enabled()
    with allocation.eager_oracle():
        assert not allocation.fastpath_enabled()
        with allocation.eager_oracle():
            assert not allocation.fastpath_enabled()
        assert not allocation.fastpath_enabled()
    assert allocation.fastpath_enabled()


# ------------------------------------------- eager bisection residuals
def test_eager_bisections_meet_residual_bound():
    """The eager solvers' asserted residual bound holds (and is <= 1e-9).

    Residuals are recomputed here independently of the in-function
    asserts, so a loosened tolerance cannot pass silently.
    """
    assert allocation.BISECT_RESIDUAL_BOUND <= 1e-9
    cluster = CLUSTERS["comm_shifted"]()
    r = cluster.total_workers // 2
    split = allocation.group_code_split(cluster, r, fastpath=False)
    # eq. (26): the per-group split must sum back to r
    assert abs(float(np.sum(split)) - r) < 1e-9 * max(1.0, float(r))
    t = allocation.comm_t_star(cluster, 1.0, 1.0, fastpath=False)
    c, g, _ = allocation.comm_deadline_terms(cluster, 1.0, 1.0)
    # deadline equation: sum_j g_j (t - c_j)_+ = 1
    covered = float(np.sum(g * np.maximum(t - c, 0.0)))
    assert abs(covered - 1.0) < 1e-9


# --------------------------------------------------- memo-cache stats
def test_allocate_memo_cache_counters():
    allocate_cache_clear()
    cluster = CLUSTERS["base_g3"]()
    scheme = make_scheme("optimal")
    info = allocate_cache_info()
    assert (info["hits"], info["misses"]) == (0, 0)
    scheme.allocate(cluster, K)
    scheme.allocate(cluster, K)  # memoized repeat
    info = allocate_cache_info()
    assert (info["hits"], info["misses"]) == (1, 1)
    assert info["size"] >= 1
    # the solver path is part of the key: an oracle solve can never be
    # served a fastpath-computed plan
    with allocation.eager_oracle():
        scheme.allocate(cluster, K)
    assert allocate_cache_info()["misses"] == 2
    allocate_cache_clear()
    info = allocate_cache_info()
    assert (info["size"], info["hits"], info["misses"]) == (0, 0, 0)


# ------------------------------------------------- bucket quantization
def test_quantize_loads_rounds_up_and_keeps_zeros():
    q = quantize_loads_int([0, 1, 7, 8, 9], 4)
    assert q.tolist() == [0, 4, 8, 8, 12]
    assert quantize_loads_int([0, 3], 1).tolist() == [0, 3]


def test_bucket_signature_identity():
    c = CLUSTERS["base_g3"]()
    assert bucket_signature(c, [8, 8, 4], K) == bucket_signature(
        c, np.asarray([8, 8, 4]), K
    )
    assert bucket_signature(c, [8, 8, 4], K) != bucket_signature(
        c, [8, 8, 8], K
    )
    assert bucket_signature(c, [8, 8, 4], K) != bucket_signature(
        c, [8, 8, 4], K + 1
    )


def test_bucket_config_validation():
    with pytest.raises(ValueError, match="quantum"):
        BucketConfig(quantum=0)
    with pytest.raises(ValueError, match="capacity"):
        BucketConfig(capacity=0)
    with pytest.raises(ValueError, match="n_headroom"):
        BucketConfig(n_headroom=0.5)


# ------------------------------------------- bucket-switch replanning
def test_bucket_switch_replan_is_trace_free():
    """Non-structural replans never retrace a compiled consumer.

    A jitted probe (stand-in for the fused serve/train step) consumes
    ``bucket_args()`` as runtime arguments; a mu-drift replan changes
    only array values + the bucket index, so the python trace counter
    stays at 1. A membership change is structural and DOES retrace.
    """
    cluster = ClusterSpec.make([8, 16, 8], [4.0, 1.0, 0.25], 1.0)
    telemetry = Telemetry(None)
    exe = CodedRoundExecutor(
        cluster, K, "optimal",
        bucket_config=BucketConfig(quantum=16), telemetry=telemetry,
    )
    traces = {"n": 0}

    def probe(key, state, index):
        traces["n"] += 1  # python side effect: runs only while tracing
        mask, sel = exe.finish_mask_bucket_jit(key, state, index)
        return jnp.sum(exe.slot_mask_bucket_jit(mask, sel))

    step = jax.jit(probe)
    key = jax.random.PRNGKey(3)
    step(key, *exe.bucket_args()).block_until_ready()
    assert traces["n"] == 1

    # mu drift on the big middle group: same membership, new plan
    g1 = dataclasses.replace(cluster.groups[1], mu=3.0)
    drifted = ClusterSpec(groups=(cluster.groups[0], g1) + cluster.groups[2:])
    exe.replan(drifted)
    assert not exe.last_replan_structural
    step(jax.random.fold_in(key, 1), *exe.bucket_args()).block_until_ready()
    assert traces["n"] == 1, "bucket-switch replan retraced the consumer"

    # replan BACK to the original cluster: same quantized signature
    exe.replan(cluster)
    assert not exe.last_replan_structural
    assert exe.last_bucket_hit
    step(jax.random.fold_in(key, 2), *exe.bucket_args()).block_until_ready()
    assert traces["n"] == 1

    events = [e["event"] for e in telemetry.events
              if e.get("event", "").startswith("plan_bucket")]
    assert "plan_bucket_hit" in events
    assert "plan_bucket_miss" in events

    # structural escape: a worker leaves -> shapes change -> one retrace
    g0 = dataclasses.replace(
        cluster.groups[0], num_workers=cluster.groups[0].num_workers - 1
    )
    exe.replan(ClusterSpec(groups=(g0,) + cluster.groups[1:]))
    assert exe.last_replan_structural
    step(jax.random.fold_in(key, 3), *exe.bucket_args()).block_until_ready()
    assert traces["n"] == 2


def test_bucket_probe_predicts_hit_without_committing():
    cluster = ClusterSpec.make([8, 16, 8], [4.0, 1.0, 0.25], 1.0)
    exe = CodedRoundExecutor(
        cluster, K, "optimal", bucket_config=BucketConfig(quantum=16)
    )
    sigs_before = exe.buckets.signatures
    # the executor's own (quantized) plan is admitted -> probing the
    # plan's cluster is a hit, and probing must not mutate the set
    assert exe.bucket_probe(cluster) is True
    assert exe.buckets.signatures == sigs_before
