"""Per-arch smoke tests: reduced config, one forward/train step on CPU,
shape + finiteness assertions, and prefill<->decode consistency."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import ARCHS, shapes_for
from repro.data.pipeline import make_extras
from repro.models.model import Model, padded_vocab

KEY = jax.random.PRNGKey(0)
B, S = 2, 32


def _batch(c):
    toks = jax.random.randint(KEY, (B, S), 0, c.vocab_size).astype(jnp.int32)
    labels = jax.random.randint(
        jax.random.fold_in(KEY, 1), (B, S), 0, c.vocab_size
    ).astype(jnp.int32)
    batch = {"tokens": toks, "labels": labels}
    extras = make_extras(c, B)
    if extras:
        batch["extras"] = extras
    return batch


@pytest.mark.parametrize("arch", sorted(ARCHS))
def test_forward_and_loss(arch):
    c = ARCHS[arch].reduced()
    m = Model(c)
    params = m.init_params(KEY)
    batch = _batch(c)
    logits = m.lm_logits(params, batch["tokens"], batch.get("extras"))
    assert logits.shape == (B, S, padded_vocab(c.vocab_size))
    assert bool(jnp.all(jnp.isfinite(logits)))
    loss, metrics = jax.jit(m.loss_fn)(params, batch)
    assert bool(jnp.isfinite(loss)) and float(loss) > 0
    assert 0.0 <= float(metrics["accuracy"]) <= 1.0


@pytest.mark.parametrize("arch", sorted(ARCHS))
def test_one_train_step_changes_params_no_nan(arch):
    from repro.optim import AdamWConfig, adamw_init
    from repro.runtime.train_loop import make_train_step

    c = ARCHS[arch].reduced()
    m = Model(c)
    params = m.init_params(KEY)
    opt_cfg = AdamWConfig(lr=1e-3, warmup_steps=0, total_steps=10)
    opt = adamw_init(opt_cfg, params)
    step = make_train_step(m, opt_cfg, donate=False)
    new_params, _, metrics = step(params, opt, _batch(c))
    assert bool(jnp.isfinite(metrics["loss"]))
    leaves_old = jax.tree.leaves(params)
    leaves_new = jax.tree.leaves(new_params)
    assert any(
        not np.allclose(np.asarray(a, np.float32), np.asarray(b, np.float32))
        for a, b in zip(leaves_old, leaves_new)
    )
    assert all(bool(jnp.all(jnp.isfinite(l))) for l in leaves_new)


@pytest.mark.parametrize(
    "arch",
    ["granite-3-2b", "moonshot-v1-16b-a3b", "zamba2-1.2b", "xlstm-125m",
     "whisper-tiny", "h2o-danube-3-4b"],
)
def test_decode_matches_prefill(arch):
    """Teacher-forced prefill logits == step-by-step decode logits.

    MoE capacity dropping depends on the routing pool (B*S tokens in
    prefill vs B in decode), so equality only holds drop-free: raise the
    capacity factor so no token is ever dropped.
    """
    import dataclasses

    c = ARCHS[arch].reduced()
    if c.family == "moe":
        c = dataclasses.replace(c, capacity_factor=float(c.num_experts))
    m = Model(c)
    params = m.init_params(KEY)
    toks = jax.random.randint(KEY, (B, S), 0, c.vocab_size).astype(jnp.int32)
    extras = make_extras(c, B)
    full = m.lm_logits(params, toks, extras)

    cache_extras = None
    if c.family == "audio":
        cache_extras = {"enc_out": m.encode(params, extras["frames"])}
    cache = m.init_cache(B, S, cache_extras)
    step = jax.jit(m.decode_step)
    outs = []
    for pos in range(S):
        logits, cache = step(params, cache, toks[:, pos], jnp.int32(pos))
        outs.append(logits)
    stepped = jnp.stack(outs, axis=1)  # (B, S, V)
    np.testing.assert_allclose(
        np.asarray(stepped, np.float32), np.asarray(full, np.float32),
        rtol=2e-2, atol=2e-2,
    )


def test_shape_cells_count():
    """40-cell grid: 10 archs x 4 shapes minus documented long_500k skips."""
    cells = [(c.name, s.name) for c in ARCHS.values() for s in shapes_for(c)]
    long_archs = {a for a, s in cells if s == "long_500k"}
    assert long_archs == {"zamba2-1.2b", "xlstm-125m", "h2o-danube-3-4b"}
    assert len(cells) == 10 * 3 + 3


def test_vlm_image_prefix_changes_logits():
    c = ARCHS["paligemma-3b"].reduced()
    m = Model(c)
    params = m.init_params(KEY)
    toks = jnp.zeros((1, 8), jnp.int32)
    e0 = {"image_embeds": jnp.zeros((1, c.num_image_tokens, c.d_model))}
    e1 = {"image_embeds": jnp.ones((1, c.num_image_tokens, c.d_model))}
    l0 = m.lm_logits(params, toks, e0)
    l1 = m.lm_logits(params, toks, e1)
    assert not np.allclose(np.asarray(l0), np.asarray(l1))


def test_sliding_window_attention_ignores_far_past():
    """Tokens beyond the window do not affect the current logits.

    Single layer only: with L layers the receptive field is L x window,
    so depth legitimately carries far-past information forward.
    """
    import dataclasses

    c = dataclasses.replace(
        ARCHS["h2o-danube-3-4b"].reduced(), num_layers=1
    )  # window = 64
    assert c.sliding_window == 64
    m = Model(c)
    params = m.init_params(KEY)
    s = 96
    t1 = jax.random.randint(KEY, (1, s), 0, c.vocab_size).astype(jnp.int32)
    t2 = t1.at[:, :16].set((t1[:, :16] + 7) % c.vocab_size)  # differ only <16
    l1 = m.lm_logits(params, t1)
    l2 = m.lm_logits(params, t2)
    # last position attends [s-window, s) = [32, 96): unaffected by 0..16
    np.testing.assert_allclose(
        np.asarray(l1[:, -1], np.float32), np.asarray(l2[:, -1], np.float32),
        rtol=1e-4, atol=1e-4,
    )
