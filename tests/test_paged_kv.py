"""Paged KV serving (DESIGN.md §13): kernel-family parity, dense-oracle
bit-parity, block-pool policy, memory admission control, chunked
prefill, and the no-retrace guarantee across prompt lengths."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import ARCHS
from repro.core import ClusterSpec
from repro.kernels import paged_attention as pa
from repro.models.model import Model
from repro.runtime.control import AdaptiveController
from repro.runtime.executor import CodedRoundExecutor
from repro.runtime.serve_loop import ServeConfig, Server
from repro.serve import BlockPool, Request, SlotScheduler, make_workload

KEY = jax.random.PRNGKey(0)


def _req(rid, arrival=0.0, out_len=4, cls="standard", plen=3):
    return Request(rid=rid, arrival=arrival, prompt=tuple(range(1, plen + 1)),
                   out_len=out_len, deadline_class=cls)


class _Sink:
    """Telemetry stand-in capturing (name, fields) event records."""

    def __init__(self):
        self.events = []

    def event(self, name, **fields):
        self.events.append((name, fields))


def _rand_paged(seed, *, s=3, nb=6, bl=4, kv=2, g=2, hd=8):
    """Random pool + a scattered (non-contiguous) block layout."""
    rng = np.random.default_rng(seed)
    k_pool = rng.standard_normal((nb + 1, bl, kv, hd)).astype(np.float32)
    v_pool = rng.standard_normal((nb + 1, bl, kv, hd)).astype(np.float32)
    q = rng.standard_normal((s, kv, g, hd)).astype(np.float32)
    table = np.full((s, nb), -1, np.int32)
    table[0, :2] = [3, 0]
    table[1, :3] = [1, 4, 2]
    table[2, :1] = [5]
    pos = np.array([5, 9, 2], np.int32)
    return q, k_pool, v_pool, table, pos


# --------------------------------------------- kernel family: ref/ops/pallas
def test_paged_decode_attend_family_parity():
    q, k_pool, v_pool, table, pos = _rand_paged(1)
    want = pa.paged_decode_attend_ref(q, k_pool, v_pool, table, pos)
    got_ops = np.asarray(pa.paged_decode_attend(
        jnp.asarray(q), jnp.asarray(k_pool), jnp.asarray(v_pool),
        jnp.asarray(table), jnp.asarray(pos),
    ))
    np.testing.assert_allclose(got_ops, want, rtol=1e-5, atol=1e-5)
    got_kernel = np.asarray(pa.paged_decode_attend_kernel(
        jnp.asarray(q), jnp.asarray(k_pool), jnp.asarray(v_pool),
        jnp.asarray(table), jnp.asarray(pos), interpret=True,
    ))
    np.testing.assert_allclose(got_kernel, want, rtol=1e-5, atol=1e-5)


def test_paged_chunk_attend_matches_ref():
    rng = np.random.default_rng(2)
    _, k_pool, v_pool, table, _ = _rand_paged(2)
    s, c = table.shape[0], 3
    q = rng.standard_normal((s, c, 2, 2, 8)).astype(np.float32)
    start = np.array([2, 6, 0], np.int32)
    q_pos = start[:, None] + np.arange(c, dtype=np.int32)[None, :]
    want = pa.paged_chunk_attend_ref(q, k_pool, v_pool, table, q_pos)
    got = np.asarray(pa.paged_chunk_attend(
        jnp.asarray(q), jnp.asarray(k_pool), jnp.asarray(v_pool),
        jnp.asarray(table), jnp.asarray(q_pos),
    ))
    np.testing.assert_allclose(got, want, rtol=1e-5, atol=1e-5)


def test_scatter_routes_inactive_rows_to_sink():
    """Frozen/padded rows must write ONLY the sink block — a freed block
    reassigned to another stream can never be corrupted by them."""
    nb, bl, kv, hd = 4, 2, 1, 3
    k_pool = jnp.zeros((nb + 1, bl, kv, hd))
    v_pool = jnp.zeros((nb + 1, bl, kv, hd))
    table = jnp.asarray([[0, 1], [2, 3]], jnp.int32)
    k_new = jnp.ones((2, kv, hd))
    pos = jnp.asarray([1, 3], jnp.int32)
    active = jnp.asarray([True, False])
    k2, _ = pa.scatter_decode(k_pool, v_pool, k_new, k_new, table, pos, active)
    k2 = np.asarray(k2)
    assert np.all(k2[0, 1] == 1.0)  # active slot 0: block 0, offset 1
    assert np.all(k2[1:nb] == 0.0)  # inactive slot 1 touched no real block
    assert np.all(k2[nb, 1] == 1.0)  # its write landed in the sink


# ----------------------------------------- dense-oracle bit parity (decode)
def test_decode_step_paged_bitmatches_dense_slot_oracle():
    """Same history, same tokens: paged decode logits must BIT-match the
    dense slot-cache path (identical einsums / promotion points), so the
    coded head sees identical inputs under either cache layout."""
    c = ARCHS["qwen3-0.6b"].reduced()
    m = Model(c)
    params = m.init_params(KEY)
    slots, s0, steps, bl = 2, 6, 4, 4
    cache_len = s0 + steps + 1
    tokens = jax.random.randint(jax.random.PRNGKey(2), (slots, s0), 0,
                                c.vocab_size).astype(jnp.int32)
    plog, ks, vs = m.prefill(params, tokens, jnp.full((slots,), s0, jnp.int32))

    dense = m.init_slot_cache(slots, cache_len)
    kv = dense["kv"]
    seq = jnp.arange(s0, dtype=jnp.int32)
    dense = {"kv": {
        "k": kv["k"].at[:, :, :s0].set(ks),
        "v": kv["v"].at[:, :, :s0].set(vs),
        "pos": kv["pos"].at[:, :s0].set(jnp.broadcast_to(seq, (slots, s0))),
    }}

    mb = -(-cache_len // bl)
    nb = slots * mb
    paged = m.init_paged_cache(nb, bl)
    table_np = np.full((slots, nb), -1, np.int32)
    for s in range(slots):
        table_np[s, :mb] = np.arange(s * mb, (s + 1) * mb)
    pk = np.array(paged["kv"]["k"])
    pv = np.array(paged["kv"]["v"])
    ks_np, vs_np = np.asarray(ks), np.asarray(vs)
    for s in range(slots):
        for t in range(s0):
            pk[:, table_np[s, t // bl], t % bl] = ks_np[:, s, t]
            pv[:, table_np[s, t // bl], t % bl] = vs_np[:, s, t]
    paged = {"kv": {"k": jnp.asarray(pk), "v": jnp.asarray(pv)}}
    table = jnp.asarray(table_np)
    active = jnp.ones((slots,), bool)

    pos = jnp.full((slots,), s0, jnp.int32)
    dlog = plog_p = plog
    for _ in range(steps):
        tok = jnp.argmax(dlog, -1).astype(jnp.int32)
        dlog, dense = m.decode_step_slots(params, dense, tok, pos)
        plog_p, paged = m.decode_step_paged(params, paged, tok, pos, table,
                                            active)
        assert np.array_equal(np.asarray(dlog), np.asarray(plog_p)), (
            "paged decode logits must bit-match the dense slot oracle"
        )
        pos = pos + 1


def test_chunked_prefill_paged_matches_full_prefill_logits():
    """Prefilling in chunks across rounds reproduces the one-shot
    batched prefill's pending logits (the serve loop's admission path
    for prompts longer than the chunk)."""
    c = ARCHS["qwen3-0.6b"].reduced()
    m = Model(c)
    params = m.init_params(KEY)
    slots, chunk, bl = 2, 4, 4
    plens = [7, 5]
    s0 = max(plens)
    tokens = jax.random.randint(jax.random.PRNGKey(3), (slots, s0), 0,
                                c.vocab_size).astype(jnp.int32)
    want, _, _ = m.prefill(params, tokens,
                           jnp.asarray(plens, jnp.int32))

    mb = -(-(s0 + 1) // bl)
    nb = slots * mb
    cache = m.init_paged_cache(nb, bl)
    table_np = np.full((slots, nb), -1, np.int32)
    for s in range(slots):
        table_np[s, :mb] = np.arange(s * mb, (s + 1) * mb)
    table = jnp.asarray(table_np)

    prefilled = [0] * slots
    final = {}
    while any(prefilled[s] < plens[s] for s in range(slots)):
        takes = [min(chunk, plens[s] - prefilled[s]) for s in range(slots)]
        chunk_tok = np.zeros((slots, chunk), np.int32)
        for s in range(slots):
            if takes[s]:
                chunk_tok[s, :takes[s]] = np.asarray(
                    tokens[s, prefilled[s]:prefilled[s] + takes[s]]
                )
        logits, cache = m.prefill_paged(
            params, cache, jnp.asarray(chunk_tok),
            jnp.asarray(prefilled, jnp.int32),
            jnp.asarray(takes, jnp.int32), table,
        )
        for s in range(slots):
            prefilled[s] += takes[s]
            if takes[s] and prefilled[s] >= plens[s]:
                final[s] = np.asarray(logits[s])
    for s in range(slots):
        np.testing.assert_allclose(final[s], np.asarray(want[s]),
                                   rtol=2e-4, atol=2e-4)


# -------------------------------------------- serve(): paged == dense A/B
@pytest.mark.parametrize("safety,seed", [(1.2, 0), (3.0, 1)])
def test_paged_serve_matches_dense_across_erasure_grid(safety, seed):
    """Same trace, same key, same deadline => same erasure masks: the
    paged path must reproduce the dense run's schedule exactly (token
    counts, finish rounds, round accounting) — any logits divergence
    would change an argmax somewhere and break this."""
    c = ARCHS["qwen3-0.6b"].reduced()
    m = Model(c)
    params = m.init_params(KEY)
    server = Server(m, params, ClusterSpec.make([2, 2], [4.0, 0.8]),
                    ServeConfig(block_rows=64, deadline_safety=safety))
    wl = make_workload("poisson", num_requests=6, prompt_len=(4, 8),
                       out_len=(2, 5), vocab=c.vocab_size)
    trace = wl.trace(seed=seed)
    key = jax.random.PRNGKey(seed)
    rep_d = server.serve(trace, slots=2, decode_block=2, paged=False, key=key)
    rep_p = server.serve(trace, slots=2, decode_block=2, paged=True, key=key)
    assert rep_p.tokens == rep_d.tokens
    assert rep_p.rounds == rep_d.rounds
    assert rep_p.decode_rounds == rep_d.decode_rounds
    assert rep_p.admitted == rep_d.admitted and rep_p.shed == rep_d.shed
    done_d = {f.request.rid: f for f in rep_d.finished if f.outcome == "done"}
    done_p = {f.request.rid: f for f in rep_p.finished if f.outcome == "done"}
    assert done_d.keys() == done_p.keys()
    for rid, f in done_d.items():
        assert done_p[rid].finish_round == f.finish_round
        assert done_p[rid].tokens == f.tokens


# ------------------------------------------------ serve(): retrace pinning
def test_paged_serve_one_trace_across_8x_prompt_spread():
    """Prompt lengths spread 8x within and across traces: ONE compiled
    program total (decode_block=1 => a single steps variant). Shapes
    depend only on (num_blocks, block_len, S) — never a prompt length."""
    c = ARCHS["qwen3-0.6b"].reduced()
    m = Model(c)
    params = m.init_params(KEY)
    server = Server(m, params, ClusterSpec.make([2, 2], [4.0, 0.8]),
                    ServeConfig(block_rows=64))
    plens = [4, 32, 8, 16, 32, 4]
    trace = [_req(i, arrival=2.0 * i, out_len=3, plen=p)
             for i, p in enumerate(plens)]
    bl, nb = 4, 2 * -(-(32 + 3 + 1) // 4)
    kw = dict(slots=2, decode_block=1, paged=True, block_len=bl,
              num_blocks=nb)
    rep = server.serve(trace, **kw)
    assert server.serve_traces == 1
    assert sum(1 for f in rep.finished if f.outcome == "done") == len(plens)
    # a second trace with a different prompt-length mix compiles nothing
    trace2 = [_req(i, arrival=1.5 * i, out_len=3, plen=p)
              for i, p in enumerate([32, 4, 24, 6])]
    server.serve(trace2, prompt_cap=32, **kw)
    assert server.serve_traces == 1


def test_long_prompt_admits_via_chunked_prefill_where_dense_raises():
    c = ARCHS["qwen3-0.6b"].reduced()
    m = Model(c)
    params = m.init_params(KEY)
    server = Server(m, params, ClusterSpec.make([2, 2], [4.0, 0.8]),
                    ServeConfig(block_rows=64))
    long_req = _req(0, out_len=3, plen=37)
    trace = [long_req, _req(1, arrival=1.0, out_len=3, plen=5)]
    with pytest.raises(ValueError, match="exceed prompt_cap"):
        server.serve(trace, slots=2, prompt_cap=8, paged=False)
    rep = server.serve(trace, slots=2, prompt_cap=8, paged=True,
                       decode_block=2)
    done = {f.request.rid: f for f in rep.finished if f.outcome == "done"}
    assert set(done) == {0, 1}
    assert done[0].tokens == 3
    assert rep.prefill_rounds >= -(-37 // 8)  # one round per chunk


# ----------------------------------------------------- BlockPool + policy
def test_block_pool_lifo_reuse():
    with pytest.raises(ValueError, match="num_blocks"):
        BlockPool(0, 4)
    with pytest.raises(ValueError, match="block_len"):
        BlockPool(4, 0)
    pool = BlockPool(6, 4)
    assert pool.blocks_for(1) == 1 and pool.blocks_for(9) == 3
    a = pool.alloc(2)
    b = pool.alloc(2)
    assert a == [0, 1] and b == [2, 3]
    assert pool.alloc(3) is None  # only 2 free; pool state untouched
    assert pool.free_blocks == 2
    pool.free(a)
    assert pool.alloc(3) == [1, 0, 4]  # most recently freed reused first
    assert pool.blocks_freed == 2


def test_scheduler_reuses_freed_blocks_after_retirement():
    pool = BlockPool(2, 4)
    sched = SlotScheduler(1, pool=pool)
    assert sched.offer(_req(0, plen=3, out_len=4, cls="batch"), 0.0)
    assert sched.offer(_req(1, plen=3, out_len=4, cls="batch"), 0.0)
    (si, _), = sched.fill_slots(0.0)
    first = sched.slots[si].blocks
    assert first == (0, 1)
    assert sched.fill_slots(0.0) == []  # pool empty: head waits, no shed
    sched.advance(4)
    sched.retire_done(4.0)
    assert pool.free_blocks == 2 and pool.blocks_freed == 2
    (si2, r2), = sched.fill_slots(4.0)
    assert r2.rid == 1
    assert set(sched.slots[si2].blocks) == {0, 1}  # LIFO reuse of the frees


def test_pool_exhaustion_sheds_only_never_fitting_requests():
    sink = _Sink()
    pool = BlockPool(2, 4, telemetry=sink)
    sched = SlotScheduler(2, pool=pool, telemetry=sink)
    big = _req(0, plen=20, out_len=20, cls="batch")  # 11 blocks > 2: never
    assert not sched.offer(big, 0.0)
    shed = [f for f in sched.finished if f.outcome == "shed"]
    assert [f.reason for f in shed] == ["pool_exhausted"]
    evicted = [f for n, f in sink.events if n == "request_evicted"]
    assert evicted[0]["reason"] == "pool_exhausted"
    # a request that fits an EMPTY pool is never shed on memory, even
    # when the pool is currently full — it waits at the queue head
    assert sched.offer(_req(1, plen=3, out_len=4, cls="batch"), 0.0)
    sched.fill_slots(0.0)
    assert sched.offer(_req(2, plen=3, out_len=4, cls="batch"), 0.0)
    assert sched.fill_slots(0.0) == []
    assert all(f.reason != "pool_exhausted"
               for f in sched.finished[len(shed):])


def test_block_pool_telemetry_schema():
    sink = _Sink()
    pool = BlockPool(4, 2, bytes_per_block=128, telemetry=sink)
    got = pool.alloc(3, rid=7, now=2.0)
    assert [n for n, _ in sink.events] == ["blocks_in_use", "kv_bytes"]
    use = sink.events[0][1]
    assert use == {"in_use": 3, "free": 1, "capacity": 4,
                   "request_id": 7, "round": 2.0}
    kvb = sink.events[1][1]
    assert kvb == {"bytes_in_use": 384, "bytes_total": 512,
                   "utilization": 0.75, "request_id": 7, "round": 2.0}
    pool.free(got[:2], rid=7, now=3.0)
    assert [n for n, _ in sink.events[2:]] == [
        "blocks_freed", "blocks_in_use", "kv_bytes"
    ]
    freed = sink.events[2][1]
    assert freed == {"blocks": 2, "total_freed": 2,
                     "request_id": 7, "round": 3.0}
    # declared-contract coverage (repro.obs.schema) on every record
    from repro.obs.schema import validate_event

    for name, fields in sink.events:
        validate_event({"event": name, **fields})


# ------------------------------------------------- controller-chosen slots
def test_recommend_slots_scales_with_measured_latency():
    exe = CodedRoundExecutor(
        ClusterSpec.make([8, 16, 8], [4.0, 1.0, 0.25]), 1_000, "optimal"
    )
    ctl = AdaptiveController(exe)
    cur = ctl.coverage_latency()
    assert np.isfinite(cur) and cur > 0
    assert ctl.recommend_slots(base=4) == 4  # no drift: estimates == plan
    assert ctl.recommend_slots(base=4, reference=2 * cur) == 8
    assert ctl.recommend_slots(base=4, reference=cur / 2) == 2
    assert ctl.recommend_slots(base=4, reference=100 * cur) == 16  # hi=4*base
    assert ctl.recommend_slots(base=4, reference=cur / 100) == 1  # lo
    assert ctl.recommend_slots(base=4, reference=float("inf")) == 4  # fallback
    with pytest.raises(ValueError, match="base"):
        ctl.recommend_slots(base=0)
