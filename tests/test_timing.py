"""Measured-reality control loop: RoundClock + timing-path bugfixes (§12).

The clock tests time REAL jitted dispatches, but every assertion is on
structure the decomposition guarantees deterministically (calibration
identity, per-round common scale, pad attribution, skip bookkeeping) —
never on absolute wall-clock values, so nothing here is load-sensitive.
The two acceptance replays (stationary fleet holds, sleep-padded group
replans) mirror ``test_adaptive.py``'s simulated closed-loop tests on
the measured path; their dispatches carry a duration floor (see
``_dispatch``) so co-tenant scheduling jitter stays a small relative
wobble, as it is for real model-step dispatches.
"""
import copy
import dataclasses
import json
import time

import jax
import numpy as np
import pytest

from repro.core.runtime_model import ClusterSpec
from repro.core.schemes import make_scheme
from repro.runtime.control import AdaptConfig, AdaptiveController
from repro.runtime.executor import CodedRoundExecutor
from repro.runtime.fault_tolerance import StragglerTracker
from repro.runtime.telemetry import Telemetry
from repro.runtime.timing import RoundClock, RoundTiming

KEY = jax.random.PRNGKey(23)
BASE = ClusterSpec.make([8, 16, 8], [4.0, 1.0, 0.25], 1.0, [16.0, 8.0, 4.0])
K = 1_000


def _dispatch(exe, key, floor_s=0.0):
    """A real jitted dispatch; ``floor_s`` pads it to a realistic round
    duration. The sampler alone runs in ~100us, so under co-tenant load
    (parallel pytest shards, CI neighbors) scheduling jitter would
    dominate ``dispatch_s`` and the per-round scale would be mostly
    noise — real dispatches are model steps, many ms long, where the
    same absolute jitter is a small relative wobble. The closed-loop
    acceptance tests use the floor; the structural tests don't care."""

    def dispatch():
        if floor_s:
            time.sleep(floor_s)
        return exe.round_times_jit(key)

    return dispatch


# ------------------------------------------------------------ RoundClock
def test_clock_warmup_then_calibration_identity():
    """The first fed round pins unit_s and decomposes to EXACTLY the
    virtual draw (scale 1.0): measured and simulated observation streams
    coincide on the calibration round by construction."""
    exe = CodedRoundExecutor(BASE, K, "optimal")
    clock = RoundClock(exe, warmup=1)
    k0, k1 = jax.random.fold_in(KEY, 0), jax.random.fold_in(KEY, 1)

    t0 = clock.measure(_dispatch(exe, k0), key=k0)
    assert t0.skipped == "warmup" and t0.times is None
    assert clock.unit_s is None and clock.fed == 0
    assert t0.dispatch_s > 0 and t0.wall_s >= t0.dispatch_s

    t1 = clock.measure(_dispatch(exe, k1), key=k1)
    v, _ = exe.round_observation(k1)
    assert t1.skipped is None and clock.fed == 1
    assert t1.scale == pytest.approx(1.0)
    np.testing.assert_allclose(t1.times, v, rtol=1e-12)
    assert clock.unit_s is not None and clock.unit_s > 0


def test_clock_later_rounds_share_one_common_scale():
    """Every post-calibration round is the virtual draw times ONE scalar
    (the round's wall-clock factor) — per-group ratios are exact, which
    is why stationary fleets can never replan spuriously."""
    exe = CodedRoundExecutor(BASE, K, "optimal")
    clock = RoundClock(exe, warmup=1)
    for i in range(2):  # warmup + calibration
        k = jax.random.fold_in(KEY, i)
        clock.measure(_dispatch(exe, k), key=k)
    k = jax.random.fold_in(KEY, 2)
    t = clock.measure(_dispatch(exe, k), key=k)
    v, _ = exe.round_observation(k)
    assert np.isfinite(t.scale) and t.scale > 0
    np.testing.assert_allclose(t.times, v * t.scale, rtol=1e-12)


def test_clock_discard_next_and_outlier_guard():
    exe = CodedRoundExecutor(BASE, K, "optimal")
    clock = RoundClock(exe, warmup=1, outlier_factor=5.0)
    for i in range(3):
        k = jax.random.fold_in(KEY, i)
        clock.measure(_dispatch(exe, k), key=k)
    unit_before, fed_before = clock.unit_s, clock.fed

    # a consumer-flagged recompile round is measured but not fed
    clock.discard_next("recompile")
    k = jax.random.fold_in(KEY, 3)
    t = clock.measure(_dispatch(exe, k), key=k)
    assert t.skipped == "recompile" and t.times is None
    assert clock.fed == fed_before

    # a dispatch way past the smoothed EMA is dropped automatically
    # (sleep INSIDE the dispatch window = a GC-pause stand-in)
    stall = max(clock.outlier_factor * clock._smoothed * 3, 0.02)

    def stalled():
        time.sleep(stall)
        return exe.round_times_jit(k)

    t = clock.measure(stalled, key=k)
    assert t.skipped == "outlier" and t.times is None
    assert clock.unit_s == unit_before  # neither skip recalibrates

    # and the next normal round feeds again
    t = clock.measure(_dispatch(exe, k), key=k)
    assert t.skipped is None


def test_clock_pad_is_slept_and_attributed_per_worker():
    """pad_s really sleeps (measured in wall_s) and each padded worker
    is attributed its proportional share of the MEASURED sleep, in
    calibrated units on top of its decomposed time."""
    exe = CodedRoundExecutor(BASE, K, "optimal")
    clock = RoundClock(exe, warmup=1)
    for i in range(2):
        k = jax.random.fold_in(KEY, i)
        clock.measure(_dispatch(exe, k), key=k)
    w = BASE.total_workers
    pad = np.zeros(w)
    pad[-8:] = 0.02  # slow the last group only
    clock.pad_s = pad
    k = jax.random.fold_in(KEY, 5)
    t = clock.measure(_dispatch(exe, k), key=k)
    assert t.pad_wall_s >= 0.02
    assert t.wall_s >= t.dispatch_s + t.pad_wall_s - 1e-6
    v, _ = exe.round_observation(k)
    expected = v * t.scale + (pad / pad.max()) * t.pad_wall_s / clock.unit_s
    np.testing.assert_allclose(t.times, expected, rtol=1e-9)
    # unpadded workers: pure decomposition; padded: strictly slower
    np.testing.assert_allclose(t.times[:-8], (v * t.scale)[:-8], rtol=1e-12)
    assert (t.times[-8:] > (v * t.scale)[-8:]).all()


def test_clock_true_cluster_leavers_decompose_to_inf():
    exe = CodedRoundExecutor(BASE, K, "optimal")
    clock = RoundClock(exe, warmup=0)
    groups = list(BASE.groups)
    groups[1] = dataclasses.replace(groups[1], num_workers=14)
    shrunk = ClusterSpec(tuple(groups))
    k = jax.random.fold_in(KEY, 9)
    t = clock.measure(_dispatch(exe, k), key=k, true_cluster=shrunk)
    assert int(np.isinf(t.times).sum()) == 2  # 16 -> 14 in group 1
    assert t.membership == (8, 14, 8)


def test_clock_emits_round_timing_events():
    exe = CodedRoundExecutor(BASE, K, "optimal")
    with Telemetry(None) as tel:
        clock = RoundClock(exe, telemetry=tel, warmup=1)
        for i in range(3):
            k = jax.random.fold_in(KEY, i)
            clock.measure(_dispatch(exe, k), key=k)
    recs = [e for e in tel.events if e["event"] == "round_timing"]
    assert len(recs) == 3
    assert [r["fed"] for r in recs] == [False, True, True]
    assert recs[0]["skipped"] == "warmup" and recs[0]["t_max"] is None
    for r in recs[1:]:
        assert r["skipped"] is None
        assert r["unit_s"] > 0 and r["t_max"] >= r["t_mean"] > 0
        assert r["workers"] == BASE.total_workers
    # JSONL-serializable as-is (the sink json.dumps's every record)
    for r in recs:
        json.dumps(r)


def test_clock_validates_knobs():
    exe = CodedRoundExecutor(BASE, K, "optimal")
    with pytest.raises(ValueError, match="warmup"):
        RoundClock(exe, warmup=-1)
    with pytest.raises(ValueError, match="outlier_factor"):
        RoundClock(exe, outlier_factor=1.0)
    with pytest.raises(ValueError, match="smooth"):
        RoundClock(exe, smooth=1.0)


# -------------------------------------------- controller ingest bugfixes
def test_observe_round_clamps_nonpositive_times_without_transfer():
    """Satellite regression: the >=1e-9 clamp used to live INSIDE the
    transfer_times branch, so measured wall-clock jitter going
    non-positive on the plain path reached the MLE raw (negative alpha
    estimates, garbage mu)."""
    exe = CodedRoundExecutor(BASE, K, "optimal")
    ctl = AdaptiveController(exe, AdaptConfig(every=1))
    times = np.array(exe.sample_round_times(KEY))
    times[:8] = -0.5  # clock jitter gone negative
    times[8] = 0.0
    d = ctl.observe_round(times)
    assert d is not None
    assert (ctl.tracker.alpha_estimates >= 0).all()
    assert np.isfinite(ctl.tracker.mu_estimates).all()
    assert (ctl.tracker.mu_estimates > 0).all()


def test_observe_round_clamps_comm_overshoot():
    """Overshooting bandwidth estimates: transfer + download subtraction
    exceeds the observed round time — the single ingest-point clamp
    keeps the compute-time residual positive."""
    sch = make_scheme("comm_aware", upload=2.0, download=1.0)
    exe = CodedRoundExecutor(BASE, K, sch)
    ctl = AdaptiveController(exe, AdaptConfig(every=1))
    times, shifts = exe.round_observation(jax.random.fold_in(KEY, 3))
    overshoot = np.where(np.isfinite(shifts), shifts + 2.0 * times, shifts)
    d = ctl.observe_round(times, transfer_times=overshoot, payload=2.0)
    assert d is not None
    assert (ctl.tracker.alpha_estimates >= 0).all()
    assert (ctl.tracker.mu_estimates > 0).all()
    assert np.isfinite(ctl.coverage_latency())


def test_tracker_defends_direct_nonpositive_times():
    tracker = StragglerTracker(BASE)
    loads = CodedRoundExecutor(BASE, K, "optimal").plan.loads_per_worker
    tracker.observe_round(
        np.full(BASE.total_workers, -1.0), np.asarray(loads), K
    )
    assert (tracker.alpha_estimates >= 0).all()
    assert (tracker.mu_estimates > 0).all()


def test_observe_timing_skipped_rounds_are_noops():
    exe = CodedRoundExecutor(BASE, K, "optimal")
    ctl = AdaptiveController(exe, AdaptConfig(every=1))
    skipped = RoundTiming(
        round=1, result=None, wall_s=0.1, dispatch_s=0.1, pad_wall_s=0.0,
        scale=float("nan"), times=None, transfer_times=None, payload=1.0,
        membership=None, skipped="warmup",
    )
    assert ctl.observe_timing(skipped) is None
    assert ctl.observe_timing(None) is None
    assert ctl.round == 0 and ctl.decisions == []


# ------------------------------------------------- Telemetry.log bugfix
def test_telemetry_log_uses_explicit_none_checks():
    """Satellite regression: truthiness dropped tokens_per_s when
    tokens_per_step == 0 (a real rate of 0.0) and divided-by-zero risk
    hid behind `if self.step_time` (0.0 falsy)."""
    with Telemetry(None) as tel:
        rec = tel.log(1, {}, tokens_per_step=128)
        assert "tokens_per_s" not in rec  # genuinely no timing yet
        tel.step_time = 0.5
        rec = tel.log(2, {}, tokens_per_step=0)
        assert rec["tokens_per_s"] == 0.0
        tel.step_time = 0.0
        rec = tel.log(3, {}, tokens_per_step=64)
        assert rec["tokens_per_s"] == float("inf")
        rec = tel.log(4, {"loss": 1.0})
        assert "tokens_per_s" not in rec  # no tokens_per_step given


# ------------------------------------- measured-vs-simulated acceptance
def test_measured_stationary_fleet_zero_spurious_replans():
    """ISSUE acceptance: wall-clock observations on a stationary fleet
    never replan — per-round decomposition applies one common factor to
    every worker, and the decision rule is scale-invariant."""
    exe = CodedRoundExecutor(BASE, K, "optimal")
    ctl = AdaptiveController(exe, AdaptConfig(every=5, threshold=0.05))
    clock = RoundClock(exe, warmup=1)
    for t in range(41):
        k = jax.random.fold_in(KEY, 100 + t)
        ctl.observe_timing(
            clock.measure(_dispatch(exe, k, floor_s=0.02), key=k)
        )
    assert clock.fed == 40 and ctl.round == 40
    assert ctl.replans == 0, [d for d in ctl.decisions if d.replanned]
    assert len(ctl.decisions) == 8
    assert all(d.reason == "hold" for d in ctl.decisions)


def test_measured_sleep_padded_group_replans_within_two_cadences():
    """ISSUE acceptance: a sleep-padded worker group — a REAL wall-clock
    slowdown, invisible to the simulated path — triggers a replan within
    two cadences of the injection, and the new plan sheds load off the
    padded group."""
    exe = CodedRoundExecutor(BASE, K, "optimal")
    old_loads = np.asarray(exe.plan.allocation.loads).copy()
    ctl = AdaptiveController(exe, AdaptConfig(every=5, threshold=0.05))
    clock = RoundClock(exe, warmup=1)
    inject_at = 10  # fed-round index of the injection
    for t in range(31):
        if clock.fed == inject_at and clock.pad_s is None:
            # group 0 (the fast one) starts stalling: pad it by several
            # calibrated units, far beyond the planned round latency
            pad = np.zeros(BASE.total_workers)
            pad[:8] = 4.0 * clock.unit_s * float(exe.deadline)
            clock.pad_s = pad
        k = jax.random.fold_in(KEY, 500 + t)
        ctl.observe_timing(
            clock.measure(_dispatch(exe, k, floor_s=0.02), key=k)
        )
    replans = [d for d in ctl.decisions if d.replanned]
    assert replans, "sleep-padded group never triggered a replan"
    # injection lands at fed round 10; cadence 5 => rounds 15/20 are the
    # first two post-injection decisions
    assert inject_at < replans[0].round <= inject_at + 2 * 5
    new_loads = np.asarray(ctl.plan.allocation.loads)
    assert new_loads[0] < old_loads[0]


def test_trainer_measured_times_static_fleet_holds():
    """End to end: Trainer --measure-times on a stationary fleet — every
    round is timed and fed, zero replans, zero extra retraces, and the
    round_timing stream lands in telemetry."""
    from repro.configs import ARCHS
    from repro.configs.base import ShapeConfig
    from repro.data import SyntheticLMData
    from repro.models.model import Model
    from repro.optim import AdamWConfig
    from repro.runtime.train_loop import TrainConfig, Trainer

    c = ARCHS["qwen3-0.6b"].reduced()
    m = Model(c)
    data = SyntheticLMData(c, ShapeConfig("t", 16, 4, "train"), seed=1)
    cluster = ClusterSpec.make([8, 8], [4.0, 0.5])
    cfg = TrainConfig(
        steps=10, log_every=5, cluster=cluster, scheme="grad_coding",
        adapt_every=2, adapt_threshold=0.1, measure_times=True,
    )
    t = Trainer(m, data, AdamWConfig(lr=1e-3, warmup_steps=0,
                                     total_steps=10), cfg)
    assert t.clock is not None
    _, _, history = t.run()
    assert all(np.isfinite(h["loss"]) for h in history)
    assert t.clock.rounds == 10 and t.clock.fed == 9  # 1 warmup
    assert t.controller.round == 9
    assert t.controller.replans == 0
    assert all(d.reason == "hold" for d in t.controller.decisions)
    assert t.traces == 1  # stationary: the step never recompiled
    recs = [e for e in t.telemetry.events if e["event"] == "round_timing"]
    assert len(recs) == 10 and sum(r["fed"] for r in recs) == 9


def test_trainer_measure_times_requires_cluster():
    from repro.configs import ARCHS
    from repro.configs.base import ShapeConfig
    from repro.data import SyntheticLMData
    from repro.models.model import Model
    from repro.optim import AdamWConfig
    from repro.runtime.train_loop import TrainConfig, Trainer

    c = ARCHS["qwen3-0.6b"].reduced()
    data = SyntheticLMData(c, ShapeConfig("t", 16, 4, "train"), seed=1)
    with pytest.raises(ValueError, match="measure_times"):
        Trainer(Model(c), data,
                AdamWConfig(lr=1e-3, warmup_steps=0, total_steps=5),
                TrainConfig(steps=5, measure_times=True))


# ------------------------------------------------------------ CLI smokes
@pytest.mark.slow
def test_train_cli_measure_times_smoke(capsys):
    from repro.launch import train as train_cli

    train_cli.main([
        "--arch", "qwen3-0.6b", "--reduced", "--steps", "6",
        "--batch", "4", "--seq-len", "16",
        "--hetero-groups", "2:2.0,2:0.5", "--scheme", "grad_coding",
        "--adapt-every", "2", "--measure-times",
    ])
    out = capsys.readouterr().out
    assert "measured:" in out and "rounds fed" in out


@pytest.mark.slow
def test_serve_cli_measure_times_smoke(tmp_path, capsys):
    from repro.launch import serve as serve_cli

    tel_path = str(tmp_path / "serve_tel.jsonl")
    serve_cli.main([
        "--arch", "qwen3-0.6b", "--reduced", "--coded", "--batch", "2",
        "--prompt-len", "8", "--max-new", "4", "--scenario", "mu_step",
        "--adapt-every", "2", "--rounds", "6", "--measure-times",
        "--telemetry", tel_path,
    ])
    out = capsys.readouterr().out
    assert "measured:" in out
    events = [json.loads(line) for line in open(tel_path)]
    names = {e.get("event") for e in events}
    assert "round_timing" in names and "adapt_decision" in names


def test_cli_measure_times_flag_validation():
    from repro.launch import serve as serve_cli
    from repro.launch import train as train_cli

    with pytest.raises(SystemExit, match="--measure-times"):
        train_cli.main(["--arch", "qwen3-0.6b", "--reduced",
                        "--measure-times"])
    with pytest.raises(SystemExit, match="--measure-times"):
        serve_cli.main(["--arch", "qwen3-0.6b", "--reduced",
                        "--measure-times"])


# -------------------------------------------------------- perf gate logic
def _gate_golden():
    return {
        "speedup_tokens_per_s": 4.0,
        "decode_latency_s": {"speedup": 20.0, "jit": 1e-4, "numpy": 2e-3},
        "jit": {"tokens_per_s": 1000.0, "generate_s": 0.1},
        "phases": {"prefill_per_decode_token": 2.5,
                   "erasure_share_of_decode": 0.1},
        "paged": {"tokens_per_s_ratio": 1.5},
    }


def test_perf_gate_bands_and_absolute_enforcement(tmp_path, monkeypatch):
    import benchmarks.common as bench_common
    from benchmarks import perf_gate

    monkeypatch.setattr(bench_common, "ARTIFACTS", str(tmp_path))
    with pytest.raises(SystemExit, match="no golden"):
        perf_gate.run(runs=1)

    golden = _gate_golden()
    (tmp_path / "serve_throughput.json").write_text(json.dumps(golden))

    # parity passes
    monkeypatch.setattr(perf_gate, "_measure",
                        lambda runs: copy.deepcopy(golden))
    rec = perf_gate.run(runs=1)
    assert rec["passed"] and all(m["passed"] for m in rec["metrics"])
    # ...and the record + perf_gate events landed in the artifact
    saved = json.loads((tmp_path / "perf_gate.json").read_text())
    assert saved["passed"]
    assert {e["event"] for e in saved["events"]} == {"perf_gate"}
    assert len(saved["events"]) == len(saved["metrics"]) == 7

    # a 19% ratio regression sits inside the 20% band; 25% fails the CI
    inside = copy.deepcopy(golden)
    inside["speedup_tokens_per_s"] = 4.0 * 0.81
    monkeypatch.setattr(perf_gate, "_measure", lambda runs: inside)
    assert perf_gate.run(runs=1)["passed"]

    beyond = copy.deepcopy(golden)
    beyond["decode_latency_s"]["speedup"] = 20.0 * 0.75
    monkeypatch.setattr(perf_gate, "_measure", lambda runs: beyond)
    with pytest.raises(SystemExit, match="perf gate FAILED"):
        perf_gate.run(runs=1)

    # per-phase rows are lower-is-better: a prefill blow-up (e.g. the
    # batched splice regressing to the sequential scan) fails on its own
    # even though every end-to-end ratio is untouched
    phase_reg = copy.deepcopy(golden)
    phase_reg["phases"]["prefill_per_decode_token"] = 2.5 * 1.25
    monkeypatch.setattr(perf_gate, "_measure", lambda runs: phase_reg)
    with pytest.raises(SystemExit, match="prefill_per_decode_token"):
        perf_gate.run(runs=1)
    # ...and the paged/dense tokens-per-s ratio gates higher-is-better
    paged_reg = copy.deepcopy(golden)
    paged_reg["paged"]["tokens_per_s_ratio"] = 1.5 * 0.75
    monkeypatch.setattr(perf_gate, "_measure", lambda runs: paged_reg)
    with pytest.raises(SystemExit, match="paged_over_dense_tokens_per_s"):
        perf_gate.run(runs=1)
    assert not json.loads(
        (tmp_path / "perf_gate.json").read_text()
    )["passed"]

    # absolute metrics: warn-only by default, enforced with --absolute;
    # decode latency is lower-is-better (a SLOWER decode fails)
    abs_reg = copy.deepcopy(golden)
    abs_reg["jit"]["tokens_per_s"] = 100.0
    abs_reg["decode_latency_s"]["jit"] = 1e-2
    monkeypatch.setattr(perf_gate, "_measure", lambda runs: abs_reg)
    rec = perf_gate.run(runs=1)  # ratios intact: passes
    rows = {m["metric"]: m for m in rec["metrics"]}
    assert not rows["jit_tokens_per_s"]["passed"]
    assert not rows["jit_tokens_per_s"]["enforced"]
    assert not rows["jit_decode_latency_s"]["passed"]
    with pytest.raises(SystemExit, match="perf gate FAILED"):
        perf_gate.run(runs=1, absolute=True)


@pytest.mark.slow
def test_perf_gate_end_to_end_self_measurement(tmp_path, monkeypatch):
    """Real measurement path: baseline with --update-golden, then gate a
    fresh run against it — same machine, same process, must pass; the
    measurement must NOT clobber the golden it is judged against."""
    import benchmarks.common as bench_common
    from benchmarks import perf_gate

    monkeypatch.setattr(bench_common, "ARTIFACTS", str(tmp_path))
    base = perf_gate.run(runs=1, update_golden=True)
    golden_on_disk = json.loads(
        (tmp_path / "serve_throughput.json").read_text()
    )
    rec = perf_gate.run(runs=1, tolerance=0.5)  # generous: shared CPU
    assert rec["passed"]
    after = json.loads((tmp_path / "serve_throughput.json").read_text())
    assert after == golden_on_disk  # gate never rewrites its golden
    assert base["speedup_tokens_per_s"] > 1.0
