"""Jit-native decode pipeline: oracle parity, fused master step, and the
single-compiled-program guarantee of the serving loop."""
import functools

import jax
import jax.numpy as jnp
import numpy as np
import pytest
from jax.sharding import Mesh

from repro.configs import ARCHS
from repro.core import ClusterSpec, plan_deployment
from repro.core.coded_matvec import (
    DecodePipeline,
    end_to_end_coded_matvec,
    masked_decode,
    pack_coded_matrix,
)
from repro.core.coding import (
    decode_systematic,
    decode_systematic_jit,
    encode,
    make_generator,
)
from repro.models.model import Model
from repro.runtime.serve_loop import CodedLMHead, ServeConfig, Server

KEY = jax.random.PRNGKey(0)


# ------------------------------------------------- decode_systematic_jit
@pytest.mark.parametrize("erasures", [0, 3, 8, 16])  # 16 = exactly threshold
@pytest.mark.parametrize("cols", [None, 5])
def test_decode_jit_matches_numpy_oracle(erasures, cols):
    """Fixed-shape jit decode == numpy oracle across the erasure grid."""
    k, n = 32, 48
    g = make_generator(n, k, KEY)
    shape = (k,) if cols is None else (k, cols)
    x = np.asarray(jax.random.normal(jax.random.PRNGKey(2), shape))
    y = np.asarray(encode(g, jnp.asarray(x)))
    rng = np.random.default_rng(erasures)
    mask = np.ones(n, bool)
    mask[rng.choice(n, size=erasures, replace=False)] = False
    z_jit, ok_jit = decode_systematic_jit(g, jnp.asarray(y), jnp.asarray(mask))
    z_np, ok_np = decode_systematic(g, y, mask, k)
    assert bool(ok_jit) and ok_np
    np.testing.assert_allclose(np.asarray(z_jit), z_np, rtol=1e-4, atol=1e-4)
    np.testing.assert_allclose(np.asarray(z_jit), x, rtol=1e-4, atol=1e-4)


def test_decode_jit_insufficient_survivors():
    """< k survivors: ok=False and a zeroed (not garbage) output."""
    k, n = 16, 24
    g = make_generator(n, k, KEY)
    y = np.asarray(encode(g, np.ones((k,), np.float32)))
    mask = np.zeros(n, bool)
    mask[: k - 1] = True
    z, ok = decode_systematic_jit(g, jnp.asarray(y), jnp.asarray(mask))
    assert not bool(ok)
    np.testing.assert_array_equal(np.asarray(z), np.zeros(k, np.float32))
    _, ok_np = decode_systematic(g, y, mask, k)
    assert not ok_np


def test_decode_jit_is_traceable_fixed_shape():
    """The decode survives jit with mask as a traced argument."""
    k, n = 8, 12
    g = make_generator(n, k, KEY)
    x = np.asarray(jax.random.normal(jax.random.PRNGKey(3), (k,)))
    y = encode(g, jnp.asarray(x))
    f = jax.jit(lambda m: decode_systematic_jit(g, y, m))
    mask = np.ones(n, bool)
    mask[[0, 5]] = False
    z, ok = f(jnp.asarray(mask))
    assert bool(ok)
    np.testing.assert_allclose(np.asarray(z), x, rtol=1e-4, atol=1e-4)


# ------------------------------------------------------ fused master step
def _one_device_mesh():
    return Mesh(np.array(jax.devices()[:1]).reshape(1), ("workers",))


def test_fused_pipeline_matches_host_decode():
    """DecodePipeline (device decode) == legacy host numpy decode."""
    mesh = _one_device_mesh()
    cluster = ClusterSpec.make([4, 4], [4.0, 1.0], 1.0)
    plan = plan_deployment(cluster, k=64)
    a = jax.random.normal(KEY, (64, 32))
    x = jax.random.normal(jax.random.PRNGKey(1), (32,))
    fin = np.ones(plan.num_workers, bool)
    fin[[plan.num_workers - 1]] = False
    z_jit, ok_jit = end_to_end_coded_matvec(mesh, a, x, plan,
                                            finished_workers=fin)
    z_host, ok_host = end_to_end_coded_matvec(mesh, a, x, plan,
                                              finished_workers=fin,
                                              jit_decode=False)
    assert bool(ok_jit) and ok_host
    np.testing.assert_allclose(np.asarray(z_jit), z_host, rtol=1e-3, atol=1e-4)
    np.testing.assert_allclose(np.asarray(z_jit), np.asarray(a @ x),
                               rtol=2e-2, atol=2e-3)


def test_fused_pipeline_insufficient_flag():
    mesh = _one_device_mesh()
    cluster = ClusterSpec.make([4], [2.0], 1.0)
    plan = plan_deployment(cluster, k=64)
    a = jax.random.normal(KEY, (64, 16))
    x = jax.random.normal(jax.random.PRNGKey(1), (16,))
    fin = np.zeros(plan.num_workers, bool)
    _, ok = end_to_end_coded_matvec(mesh, a, x, plan, finished_workers=fin)
    assert not bool(ok)


def test_decode_pipeline_kernel_route():
    """use_kernel=True (Pallas interpret) matches the einsum route."""
    mesh = _one_device_mesh()
    cluster = ClusterSpec.make([3, 3], [4.0, 1.0], 1.0)
    plan = plan_deployment(cluster, k=48)
    gen = make_generator(plan.n, plan.k, KEY)
    a = jax.random.normal(KEY, (48, 16))
    x = jax.random.normal(jax.random.PRNGKey(1), (16,))
    packed, row_of = pack_coded_matrix(gen, a, plan)
    fin = jnp.ones(plan.num_workers, bool)
    ref = DecodePipeline(mesh, gen, row_of)
    ker = DecodePipeline(mesh, gen, row_of, use_kernel=True)
    z_ref, ok_ref = ref(jnp.asarray(packed), x, fin)
    z_ker, ok_ker = ker(jnp.asarray(packed), x, fin)
    assert bool(ok_ref) and bool(ok_ker)
    np.testing.assert_allclose(np.asarray(z_ker), np.asarray(z_ref),
                               rtol=1e-4, atol=1e-4)


def test_masked_decode_drops_pad_and_dead_slots():
    """Pad slots (-1) and straggler rows never reach the solve."""
    k, n = 8, 12
    g = make_generator(n, k, KEY)
    x = np.asarray(jax.random.normal(jax.random.PRNGKey(4), (k,)))
    y = np.asarray(encode(g, jnp.asarray(x)))
    # 3 workers x 5 slots, ragged loads (4, 4, 4) + pads
    row_of = np.full((3, 5), -1, np.int32)
    partials = np.full((3, 5), 1e9, np.float32)  # garbage in pad slots
    for w in range(3):
        rows = np.arange(4 * w, 4 * w + 4)
        row_of[w, :4] = rows
        partials[w, :4] = y[rows]
    fin = np.array([True, False, True])  # worker 1 straggles: rows 4..7 dead
    z, ok = masked_decode(g, row_of, jnp.asarray(partials), jnp.asarray(fin))
    assert bool(ok)  # 8 surviving rows == k
    np.testing.assert_allclose(np.asarray(z), x, rtol=1e-4, atol=1e-4)


# ----------------------------------------------------------- coded head
def _head(block_rows=64, groups=((4, 2.0), (4, 0.5))):
    c = ARCHS["qwen3-0.6b"].reduced()
    m = Model(c)
    params = m.init_params(KEY)
    cluster = ClusterSpec.make([n for n, _ in groups], [mu for _, mu in groups])
    head = CodedLMHead(params["embed"]["table"], cluster, block_rows=block_rows)
    return c, m, params, cluster, head


def test_head_decode_jit_matches_numpy_oracle():
    c, m, params, cluster, head = _head()
    h = jax.random.normal(KEY, (3, c.d_model))
    products = head.worker_products(h)
    # kill one worker (stays above threshold for the optimal plan's slack)
    mask = np.ones(head.plan.num_workers, bool)
    w_kill = int(np.argmin(head.plan.loads_per_worker))
    if head.nb - int(head.plan.loads_per_worker[w_kill]) >= head.kb:
        mask[w_kill] = False
    logits_jit, ok_jit = head.decode_logits_jit(products, jnp.asarray(mask))
    logits_np, ok_np = head.decode_logits(products, mask)
    assert bool(ok_jit) and ok_np
    np.testing.assert_allclose(np.asarray(logits_jit), logits_np,
                               rtol=1e-3, atol=1e-3)
    expected = np.asarray(h @ head.table.T)
    np.testing.assert_allclose(
        np.asarray(logits_jit)[:, : head.table.shape[0]], expected,
        rtol=1e-3, atol=1e-3,
    )


def test_head_encode_logits_kernel_parity():
    c, m, params, cluster, head = _head()
    logits = jax.random.normal(KEY, (2, head.kb * head.block_rows))
    ref = head.encode_logits(logits)
    ker = head.encode_logits(logits, use_kernel=True)
    np.testing.assert_allclose(np.asarray(ker), np.asarray(ref),
                               rtol=1e-4, atol=1e-4)


def test_head_worker_products_kernel_parity():
    c, m, params, cluster, head = _head()
    h = jax.random.normal(KEY, (2, c.d_model))
    ref = head.worker_products(h)
    ker = head.worker_products(h, use_kernel=True)
    np.testing.assert_allclose(np.asarray(ker), np.asarray(ref),
                               rtol=1e-4, atol=1e-4)


# -------------------------------------------------------- serving loop
def test_server_generate_coded_matches_uncoded_regression():
    """Full generate with coded head == uncoded argmax, no stragglers."""
    c = ARCHS["qwen3-0.6b"].reduced()
    m = Model(c)
    params = m.init_params(KEY)
    prompts = jax.random.randint(KEY, (2, 4), 0, c.vocab_size).astype(jnp.int32)

    plain = Server(m, params, None, ServeConfig(max_decode_steps=8))
    out_plain = plain.generate(prompts, 8)

    cluster = ClusterSpec.make([8], [5.0])
    coded = Server(m, params, cluster, ServeConfig(max_decode_steps=8))
    coded.coded_head.deadline = 1e9  # nobody misses
    out_coded = coded.generate(prompts, 8)
    np.testing.assert_array_equal(np.asarray(out_plain), np.asarray(out_coded))


def test_jit_pipeline_matches_legacy_hostloop():
    """The compiled pipeline reproduces the host loop token-for-token."""
    c = ARCHS["qwen3-0.6b"].reduced()
    m = Model(c)
    params = m.init_params(KEY)
    prompts = jax.random.randint(KEY, (2, 4), 0, c.vocab_size).astype(jnp.int32)
    cluster = ClusterSpec.make([6], [4.0])
    jit_srv = Server(m, params, cluster, ServeConfig(max_decode_steps=6))
    host_srv = Server(m, params, cluster,
                      ServeConfig(max_decode_steps=6, jit_pipeline=False))
    jit_srv.coded_head.deadline = 1e9
    host_srv.coded_head.deadline = 1e9
    out_jit = jit_srv.generate(prompts, 6, key=jax.random.PRNGKey(7))
    out_host = host_srv.generate(prompts, 6, key=jax.random.PRNGKey(7))
    np.testing.assert_array_equal(np.asarray(out_jit), np.asarray(out_host))


def test_generate_is_single_compiled_program():
    """No retrace across calls; the program is scan-driven and callback-free."""
    c = ARCHS["qwen3-0.6b"].reduced()
    m = Model(c)
    params = m.init_params(KEY)
    cluster = ClusterSpec.make([8], [5.0])
    server = Server(m, params, cluster, ServeConfig(max_decode_steps=5))
    prompts = jax.random.randint(KEY, (2, 4), 0, c.vocab_size).astype(jnp.int32)

    server.generate(prompts, 5)
    assert server.traces == 1
    server.generate(prompts, 5, key=jax.random.PRNGKey(9))
    assert server.traces == 1  # same shapes: zero Python work between tokens

    # jaxpr-level: the token loop is lax.scan, with no host callbacks
    cache = m.init_cache(2, 9, None)
    closed = jax.make_jaxpr(functools.partial(server._gen_program, max_new=5))(
        server.params, cache, prompts, KEY, jnp.float32(1e9)
    )
    ClosedJaxpr = type(closed)
    Jaxpr = type(closed.jaxpr)

    def prims(jaxpr, acc):
        for eqn in jaxpr.eqns:
            acc.add(eqn.primitive.name)
            for v in eqn.params.values():
                for sub in jax.tree_util.tree_leaves(
                    v, is_leaf=lambda x: isinstance(x, (Jaxpr, ClosedJaxpr))
                ):
                    if isinstance(sub, ClosedJaxpr):
                        prims(sub.jaxpr, acc)
                    elif isinstance(sub, Jaxpr):
                        prims(sub, acc)
        return acc

    top = {eqn.primitive.name for eqn in closed.jaxpr.eqns}
    assert "scan" in top  # prefill scan + token-loop scan
    everything = prims(closed.jaxpr, set())
    assert not everything & {"pure_callback", "io_callback", "debug_callback"}


def test_hostloop_first_post_prefill_token_is_coded():
    """Regression: every sampled token goes through the coded head."""
    c = ARCHS["qwen3-0.6b"].reduced()
    m = Model(c)
    params = m.init_params(KEY)
    cluster = ClusterSpec.make([8], [5.0])
    server = Server(m, params, cluster,
                    ServeConfig(max_decode_steps=4, jit_pipeline=False))
    server.coded_head.deadline = 1e9
    calls = []
    orig = server._coded_logits

    def spy(*a, **kw):
        calls.append(1)
        return orig(*a, **kw)

    server._coded_logits = spy
    prompts = jax.random.randint(KEY, (1, 3), 0, c.vocab_size).astype(jnp.int32)
    server.generate(prompts, 4)
    assert len(calls) == 4  # one per sampled token, incl. the first


def test_jit_pipeline_first_token_is_coded():
    """Trace-time spy: the coded select runs for token 0 and the scan body."""
    c = ARCHS["qwen3-0.6b"].reduced()
    m = Model(c)
    params = m.init_params(KEY)
    cluster = ClusterSpec.make([8], [5.0])
    server = Server(m, params, cluster, ServeConfig(max_decode_steps=4))
    calls = []
    orig = server._coded_select

    def spy(*a, **kw):
        calls.append(1)
        return orig(*a, **kw)

    server._coded_select = spy
    prompts = jax.random.randint(KEY, (1, 3), 0, c.vocab_size).astype(jnp.int32)
    server.generate(prompts, 4)
    assert len(calls) == 2  # token 0 + once inside the (traced-once) scan body
