"""Substrate layers: data pipeline, optimizer, checkpointing, sharding rules."""
import os

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.checkpoint import (
    AsyncCheckpointer,
    latest_step,
    restore_checkpoint,
    save_checkpoint,
)
from repro.configs import ARCHS
from repro.configs.base import ShapeConfig
from repro.data import SyntheticLMData
from repro.optim import (
    AdamWConfig,
    adamw_init,
    adamw_update,
    compress_bf16_ef,
    cosine_schedule,
    init_error_feedback,
)


# --------------------------------------------------------------------- data
def test_data_deterministic_and_seekable():
    c = ARCHS["qwen3-0.6b"].reduced()
    sh = ShapeConfig("t", 16, 4, "train")
    d1 = SyntheticLMData(c, sh, seed=3)
    batches = [d1.next_batch() for _ in range(3)]
    d2 = SyntheticLMData(c, sh, seed=3, start_step=2)  # seek to step 2
    b2 = d2.next_batch()
    np.testing.assert_array_equal(batches[2]["tokens"], b2["tokens"])
    assert int(jnp.max(batches[0]["tokens"])) < c.vocab_size
    # labels are next-token shifted
    np.testing.assert_array_equal(
        np.asarray(batches[0]["tokens"])[:, 1:],
        np.asarray(batches[0]["labels"])[:, :-1],
    )


# -------------------------------------------------------------------- optim
def test_adamw_minimizes_quadratic():
    cfg = AdamWConfig(lr=0.1, warmup_steps=0, total_steps=200,
                      weight_decay=0.0, clip_norm=100.0)
    params = {"w": jnp.array([5.0, -3.0])}
    opt = adamw_init(cfg, params)
    loss = lambda p: jnp.sum(p["w"] ** 2)
    for _ in range(150):
        g = jax.grad(loss)(params)
        params, opt, _ = adamw_update(cfg, g, opt, params)
    assert float(loss(params)) < 1e-3


def test_schedule_warmup_and_decay():
    cfg = AdamWConfig(lr=1.0, warmup_steps=10, total_steps=100, min_lr_ratio=0.1)
    assert float(cosine_schedule(cfg, 0)) == 0.0
    assert float(cosine_schedule(cfg, 10)) == pytest.approx(1.0)
    assert float(cosine_schedule(cfg, 100)) == pytest.approx(0.1, rel=1e-3)


def test_grad_clip_applied():
    cfg = AdamWConfig(lr=1e-3, warmup_steps=0, clip_norm=1.0, weight_decay=0.0)
    params = {"w": jnp.zeros(3)}
    opt = adamw_init(cfg, params)
    huge = {"w": jnp.full(3, 1e6)}
    _, _, metrics = adamw_update(cfg, huge, opt, params)
    assert float(metrics["grad_norm"]) > 1e5  # raw norm reported


def test_bf16_error_feedback_unbiased():
    """Sum of compressed grads + final residual == sum of true grads."""
    key = jax.random.PRNGKey(0)
    grads = [
        {"w": jax.random.normal(jax.random.fold_in(key, i), (64,)) * 1e-3}
        for i in range(50)
    ]
    ef = init_error_feedback(grads[0])
    sent = jnp.zeros(64)
    for g in grads:
        comp, ef = compress_bf16_ef(g, ef)
        sent = sent + comp["w"].astype(jnp.float32)
    true = sum(g["w"] for g in grads)
    np.testing.assert_allclose(
        np.asarray(sent + ef["w"]), np.asarray(true), rtol=1e-4, atol=1e-6
    )
    # plain bf16 (no EF) drifts measurably more
    plain = sum(g["w"].astype(jnp.bfloat16).astype(jnp.float32) for g in grads)
    assert float(jnp.abs(sent + ef["w"] - true).max()) <= float(
        jnp.abs(plain - true).max()
    )


# --------------------------------------------------------------- checkpoint
def test_checkpoint_roundtrip_and_atomicity(tmp_path):
    tree = {"a": jnp.arange(6).reshape(2, 3).astype(jnp.float32),
            "b": [jnp.ones(4, jnp.bfloat16), {"c": jnp.int32(7)}]}
    d = str(tmp_path)
    save_checkpoint(d, 10, tree)
    save_checkpoint(d, 20, tree)
    assert latest_step(d) == 20
    restored, meta = restore_checkpoint(d, 10, tree)
    for x, y in zip(jax.tree.leaves(tree), jax.tree.leaves(restored)):
        np.testing.assert_array_equal(np.asarray(x), np.asarray(y))
    # a stale .tmp dir must not be seen as a checkpoint
    os.makedirs(os.path.join(d, "step_99.tmp"))
    assert latest_step(d) == 20


def test_async_checkpointer(tmp_path):
    ck = AsyncCheckpointer(str(tmp_path))
    tree = {"w": jnp.ones((8, 8))}
    ck.save(1, tree)
    ck.save(2, jax.tree.map(lambda t: t * 2, tree))  # waits for save(1)
    ck.wait()
    assert latest_step(str(tmp_path)) == 2
    r, _ = restore_checkpoint(str(tmp_path), 2, tree)
    np.testing.assert_array_equal(np.asarray(r["w"]), 2 * np.ones((8, 8)))


# ----------------------------------------------------------------- sharding
def test_sharding_rules_divisibility_fallback():
    from jax.sharding import PartitionSpec as P

    from repro.sharding.rules import _fit

    mesh = jax.make_mesh((1, 1), ("data", "model"))

    # single-device mesh: everything effectively replicable but specs valid
    assert _fit(("data", "model"), (8, 16), mesh) == P("data", "model")

    class FakeMesh:
        shape = {"data": 16, "model": 16}

    assert _fit(("data", "model"), (8, 32), FakeMesh()) == P(None, "model")
    assert _fit(("data", "model"), (32, 7), FakeMesh()) == P("data", None)


def test_param_sharding_tree_builds_for_all_archs():
    from repro.models.model import Model
    from repro.sharding import make_param_sharding

    mesh = jax.make_mesh((1, 1), ("data", "model"))
    for name, cfg in ARCHS.items():
        m = Model(cfg.reduced())
        shapes = jax.eval_shape(m.init_params, jax.random.PRNGKey(0))
        tree = make_param_sharding(mesh, shapes)
        assert len(jax.tree.leaves(tree, is_leaf=lambda x: hasattr(x, "spec"))) == len(
            jax.tree.leaves(shapes)
        ), name


def test_batch_split_heterogeneous_sums_and_orders():
    from repro.core.runtime_model import ClusterSpec
    from repro.runtime.train_loop import heterogeneous_batch_split

    cluster = ClusterSpec.make([4, 4], [2.0, 0.5])
    split = heterogeneous_batch_split(cluster, 64)
    assert split.sum() == 64
    assert split[0] > split[1]  # faster group gets the bigger share
