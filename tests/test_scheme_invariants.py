"""Property-based invariants over EVERY registered allocation scheme.

Each test iterates ``scheme_names()`` (via pytest parametrization, plus
hypothesis-randomized clusters when the library is installed), so future
schemes registered through ``register_scheme`` are covered with zero
test edits. Schemes whose factories require parameters are instantiated
generically: ``make_scheme(name)`` first, then a canonical fallback
value per accepted parameter (``scheme_params``) — no per-scheme
special-casing.

Invariants:
* feasibility — real loads >= 0, integer loads are non-negative ints,
  the deployed code always covers k (``n_int >= k``);
* ``expected_latency >= lower_bound`` (for schemes with a finite bound);
* ``replan`` preserves the scheme object (all params) exactly;
* ``make_scheme(tag, **params)`` round-trips every scheme through its
  own name tag.
"""
import dataclasses

import jax
import numpy as np
import pytest
from _hypothesis_compat import HAVE_HYPOTHESIS, given, settings, st

from repro.core import (
    ClusterSpec,
    make_scheme,
    scheme_names,
    scheme_params,
)
from repro.core.planner import deploy, replan_on_membership_change

KEY = jax.random.PRNGKey(7)
K = 512

# canonical fallback per ACCEPTED PARAM NAME (not per scheme name): any
# future scheme that reuses these conventional params is instantiable
# here without edits.
PARAM_FALLBACKS = {
    "n": lambda cluster, k: 1.5 * k,
    "r": lambda cluster, k: max(1, cluster.total_workers // 2),
}


def instantiate(name: str, cluster: ClusterSpec, k: int):
    """Build a scheme for ``name`` with no per-scheme knowledge."""
    try:
        return make_scheme(name)
    except ValueError:
        params = {
            p: fb(cluster, k)
            for p, fb in PARAM_FALLBACKS.items()
            if p in scheme_params(name)
        }
        return make_scheme(name, **params)


def base_cluster() -> ClusterSpec:
    return ClusterSpec.make([6, 10, 8], [4.0, 1.0, 0.4], 1.0)


def comm_cluster() -> ClusterSpec:
    """Same groups behind finite links — exercises the comm-delay terms."""
    return ClusterSpec.make([6, 10, 8], [4.0, 1.0, 0.4], 1.0, [8.0, 2.0, 0.5])


CLUSTERS = {"free_links": base_cluster, "finite_links": comm_cluster}


def check_feasibility(scheme, cluster, k):
    plan = scheme.allocate(cluster, k)
    assert plan.k == k
    assert plan.scheme_obj is scheme
    assert np.all(plan.loads >= 0), plan.loads
    assert np.issubdtype(plan.loads_int.dtype, np.integer)
    assert np.all(plan.loads_int >= 0)
    assert np.all(plan.loads_int >= plan.loads - 1e-6)  # ceil, never floor
    n_w = np.asarray([g.num_workers for g in cluster.groups], dtype=np.int64)
    assert plan.n_int == int(np.sum(n_w * plan.loads_int))
    assert plan.n_int >= k, f"{plan.scheme}: n_int={plan.n_int} < k={k}"
    return plan


def check_replan(scheme, cluster, k):
    dep = deploy(scheme, cluster, k)
    groups = list(cluster.groups)
    if groups[0].num_workers > 1:
        groups[0] = dataclasses.replace(
            groups[0], num_workers=groups[0].num_workers - 1
        )
    new_cluster = ClusterSpec(tuple(groups))
    dep2 = replan_on_membership_change(dep, new_cluster)
    assert dep2.scheme_obj == scheme, dep.scheme
    assert dep2.scheme == dep.scheme
    assert dep2.num_workers == new_cluster.total_workers


def check_tag_round_trip(scheme):
    params = {
        key: v
        for key, v in dataclasses.asdict(scheme).items()
        if v is not None
    }
    rebuilt = make_scheme(scheme.tag, **params)
    assert rebuilt == scheme, (scheme, rebuilt)


# ------------------------------------------------- deterministic sweep
@pytest.mark.parametrize("cluster_kind", sorted(CLUSTERS))
@pytest.mark.parametrize("name", scheme_names())
def test_allocation_feasibility(name, cluster_kind):
    cluster = CLUSTERS[cluster_kind]()
    scheme = instantiate(name, cluster, K)
    check_feasibility(scheme, cluster, K)


@pytest.mark.parametrize("name", scheme_names())
def test_expected_latency_dominates_lower_bound(name):
    """MC mean >= the scheme's analytic bound (small MC-noise slack).

    Schemes without an analytic bound (NaN t_star) are exempt — the
    invariant is vacuous for them by construction.
    """
    cluster = comm_cluster()
    scheme = instantiate(name, cluster, K)
    bound = scheme.lower_bound(cluster, K)
    if not np.isfinite(bound):
        pytest.skip(f"{name} has no analytic lower bound")
    lat = scheme.expected_latency(KEY, cluster, scheme.allocate(cluster, K),
                                  num_trials=4000)
    assert lat >= bound * (1 - 0.03), (name, lat, bound)


@pytest.mark.parametrize("cluster_kind", sorted(CLUSTERS))
@pytest.mark.parametrize("name", scheme_names())
def test_replan_preserves_scheme_params(name, cluster_kind):
    cluster = CLUSTERS[cluster_kind]()
    scheme = instantiate(name, cluster, K)
    check_replan(scheme, cluster, K)


@pytest.mark.parametrize("name", scheme_names())
def test_make_scheme_round_trips_through_tag(name):
    scheme = instantiate(name, base_cluster(), K)
    check_tag_round_trip(scheme)


# ----------------------------------------------- hypothesis randomized
def draw_cluster(data) -> ClusterSpec:
    # min group size 3: the r = N/2 fallback must stay feasible (r < N-1)
    # after check_replan removes a worker
    g = data.draw(st.integers(1, 4), label="num_groups")
    ns = [data.draw(st.integers(3, 24), label=f"N_{j}") for j in range(g)]
    mus = [
        data.draw(st.floats(0.25, 8.0, allow_nan=False), label=f"mu_{j}")
        for j in range(g)
    ]
    alphas = [
        data.draw(st.floats(0.25, 4.0, allow_nan=False), label=f"alpha_{j}")
        for j in range(g)
    ]
    bws = [
        data.draw(
            st.one_of(st.just(float("inf")),
                      st.floats(0.5, 50.0, allow_nan=False)),
            label=f"bw_{j}",
        )
        for j in range(g)
    ]
    return ClusterSpec.make(ns, mus, alphas, bws)


@settings(max_examples=25, deadline=None)
@given(data=st.data() if HAVE_HYPOTHESIS else st.nothing())
def test_property_invariants_all_schemes(data):
    """Feasibility + replan + tag round-trip on random heterogeneous
    clusters (finite and infinite links), for every registered scheme."""
    cluster = draw_cluster(data)
    k = data.draw(st.sampled_from([64, 256, 1024]), label="k")
    for name in scheme_names():
        scheme = instantiate(name, cluster, k)
        check_feasibility(scheme, cluster, k)
        check_replan(scheme, cluster, k)
        check_tag_round_trip(scheme)


# ------------------------------------------------ strict make_scheme
def test_make_scheme_rejects_unknown_kwargs():
    """Regression: a typo'd param used to be silently swallowed by the
    factories' ``**_`` catch-alls (``--scheme uniform_n --r 3`` no-oped);
    now every scheme rejects parameters it does not declare."""
    with pytest.raises(ValueError, match="does not accept"):
        make_scheme("uniform_n", n=700.0, r=3)
    with pytest.raises(ValueError, match="does not accept"):
        make_scheme("uncoded", r=3)
    with pytest.raises(ValueError, match="does not accept"):
        make_scheme("optimal", upload=1.0)
    with pytest.raises(ValueError, match="does not accept"):
        make_scheme("comm_aware", n=100.0)
    with pytest.raises(ValueError, match="does not accept"):
        make_scheme("uniform_r", r=8, totally_bogus=1)
    # None means "not provided" (legacy callers pass the full trio)
    assert make_scheme("uncoded", per_row=None, n=None, r=None).name == "uncoded"


def test_scheme_params_exposes_accepted_params():
    assert scheme_params("uniform_n") == ("n",)
    assert scheme_params("comm_aware") == ("download", "upload")
    assert scheme_params("uncoded") == ()
    with pytest.raises(ValueError, match="unknown scheme"):
        scheme_params("no_such_scheme")
