"""Observability layer (DESIGN.md §14): span tracer, metrics registry,
event-schema registry, telemetry sink contracts, XLA-profile
summarization, and the ops report."""
import gzip
import json
import os
import time

import jax
import jax.numpy as jnp
import pytest

from repro.configs import ARCHS
from repro.core import ClusterSpec
from repro.models.model import Model
from repro.obs.metrics import (
    Counter,
    Gauge,
    Histogram,
    MetricsRegistry,
)
from repro.obs.schema import (
    EVENT_SCHEMAS,
    extract_generated_block,
    render_markdown,
    validate_event,
    validate_events,
)
from repro.obs.trace import NULL_TRACER, SpanTracer, spans_to_chrome
from repro.runtime.serve_loop import ServeConfig, Server
from repro.runtime.telemetry import Telemetry
from repro.serve import Request, SlotScheduler, make_workload

KEY = jax.random.PRNGKey(0)


def _req(rid, arrival=0.0, out_len=4, cls="standard", plen=3):
    return Request(rid=rid, arrival=arrival,
                   prompt=tuple(range(1, plen + 1)), out_len=out_len,
                   deadline_class=cls)


# ------------------------------------------------------------ span tracer
def test_span_nesting_records_depth_parent_attrs():
    tr = SpanTracer()
    with tr.span("decode_chunk", steps=4) as outer:
        with tr.span("dispatch"):
            pass
        outer.set(placed=2)
    inner, top = tr.spans
    assert (inner.name, inner.depth, inner.parent) == ("dispatch", 1,
                                                       "decode_chunk")
    assert (top.name, top.depth, top.parent) == ("decode_chunk", 0, None)
    assert top.attrs == {"steps": 4, "placed": 2}
    assert top.dur_s >= inner.dur_s >= 0.0
    assert top.t0_s <= inner.t0_s


def test_span_exception_propagates_but_still_records():
    tr = SpanTracer()
    with pytest.raises(RuntimeError, match="boom"):
        with tr.span("dispatch"):
            raise RuntimeError("boom")
    assert [s.name for s in tr.spans] == ["dispatch"]


def test_spans_emit_schema_valid_telemetry_events():
    tel = Telemetry(None)
    tr = SpanTracer(tel)
    with tr.span("admit", round=3):
        pass
    (rec,) = tel.events
    assert rec["event"] == "span" and rec["span"] == "admit"
    assert rec["attrs"] == {"round": 3}
    validate_event(rec)


def test_null_tracer_is_one_shared_noop():
    a = NULL_TRACER.span("x", foo=1)
    b = NULL_TRACER.span("y")
    assert a is b  # never allocates on the disabled path
    with a as s:
        s.set(ignored=True)
    assert NULL_TRACER.spans == () and not NULL_TRACER.enabled


def test_span_ring_is_bounded():
    tr = SpanTracer(max_spans=3)
    for i in range(5):
        with tr.span(f"s{i}"):
            pass
    assert [s.name for s in tr.spans] == ["s2", "s3", "s4"]
    with pytest.raises(ValueError, match="max_spans"):
        SpanTracer(max_spans=0)


def test_chrome_export_from_tracer_and_telemetry_rows(tmp_path):
    tel = Telemetry(None)
    tr = SpanTracer(tel)
    with tr.span("decode_chunk", steps=2):
        with tr.span("dispatch"):
            pass
    p1 = tr.export_chrome(str(tmp_path / "tracer.json"))
    p2 = spans_to_chrome(tel.events, str(tmp_path / "rows.json"))
    for p in (p1, p2):
        doc = json.load(open(p))
        evs = doc["traceEvents"]
        assert {e["name"] for e in evs} == {"decode_chunk", "dispatch"}
        assert all(e["ph"] == "X" and e["ts"] >= 0 and e["dur"] >= 0
                   for e in evs)
        outer = next(e for e in evs if e["name"] == "decode_chunk")
        assert outer["ts"] == 0.0  # timestamps relative to first span
        assert outer["args"]["steps"] == 2


# -------------------------------------------------------- metrics registry
def test_counter_is_monotonic_and_merges():
    c = Counter()
    assert c.inc() == 1 and c.inc(4) == 5
    with pytest.raises(ValueError, match="only go up"):
        c.inc(-1)
    other = Counter()
    other.inc(2)
    c.merge(other)
    assert c.value == 7
    c.reset()
    assert c.value == 0


def test_gauge_last_writer_wins():
    g = Gauge()
    g.set(3)
    other = Gauge()
    other.set(9.5)
    g.merge(other)
    assert g.value == 9.5


def test_histogram_percentiles_clamped_to_observed_range():
    h = Histogram(bounds=(1.0, 10.0, 100.0))
    for v in (2.0, 3.0, 4.0, 50.0):
        h.observe(v)
    assert h.count == 4 and h.sum == 59.0 and h.mean == pytest.approx(14.75)
    assert 2.0 <= h.percentile(0.5) <= 10.0
    assert h.percentile(0.0) >= h.min and h.percentile(1.0) <= h.max
    # sparse histograms must not report values outside what was seen
    one = Histogram(bounds=(1.0, 10.0))
    one.observe(5.0)
    assert one.percentile(0.5) == 5.0 == one.percentile(0.99)


def test_histogram_merge_requires_equal_bounds():
    a, b = Histogram(bounds=(1.0, 2.0)), Histogram(bounds=(1.0, 2.0))
    a.observe(0.5)
    b.observe(3.0)
    a.merge(b)
    assert a.count == 2 and a.min == 0.5 and a.max == 3.0
    with pytest.raises(ValueError, match="different bounds"):
        a.merge(Histogram(bounds=(1.0, 3.0)))
    with pytest.raises(ValueError, match="ascending"):
        Histogram(bounds=(2.0, 1.0))


def test_registry_get_or_create_and_type_guard():
    reg = MetricsRegistry()
    c1 = reg.counter("requests_shed", reason="queue_full")
    c2 = reg.counter("requests_shed", reason="queue_full")
    assert c1 is c2 and len(reg) == 1
    reg.counter("requests_shed", reason="deadline_risk")  # distinct labels
    assert len(reg) == 2
    with pytest.raises(TypeError, match="already registered"):
        reg.gauge("requests_shed", reason="queue_full")


def test_registry_emit_writes_one_schema_valid_snapshot():
    reg = MetricsRegistry()
    reg.counter("tokens_emitted").inc(42)
    reg.gauge("queue_depth").set(3)
    reg.histogram("request_latency", deadline_class="strict")  # empty
    tel = Telemetry(None)
    reg.emit(tel, phase="serve", rounds=7.0)
    (rec,) = tel.events
    validate_event(rec)
    assert rec["size"] == 3 and rec["phase"] == "serve"
    hist = next(m for m in rec["metrics"] if m["type"] == "histogram")
    assert hist["p50"] is None  # NaN of the empty histogram -> JSON null
    json.dumps(rec)  # strictly serializable
    assert reg.emit(None) is None


def test_registry_merge_folds_counts():
    a, b = MetricsRegistry(), MetricsRegistry()
    a.counter("tokens_emitted").inc(1)
    b.counter("tokens_emitted").inc(2)
    b.counter("requests_admitted").inc(5)
    a.merge(b)
    assert a.counter("tokens_emitted").value == 3
    assert a.counter("requests_admitted").value == 5


def test_scheduler_populates_registry():
    reg = MetricsRegistry()
    sched = SlotScheduler(1, queue_cap=1, metrics=reg)
    sched.offer(_req(0, out_len=2, cls="strict"), 0.0)
    sched.offer(_req(1), 0.0)  # queue full -> shed
    sched.fill_slots(1.0)
    sched.advance(2)
    sched.retire_done(3.0)
    assert sched.admitted == reg.counter("requests_admitted").value == 1
    assert sched.shed == reg.counter("requests_shed_total").value == 1
    assert reg.counter("requests_shed", reason="queue_full").value == 1
    assert reg.counter("tokens_emitted").value == 2
    lat = reg.histogram("request_latency", deadline_class="strict")
    assert lat.count == 1 and lat.max == 3.0
    assert reg.gauge("queue_depth").value == 0


def test_alloc_cache_counters_back_the_info_api():
    from repro.core.schemes import (
        allocate_cache_clear,
        allocate_cache_info,
        make_scheme,
    )

    allocate_cache_clear()
    scheme = make_scheme("optimal")
    cluster = ClusterSpec.make([2, 2], [2.0, 0.5])
    scheme.allocate(cluster, 100)
    first = allocate_cache_info()
    assert first["misses"] == 1 and first["hits"] == 0
    scheme.allocate(cluster, 100)
    again = allocate_cache_info()
    assert again["hits"] == 1 and again["misses"] == 1
    allocate_cache_clear()
    info = allocate_cache_info()
    assert info["size"] == 0 and info["hits"] == info["misses"] == 0


# ------------------------------------------------------------ event schema
def test_validate_event_enforces_contracts():
    good = {"event": "replan", "t": 0, "wall_s": 1.0, "workers": 4,
            "n": 12, "deadline": 1.5}
    assert validate_event(good) is EVENT_SCHEMAS["replan"]
    with pytest.raises(ValueError, match="missing required"):
        validate_event({"event": "replan", "workers": 4})
    with pytest.raises(ValueError, match="undeclared fields"):
        validate_event({**good, "oops": 1})
    with pytest.raises(ValueError, match="unknown event"):
        validate_event({"event": "not_a_thing"})
    with pytest.raises(ValueError, match="no 'event' field"):
        validate_event({"t": 0})
    # optional fields are accepted without being required
    snap = {"event": "metrics_snapshot", "metrics": [], "size": 0}
    validate_event(snap)
    validate_event({**snap, "phase": "serve", "rounds": 3.0})


def test_design_md_event_table_is_generated_and_in_sync():
    design = os.path.join(os.path.dirname(__file__), "..", "DESIGN.md")
    with open(design) as f:
        block = extract_generated_block(f.read())
    assert block == render_markdown(), (
        "DESIGN.md §8 event table is stale — regenerate with: "
        "python -m repro.obs.schema"
    )
    # and the table covers every declared event
    for name in EVENT_SCHEMAS:
        assert f"| `{name}` |" in block


def test_serve_run_emits_only_declared_events_and_spans():
    """End to end: a traced paged serve run's ENTIRE event stream
    satisfies the schema registry, and the loop actually spans."""
    c = ARCHS["qwen3-0.6b"].reduced()
    m = Model(c)
    server = Server(m, m.init_params(KEY),
                    ClusterSpec.make([2, 2], [4.0, 0.8]),
                    ServeConfig(block_rows=64))
    wl = make_workload("poisson", num_requests=6, prompt_len=(4, 8),
                       out_len=(2, 4), vocab=c.vocab_size)
    tel = Telemetry(None)
    rep = server.serve(wl.trace(seed=3), slots=2, decode_block=2,
                       telemetry=tel)
    assert rep.admitted > 0
    n = validate_events(tel.events, source="paged serve run")
    names = {e["event"] for e in tel.events}
    assert {"span", "metrics_snapshot", "request_admitted",
            "blocks_in_use"} <= names
    spans = {e["span"] for e in tel.events if e["event"] == "span"}
    assert {"admit", "prefill_chunk", "dispatch"} <= spans
    assert n == len(tel.events) > 0


# ---------------------------------------------------------- telemetry sink
def test_telemetry_stamps_wall_s_and_keeps_caller_override():
    tel = Telemetry(None)
    before = time.perf_counter()
    rec = tel.event("replan", workers=4, n=12, deadline=1.5)
    assert before <= rec["wall_s"] <= time.perf_counter()
    # round_timing-style override: the caller's measured window wins
    rec2 = tel.event("replan", workers=4, n=12, deadline=1.5, wall_s=123.0)
    assert rec2["wall_s"] == 123.0
    assert [r["t"] for r in tel.events] == [0, 1]


def test_telemetry_log_coerces_and_ring_bounds_events(tmp_path):
    tel = Telemetry(str(tmp_path / "t.jsonl"), max_events=3)
    rec = tel.log(0, {"loss": jnp.float32(1.5), "scheme": "optimal"})
    assert rec["loss"] == 1.5 and rec["scheme"] == "optimal"
    for i in range(5):
        tel.event("replan", workers=i, n=1, deadline=1.0)
    assert [r["workers"] for r in tel.events] == [2, 3, 4]  # ring kept 3
    tel.close()
    lines = [json.loads(x) for x in open(tmp_path / "t.jsonl")]
    assert len(lines) == 6  # the JSONL sink stays complete
    with pytest.raises(ValueError, match="max_events"):
        Telemetry(None, max_events=0)


# ----------------------------------------------------- profile attribution
def _write_trace(profile_dir, sub, events):
    d = os.path.join(profile_dir, sub, "plugins", "profile", "run")
    os.makedirs(d)
    with gzip.open(os.path.join(d, "host.trace.json.gz"), "wt") as f:
        json.dump({"traceEvents": events}, f)


def _x(name, ts, dur):
    return {"ph": "X", "name": name, "ts": ts, "dur": dur, "pid": 0}


def test_profile_summarize_merges_phase_captures(tmp_path):
    from repro.obs.profile import diff_summaries, format_diff, summarize

    # two capture sessions with unrelated time bases, one phase each
    _write_trace(tmp_path, "generate", [
        _x("jit_generate#meta#", 1000, 100),
        _x("matmul", 1010, 40), _x("matmul", 1060, 20),
        _x("outside_window", 5000, 50),
    ])
    _write_trace(tmp_path, "prefill", [
        _x("prefill", 40, 10), _x("splice", 42, 6),
    ])
    summ = summarize(str(tmp_path), ("jit_generate", "prefill"))
    assert summ["jit_generate"]["wall_us"] == 100
    assert summ["jit_generate"]["n_ops"] == 2
    assert summ["jit_generate"]["ops"][0] == {
        "name": "matmul", "total_us": 60.0, "count": 2,
    }
    assert summ["prefill"]["wall_us"] == 10 and summ["prefill"]["n_ops"] == 1

    golden = {
        "jit_generate": {"wall_us": 50.0, "op_total_us": 60.0, "n_ops": 2,
                         "ops": [{"name": "matmul", "total_us": 60.0,
                                  "count": 2}]},
        "prefill": {"wall_us": 10.0, "op_total_us": 6.0, "n_ops": 1,
                    "ops": []},
    }
    diff = diff_summaries(summ, golden)
    assert diff["worst_phase"] == "jit_generate"
    assert diff["worst_ratio"] == pytest.approx(2.0)
    text = format_diff(diff)
    assert "jit_generate" in text and "<-- regressed" in text
    assert "matmul" in text


def test_profile_summarize_raises_without_captures(tmp_path):
    from repro.obs.profile import summarize

    with pytest.raises(FileNotFoundError, match="no profiler capture"):
        summarize(str(tmp_path), ("jit_generate",))


# --------------------------------------------------------------- obsreport
def _report_records():
    tel = Telemetry(None)
    tr = SpanTracer(tel)
    with tr.span("decode_chunk", steps=2):
        with tr.span("dispatch"):
            pass
    tel.event("request_admitted", request_id=0, slot=0, queue_wait=1.0,
              deadline_class="standard", round=1.0)
    tel.event("request_done", request_id=0, slot=0, tokens=4, latency=9.0,
              deadline_class="standard", round=10.0)
    tel.event("request_evicted", request_id=1, reason="queue_full",
              deadline_class="strict", round=2.0, queue_depth=3)
    tel.event("adapt_decision", round=4, replanned=True,
              reason="improvement", current=2.0, candidate=1.5, gain=0.25,
              deadline=1.9, workers=4)
    tel.event("round_timing", round=0, wall_s=0.5, dispatch_s=0.4,
              pad_wall_s=0.0, scale=1.1, unit_s=0.01, workers=4, fed=True,
              skipped=None, t_max=0.2, t_mean=0.1)
    tel.event("blocks_in_use", in_use=3, free=1, capacity=4, request_id=0,
              round=1.0)
    tel.event("kv_bytes", bytes_in_use=384, bytes_total=512,
              utilization=0.75, request_id=0, round=1.0)
    reg = MetricsRegistry()
    reg.counter("tokens_emitted").inc(4)
    reg.emit(tel, phase="serve", rounds=10.0)
    validate_events(tel.events)
    return list(tel.events) + [{"step": 0, "loss": 2.5}]


def test_obsreport_renders_every_section():
    from repro.launch.obsreport import render_report

    md = render_report(_report_records(), source="unit.jsonl")
    for heading in ("# Ops report", "## Overview", "## Span waterfall",
                    "## Request latency", "## Replan / decision timeline",
                    "## Straggler-estimate drift", "## KV block pool",
                    "## Metrics snapshot"):
        assert heading in md, f"missing section {heading!r}"
    assert "`decode_chunk`" in md and "1 scalar log lines" in md
    assert "`deadline_risk`" not in md  # only observed reasons appear
    assert "UNDECLARED" not in md


def test_obsreport_cli_writes_files_and_requires_spans(tmp_path, capsys):
    from repro.launch.obsreport import main

    src = tmp_path / "run.jsonl"
    with open(src, "w") as f:
        for rec in _report_records():
            f.write(json.dumps(rec) + "\n")
    out, html = tmp_path / "r.md", tmp_path / "r.html"
    main([str(src), "-o", str(out), "--html", str(html),
          "--require-spans"])
    assert "## Span waterfall" in out.read_text()
    assert html.read_text().startswith("<!doctype html>")
    assert "span coverage: 2 spans" in capsys.readouterr().out

    bare = tmp_path / "untraced.jsonl"
    with open(bare, "w") as f:
        f.write(json.dumps({"event": "replan", "t": 0, "wall_s": 0.0,
                            "workers": 2, "n": 4, "deadline": 1.0}) + "\n")
    main([str(bare)])  # fine without the flag
    with pytest.raises(SystemExit, match="no span events"):
        main([str(bare), "--require-spans"])


# ------------------------------------------------------- overhead (gated)
@pytest.mark.slow
def test_span_tracing_overhead_within_two_percent():
    """The instrumented serve loop must cost <= 2% wall time (ISSUE
    acceptance). Run-to-run serve wall jitters ~10% on a loaded host —
    a raw traced-vs-untraced A/B at a 2% bound is a coin flip — so the
    budget is checked as (spans recorded by a real traced serve) x
    (per-span cost from a tight microbenchmark, which IS stable)
    against the untraced serve floor, with a loose wall-clock A/B on
    top to catch regressions the microbenchmark can't see (tracing
    forcing a retrace, say)."""
    c = ARCHS["qwen3-0.6b"].reduced()
    m = Model(c)
    params = m.init_params(KEY)
    server = Server(m, params, ClusterSpec.make([2, 2], [4.0, 0.8]),
                    ServeConfig(block_rows=64))
    wl = make_workload("poisson", num_requests=24, prompt_len=(4, 8),
                       out_len=(8, 16), vocab=c.vocab_size)
    trace = wl.trace(seed=5)

    def run(tracer) -> float:
        t0 = time.perf_counter()
        server.serve(trace, slots=2, decode_block=2, tracer=tracer)
        return time.perf_counter() - t0

    run(SpanTracer())  # shared warmup: all programs compile first
    tracer = SpanTracer()
    traced = [run(tracer)]
    n_spans = len(tracer.spans)
    assert n_spans > 100, "workload too small to exercise tracing"
    untraced = [run(NULL_TRACER)]
    for _ in range(2):  # interleave so drift hits both modes alike
        traced.append(run(SpanTracer()))
        untraced.append(run(NULL_TRACER))
    off = min(untraced)

    reps = 20_000
    bench = SpanTracer()  # one tracer, like the serve loop holds one
    t0 = time.perf_counter()
    for _ in range(reps):
        with bench.span("decode_chunk", steps=2) as s:
            s.set(placed=0)
    t1 = time.perf_counter()
    for _ in range(reps):
        with NULL_TRACER.span("decode_chunk", steps=2) as s:
            s.set(placed=0)
    t2 = time.perf_counter()
    per_span_s = max(0.0, ((t1 - t0) - (t2 - t1)) / reps)

    cost = n_spans * per_span_s
    assert cost <= 0.02 * off, (
        f"span tracing budget blown: {n_spans} spans x "
        f"{per_span_s * 1e6:.2f}us = {cost * 1e3:.2f}ms > 2% of "
        f"{off * 1e3:.1f}ms untraced serve"
    )
    # traced serve must also not be catastrophically slower end to end
    assert min(traced) <= off * 1.15, (
        f"traced serve {min(traced):.3f}s vs untraced {off:.3f}s"
    )
