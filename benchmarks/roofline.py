"""Roofline report: read artifacts/dryrun/*.json -> §Roofline table.

Terms (seconds, per the prompt's definitions, v5e constants):
  compute    = HLO_FLOPs / (chips x 197e12)
  memory     = HLO_bytes / (chips x 819e9)
  collective = collective_bytes / (chips x 50e9)
HLO quantities from cost_analysis are PER-DEVICE in the partitioned
module, so dividing the per-device value by the per-chip peak gives the
same number — that is what dryrun.py stored in t_compute/t_memory/
t_collective.
"""
from __future__ import annotations

import glob
import json
import os

ARTDIR = os.path.join(os.path.dirname(__file__), "..", "artifacts", "dryrun")


def load_records(mesh: str | None = "single_pod_16x16") -> list[dict]:
    recs = []
    for path in sorted(glob.glob(os.path.join(ARTDIR, "*.json"))):
        with open(path) as f:
            r = json.load(f)
        if r.get("scan_layers"):  # compile-proof records undercount layers
            continue
        if mesh is None or r["mesh"] == mesh:
            recs.append(r)
    return recs


def as_markdown(recs: list[dict]) -> str:
    lines = [
        "| arch | shape | t_compute | t_memory | t_collective | bottleneck "
        "| roofline frac | useful FLOPs |",
        "|---|---|---|---|---|---|---|---|",
    ]
    for r in recs:
        lines.append(
            f"| {r['arch']} | {r['shape']} | {r['t_compute']:.3e} "
            f"| {r['t_memory']:.3e} | {r['t_collective']:.3e} "
            f"| {r['bottleneck'].replace('t_', '')} "
            f"| {r['roofline_fraction']:.3f} | {r['useful_flops_ratio']:.3f} |"
        )
    return "\n".join(lines)


def run(verbose: bool = True) -> dict:
    recs = load_records()
    if not recs:
        print("roofline: no dry-run artifacts found — run "
              "`python -m repro.launch.dryrun` first")
        return {"rows": []}
    if verbose:
        print("Roofline (single-pod 16x16, per-device terms in seconds):")
        print(as_markdown(recs))
        worst = min(recs, key=lambda r: r["roofline_fraction"])
        coll = max(recs, key=lambda r: r["t_collective"] /
                   max(r["t_compute"] + r["t_memory"], 1e-30))
        print(f"\nworst roofline fraction: {worst['arch']}/{worst['shape']} "
              f"({worst['roofline_fraction']:.3f})")
        print(f"most collective-bound: {coll['arch']}/{coll['shape']}")
    return {"rows": recs}


if __name__ == "__main__":
    run()
