"""Fig. 3 — MDS rate k/n* for fixed group 1 and varying (N2, mu2).

Paper setting: (N1, mu1, a1) = (100, 1, 1), a2 = 1. The paper's
observation: for fixed N2 the rate is NOT monotone increasing in mu2
(counter-intuitive) — we verify non-monotonicity numerically.
"""
from __future__ import annotations

import numpy as np

from benchmarks.common import save, table
from repro.core.runtime_model import ClusterSpec
from repro.core.schemes import Optimal


def run(verbose: bool = True) -> dict:
    scheme = Optimal()
    # the dip sits near mu2 ~ 1e-2; sweep wide enough to capture it
    mu2s = np.logspace(-2.5, 1.5, 30)
    n2s = [50, 100, 200, 400]
    rows = []
    grid = {}
    for n2 in n2s:
        rates = []
        for mu2 in mu2s:
            c = ClusterSpec.make([100, n2], [1.0, float(mu2)], 1.0)
            plan = scheme.allocate(c, k=10_000)
            rates.append(plan.rate)
        grid[n2] = rates
        rows.append({"N2": n2, "rate_min": min(rates), "rate_max": max(rates),
                     "monotone": bool(np.all(np.diff(rates) >= -1e-12))})
    record = {
        "mu2": mu2s.tolist(),
        "rates_by_N2": {str(k): v for k, v in grid.items()},
        "rows": rows,
        "nonmonotone_exists": bool(any(not r["monotone"] for r in rows)),
    }
    if verbose:
        print("Fig 3: rate k/n* vs (N2, mu2); fixed (N1=100, mu1=1)")
        print(table(rows, ["N2", "rate_min", "rate_max", "monotone"]))
        print(f"non-monotone-in-mu2 observed: {record['nonmonotone_exists']} "
              "(paper: 'interestingly, it is not true')")
    save("fig3", record)
    return record


if __name__ == "__main__":
    run()
