"""Fig. 8 — two-group latency vs rate under uniform allocation.

Paper setting: N = (300, 600), mu = (4, 0.5), alpha = (1, 1). Claims:
the best uniform rate is ~0.52, and the proposed allocation is ~10%
below that optimum.
"""
from __future__ import annotations

import jax
import numpy as np

from benchmarks.common import KEY, TRIALS, save, table
from repro.core.engine import CodedComputeEngine
from repro.core.runtime_model import ClusterSpec
from repro.core.schemes import Optimal, UniformN

K = 100_000


def run(verbose: bool = True, cluster: ClusterSpec | None = None,
        rates=None, trials: int | None = None, k: int = K) -> dict:
    """Paper setting by default; the keyword params let the golden
    regression tests drive a tiny seeded cluster through the same path."""
    c = ClusterSpec.make([300, 600], [4.0, 0.5], 1.0) if cluster is None \
        else cluster
    rates = np.linspace(0.35, 0.95, 13) if rates is None \
        else np.asarray(rates, float)
    trials = TRIALS if trials is None else trials
    rows = []
    for i, rate in enumerate(rates):
        key = jax.random.fold_in(KEY, 300 + i)
        lat = CodedComputeEngine(
            c, k, UniformN(n=k / rate)
        ).expected_latency(key, trials)
        rows.append({"rate": float(rate), "uniform": lat})
    best = min(rows, key=lambda r: r["uniform"])
    opt = CodedComputeEngine(c, k, Optimal())
    proposed = opt.expected_latency(KEY, trials)
    record = {
        "rows": rows,
        "best_uniform_rate": best["rate"],
        "best_uniform_latency": best["uniform"],
        "proposed": proposed,
        "reduction_vs_best_uniform": 1.0 - proposed / best["uniform"],
    }
    if verbose:
        print("Fig 8: two-group latency vs uniform rate")
        print(table(rows, ["rate", "uniform"]))
        print(f"best uniform rate: {best['rate']:.2f} (paper: ~0.52); "
              f"proposed reduction vs it: "
              f"{100 * record['reduction_vs_best_uniform']:.1f}% (paper: ~10%)")
    save("fig8", record)
    return record


if __name__ == "__main__":
    run()
