"""Fig. 5 — expected latency vs q (scale of mu) at fixed N = 2500.

Same 5-group cluster as Fig. 4. Claims: uniform-n* achieves the bound
for q <= 1e-2; uniform rate-1/2 is competitive only on [1e-1.5, 1e-1];
uncoded approaches T* as q -> 1e1.5.
"""
from __future__ import annotations

import jax
import numpy as np

from benchmarks.common import KEY, TRIALS, save, table
from benchmarks.fig4 import K, R_FIXED, make_cluster
from repro.core.engine import CodedComputeEngine
from repro.core.schemes import Optimal, Uncoded, UniformN, UniformR


def run(verbose: bool = True, n_total: int = 2500, qs=None,
        trials: int | None = None, k: int = K,
        r_fixed: int = R_FIXED) -> dict:
    """Paper setting by default; the keyword params let the golden
    regression tests drive a tiny seeded cluster through the same path."""
    base = make_cluster(n_total)
    qs = np.logspace(-2, 1.5, 8) if qs is None else np.asarray(qs, float)
    trials = TRIALS if trials is None else trials
    rows = []
    for i, q in enumerate(qs):
        c = base.scale_mu(float(q))
        key = jax.random.fold_in(KEY, 100 + i)
        opt = CodedComputeEngine(c, k, Optimal())
        row = {
            "q": float(q),
            "proposed": opt.expected_latency(key, trials),
            "T*": opt.t_star,
        }
        baselines = {
            "uniform_n*": UniformN(n=opt.allocation.n),
            "uniform_rate_half": UniformN(n=2.0 * k),
            "uncoded": Uncoded(),
            "group_code_r100": UniformR(r=r_fixed),
        }
        for name, scheme in baselines.items():
            row[name] = CodedComputeEngine(c, k, scheme).expected_latency(
                key, trials
            )
        rows.append(row)
    first, last = rows[0], rows[-1]
    record = {
        "rows": rows,
        "uniform_nstar_achieves_bound_small_q": first["uniform_n*"] / first["T*"],
        "uncoded_approaches_bound_large_q": last["uncoded"] / last["T*"],
    }
    if verbose:
        print("Fig 5: latency vs q at N=2500")
        print(table(rows, ["q", "proposed", "T*", "uniform_n*",
                           "uniform_rate_half", "uncoded", "group_code_r100"]))
        print(f"uniform-n*/T* at q={first['q']:.3g}: "
              f"{record['uniform_nstar_achieves_bound_small_q']:.3f} (paper: ~1)")
        print(f"uncoded/T* at q={last['q']:.3g}: "
              f"{record['uncoded_approaches_bound_large_q']:.3f} (paper: -> 1)")
    save("fig5", record)
    return record


if __name__ == "__main__":
    run()
