"""Fig. 2 — N x T* as a function of q*mu (T* = Theta(1/N)).

Paper setting: N = (1000, 2000, 3000), mu = (2, 1, 0.5), alpha = 1.
The product N*T* should be (nearly) invariant in N for every q, showing
T* = Theta(1/N); the curve over q shows the straggling-rate dependence.
"""
from __future__ import annotations

import numpy as np

from benchmarks.common import save, table
from repro.core.runtime_model import ClusterSpec
from repro.core.schemes import Optimal

K = 10_000  # T* under model (1) is k-free; the scheme API still takes a k


def run(verbose: bool = True) -> dict:
    scheme = Optimal()
    base = ClusterSpec.make([1000, 2000, 3000], [2.0, 1.0, 0.5], 1.0)
    qs = np.logspace(-2, 2, 17)
    rows = []
    for q in qs:
        c = base.scale_mu(float(q))
        rows.append(
            {"q": float(q), "N*T*": c.total_workers * scheme.lower_bound(c, K)}
        )
    # invariance check at q=1 across N scales
    scales = []
    for s in (1, 2, 4):
        c = ClusterSpec.make([1000 * s, 2000 * s, 3000 * s], [2.0, 1.0, 0.5], 1.0)
        scales.append(c.total_workers * scheme.lower_bound(c, K))
    record = {
        "rows": rows,
        "N_invariance": scales,
        "theta_1_over_N": bool(np.allclose(scales, scales[0], rtol=1e-9)),
    }
    if verbose:
        print("Fig 2: N*T* vs q (scale of mu); T* = Theta(1/N)")
        print(table(rows, ["q", "N*T*"]))
        print(f"N*T* across N-scales x1/x2/x4: {scales} "
              f"(invariant: {record['theta_1_over_N']})")
    save("fig2", record)
    return record


if __name__ == "__main__":
    run()
