"""Shared helpers for the paper-figure benchmarks."""
from __future__ import annotations

import json
import os

import jax
import numpy as np

# allocation math at f64 (matches the scipy-validated test precision)
jax.config.update("jax_enable_x64", True)

# cold-process compile reuse: every benchmark program (bucket branches
# included) persists to disk, so reruns and cache-restored CI jobs skip
# the XLA compile (DESIGN.md §11; REPRO_NO_COMPILE_CACHE opts out)
from repro.runtime.compile_cache import enable_persistent_cache  # noqa: E402

enable_persistent_cache()

ARTIFACTS = os.path.join(os.path.dirname(__file__), "..", "artifacts", "bench")

KEY = jax.random.PRNGKey(2019)
TRIALS = 4000  # paper uses 1e4; 4e3 keeps the full suite CPU-friendly


def save(name: str, record: dict):
    os.makedirs(ARTIFACTS, exist_ok=True)
    path = os.path.join(ARTIFACTS, f"{name}.json")
    with open(path, "w") as f:
        json.dump(record, f, indent=1, default=_np_default)
    return path


def _np_default(o):
    if isinstance(o, (np.integer,)):
        return int(o)
    if isinstance(o, (np.floating,)):
        return float(o)
    if isinstance(o, np.ndarray):
        return o.tolist()
    raise TypeError(type(o))


def table(rows: list[dict], cols: list[str], *, fmt: str = "10.4g") -> str:
    head = " | ".join(f"{c:>12s}" for c in cols)
    sep = "-" * len(head)
    lines = [head, sep]
    for r in rows:
        cells = []
        for c in cols:
            v = r.get(c, "")
            cells.append(
                f"{v:>12{fmt[2:]}}" if isinstance(v, float) else f"{str(v):>12s}"
            )
        lines.append(" | ".join(cells))
    return "\n".join(lines)
