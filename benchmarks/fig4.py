"""Fig. 4 — expected latency vs N: proposed vs all baselines (5 groups).

Paper setting: N = (3N,4N,5N,6N,7N)/25, mu = (16,12,8,4,1), alpha = 1,
r = 100 for the group-code scheme of [33]. Claims validated:
  (a) proposed MC latency achieves the lower bound T* as N grows;
  (b) >=10x gain over the fixed-r group code for large N (whose latency
      floors at 1/r);
  (c) ~18% lower latency than uniform with the same (n*, k) code.

Every scheme runs through the typed registry + CodedComputeEngine.
"""
from __future__ import annotations

import jax
import numpy as np

from benchmarks.common import KEY, TRIALS, save, table
from repro.core.engine import CodedComputeEngine
from repro.core.runtime_model import ClusterSpec
from repro.core.schemes import Optimal, Uncoded, UniformN, UniformR

K = 100_000
R_FIXED = 100


def make_cluster(n_total: int) -> ClusterSpec:
    parts = np.array([3, 4, 5, 6, 7]) * n_total // 25
    return ClusterSpec.make(parts.tolist(), [16.0, 12.0, 8.0, 4.0, 1.0], 1.0)


def run(verbose: bool = True, ns=None, trials: int | None = None,
        k: int = K, r_fixed: int = R_FIXED) -> dict:
    """Paper setting by default; ``ns``/``trials``/``k``/``r_fixed`` let the
    golden regression tests drive the same pipeline on tiny seeded
    clusters (tests/test_fig_golden.py)."""
    ns = [250, 500, 1000, 2000, 4000, 8000] if ns is None else ns
    trials = TRIALS if trials is None else trials
    rows = []
    for i, n_total in enumerate(ns):
        c = make_cluster(n_total)
        key = jax.random.fold_in(KEY, i)
        opt = CodedComputeEngine(c, k, Optimal())
        baselines = {
            "uniform_n*": UniformN(n=opt.allocation.n),
            "uniform_rate_half": UniformN(n=2.0 * k),
            "uncoded": Uncoded(),
            "group_code_r100": UniformR(r=r_fixed),
        }
        row = {
            "N": c.total_workers,
            "proposed": opt.expected_latency(key, trials),
            "lower_bound_T*": opt.t_star,
            "group_code_floor": 1.0 / r_fixed,
        }
        for name, scheme in baselines.items():
            row[name] = CodedComputeEngine(c, k, scheme).expected_latency(
                key, trials
            )
        rows.append(row)
    last = rows[-1]
    record = {
        "rows": rows,
        "achieves_lower_bound": last["proposed"] / last["lower_bound_T*"],
        "gain_over_group_code": last["group_code_r100"] / last["proposed"],
        "gain_over_uniform_nstar": 1.0 - last["proposed"] / last["uniform_n*"],
    }
    if verbose:
        print("Fig 4: expected latency vs N (5 heterogeneous groups)")
        print(table(rows, ["N", "proposed", "lower_bound_T*", "uniform_n*",
                           "uniform_rate_half", "uncoded", "group_code_r100"]))
        print(f"proposed/T* at N={last['N']}: "
              f"{record['achieves_lower_bound']:.3f} (-> 1.0 = achieves bound)")
        print(f"gain over fixed-r group code: "
              f"{record['gain_over_group_code']:.1f}x (paper: >=10x)")
        print(f"gain over uniform with same (n*,k): "
              f"{100 * record['gain_over_uniform_nstar']:.1f}% (paper: ~18%)")
    save("fig4", record)
    return record


if __name__ == "__main__":
    run()
