"""Continuous batching vs sequential full-batch serving, equal fleet.

``PYTHONPATH=src python -m benchmarks.serve_frontend [--reduced]``

Replays one seeded Poisson arrival trace (``repro.serve.workload``) on
the reference 12-worker heterogeneous fleet two ways:

* ``sequential`` — the pre-front-end discipline: requests are grouped
  FIFO into full batches of ``slots`` and each batch runs one
  ``Server.generate`` call (everyone padded to the global max output
  length; a batch cannot start before its last member arrives, the next
  batch cannot start before the previous finishes).
* ``continuous`` — ``Server.serve``: slots free up per request and are
  refilled from the queue mid-flight via the batched-prefill splice.

Both paths sample the same coded head per decode round. Throughput is
wall-clock useful tokens/s (generated tokens of finished requests;
sequential's padding steps are the waste being measured). Per-request
latency is in virtual-clock ROUNDS — arrival to last token, where one
decode step = one round and a whole prefill (batched pass OR the
sequential prefill scan) = one round, a unit that is deterministic
across machines; the sequential prefill-scan charge of one round is
deliberately generous to the baseline.

Two more continuous-only runs probe admission control: a ``trickle``
trace (far under capacity — zero sheds expected) and an ``overload``
trace (arrivals beyond fleet capacity — the queue must shed and keep
the p99 of what it admits bounded). Gates are asserted in BOTH modes
(the CI fast lane runs ``--reduced``); results land in
``artifacts/bench/serve_frontend.json``.

``--paged`` runs the paged-KV A/B instead (DESIGN.md §13): the same
chat trace plus one long-tail prompt served from the block-pooled cache
with chunked prefill vs the dense per-slot cache, gating that paged
serving (a) matches or beats dense tokens/s, (b) holds >= 4x less KV
memory than dense's worst-case cache, and (c) admits every prompt
length through ONE compiled program — zero retraces in the timed runs,
long prompts included (dense must size every slot for the longest
prompt; paged pays per 16-token block actually referenced).
"""
from __future__ import annotations

import argparse
import time

import jax
import jax.numpy as jnp
import numpy as np

from benchmarks.common import save, table
from repro.configs import get_arch
from repro.core.runtime_model import ClusterSpec
from repro.models.model import Model
from repro.runtime.serve_loop import ServeConfig, Server
from repro.serve import Request, make_workload

KEY = jax.random.PRNGKey(0)

#: same reference fleet as serve_throughput: 6 fast + 6 slow workers
FLEET = ClusterSpec.make([6, 6], [8.0, 0.7])
#: batch width shared by BOTH paths. Kept narrow: per-decode-round cost
#: is nearly flat in batch size on this fleet (coded-round fixed costs
#: dominate), so wide batches hand the sequential baseline free
#: parallelism while continuous batching's win is slot recycling — the
#: narrow setting is where the padding waste being measured is starkest.
SLOTS = 2
DECODE_BLOCK = 4
SPEEDUP_GATE = 1.5


def _sequential(server, trace, prompt_cap, max_out):
    """Full-batch FIFO baseline: one ``generate`` per ``SLOTS`` requests.

    Returns (useful tokens, wall seconds, per-request latencies in
    rounds). Batch b starts at max(previous batch finish, its last
    arrival) and takes ``1 + max_out`` rounds (prefill charged one round,
    matching the continuous path's accounting).
    """
    batches = [trace[i:i + SLOTS] for i in range(0, len(trace), SLOTS)]
    prompts0 = np.zeros((SLOTS, prompt_cap), np.int32)
    for r, req in enumerate(batches[0]):
        prompts0[r, : req.prompt_len] = req.prompt
    jax.block_until_ready(  # warmup: all batches share one compiled shape
        server.generate(jnp.asarray(prompts0), max_out, key=KEY)
    )
    tokens = 0
    latencies = []
    now = 0.0
    t0 = time.perf_counter()
    for i, batch in enumerate(batches):
        prompts = np.zeros((SLOTS, prompt_cap), np.int32)
        for r, req in enumerate(batch):
            prompts[r, : req.prompt_len] = req.prompt
        out = server.generate(
            jnp.asarray(prompts), max_out, key=jax.random.fold_in(KEY, i)
        )
        jax.block_until_ready(out)
        now = max(now, max(r.arrival for r in batch)) + 1.0 + max_out
        for req in batch:
            tokens += req.out_len
            latencies.append(now - req.arrival)
    wall = time.perf_counter() - t0
    return tokens, wall, np.asarray(latencies)


#: paged A/B geometry: 8 slots, 16-token blocks, admission chunk = one
#: block row — the long-tail prompt spans 15 chunks
PAGED_SLOTS = 8
BLOCK_LEN = 16
PREFILL_CHUNK = 16
LONG_PROMPT = 240
KV_BYTES_GATE = 4.0


def paged_dense_ab(reduced: bool = True, repeats: int = 3,
                   assert_gates: bool = True) -> dict:
    """Paged vs dense serving A/B on chat + one long-tail prompt.

    Returns a record with both paths' tokens/s, the paged/dense ratio,
    the KV-memory ratio (dense worst-case slot cache bytes over the
    paged pool bytes, both from real ``.nbytes``), and the retrace
    counts of the timed runs. Reused by ``benchmarks/serve_throughput``
    so the perf gate can hold a paged/dense tokens-per-second golden.
    """
    config = get_arch("qwen3-0.6b").reduced()
    model = Model(config)
    params = model.init_params(KEY)

    n_req = 10 if reduced else 20
    wl = make_workload(
        "chat", num_requests=n_req, prompt_len=(8, 16),
        vocab=config.vocab_size,
    )
    trace = list(wl.trace(seed=0))
    # the long-tail request: one prompt far past the admission chunk —
    # dense must size EVERY slot's cache for it; paged prefills it over
    # LONG_PROMPT / PREFILL_CHUNK admit rounds of the same program
    rng = np.random.RandomState(7)
    long_arrival = trace[len(trace) // 2].arrival
    trace.append(Request(
        rid=n_req, arrival=long_arrival,
        prompt=tuple(int(t) for t in rng.randint(1, config.vocab_size,
                                                 LONG_PROMPT)),
        out_len=8, deadline_class="batch",
    ))
    prompt_cap = max(r.prompt_len for r in trace)
    max_out = max(r.out_len for r in trace)
    cache_len = prompt_cap + max_out + 1
    # pool: the long request's full reservation + three concurrent chat
    # requests' worth — transient pressure queues, nothing can deadlock
    need_long = -(-(LONG_PROMPT + 8 + 1) // BLOCK_LEN)
    need_chat = -(-(16 + max_out + 1) // BLOCK_LEN)
    num_blocks = need_long + 3 * need_chat

    serve_kw = dict(
        slots=PAGED_SLOTS, decode_block=DECODE_BLOCK,
        prompt_cap=prompt_cap, max_out=max_out,
        queue_cap=10 * n_req, admission_threshold=1e-3,
    )
    dense_kw = dict(serve_kw, paged=False)
    paged_kw = dict(
        serve_kw, paged=True, block_len=BLOCK_LEN,
        num_blocks=num_blocks, prefill_chunk=PREFILL_CHUNK,
    )
    server = Server(model, params, FLEET, ServeConfig(block_rows=64))
    server.serve(trace, **dense_kw)  # warmup / compile
    server.serve(trace, **paged_kw)
    traces_after_warmup = server.serve_traces
    dense_runs, paged_runs = [], []
    for _ in range(repeats):
        dense_runs.append(server.serve(trace, **dense_kw))
        paged_runs.append(server.serve(trace, **paged_kw))
    retraces = server.serve_traces - traces_after_warmup
    dense = min(dense_runs, key=lambda r: r.wall_s)
    paged = min(paged_runs, key=lambda r: r.wall_s)
    for name, rep in [("dense", dense), ("paged", paged)]:
        assert rep.shed == 0 and rep.admitted == len(trace), (
            f"{name} A/B run must serve the full trace "
            f"(admitted {rep.admitted}, shed {rep.shed})"
        )
    assert paged.tokens == dense.tokens, "paths must serve identical work"
    long_fin = [f for f in paged.finished if f.request.rid == n_req]
    assert long_fin and long_fin[0].outcome == "done", (
        "the long-tail prompt must be admitted and finished via chunked "
        "prefill"
    )

    dense_cache = model.init_slot_cache(PAGED_SLOTS, cache_len)
    paged_cache = model.init_paged_cache(num_blocks, BLOCK_LEN)
    nbytes = lambda c: sum(
        int(t.nbytes) for t in (c["kv"]["k"], c["kv"]["v"])
    )
    dense_bytes, paged_bytes = nbytes(dense_cache), nbytes(paged_cache)
    kv_ratio = dense_bytes / paged_bytes
    tok_ratio = paged.tokens_per_s / dense.tokens_per_s

    record = {
        "slots": PAGED_SLOTS,
        "block_len": BLOCK_LEN,
        "num_blocks": num_blocks,
        "prefill_chunk": PREFILL_CHUNK,
        "long_prompt": LONG_PROMPT,
        "prompt_cap": prompt_cap,
        "num_requests": len(trace),
        "dense": {"tokens": dense.tokens, "wall_s": dense.wall_s,
                  "tokens_per_s": dense.tokens_per_s,
                  "prefill_rounds": dense.prefill_rounds,
                  "kv_bytes": dense_bytes},
        "paged": {"tokens": paged.tokens, "wall_s": paged.wall_s,
                  "tokens_per_s": paged.tokens_per_s,
                  "prefill_rounds": paged.prefill_rounds,
                  "kv_bytes": paged_bytes},
        "tokens_per_s_ratio": tok_ratio,
        "kv_bytes_ratio": kv_ratio,
        "timed_retraces": retraces,
    }
    if assert_gates:
        assert tok_ratio >= 1.0, (
            f"paged serving must match or beat dense tokens/s, got "
            f"{tok_ratio:.2f}x"
        )
        assert kv_ratio >= KV_BYTES_GATE, (
            f"paged pool must hold >= {KV_BYTES_GATE}x less KV than the "
            f"dense worst-case cache, got {kv_ratio:.2f}x"
        )
        assert retraces == 0, (
            f"timed serve runs must not retrace (mixed prompt lengths "
            f"ride one compiled program), got {retraces}"
        )
    return record


def run_paged(reduced: bool = False):
    """CLI entry for the paged A/B: run, print, save, gate."""
    record = paged_dense_ab(reduced=reduced, assert_gates=True)
    rows = [
        {"path": p, **{k: record[p][k]
                       for k in ("tokens_per_s", "prefill_rounds",
                                 "kv_bytes")}}
        for p in ("dense", "paged")
    ]
    path = save("serve_paged", record)
    print(table(rows, ["path", "tokens_per_s", "prefill_rounds",
                       "kv_bytes"]))
    print(f"paged / dense tokens/s: {record['tokens_per_s_ratio']:.2f}x "
          f"(gate >= 1.0x)")
    print(f"dense / paged KV bytes: {record['kv_bytes_ratio']:.2f}x "
          f"(gate >= {KV_BYTES_GATE}x)")
    print(f"timed-run retraces: {record['timed_retraces']} (gate == 0); "
          f"long prompt of {record['long_prompt']} tokens chunk-prefilled "
          f"at {record['prefill_chunk']}/round")
    print(f"wrote {path}")
    return record


def run(reduced: bool = False):
    config = get_arch("qwen3-0.6b").reduced()
    model = Model(config)
    params = model.init_params(KEY)
    server = Server(
        model, params, FLEET, ServeConfig(block_rows=64)
    )

    n_req = 16 if reduced else 32
    wl = make_workload(
        "chat", num_requests=n_req, prompt_len=(8, 16),
        vocab=config.vocab_size,
    )
    trace = wl.trace(seed=0)
    prompt_cap = max(r.prompt_len for r in trace)
    max_out = max(r.out_len for r in trace)

    # -------- continuous batching (shedding disabled via a lenient
    # admission threshold + deep queue: both paths serve equal work)
    serve_kw = dict(
        slots=SLOTS, decode_block=DECODE_BLOCK, prompt_cap=prompt_cap,
        max_out=max_out, queue_cap=10 * n_req, admission_threshold=1e-3,
    )
    server.serve(trace, **serve_kw)  # warmup / compile
    # interleave the two paths and keep each one's best wall time: CI
    # machines are noisy, and alternating exposes both paths to the same
    # load transients instead of letting one eat a slow spell alone
    cont_runs, seq_runs = [], []
    for _ in range(3):
        cont_runs.append(server.serve(trace, **serve_kw))
        seq_runs.append(_sequential(server, trace, prompt_cap, max_out))
    cont = min(cont_runs, key=lambda r: r.wall_s)
    seq_tokens, seq_wall, seq_lat = min(seq_runs, key=lambda r: r[1])
    assert cont.shed == 0 and cont.admitted == n_req, (
        "comparison run must serve the full trace"
    )
    assert seq_tokens == cont.tokens, "paths must serve identical work"

    speedup = cont.tokens_per_s / (seq_tokens / seq_wall)
    cont_p99 = cont.latency_percentile(99)
    seq_p99 = float(np.percentile(seq_lat, 99))

    # -------- admission control: low rate sheds nothing ...
    wl_low = make_workload(
        "trickle", num_requests=max(6, n_req // 2),
        prompt_len=(8, 16), out_len=(4, 28), vocab=config.vocab_size,
    )
    low = server.serve(wl_low.trace(seed=1), prompt_cap=prompt_cap,
                       max_out=max_out, slots=SLOTS,
                       decode_block=DECODE_BLOCK)
    # ... and overload sheds load while keeping admitted p99 bounded
    queue_cap = 2 * SLOTS
    wl_over = make_workload(
        "overload", num_requests=n_req,
        prompt_len=(8, 16), out_len=(4, 28), vocab=config.vocab_size,
    )
    over = server.serve(wl_over.trace(seed=2), prompt_cap=prompt_cap,
                        max_out=max_out, slots=SLOTS,
                        decode_block=DECODE_BLOCK, queue_cap=queue_cap)
    max_work = 1 + max_out
    # every admitted request waits at most the bounded backlog ahead of it
    p99_bound = (queue_cap + SLOTS) * max_work / SLOTS + max_work + DECODE_BLOCK
    over_p99 = over.latency_percentile(99)

    rows = [
        {"path": "sequential", "tokens_per_s": seq_tokens / seq_wall,
         "p50_rounds": float(np.percentile(seq_lat, 50)),
         "p99_rounds": seq_p99},
        {"path": "continuous", "tokens_per_s": cont.tokens_per_s,
         "p50_rounds": cont.latency_percentile(50),
         "p99_rounds": cont_p99},
    ]
    record = {
        "arch": "qwen3-0.6b (reduced)",
        "cluster": "6:8.0,6:0.7",
        "reduced": reduced,
        "num_requests": n_req,
        "slots": SLOTS,
        "decode_block": DECODE_BLOCK,
        "prompt_cap": prompt_cap,
        "max_out": max_out,
        "sequential": {"tokens": seq_tokens, "wall_s": seq_wall,
                       "tokens_per_s": seq_tokens / seq_wall,
                       "p50_rounds": float(np.percentile(seq_lat, 50)),
                       "p99_rounds": seq_p99},
        "continuous": {"tokens": cont.tokens, "wall_s": cont.wall_s,
                       "tokens_per_s": cont.tokens_per_s,
                       "rounds": cont.rounds,
                       "prefill_rounds": cont.prefill_rounds,
                       "decode_rounds": cont.decode_rounds,
                       "p50_rounds": cont.latency_percentile(50),
                       "p99_rounds": cont_p99},
        "speedup_tokens_per_s": speedup,
        "admission": {
            "low_rate": {"admitted": low.admitted, "shed": low.shed},
            "overload": {"admitted": over.admitted, "shed": over.shed,
                         "queue_cap": queue_cap,
                         "p99_rounds": over_p99,
                         "p99_bound_rounds": p99_bound},
        },
    }
    path = save("serve_frontend", record)
    print(table(rows, ["path", "tokens_per_s", "p50_rounds", "p99_rounds"]))
    print(f"continuous / sequential tokens/s: {speedup:.2f}x "
          f"(gate >= {SPEEDUP_GATE}x)")
    print(f"overload: {over.shed} shed / {over.admitted} admitted, "
          f"p99 {over_p99:.1f} <= bound {p99_bound:.1f} rounds")
    print(f"wrote {path}")

    assert speedup >= SPEEDUP_GATE, (
        f"continuous batching must sustain >= {SPEEDUP_GATE}x tokens/s over "
        f"sequential full-batch, got {speedup:.2f}x"
    )
    assert cont_p99 <= seq_p99, (
        f"continuous p99 ({cont_p99:.1f} rounds) must not exceed "
        f"sequential p99 ({seq_p99:.1f} rounds)"
    )
    assert low.shed == 0, "no request may be shed at low arrival rate"
    assert over.shed > 0, "overload must shed load"
    assert np.isfinite(over_p99) and over_p99 <= p99_bound, (
        f"admitted p99 under overload must stay bounded: "
        f"{over_p99:.1f} > {p99_bound:.1f} rounds"
    )
    return record


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--reduced", action="store_true",
                    help="smaller trace for the CI fast lane")
    ap.add_argument("--paged", action="store_true",
                    help="paged-KV vs dense slot-cache A/B (chat trace + "
                         "long-tail prompt) instead of continuous vs "
                         "sequential")
    args = ap.parse_args()
    if args.paged:
        run_paged(reduced=args.reduced)
    else:
        run(reduced=args.reduced)


if __name__ == "__main__":
    main()
