"""fig_grad — gradient coding vs drop-straggler vs uniform DP training.

Training on a heterogeneous fleet has three classic straggler policies:

* ``uniform_dp``     — equal microbatches, wait for EVERY worker: the
  step time is the max over workers, dominated by the slowest group.
* ``drop_straggler`` — Theorem-2-proportional microbatches
  (``heterogeneous_batch_split``) with a per-round deadline; late
  workers' gradients are dropped and the mean rescaled
  (``aggregate_with_erasures`` semantics). Fast steps, but every drop
  throws away data — the gradient is noisier and the round still waits
  for ``min(max worker time, deadline)``.
* ``grad_coding``    — the coded scheme of Wang et al. (arXiv:1901.09339)
  on this repo's substrate (DESIGN.md §5): Theorem-2 partition loads
  with redundancy, full-batch gradient recovered from ANY k surviving
  coded rows, so the master stops at the k-th coverage time — the same
  order-statistic win the paper proves for coded matvec.

Two measurements per fleet:

1. **Expected step latency** (Monte Carlo under model (1)): uniform
   waits for the max; drop waits for ``min(max, deadline)``; coded
   stops at ``min(threshold-coverage time, deadline)`` (a round that
   covers < k rows by the deadline is a skipped step at full deadline
   cost — counted). Each policy gets a deadline of ``safety x`` its own
   expected round time.
2. **Convergence** (real training, reduced model): identical data /
   init / step budget under each aggregation; drop-straggler loses
   batch fraction to erasures while coded recovers the exact full-batch
   gradient whenever >= k coded rows survive.

The acceptance claim of the subsystem: coded expected step latency beats
drop-straggler on a heterogeneous fleet (``coded_beats_drop``).
"""
from __future__ import annotations

import jax
import jax.numpy as jnp
import numpy as np

from benchmarks.common import KEY, TRIALS, save, table
from repro.core.gradient_coding import assignment_matrix, decode_vector
from repro.core.runtime_model import ClusterSpec, expand_groups, sample_worker_times
from repro.runtime.executor import CodedRoundExecutor
from repro.runtime.train_loop import heterogeneous_batch_split

K = 2_000  # gradient partitions for the latency MC
SAFETY = 3.0


def _times(key, cluster, loads_w, k, trials):
    mus = expand_groups(cluster, [g.mu for g in cluster.groups])
    als = expand_groups(cluster, [g.alpha for g in cluster.groups])
    return sample_worker_times(
        key, jnp.asarray(loads_w, jnp.float32), mus, als, k, trials
    )


def _drop_loads(cluster: ClusterSpec, split) -> np.ndarray:
    """Per-worker loads of the drop-straggler (Theorem-2 microbatch) plan."""
    return np.concatenate([
        np.full((g.num_workers,), split[j] / g.num_workers)
        for j, g in enumerate(cluster.groups)
    ])


def _threshold_time(times, loads_w, k):
    """Per-trial first time the finished workers cover k coded rows."""
    order = jnp.argsort(times, axis=1)
    st = jnp.take_along_axis(times, order, axis=1)
    covered = jnp.cumsum(jnp.asarray(loads_w, jnp.float32)[order], axis=1)
    done = covered >= k - 1e-6
    idx = jnp.argmax(done, axis=1)
    lat = jnp.take_along_axis(st, idx[:, None], axis=1)[:, 0]
    return jnp.where(jnp.any(done, axis=1), lat, jnp.inf)


def step_latencies(cluster: ClusterSpec, k: int, trials: int, key,
                   safety: float = SAFETY) -> dict:
    """MC expected step latency for the three policies on one fleet."""
    n_workers = cluster.total_workers
    n_w = np.asarray([g.num_workers for g in cluster.groups], float)

    # uniform DP: equal loads, wait for everyone
    uni_loads = np.full((n_workers,), k / n_workers)
    t_uni = _times(jax.random.fold_in(key, 0), cluster, uni_loads, k, trials)
    uniform_dp = float(jnp.mean(jnp.max(t_uni, axis=1)))

    # drop-straggler: Theorem-2 microbatch split, cutoff at its deadline
    split = heterogeneous_batch_split(cluster, k)
    drop_loads = _drop_loads(cluster, split)
    t_drop = _times(jax.random.fold_in(key, 1), cluster, drop_loads, k, trials)
    max_drop = jnp.max(t_drop, axis=1)
    drop_deadline = safety * float(jnp.mean(max_drop))
    drop_lat = float(jnp.mean(jnp.minimum(max_drop, drop_deadline)))
    fin = t_drop <= drop_deadline
    kept = jnp.sum(fin * jnp.asarray(drop_loads, jnp.float32), axis=1) / k
    drop_kept = float(jnp.mean(kept))

    # gradient coding: threshold coverage, cutoff at its deadline
    exe = CodedRoundExecutor(cluster, k, "grad_coding",
                             deadline_safety=safety)
    coded_loads = np.asarray(exe.plan.loads_per_worker, float)
    t_cod = _times(jax.random.fold_in(key, 2), cluster, coded_loads, k, trials)
    thr = _threshold_time(t_cod, coded_loads, k)
    coded_deadline = exe.deadline
    coded_lat = float(jnp.mean(jnp.minimum(thr, coded_deadline)))
    coded_skip = float(jnp.mean((thr > coded_deadline).astype(jnp.float32)))

    return {
        "uniform_dp": uniform_dp,
        "drop_straggler": drop_lat,
        "drop_batch_kept": drop_kept,
        "grad_coding": coded_lat,
        "coded_skip_frac": coded_skip,
        "bound_T*": float(exe.plan.t_star),
        "coded_redundancy": float(exe.plan.n / k),
    }


def convergence(cluster: ClusterSpec, *, steps: int, batch: int, seq: int,
                seed: int = 0, arch: str = "qwen3-0.6b",
                safety: float = 1.5) -> dict:
    """Identical-budget training under each aggregation policy.

    Per-partition gradients are computed once per step and re-weighted
    per policy with the SAME sampled worker times AND the same
    wall-clock deadline (the coded plan's), so the comparison isolates
    data efficiency at an equal per-round latency budget: uniform sees
    every partition (it pays the max-time latency for that — see
    ``step_latencies``), coded recovers ALL of them whenever >= k coded
    rows survive (exact decode vector), drop keeps only partitions whose
    owner met the deadline and rescales.

    A tighter default safety (1.5x vs the trainer's 3x) makes the
    deadline actually bind on the tiny fleet: the headline metric is the
    mean relative L2 error of each policy's aggregated gradient vs the
    true full-batch gradient — exactly zero-ish for coded rounds that
    decode, structurally nonzero for every drop round that loses data.
    """
    from repro.configs import ARCHS
    from repro.configs.base import ShapeConfig
    from repro.data import SyntheticLMData
    from repro.models.model import Model
    from repro.optim import AdamWConfig, adamw_init, adamw_update

    config = ARCHS[arch].reduced()
    model = Model(config)
    k = batch  # one partition per batch row
    exe = CodedRoundExecutor(cluster, k, "grad_coding",
                             deadline_safety=safety)
    b_mat = np.asarray(assignment_matrix(exe.n, k,
                                         key=jax.random.PRNGKey(seed)))
    row_owner = np.asarray(exe.slot_owner)
    coded_deadline = exe.deadline
    coded_loads = np.asarray(exe.plan.loads_per_worker, float)

    split = heterogeneous_batch_split(cluster, k)
    part_owner = np.repeat(np.arange(cluster.total_workers), np.concatenate([
        _spread(split[j], g.num_workers)
        for j, g in enumerate(cluster.groups)
    ]))[:k]
    drop_loads = _drop_loads(cluster, split)
    key0 = jax.random.fold_in(KEY, seed)
    drop_deadline = coded_deadline  # equal latency budget per round

    opt_cfg = AdamWConfig(lr=1e-3, warmup_steps=0, total_steps=steps)

    def part_grads(params, batch):
        toks = batch["tokens"].reshape(k, 1, seq)
        labs = batch["labels"].reshape(k, 1, seq)

        def one(tb, lb):
            (loss, _), g = jax.value_and_grad(model.loss_fn, has_aux=True)(
                params, {"tokens": tb, "labels": lb}
            )
            return g, loss

        return jax.vmap(one)(toks, labs)

    part_grads = jax.jit(part_grads)

    @jax.jit
    def apply(params, opt_state, grads_k, weights):
        agg = jax.tree.map(
            lambda g: jnp.tensordot(
                jnp.asarray(weights, jnp.float32) / k,
                g.astype(jnp.float32), axes=1),
            grads_k,
        )
        return adamw_update(opt_cfg, agg, opt_state, params)[:2]

    @jax.jit
    def grad_error(grads_k, weights):
        """Relative L2 error of the weighted aggregate vs the true mean."""
        dw = (jnp.asarray(weights, jnp.float32) - 1.0) / k
        tw = jnp.full((k,), 1.0 / k, jnp.float32)
        num = den = 0.0
        for g in jax.tree.leaves(grads_k):
            g = g.astype(jnp.float32)
            num += jnp.sum(jnp.tensordot(dw, g, axes=1) ** 2)
            den += jnp.sum(jnp.tensordot(tw, g, axes=1) ** 2)
        return jnp.sqrt(num / jnp.maximum(den, 1e-30))

    policies = ("uniform_dp", "grad_coding", "drop_straggler")
    states, losses = {}, {p: [] for p in policies}
    errors = {p: [] for p in policies}
    skips = dict.fromkeys(policies, 0)
    drop_kept = []
    params0 = model.init_params(jax.random.PRNGKey(seed))
    for p in policies:
        states[p] = (params0, adamw_init(opt_cfg, params0))

    data = SyntheticLMData(config, ShapeConfig("fig_grad", seq, batch, "train"),
                           seed=seed)
    for step in range(steps):
        batch = data.next_batch()
        skey = jax.random.fold_in(key0, 1000 + step)
        # the same key (-> the same per-worker exponential draws) drives
        # both policies' round times, via the shared runtime-model sampler
        t_cod = np.asarray(_times(skey, cluster, coded_loads, k, 1)[0])
        t_drp = np.asarray(_times(skey, cluster, drop_loads, k, 1)[0])
        weights = {"uniform_dp": np.ones((k,))}
        a, ok = decode_vector(b_mat, (t_cod <= coded_deadline)[row_owner])
        weights["grad_coding"] = a @ b_mat if ok else None
        fin = (t_drp <= drop_deadline)[part_owner]
        drop_kept.append(float(fin.mean()))
        weights["drop_straggler"] = (
            fin * (k / fin.sum()) if fin.any() else None
        )
        for p in policies:
            params, opt_state = states[p]
            grads_k, loss_k = part_grads(params, batch)
            if weights[p] is None:  # skipped step (all erased)
                skips[p] += 1
            else:
                errors[p].append(float(grad_error(grads_k, weights[p])))
                params, opt_state = apply(params, opt_state, grads_k,
                                          weights[p])
            states[p] = (params, opt_state)
            losses[p].append(float(jnp.mean(loss_k)))

    tail = max(2, steps // 5)
    return {
        "steps": steps,
        "deadline": float(coded_deadline),
        "final_loss": {p: float(np.mean(losses[p][-tail:])) for p in policies},
        "first_loss": {p: losses[p][0] for p in policies},
        "grad_error": {
            p: float(np.mean(errors[p])) if errors[p] else float("nan")
            for p in policies
        },
        "skipped_steps": skips,
        "drop_batch_kept": float(np.mean(drop_kept)),
    }


def _spread(total: int, parts: int) -> np.ndarray:
    """Split integer ``total`` into ``parts`` near-equal integer cells."""
    base = np.full((parts,), total // parts, int)
    base[: total - base.sum()] += 1
    return base


def run(verbose: bool = True, cluster: ClusterSpec | None = None,
        conv_cluster: ClusterSpec | None = None,
        trials: int | None = None, k: int | None = None,
        conv_steps: int = 24, conv_batch: int = 8, conv_seq: int = 32) -> dict:
    cluster = cluster or ClusterSpec.make([20, 40, 20], [4.0, 1.0, 0.25], 1.0)
    # convergence runs a REAL model with k = batch partitions, so its
    # fleet is sized to the batch (a worker per few partitions)
    conv_cluster = conv_cluster or ClusterSpec.make([2, 4, 2],
                                                    [4.0, 1.0, 0.25], 1.0)
    trials = TRIALS if trials is None else trials
    k = K if k is None else k

    lat = step_latencies(cluster, k, trials, jax.random.fold_in(KEY, 900))
    conv = convergence(conv_cluster, steps=conv_steps, batch=conv_batch,
                       seq=conv_seq)
    record = {
        "cluster": [(g.num_workers, g.mu) for g in cluster.groups],
        "k": k,
        **lat,
        "convergence": conv,
        "coded_beats_drop": lat["grad_coding"] < lat["drop_straggler"],
        "coded_beats_uniform": lat["grad_coding"] < lat["uniform_dp"],
        "speedup_vs_drop": lat["drop_straggler"] / lat["grad_coding"],
        "speedup_vs_uniform": lat["uniform_dp"] / lat["grad_coding"],
    }
    if verbose:
        print("fig_grad: expected step latency per straggler policy")
        print(table([lat], ["uniform_dp", "drop_straggler", "grad_coding",
                            "bound_T*", "drop_batch_kept", "coded_skip_frac",
                            "coded_redundancy"]))
        print(f"gradient coding vs drop-straggler: "
              f"{record['speedup_vs_drop']:.2f}x faster per step "
              f"(vs uniform DP: {record['speedup_vs_uniform']:.2f}x)")
        print(f"convergence (final loss, same step budget): "
              f"{conv['final_loss']} (skipped: {conv['skipped_steps']})")
        print(f"mean gradient error vs true full-batch gradient: "
              f"{conv['grad_error']} "
              f"(drop kept {conv['drop_batch_kept']:.1%} of the batch)")
    save("fig_grad", record)
    return record


if __name__ == "__main__":
    run()
