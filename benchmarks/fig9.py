"""Fig. 9 — shift-parameter model (30): Corollary 2 vs the [32] scheme.

Paper setting: N = (3N,3N,4N)/10, mu = (1,4,8), alpha = (1,4,12),
k = 1e5. Claim: our allocation under model (30) achieves the lower bound
T*_b and coincides with [32]'s optimal scheme.

Both schemes carry MODEL_30 as their LatencyModel, so the engine
simulates them under the per-row model without any flag threading.
"""
from __future__ import annotations

import jax

from benchmarks.common import KEY, TRIALS, save, table
from repro.core.engine import CodedComputeEngine
from repro.core.runtime_model import ClusterSpec, LatencyModel
from repro.core.schemes import Optimal, Reisizadeh

K = 100_000


def make_cluster(n_total: int) -> ClusterSpec:
    parts = [3 * n_total // 10, 3 * n_total // 10, 4 * n_total // 10]
    return ClusterSpec.make(parts, [1.0, 4.0, 8.0], [1.0, 4.0, 12.0])


def run(verbose: bool = True, ns=None, trials: int | None = None,
        k: int = K) -> dict:
    """Paper setting by default; ``ns``/``trials``/``k`` let the golden
    regression tests drive tiny seeded clusters through the same path."""
    ns = [100, 300, 1000, 3000] if ns is None else ns
    trials = TRIALS if trials is None else trials
    rows = []
    for i, n_total in enumerate(ns):
        c = make_cluster(n_total)
        key = jax.random.fold_in(KEY, 400 + i)
        ours = CodedComputeEngine(c, k, Optimal(model=LatencyModel.MODEL_30))
        reis = CodedComputeEngine(c, k, Reisizadeh())
        rows.append({
            "N": c.total_workers,
            "ours_cor2": ours.expected_latency(key, trials),
            "reisizadeh": reis.expected_latency(key, trials),
            "T*_b": ours.t_star,
        })
    last = rows[-1]
    record = {
        "rows": rows,
        "ours_over_bound": last["ours_cor2"] / last["T*_b"],
        "matches_reisizadeh": abs(last["ours_cor2"] - last["reisizadeh"])
        / last["reisizadeh"],
    }
    if verbose:
        print("Fig 9: shift-parameter model — Corollary 2 vs [32]")
        print(table(rows, ["N", "ours_cor2", "reisizadeh", "T*_b"]))
        print(f"ours/T*_b at N={last['N']}: {record['ours_over_bound']:.3f} "
              "(paper: -> 1)")
        print(f"relative gap to [32]: {100 * record['matches_reisizadeh']:.2f}% "
              "(paper: consistent/optimal)")
    save("fig9", record)
    return record


if __name__ == "__main__":
    run()
