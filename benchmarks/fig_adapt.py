"""fig_adapt — static plan vs oracle replan vs adaptive controller.

The paper's headline claim — orders-of-magnitude latency reduction from
heterogeneity-AWARE allocation — assumes the plan knows the true
(a_j, mu_j). This benchmark measures what happens when the cluster
drifts (``repro.sim`` scenario registry: mu drift/step, churn,
bandwidth collapse, a correlated bad rack) under three policies:

* ``static``   — plan once on the initial cluster, never look again;
* ``oracle``   — replan every round with perfect knowledge of the true
  cluster, at zero cost (the unachievable lower envelope);
* ``adaptive`` — the closed-loop ``AdaptiveController``: per-round
  straggler observations -> (mu, alpha, bandwidth) estimates -> replan
  on a cadence when the hysteresis rule fires, paying ``REPLAN_COST``
  (in round-latency units — a replan recompiles the consumer's step)
  for every plan change.

Per-round cost: the deterministic mean-field ``coverage_latency`` of the
policy's current loads under the TRUE cluster, clamped at the policy's
own deadline (a round whose coverage cannot reach k by the deadline is
a timeout — it costs the full deadline AND is counted as a skip). All
three policies are scored with the same metric, so ratios are exact.

Acceptance (asserted by tests/test_adaptive.py on the reduced run):
on every drift/churn scenario the adaptive controller beats the static
plan and lands within 1.5x of the oracle; on control scenarios (static
fleet, estimation noise) it must not replan at all.
"""
from __future__ import annotations

import zlib

import jax
import numpy as np

from benchmarks.common import KEY, save, table
from repro.core.runtime_model import ClusterSpec
from repro.core.schemes import make_scheme
from repro.runtime.control import AdaptConfig, AdaptiveController, coverage_latency
from repro.runtime.executor import CodedRoundExecutor
from repro.runtime.plan_bucket import BucketConfig
from repro.sim import make_scenario, scenario_names

K = 2_000  # coded rows / partitions
HORIZON = 120  # rounds per scenario
ADAPT_EVERY = 5  # controller cadence
THRESHOLD = 0.05  # hysteresis: relative improvement needed
#: modeled cost of one replan in round-latency units (recompile + plan
#: distribution) — charged to the adaptive policy only; the oracle is a
#: deliberately free bound
REPLAN_COST = 0.05
SAFETY = 3.0

#: heterogeneous base fleet behind finite links (so CommDelay scenarios
#: have a bandwidth to collapse); group 0 is the fast one the built-in
#: scenarios pick on
BASE = ClusterSpec.make([8, 16, 8], [4.0, 1.0, 0.25], 1.0, [16.0, 8.0, 4.0])


def _oracle_loads(scheme, cluster, k) -> np.ndarray:
    """Real per-group loads of a fresh solve on (cluster, k).

    Scenario traces revisit the same cluster for long stretches (steps,
    windows, churn plateaus); ``AllocationScheme.allocate`` is memoized
    on (scheme, cluster, k), so the oracle's every-round replan
    collapses to one allocation per distinct state for free.
    """
    return np.asarray(scheme.allocate(cluster, k).loads, float)


def _policy_eval(true_cluster, loads, k, deadline, scheme):
    """(cost, skipped): mean-field latency under truth, deadline-clamped."""
    lat = coverage_latency(
        true_cluster, loads, k,
        model=scheme.latency_model,
        upload=float(getattr(scheme, "upload", 0.0)),
        download=float(getattr(scheme, "download", 0.0)),
    )
    if not np.isfinite(lat) or lat > deadline:
        return float(deadline), True
    return float(lat), False


def run_scenario(name: str, *, base: ClusterSpec = BASE, k: int = K,
                 horizon: int | None = None, every: int = ADAPT_EVERY,
                 threshold: float = THRESHOLD,
                 replan_cost: float = REPLAN_COST, seed: int = 0,
                 bucket_quantum: int | None = None) -> dict:
    """Replay one registered scenario under the three policies.

    ``bucket_quantum`` runs the adaptive executor in bucket-switch mode
    (DESIGN.md §11): a replan landing in an already-admitted bucket is
    retrace-free, so ``replan_cost`` is charged only on true bucket
    misses — this is what makes an ``every=1`` cadence affordable.
    """
    spec = make_scenario(name, horizon=horizon)
    trace = spec.trace(base, seed=seed)
    scheme = make_scheme(spec.scheme)
    h = trace.horizon

    exe_static = CodedRoundExecutor(base, k, spec.scheme,
                                    deadline_safety=SAFETY)
    static_loads = np.asarray(exe_static.plan.allocation.loads, float)
    static_deadline = exe_static.deadline

    exe_adapt = CodedRoundExecutor(
        base, k, spec.scheme, deadline_safety=SAFETY,
        bucket_config=(
            BucketConfig(quantum=bucket_quantum)
            if bucket_quantum is not None else None
        ),
    )
    ctl = AdaptiveController(
        exe_adapt,
        AdaptConfig(every=every, threshold=threshold,
                    replan_cost=replan_cost, horizon=max(h // 2, 1)),
    )

    key = jax.random.fold_in(KEY, zlib.crc32(name.encode()) % (2**31))
    lat = {"static": [], "oracle": [], "adaptive": []}
    skips = {"static": 0, "adaptive": 0}
    replan_rounds = []
    free_replans = 0  # bucket hits: plan changed, nothing recompiled
    for t in range(h):
        truth = trace.at(t)
        # static: the t=0 plan, scored against today's truth
        c, s = _policy_eval(truth, static_loads, k, static_deadline, scheme)
        lat["static"].append(c)
        skips["static"] += s
        # oracle: fresh plan on the truth, free of charge
        lat["oracle"].append(
            coverage_latency(
                truth, _oracle_loads(scheme, truth, k), k,
                model=scheme.latency_model,
                upload=float(getattr(scheme, "upload", 0.0)),
                download=float(getattr(scheme, "download", 0.0)),
            )
        )
        # adaptive: score the incumbent plan, then observe + maybe replan
        cur_loads = np.asarray(exe_adapt.plan.allocation.loads, float)
        # the plan's loads are per-group for the PLAN's cluster; under
        # churn the truth has different counts — evaluate on the truth's
        # counts only when the group lists line up, else it's a timeout
        if exe_adapt.plan.cluster.num_groups == truth.num_groups:
            eval_cluster = truth
        else:  # a group vanished entirely: plan/truth are incomparable
            eval_cluster = exe_adapt.plan.cluster
        c, s = _policy_eval(eval_cluster, cur_loads, k, exe_adapt.deadline,
                            scheme)
        skips["adaptive"] += s
        d = ctl.observe_truth(jax.random.fold_in(key, t), truth)
        if d is not None and d.replanned:
            if exe_adapt.last_bucket_hit:
                free_replans += 1  # in-program bucket switch: no retrace
            else:
                c += replan_cost
            replan_rounds.append(t)
        lat["adaptive"].append(c)

    mean = {p: float(np.mean(v)) for p, v in lat.items()}
    # goodput view: a timed-out round costs the full deadline AND
    # delivers nothing, so the latency per COMPLETED round is what a
    # serving SLA actually sees — this is where deadline violations make
    # the static plan lose by a wide margin, not just the mean
    eff = {
        "static": float(np.sum(lat["static"])
                        / max(h - skips["static"], 1)),
        "adaptive": float(np.sum(lat["adaptive"])
                          / max(h - skips["adaptive"], 1)),
    }
    return {
        "scenario": name,
        "kind": spec.kind,
        "scheme": spec.scheme,
        "horizon": h,
        "static": mean["static"],
        "oracle": mean["oracle"],
        "adaptive": mean["adaptive"],
        "adaptive_vs_oracle": mean["adaptive"] / mean["oracle"],
        "static_vs_adaptive": mean["static"] / mean["adaptive"],
        "effective_static": eff["static"],
        "effective_adaptive": eff["adaptive"],
        "effective_gain": eff["static"] / eff["adaptive"],
        "replans": len(replan_rounds),
        "free_replans": free_replans,
        "replan_rounds": replan_rounds,
        "static_skips": skips["static"],
        "adaptive_skips": skips["adaptive"],
        "decisions": len(ctl.decisions),
    }


def run(verbose: bool = True, *, horizon: int | None = None,
        every: int = ADAPT_EVERY, threshold: float = THRESHOLD,
        replan_cost: float = REPLAN_COST, seed: int = 0,
        scenarios=None) -> dict:
    rows = [
        run_scenario(name, horizon=horizon, every=every,
                     threshold=threshold, replan_cost=replan_cost, seed=seed)
        for name in (scenarios or scenario_names())
    ]
    dynamic = [r for r in rows if r["kind"] != "control"]
    control = [r for r in rows if r["kind"] == "control"]
    record = {
        "k": K,
        "cluster": [(g.num_workers, g.mu, g.bandwidth)
                    for g in BASE.groups],
        "adapt_every": every,
        "threshold": threshold,
        "replan_cost": replan_cost,
        "rows": rows,
        # acceptance: adaptive tracks the oracle and beats the static
        # plan on every non-stationary scenario...
        "adaptive_within_1p5x_oracle": all(
            r["adaptive_vs_oracle"] <= 1.5 for r in rows
        ),
        "adaptive_beats_static_on_dynamic": all(
            r["adaptive"] < r["static"] for r in dynamic
        ),
        # ...and holds (zero replans) when the fleet is stationary
        "no_replans_on_control": all(r["replans"] == 0 for r in control),
        "max_static_vs_adaptive": max(
            r["static_vs_adaptive"] for r in dynamic
        ),
        "max_effective_gain": max(r["effective_gain"] for r in dynamic),
    }
    if verbose:
        print("fig_adapt: mean round latency per policy "
              f"(k={K}, cadence={every}, threshold={threshold:.0%}, "
              f"replan_cost={replan_cost})")
        print(table(rows, ["scenario", "kind", "scheme", "static", "oracle",
                           "adaptive", "adaptive_vs_oracle",
                           "static_vs_adaptive", "effective_gain",
                           "replans"]))
        print(f"adaptive within 1.5x of oracle everywhere: "
              f"{record['adaptive_within_1p5x_oracle']}; beats static on "
              f"every drift/churn scenario: "
              f"{record['adaptive_beats_static_on_dynamic']} "
              f"(mean up to {record['max_static_vs_adaptive']:.2f}x, "
              f"per-completed-round up to "
              f"{record['max_effective_gain']:.2f}x); holds on "
              f"control scenarios: {record['no_replans_on_control']}")
    save("fig_adapt", record)
    return record


if __name__ == "__main__":
    import argparse

    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--reduced", action="store_true",
                    help="CI smoke: short horizon, same acceptance checks")
    args = ap.parse_args()
    rec = run(horizon=48 if args.reduced else None)
    if args.reduced:
        # the smoke doubles as a regression gate in the CI fast lane
        assert rec["adaptive_within_1p5x_oracle"], rec
        assert rec["adaptive_beats_static_on_dynamic"], rec
        assert rec["no_replans_on_control"], rec
