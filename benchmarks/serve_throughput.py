"""Serving throughput: legacy numpy-decode host loop vs jit-native pipeline.

``PYTHONPATH=src python -m benchmarks.serve_throughput``

Measures a full ``Server.generate`` (prefill + coded greedy decode) under
both paths on CPU:

* ``legacy``  — ``ServeConfig(jit_pipeline=False)``: one Python round-trip
  per prefill token and per decoded token; erasure decode on the host via
  ``np.linalg.solve`` (the pre-refactor hot path).
* ``jit``     — the default pipeline: the whole generation is one compiled
  program (two ``lax.scan``s), finish masks sampled and erasure decode
  solved on-device.

Also times the erasure decode alone (numpy oracle vs jitted fixed-shape
decode) for a per-token decode-latency number, breaks the jit pipeline
into per-phase timings (batched prefill vs per-token decode vs erasure
solve — the ratios ``benchmarks/perf_gate.py`` gates separately), runs
the paged/dense serving A/B (``benchmarks.serve_frontend``) for the
paged tokens-per-second ratio golden, and writes
``artifacts/bench/serve_throughput.json`` — the serving-path companion to
the paper-figure latency benchmarks.
"""
from __future__ import annotations

import time

import jax
import jax.numpy as jnp
import numpy as np

from benchmarks.common import save, table
from repro.configs import get_arch
from repro.core.runtime_model import ClusterSpec
from repro.models.model import Model
from repro.runtime.serve_loop import ServeConfig, Server

KEY = jax.random.PRNGKey(0)


def _time_generate(server, prompts, max_new, *, runs=3):
    out = server.generate(prompts, max_new, key=KEY)  # warmup / compile
    jax.block_until_ready(out)
    t0 = time.perf_counter()
    for i in range(runs):
        out = server.generate(prompts, max_new, key=jax.random.fold_in(KEY, i))
        jax.block_until_ready(out)
    dt = (time.perf_counter() - t0) / runs
    return prompts.shape[0] * max_new / dt, dt


def _time_decode(head, products, *, rounds=50):
    """Per-round mask-sample + erasure-decode latency.

    The host path pays a Python round-trip per round (mask to numpy,
    ``np.linalg.solve``); the jit path is measured the way the serving
    pipeline actually runs it — amortized inside one compiled
    ``lax.scan`` over per-round fold_in'd keys, so per-call dispatch
    overhead (which the pipeline eliminates) is not billed to it.
    """
    keys = jax.random.split(KEY, rounds)
    t0 = time.perf_counter()
    for i in range(rounds):
        mask = head.sample_finish_mask(keys[i])
        head.decode_logits(products, mask)
    t_np = (time.perf_counter() - t0) / rounds

    deadline = head.deadline

    @jax.jit
    def scanned(products):
        def body(acc, k):
            m = head.finish_mask_jit(k, deadline)
            logits, ok = head.decode_logits_jit(products, m)
            # data dep on every round: nothing gets hoisted out of the scan
            return acc + logits.mean().astype(acc.dtype), None

        acc, _ = jax.lax.scan(body, jnp.float32(0.0), keys)
        return acc

    jax.block_until_ready(scanned(products))  # compile
    t0 = time.perf_counter()
    jax.block_until_ready(scanned(products))
    t_jit = (time.perf_counter() - t0) / rounds
    return t_np, t_jit


def run(batch=4, prompt_len=16, max_new=32, runs=3):
    config = get_arch("qwen3-0.6b").reduced()
    model = Model(config)
    params = model.init_params(KEY)
    cluster = ClusterSpec.make([6, 6], [8.0, 0.7])
    prompts = jax.random.randint(
        jax.random.PRNGKey(1), (batch, prompt_len), 0, config.vocab_size
    ).astype(jnp.int32)

    rows, modes = [], {}
    for name, cfg in [
        ("legacy", ServeConfig(block_rows=64, max_decode_steps=max_new,
                               jit_pipeline=False)),
        ("jit", ServeConfig(block_rows=64, max_decode_steps=max_new)),
    ]:
        server = Server(model, params, cluster, cfg)
        tok_s, dt = _time_generate(server, prompts, max_new, runs=runs)
        modes[name] = {"tokens_per_s": tok_s, "generate_s": dt,
                       "server": server}
        rows.append({"path": name, "tokens_per_s": tok_s, "generate_s": dt})

    head = modes["jit"]["server"].coded_head
    h = jax.random.normal(KEY, (batch, config.d_model), dtype=jnp.float32)
    products = head.worker_products(h)
    t_np, t_jit = _time_decode(head, products)

    # per-phase split of the jit pipeline: the batched prefill is timed
    # alone (the same ``_prefill_into_cache`` program the compiled
    # generate runs), the decode share is what remains of a generate
    # call, and the erasure solve is the scanned jit decode above. The
    # RATIOS between phases are same-process and machine-invariant —
    # perf_gate enforces them so one phase cannot silently eat the
    # others' budget (a prefill falling back to the sequential scan
    # multiplies prefill_per_decode_token ~prompt_len-fold).
    srv = modes["jit"]["server"]
    cache0 = model.init_cache(batch, prompt_len + max_new)
    jax.block_until_ready(srv._prefill_fn(params, cache0, prompts)[0])
    t0 = time.perf_counter()
    for _ in range(runs):
        jax.block_until_ready(srv._prefill_fn(params, cache0, prompts)[0])
    prefill_s = (time.perf_counter() - t0) / runs
    decode_per_token_s = max(
        (modes["jit"]["generate_s"] - prefill_s) / max_new, 1e-12
    )
    phases = {
        "prefill_s": prefill_s,
        "decode_per_token_s": decode_per_token_s,
        "erasure_solve_s": t_jit,
        "prefill_per_decode_token": prefill_s / decode_per_token_s,
        "erasure_share_of_decode": t_jit / decode_per_token_s,
    }

    # paged/dense serving A/B (ratio golden for the perf gate)
    from benchmarks.serve_frontend import paged_dense_ab

    paged = paged_dense_ab(reduced=True, repeats=max(runs, 2),
                           assert_gates=False)

    speedup = modes["jit"]["tokens_per_s"] / modes["legacy"]["tokens_per_s"]
    record = {
        "arch": "qwen3-0.6b (reduced)",
        "batch": batch,
        "prompt_len": prompt_len,
        "max_new": max_new,
        "cluster": "6:8.0,6:0.7",
        "block_rows": 64,
        "kb": head.kb,
        "nb": head.nb,
        "legacy": {k: v for k, v in modes["legacy"].items() if k != "server"},
        "jit": {k: v for k, v in modes["jit"].items() if k != "server"},
        "speedup_tokens_per_s": speedup,
        "decode_latency_s": {"numpy": t_np, "jit": t_jit,
                             "speedup": t_np / t_jit},
        "phases": phases,
        "paged": paged,
    }
    path = save("serve_throughput", record)
    print(table(rows, ["path", "tokens_per_s", "generate_s"]))
    print(f"tokens/s speedup (jit / legacy): {speedup:.2f}x")
    print(f"per-round decode: numpy {t_np * 1e3:.3f} ms "
          f"vs jit {t_jit * 1e3:.3f} ms ({t_np / t_jit:.2f}x)")
    print(f"phases: prefill {prefill_s * 1e3:.3f} ms "
          f"({phases['prefill_per_decode_token']:.2f} decode tokens), "
          f"decode/token {decode_per_token_s * 1e3:.3f} ms, "
          f"erasure solve {t_jit * 1e3:.3f} ms "
          f"({phases['erasure_share_of_decode']:.2f} of a decode token)")
    print(f"paged / dense serve tokens/s: "
          f"{paged['tokens_per_s_ratio']:.2f}x "
          f"(KV bytes {paged['kv_bytes_ratio']:.2f}x smaller)")
    print(f"wrote {path}")
    assert speedup > 1.0, "jit pipeline must beat the legacy numpy path"
    return record


if __name__ == "__main__":
    run()
