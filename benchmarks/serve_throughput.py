"""Serving throughput: legacy numpy-decode host loop vs jit-native pipeline.

``PYTHONPATH=src python -m benchmarks.serve_throughput``

Measures a full ``Server.generate`` (prefill + coded greedy decode) under
both paths on CPU:

* ``legacy``  — ``ServeConfig(jit_pipeline=False)``: one Python round-trip
  per prefill token and per decoded token; erasure decode on the host via
  ``np.linalg.solve`` (the pre-refactor hot path).
* ``jit``     — the default pipeline: the whole generation is one compiled
  program (two ``lax.scan``s), finish masks sampled and erasure decode
  solved on-device.

Also times the erasure decode alone (numpy oracle vs jitted fixed-shape
decode) for a per-token decode-latency number, breaks the jit pipeline
into per-phase timings (batched prefill vs per-token decode vs erasure
solve — the ratios ``benchmarks/perf_gate.py`` gates separately), runs
the paged/dense serving A/B (``benchmarks.serve_frontend``) for the
paged tokens-per-second ratio golden, and writes
``artifacts/bench/serve_throughput.json`` — the serving-path companion to
the paper-figure latency benchmarks.
"""
from __future__ import annotations

import contextlib
import os
import time

import jax
import jax.numpy as jnp
import numpy as np
from jax.profiler import TraceAnnotation

from benchmarks.common import save, table
from repro.configs import get_arch
from repro.core.runtime_model import ClusterSpec
from repro.models.model import Model
from repro.runtime.serve_loop import ServeConfig, Server

KEY = jax.random.PRNGKey(0)

#: TraceAnnotation names wrapping each measured phase — near-free when
#: no profiler is active, so they always stay on; under ``perf_gate.py
#: --profile`` they become the attribution windows ``repro.obs.profile``
#: buckets op events into (DESIGN.md §14). Only the compiled-pipeline
#: phases are captured — the legacy host loop and the paged A/B drive
#: thousands of per-token dispatches that flood the profiler's host
#: event buffer — and each phase runs in its OWN capture session
#: (``_capture``) so one phase's op volume cannot exhaust the
#: fixed-size buffer before a later phase's annotation lands.
PROFILE_PHASES = ("jit_generate", "erasure_decode", "prefill")


@contextlib.contextmanager
def _capture(profile_dir, name):
    """One ``jax.profiler`` session into ``profile_dir/name`` (no-op
    when profiling is off). ``repro.obs.profile.summarize`` merges the
    per-phase subdirs back into one summary."""
    if profile_dir is None:
        yield
        return
    jax.profiler.start_trace(os.path.join(profile_dir, name))
    try:
        yield
    finally:
        jax.profiler.stop_trace()


def _time_generate(server, prompts, max_new, *, runs=3, pad_s=0.0,
                   phase="generate"):
    """Timed generate reps; ``pad_s`` sleeps inside each timed iteration
    — the perf gate's regression-injection hook (host-side wall-time
    growth with flat op totals, exactly what a real dispatch stall looks
    like to the profile diff). The ``phase`` annotation window wraps
    ONLY the timed loop, so warmup/compile events never pollute the
    phase's op attribution."""
    out = server.generate(prompts, max_new, key=KEY)  # warmup / compile
    jax.block_until_ready(out)
    with TraceAnnotation(phase):
        t0 = time.perf_counter()
        for i in range(runs):
            out = server.generate(
                prompts, max_new, key=jax.random.fold_in(KEY, i)
            )
            jax.block_until_ready(out)
            if pad_s > 0:
                time.sleep(pad_s)
        dt = (time.perf_counter() - t0) / runs
    return prompts.shape[0] * max_new / dt, dt


def _time_decode_numpy(head, products, *, rounds=50):
    """Per-round host-path decode latency: a Python round-trip per round
    (mask to numpy, ``np.linalg.solve``). Runs OUTSIDE the profiler
    capture — 50 rounds of per-op host dispatch flood the TraceMe
    buffer and starve later annotation windows (see PROFILE_PHASES)."""
    keys = jax.random.split(KEY, rounds)
    t0 = time.perf_counter()
    for i in range(rounds):
        mask = head.sample_finish_mask(keys[i])
        head.decode_logits(products, mask)
    return (time.perf_counter() - t0) / rounds


def _time_decode_jit(head, products, *, rounds=50):
    """Per-round jitted erasure-decode latency, measured the way the
    serving pipeline actually runs it — amortized inside one compiled
    ``lax.scan`` over per-round fold_in'd keys, so per-call dispatch
    overhead (which the pipeline eliminates) is not billed to it.
    """
    keys = jax.random.split(KEY, rounds)
    deadline = head.deadline

    @jax.jit
    def scanned(products):
        def body(acc, k):
            m = head.finish_mask_jit(k, deadline)
            logits, ok = head.decode_logits_jit(products, m)
            # data dep on every round: nothing gets hoisted out of the scan
            return acc + logits.mean().astype(acc.dtype), None

        acc, _ = jax.lax.scan(body, jnp.float32(0.0), keys)
        return acc

    jax.block_until_ready(scanned(products))  # compile
    with TraceAnnotation("erasure_decode"):
        t0 = time.perf_counter()
        jax.block_until_ready(scanned(products))
        t_jit = (time.perf_counter() - t0) / rounds
    return t_jit


def run(batch=4, prompt_len=16, max_new=32, runs=3, *,
        decode_pad_s=0.0, profile_dir=None):
    """Serving benchmark; ``profile_dir`` captures the compiled-pipeline
    phases (``PROFILE_PHASES``) under ``jax.profiler.trace`` and
    attaches a per-phase op summary (``record["profile_summary"]``) for
    the perf gate's golden diff. ``decode_pad_s`` injects a
    per-iteration sleep into the jit generate timing — the gate's
    forced-regression test hook."""
    config = get_arch("qwen3-0.6b").reduced()
    model = Model(config)
    params = model.init_params(KEY)
    cluster = ClusterSpec.make([6, 6], [8.0, 0.7])
    prompts = jax.random.randint(
        jax.random.PRNGKey(1), (batch, prompt_len), 0, config.vocab_size
    ).astype(jnp.int32)

    rows, modes = [], {}

    def _mode(name, cfg, pad_s):
        server = Server(model, params, cluster, cfg)
        tok_s, dt = _time_generate(
            server, prompts, max_new, runs=runs, pad_s=pad_s,
            phase=f"{name}_generate",
        )
        modes[name] = {"tokens_per_s": tok_s, "generate_s": dt,
                       "server": server}
        rows.append({"path": name, "tokens_per_s": tok_s,
                     "generate_s": dt})

    # legacy runs OUTSIDE any capture (see PROFILE_PHASES)
    _mode("legacy", ServeConfig(block_rows=64, max_decode_steps=max_new,
                                jit_pipeline=False), 0.0)
    with _capture(profile_dir, "generate"):
        _mode("jit", ServeConfig(block_rows=64, max_decode_steps=max_new),
              decode_pad_s)

    # per-phase split of the jit pipeline: the batched prefill is timed
    # alone (the same ``_prefill_into_cache`` program the compiled
    # generate runs), the decode share is what remains of a generate
    # call, and the erasure solve is the scanned jit decode below. The
    # RATIOS between phases are same-process and machine-invariant —
    # perf_gate enforces them so one phase cannot silently eat the
    # others' budget (a prefill falling back to the sequential scan
    # multiplies prefill_per_decode_token ~prompt_len-fold).
    srv = modes["jit"]["server"]
    cache0 = model.init_cache(batch, prompt_len + max_new)
    jax.block_until_ready(  # warmup/compile, outside the capture
        srv._prefill_fn(params, cache0, prompts)[0]
    )
    with _capture(profile_dir, "prefill"):
        with TraceAnnotation("prefill"):
            t0 = time.perf_counter()
            for _ in range(runs):
                jax.block_until_ready(
                    srv._prefill_fn(params, cache0, prompts)[0]
                )
            prefill_s = (time.perf_counter() - t0) / runs

    head = modes["jit"]["server"].coded_head
    h = jax.random.normal(KEY, (batch, config.d_model),
                          dtype=jnp.float32)
    products = head.worker_products(h)
    with _capture(profile_dir, "erasure"):
        t_jit = _time_decode_jit(head, products)
    # host-path decode baseline, outside the capture like the legacy loop
    t_np = _time_decode_numpy(head, products)
    decode_per_token_s = max(
        (modes["jit"]["generate_s"] - prefill_s) / max_new, 1e-12
    )
    phases = {
        "prefill_s": prefill_s,
        "decode_per_token_s": decode_per_token_s,
        "erasure_solve_s": t_jit,
        "prefill_per_decode_token": prefill_s / decode_per_token_s,
        "erasure_share_of_decode": t_jit / decode_per_token_s,
    }

    # paged/dense serving A/B (ratio golden for the perf gate); outside
    # the capture like the legacy loop (see PROFILE_PHASES)
    from benchmarks.serve_frontend import paged_dense_ab

    paged = paged_dense_ab(reduced=True, repeats=max(runs, 2),
                           assert_gates=False)

    speedup = modes["jit"]["tokens_per_s"] / modes["legacy"]["tokens_per_s"]
    record = {
        "arch": "qwen3-0.6b (reduced)",
        "batch": batch,
        "prompt_len": prompt_len,
        "max_new": max_new,
        "cluster": "6:8.0,6:0.7",
        "block_rows": 64,
        "kb": head.kb,
        "nb": head.nb,
        "legacy": {k: v for k, v in modes["legacy"].items() if k != "server"},
        "jit": {k: v for k, v in modes["jit"].items() if k != "server"},
        "speedup_tokens_per_s": speedup,
        "decode_latency_s": {"numpy": t_np, "jit": t_jit,
                             "speedup": t_np / t_jit},
        "phases": phases,
        "paged": paged,
    }
    if profile_dir is not None:
        from repro.obs.profile import summarize

        record["profile_summary"] = summarize(profile_dir, PROFILE_PHASES)
    path = save("serve_throughput", record)
    print(table(rows, ["path", "tokens_per_s", "generate_s"]))
    print(f"tokens/s speedup (jit / legacy): {speedup:.2f}x")
    print(f"per-round decode: numpy {t_np * 1e3:.3f} ms "
          f"vs jit {t_jit * 1e3:.3f} ms ({t_np / t_jit:.2f}x)")
    print(f"phases: prefill {prefill_s * 1e3:.3f} ms "
          f"({phases['prefill_per_decode_token']:.2f} decode tokens), "
          f"decode/token {decode_per_token_s * 1e3:.3f} ms, "
          f"erasure solve {t_jit * 1e3:.3f} ms "
          f"({phases['erasure_share_of_decode']:.2f} of a decode token)")
    print(f"paged / dense serve tokens/s: "
          f"{paged['tokens_per_s_ratio']:.2f}x "
          f"(KV bytes {paged['kv_bytes_ratio']:.2f}x smaller)")
    print(f"wrote {path}")
    assert speedup > 1.0, "jit pipeline must beat the legacy numpy path"
    return record


if __name__ == "__main__":
    run()
