"""Run every paper-figure benchmark + the roofline report.

``PYTHONPATH=src python -m benchmarks.run [--only fig4,fig9] [--skip roofline]``
(``--list`` prints the registered benchmark names and exits.)
"""
from __future__ import annotations

import argparse
import time

from benchmarks import (
    alloc_fastpath,
    fig2,
    fig3,
    fig4,
    fig5,
    fig6,
    fig7,
    fig8,
    fig9,
    fig_adapt,
    fig_comm,
    fig_grad,
    perf_gate,
    roofline,
    serve_frontend,
    serve_throughput,
)


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--only", default=None,
                    help="comma-separated subset to run, e.g. fig4,fig9")
    ap.add_argument("--skip", default=None,
                    help="comma-separated subset to leave out, e.g. "
                         "serve_throughput,roofline")
    ap.add_argument("--list", action="store_true",
                    help="print registered benchmark names and exit")
    args = ap.parse_args()
    mods = {
        "fig2": fig2, "fig3": fig3, "fig4": fig4, "fig5": fig5,
        "fig6": fig6, "fig7": fig7, "fig8": fig8, "fig9": fig9,
        "fig_comm": fig_comm, "fig_grad": fig_grad, "fig_adapt": fig_adapt,
        "alloc_fastpath": alloc_fastpath, "roofline": roofline,
        "serve_throughput": serve_throughput,
        "serve_frontend": serve_frontend,
        # after serve_throughput: gates the measurement it just re-based
        "perf_gate": perf_gate,
    }
    if args.list:
        print("\n".join(mods))
        return
    names = args.only.split(",") if args.only else list(mods)
    skips = args.skip.split(",") if args.skip else []
    unknown = [n for n in names + skips if n not in mods]
    if unknown:
        raise SystemExit(
            f"unknown benchmark(s): {', '.join(unknown)}; "
            f"available: {', '.join(mods)}"
        )
    names = [n for n in names if n not in set(skips)]
    if not names:
        raise SystemExit("nothing to run: --skip removed every benchmark")
    for name in names:
        print(f"\n{'=' * 72}\n{name}\n{'=' * 72}")
        t0 = time.perf_counter()
        mods[name].run()
        print(f"[{name} done in {time.perf_counter() - t0:.1f}s]")


if __name__ == "__main__":
    main()
