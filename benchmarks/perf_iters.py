"""§Perf hillclimb driver: run named optimization iterations per cell.

    PYTHONPATH=src python -m benchmarks.perf_iters [--only grok_train]

Each iteration re-lowers + re-analyses one (arch x shape) cell on the
single-pod mesh with one change applied, and saves the record to
artifacts/perf/<cell>__<iter>.json. EXPERIMENTS.md §Perf narrates the
hypothesis -> change -> before -> after chain from these artifacts.
NOTE: must run in a fresh process (dryrun import sets the 512-device
flag); this module imports repro.launch.dryrun first for that reason.
"""
from repro.launch import dryrun  # noqa: F401  (sets XLA_FLAGS first)

import argparse  # noqa: E402
import dataclasses  # noqa: E402
import json  # noqa: E402
import os  # noqa: E402

from repro.configs import get_arch  # noqa: E402
from repro.configs.base import SHAPES_BY_NAME  # noqa: E402
from repro.launch.dryrun import roofline_cell  # noqa: E402

OUT = os.path.join(os.path.dirname(__file__), "..", "artifacts", "perf")

# cell -> list of (iteration-name, config-transform, cell-kwargs)
ITERS = {
    # H1 follow-ups (expert sharding fix itself is rules.py commit;
    # baseline/after recorded already). Memory-dominated now.
    "grok_train": (
        "grok-1-314b", "train_4k",
        [
            ("i2_no_remat",
             lambda c: dataclasses.replace(c, remat=False), {}),
            ("i3_bf16_logits",
             lambda c: dataclasses.replace(c, logits_dtype="bfloat16"), {}),
            ("i4_no_remat_bf16_logits_skip",
             lambda c: dataclasses.replace(
                 c, remat=False, logits_dtype="bfloat16",
                 causal_block_skip=True), {}),
        ],
    ),
    # H2: tiny model, TP collectives dominate -> replicate params.
    "whisper_prefill": (
        "whisper-tiny", "prefill_32k",
        [
            ("i1_pure_dp", lambda c: c, {"param_strategy": "replicated"}),
            ("i2_pure_dp_bf16_logits",
             lambda c: dataclasses.replace(c, logits_dtype="bfloat16"),
             {"param_strategy": "replicated"}),
            ("i3_dp_seq", lambda c: c, {"param_strategy": "dp_seq"}),
            ("i4_dp_seq_bf16_logits",
             lambda c: dataclasses.replace(c, logits_dtype="bfloat16"),
             {"param_strategy": "dp_seq"}),
            ("i5_dp_seq_causal_skip",
             lambda c: dataclasses.replace(
                 c, logits_dtype="bfloat16", causal_block_skip=True),
             {"param_strategy": "dp_seq"}),
        ],
    ),
    # H3: decode is cache-byte bound -> in-place cache + bf16 logits.
    "granite_decode": (
        "granite-3-2b", "decode_32k",
        [
            ("i1_donate_cache", lambda c: c, {"donate_cache": True}),
            ("i2_donate_bf16_logits",
             lambda c: dataclasses.replace(c, logits_dtype="bfloat16"),
             {"donate_cache": True}),
            ("i3_int8_kv",
             lambda c: dataclasses.replace(c, kv_quant=True), {}),
            ("i4_int8_kv_bf16_logits",
             lambda c: dataclasses.replace(
                 c, kv_quant=True, logits_dtype="bfloat16"), {}),
        ],
    ),
}


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--only", default=None)
    args = ap.parse_args()
    os.makedirs(OUT, exist_ok=True)
    cells = {args.only: ITERS[args.only]} if args.only else ITERS
    for cell, (arch, shape_name, iters) in cells.items():
        cfg0 = get_arch(arch)
        shape = SHAPES_BY_NAME[shape_name]
        for name, transform, kwargs in iters:
            rec = roofline_cell(transform(cfg0), shape, multi_pod=False,
                                verbose=True, **kwargs)
            rec["iteration"] = name
            path = os.path.join(OUT, f"{cell}__{name}.json")
            with open(path, "w") as f:
                json.dump(rec, f, indent=1)
            print(f"  -> {path}")


if __name__ == "__main__":
    main()
