"""Fig. 7 — uniform load allocation at various MDS rates vs q.

Paper claim: at q = 1 the rate-2/3 code beats uniform with the optimal
(n*, k) code — i.e. under UNIFORM allocation the best rate is not k/n*.
The proposed (non-uniform) allocation still beats all of them.
"""
from __future__ import annotations

import jax
import numpy as np

from benchmarks.common import KEY, TRIALS, save, table
from benchmarks.fig4 import K, make_cluster
from repro.core.engine import CodedComputeEngine
from repro.core.schemes import Optimal, UniformN

RATES = [0.4, 0.5, 2.0 / 3.0, 0.8, 0.9]


def run(verbose: bool = True, n_total: int = 2500, qs=None,
        trials: int | None = None, k: int = K) -> dict:
    """Paper setting by default; the keyword params let the golden
    regression tests drive a tiny seeded cluster through the same path."""
    base = make_cluster(n_total)
    qs = np.logspace(-2, 1.5, 6) if qs is None else np.asarray(qs, float)
    trials = TRIALS if trials is None else trials
    rows = []
    for i, q in enumerate(qs):
        c = base.scale_mu(float(q))
        key = jax.random.fold_in(KEY, 200 + i)
        opt = CodedComputeEngine(c, k, Optimal())
        row = {
            "q": float(q),
            "proposed": opt.expected_latency(key, trials),
            "uniform_n*": CodedComputeEngine(
                c, k, UniformN(n=opt.allocation.n)
            ).expected_latency(key, trials),
        }
        for rate in RATES:
            row[f"rate_{rate:.2f}"] = CodedComputeEngine(
                c, k, UniformN(n=k / rate)
            ).expected_latency(key, trials)
        rows.append(row)
    q1 = min(rows, key=lambda r: abs(r["q"] - 1.0))
    record = {
        "rows": rows,
        "at_q1_rate23_beats_uniform_nstar": q1["rate_0.67"] < q1["uniform_n*"],
        "proposed_always_best": all(
            r["proposed"] <= min(v for k, v in r.items()
                                 if k not in ("q", "proposed")) * 1.02
            for r in rows
        ),
    }
    if verbose:
        cols = ["q", "proposed", "uniform_n*"] + [f"rate_{r:.2f}" for r in RATES]
        print("Fig 7: uniform allocation rate sweep vs q (N=2500)")
        print(table(rows, cols))
        print(f"q~1: rate-2/3 beats uniform-n*: "
              f"{record['at_q1_rate23_beats_uniform_nstar']} (paper: True)")
        print(f"proposed best everywhere: {record['proposed_always_best']}")
    save("fig7", record)
    return record


if __name__ == "__main__":
    run()
