"""fig_comm — comm-aware vs comm-blind allocation across bandwidth sweeps.

The paper's allocation assumes latency is pure compute; following Sun et
al. (arXiv:2109.11246) each group additionally pays transfer costs
against its link bandwidth (see ``runtime_model.comm_terms``). This
benchmark sweeps a bandwidth scale over a cluster whose FAST workers sit
behind SLOW links (the adversarial case for a comm-blind planner) and
compares, per bandwidth point, the Monte-Carlo latency of:

* ``comm_aware``   — the comm-augmented optimum (numeric deadline solve;
  slow-link groups may receive zero load),
* ``comm_blind``   — the paper's Theorem-2 plan computed WITHOUT looking
  at bandwidths, then evaluated under the comm model,
* ``comm_uniform`` — same total redundancy as comm-aware, split
  uniformly over every worker.

Claims checked: comm_aware tracks its lower bound, never loses to the
comm-blind plan, and converges exactly to the Theorem-2 plan as
bandwidth -> inf (the Lambert-W fast path).
"""
from __future__ import annotations

import jax
import numpy as np

from benchmarks.common import KEY, TRIALS, save, table
from repro.core.engine import CodedComputeEngine
from repro.core.runtime_model import ClusterSpec
from repro.core.schemes import CommAware, CommUniform, Optimal
from repro.core.simulator import simulate_comm_threshold

K = 10_000
# fast compute behind slow links: group bandwidth ratio is the inverse
# of the compute-speed ordering, scaled by the sweep variable b
BW_RATIO = (0.5, 2.0, 8.0)


def make_cluster(b: float, n_scale: int = 1) -> ClusterSpec:
    return ClusterSpec.make(
        [100 * n_scale, 200 * n_scale, 100 * n_scale],
        [4.0, 1.0, 0.5],
        1.0,
        [r * b for r in BW_RATIO],
    )


def run(verbose: bool = True, bs=None, trials: int | None = None,
        n_scale: int = 1) -> dict:
    bs = np.logspace(-1, 2, 7) if bs is None else np.asarray(bs, float)
    trials = TRIALS if trials is None else trials
    aware, uniform = CommAware(), CommUniform()
    rows = []
    for i, b in enumerate(bs):
        c = make_cluster(float(b), n_scale)
        key = jax.random.fold_in(KEY, 500 + i)
        eng = CodedComputeEngine(c, K, aware)
        blind_plan = Optimal().allocate(c, K)
        blind = float(np.mean(np.asarray(simulate_comm_threshold(
            key, c, blind_plan.loads, K, trials,
            upload=aware.upload, download=aware.download,
        ))))
        uni = CodedComputeEngine(c, K, uniform).expected_latency(key, trials)
        row = {
            "b": float(b),
            "comm_aware": eng.expected_latency(key, trials),
            "bound": eng.t_star,
            "comm_blind": blind,
            "comm_uniform": uni,
            "active_groups": int(np.sum(eng.allocation.loads > 0)),
        }
        row["gain_vs_blind"] = row["comm_blind"] / row["comm_aware"]
        rows.append(row)
    # bandwidth -> inf: the comm-aware plan IS the Theorem-2 plan
    c_inf = make_cluster(float("inf"), n_scale)
    p_aware = aware.allocate(c_inf, K)
    p_opt = Optimal().allocate(c_inf, K)
    record = {
        "rows": rows,
        "max_gain_vs_blind": max(r["gain_vs_blind"] for r in rows),
        "aware_never_loses_to_blind": all(
            r["comm_aware"] <= r["comm_blind"] * 1.02 for r in rows
        ),
        "slow_links_excluded_at_low_b": rows[0]["active_groups"]
        < len(BW_RATIO),
        "infinite_bandwidth_matches_optimal": bool(
            np.array_equal(p_aware.loads, p_opt.loads)
            and p_aware.t_star == p_opt.t_star
        ),
    }
    if verbose:
        print("fig_comm: comm-aware vs comm-blind latency vs bandwidth scale")
        print(table(rows, ["b", "comm_aware", "bound", "comm_blind",
                           "comm_uniform", "active_groups", "gain_vs_blind"]))
        print(f"max gain over comm-blind allocation: "
              f"{record['max_gain_vs_blind']:.2f}x")
        print(f"comm-aware never loses to comm-blind: "
              f"{record['aware_never_loses_to_blind']}")
        print(f"slow links excluded at lowest bandwidth: "
              f"{record['slow_links_excluded_at_low_b']}")
        print(f"b->inf plan equals Theorem 2 exactly: "
              f"{record['infinite_bandwidth_matches_optimal']}")
    save("fig_comm", record)
    return record


if __name__ == "__main__":
    run()
