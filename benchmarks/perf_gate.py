"""Performance regression gate: measured serving perf vs committed goldens.

``PYTHONPATH=src python -m benchmarks.perf_gate [--tolerance 0.2]
[--absolute] [--update-golden] [--profile]``

Correctness regressions already fail CI; this module makes *performance*
regressions do the same. It re-measures the serving path the way
``benchmarks/serve_throughput.py`` does (legacy numpy host loop vs the
jit pipeline, plus the per-round erasure decode) and compares against
the committed golden ``artifacts/bench/serve_throughput.json`` with a
tolerance band. On a regression past the band it exits non-zero, so the
CI fast lane goes red on a >=20% tokens/s or per-round decode-latency
regression the same way it does on a failing test.

Two metric classes, because shared CI runners are not the machine that
wrote the golden:

* **ratio metrics** (always enforced) — jit/legacy tokens-per-second
  speedup, numpy/jit per-round decode-latency speedup, the PER-PHASE
  ratios (batched prefill vs per-token decode vs erasure solve), and
  the paged/dense serving tokens-per-second ratio. All sides of every
  ratio run on the same machine in the same process, so machine speed
  divides out; a drop means the *architecture* regressed (e.g. a host
  sync sneaking into the compiled pipeline, or prefill falling back to
  the sequential scan), which is exactly what a perf gate exists to
  catch.
* **absolute metrics** (warn-only unless ``--absolute``) — raw jit
  tokens/s and per-round decode seconds. Meaningful on a stable
  dedicated runner; noise on shared hardware, hence the flag.

The fresh measurement is redirected to a temp dir so the gate NEVER
overwrites the golden it compares against; ``--update-golden`` is the
explicit re-baseline path. Results (per-metric rows + ``perf_gate``
telemetry events, DESIGN.md §8) land in
``artifacts/bench/perf_gate.json`` and upload with the other bench
artifacts in CI.

``--profile`` (DESIGN.md §14) wraps the gated measurement in
``jax.profiler.trace``: each benchmark phase runs under a
``TraceAnnotation`` window, ``repro.obs.profile`` buckets the captured
op events per phase, and — when the golden was re-baselined with
``--update-golden --profile`` — any failing ratio row is reported WITH
the phase whose wall time grew most vs the golden and its top-K op
diff, so the gate names what regressed, not just that something did.
"""
from __future__ import annotations

import argparse
import json
import os
import tempfile

from benchmarks import common, serve_throughput
from repro.runtime.telemetry import Telemetry

GOLDEN = "serve_throughput"

#: (name, path into the record, higher-is-better) — enforced ratios.
#: The per-phase rows gate each serving phase separately: a regression
#: confined to prefill (e.g. losing the batched splice) or to the
#: erasure solve moves its own ratio even when end-to-end tokens/s
#: hides it behind the other phases.
RATIO_METRICS = (
    ("speedup_tokens_per_s", ("speedup_tokens_per_s",), True),
    ("decode_speedup", ("decode_latency_s", "speedup"), True),
    ("prefill_per_decode_token",
     ("phases", "prefill_per_decode_token"), False),
    ("erasure_share_of_decode",
     ("phases", "erasure_share_of_decode"), False),
    ("paged_over_dense_tokens_per_s",
     ("paged", "tokens_per_s_ratio"), True),
)
#: absolute metrics: machine-dependent, warn-only without --absolute
ABS_METRICS = (
    ("jit_tokens_per_s", ("jit", "tokens_per_s"), True),
    ("jit_decode_latency_s", ("decode_latency_s", "jit"), False),
)


def _get(record: dict, path) -> float:
    for p in path:
        record = record[p]
    return float(record)


def _measure(runs: int, profile_dir=None, decode_pad_s: float = 0.0) -> dict:
    """Fresh serve_throughput record, written to a temp dir — the
    committed golden must survive the measurement that is judged
    against it. ``profile_dir`` captures an XLA profile of the
    measurement; ``decode_pad_s`` injects a forced decode regression
    (testing hook)."""
    keep = common.ARTIFACTS
    tmp = tempfile.mkdtemp(prefix="perf_gate_")
    common.ARTIFACTS = tmp
    try:
        return serve_throughput.run(
            runs=runs, profile_dir=profile_dir, decode_pad_s=decode_pad_s
        )
    finally:
        common.ARTIFACTS = keep


def run(tolerance: float = 0.2, absolute: bool = False, runs: int = 3,
        update_golden: bool = False, profile: bool = False,
        profile_dir: str | None = None,
        inject_decode_pad_s: float = 0.0):
    golden_path = os.path.join(common.ARTIFACTS, f"{GOLDEN}.json")
    if profile and profile_dir is None:
        profile_dir = os.path.join(common.ARTIFACTS, "profile")
    if update_golden:
        # writes the golden (with its phase op summary under --profile,
        # the baseline the gating path diffs against)
        record = serve_throughput.run(runs=runs, profile_dir=profile_dir)
        print(f"re-baselined golden {os.path.abspath(golden_path)}")
        return record
    if not os.path.exists(golden_path):
        raise SystemExit(
            f"no golden at {golden_path}; run with --update-golden first"
        )
    with open(golden_path) as f:
        golden = json.load(f)
    if profile or inject_decode_pad_s:
        measured = _measure(runs, profile_dir, inject_decode_pad_s)
    else:
        # positional single-arg call: the stable interface tests stub
        measured = _measure(runs)

    tel = Telemetry(None)
    rows, failures = [], []
    checks = [(m, True) for m in RATIO_METRICS] + \
             [(m, absolute) for m in ABS_METRICS]
    for (name, path, higher), enforced in checks:
        m, g = _get(measured, path), _get(golden, path)
        # one-sided band: only regressions gate — a faster run passes
        bound = g * (1 - tolerance) if higher else g * (1 + tolerance)
        ok = m >= bound if higher else m <= bound
        rows.append({
            "metric": name, "measured": m, "golden": g, "bound": bound,
            "passed": ok, "enforced": enforced,
        })
        tel.event(
            "perf_gate", metric=name, measured=m, golden=g, bound=bound,
            tolerance=tolerance, passed=ok, enforced=enforced,
        )
        if enforced and not ok:
            failures.append(
                f"{name}: measured {m:.4g} vs golden {g:.4g} "
                f"(bound {bound:.4g}, tolerance {tolerance:.0%})"
            )
    print(common.table(rows, ["metric", "measured", "golden", "bound",
                              "passed", "enforced"]))
    record = {
        "golden": GOLDEN,
        "tolerance": tolerance,
        "absolute_enforced": absolute,
        "runs": runs,
        "metrics": rows,
        "passed": not failures,
        "events": list(tel.events),
    }
    # op-level attribution (§14): with --profile AND a golden captured
    # the same way, a failing ratio row comes with the phase whose wall
    # time grew most vs the baseline and its top-K op diff — the gate
    # then *explains* the regression instead of just asserting it
    diff_text = None
    if profile:
        from repro.obs.profile import diff_summaries, format_diff

        record["profile_summary"] = measured.get("profile_summary")
        if measured.get("profile_summary") and golden.get("profile_summary"):
            diff = diff_summaries(
                measured["profile_summary"], golden["profile_summary"]
            )
            record["profile_diff"] = diff
            diff_text = format_diff(diff)
            if failures:
                diff_text += (
                    f"\nregressed phase: {diff['worst_phase']} "
                    f"(x{diff['worst_ratio']:.2f} wall vs golden)"
                )
        elif failures:
            diff_text = (
                "no golden profile summary to diff against — re-baseline "
                "with --update-golden --profile"
            )
    path = common.save("perf_gate", record)
    print(f"wrote {path}")
    if diff_text:
        print(diff_text)
    if failures:
        msg = "perf gate FAILED:\n  " + "\n  ".join(failures)
        if diff_text:
            msg += "\n" + diff_text
        raise SystemExit(msg)
    print(f"perf gate passed ({len(rows)} metrics, "
          f"tolerance {tolerance:.0%})")
    return record


def main():
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--tolerance", type=float, default=0.2,
                    help="allowed relative regression before the gate "
                         "fails (default 0.2 = 20%%, generous for shared "
                         "CI runners)")
    ap.add_argument("--absolute", action="store_true",
                    help="also ENFORCE the absolute metrics (raw tokens/s "
                         "and decode seconds); default warns only — "
                         "absolutes are machine-dependent")
    ap.add_argument("--runs", type=int, default=3,
                    help="timed generate repetitions per path")
    ap.add_argument("--update-golden", action="store_true",
                    help="re-baseline: overwrite the committed golden "
                         "with a fresh measurement instead of gating "
                         "(add --profile to bake the phase op summary "
                         "into the golden)")
    ap.add_argument("--profile", action="store_true",
                    help="capture the measurement under "
                         "jax.profiler.trace and attach a per-phase "
                         "top-K op diff vs the golden's summary to any "
                         "failing ratio row")
    ap.add_argument("--profile-dir", default=None,
                    help="where the XLA capture lands (default "
                         "artifacts/bench/profile; uploaded with the "
                         "bench artifacts in CI)")
    ap.add_argument("--inject-decode-pad", type=float, default=0.0,
                    metavar="SECONDS",
                    help="testing hook: sleep this long inside each "
                         "timed jit-generate iteration to force a "
                         "decode regression the gate must catch")
    args = ap.parse_args()
    run(tolerance=args.tolerance, absolute=args.absolute, runs=args.runs,
        update_golden=args.update_golden, profile=args.profile,
        profile_dir=args.profile_dir,
        inject_decode_pad_s=args.inject_decode_pad)


if __name__ == "__main__":
    main()
