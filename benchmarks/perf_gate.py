"""Performance regression gate: measured serving perf vs committed goldens.

``PYTHONPATH=src python -m benchmarks.perf_gate [--tolerance 0.2]
[--absolute] [--update-golden]``

Correctness regressions already fail CI; this module makes *performance*
regressions do the same. It re-measures the serving path the way
``benchmarks/serve_throughput.py`` does (legacy numpy host loop vs the
jit pipeline, plus the per-round erasure decode) and compares against
the committed golden ``artifacts/bench/serve_throughput.json`` with a
tolerance band. On a regression past the band it exits non-zero, so the
CI fast lane goes red on a >=20% tokens/s or per-round decode-latency
regression the same way it does on a failing test.

Two metric classes, because shared CI runners are not the machine that
wrote the golden:

* **ratio metrics** (always enforced) — jit/legacy tokens-per-second
  speedup, numpy/jit per-round decode-latency speedup, the PER-PHASE
  ratios (batched prefill vs per-token decode vs erasure solve), and
  the paged/dense serving tokens-per-second ratio. All sides of every
  ratio run on the same machine in the same process, so machine speed
  divides out; a drop means the *architecture* regressed (e.g. a host
  sync sneaking into the compiled pipeline, or prefill falling back to
  the sequential scan), which is exactly what a perf gate exists to
  catch.
* **absolute metrics** (warn-only unless ``--absolute``) — raw jit
  tokens/s and per-round decode seconds. Meaningful on a stable
  dedicated runner; noise on shared hardware, hence the flag.

The fresh measurement is redirected to a temp dir so the gate NEVER
overwrites the golden it compares against; ``--update-golden`` is the
explicit re-baseline path. Results (per-metric rows + ``perf_gate``
telemetry events, DESIGN.md §8) land in
``artifacts/bench/perf_gate.json`` and upload with the other bench
artifacts in CI.
"""
from __future__ import annotations

import argparse
import json
import os
import tempfile

from benchmarks import common, serve_throughput
from repro.runtime.telemetry import Telemetry

GOLDEN = "serve_throughput"

#: (name, path into the record, higher-is-better) — enforced ratios.
#: The per-phase rows gate each serving phase separately: a regression
#: confined to prefill (e.g. losing the batched splice) or to the
#: erasure solve moves its own ratio even when end-to-end tokens/s
#: hides it behind the other phases.
RATIO_METRICS = (
    ("speedup_tokens_per_s", ("speedup_tokens_per_s",), True),
    ("decode_speedup", ("decode_latency_s", "speedup"), True),
    ("prefill_per_decode_token",
     ("phases", "prefill_per_decode_token"), False),
    ("erasure_share_of_decode",
     ("phases", "erasure_share_of_decode"), False),
    ("paged_over_dense_tokens_per_s",
     ("paged", "tokens_per_s_ratio"), True),
)
#: absolute metrics: machine-dependent, warn-only without --absolute
ABS_METRICS = (
    ("jit_tokens_per_s", ("jit", "tokens_per_s"), True),
    ("jit_decode_latency_s", ("decode_latency_s", "jit"), False),
)


def _get(record: dict, path) -> float:
    for p in path:
        record = record[p]
    return float(record)


def _measure(runs: int) -> dict:
    """Fresh serve_throughput record, written to a temp dir — the
    committed golden must survive the measurement that is judged
    against it."""
    keep = common.ARTIFACTS
    tmp = tempfile.mkdtemp(prefix="perf_gate_")
    common.ARTIFACTS = tmp
    try:
        return serve_throughput.run(runs=runs)
    finally:
        common.ARTIFACTS = keep


def run(tolerance: float = 0.2, absolute: bool = False, runs: int = 3,
        update_golden: bool = False):
    golden_path = os.path.join(common.ARTIFACTS, f"{GOLDEN}.json")
    if update_golden:
        record = serve_throughput.run(runs=runs)  # writes the golden
        print(f"re-baselined golden {os.path.abspath(golden_path)}")
        return record
    if not os.path.exists(golden_path):
        raise SystemExit(
            f"no golden at {golden_path}; run with --update-golden first"
        )
    with open(golden_path) as f:
        golden = json.load(f)
    measured = _measure(runs)

    tel = Telemetry(None)
    rows, failures = [], []
    checks = [(m, True) for m in RATIO_METRICS] + \
             [(m, absolute) for m in ABS_METRICS]
    for (name, path, higher), enforced in checks:
        m, g = _get(measured, path), _get(golden, path)
        # one-sided band: only regressions gate — a faster run passes
        bound = g * (1 - tolerance) if higher else g * (1 + tolerance)
        ok = m >= bound if higher else m <= bound
        rows.append({
            "metric": name, "measured": m, "golden": g, "bound": bound,
            "passed": ok, "enforced": enforced,
        })
        tel.event(
            "perf_gate", metric=name, measured=m, golden=g, bound=bound,
            tolerance=tolerance, passed=ok, enforced=enforced,
        )
        if enforced and not ok:
            failures.append(
                f"{name}: measured {m:.4g} vs golden {g:.4g} "
                f"(bound {bound:.4g}, tolerance {tolerance:.0%})"
            )
    print(common.table(rows, ["metric", "measured", "golden", "bound",
                              "passed", "enforced"]))
    record = {
        "golden": GOLDEN,
        "tolerance": tolerance,
        "absolute_enforced": absolute,
        "runs": runs,
        "metrics": rows,
        "passed": not failures,
        "events": tel.events,
    }
    path = common.save("perf_gate", record)
    print(f"wrote {path}")
    if failures:
        raise SystemExit(
            "perf gate FAILED:\n  " + "\n  ".join(failures)
        )
    print(f"perf gate passed ({len(rows)} metrics, "
          f"tolerance {tolerance:.0%})")
    return record


def main():
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--tolerance", type=float, default=0.2,
                    help="allowed relative regression before the gate "
                         "fails (default 0.2 = 20%%, generous for shared "
                         "CI runners)")
    ap.add_argument("--absolute", action="store_true",
                    help="also ENFORCE the absolute metrics (raw tokens/s "
                         "and decode seconds); default warns only — "
                         "absolutes are machine-dependent")
    ap.add_argument("--runs", type=int, default=3,
                    help="timed generate repetitions per path")
    ap.add_argument("--update-golden", action="store_true",
                    help="re-baseline: overwrite the committed golden "
                         "with a fresh measurement instead of gating")
    args = ap.parse_args()
    run(tolerance=args.tolerance, absolute=args.absolute, runs=args.runs,
        update_golden=args.update_golden)


if __name__ == "__main__":
    main()
