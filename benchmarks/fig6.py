"""Fig. 6 — optimal rate k/n* vs q at N = 2500 (5-group cluster).

Paper claims: rate ~1/2 on q in [1e-1.5, 1e-1]; rate ~0.99 at q = 1e1.5.
"""
from __future__ import annotations

import numpy as np

from benchmarks.common import save, table
from benchmarks.fig4 import K, make_cluster
from repro.core.schemes import Optimal


def run(verbose: bool = True) -> dict:
    scheme = Optimal()
    base = make_cluster(2500)
    qs = np.logspace(-2, 1.5, 15)
    rows = []
    for q in qs:
        plan = scheme.allocate(base.scale_mu(float(q)), K)
        rows.append({"q": float(q), "rate": plan.rate})
    rate_mid = [r["rate"] for r in rows if 10 ** -1.5 <= r["q"] <= 10 ** -1]
    record = {
        "rows": rows,
        "rate_near_half_mid_q": rate_mid,
        "rate_at_large_q": rows[-1]["rate"],
    }
    if verbose:
        print("Fig 6: optimal MDS rate k/n* vs q at N=2500")
        print(table(rows, ["q", "rate"]))
        print(f"rate on [1e-1.5, 1e-1]: {rate_mid} (paper: ~0.5)")
        print(f"rate at q=10^1.5: {rows[-1]['rate']:.3f} (paper: ~0.99)")
    save("fig6", record)
    return record


if __name__ == "__main__":
    run()
